//! Deploying under a hard memory budget: sweep a small hyperparameter
//! grid, then let the planner pick the best model that fits each device
//! class — the paper's `toad_forestsize` deployment story (§4.1–4.2).
//!
//! ```bash
//! cargo run --release --example deploy_budget
//! ```

use toad::coordinator::{DeploymentPlanner, DeviceKind, ModelCard, SimulatedDevice};
use toad::data::synth::PaperDataset;
use toad::data::train_test_split;
use toad::gbdt::GbdtParams;
use toad::sweep::table::{human_bytes, render};
use toad::toad::{train_toad, train_toad_with_budget, ToadParams};

fn main() {
    let ds = PaperDataset::CovertypeBinary;
    let data = ds.generate(1).select(&(0..8000).collect::<Vec<_>>());
    let (train_set, test_set) = train_test_split(&data, 0.2, 1);
    println!("dataset: {} ({} train rows)", ds.name(), train_set.n_rows());

    // Candidate sweep: rounds × depth × penalties.
    let mut planner = DeploymentPlanner::new();
    for rounds in [8usize, 32, 128] {
        for depth in [2usize, 3] {
            for (iota, xi) in [(0.0, 0.0), (2.0, 1.0), (16.0, 8.0)] {
                let params = ToadParams::new(GbdtParams::paper(rounds, depth), iota, xi);
                let m = train_toad(&train_set, &params);
                planner.add_candidate(ModelCard {
                    id: format!("r{rounds}_d{depth}_i{iota}_x{xi}"),
                    score: m.model.score(&test_set),
                    size_bytes: m.size_bytes(),
                    blob: m.blob,
                });
            }
        }
    }
    println!("{} candidates swept", planner.candidates().len());

    // Pareto frontier (nondominated solutions, paper §4.4).
    let rows: Vec<Vec<String>> = planner
        .pareto_frontier()
        .iter()
        .map(|c| vec![c.id.clone(), human_bytes(c.size_bytes), format!("{:.4}", c.score)])
        .collect();
    println!("\nquality-memory Pareto frontier:");
    print!("{}", render(&["model", "size", "accuracy"], &rows));

    // Deploy the best fit per device class.
    println!("\ndeployments:");
    for kind in [DeviceKind::TinyNode, DeviceKind::UnoR4, DeviceKind::Esp32S3] {
        let mut dev = SimulatedDevice::new(0, kind);
        match planner.deploy_to(&mut dev) {
            Ok(id) => println!(
                "  {:?} (budget {}): deployed `{id}` ({})",
                kind,
                human_bytes(dev.budget_bytes),
                human_bytes(dev.model_size().unwrap()),
            ),
            Err(e) => println!("  {kind:?}: {e}"),
        }
    }

    // Direct budget-bounded training (`toad_forestsize`): grow until the
    // encoded model would exceed 1 KB.
    let mut params = ToadParams::new(GbdtParams::paper(256, 2), 2.0, 1.0);
    params.forestsize_bytes = Some(1024);
    let budgeted = train_toad_with_budget(&train_set, &params);
    println!(
        "\nforestsize=1KB training: {} trees, {} bytes, accuracy {:.4}",
        budgeted.model.n_trees(),
        budgeted.size_bytes(),
        budgeted.model.score(&test_set)
    );
}
