//! End-to-end driver: the full three-layer system on a realistic
//! workload, including a mid-traffic hot-swap.
//!
//! * trains a grid of compact ToaD candidates on the Covertype-binary
//!   stand-in (the paper's Fig. 4 protocol),
//! * deploys the best budget-fitting candidate to a fleet of simulated
//!   memory-constrained devices (on-device bit-packed inference + MCU
//!   time accounting),
//! * serves the same key through a **registry-backed gateway**:
//!   dynamic batching with bounded-queue admission control into the
//!   quantized-threshold columnar engine,
//! * hammers `FleetServer::submit` from several threads while a
//!   planner `replan` publishes a better candidate into the registry —
//!   the serving version swaps live, with no dropped or torn replies,
//! * reports accuracy, latency percentiles, throughput, and how many
//!   requests each registry version served,
//! * serves the *same* published model to two device classes through
//!   per-class gateways that differ only in their adaptive exit
//!   tolerance, reporting per-class mean trees evaluated.
//!
//! ```bash
//! cargo run --release --example iot_fleet
//! ```

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::time::{Duration, Instant};
use toad::coordinator::batcher::SubmitError;
use toad::coordinator::{
    BatcherConfig, ClassAssignment, DeploymentPlanner, DeviceKind, FleetServer, ModelCard,
    SimulatedDevice,
};
use toad::data::synth::PaperDataset;
use toad::data::train_test_split;
use toad::gbdt::GbdtParams;
use toad::inference::AdaptivePolicy;
use toad::sweep::table::human_bytes;
use toad::toad::{train_toad, ToadParams};

fn main() {
    // ---- sweep a small candidate grid --------------------------------
    let ds = PaperDataset::CovertypeBinary;
    let data = ds.generate(7).select(&(0..12_000).collect::<Vec<_>>());
    let (train_set, test_set) = train_test_split(&data, 0.2, 7);

    let mut planner = DeploymentPlanner::new();
    for (rounds, iota, xi) in [(16usize, 2.0, 1.0), (64, 2.0, 1.0)] {
        let params = ToadParams::new(GbdtParams::paper(rounds, 3), iota, xi);
        let m = train_toad(&train_set, &params);
        let card = ModelCard {
            id: format!("cov_r{rounds}"),
            score: m.model.score(&test_set),
            size_bytes: m.size_bytes(),
            blob: m.blob.clone(),
        };
        println!(
            "candidate {}: {} trees, {}, accuracy {:.4}",
            card.id,
            m.model.n_trees(),
            human_bytes(card.size_bytes),
            card.score
        );
        planner.add_candidate(card);
    }

    // ---- fleet: four devices running the best packed fit locally -----
    let mut server = FleetServer::new();
    for id in 0..4 {
        let mut dev = SimulatedDevice::new(id, DeviceKind::UnoR4);
        let chosen = planner.deploy_to(&mut dev).expect("a candidate fits 32 KB");
        if id == 0 {
            println!("device fleet runs `{chosen}` ({:?})", DeviceKind::UnoR4);
        }
        server.add_device("cov", dev);
    }

    // ---- gateway: registry-backed batched inference ------------------
    server.add_registry_gateway(
        "cov",
        BatcherConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(1),
            queue_depth: 4096,
            ..Default::default()
        },
    );
    // Initial publish: a budget that admits only the smallest
    // candidate (as if the gateway host were memory-constrained at
    // launch), so the later replan has a strictly better fit to find.
    let small_budget = planner.candidates().iter().map(|c| c.size_bytes).min().unwrap();
    let d1 = planner
        .replan(server.registry(), "cov", small_budget)
        .expect("smallest candidate fits")
        .expect("first publish");
    println!(
        "gateway serves `{}` as v{} (budget {})",
        d1.card.id,
        d1.version,
        human_bytes(small_budget)
    );

    // Warm-up round: one request per replica (4 devices + the
    // gateway), so the launch version provably serves before the swap
    // regardless of how slowly the serving threads spin up.
    for i in 0..5 {
        server.predict("cov", test_set.row(i)).expect("warm-up request");
    }

    // ---- serve a sensor stream from several threads ------------------
    let n_requests = 2000usize;
    let n_test = test_set.n_rows();
    let n_threads = 4usize;
    let correct = AtomicUsize::new(0);
    let shed = AtomicUsize::new(0);
    let swapped = AtomicBool::new(false);
    let start = Instant::now();

    std::thread::scope(|s| {
        for t in 0..n_threads {
            let server = &server;
            let test_set = &test_set;
            let correct = &correct;
            let shed = &shed;
            s.spawn(move || {
                let per_thread = n_requests / n_threads;
                for r in 0..per_thread {
                    let i = (t * per_thread + r) % n_test;
                    let ticket = match server.submit("cov", test_set.row(i)) {
                        Ok(tk) => tk,
                        Err(SubmitError::Overloaded { .. }) => {
                            shed.fetch_add(1, Ordering::Relaxed);
                            continue;
                        }
                        Err(e) => panic!("submit failed: {e}"),
                    };
                    let reply = ticket.wait().expect("published key serves");
                    if (reply.scores[0] > 0.0) as usize == test_set.labels[i] {
                        correct.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }

        // Mid-traffic: the budget rises (say the gateway host grew) and
        // the planner publishes the better candidate — a live hot-swap
        // while the threads above keep submitting.
        let server = &server;
        let planner = &planner;
        let swapped = &swapped;
        s.spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            let dep = planner
                .replan(server.registry(), "cov", usize::MAX)
                .expect("candidates exist")
                .unwrap_or_else(|| {
                    // Scores tied (rare): roll the best candidate out
                    // anyway so the demo always shows a live swap.
                    let best = planner.best_under(usize::MAX).expect("candidates");
                    let model = toad::layout::decode(&best.blob);
                    server.registry().publish("cov", best.clone(), model.quantize())
                });
            swapped.store(true, Ordering::Relaxed);
            println!("hot-swap: `{}` published as v{} mid-traffic", dep.card.id, dep.version);
        });
    });
    let wall = start.elapsed();

    // ---- report -------------------------------------------------------
    let served = server.metrics("cov").unwrap();
    let n_served = served.count();
    println!("\nserved {n_served} requests in {wall:.2?} from {n_threads} threads");
    let n_shed = shed.load(Ordering::Relaxed);
    if n_shed > 0 {
        println!("backpressure shed {n_shed} requests at the bounded queue");
    }
    println!(
        "accuracy over stream: {:.4}",
        correct.load(Ordering::Relaxed) as f64 / n_served.max(1) as f64
    );
    println!("latency/throughput:   {}", served.summary(wall));
    let counts = served.version_counts();
    println!("requests per serving version (v0 = static device fleet):");
    for (v, c) in &counts {
        println!("  v{v}: {c}");
    }
    assert!(swapped.load(Ordering::Relaxed), "replan must have published an upgrade");
    assert!(
        counts.iter().any(|&(v, _)| v == d1.version),
        "the launch version must have served the pre-swap traffic"
    );
    // The teeth of the demo: traffic continues for ~100ms+ after the
    // 30ms replan, so the *new* version must actually have served
    // requests — this fails if the gateway ever caches its first
    // resolved deployment instead of re-resolving per flush.
    assert!(
        counts.iter().any(|&(v, _)| v > d1.version),
        "the hot-swapped version must have served mid-stream traffic"
    );
    println!(
        "simulated on-device compute: {:.1} ms across the fleet",
        server.fleet_sim_busy_seconds() * 1e3
    );

    // ---- device classes: one model, per-class exit tolerances --------
    // A line-powered hub wants exact scores; a battery sensor accepts a
    // margin-bounded answer for fewer trees walked per row. Both
    // classes resolve the same registry key, so the hot-swap above
    // upgraded every class at once.
    let classes = [
        ClassAssignment { class: "sensor".into(), policy: AdaptivePolicy::Margin(0.25) },
        ClassAssignment { class: "hub".into(), policy: AdaptivePolicy::Exact },
    ];
    let (dep, gateways) = planner
        .replan_classes(server.registry(), "cov", usize::MAX, &classes)
        .expect("candidates exist");
    server.add_class_gateways("cov", &gateways);
    println!("\ndevice classes share `{}` v{}:", dep.card.id, dep.version);
    let n_probe = 400usize;
    let mut class_trees = Vec::new();
    for class in ["sensor", "hub"] {
        let route = format!("cov@{class}");
        let mut trees = 0u64;
        let mut agree = 0usize;
        for i in 0..n_probe {
            let reply = server.submit(&route, test_set.row(i)).unwrap().wait().unwrap();
            trees += u64::from(reply.trees_evaluated);
            if (reply.scores[0] > 0.0) as usize == test_set.labels[i] {
                agree += 1;
            }
        }
        let mean_trees = trees as f64 / n_probe as f64;
        println!(
            "  {class:>6}: mean trees evaluated {:.1}, stream accuracy {:.4}",
            mean_trees,
            agree as f64 / n_probe as f64
        );
        class_trees.push(mean_trees);
    }
    assert!(
        class_trees[0] <= class_trees[1],
        "the Margin class must not walk more trees than the Exact class"
    );
}
