//! End-to-end driver: the full three-layer system on a realistic
//! workload.
//!
//! * trains a compact ToaD model on the Covertype-binary stand-in,
//! * deploys it to a fleet of simulated memory-constrained devices
//!   (on-device bit-packed inference + MCU-model time accounting),
//! * AND serves the same model through the gateway path: dynamic
//!   batching into the quantized-threshold flat batch engine (u16
//!   threshold ranks, pre-binned rows, interleaved multi-row descent)
//!   — or, with the `xla` feature and `make artifacts`, into the
//!   AOT-compiled XLA predict artifact,
//! * streams sensor-like requests through both, reports accuracy,
//!   latency percentiles, and throughput.
//!
//! ```bash
//! cargo run --release --example iot_fleet
//! ```

use std::time::{Duration, Instant};
use toad::coordinator::batcher::{Backend, Batcher, BatcherConfig};
use toad::coordinator::{DeviceKind, FleetServer, SimulatedDevice};
use toad::data::synth::PaperDataset;
use toad::data::train_test_split;
use toad::gbdt::GbdtParams;
use toad::sweep::table::human_bytes;
use toad::toad::{train_toad, ToadParams};

fn main() {
    // ---- train the compact model -------------------------------------
    let ds = PaperDataset::CovertypeBinary;
    let data = ds.generate(7).select(&(0..12_000).collect::<Vec<_>>());
    let (train_set, test_set) = train_test_split(&data, 0.2, 7);
    let params = ToadParams::new(GbdtParams::paper(64, 3), 2.0, 1.0);
    let model = train_toad(&train_set, &params);
    println!(
        "model: {} trees, {} ({:.1}x vs pointer layout), accuracy {:.4}",
        model.model.n_trees(),
        human_bytes(model.size_bytes()),
        toad::layout::baseline::pointer_f32_bytes(&model.model) as f64
            / model.size_bytes() as f64,
        model.model.score(&test_set)
    );

    let mut server = FleetServer::new();

    // ---- fleet: four devices running the packed model locally --------
    for id in 0..4 {
        let mut dev = SimulatedDevice::new(id, DeviceKind::UnoR4);
        dev.deploy(model.blob.clone()).expect("fits 32 KB budget");
        server.add_device("cov", dev);
    }

    // ---- gateway: batched inference ----------------------------------
    // The XLA artifact backend takes over when it is compiled in and
    // artifacts exist; the flattened native engine is the default.
    let backend = gateway_backend(&model.model);
    let batcher = Batcher::spawn(
        BatcherConfig { max_batch: 32, max_wait: Duration::from_millis(1) },
        backend,
    );
    server.add_gateway("cov", batcher);

    // ---- serve a sensor stream ---------------------------------------
    let n_requests = 2000usize;
    let n_test = test_set.n_rows();
    let start = Instant::now();
    let mut correct = 0usize;
    for r in 0..n_requests {
        let i = r % n_test;
        let out = server.predict("cov", test_set.row(i)).unwrap();
        if (out[0] > 0.0) as usize == test_set.labels[i] {
            correct += 1;
        }
    }
    let wall = start.elapsed();

    // ---- report -------------------------------------------------------
    let m = server.metrics("cov").unwrap();
    println!("\nserved {n_requests} requests in {:.2?}", wall);
    println!("accuracy over stream: {:.4}", correct as f64 / n_requests as f64);
    println!("latency/throughput:   {}", m.summary(wall));
    println!(
        "simulated on-device compute: {:.1} ms across the fleet \
         (~{:.0} us/prediction on Cortex-M4 @48 MHz)",
        server.fleet_sim_busy_seconds() * 1e3,
        server.fleet_sim_busy_seconds() * 1e6 / (n_requests as f64 * 0.8)
    );
}

#[cfg(feature = "xla")]
fn gateway_backend(model: &toad::gbdt::GbdtModel) -> Backend {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if artifacts.join("MANIFEST.txt").exists() {
        let tm = toad::runtime::tensorize(model, 256, 4, 64, 1)
            .expect("model fits artifact shape");
        println!("gateway: XLA predict artifact online (batch 32)");
        return Backend::Xla { artifacts_dir: artifacts, features: 64, tensors: tm };
    }
    println!("gateway: artifacts missing, using quantized flat engine (run `make artifacts`)");
    Backend::Quantized(model.quantize())
}

#[cfg(not(feature = "xla"))]
fn gateway_backend(model: &toad::gbdt::GbdtModel) -> Backend {
    println!("gateway: quantized flat batch engine online (batch 32)");
    Backend::Quantized(model.quantize())
}
