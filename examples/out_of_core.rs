//! Out-of-core training demo and parity harness.
//!
//! Trains the same regression model two ways over the streaming
//! `synth_rows` generator and emits its predictions as exact f64 bit
//! patterns, so runs are comparable byte-for-byte:
//!
//! * `--mode ram` materializes the whole float matrix and trains the
//!   ordinary resident booster;
//! * `--mode chunked` streams row blocks through
//!   `Binner::fit_transform_to_disk` into an on-disk bin arena and
//!   trains from it — the float matrix never exists in memory, so the
//!   dataset can be (much) larger than the address space. The CI
//!   `out_of_core` job runs this mode under a `ulimit -v` cap smaller
//!   than the float matrix and `cmp`s the prediction files of both
//!   modes: chunked training is bit-identical to in-RAM training.
//!
//! ```bash
//! cargo run --release --example out_of_core -- --mode ram     --rows 200000 --preds ram.txt
//! cargo run --release --example out_of_core -- --mode chunked --rows 200000 --preds ooc.txt
//! cmp ram.txt ooc.txt
//! ```
//!
//! Flags: `--mode ram|chunked` (default ram), `--rows N`, `--block N`
//! (chunk rows, default 65536), `--workers K` (row-sharded reduction,
//! default 0 = off), `--rounds N`, `--depth D`, `--seed S`,
//! `--preds FILE` (hex predictions of the first 512 rows; stdout if
//! omitted), `--arena FILE` (arena path, default under the temp dir).

use std::io::Write;
use toad::data::binning::Binner;
use toad::data::synth::{synth_rows, SYNTH_ROWS_FEATURES};
use toad::data::{Dataset, Task};
use toad::gbdt::booster::{train, train_chunked};
use toad::gbdt::GbdtParams;

fn flag(argv: &[String], name: &str) -> Option<String> {
    argv.iter().position(|a| a == name).and_then(|i| argv.get(i + 1)).cloned()
}

fn parse<T: std::str::FromStr>(argv: &[String], name: &str, default: T) -> T {
    match flag(argv, name) {
        Some(v) => v.parse().unwrap_or_else(|_| panic!("invalid value for {name}: {v}")),
        None => default,
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mode = flag(&argv, "--mode").unwrap_or_else(|| "ram".into());
    let rows: usize = parse(&argv, "--rows", 100_000);
    let block: usize = parse(&argv, "--block", 65_536);
    let workers: usize = parse(&argv, "--workers", 0);
    let rounds: usize = parse(&argv, "--rounds", 3);
    let depth: usize = parse(&argv, "--depth", 3);
    let seed: u64 = parse(&argv, "--seed", 42);
    assert!(rows > 0 && block > 0, "--rows and --block must be positive");

    let mut params = GbdtParams::paper(rounds, depth);
    params.row_workers = workers;

    let model = match mode.as_str() {
        "ram" => {
            let (features, targets) = synth_rows(seed, 0..rows);
            let ds = Dataset {
                name: "synth_rows".into(),
                features,
                targets,
                labels: vec![],
                task: Task::Regression,
            };
            train(&ds, params)
        }
        "chunked" => {
            let arena = flag(&argv, "--arena").map(std::path::PathBuf::from).unwrap_or_else(|| {
                std::env::temp_dir().join(format!("toad-ooc-{}.bin", std::process::id()))
            });
            // Targets are captured during the streaming passes (the
            // closure runs twice per block; the writes are idempotent).
            let mut targets = vec![0f64; rows];
            let (binner, chunked) = Binner::fit_transform_to_disk(
                &arena,
                rows,
                SYNTH_ROWS_FEATURES,
                params.max_bins,
                block,
                |range| {
                    let (cols, t) = synth_rows(seed, range.clone());
                    targets[range].copy_from_slice(&t);
                    cols
                },
            )
            .expect("streaming fit/transform failed");
            let model = train_chunked(
                binner,
                chunked,
                targets,
                vec![],
                Task::Regression,
                "synth_rows",
                params,
            );
            let _ = std::fs::remove_file(&arena);
            model
        }
        other => {
            eprintln!("--mode must be ram|chunked, got `{other}`");
            std::process::exit(2);
        }
    };

    // Predictions of the first rows as exact bit patterns — `cmp`-able
    // across modes, block sizes, and worker counts.
    let n_preds = rows.min(512);
    let (cols, _) = synth_rows(seed, 0..n_preds);
    let mut out: Box<dyn Write> = match flag(&argv, "--preds") {
        Some(p) => Box::new(std::fs::File::create(p).expect("create --preds file")),
        None => Box::new(std::io::stdout().lock()),
    };
    for i in 0..n_preds {
        let x: Vec<f32> = (0..SYNTH_ROWS_FEATURES).map(|f| cols[f][i]).collect();
        writeln!(out, "{:016x}", model.predict_value(&x).to_bits()).expect("write prediction");
    }
    out.flush().expect("flush predictions");
    eprintln!(
        "mode={mode} rows={rows} block={block} workers={workers} trees={} preds={n_preds}",
        model.n_trees()
    );
}
