//! Regenerate the paper's figures on fuller grids than the benches.
//!
//! ```bash
//! cargo run --release --example paper_figures -- [fig4|fig5|fig6|fig7|fig8|table2|adaptive|all]
//! ```
//!
//! The benches (`cargo bench`) run the same drivers on reduced grids;
//! this binary trades minutes of compute for denser curves. Output is
//! aligned tables plus TSV blocks for plotting.

use toad::data::synth::PaperDataset;
use toad::sweep::figures::{
    adaptive_rows, fig4_rows, fig8_rows, multivariate_rows, table2_rows, univariate_rows,
    PenaltyKind,
};
use toad::sweep::table::{human_bytes, render};

fn main() {
    let which = std::env::args().nth(1).unwrap_or_else(|| "all".into());
    match which.as_str() {
        "fig4" => fig4(),
        "fig5" => fig5(),
        "fig6" => fig6(),
        "fig7" => fig7(),
        "fig8" => fig8(),
        "table2" => table2(),
        "adaptive" => adaptive(),
        "all" => {
            fig4();
            fig5();
            fig6();
            fig7();
            fig8();
            table2();
            adaptive();
        }
        other => eprintln!("unknown figure `{other}`"),
    }
}

const KB: usize = 1024;

fn fig4() {
    println!("== Figure 4: accuracy vs memory, all methods ==");
    let limits = [KB / 4, KB / 2, KB, 2 * KB, 4 * KB, 8 * KB, 16 * KB, 32 * KB, 128 * KB];
    let penalties = [(1.0, 0.5), (4.0, 2.0), (32.0, 16.0), (256.0, 128.0)];
    for ds in [
        PaperDataset::BreastCancer,
        PaperDataset::KrVsKp,
        PaperDataset::Mushroom,
        PaperDataset::CovertypeBinary,
        PaperDataset::CaliforniaHousing,
        PaperDataset::Kin8nm,
        PaperDataset::WineQuality,
        PaperDataset::Covertype,
    ] {
        let row_cap = if matches!(ds, PaperDataset::Covertype | PaperDataset::CovertypeBinary) {
            8000
        } else {
            6000
        };
        let rows = fig4_rows(ds, &[1, 2, 3], &[1, 2, 3], 7, &penalties, &limits, row_cap);
        let table: Vec<Vec<String>> = rows
            .iter()
            .filter(|r| r.n > 0)
            .map(|r| {
                vec![
                    r.series.clone(),
                    human_bytes(r.limit_bytes),
                    format!("{:.4}", r.mean),
                    format!("{:.4}", r.std),
                    format!("{}", r.n),
                ]
            })
            .collect();
        println!("\n-- {} --", ds.name());
        print!("{}", render(&["series", "limit", "mean", "std", "seeds"], &table));
    }
}

fn fig5() {
    println!("\n== Figure 5: penalty grid at a fixed 1 KB budget, California Housing ==");
    let mut grid: Vec<f64> = vec![0.0];
    grid.extend((-4..=10).step_by(2).map(|e| 2f64.powi(e)));
    let rows = toad::sweep::figures::multivariate_budget_rows(
        PaperDataset::CaliforniaHousing,
        1,
        &grid,
        &grid,
        1024,
        2,
        KB,
        6000,
    );
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("{:.3}", r.iota),
                format!("{:.3}", r.xi),
                human_bytes(r.size_bytes),
                format!("{:.4}", r.score),
            ]
        })
        .collect();
    print!("{}", render(&["iota", "xi", "size(<=1KB)", "R2"], &table));
}

fn fig6() {
    println!("\n== Figure 6: univariate sensitivity (256 iters, depth 2) ==");
    let values: Vec<f64> = (-10..=15).map(|e| 2f64.powi(e)).collect();
    for ds in [
        PaperDataset::BreastCancer,
        PaperDataset::CaliforniaHousing,
        PaperDataset::Kin8nm,
        PaperDataset::CovertypeBinary,
        PaperDataset::WineQuality,
    ] {
        for (kind, label) in [(PenaltyKind::Feature, "iota"), (PenaltyKind::Threshold, "xi")] {
            let rows = univariate_rows(ds, 1, kind, &values, 256, 2, 6000);
            let table: Vec<Vec<String>> = rows
                .iter()
                .map(|r| {
                    vec![
                        format!("{:.4}", r.penalty),
                        format!("{:.4}", r.score),
                        format!("{}", r.n_features),
                        format!("{}", r.n_global_values),
                        format!("{:.2}", r.reuse_factor),
                    ]
                })
                .collect();
            println!("\n-- {} / {} --", ds.name(), label);
            print!(
                "{}",
                render(&[label, "score", "features", "global_values", "ReF"], &table)
            );
        }
    }
}

fn fig7() {
    println!("\n== Figure 7: multivariate penalty grids (256 iters, depth 2) ==");
    let grid: Vec<f64> = (-10..=15).step_by(5).map(|e| 2f64.powi(e)).collect();
    for ds in [
        PaperDataset::BreastCancer,
        PaperDataset::CaliforniaHousing,
        PaperDataset::CovertypeBinary,
        PaperDataset::WineQuality,
    ] {
        let rows = multivariate_rows(ds, 1, &grid, &grid, 256, 2, 6000);
        let table: Vec<Vec<String>> = rows
            .iter()
            .map(|r| {
                vec![
                    format!("{:.4}", r.iota),
                    format!("{:.4}", r.xi),
                    human_bytes(r.size_bytes),
                    format!("{:.4}", r.score),
                ]
            })
            .collect();
        println!("\n-- {} --", ds.name());
        print!("{}", render(&["iota", "xi", "memory", "score"], &table));
    }
}

fn fig8() {
    println!("\n== Figure 8 / Appendix D: boosted vs RF & pruned RF ==");
    let limits = [2 * KB, 8 * KB, 32 * KB, 128 * KB, 512 * KB];
    for ds in [PaperDataset::BreastCancer, PaperDataset::KrVsKp, PaperDataset::Mushroom] {
        let rows = fig8_rows(ds, &[1, 2], &[2, 3], &limits, 3000);
        let table: Vec<Vec<String>> = rows
            .iter()
            .filter(|r| r.n > 0)
            .map(|r| {
                vec![
                    r.series.clone(),
                    human_bytes(r.limit_bytes),
                    format!("{:.4}", r.mean),
                    format!("{:.4}", r.std),
                ]
            })
            .collect();
        println!("\n-- {} --", ds.name());
        print!("{}", render(&["series", "limit", "mean", "std"], &table));
    }
}

fn adaptive() {
    println!("\n== Adaptive early exit: accuracy vs mean trees evaluated ==");
    let eps_grid = [0.0f32, 1e-6, 0.01, 0.05, 0.1, 0.25, 0.5, 1.0, 2.0, 4.0];
    for ds in [
        PaperDataset::Mushroom,
        PaperDataset::BreastCancer,
        PaperDataset::KrVsKp,
        PaperDataset::CovertypeBinary,
    ] {
        let rows = adaptive_rows(ds, 1, 64, 2, &eps_grid, 6000);
        let table: Vec<Vec<String>> = rows
            .iter()
            .map(|r| {
                vec![
                    format!("{:.2e}", r.eps),
                    format!("{:.4}", r.score),
                    format!("{:+.4}", r.score - r.exact_score),
                    format!("{:.1}", r.mean_trees),
                    format!("{}", r.n_trees),
                ]
            })
            .collect();
        println!("\n-- {} --", ds.name());
        print!("{}", render(&["eps", "score", "delta", "mean_trees", "n_trees"], &table));
    }
}

fn table2() {
    println!("\n== Table 2 / Appendix E.1: per-prediction latency ==");
    let (rows, packed, test) = table2_rows(1, 8000);
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.hardware.to_string(),
                format!("{:.2}", r.toad_us),
                format!("{:.2}", r.lgbm_us),
                format!("{:.1}x", r.slowdown),
            ]
        })
        .collect();
    print!("{}", render(&["hardware", "ToaD(us)", "LightGBM(us)", "slowdown"], &table));
    println!("model: {} bytes packed; paper measured 137us/513us with slowdown 5-8x", packed.size_bytes());

    // Host wall-clock cross-check of the two interpreters (500
    // predictions × 20 runs, as in Appendix E.1).
    let decoded = toad::layout::decode(packed.bytes());
    let rows_500: Vec<Vec<f32>> = (0..500).map(|i| test.row(i % test.n_rows())).collect();
    let mut t_packed = f64::INFINITY;
    let mut t_decoded = f64::INFINITY;
    for _ in 0..20 {
        let s = std::time::Instant::now();
        let mut acc = 0.0f64;
        for r in &rows_500 {
            acc += packed.predict_raw(r)[0];
        }
        t_packed = t_packed.min(s.elapsed().as_secs_f64() / 500.0);
        std::hint::black_box(acc);
        let s = std::time::Instant::now();
        let mut acc2 = 0.0f64;
        for r in &rows_500 {
            acc2 += decoded.predict_raw(r)[0];
        }
        t_decoded = t_decoded.min(s.elapsed().as_secs_f64() / 500.0);
        std::hint::black_box(acc2);
    }
    println!(
        "host wall-clock: packed {:.2}us vs decoded {:.2}us per prediction ({:.1}x)",
        t_packed * 1e6,
        t_decoded * 1e6,
        t_packed / t_decoded
    );
}
