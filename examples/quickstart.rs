//! Quickstart: train a compact ToaD model, inspect its size, and run
//! bit-packed inference — the 60-second tour of the public API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use toad::data::synth::PaperDataset;
use toad::data::train_test_split;
use toad::gbdt::GbdtParams;
use toad::layout::{baseline, PackedModel};
use toad::sweep::table::human_bytes;
use toad::toad::{train_toad, ToadParams};

fn main() {
    // 1. Data: the Breast Cancer stand-in (569 rows × 30 features).
    let data = PaperDataset::BreastCancer.generate(1);
    let (train_set, test_set) = train_test_split(&data, 0.2, 1);
    println!("dataset: {} ({} train / {} test rows, {} features)",
        data.name, train_set.n_rows(), test_set.n_rows(), data.n_features());

    // 2. Train with reuse penalties: ι charges new features, ξ new
    //    thresholds (paper Eq. 3).
    let params = ToadParams::new(GbdtParams::paper(32, 2), 2.0, 1.0);
    let model = train_toad(&train_set, &params);
    println!(
        "trained {} trees, depth ≤ 2: accuracy {:.3}",
        model.model.n_trees(),
        model.model.score(&test_set)
    );

    // 3. Size: the ToaD layout vs the baselines of paper §4.2.
    let toad_b = model.size_bytes();
    let ptr_b = baseline::pointer_f32_bytes(&model.model);
    let q16_b = baseline::pointer_f16_bytes(&model.model);
    let arr_b = baseline::array_f32_bytes(&model.model);
    println!("sizes: toad={} pointer_f32={} pointer_f16={} array_f32={}",
        human_bytes(toad_b), human_bytes(ptr_b), human_bytes(q16_b), human_bytes(arr_b));
    println!("compression vs float32 pointers: {:.1}x", ptr_b as f64 / toad_b as f64);
    println!(
        "reuse: |F_U|={} thresholds={} leaf values={} ReF={:.2}",
        model.stats.n_features_used,
        model.stats.n_thresholds,
        model.stats.n_leaf_values,
        model.reuse_factor()
    );

    // 4. Inference directly from the packed bits (what an MCU runs).
    let packed = PackedModel::from_bytes(model.blob.clone());
    let mut hits = 0usize;
    for i in 0..test_set.n_rows() {
        if packed.predict_class(&test_set.row(i)) == test_set.labels[i] {
            hits += 1;
        }
    }
    println!(
        "bit-packed inference accuracy: {:.3} (identical routing)",
        hits as f64 / test_set.n_rows() as f64
    );
}
