"""AOT lowering: JAX/Pallas → HLO text artifacts for the Rust runtime.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md and gen_hlo.py there.

Artifacts (shapes baked in; the Rust runtime pads models/batches):

    predict_n{N}_t{T}_d{D}_f{F}_o{O}.hlo.txt   — predict_outputs
    pertree_n{N}_t{T}_d{D}_f{F}.hlo.txt        — per-tree values
    histogram_s{S}_f{F}_b{B}.hlo.txt           — gradient histograms
    MANIFEST.txt                               — one line per artifact

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (batch, trees, depth, features, outputs) predict configurations.
# n32 is the low-latency serving shape; n256 the batch/throughput shape.
PREDICT_CONFIGS = [
    (32, 256, 4, 64, 1),
    (256, 256, 4, 64, 1),
    (256, 256, 4, 64, 8),
]
PERTREE_CONFIGS = [
    (256, 256, 4, 64),
]
# (samples, features, bins) histogram configurations.
HISTOGRAM_CONFIGS = [
    (4096, 64, 64),
]


def to_hlo_text(lowered):
    """Convert a jitted-and-lowered function to XLA HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_predict(n, t, depth, f, o):
    i_slots = (1 << depth) - 1
    l_slots = 1 << depth
    fn = functools.partial(model.predict_outputs, n_outputs=o)
    lowered = jax.jit(fn).lower(
        _spec((n, f), jnp.float32),
        _spec((t, i_slots), jnp.int32),
        _spec((t, i_slots), jnp.float32),
        _spec((t, l_slots), jnp.float32),
        _spec((o,), jnp.float32),
    )
    return to_hlo_text(lowered)


def lower_pertree(n, t, depth, f):
    i_slots = (1 << depth) - 1
    l_slots = 1 << depth
    lowered = jax.jit(model.predict_pertree).lower(
        _spec((n, f), jnp.float32),
        _spec((t, i_slots), jnp.int32),
        _spec((t, i_slots), jnp.float32),
        _spec((t, l_slots), jnp.float32),
    )
    return to_hlo_text(lowered)


def lower_histogram(s, f, b):
    fn = functools.partial(model.histogram_fn, n_bins=b)
    lowered = jax.jit(fn).lower(
        _spec((s, f), jnp.int32),
        _spec((s,), jnp.float32),
        _spec((s,), jnp.float32),
    )
    return to_hlo_text(lowered)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []

    for n, t, d, f, o in PREDICT_CONFIGS:
        name = f"predict_n{n}_t{t}_d{d}_f{f}_o{o}.hlo.txt"
        text = lower_predict(n, t, d, f, o)
        with open(os.path.join(args.out_dir, name), "w") as fh:
            fh.write(text)
        manifest.append(f"predict {name} n={n} t={t} d={d} f={f} o={o}")
        print(f"wrote {name} ({len(text)} chars)")

    for n, t, d, f in PERTREE_CONFIGS:
        name = f"pertree_n{n}_t{t}_d{d}_f{f}.hlo.txt"
        text = lower_pertree(n, t, d, f)
        with open(os.path.join(args.out_dir, name), "w") as fh:
            fh.write(text)
        manifest.append(f"pertree {name} n={n} t={t} d={d} f={f}")
        print(f"wrote {name} ({len(text)} chars)")

    for s, f, b in HISTOGRAM_CONFIGS:
        name = f"histogram_s{s}_f{f}_b{b}.hlo.txt"
        text = lower_histogram(s, f, b)
        with open(os.path.join(args.out_dir, name), "w") as fh:
            fh.write(text)
        manifest.append(f"histogram {name} s={s} f={f} b={b}")
        print(f"wrote {name} ({len(text)} chars)")

    # Manifest last: the Makefile uses it as the up-to-date sentinel.
    with open(os.path.join(args.out_dir, "MANIFEST.txt"), "w") as fh:
        fh.write("\n".join(manifest) + "\n")
    print(f"wrote MANIFEST.txt ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
