"""Layer-1 Pallas kernels (build-time only).

Two kernels cover the system's compute hot-spots:

* :mod:`.histogram` — gradient/hessian histogram accumulation, the GBDT
  training hot path, expressed as a one-hot matmul (MXU-friendly TPU
  adaptation of the GPU scatter-add idiom).
* :mod:`.ensemble` — tensorized complete-tree ensemble traversal, the
  serving hot path; level-synchronous gathers over the pointer-less
  array layout that ToaD stores.

Both are authored for TPU BlockSpecs but validated under
``interpret=True`` (the CPU PJRT plugin cannot execute Mosaic
custom-calls); :mod:`.ref` holds the pure-jnp oracles.
"""
