"""Pallas kernel: tensorized complete-tree ensemble traversal.

The ToaD layout stores trees as pointer-less complete arrays — which is
*also* the ideal execution format on TPU: instead of per-thread pointer
chasing (the GPU idiom), traversal becomes ``depth`` level-synchronous
gathers, fully vectorized over the (batch × tree) plane:

    idx ← 2·idx + 1 + (x[:, feat[t, idx]] > thr[t, idx])

The grid walks batch blocks; every step keeps the whole (padded) model —
``feat``/``thr`` ``(T, I)`` and ``leaves`` ``(T, L)`` — resident in VMEM
(256 trees × 15 slots is tiny) and emits the per-tree leaf values
``(N_B, T)``. The L2 model reduces over trees per output stream.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 32


def _predict_kernel(x_ref, feat_ref, thr_ref, leaves_ref, out_ref, *, depth):
    x = x_ref[...]  # (N_B, D)
    feat = feat_ref[...]  # (T, I)
    thr = thr_ref[...]  # (T, I)
    leaves = leaves_ref[...]  # (T, L)
    n_b = x.shape[0]
    t = feat.shape[0]
    i_slots = feat.shape[1]
    idx = jnp.zeros((n_b, t), dtype=jnp.int32)
    t_ar = jnp.arange(t)[None, :]
    n_ar = jnp.arange(n_b)[:, None]
    for _ in range(depth):
        f = feat[t_ar, idx]  # (N_B, T)
        v = x[n_ar, f]
        tv = thr[t_ar, idx]
        idx = 2 * idx + 1 + (v > tv).astype(jnp.int32)
    out_ref[...] = leaves[t_ar, idx - i_slots]


def predict_pertree(x, feat, thr, leaves, *, block_n=DEFAULT_BLOCK_N, interpret=True):
    """Per-tree leaf values ``(N, T)`` for a batch of rows.

    Trees must be complete at a common depth (pad shallower trees by
    replicating early leaves; pad the tree count with all-zero-leaf
    trees). ``N`` must be a multiple of ``block_n``.
    """
    n, d = x.shape
    t, i_slots = feat.shape
    depth = (i_slots + 1).bit_length() - 1
    assert (1 << depth) - 1 == i_slots, "internal slots must be 2^d - 1"
    assert leaves.shape == (t, 1 << depth)
    assert n % block_n == 0, f"batch {n} not a multiple of block {block_n}"
    grid = (n // block_n,)
    kernel = functools.partial(_predict_kernel, depth=depth)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec(feat.shape, lambda i: (0, 0)),
            pl.BlockSpec(thr.shape, lambda i: (0, 0)),
            pl.BlockSpec(leaves.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, t), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, t), jnp.float32),
        interpret=interpret,
    )(x, feat, thr, leaves)
