"""Pallas kernel: gradient/hessian histogram accumulation.

GBDT split finding needs, per (feature, bin), the sums of gradients and
hessians over the rows of a leaf. GPU implementations build these with
atomic scatter-adds into shared memory; TPUs have no atomics, but they
have a systolic MXU — so the kernel re-expresses accumulation as a
matmul with a one-hot expansion of the bin indices:

    hist[f] = onehot(bins[:, f])ᵀ · [grad, hess]        # (B, S) x (S, 2)

The grid walks sample blocks; the (F, B, 2) output block is revisited at
every step ("arbitrary" sequential semantics) and accumulated in place,
so the one-hot slab only ever holds ``S_BLOCK × F × B`` f32 in VMEM
(e.g. 256 × 64 × 64 × 4 B = 4 MB, comfortably under ~16 MB).

Real-TPU note: lowering without ``interpret=True`` produces a Mosaic
custom-call that the CPU PJRT plugin cannot execute; all artifacts in
this repo are interpret-lowered (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default sample-block size: keeps the one-hot slab at 4 MB for F=B=64.
DEFAULT_BLOCK_S = 256


def _hist_kernel(bins_ref, grad_ref, hess_ref, out_ref, *, n_bins):
    """One grid step: accumulate one sample block into the histogram."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bins = bins_ref[...]  # (S_B, F) int32
    grad = grad_ref[...]  # (S_B,)
    hess = hess_ref[...]  # (S_B,)
    onehot = (
        bins[:, :, None] == jnp.arange(n_bins, dtype=bins.dtype)[None, None, :]
    ).astype(jnp.float32)  # (S_B, F, B)
    gh = jnp.stack([grad, hess], axis=-1)  # (S_B, 2)
    # MXU-shaped contraction over the sample axis.
    out_ref[...] += jnp.einsum("sfb,sc->fbc", onehot, gh)


def histogram(bins, grad, hess, n_bins, *, block_s=DEFAULT_BLOCK_S, interpret=True):
    """Per-feature gradient/hessian histograms via Pallas.

    Args:
        bins: int32 ``(S, F)``; ``S`` must be a multiple of ``block_s``
            (pad with ``bin=0, grad=hess=0`` rows — they are no-ops).
        grad, hess: f32 ``(S,)``.
        n_bins: static number of bins ``B``.

    Returns:
        f32 ``(F, B, 2)``.
    """
    s, f = bins.shape
    assert s % block_s == 0, f"samples {s} not a multiple of block {block_s}"
    grid = (s // block_s,)
    kernel = functools.partial(_hist_kernel, n_bins=n_bins)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_s, f), lambda i: (i, 0)),
            pl.BlockSpec((block_s,), lambda i: (i,)),
            pl.BlockSpec((block_s,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((f, n_bins, 2), lambda i: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((f, n_bins, 2), jnp.float32),
        interpret=interpret,
    )(bins, grad, hess)
