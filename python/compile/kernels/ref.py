"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the pytest/hypothesis suites compare against;
they favour obviousness over speed.
"""

import jax.numpy as jnp


def histogram_ref(bins, grad, hess, n_bins):
    """Gradient/hessian histograms.

    Args:
        bins: int32 ``(S, F)`` — per-row bin index of each feature.
        grad: f32 ``(S,)`` — gradients.
        hess: f32 ``(S,)`` — hessians.
        n_bins: static bin count ``B``.

    Returns:
        f32 ``(F, B, 2)`` — per feature and bin, ``[Σ grad, Σ hess]``.
    """
    onehot = (bins[:, :, None] == jnp.arange(n_bins, dtype=bins.dtype)[None, None, :]).astype(
        jnp.float32
    )
    gh = jnp.stack([grad, hess], axis=-1)  # (S, 2)
    return jnp.einsum("sfb,sc->fbc", onehot, gh)


def predict_ref(x, feat, thr, leaves):
    """Per-tree leaf values for complete trees in heap layout.

    Args:
        x: f32 ``(N, D)`` — input rows.
        feat: int32 ``(T, I)`` — split feature per internal slot,
            ``I = 2^depth − 1``; slot ``i``'s children are ``2i+1``/``2i+2``.
        thr: f32 ``(T, I)`` — split thresholds (route left iff ``x <= thr``).
        leaves: f32 ``(T, L)`` — leaf values, ``L = 2^depth``.

    Returns:
        f32 ``(N, T)`` — the leaf value each row reaches in each tree.
    """
    n = x.shape[0]
    t, i_slots = feat.shape
    depth = (i_slots + 1).bit_length() - 1
    assert (1 << depth) - 1 == i_slots, "internal slots must be 2^d - 1"
    idx = jnp.zeros((n, t), dtype=jnp.int32)
    t_ar = jnp.arange(t)[None, :]
    n_ar = jnp.arange(n)[:, None]
    for _ in range(depth):
        f = feat[t_ar, idx]
        v = x[n_ar, f]
        tv = thr[t_ar, idx]
        idx = 2 * idx + 1 + (v > tv).astype(jnp.int32)
    return leaves[t_ar, idx - i_slots]


def predict_sum_ref(x, feat, thr, leaves):
    """Summed raw scores over all trees: ``(N,)``."""
    return predict_ref(x, feat, thr, leaves).sum(axis=1)
