"""Layer-2 JAX model functions (build-time only).

The compute graphs the Rust runtime executes, composed from the Layer-1
Pallas kernels:

* :func:`predict_outputs` — the serving path: per-tree kernel values
  reduced per output stream and shifted by the base scores. Trees are
  laid out ``[output0 round0..K-1, output1 round0..K-1, …]``.
* :func:`histogram_fn` — the training hot path (gradient histograms).

``aot.py`` lowers jitted instances of these at fixed shapes to HLO text;
Python never runs at serving time.
"""

import jax.numpy as jnp

from .kernels import ensemble, histogram


def predict_pertree(x, feat, thr, leaves):
    """Per-tree leaf values ``(N, T)`` (thin wrapper over the kernel)."""
    return ensemble.predict_pertree(x, feat, thr, leaves)


def predict_outputs(x, feat, thr, leaves, base, *, n_outputs):
    """Raw scores per output stream.

    Args:
        x: f32 ``(N, D)``.
        feat/thr/leaves: packed complete-tree tensors with
            ``T = n_outputs * K`` trees, grouped by output stream.
        base: f32 ``(n_outputs,)`` base scores.
        n_outputs: static output-stream count.

    Returns:
        f32 ``(N, n_outputs)``.
    """
    per_tree = ensemble.predict_pertree(x, feat, thr, leaves)  # (N, T)
    n = per_tree.shape[0]
    grouped = per_tree.reshape(n, n_outputs, -1).sum(axis=2)
    return grouped + base[None, :]


def histogram_fn(bins, grad, hess, *, n_bins):
    """Gradient/hessian histograms ``(F, B, 2)`` (kernel wrapper)."""
    return histogram.histogram(bins, grad, hess, n_bins)


def predict_outputs_ref(x, feat, thr, leaves, base, *, n_outputs):
    """Pure-jnp reference of :func:`predict_outputs` for tests."""
    from .kernels import ref

    per_tree = ref.predict_ref(x, feat, thr, leaves)
    n = per_tree.shape[0]
    return per_tree.reshape(n, n_outputs, -1).sum(axis=2) + jnp.asarray(base)[None, :]
