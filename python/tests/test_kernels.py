"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes and value distributions; fixed-seed examples
pin the edge cases (empty gradients, boundary routing, padding rows).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ensemble, histogram, ref

RNG = np.random.default_rng(0x70AD)


def random_complete_trees(rng, t, depth, d):
    """Random complete trees: features, thresholds, leaves."""
    i_slots = (1 << depth) - 1
    l_slots = 1 << depth
    feat = rng.integers(0, d, size=(t, i_slots), dtype=np.int32)
    thr = rng.normal(size=(t, i_slots)).astype(np.float32)
    leaves = rng.normal(size=(t, l_slots)).astype(np.float32)
    return feat, thr, leaves


# ---------------------------------------------------------------- histogram


@settings(max_examples=25, deadline=None)
@given(
    s_blocks=st.integers(1, 4),
    f=st.integers(1, 8),
    b=st.integers(2, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_histogram_matches_ref(s_blocks, f, b, seed):
    rng = np.random.default_rng(seed)
    block = 8
    s = s_blocks * block
    bins = rng.integers(0, b, size=(s, f), dtype=np.int32)
    grad = rng.normal(size=s).astype(np.float32)
    hess = rng.uniform(0.1, 2.0, size=s).astype(np.float32)
    got = histogram.histogram(bins, grad, hess, b, block_s=block)
    want = ref.histogram_ref(bins, grad, hess, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_histogram_counts_mass():
    # Total gradient mass is preserved per feature.
    s, f, b = 512, 4, 8
    bins = RNG.integers(0, b, size=(s, f), dtype=np.int32)
    grad = RNG.normal(size=s).astype(np.float32)
    hess = np.ones(s, dtype=np.float32)
    out = np.asarray(histogram.histogram(bins, grad, hess, b))
    for fi in range(f):
        np.testing.assert_allclose(out[fi, :, 0].sum(), grad.sum(), rtol=1e-4)
        np.testing.assert_allclose(out[fi, :, 1].sum(), s, rtol=1e-6)


def test_histogram_padding_rows_are_noops():
    # Padding convention: bin 0, grad = hess = 0.
    s, f, b = 256, 3, 4
    bins = RNG.integers(0, b, size=(s, f), dtype=np.int32)
    grad = RNG.normal(size=s).astype(np.float32)
    hess = RNG.uniform(0.5, 1.0, size=s).astype(np.float32)
    base = np.asarray(histogram.histogram(bins, grad, hess, b))

    pad = 256
    bins_p = np.vstack([bins, np.zeros((pad, f), np.int32)])
    grad_p = np.concatenate([grad, np.zeros(pad, np.float32)])
    hess_p = np.concatenate([hess, np.zeros(pad, np.float32)])
    padded = np.asarray(histogram.histogram(bins_p, grad_p, hess_p, b))
    np.testing.assert_allclose(padded, base, rtol=1e-5, atol=1e-6)


def test_histogram_multiblock_accumulates():
    # Two grid steps must accumulate, not overwrite.
    s, f, b = 512, 2, 4
    bins = np.zeros((s, f), np.int32)  # everything in bin 0
    grad = np.ones(s, np.float32)
    hess = np.ones(s, np.float32)
    out = np.asarray(histogram.histogram(bins, grad, hess, b, block_s=256))
    np.testing.assert_allclose(out[:, 0, 0], s, rtol=1e-6)
    np.testing.assert_allclose(out[:, 1:, :], 0.0)


# ----------------------------------------------------------------- ensemble


@settings(max_examples=25, deadline=None)
@given(
    n_blocks=st.integers(1, 3),
    t=st.integers(1, 16),
    depth=st.integers(1, 5),
    d=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_predict_matches_ref(n_blocks, t, depth, d, seed):
    rng = np.random.default_rng(seed)
    block = 8
    n = n_blocks * block
    x = rng.normal(size=(n, d)).astype(np.float32)
    feat, thr, leaves = random_complete_trees(rng, t, depth, d)
    got = ensemble.predict_pertree(x, feat, thr, leaves, block_n=block)
    want = ref.predict_ref(x, feat, thr, leaves)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_predict_boundary_routes_left():
    # x == threshold must go left (<= semantics), matching the Rust side.
    feat = np.zeros((1, 1), np.int32)
    thr = np.array([[1.5]], np.float32)
    leaves = np.array([[10.0, 20.0]], np.float32)
    x = np.array([[1.5]] * 32, np.float32)
    out = np.asarray(ensemble.predict_pertree(x, feat, thr, leaves, block_n=32))
    np.testing.assert_allclose(out, 10.0)
    x2 = np.array([[1.5000001]] * 32, np.float32)
    out2 = np.asarray(ensemble.predict_pertree(x2, feat, thr, leaves, block_n=32))
    np.testing.assert_allclose(out2, 20.0)


def test_predict_against_scalar_traversal():
    # Cross-check the vectorized descent against a plain per-row walk.
    rng = np.random.default_rng(7)
    t, depth, d, n = 8, 3, 5, 16
    feat, thr, leaves = random_complete_trees(rng, t, depth, d)
    x = rng.normal(size=(n, d)).astype(np.float32)
    got = np.asarray(ensemble.predict_pertree(x, feat, thr, leaves, block_n=16))
    i_slots = (1 << depth) - 1
    for i in range(n):
        for tt in range(t):
            idx = 0
            while idx < i_slots:
                go_right = x[i, feat[tt, idx]] > thr[tt, idx]
                idx = 2 * idx + 2 if go_right else 2 * idx + 1
            want = leaves[tt, idx - i_slots]
            assert got[i, tt] == pytest.approx(want, rel=1e-6)


def test_zero_leaf_padding_trees_contribute_nothing():
    rng = np.random.default_rng(9)
    t, depth, d, n = 4, 2, 3, 8
    feat, thr, leaves = random_complete_trees(rng, t, depth, d)
    x = rng.normal(size=(n, d)).astype(np.float32)
    base = np.asarray(ensemble.predict_pertree(x, feat, thr, leaves, block_n=8)).sum(axis=1)
    # Add 4 padding trees with zero leaves.
    feat_p = np.vstack([feat, np.zeros((4, feat.shape[1]), np.int32)])
    thr_p = np.vstack([thr, np.zeros((4, thr.shape[1]), np.float32)])
    leaves_p = np.vstack([leaves, np.zeros((4, leaves.shape[1]), np.float32)])
    padded = np.asarray(ensemble.predict_pertree(x, feat_p, thr_p, leaves_p, block_n=8)).sum(axis=1)
    np.testing.assert_allclose(padded, base, rtol=1e-6)
