"""Layer-2 model functions: output grouping, base scores, AOT lowering."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import aot, model


def random_model(rng, t, depth, d):
    i_slots = (1 << depth) - 1
    l_slots = 1 << depth
    feat = rng.integers(0, d, size=(t, i_slots), dtype=np.int32)
    thr = rng.normal(size=(t, i_slots)).astype(np.float32)
    leaves = rng.normal(size=(t, l_slots)).astype(np.float32)
    return feat, thr, leaves


@settings(max_examples=15, deadline=None)
@given(
    o=st.sampled_from([1, 2, 4]),
    k=st.integers(1, 8),
    depth=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_outputs_match_ref(o, k, depth, seed):
    rng = np.random.default_rng(seed)
    d, n = 6, 32
    t = o * k
    feat, thr, leaves = random_model(rng, t, depth, d)
    x = rng.normal(size=(n, d)).astype(np.float32)
    base = rng.normal(size=o).astype(np.float32)
    got = model.predict_outputs(x, feat, thr, leaves, base, n_outputs=o)
    want = model.predict_outputs_ref(x, feat, thr, leaves, base, n_outputs=o)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_output_grouping_order():
    # Trees are grouped [out0 trees..., out1 trees...]: constant-leaf
    # trees with distinct values verify the reduction respects grouping.
    o, k, depth, d, n = 2, 2, 1, 2, 32
    t = o * k
    feat = np.zeros((t, 1), np.int32)
    thr = np.zeros((t, 1), np.float32)
    leaves = np.stack([np.full(2, v, np.float32) for v in [1.0, 2.0, 10.0, 20.0]])
    x = np.zeros((n, d), np.float32)
    base = np.array([100.0, 200.0], np.float32)
    out = np.asarray(model.predict_outputs(x, feat, thr, leaves, base, n_outputs=o))
    np.testing.assert_allclose(out[:, 0], 103.0)  # 100 + 1 + 2
    np.testing.assert_allclose(out[:, 1], 230.0)  # 200 + 10 + 20


@pytest.mark.parametrize("cfg", aot.PREDICT_CONFIGS)
def test_aot_predict_lowering(cfg):
    n, t, d, f, o = cfg
    text = aot.lower_predict(n, t, d, f, o)
    assert "HloModule" in text
    assert len(text) > 500


def test_aot_histogram_lowering():
    s, f, b = aot.HISTOGRAM_CONFIGS[0]
    text = aot.lower_histogram(s, f, b)
    assert "HloModule" in text


def test_aot_pertree_lowering():
    n, t, d, f = aot.PERTREE_CONFIGS[0]
    text = aot.lower_pertree(n, t, d, f)
    assert "HloModule" in text
