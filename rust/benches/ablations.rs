//! Ablations of the design choices DESIGN.md calls out — what each
//! ToaD ingredient contributes, measured independently:
//!
//! 1. layout only (pointer → bit-wise encoding, same trees),
//! 2. + f16 thresholds (EncodeOptions::allow_f16),
//! 3. + reuse penalties (linear, paper Eq. 2),
//! 4. penalty shape: linear vs escalating (paper footnote 3),
//! 5. + leaf-value sharing (future-work extension; mantissa truncation).

use toad::data::synth::PaperDataset;
use toad::data::train_test_split;
use toad::gbdt::GbdtParams;
use toad::layout::{baseline, encode, toad_format::size_breakdown, EncodeOptions, FeatureInfo};
use toad::sweep::table::{human_bytes, render};
use toad::toad::penalty::PenaltyShape;
use toad::toad::{train_toad, ToadParams};

fn main() {
    let ds = PaperDataset::CovertypeBinary;
    let data = ds.generate(1).select(&(0..6000).collect::<Vec<_>>());
    let (tr, te) = train_test_split(&data, 0.2, 1);
    let gbdt = GbdtParams::paper(64, 3);

    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut push = |label: &str, score: f64, bytes: usize, baseline_bytes: usize| {
        rows.push(vec![
            label.to_string(),
            format!("{score:.4}"),
            human_bytes(bytes),
            format!("{:.1}x", baseline_bytes as f64 / bytes as f64),
        ]);
    };

    // Plain training once; re-encoded under different options.
    let plain = train_toad(&tr, &ToadParams::new(gbdt, 0.0, 0.0));
    let finfo = FeatureInfo::from_dataset(&tr);
    let ptr = baseline::pointer_f32_bytes(&plain.model);
    push("pointer f32 (reference)", plain.model.score(&te), ptr, ptr);
    push(
        "array f32 (pointer-less only)",
        plain.model.score(&te),
        baseline::array_f32_bytes(&plain.model),
        ptr,
    );

    let no_f16 = EncodeOptions { allow_f16: false, ..Default::default() };
    let bd = size_breakdown(&plain.model, &finfo, &no_f16);
    push("toad layout, f32 thresholds", plain.model.score(&te), bd.total_bytes(), ptr);

    let with_f16 = EncodeOptions::default();
    let bd = size_breakdown(&plain.model, &finfo, &with_f16);
    push("toad layout, +f16 thresholds", plain.model.score(&te), bd.total_bytes(), ptr);

    let shared = EncodeOptions { leaf_mantissa_bits: Some(8), ..Default::default() };
    let blob = encode(&plain.model, &finfo, &shared).unwrap();
    let dec = toad::layout::decode(&blob);
    push("toad layout, +leaf sharing (8-bit mantissa)", dec.score(&te), blob.len(), ptr);

    // Penalized runs: linear vs escalating shape at matched (ι, ξ).
    let lin = train_toad(&tr, &ToadParams::new(gbdt, 4.0, 2.0));
    push("+penalties linear (i=4, x=2)", lin.model.score(&te), lin.size_bytes(), ptr);

    let mut esc_params = ToadParams::new(gbdt, 0.25, 0.02);
    esc_params.shape = PenaltyShape::Escalating;
    let esc = train_toad(&tr, &esc_params);
    push(
        "+penalties escalating (i=.25, x=.02)",
        esc.model.score(&te),
        esc.size_bytes(),
        ptr,
    );

    println!("== Ablations ({}, 64 rounds, depth 3) ==", ds.name());
    print!("{}", render(&["configuration", "accuracy", "size", "vs pointer"], &rows));
    println!(
        "\nreuse stats: linear |F_U|={} thr={} ReF={:.2} | escalating |F_U|={} thr={} ReF={:.2}",
        lin.stats.n_features_used,
        lin.stats.n_thresholds,
        lin.reuse_factor(),
        esc.stats.n_features_used,
        esc.stats.n_thresholds,
        esc.reuse_factor(),
    );
}
