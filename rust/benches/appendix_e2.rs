//! Appendix E.2: the univariate sensitivity analysis across
//! hyperparameter settings — iterations ∈ {4, 64} × depth ∈ {2, 4}
//! (the appendix also shows 1024 iterations / depth 8; run
//! `examples/paper_figures.rs` for denser settings).
//!
//! Expected: the Figure 6 patterns persist across settings — threshold
//! counts fall with ξ, ReF peaks then collapses, accuracy knees later
//! for feature-rich datasets.

use toad::data::synth::PaperDataset;
use toad::sweep::figures::{univariate_rows, PenaltyKind};
use toad::sweep::table::render;

fn main() {
    let values: Vec<f64> = (-4..=15).step_by(3).map(|e| 2f64.powi(e)).collect();
    for (iters, depth) in [(4usize, 2usize), (4, 4), (64, 2), (64, 4)] {
        for (ds, cap) in
            [(PaperDataset::BreastCancer, 569), (PaperDataset::CovertypeBinary, 3000)]
        {
            for (kind, label) in
                [(PenaltyKind::Feature, "iota"), (PenaltyKind::Threshold, "xi")]
            {
                let rows = univariate_rows(ds, 1, kind, &values, iters, depth, cap);
                println!(
                    "\n== E.2: {} / {label}, max_iterations={iters}, max_depth={depth} ==",
                    ds.name()
                );
                let table: Vec<Vec<String>> = rows
                    .iter()
                    .map(|r| {
                        vec![
                            format!("{}", r.penalty),
                            format!("{:.4}", r.score),
                            format!("{}", r.n_features),
                            format!("{}", r.n_global_values),
                            format!("{:.2}", r.reuse_factor),
                        ]
                    })
                    .collect();
                print!(
                    "{}",
                    render(&[label, "score", "features", "values", "ReF"], &table)
                );
            }
        }
    }
}
