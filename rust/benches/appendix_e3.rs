//! Appendix E.3: the multivariate (ι × ξ) analysis across
//! hyperparameter settings — iterations ∈ {4, 64} × depth ∈ {2, 4}.
//!
//! Expected: useful penalty combinations (small score loss, large
//! memory drop) exist at every setting; with more iterations the
//! memory span between the free and heavily-penalized corners widens.

use toad::data::synth::PaperDataset;
use toad::sweep::figures::multivariate_rows;
use toad::sweep::table::{human_bytes, render};

fn main() {
    let grid: Vec<f64> = vec![0.0, 1.0, 32.0, 1024.0, 32768.0];
    for (iters, depth) in [(4usize, 2usize), (4, 4), (64, 2), (64, 4)] {
        for (ds, cap) in
            [(PaperDataset::BreastCancer, 569), (PaperDataset::CaliforniaHousing, 3000)]
        {
            let rows = multivariate_rows(ds, 1, &grid, &grid, iters, depth, cap);
            println!(
                "\n== E.3: {}, max_iterations={iters}, max_depth={depth} ==",
                ds.name()
            );
            let table: Vec<Vec<String>> = rows
                .iter()
                .map(|r| {
                    vec![
                        format!("{}", r.iota),
                        format!("{}", r.xi),
                        human_bytes(r.size_bytes),
                        format!("{:.4}", r.score),
                    ]
                })
                .collect();
            print!("{}", render(&["iota", "xi", "memory", "score"], &table));
            let free = &rows[0];
            let heavy = rows.last().unwrap();
            println!(
                "finding: memory {} -> {} from free to max-penalty corner",
                human_bytes(free.size_bytes),
                human_bytes(heavy.size_bytes)
            );
        }
    }
}
