//! Figure 4: accuracy/R² vs memory for ToaD (penalized + plain) against
//! LightGBM float32 / quantized / array, CEGB and CCP.
//!
//! Reduced grid (full grid: `cargo run --release --example
//! paper_figures -- fig4`). Expected shape (paper §4.2.1): ToaD wins at
//! every limit in the ≤128 KB regime; competitors need ~4–16× the
//! memory for equal score; array-based LightGBM sits between ToaD and
//! pointer LightGBM.

use std::time::Instant;
use toad::data::synth::PaperDataset;
use toad::sweep::figures::fig4_rows;
use toad::sweep::table::{human_bytes, render};

fn main() {
    const KB: usize = 1024;
    let limits = [KB / 2, KB, 2 * KB, 8 * KB, 32 * KB];
    let penalties = [(2.0, 1.0), (16.0, 8.0)];
    let start = Instant::now();
    for (ds, row_cap) in [
        (PaperDataset::BreastCancer, 569),
        (PaperDataset::CovertypeBinary, 4000),
        (PaperDataset::CaliforniaHousing, 4000),
        (PaperDataset::WineQuality, 3000),
    ] {
        let rows = fig4_rows(ds, &[1, 2], &[2, 3], 6, &penalties, &limits, row_cap);
        let table: Vec<Vec<String>> = rows
            .iter()
            .filter(|r| r.n > 0)
            .map(|r| {
                vec![
                    r.series.clone(),
                    human_bytes(r.limit_bytes),
                    format!("{:.4}", r.mean),
                    format!("{:.4}", r.std),
                    format!("{}", r.n),
                ]
            })
            .collect();
        println!("\n== Figure 4 ({}) ==", ds.name());
        print!("{}", render(&["series", "limit", "mean", "std", "seeds"], &table));

        // Headline check: memory ToaD needs for the f32 baseline's best
        // small-budget score.
        let lgbm_1k = rows
            .iter()
            .find(|r| r.series == "lgbm_f32" && r.limit_bytes == 2 * KB && r.n > 0)
            .map(|r| r.mean);
        if let Some(target) = lgbm_1k {
            let toad_needs = limits
                .iter()
                .find(|&&l| {
                    rows.iter().any(|r| {
                        r.series == "toad(penalized)" && r.limit_bytes == l && r.mean >= target
                    })
                })
                .copied();
            if let Some(l) = toad_needs {
                println!(
                    "headline: lgbm_f32@2KB scores {:.4}; toad matches it at {} ({}x less)",
                    target,
                    human_bytes(l),
                    2 * KB / l.max(1)
                );
            }
        }
    }
    println!("\ntotal bench time: {:.1?}", start.elapsed());
}
