//! Figure 5: model performance on California Housing under a 1 KB
//! memory limit as a function of the two penalties.
//!
//! Matches the paper's protocol: the memory budget is fixed via
//! `toad_forestsize` and training adds trees until the next one would
//! overflow it — so each (ι, ξ) cell reports how much *quality* fits
//! into the same bytes. Expected shape (paper §4.2.1): moderate
//! penalty combinations dominate the unpenalized corner.

use toad::data::synth::PaperDataset;
use toad::sweep::figures::multivariate_budget_rows;
use toad::sweep::table::{human_bytes, render};

fn main() {
    const KB: usize = 1024;
    let grid: Vec<f64> = vec![0.0, 0.25, 1.0, 4.0, 16.0, 64.0, 256.0];
    let rows = multivariate_budget_rows(
        PaperDataset::CaliforniaHousing,
        1,
        &grid,
        &grid,
        512, // round cap; the byte budget is the real stop
        2,
        KB,
        4000,
    );

    println!("== Figure 5: California Housing, 1 KB budget ==");
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("{}", r.iota),
                format!("{}", r.xi),
                human_bytes(r.size_bytes),
                format!("{:.4}", r.score),
            ]
        })
        .collect();
    print!("{}", render(&["iota", "xi", "size", "R2"], &table));

    let best_pen = rows
        .iter()
        .filter(|r| r.iota > 0.0 || r.xi > 0.0)
        .max_by(|a, b| a.score.partial_cmp(&b.score).unwrap());
    let plain = rows.iter().find(|r| r.iota == 0.0 && r.xi == 0.0);
    if let (Some(p), Some(q)) = (best_pen, plain) {
        println!(
            "\nbest penalized: R2={:.4} at (i={}, x={}); unpenalized: R2={:.4} — \
             penalties {} the same 1 KB",
            p.score,
            p.iota,
            p.xi,
            q.score,
            if p.score > q.score { "beat" } else { "match" }
        );
    }
}
