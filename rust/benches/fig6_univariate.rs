//! Figure 6: univariate sensitivity of the feature penalty ι (top) and
//! threshold penalty ξ (bottom) at 256 iterations, depth 2.
//!
//! Expected shapes (paper §4.3): for ι — feature count flat below a
//! dataset-specific knee then dropping, score degrading later for
//! feature-rich datasets; for ξ — global values decreasing
//! monotonically, ReF rising to a peak ≥1.5 before collapsing to ~1 at
//! extreme penalties, score dropping after the ReF peak.

use toad::data::synth::PaperDataset;
use toad::sweep::figures::{univariate_rows, PenaltyKind};
use toad::sweep::table::render;

fn main() {
    let values: Vec<f64> = (-4..=15).step_by(2).map(|e| 2f64.powi(e)).collect();
    for (ds, row_cap) in [
        (PaperDataset::BreastCancer, 569),
        (PaperDataset::CaliforniaHousing, 4000),
        (PaperDataset::CovertypeBinary, 4000),
        (PaperDataset::KrVsKp, 3196),
    ] {
        for (kind, label) in [(PenaltyKind::Feature, "iota"), (PenaltyKind::Threshold, "xi")] {
            let rows = univariate_rows(ds, 1, kind, &values, 256, 2, row_cap);
            let table: Vec<Vec<String>> = rows
                .iter()
                .map(|r| {
                    vec![
                        format!("{}", r.penalty),
                        format!("{:.4}", r.score),
                        format!("{}", r.n_features),
                        format!("{}", r.n_global_values),
                        format!("{:.2}", r.reuse_factor),
                    ]
                })
                .collect();
            println!("\n== Figure 6 ({} / {label}) ==", ds.name());
            print!(
                "{}",
                render(&[label, "score", "features", "global_values", "ReF"], &table)
            );
            // Shape assertions printed as findings.
            let first = rows.first().unwrap();
            let last = rows.last().unwrap();
            let peak_ref =
                rows.iter().map(|r| r.reuse_factor).fold(f64::NEG_INFINITY, f64::max);
            match kind {
                PenaltyKind::Feature => println!(
                    "finding: features {} -> {} as iota grows",
                    first.n_features, last.n_features
                ),
                PenaltyKind::Threshold => println!(
                    "finding: values {} -> {}; ReF peak {:.2} (paper: >=1.5 before collapse)",
                    first.n_global_values, last.n_global_values, peak_ref
                ),
            }
        }
    }
}
