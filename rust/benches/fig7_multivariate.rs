//! Figure 7: the combined (ι × ξ) grids — memory (top) and score
//! (bottom) per cell, at 256 iterations, depth 2.
//!
//! Expected shape (paper §4.4): memory falls steeply past a
//! dataset-specific penalty threshold (covtype/california: ~KBs down to
//! ~tens of bytes); score stays flat until the same region then
//! collapses; very few cells are dominated.

use toad::data::synth::PaperDataset;
use toad::sweep::figures::multivariate_rows;
use toad::sweep::table::{human_bytes, render};

fn main() {
    let grid: Vec<f64> = vec![0.0, 0.0625, 1.0, 16.0, 256.0, 4096.0, 32768.0];
    for (ds, row_cap) in [
        (PaperDataset::BreastCancer, 569),
        (PaperDataset::CaliforniaHousing, 4000),
        (PaperDataset::CovertypeBinary, 4000),
        (PaperDataset::WineQuality, 3000),
    ] {
        let rows = multivariate_rows(ds, 1, &grid, &grid, 128, 2, row_cap);
        println!("\n== Figure 7 ({}) ==", ds.name());
        let table: Vec<Vec<String>> = rows
            .iter()
            .map(|r| {
                vec![
                    format!("{}", r.iota),
                    format!("{}", r.xi),
                    human_bytes(r.size_bytes),
                    format!("{:.4}", r.score),
                ]
            })
            .collect();
        print!("{}", render(&["iota", "xi", "memory", "score"], &table));

        // Domination census (paper: only ~3.4% of solutions dominated).
        let mut dominated = 0usize;
        for a in &rows {
            if rows.iter().any(|b| {
                (b.score > a.score && b.size_bytes <= a.size_bytes)
                    || (b.score >= a.score && b.size_bytes < a.size_bytes)
            }) {
                dominated += 1;
            }
        }
        let max_mem = rows.iter().map(|r| r.size_bytes).max().unwrap();
        let min_mem = rows.iter().map(|r| r.size_bytes).min().unwrap();
        println!(
            "finding: memory spans {} .. {}; {}/{} cells dominated",
            human_bytes(min_mem),
            human_bytes(max_mem),
            dominated,
            rows.len()
        );
    }
}
