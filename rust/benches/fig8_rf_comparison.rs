//! Figure 8 / Appendix D: boosted methods vs random forests and
//! Guo-et-al.-pruned forests, classification datasets, ≤256 trees.
//!
//! Expected shape (paper App. D): boosted/ToaD dominates at small
//! budgets; RF needs far more memory per accuracy point (deep trees,
//! 128-bit nodes); Guo pruning moves RF toward the origin but not past
//! ToaD.

use toad::data::synth::PaperDataset;
use toad::sweep::figures::fig8_rows;
use toad::sweep::table::{human_bytes, render};

fn main() {
    const KB: usize = 1024;
    let limits = [2 * KB, 8 * KB, 32 * KB, 128 * KB, 512 * KB];
    for (ds, row_cap) in [
        (PaperDataset::BreastCancer, 569),
        (PaperDataset::KrVsKp, 3196),
        (PaperDataset::Mushroom, 3000),
    ] {
        let rows = fig8_rows(ds, &[1, 2], &[2, 3], &limits, row_cap);
        println!("\n== Figure 8 ({}) ==", ds.name());
        let table: Vec<Vec<String>> = rows
            .iter()
            .filter(|r| r.n > 0)
            .map(|r| {
                vec![
                    r.series.clone(),
                    human_bytes(r.limit_bytes),
                    format!("{:.4}", r.mean),
                    format!("{:.4}", r.std),
                ]
            })
            .collect();
        print!("{}", render(&["series", "limit", "mean", "std"], &table));

        // Finding: smallest budget at which each series reaches 95% of
        // its own best score.
        for series in ["toad(penalized)", "rf", "rf_guo_pruned"] {
            let best = rows
                .iter()
                .filter(|r| r.series == series && r.n > 0)
                .map(|r| r.mean)
                .fold(f64::NEG_INFINITY, f64::max);
            let first = limits.iter().find(|&&l| {
                rows.iter()
                    .any(|r| r.series == series && r.limit_bytes == l && r.mean >= 0.95 * best)
            });
            if let Some(&l) = first {
                println!("finding: {series} reaches 95% of its best at {}", human_bytes(l));
            }
        }
    }
}
