//! §Perf: micro/meso benchmarks of every hot path in the stack.
//! Results feed EXPERIMENTS.md §Perf (before/after iteration log).
//!
//! L3 native: histogram build, split scan, boosting round, native and
//! bit-packed inference, ToaD encode/decode. Runtime: XLA batch predict
//! throughput and gateway batching overhead (needs `make artifacts`).

use std::time::{Duration, Instant};
use toad::data::synth::PaperDataset;
use toad::data::Binner;
use toad::gbdt::histogram::HistogramSet;
use toad::gbdt::{self, GbdtParams};
use toad::layout::{encode, EncodeOptions, FeatureInfo, PackedModel};

fn time<F: FnMut()>(label: &str, iters: usize, mut f: F) -> f64 {
    // Warmup.
    f();
    let start = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = start.elapsed().as_secs_f64() / iters as f64;
    println!("{label:44} {:>12.3} us/iter", per * 1e6);
    per
}

fn main() {
    let data = PaperDataset::CovertypeBinary.generate(1);
    let data = data.select(&(0..16_384).collect::<Vec<_>>());
    let binner = Binner::fit(&data, 255);
    let binned = binner.bin_dataset(&data);
    let bins: Vec<usize> = (0..binner.n_features()).map(|f| binner.n_bins(f)).collect();
    let n = data.n_rows();
    let grad: Vec<f64> = (0..n).map(|i| (i % 7) as f64 - 3.0).collect();
    let hess = vec![1.0f64; n];
    let rows: Vec<u32> = (0..n as u32).collect();

    println!("== L3 hot paths (covtype_binary, {n} rows × {} features) ==", data.n_features());

    // Histogram build: the training hot path.
    let mut hist = HistogramSet::new(&bins);
    let per = time("histogram build (16k rows, 54 feats)", 20, || {
        hist.build(&binned, &rows, &grad, &hess);
    });
    let pts = (n * data.n_features()) as f64 / per;
    println!("{:44} {:>12.1} M (row,feature)/s", "  -> throughput", pts / 1e6);

    // One boosting round end to end.
    time("boosting round (depth 3, 16k rows)", 5, || {
        let _ = gbdt::booster::train(&data, GbdtParams::paper(1, 3));
    });

    // Inference paths.
    let model = gbdt::booster::train(&data, GbdtParams::paper(64, 4));
    let finfo = FeatureInfo::from_dataset(&data);
    let blob = encode(&model, &finfo, &EncodeOptions::default());
    println!(
        "model: {} trees depth<=4, toad blob {} bytes",
        model.n_trees(),
        blob.len()
    );
    let packed = PackedModel::from_bytes(blob.clone());
    let test_rows: Vec<Vec<f32>> = (0..512).map(|i| data.row(i)).collect();

    time("native predict (512 rows, 64 trees)", 20, || {
        let mut acc = 0.0;
        for r in &test_rows {
            acc += model.predict_raw(r)[0];
        }
        std::hint::black_box(acc);
    });
    time("bit-packed predict (512 rows)", 5, || {
        let mut acc = 0.0;
        for r in &test_rows {
            acc += packed.predict_raw(r)[0];
        }
        std::hint::black_box(acc);
    });

    // Layout codec.
    time("toad encode", 50, || {
        std::hint::black_box(encode(&model, &finfo, &EncodeOptions::default()));
    });
    time("toad decode", 50, || {
        std::hint::black_box(toad::layout::decode(&blob));
    });

    // XLA runtime (optional).
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if artifacts.join("MANIFEST.txt").exists() {
        println!("\n== XLA runtime ==");
        let rt = toad::runtime::XlaRuntime::open(&artifacts).unwrap();
        let tm = toad::runtime::tensorize(&model, 256, 4, 64, 1).unwrap();
        let t = Instant::now();
        let mut engine = toad::runtime::PredictEngine::new(&rt, tm.clone(), 256, 64).unwrap();
        println!("{:44} {:>12.3} ms", "compile predict artifact (one-off)", t.elapsed().as_secs_f64() * 1e3);
        let batch: Vec<Vec<f32>> = test_rows.iter().take(256).cloned().collect();
        let per = time("xla batch predict (256 rows/call)", 20, || {
            std::hint::black_box(engine.predict(&batch).unwrap());
        });
        println!(
            "{:44} {:>12.1} K rows/s",
            "  -> throughput",
            256.0 / per / 1e3
        );

        // Gateway batching overhead: single-row latency through the
        // batcher vs direct engine call.
        let batcher = toad::coordinator::Batcher::spawn(
            tm,
            toad::coordinator::BatcherConfig {
                max_batch: 32,
                max_wait: Duration::from_micros(200),
            },
            toad::coordinator::batcher::Backend::Xla { artifacts_dir: artifacts, features: 64 },
        );
        time("gateway single-row predict (batch=1 flush)", 50, || {
            std::hint::black_box(batcher.predict(test_rows[0].clone()));
        });
    } else {
        println!("\n(xla section skipped: run `make artifacts`)");
    }
}
