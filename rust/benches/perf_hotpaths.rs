//! §Perf: micro/meso benchmarks of every hot path in the stack, with
//! before/after pairs for the columnar histogram kernel and the blocked
//! flat inference engine. Results feed EXPERIMENTS.md §Perf and are
//! also written machine-readable to `BENCH_hotpaths.json` at the repo
//! root (kernel → ns/op) so the perf trajectory is tracked across PRs.
//!
//! ```bash
//! cargo bench --bench perf_hotpaths
//! ```

use std::time::Instant;
use toad::data::synth::PaperDataset;
use toad::data::Binner;
use toad::gbdt::histogram::{HistogramPool, HistogramSet};
use toad::gbdt::{self, GbdtParams};
use toad::inference::{FlatModel, QuantizedFlatModel};
use toad::layout::{encode, EncodeOptions, FeatureInfo, PackedModel};

/// Wall-clock a closure; returns seconds per iteration and prints.
fn time<F: FnMut()>(label: &str, iters: usize, mut f: F) -> f64 {
    // Warmup.
    f();
    let start = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = start.elapsed().as_secs_f64() / iters as f64;
    println!("{label:44} {:>12.3} us/iter", per * 1e6);
    per
}

/// `(key, ns/op)` records destined for BENCH_hotpaths.json.
struct Records(Vec<(String, f64)>);

impl Records {
    fn push(&mut self, key: &str, secs_per_op: f64) {
        self.0.push((key.to_string(), secs_per_op * 1e9));
    }

    fn lookup(&self, key: &str) -> f64 {
        self.0.iter().find(|(k, _)| k == key).map(|(_, v)| *v).unwrap_or(f64::NAN)
    }

    /// Hand-rolled JSON (the build is dependency-free by design).
    fn to_json(
        &self,
        dataset: &str,
        simd_tier: &str,
        speedups: &[(&str, f64)],
        stats: &[(&str, f64)],
    ) -> String {
        let mut s = String::from("{\n");
        s.push_str(&format!("  \"dataset\": \"{dataset}\",\n"));
        s.push_str(&format!("  \"simd_tier\": \"{simd_tier}\",\n"));
        s.push_str("  \"unit\": \"ns_per_op\",\n");
        s.push_str("  \"kernels\": {\n");
        for (i, (k, v)) in self.0.iter().enumerate() {
            let comma = if i + 1 == self.0.len() { "" } else { "," };
            s.push_str(&format!("    \"{k}\": {v:.1}{comma}\n"));
        }
        s.push_str("  },\n  \"speedups\": {\n");
        for (i, (k, v)) in speedups.iter().enumerate() {
            let comma = if i + 1 == speedups.len() { "" } else { "," };
            s.push_str(&format!("    \"{k}\": {v:.2}{comma}\n"));
        }
        s.push_str("  },\n  \"stats\": {\n");
        for (i, (k, v)) in stats.iter().enumerate() {
            let comma = if i + 1 == stats.len() { "" } else { "," };
            s.push_str(&format!("    \"{k}\": {v:.2}{comma}\n"));
        }
        s.push_str("  }\n}\n");
        s
    }
}

fn main() {
    // The tier every dispatched hot path below runs on (also consumed
    // by the CI bench job's log: grep "simd dispatch tier").
    let tier = toad::simd::tier();
    println!("simd dispatch tier: {} (lane kernels + scalar fallback)", tier.name());
    let data = PaperDataset::CovertypeBinary.generate(1);
    let data = data.select(&(0..16_384).collect::<Vec<_>>());
    let binner = Binner::fit(&data, 255);
    let binned = binner.bin_matrix(&data);
    println!(
        "bin arena: {} ({} KB for {} cells)",
        if binned.is_u8() { "u8" } else { "u16" },
        binned.arena_bytes() / 1024,
        binned.n_rows() * binned.n_features(),
    );
    let bins: Vec<usize> = (0..binner.n_features()).map(|f| binner.n_bins(f)).collect();
    let n = data.n_rows();
    let d = data.n_features();
    let grad: Vec<f64> = (0..n).map(|i| (i % 7) as f64 - 3.0).collect();
    let hess = vec![1.0f64; n];
    let rows: Vec<u32> = (0..n as u32).collect();
    // A leaf-like subset (every other row) exercises the gathered path.
    let half_rows: Vec<u32> = (0..n as u32).step_by(2).collect();

    let mut rec = Records(Vec::new());

    println!("== L3 hot paths (covtype_binary, {n} rows x {d} features) ==");

    // ---- histogram build: scalar baseline vs columnar kernel ---------
    let mut hist = HistogramSet::new(&bins);
    let per = time("histogram build scalar (16k rows, before)", 20, || {
        hist.build_scalar(&binned, &rows, &grad, &hess);
    });
    rec.push("histogram_build_scalar", per);

    let mut pool = HistogramPool::new(&bins);
    let per_fast = time("histogram build columnar+pool (after)", 20, || {
        let h = pool.build(&binned, &rows, &grad, &hess);
        pool.recycle(h);
    });
    rec.push("histogram_build_columnar", per_fast);
    let pts = (n * d) as f64 / per_fast;
    println!("{:44} {:>12.1} M (row,feature)/s", "  -> columnar throughput", pts / 1e6);

    let per = time("histogram subset scalar (8k rows, before)", 20, || {
        hist.build_scalar(&binned, &half_rows, &grad, &hess);
    });
    rec.push("histogram_subset_scalar", per);
    let per = time("histogram subset gathered (after)", 20, || {
        let h = pool.build(&binned, &half_rows, &grad, &hess);
        pool.recycle(h);
    });
    rec.push("histogram_subset_gathered", per);

    // ---- histogram accumulation: forced-scalar twin vs SIMD tier -----
    // Same columnar+pool path both times; only the dispatch tier
    // differs, so this isolates the explicit SIMD win.
    let per_hist_scalar = time("histogram build forced-scalar tier", 20, || {
        let h = pool.build_with_tier(&binned, &rows, &grad, &hess, toad::simd::Tier::Scalar);
        pool.recycle(h);
    });
    rec.push("histogram_build_forced_scalar", per_hist_scalar);
    let per_hist_simd = time(&format!("histogram build simd tier ({})", tier.name()), 20, || {
        let h = pool.build_with_tier(&binned, &rows, &grad, &hess, tier);
        pool.recycle(h);
    });
    rec.push("histogram_build_simd", per_hist_simd);

    // ---- feature-sharded parallel build (auto-selected count) ---------
    let shards = toad::gbdt::histogram::auto_shards(bins.len());
    let mut sharded_pool = HistogramPool::with_shards(&bins, shards);
    let per = time(&format!("histogram build sharded x{shards} (16k rows)"), 20, || {
        let h = sharded_pool.build(&binned, &rows, &grad, &hess);
        sharded_pool.recycle(h);
    });
    rec.push("histogram_sharded", per);

    // ---- sparse CSR histogram kernel (1% density) ---------------------
    // nnz-scaled accumulation (present entries + one closed-form
    // default-bin correction per feature) vs the dense kernel on the
    // densified twin of the *same* data — bit-identical histograms on
    // integer stats, cost O(nnz) vs O(rows x features). The speedup is
    // logged and exported, not assumed >= 1 here; the CI bench-sanity
    // step asserts it is a positive finite number.
    let (sx, stargets) = toad::data::synth::synth_sparse_rows(7, 0..n, 64, 0.01);
    let sds = toad::data::SparseDataset {
        name: "synth_sparse".into(),
        x: sx,
        targets: stargets,
        labels: vec![],
        task: toad::data::Task::Regression,
    };
    let sbinner = Binner::fit_sparse(&sds, 255);
    let sparse_binned = sbinner.bin_sparse(&sds.x);
    let dense_twin = sbinner.bin_matrix(&sds.densify());
    println!(
        "sparse arena: {}/{} cols sparse at density {:.4}, {} KB (densified twin {} KB)",
        sparse_binned.n_sparse_cols(),
        sparse_binned.n_features(),
        sds.x.density(),
        sparse_binned.arena_bytes() / 1024,
        dense_twin.arena_bytes() / 1024,
    );
    let sbins: Vec<usize> = (0..sbinner.n_features()).map(|f| sbinner.n_bins(f)).collect();
    let mut spool = HistogramPool::new(&sbins);
    let per_sparse = time("histogram sparse kernel (16k x 64 @ 1%)", 20, || {
        let h = spool.build(&sparse_binned, &rows, &grad, &hess);
        spool.recycle(h);
    });
    rec.push("histogram_sparse", per_sparse);
    let per_sparse_twin = time("histogram densified twin (same data)", 20, || {
        let h = spool.build(&dense_twin, &rows, &grad, &hess);
        spool.recycle(h);
    });
    rec.push("histogram_sparse_densified_twin", per_sparse_twin);

    // ---- one boosting round end to end -------------------------------
    let per = time("boosting round (depth 3, 16k rows)", 5, || {
        let _ = gbdt::booster::train(&data, GbdtParams::paper(1, 3));
    });
    rec.push("boosting_round_d3", per);

    // ---- histogram merge (row-sharded reduction primitive) -----------
    // Seed-by-copy + one merge: exactly what the banded fold pays per
    // reduced cell beyond the accumulation itself.
    let odd_rows: Vec<u32> = (1..n as u32).step_by(2).collect();
    let mut part_a = HistogramSet::new(&bins);
    part_a.build(&binned, &half_rows, &grad, &hess);
    let mut part_b = HistogramSet::new(&bins);
    part_b.build(&binned, &odd_rows, &grad, &hess);
    let mut folded = HistogramSet::new(&bins);
    let per = time("histogram merge (copy seed + 1 merge)", 200, || {
        folded.copy_from(&part_a);
        folded.merge(&part_b);
        std::hint::black_box(folded.bin(0, 0));
    });
    rec.push("histogram_merge", per);

    // ---- out-of-core boosting round (streamed on-disk arena) ----------
    // Full pipeline twin of `boosting_round_d3`: two streaming passes
    // (sketch + transform) into a temp arena, then one boosting round
    // reading row blocks back from disk. Bit-identical model; the delta
    // over `boosting_round_d3` is the out-of-core tax.
    let arena =
        std::env::temp_dir().join(format!("toad-bench-arena-{}.bin", std::process::id()));
    let per_ooc = time("out-of-core boosting round (block 4096)", 5, || {
        let (b, c) = Binner::fit_transform_to_disk(&arena, n, d, 255, 4096, |range| {
            data.features
                .iter()
                .map(|col| col[range.clone()].to_vec())
                .collect::<Vec<Vec<f32>>>()
        })
        .expect("stream bench dataset to disk");
        let _ = gbdt::booster::train_chunked(
            b,
            c,
            data.targets.clone(),
            data.labels.clone(),
            data.task,
            &data.name,
            GbdtParams::paper(1, 3),
        );
    });
    rec.push("train_out_of_core", per_ooc);
    let _ = std::fs::remove_file(&arena);

    // ---- row-sharded multi-worker boosting round ----------------------
    // K = 1 is the single-node reference (same banded fold, one
    // worker); the speedup below is logged, not asserted >= 1 — at 16k
    // rows thread spawn can eat the win on small machines.
    let row_workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(8);
    let per_rs_single = time("row-sharded boosting round (K=1)", 5, || {
        let _ = gbdt::train_row_sharded(&data, GbdtParams::paper(1, 3), 1);
    });
    rec.push("train_row_sharded_single", per_rs_single);
    let per_rs = time(&format!("row-sharded boosting round (K={row_workers})"), 5, || {
        let _ = gbdt::train_row_sharded(&data, GbdtParams::paper(1, 3), row_workers);
    });
    rec.push("train_row_sharded", per_rs);

    // ---- inference: row-at-a-time pointer trees vs blocked flat ------
    let model = gbdt::booster::train(&data, GbdtParams::paper(64, 4));
    let finfo = FeatureInfo::from_dataset(&data);
    let blob = encode(&model, &finfo, &EncodeOptions::default()).expect("model fits layout fields");
    println!("model: {} trees depth<=4, toad blob {} bytes", model.n_trees(), blob.len());
    let packed = PackedModel::from_bytes(blob.clone());
    let flat = FlatModel::from_model(&model);
    let quant = QuantizedFlatModel::from_model(&model);
    println!(
        "quantized engine: {} distinct thresholds -> u16 ranks ({} complete trees)",
        quant.n_thresholds(),
        quant.n_complete_trees()
    );
    let test_rows: Vec<Vec<f32>> = (0..512).map(|i| data.row(i)).collect();

    let per = time("native predict row-wise (512 rows, before)", 20, || {
        let mut acc = 0.0;
        for r in &test_rows {
            acc += model.predict_raw(r)[0];
        }
        std::hint::black_box(acc);
    });
    rec.push("native_predict_rowwise_512", per);

    let per_flat = time("flat predict_batch (512 rows, after)", 20, || {
        std::hint::black_box(flat.predict_batch(&test_rows));
    });
    rec.push("native_predict_flat_batch_512", per_flat);
    println!(
        "{:44} {:>12.1} K rows/s",
        "  -> flat batch throughput",
        512.0 / per_flat / 1e3
    );

    let per = time("flat predict single-row (512 rows)", 20, || {
        let mut acc = 0.0;
        for r in &test_rows {
            acc += flat.predict_raw(r)[0];
        }
        std::hint::black_box(acc);
    });
    rec.push("native_predict_flat_single_512", per);

    let per_quant = time("quantized predict_batch (512 rows, after)", 20, || {
        std::hint::black_box(quant.predict_batch(&test_rows));
    });
    rec.push("quantized_batch", per_quant);
    println!(
        "{:44} {:>12.1} K rows/s",
        "  -> quantized batch throughput",
        512.0 / per_quant / 1e3
    );

    let per = time("quantized predict single-row (512 rows)", 20, || {
        let mut acc = 0.0;
        for r in &test_rows {
            acc += quant.predict_raw(r)[0];
        }
        std::hint::black_box(acc);
    });
    rec.push("quantized_single_512", per);

    // ---- quantized descent: forced-scalar twin vs SIMD tier ----------
    // Same binning + block partition both times; only the descent lane
    // kernel differs.
    let per_desc_scalar = time("quantized batch forced-scalar tier", 20, || {
        std::hint::black_box(quant.predict_batch_with_tier(&test_rows, toad::simd::Tier::Scalar));
    });
    rec.push("quantized_batch_forced_scalar", per_desc_scalar);
    let per_desc_simd = time(&format!("quantized batch simd tier ({})", tier.name()), 20, || {
        std::hint::black_box(quant.predict_batch_with_tier(&test_rows, tier));
    });
    rec.push("quantized_batch_simd", per_desc_simd);

    // ---- oblivious mode: level-shared splits, lookup descent ---------
    // Trained separately (level-uniform trees are a different model),
    // so this compares each engine on its natural model shape. The
    // speedup is logged either way and NOT assumed >= 1: lookup
    // descent drops per-node branching but a level-shared split can
    // grow less discriminating trees, and on shallow depths the 2^d
    // leaf-table gather can offset the branch savings.
    let per_obl_train = time("oblivious boosting round (depth 3, 16k rows)", 5, || {
        let mut p = GbdtParams::paper(1, 3);
        p.growth = gbdt::GrowthMode::Oblivious;
        let _ = gbdt::booster::train(&data, p);
    });
    rec.push("oblivious_train", per_obl_train);
    let mut obl_params = GbdtParams::paper(64, 4);
    obl_params.growth = gbdt::GrowthMode::Oblivious;
    let obl_model = gbdt::booster::train(&data, obl_params);
    let obl_quant = QuantizedFlatModel::from_model(&obl_model);
    println!(
        "oblivious engine: {} of {} trees in the level-shared sub-format",
        obl_quant.n_oblivious_trees(),
        obl_model.n_trees()
    );
    let per_obl = time("oblivious predict_batch (512 rows)", 20, || {
        std::hint::black_box(obl_quant.predict_batch(&test_rows));
    });
    rec.push("oblivious_batch", per_obl);
    println!(
        "{:44} {:>12.1} K rows/s",
        "  -> oblivious batch throughput",
        512.0 / per_obl / 1e3
    );

    // Columnar batch: feeds the dataset's own feature columns (no
    // per-row gather, one binning pass per column).
    let test_cols: Vec<&[f32]> = data.features.iter().map(|c| &c[..512]).collect();
    let per_columnar = time("quantized predict_batch_columns (512 rows)", 20, || {
        std::hint::black_box(quant.predict_batch_columns(&test_cols, 512));
    });
    rec.push("columnar_batch", per_columnar);
    println!(
        "{:44} {:>12.1} K rows/s",
        "  -> columnar batch throughput",
        512.0 / per_columnar / 1e3
    );

    // ---- adaptive early exit vs full descent --------------------------
    // Same engine, same rows; the adaptive kernel may stop a row's
    // descent once the remaining trees cannot change its predicted
    // sign (or move it more than the margin).
    use toad::inference::AdaptivePolicy;
    let adaptive_policy = AdaptivePolicy::Margin(0.1);
    let per_adaptive = time("adaptive predict_batch Margin(0.1)", 20, || {
        std::hint::black_box(quant.predict_batch_adaptive(&test_rows, adaptive_policy));
    });
    rec.push("adaptive_batch", per_adaptive);
    let mean_trees = quant.predict_batch_adaptive(&test_rows, adaptive_policy).mean_trees();
    println!(
        "{:44} {:>12.1} of {} trees",
        "  -> mean trees evaluated per row",
        mean_trees,
        model.n_trees()
    );

    let per = time("bit-packed predict (512 rows)", 5, || {
        let mut acc = 0.0;
        for r in &test_rows {
            acc += packed.predict_raw(r)[0];
        }
        std::hint::black_box(acc);
    });
    rec.push("packed_predict_512", per);

    // ---- layout codec -------------------------------------------------
    let per = time("toad encode", 50, || {
        std::hint::black_box(encode(&model, &finfo, &EncodeOptions::default()).unwrap());
    });
    rec.push("toad_encode", per);
    let per = time("toad decode", 50, || {
        std::hint::black_box(toad::layout::decode(&blob));
    });
    rec.push("toad_decode", per);

    // ---- gateway overhead over the native batch engine ----------------
    let batcher = toad::coordinator::Batcher::spawn(
        toad::coordinator::BatcherConfig {
            max_batch: 32,
            max_wait: std::time::Duration::from_micros(200),
            queue_depth: 4096,
            ..Default::default()
        },
        toad::coordinator::batcher::Backend::Native(flat.clone()),
    );
    let per_gateway = time("gateway single-row predict (native)", 50, || {
        std::hint::black_box(batcher.predict(test_rows[0].clone()).unwrap());
    });
    rec.push("gateway_native_single_row", per_gateway);
    drop(batcher);

    // ---- registry hot-swap + concurrent serving ----------------------
    use toad::coordinator::{BatcherConfig, FleetServer, ModelCard, ModelRegistry};
    let registry = ModelRegistry::new();
    let card = ModelCard {
        id: "bench".into(),
        score: 0.9,
        size_bytes: blob.len(),
        blob: blob.clone(),
    };
    let engine = model.quantize();
    let per = time("registry publish+resolve (swap)", 200, || {
        registry.publish("cov", card.clone(), engine.clone());
        std::hint::black_box(registry.current("cov").unwrap().version);
    });
    rec.push("registry_swap", per);

    let mut server = FleetServer::new();
    server.add_registry_gateway(
        "cov",
        BatcherConfig {
            max_batch: 64,
            max_wait: std::time::Duration::from_micros(200),
            queue_depth: 65_536,
            ..Default::default()
        },
    );
    server.registry().publish("cov", card.clone(), engine.clone());
    let threads = 4usize;
    let reqs_per_thread = 256usize;
    let per_burst = time(&format!("server submit x{threads} threads (per req)"), 10, || {
        std::thread::scope(|s| {
            for t in 0..threads {
                let server = &server;
                let rows = &test_rows;
                s.spawn(move || {
                    let tickets: Vec<_> = (0..reqs_per_thread)
                        .map(|i| {
                            server.submit("cov", rows[(t + i) % rows.len()].clone()).unwrap()
                        })
                        .collect();
                    for tk in tickets {
                        std::hint::black_box(tk.wait().unwrap());
                    }
                });
            }
        });
    });
    let per_req = per_burst / (threads * reqs_per_thread) as f64;
    rec.push("server_submit_concurrent", per_req);
    println!(
        "{:44} {:>12.1} K req/s",
        "  -> concurrent server throughput",
        1.0 / per_req / 1e3
    );

    // ---- XLA runtime (feature-gated, needs `make artifacts`) ----------
    xla_section(&test_rows);

    // ---- summary + JSON -----------------------------------------------
    let hist_speedup =
        rec.lookup("histogram_build_scalar") / rec.lookup("histogram_build_columnar");
    let subset_speedup =
        rec.lookup("histogram_subset_scalar") / rec.lookup("histogram_subset_gathered");
    let sharded_speedup =
        rec.lookup("histogram_build_scalar") / rec.lookup("histogram_sharded");
    let predict_speedup =
        rec.lookup("native_predict_rowwise_512") / rec.lookup("native_predict_flat_batch_512");
    let quant_speedup =
        rec.lookup("native_predict_rowwise_512") / rec.lookup("quantized_batch");
    let quant_vs_flat =
        rec.lookup("native_predict_flat_batch_512") / rec.lookup("quantized_batch");
    let columnar_vs_row =
        rec.lookup("quantized_batch") / rec.lookup("columnar_batch");
    let concurrent_vs_serial =
        rec.lookup("gateway_native_single_row") / rec.lookup("server_submit_concurrent");
    let simd_vs_scalar_descent =
        rec.lookup("quantized_batch_forced_scalar") / rec.lookup("quantized_batch_simd");
    let simd_vs_scalar_histogram =
        rec.lookup("histogram_build_forced_scalar") / rec.lookup("histogram_build_simd");
    let adaptive_vs_full = rec.lookup("quantized_batch") / rec.lookup("adaptive_batch");
    let oblivious_vs_quantized = rec.lookup("quantized_batch") / rec.lookup("oblivious_batch");
    let row_sharded_vs_single =
        rec.lookup("train_row_sharded_single") / rec.lookup("train_row_sharded");
    let sparse_vs_dense_hist =
        rec.lookup("histogram_sparse_densified_twin") / rec.lookup("histogram_sparse");
    println!("\n== speedups vs scalar baselines ==");
    println!("{:44} {:>11.2}x", "histogram build (dense)", hist_speedup);
    println!("{:44} {:>11.2}x", "histogram build (subset/gathered)", subset_speedup);
    println!("{:44} {:>11.2}x", "histogram build (sharded)", sharded_speedup);
    println!("{:44} {:>11.2}x", "native batched predict", predict_speedup);
    println!("{:44} {:>11.2}x", "quantized batched predict", quant_speedup);
    println!("{:44} {:>11.2}x", "quantized vs flat batch", quant_vs_flat);
    println!("{:44} {:>11.2}x", "columnar vs row-major batch", columnar_vs_row);
    println!("{:44} {:>11.2}x", "concurrent server vs serial gateway", concurrent_vs_serial);
    println!("{:44} {:>11.2}x", "simd vs scalar descent", simd_vs_scalar_descent);
    println!("{:44} {:>11.2}x", "simd vs scalar histogram", simd_vs_scalar_histogram);
    println!("{:44} {:>11.2}x", "adaptive vs full quantized batch", adaptive_vs_full);
    println!("{:44} {:>11.2}x", "oblivious vs quantized batch", oblivious_vs_quantized);
    println!("{:44} {:>11.2}x", "row-sharded K vs K=1 boosting round", row_sharded_vs_single);
    println!("{:44} {:>11.2}x", "sparse vs densified histogram (1%)", sparse_vs_dense_hist);

    let json = rec.to_json(
        &format!("covtype_binary_{n}x{d}"),
        tier.name(),
        &[
            ("histogram_build", hist_speedup),
            ("histogram_subset", subset_speedup),
            ("histogram_sharded", sharded_speedup),
            ("native_predict_batch", predict_speedup),
            ("quantized_predict_batch", quant_speedup),
            ("quantized_vs_flat_batch", quant_vs_flat),
            ("columnar_vs_row_batch", columnar_vs_row),
            ("server_concurrent_vs_serial", concurrent_vs_serial),
            ("simd_vs_scalar_descent", simd_vs_scalar_descent),
            ("simd_vs_scalar_histogram", simd_vs_scalar_histogram),
            ("adaptive_vs_full", adaptive_vs_full),
            ("oblivious_vs_quantized", oblivious_vs_quantized),
            ("row_sharded_vs_single", row_sharded_vs_single),
            ("sparse_vs_dense_hist", sparse_vs_dense_hist),
        ],
        &[("mean_trees_evaluated", mean_trees), ("n_trees", model.n_trees() as f64)],
    );
    // CARGO_MANIFEST_DIR is <repo>/rust; the trajectory file lives at
    // the repo root.
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_hotpaths.json");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("\nwrote {}", out.display()),
        Err(e) => eprintln!("\ncould not write {}: {e}", out.display()),
    }
}

#[cfg(feature = "xla")]
fn xla_section(test_rows: &[Vec<f32>]) {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("MANIFEST.txt").exists() {
        println!("\n(xla section skipped: run `make artifacts`)");
        return;
    }
    let data = PaperDataset::CovertypeBinary.generate(1);
    let data = data.select(&(0..16_384).collect::<Vec<_>>());
    let model = gbdt::booster::train(&data, GbdtParams::paper(64, 4));
    println!("\n== XLA runtime ==");
    let rt = toad::runtime::XlaRuntime::open(&artifacts).unwrap();
    let tm = toad::runtime::tensorize(&model, 256, 4, 64, 1).unwrap();
    let t = Instant::now();
    let mut engine = toad::runtime::PredictEngine::new(&rt, tm.clone(), 256, 64).unwrap();
    println!(
        "{:44} {:>12.3} ms",
        "compile predict artifact (one-off)",
        t.elapsed().as_secs_f64() * 1e3
    );
    let batch: Vec<Vec<f32>> = test_rows.iter().take(256).cloned().collect();
    let per = time("xla batch predict (256 rows/call)", 20, || {
        std::hint::black_box(engine.predict(&batch).unwrap());
    });
    println!("{:44} {:>12.1} K rows/s", "  -> throughput", 256.0 / per / 1e3);

    // Gateway batching overhead: single-row latency through the
    // batcher vs direct engine call.
    let batcher = toad::coordinator::Batcher::spawn(
        toad::coordinator::BatcherConfig {
            max_batch: 32,
            max_wait: std::time::Duration::from_micros(200),
            queue_depth: 4096,
            ..Default::default()
        },
        toad::coordinator::batcher::Backend::Xla {
            artifacts_dir: artifacts,
            features: 64,
            tensors: tm,
        },
    );
    time("gateway single-row predict (batch=1 flush)", 50, || {
        std::hint::black_box(batcher.predict(test_rows[0].clone()).unwrap());
    });
}

#[cfg(not(feature = "xla"))]
fn xla_section(_test_rows: &[Vec<f32>]) {
    println!("\n(xla section skipped: build with --features xla and run `make artifacts`)");
}
