//! Table 1: the dataset inventory (paper Appendix B), plus generator
//! timing — regenerates the table the evaluation rests on.
//!
//! Paper row counts are listed next to the generated counts (Covertype
//! is scaled down; DESIGN.md §5 documents the substitution).

use std::time::Instant;
use toad::data::synth::PaperDataset;
use toad::sweep::table::render;

fn main() {
    println!("== Table 1: datasets ==");
    let mut rows = Vec::new();
    for ds in PaperDataset::TABLE1 {
        let t = Instant::now();
        let d = ds.generate(1);
        let gen_ms = t.elapsed().as_secs_f64() * 1e3;
        d.validate().expect("generated dataset must validate");
        rows.push(vec![
            ds.name().to_string(),
            format!("{}", ds.paper_rows()),
            format!("{}", d.n_rows()),
            format!("{}", d.n_features()),
            format!("{:?}", d.task),
            format!("{gen_ms:.0}ms"),
        ]);
    }
    print!(
        "{}",
        render(
            &["dataset", "paper_rows", "gen_rows", "features", "task", "gen_time"],
            &rows
        )
    );
    println!("\npaper: 8 datasets, 569..581,012 instances, 8..54 features; matched above.");
}
