//! Table 2 / Appendix E.1: per-prediction latency of ToaD vs a
//! pointer-layout LightGBM export — Covertype-binary at 0.5 KB (4 trees
//! of depth 4), 500 predictions × 20 runs.
//!
//! Hardware substitution (DESIGN.md §5): the paper's physical boards are
//! replaced by the MCU cycle model; a host wall-clock measurement of the
//! same two interpreters cross-checks the *relative* slowdown. Paper
//! numbers: ESP32-S3 137 µs vs 17.6 µs (~8×); Nano 33 BLE 513 µs vs
//! 102 µs (~5×).

use std::time::Instant;
use toad::sweep::figures::table2_rows;
use toad::sweep::table::render;

fn main() {
    let (rows, packed, test) = table2_rows(1, 8000);
    println!("== Table 2: MCU cycle-model latency ==");
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.hardware.to_string(),
                format!("{:.2}", r.toad_us),
                format!("{:.2}", r.lgbm_us),
                format!("{:.1}x", r.slowdown),
            ]
        })
        .collect();
    print!("{}", render(&["hardware", "ToaD(us)", "LightGBM(us)", "slowdown"], &table));
    println!("model: {} bytes packed (budget 512B)", packed.size_bytes());
    println!("paper: ESP32S3 137.08 vs 17.63 us; Nano33BLE 512.89 vs 102.16 us");

    // Host wall-clock: 20 runs × 500 predictions, as in the appendix.
    let decoded = toad::layout::decode(packed.bytes());
    let rows500: Vec<Vec<f32>> = (0..500).map(|i| test.row(i % test.n_rows())).collect();
    let (mut t_bits, mut t_ptr) = (f64::INFINITY, f64::INFINITY);
    for _ in 0..20 {
        let s = Instant::now();
        let mut acc = 0f64;
        for r in &rows500 {
            acc += packed.predict_raw(r)[0];
        }
        std::hint::black_box(acc);
        t_bits = t_bits.min(s.elapsed().as_secs_f64() / 500.0);

        let s = Instant::now();
        let mut acc = 0f64;
        for r in &rows500 {
            acc += decoded.predict_raw(r)[0];
        }
        std::hint::black_box(acc);
        t_ptr = t_ptr.min(s.elapsed().as_secs_f64() / 500.0);
    }
    println!(
        "\nhost wall-clock: bit-packed {:.3} us vs pointer {:.3} us per prediction ({:.1}x slowdown)",
        t_bits * 1e6,
        t_ptr * 1e6,
        t_bits / t_ptr
    );
}
