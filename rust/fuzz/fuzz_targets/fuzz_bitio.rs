//! Structure-aware write/read round-trip over the bit-packing substrate.
//!
//! Input is parsed as 9-byte ops `(selector, u64 value)`:
//!
//! * selector 0..=64 — `write(value, selector)`; reading the field back
//!   must yield `value mod 2^selector` (the writer masks, the reader
//!   must agree bit for bit),
//! * 65 — `align_byte` on both sides,
//! * 66 — `write_f32` of the raw bits; the read-back bits must be
//!   identical (including NaN payloads).
//!
//! Any mismatch, panic, or out-of-bounds read in either direction is a
//! finding. This drives exactly the pointer-adjacent fast/slow read
//! paths (`57-bit window vs byte loop) that the Miri surface tests pin
//! with fixed vectors, but with fuzzer-chosen widths and alignments.
#![no_main]

use libfuzzer_sys::fuzz_target;
use toad::bitio::{BitReader, BitWriter};

enum Op {
    Field { value: u64, width: u32 },
    Align,
    F32(u32),
}

fuzz_target!(|data: &[u8]| {
    let mut ops = Vec::new();
    for chunk in data.chunks_exact(9) {
        let sel = chunk[0] % 67;
        let value = u64::from_le_bytes(chunk[1..9].try_into().unwrap());
        ops.push(match sel {
            0..=64 => Op::Field { value, width: sel as u32 },
            65 => Op::Align,
            _ => Op::F32(value as u32),
        });
    }

    let mut w = BitWriter::new();
    for op in &ops {
        match op {
            Op::Field { value, width } => w.write(*value, *width),
            Op::Align => w.align_byte(),
            Op::F32(bits) => w.write_f32(f32::from_bits(*bits)),
        }
    }
    let expected_bits = w.len_bits();
    let bytes = w.into_bytes();
    assert!(bytes.len() * 8 >= expected_bits && bytes.len() * 8 < expected_bits + 8);

    let mut r = BitReader::new(&bytes);
    for op in &ops {
        match op {
            Op::Field { value, width } => {
                let mask = if *width == 64 { u64::MAX } else { (1u64 << width) - 1 };
                assert_eq!(r.read(*width), value & mask, "width {width}");
            }
            Op::Align => r.align_byte(),
            Op::F32(bits) => assert_eq!(r.read_f32().to_bits(), *bits),
        }
    }
    assert_eq!(r.bit_pos(), expected_bits);
});
