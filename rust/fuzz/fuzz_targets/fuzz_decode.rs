//! Arbitrary bytes into the untrusted-blob entry point.
//!
//! The contract under test: `try_decode` on *any* input returns
//! `Ok`/`Err` — it must never panic, index out of bounds, or allocate
//! unboundedly (every component's size is validated against the blob
//! length before `decode` touches it). Seeds in `corpus/fuzz_decode/`
//! include a minimal valid blob and the hand-packed single-tree blob
//! from `tests/decode_robustness.rs`, so the fuzzer starts from inputs
//! that reach deep into the tree walk rather than dying at the header.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    let _ = toad::layout::toad_format::try_decode(data);
});
