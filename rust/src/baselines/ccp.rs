//! Minimal cost-complexity pruning (Breiman et al., 1984), adapted to
//! boosted trees.
//!
//! Classic CCP prunes the subtree with the smallest *effective alpha*
//!
//! ```text
//! α_eff(t) = (R(t) − R(T_t)) / (|leaves(T_t)| − 1)
//! ```
//!
//! until every remaining internal node has `α_eff > α`. For a boosted
//! regression tree the natural risk functional is the second-order
//! objective of the boosting round (paper Eq. 6): a node with gradient
//! statistics `(G, H)` has `R(node) = −½·G²/(H+λ)`, so pruning a split
//! undoes exactly the gain it contributed. Each tree is pruned right
//! after it is grown — while its round's gradients are valid — before
//! the raw scores are updated, which is the faithful way to apply CCP
//! inside a boosting loop.

use crate::data::{BinMatrix, Dataset};
use crate::gbdt::booster::{Booster, GbdtParams};
use crate::gbdt::splitter::{leaf_weight, NoPenalty};
use crate::gbdt::tree::{Node, Tree};
use crate::gbdt::GbdtModel;

/// Per-node statistics recomputed by routing the round's rows.
#[derive(Clone, Copy, Debug, Default)]
struct NodeStats {
    g: f64,
    h: f64,
}

/// Prune `tree` with cost-complexity parameter `alpha` using the
/// round's gradient/hessian statistics; leaf values of collapsed nodes
/// are refitted as `−G/(H+λ) · leaf_scale`.
pub fn prune_tree(
    tree: &Tree,
    binned: &BinMatrix,
    grad: &[f64],
    hess: &[f64],
    lambda: f64,
    leaf_scale: f64,
    alpha: f64,
) -> Tree {
    if tree.n_internal() == 0 {
        return tree.clone();
    }
    // Route every row to accumulate (G, H) per node.
    let mut stats = vec![NodeStats::default(); tree.nodes.len()];
    for i in 0..binned.n_rows() {
        let mut idx = 0usize;
        loop {
            stats[idx].g += grad[i];
            stats[idx].h += hess[i];
            match &tree.nodes[idx] {
                Node::Leaf { .. } => break,
                Node::Internal { feature, bin, left, right, .. } => {
                    idx = if binned.bin(*feature, i) <= *bin { *left } else { *right };
                }
            }
        }
    }

    // Work on a mutable copy: repeatedly collapse the weakest link.
    let mut nodes = tree.nodes.clone();
    loop {
        let weakest = weakest_link(&nodes, &stats, lambda);
        match weakest {
            Some((idx, a_eff)) if a_eff <= alpha => {
                let s = stats[idx];
                nodes[idx] =
                    Node::Leaf { value: leaf_weight(s.g, s.h, lambda) * leaf_scale };
            }
            _ => break,
        }
    }
    compact(&nodes)
}

/// Find the internal node with minimal effective alpha. Subtree leaves
/// and risk are computed bottom-up on each call (trees are tiny).
fn weakest_link(nodes: &[Node], stats: &[NodeStats], lambda: f64) -> Option<(usize, f64)> {
    fn subtree(
        nodes: &[Node],
        stats: &[NodeStats],
        lambda: f64,
        idx: usize,
    ) -> (f64 /*risk*/, usize /*leaves*/) {
        match &nodes[idx] {
            Node::Leaf { .. } => {
                let s = stats[idx];
                (-0.5 * s.g * s.g / (s.h + lambda), 1)
            }
            Node::Internal { left, right, .. } => {
                let (rl, ll) = subtree(nodes, stats, lambda, *left);
                let (rr, lr) = subtree(nodes, stats, lambda, *right);
                (rl + rr, ll + lr)
            }
        }
    }
    let mut best: Option<(usize, f64)> = None;
    for (idx, n) in nodes.iter().enumerate() {
        if !matches!(n, Node::Internal { .. }) {
            continue;
        }
        // Is this node reachable? (Collapsed subtrees leave orphans.)
        if !reachable(nodes, idx) {
            continue;
        }
        let s = stats[idx];
        let r_node = -0.5 * s.g * s.g / (s.h + lambda);
        let (r_sub, leaves) = subtree(nodes, stats, lambda, idx);
        if leaves <= 1 {
            continue;
        }
        let a_eff = (r_node - r_sub) / (leaves - 1) as f64;
        if best.map_or(true, |(_, a)| a_eff < a) {
            best = Some((idx, a_eff));
        }
    }
    best
}

fn reachable(nodes: &[Node], target: usize) -> bool {
    let mut stack = vec![0usize];
    while let Some(i) = stack.pop() {
        if i == target {
            return true;
        }
        if let Node::Internal { left, right, .. } = &nodes[i] {
            stack.push(*left);
            stack.push(*right);
        }
    }
    false
}

/// Drop orphaned nodes and reindex children.
fn compact(nodes: &[Node]) -> Tree {
    let mut out = Vec::new();
    fn copy(nodes: &[Node], idx: usize, out: &mut Vec<Node>) -> usize {
        let new_idx = out.len();
        match &nodes[idx] {
            Node::Leaf { value } => {
                out.push(Node::Leaf { value: *value });
            }
            Node::Internal { feature, bin, threshold, left, right } => {
                out.push(Node::Leaf { value: 0.0 }); // placeholder
                let l = copy(nodes, *left, out);
                let r = copy(nodes, *right, out);
                out[new_idx] = Node::Internal {
                    feature: *feature,
                    bin: *bin,
                    threshold: *threshold,
                    left: l,
                    right: r,
                };
            }
        }
        new_idx
    }
    copy(nodes, 0, &mut out);
    Tree { nodes: out }
}

/// Train a boosted ensemble with per-tree CCP at parameter `alpha`.
pub fn train_ccp(data: &Dataset, params: GbdtParams, alpha: f64) -> GbdtModel {
    let lambda = params.lambda;
    let leaf_scale = params.learning_rate;
    let mut b = Booster::new(data, params, NoPenalty);
    for _ in 0..params.n_rounds {
        b.boost_round_map(|binned, grad, hess, tree| {
            prune_tree(&tree, binned, grad, hess, lambda, leaf_scale, alpha)
        });
    }
    b.into_model()
}

#[cfg(test)]
#[cfg(not(miri))] // trains models / generates datasets - too slow under the Miri interpreter
mod tests {
    use super::*;
    use crate::data::synth::PaperDataset;
    use crate::data::train_test_split;

    #[test]
    fn zero_alpha_only_prunes_useless_splits() {
        let data = PaperDataset::BreastCancer.generate(1);
        let (train_set, test_set) = train_test_split(&data, 0.2, 1);
        let params = GbdtParams::paper(16, 3);
        let plain = crate::gbdt::booster::train(&train_set, params);
        let pruned = train_ccp(&train_set, params, 0.0);
        // alpha=0 prunes only zero-gain subtrees: score preserved.
        let a = plain.score(&test_set);
        let b = pruned.score(&test_set);
        assert!((a - b).abs() < 0.05, "alpha=0 moved accuracy {a} -> {b}");
    }

    #[test]
    fn large_alpha_collapses_trees() {
        let data = PaperDataset::Mushroom.generate(2).select(&(0..2000).collect::<Vec<_>>());
        let params = GbdtParams::paper(8, 4);
        let pruned = train_ccp(&data, params, 1e12);
        for t in pruned.trees.iter().flatten() {
            assert_eq!(t.n_internal(), 0, "huge alpha must collapse to bare leaves");
        }
    }

    #[test]
    fn monotone_in_alpha() {
        let data = PaperDataset::KrVsKp.generate(3).select(&(0..1500).collect::<Vec<_>>());
        let params = GbdtParams::paper(8, 4);
        let sizes: Vec<usize> = [0.0, 0.5, 5.0, 50.0]
            .iter()
            .map(|&a| {
                let m = train_ccp(&data, params, a);
                m.trees.iter().flatten().map(|t| t.n_nodes()).sum()
            })
            .collect();
        for w in sizes.windows(2) {
            assert!(w[1] <= w[0] + 2, "node count should shrink with alpha: {sizes:?}");
        }
    }

    #[test]
    fn pruned_tree_is_well_formed() {
        let data = PaperDataset::CaliforniaHousing.generate(4).select(&(0..1000).collect::<Vec<_>>());
        let params = GbdtParams::paper(6, 4);
        let m = train_ccp(&data, params, 0.01);
        for t in m.trees.iter().flatten() {
            // Every node reachable, children indices in bounds.
            for n in &t.nodes {
                if let Node::Internal { left, right, .. } = n {
                    assert!(*left < t.nodes.len() && *right < t.nodes.len());
                }
            }
            let _ = t.depth();
            assert_eq!(t.n_leaves() + t.n_internal(), t.n_nodes());
        }
    }
}
