//! Cost-efficient gradient boosting (Peter et al., NeurIPS 2017).
//!
//! CEGB penalizes the *acquisition cost* of features and the evaluation
//! cost of deep trees: the gain of a candidate split is charged a
//! feature cost the first time a feature is used anywhere in the
//! ensemble ("coupled" costs, as in LightGBM's
//! `cegb_penalty_feature_coupled`) plus a constant per-split cost
//! (`cegb_penalty_split`). ToaD extends this idea with threshold costs
//! and an encoding-aware layout; CEGB is therefore the closest training
//! baseline (paper §4.2).

use crate::data::Dataset;
use crate::gbdt::booster::{Booster, GbdtParams};
use crate::gbdt::splitter::SplitPenalty;
use crate::gbdt::GbdtModel;

/// CEGB gain penalty: coupled feature costs + per-split cost.
#[derive(Clone, Debug)]
pub struct CegbPenalty {
    /// Cost charged the first time feature `f` is used by the ensemble.
    pub feature_cost: Vec<f64>,
    /// Constant cost per split (tree-evaluation cost).
    pub split_cost: f64,
    used: Vec<bool>,
    version: u64,
}

impl CegbPenalty {
    /// Uniform feature cost (the setting used in the paper's comparison,
    /// where no per-feature acquisition prices exist).
    pub fn uniform(n_features: usize, feature_cost: f64, split_cost: f64) -> CegbPenalty {
        CegbPenalty {
            feature_cost: vec![feature_cost; n_features],
            split_cost,
            used: vec![false; n_features],
            version: 0,
        }
    }

    /// Per-feature acquisition costs.
    pub fn with_costs(feature_cost: Vec<f64>, split_cost: f64) -> CegbPenalty {
        let n = feature_cost.len();
        CegbPenalty { feature_cost, split_cost, used: vec![false; n], version: 0 }
    }

    pub fn n_features_used(&self) -> usize {
        self.used.iter().filter(|&&u| u).count()
    }
}

impl SplitPenalty for CegbPenalty {
    #[inline]
    fn penalty(&self, feature: usize, _bin: u16) -> f64 {
        let acq = if self.used[feature] { 0.0 } else { self.feature_cost[feature] };
        acq + self.split_cost
    }

    fn on_split(&mut self, feature: usize, _bin: u16) {
        if !self.used[feature] {
            self.used[feature] = true;
            self.version += 1;
        }
    }

    fn version(&self) -> u64 {
        self.version
    }
}

/// Train a CEGB model.
pub fn train_cegb(
    data: &Dataset,
    params: GbdtParams,
    feature_cost: f64,
    split_cost: f64,
) -> GbdtModel {
    let penalty = CegbPenalty::uniform(data.n_features(), feature_cost, split_cost);
    let mut b = Booster::new(data, params, penalty);
    b.run();
    b.into_model()
}

#[cfg(test)]
#[cfg(not(miri))] // trains models / generates datasets - too slow under the Miri interpreter
mod tests {
    use super::*;
    use crate::data::synth::PaperDataset;
    use crate::data::train_test_split;
    use crate::toad::ReuseStats;

    #[test]
    fn penalty_semantics() {
        let mut p = CegbPenalty::uniform(3, 2.0, 0.25);
        assert_eq!(p.penalty(0, 5), 2.25);
        p.on_split(0, 5);
        assert_eq!(p.penalty(0, 9), 0.25, "used feature costs only the split");
        assert_eq!(p.penalty(1, 0), 2.25);
        assert_eq!(p.n_features_used(), 1);
    }

    #[test]
    fn version_on_new_feature_only() {
        let mut p = CegbPenalty::uniform(3, 1.0, 0.0);
        let v0 = p.version();
        p.on_split(2, 1);
        assert!(p.version() > v0);
        let v1 = p.version();
        p.on_split(2, 7); // same feature, different threshold
        assert_eq!(p.version(), v1);
    }

    #[test]
    fn feature_cost_reduces_feature_count() {
        let data = PaperDataset::BreastCancer.generate(1);
        let (train_set, _) = train_test_split(&data, 0.2, 1);
        let params = GbdtParams::paper(24, 2);
        let free = train_cegb(&train_set, params, 0.0, 0.0);
        let costly = train_cegb(&train_set, params, 100.0, 0.0);
        let f_free = ReuseStats::from_model(&free).n_features_used;
        let f_costly = ReuseStats::from_model(&costly).n_features_used;
        assert!(f_costly <= f_free, "cegb features {f_costly} > {f_free}");
    }

    #[test]
    fn split_cost_prunes_trees() {
        let data = PaperDataset::Mushroom.generate(2);
        let data = data.select(&(0..2000).collect::<Vec<_>>());
        let params = GbdtParams::paper(8, 4);
        let free = train_cegb(&data, params, 0.0, 0.0);
        let costly = train_cegb(&data, params, 0.0, 5.0);
        let n_free: usize = free.trees.iter().flatten().map(|t| t.n_internal()).sum();
        let n_costly: usize = costly.trees.iter().flatten().map(|t| t.n_internal()).sum();
        assert!(n_costly <= n_free, "split cost should shrink trees: {n_costly} vs {n_free}");
    }
}
