//! Margin & diversity based ordering ensemble pruning (Guo et al.,
//! Neurocomputing 2018) over random forests — the pruned-RF baseline of
//! the paper's Appendix D (Figure 8).
//!
//! Guo et al. order ensemble members by a measure that rewards
//! classifiers that are correct on *low-margin* (hard) examples: a
//! classifier that fixes examples the ensemble barely gets right
//! contributes both margin and diversity. We implement the ordering with
//! the combined per-sample weight
//!
//! ```text
//! w(h) = Σ_i  1[h correct on x_i] · ( α·(1 − |margin_i|) + (1−α)·(1 − v_i) )
//! ```
//!
//! where `v_i` is the fraction of ensemble votes for the true class of
//! `x_i` and `margin_i = v_i − max_{c≠y_i} v_c`. Samples every tree gets
//! right contribute little (their margin is high), hard samples a lot —
//! the margin (α) and diversity (1−α) components of the original
//! measure. Trees are sorted by `w` descending and the best prefix of
//! the requested size is kept (ordering-based pruning).

use super::rf::RfModel;
use crate::data::Dataset;

/// Compute the Guo et al. ordering of trees on a pruning set.
/// Returns tree indices, best first.
pub fn order_trees(rf: &RfModel, prune_set: &Dataset, alpha: f64) -> Vec<usize> {
    let n = prune_set.n_rows();
    let c = rf.n_classes;
    // Per-sample vote distribution of the full ensemble, and per-tree
    // correctness.
    let mut votes = vec![vec![0f64; c]; n];
    let mut correct: Vec<Vec<bool>> = vec![vec![false; n]; rf.trees.len()];
    for i in 0..n {
        let x = prune_set.row(i);
        for (t, tree) in rf.trees.iter().enumerate() {
            let dist = tree.predict_dist(&x);
            let pred = dist
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(k, _)| k)
                .unwrap_or(0);
            votes[i][pred] += 1.0;
            correct[t][i] = pred == prune_set.labels[i];
        }
    }
    let total = rf.trees.len() as f64;
    // Per-sample hardness weights from ensemble margins.
    let weights: Vec<f64> = (0..n)
        .map(|i| {
            let y = prune_set.labels[i];
            let v_true = votes[i][y] / total;
            let v_other = votes[i]
                .iter()
                .enumerate()
                .filter(|&(k, _)| k != y)
                .map(|(_, &v)| v / total)
                .fold(0.0, f64::max);
            let margin = v_true - v_other;
            alpha * (1.0 - margin.abs()) + (1.0 - alpha) * (1.0 - v_true)
        })
        .collect();

    let mut scored: Vec<(usize, f64)> = correct
        .iter()
        .enumerate()
        .map(|(t, corr)| {
            let w: f64 =
                corr.iter().zip(&weights).filter(|(&c, _)| c).map(|(_, &w)| w).sum();
            (t, w)
        })
        .collect();
    scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    scored.into_iter().map(|(t, _)| t).collect()
}

/// Keep the best `k` trees under the Guo ordering.
pub fn prune(rf: &RfModel, prune_set: &Dataset, k: usize, alpha: f64) -> RfModel {
    let order = order_trees(rf, prune_set, alpha);
    rf.subensemble(&order[..k.min(order.len())])
}

#[cfg(test)]
#[cfg(not(miri))] // trains models / generates datasets - too slow under the Miri interpreter
mod tests {
    use super::*;
    use crate::baselines::rf::{train_rf, RfParams};
    use crate::data::synth::PaperDataset;
    use crate::data::train_test_split;

    fn setup() -> (RfModel, Dataset, Dataset) {
        let data = PaperDataset::BreastCancer.generate(1);
        let (train_set, test_set) = train_test_split(&data, 0.2, 1);
        let (fit_set, prune_set) = train_test_split(&train_set, 0.25, 2);
        let rf = train_rf(
            &fit_set,
            RfParams { n_trees: 40, max_depth: 6, ..Default::default() },
        );
        (rf, prune_set, test_set)
    }

    #[test]
    fn ordering_is_a_permutation() {
        let (rf, prune_set, _) = setup();
        let mut order = order_trees(&rf, &prune_set, 0.5);
        assert_eq!(order.len(), 40);
        order.sort_unstable();
        order.dedup();
        assert_eq!(order.len(), 40);
    }

    #[test]
    fn pruned_is_smaller_and_competitive() {
        let (rf, prune_set, test_set) = setup();
        let pruned = prune(&rf, &prune_set, 10, 0.5);
        assert_eq!(pruned.trees.len(), 10);
        assert!(pruned.n_nodes() < rf.n_nodes());
        let full = rf.score(&test_set);
        let sub = pruned.score(&test_set);
        assert!(
            sub >= full - 0.06,
            "pruned accuracy {sub} collapsed vs full {full}"
        );
    }

    #[test]
    fn ordered_prefix_beats_arbitrary_prefix_on_prune_set() {
        let (rf, prune_set, _) = setup();
        let k = 8;
        let ordered = prune(&rf, &prune_set, k, 0.5);
        let arbitrary = rf.subensemble(&(0..k).collect::<Vec<_>>());
        // On the pruning set itself, the ordered prefix should not be
        // (much) worse than the arbitrary one.
        let a = ordered.score(&prune_set);
        let b = arbitrary.score(&prune_set);
        assert!(a >= b - 0.02, "ordered {a} vs arbitrary {b}");
    }

    #[test]
    fn k_larger_than_ensemble_is_clamped() {
        let (rf, prune_set, _) = setup();
        let pruned = prune(&rf, &prune_set, 10_000, 0.5);
        assert_eq!(pruned.trees.len(), 40);
    }
}
