//! Every comparison method of the paper's evaluation (§4.2, Appendix D).
//!
//! * [`cegb`] — cost-efficient gradient boosting (Peter et al., 2017):
//!   feature-acquisition and per-split costs in the gain.
//! * [`ccp`] — minimal cost-complexity pruning (Breiman et al., 1984)
//!   applied to each boosted tree at training time.
//! * [`rf`] — random forests (Breiman, 2001) with gini split finding
//!   and class distributions in the leaves.
//! * [`guo`] — margin-&-diversity ordering-based ensemble pruning
//!   (Guo et al., 2018) over random forests.
//!
//! The plain and quantized LightGBM baselines need no extra code: they
//! are the [`crate::gbdt`] trainer scored under the
//! [`crate::layout::baseline`] size models.

pub mod ccp;
pub mod cegb;
pub mod guo;
pub mod rf;
