//! Random forests (Breiman, 2001) — the Appendix D baseline.
//!
//! Classification-only (the paper's Figure 8 comparison is restricted to
//! classification because the Guo et al. pruning method is). Trees are
//! grown depth-first on bootstrap samples with per-split feature
//! subsampling and gini split finding over binned features; leaves store
//! the class distribution ("RF stores the class information in the
//! nodes", paper Appendix D), and prediction averages leaf
//! distributions.

use crate::data::{BinMatrix, Binner, Dataset};
use crate::prng::Pcg64;

/// Random-forest hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct RfParams {
    pub n_trees: usize,
    pub max_depth: usize,
    pub min_samples_leaf: usize,
    /// Features sampled per split; 0 = `ceil(sqrt(d))`.
    pub n_feature_sample: usize,
    pub max_bins: usize,
    pub seed: u64,
}

impl Default for RfParams {
    fn default() -> Self {
        RfParams {
            n_trees: 100,
            max_depth: 12,
            min_samples_leaf: 2,
            n_feature_sample: 0,
            max_bins: 64,
            seed: 0,
        }
    }
}

/// One node of a random-forest tree.
#[derive(Clone, Debug)]
pub enum RfNode {
    Internal { feature: usize, threshold: f32, left: usize, right: usize },
    Leaf { dist: Vec<f32> },
}

/// A single forest tree (root at index 0).
#[derive(Clone, Debug)]
pub struct RfTree {
    pub nodes: Vec<RfNode>,
}

impl RfTree {
    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Leaf class distribution for a row.
    pub fn predict_dist(&self, x: &[f32]) -> &[f32] {
        let mut idx = 0usize;
        loop {
            match &self.nodes[idx] {
                RfNode::Leaf { dist } => return dist,
                RfNode::Internal { feature, threshold, left, right } => {
                    idx = if x[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }
}

/// A trained random forest.
#[derive(Clone, Debug)]
pub struct RfModel {
    pub trees: Vec<RfTree>,
    pub n_classes: usize,
    pub n_features: usize,
}

impl RfModel {
    /// Soft-vote class prediction.
    pub fn predict_class(&self, x: &[f32]) -> usize {
        let mut acc = vec![0f32; self.n_classes];
        for t in &self.trees {
            for (c, &p) in t.predict_dist(x).iter().enumerate() {
                acc[c] += p;
            }
        }
        acc.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0
    }

    pub fn score(&self, data: &Dataset) -> f64 {
        let preds: Vec<usize> =
            (0..data.n_rows()).map(|i| self.predict_class(&data.row(i))).collect();
        crate::metrics::accuracy(&data.labels, &preds)
    }

    pub fn n_nodes(&self) -> usize {
        self.trees.iter().map(|t| t.n_nodes()).sum()
    }

    /// Pointer-layout size (128 bits per node, as for the boosted
    /// baselines; leaves store the class id in the same node budget).
    pub fn pointer_f32_bytes(&self) -> usize {
        self.n_nodes() * 16
    }

    /// Keep only the given trees (used by ensemble pruning).
    pub fn subensemble(&self, idx: &[usize]) -> RfModel {
        RfModel {
            trees: idx.iter().map(|&i| self.trees[i].clone()).collect(),
            n_classes: self.n_classes,
            n_features: self.n_features,
        }
    }
}

/// Train a random forest on a classification dataset.
pub fn train_rf(data: &Dataset, params: RfParams) -> RfModel {
    assert!(data.task.is_classification(), "RF baseline is classification-only");
    let n_classes = data.task.n_classes();
    let binner = Binner::fit(data, params.max_bins);
    let binned = binner.bin_matrix(data);
    let n = data.n_rows();
    let d = data.n_features();
    let n_feat = if params.n_feature_sample == 0 {
        (d as f64).sqrt().ceil() as usize
    } else {
        params.n_feature_sample.min(d)
    };
    let mut rng = Pcg64::new(params.seed ^ 0xF0FE57);

    let trees = (0..params.n_trees)
        .map(|_| {
            // Bootstrap sample.
            let rows: Vec<u32> = (0..n).map(|_| rng.gen_range(n) as u32).collect();
            let mut nodes = Vec::new();
            grow(
                &binned,
                &binner,
                &data.labels,
                rows,
                n_classes,
                n_feat,
                0,
                &params,
                &mut rng,
                &mut nodes,
            );
            RfTree { nodes }
        })
        .collect();
    RfModel { trees, n_classes, n_features: d }
}

/// Gini impurity of a class-count vector.
fn gini(counts: &[u32], total: u32) -> f64 {
    if total == 0 {
        return 0.0;
    }
    let t = total as f64;
    1.0 - counts.iter().map(|&c| (c as f64 / t) * (c as f64 / t)).sum::<f64>()
}

#[allow(clippy::too_many_arguments)]
fn grow(
    binned: &BinMatrix,
    binner: &Binner,
    labels: &[usize],
    rows: Vec<u32>,
    n_classes: usize,
    n_feat: usize,
    depth: usize,
    params: &RfParams,
    rng: &mut Pcg64,
    nodes: &mut Vec<RfNode>,
) -> usize {
    let idx = nodes.len();
    let mut counts = vec![0u32; n_classes];
    for &i in &rows {
        counts[labels[i as usize]] += 1;
    }
    let total = rows.len() as u32;
    let make_leaf = |counts: &[u32], nodes: &mut Vec<RfNode>| {
        let t = counts.iter().sum::<u32>().max(1) as f32;
        nodes.push(RfNode::Leaf { dist: counts.iter().map(|&c| c as f32 / t).collect() });
    };
    let pure = counts.iter().filter(|&&c| c > 0).count() <= 1;
    if depth >= params.max_depth || pure || rows.len() < 2 * params.min_samples_leaf {
        make_leaf(&counts, nodes);
        return idx;
    }

    // Best gini split over a random feature subset.
    let parent_gini = gini(&counts, total);
    let feats = rng.sample_indices(binned.n_features(), n_feat);
    let mut best: Option<(usize, u16, f64)> = None; // (feature, boundary, gain)
    for &f in &feats {
        let n_bins = binner.n_bins(f);
        if n_bins < 2 {
            continue;
        }
        // Class counts per bin.
        let mut hist = vec![0u32; n_bins * n_classes];
        for &i in &rows {
            let b = binned.bin(f, i as usize) as usize;
            hist[b * n_classes + labels[i as usize]] += 1;
        }
        let mut left = vec![0u32; n_classes];
        let mut left_total = 0u32;
        for b in 0..(n_bins - 1) {
            for c in 0..n_classes {
                left[c] += hist[b * n_classes + c];
            }
            left_total = left.iter().sum();
            let right_total = total - left_total;
            if (left_total as usize) < params.min_samples_leaf
                || (right_total as usize) < params.min_samples_leaf
            {
                continue;
            }
            let right: Vec<u32> = (0..n_classes).map(|c| counts[c] - left[c]).collect();
            let w_l = left_total as f64 / total as f64;
            let w_r = right_total as f64 / total as f64;
            let gain = parent_gini - w_l * gini(&left, left_total) - w_r * gini(&right, right_total);
            if gain > 1e-12 && best.map_or(true, |(_, _, g)| gain > g) {
                best = Some((f, b as u16, gain));
            }
        }
        let _ = left_total;
    }

    let Some((f, b, _)) = best else {
        make_leaf(&counts, nodes);
        return idx;
    };
    nodes.push(RfNode::Leaf { dist: vec![] }); // placeholder
    let threshold = binner.threshold_value(f, b as usize);
    let (mut lrows, mut rrows) = (Vec::new(), Vec::new());
    for &i in &rows {
        if binned.bin(f, i as usize) <= b {
            lrows.push(i);
        } else {
            rrows.push(i);
        }
    }
    let left =
        grow(binned, binner, labels, lrows, n_classes, n_feat, depth + 1, params, rng, nodes);
    let right =
        grow(binned, binner, labels, rrows, n_classes, n_feat, depth + 1, params, rng, nodes);
    nodes[idx] = RfNode::Internal { feature: f, threshold, left, right };
    idx
}

#[cfg(test)]
#[cfg(not(miri))] // trains models / generates datasets - too slow under the Miri interpreter
mod tests {
    use super::*;
    use crate::data::synth::PaperDataset;
    use crate::data::train_test_split;

    #[test]
    fn learns_breast_cancer() {
        let data = PaperDataset::BreastCancer.generate(1);
        let (train_set, test_set) = train_test_split(&data, 0.2, 1);
        let rf = train_rf(
            &train_set,
            RfParams { n_trees: 30, max_depth: 8, ..Default::default() },
        );
        let acc = rf.score(&test_set);
        assert!(acc > 0.9, "rf accuracy {acc}");
        assert_eq!(rf.n_classes, 2);
    }

    #[test]
    fn multiclass_votes() {
        let data = PaperDataset::WineQuality.generate(2).select(&(0..2000).collect::<Vec<_>>());
        let (train_set, test_set) = train_test_split(&data, 0.2, 2);
        let rf = train_rf(
            &train_set,
            RfParams { n_trees: 20, max_depth: 10, ..Default::default() },
        );
        let mut counts = vec![0usize; 7];
        for &l in &train_set.labels {
            counts[l] += 1;
        }
        let maj = counts.iter().enumerate().max_by_key(|(_, &c)| c).unwrap().0;
        let maj_acc = test_set.labels.iter().filter(|&&l| l == maj).count() as f64
            / test_set.n_rows() as f64;
        assert!(rf.score(&test_set) > maj_acc, "rf should beat majority vote");
    }

    #[test]
    fn respects_depth() {
        let data = PaperDataset::KrVsKp.generate(3).select(&(0..800).collect::<Vec<_>>());
        let rf = train_rf(&data, RfParams { n_trees: 5, max_depth: 3, ..Default::default() });
        for t in &rf.trees {
            // depth <= 3 means at most 2^4 - 1 nodes
            assert!(t.n_nodes() <= 15);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let data = PaperDataset::BreastCancer.generate(4).select(&(0..300).collect::<Vec<_>>());
        let a = train_rf(&data, RfParams { n_trees: 5, seed: 9, ..Default::default() });
        let b = train_rf(&data, RfParams { n_trees: 5, seed: 9, ..Default::default() });
        assert_eq!(a.n_nodes(), b.n_nodes());
        for i in 0..data.n_rows().min(50) {
            assert_eq!(a.predict_class(&data.row(i)), b.predict_class(&data.row(i)));
        }
    }

    #[test]
    fn subensemble_selects() {
        let data = PaperDataset::BreastCancer.generate(5).select(&(0..300).collect::<Vec<_>>());
        let rf = train_rf(&data, RfParams { n_trees: 10, ..Default::default() });
        let sub = rf.subensemble(&[0, 3, 7]);
        assert_eq!(sub.trees.len(), 3);
        assert!(sub.n_nodes() < rf.n_nodes());
    }

    #[test]
    fn pointer_size_accounting() {
        let data = PaperDataset::BreastCancer.generate(6).select(&(0..300).collect::<Vec<_>>());
        let rf = train_rf(&data, RfParams { n_trees: 3, ..Default::default() });
        assert_eq!(rf.pointer_f32_bytes(), rf.n_nodes() * 16);
    }
}
