//! Bit-granular serialization substrate for the ToaD memory layout.
//!
//! The paper's layout (§3.2) packs every field — node references,
//! threshold indices, per-feature bit-width descriptors, leaf-value
//! references — at its minimal bit width instead of rounding up to a host
//! data type. [`BitWriter`] and [`BitReader`] provide that substrate:
//! LSB-first packing of `width ≤ 64`-bit unsigned fields into a byte
//! buffer, plus helpers for IEEE-754 payloads and minimal-width
//! computation.

/// Number of bits needed to distinguish `n` values (`ceil(log2(n))`),
/// with the convention that 0 or 1 values need 0 bits.
#[inline]
pub fn bits_for(n: usize) -> u32 {
    if n <= 1 {
        0
    } else {
        usize::BITS - (n - 1).leading_zeros()
    }
}

#[inline]
fn mask64(width: u32) -> u64 {
    if width >= 64 {
        u64::MAX
    } else {
        (1u64 << width) - 1
    }
}

/// Bit-granular writer. Bits are packed LSB-first within each byte, so a
/// sequence of writes is independent of field alignment.
#[derive(Default, Debug, Clone)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Number of valid bits in the final byte (0 means byte-aligned).
    bit_pos: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Total number of bits written so far.
    pub fn len_bits(&self) -> usize {
        if self.bit_pos == 0 {
            self.buf.len() * 8
        } else {
            (self.buf.len() - 1) * 8 + self.bit_pos as usize
        }
    }

    /// Write the low `width` bits of `value` (LSB first). `width` may be 0
    /// (no-op), at most 64.
    ///
    /// Bits of `value` at or above `width` are **masked off
    /// deterministically**: the stored field is `value mod 2^width` in
    /// every build profile, so debug and release builds produce the
    /// same bytes. Passing an oversized value is almost certainly a
    /// caller bug — range-validate at the encoding layer (as
    /// [`crate::layout::toad_format::encode`] does for every fixed
    /// header field) rather than relying on the truncation.
    pub fn write(&mut self, value: u64, width: u32) {
        debug_assert!(width <= 64);
        let mut remaining = width;
        let mut v = value & mask64(width);
        while remaining > 0 {
            if self.bit_pos == 0 {
                self.buf.push(0);
            }
            let free = 8 - self.bit_pos;
            let take = free.min(remaining);
            let last = self.buf.last_mut().unwrap();
            *last |= ((v & ((1u64 << take) - 1)) as u8) << self.bit_pos;
            v >>= take;
            self.bit_pos = (self.bit_pos + take) % 8;
            remaining -= take;
        }
    }

    /// Write an `f32` as its 32 raw bits.
    pub fn write_f32(&mut self, value: f32) {
        self.write(value.to_bits() as u64, 32);
    }

    /// Write an IEEE-754 half-precision value (round-to-nearest-even
    /// conversion from `f32`), 16 bits.
    pub fn write_f16(&mut self, value: f32) {
        self.write(f32_to_f16_bits(value) as u64, 16);
    }

    /// Pad with zero bits to the next byte boundary.
    pub fn align_byte(&mut self) {
        self.bit_pos = 0;
    }

    /// Finish and return the packed bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.buf
    }
}

/// Bit-granular reader over a byte slice; mirror of [`BitWriter`].
#[derive(Debug, Clone)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    /// Absolute bit cursor.
    pos: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Current bit position.
    pub fn bit_pos(&self) -> usize {
        self.pos
    }

    /// Remaining bits in the buffer.
    pub fn remaining_bits(&self) -> usize {
        self.buf.len() * 8 - self.pos
    }

    /// Jump to an absolute bit offset.
    pub fn seek(&mut self, bit: usize) {
        debug_assert!(bit <= self.buf.len() * 8);
        self.pos = bit;
    }

    /// Read the next `width` bits as an unsigned value (LSB first).
    ///
    /// Fast path: one unaligned little-endian 64-bit window load + a
    /// shift/mask serves any field with `bit-in-byte + width <= 57`
    /// when 8 bytes are available; the byte loop only handles buffer
    /// tails and >57-bit fields. (§Perf iteration 1: the byte loop cost
    /// ~4× on the packed-model interpreter hot path.)
    #[inline]
    pub fn read(&mut self, width: u32) -> u64 {
        debug_assert!(width <= 64);
        debug_assert!(
            self.pos + width as usize <= self.buf.len() * 8,
            "bit read past end: pos={} width={} len={}",
            self.pos,
            width,
            self.buf.len() * 8
        );
        if width == 0 {
            return 0;
        }
        let byte_pos = self.pos / 8;
        let bit_in_byte = (self.pos % 8) as u32;
        if bit_in_byte + width <= 57 && byte_pos + 8 <= self.buf.len() {
            let window = u64::from_le_bytes(
                self.buf[byte_pos..byte_pos + 8].try_into().unwrap(),
            );
            let out = (window >> bit_in_byte) & mask64(width);
            self.pos += width as usize;
            return out;
        }
        self.read_slow(width)
    }

    #[cold]
    fn read_slow(&mut self, width: u32) -> u64 {
        let mut out: u64 = 0;
        let mut got: u32 = 0;
        while got < width {
            let byte = self.buf[self.pos / 8];
            let bit_in_byte = (self.pos % 8) as u32;
            let avail = 8 - bit_in_byte;
            let take = avail.min(width - got);
            let chunk = ((byte >> bit_in_byte) as u64) & ((1u64 << take) - 1);
            out |= chunk << got;
            got += take;
            self.pos += take as usize;
        }
        out
    }

    /// Read 32 bits as an `f32`.
    pub fn read_f32(&mut self) -> f32 {
        f32::from_bits(self.read(32) as u32)
    }

    /// Read 16 bits as an IEEE-754 half, widened to `f32`.
    pub fn read_f16(&mut self) -> f32 {
        f16_bits_to_f32(self.read(16) as u16)
    }

    /// Skip to the next byte boundary.
    pub fn align_byte(&mut self) {
        self.pos = (self.pos + 7) / 8 * 8;
    }
}

/// Round-to-nearest-even conversion of `f32` to IEEE-754 binary16 bits.
/// Handles subnormals, infinities, and NaN.
pub fn f32_to_f16_bits(value: f32) -> u16 {
    let bits = value.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf / NaN: preserve NaN-ness.
        return sign | 0x7C00 | if mant != 0 { 0x0200 } else { 0 };
    }
    // Unbiased exponent, rebiased for half (bias 15).
    let e = exp - 127 + 15;
    if e >= 0x1F {
        return sign | 0x7C00; // overflow -> inf
    }
    if e <= 0 {
        // Subnormal half or underflow to zero.
        if e < -10 {
            return sign;
        }
        let m = mant | 0x0080_0000; // implicit leading 1
        let shift = (14 - e) as u32;
        let half = 1u32 << (shift - 1);
        let rounded = (m + half - 1 + ((m >> shift) & 1)) >> shift;
        return sign | rounded as u16;
    }
    // Normal: round mantissa from 23 to 10 bits, nearest-even.
    let round_bit = 1u32 << 12;
    let mut m = mant;
    let mut e16 = e as u32;
    if (m & round_bit) != 0 && ((m & (round_bit - 1)) != 0 || (m & (round_bit << 1)) != 0) {
        m += round_bit << 1;
        if m & 0x0080_0000 != 0 {
            // mantissa overflowed into the exponent
            m = 0;
            e16 += 1;
            if e16 >= 0x1F {
                return sign | 0x7C00;
            }
        }
    }
    sign | ((e16 as u16) << 10) | ((m >> 13) as u16)
}

/// Widen IEEE-754 binary16 bits to `f32`.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // subnormal half -> normalized float
            let mut e = 0i32;
            let mut m = mant;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x03FF;
            sign | (((127 - 15 + e + 1) as u32) << 23) | (m << 13)
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg64;

    #[test]
    fn bits_for_edges() {
        assert_eq!(bits_for(0), 0);
        assert_eq!(bits_for(1), 0);
        assert_eq!(bits_for(2), 1);
        assert_eq!(bits_for(3), 2);
        assert_eq!(bits_for(4), 2);
        assert_eq!(bits_for(5), 3);
        assert_eq!(bits_for(256), 8);
        assert_eq!(bits_for(257), 9);
    }

    #[test]
    fn roundtrip_simple() {
        let mut w = BitWriter::new();
        w.write(0b101, 3);
        w.write(0b11, 2);
        w.write(0xABCD, 16);
        w.write(0, 0); // no-op
        w.write(1, 1);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(3), 0b101);
        assert_eq!(r.read(2), 0b11);
        assert_eq!(r.read(16), 0xABCD);
        assert_eq!(r.read(0), 0);
        assert_eq!(r.read(1), 1);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 200-case random sweep - slow under Miri;
                              // tests/miri_surface.rs keeps fixed-vector coverage.
    fn roundtrip_randomized() {
        // Property: any sequence of (value, width) writes reads back
        // identically — the core invariant the ToaD layout depends on.
        let mut rng = Pcg64::new(0xB17);
        for _ in 0..200 {
            let n = 1 + rng.gen_range(64);
            let fields: Vec<(u64, u32)> = (0..n)
                .map(|_| {
                    let w = 1 + rng.gen_range(64) as u32;
                    let v = if w == 64 { rng.next_u64() } else { rng.next_u64() & ((1u64 << w) - 1) };
                    (v, w)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(v, width) in &fields {
                w.write(v, width);
            }
            let total = w.len_bits();
            assert_eq!(total, fields.iter().map(|&(_, w)| w as usize).sum::<usize>());
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            for &(v, width) in &fields {
                assert_eq!(r.read(width), v);
            }
        }
    }

    #[test]
    fn oversized_values_mask_deterministically() {
        // An out-of-width value must store `value mod 2^width` — the
        // same bytes in debug and release — never silently corrupt
        // neighbouring fields.
        let mut w = BitWriter::new();
        w.write(0b101, 3);
        w.write(0xFFFF, 4); // oversized: only the low 4 bits land
        w.write(0xAB, 8);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(3), 0b101);
        assert_eq!(r.read(4), 0xF);
        assert_eq!(r.read(8), 0xAB, "oversized write must not spill into later fields");
        assert_eq!(w_len_bits(3 + 4 + 8), bytes.len());

        fn w_len_bits(bits: usize) -> usize {
            (bits + 7) / 8
        }
    }

    #[test]
    fn f32_roundtrip() {
        let mut w = BitWriter::new();
        w.write(1, 1); // misalign on purpose
        w.write_f32(-1234.5678);
        w.write_f32(f32::MIN_POSITIVE);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(1), 1);
        assert_eq!(r.read_f32(), -1234.5678f32);
        assert_eq!(r.read_f32(), f32::MIN_POSITIVE);
    }

    #[test]
    fn f16_known_values() {
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(1.0), 0x3C00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xC000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7BFF); // max half
        assert_eq!(f32_to_f16_bits(1e9), 0x7C00); // overflow -> inf
        assert_eq!(f16_bits_to_f32(0x3C00), 1.0);
        assert_eq!(f16_bits_to_f32(0x7C00), f32::INFINITY);
        assert!(f16_bits_to_f32(0x7E00).is_nan());
    }

    #[test]
    fn f16_roundtrip_exactness_on_representables() {
        // Values exactly representable in binary16 must round-trip bit-exactly.
        for v in [0.5f32, 0.25, 1.5, 3.0, 100.0, -0.125, 2048.0] {
            assert_eq!(f16_bits_to_f32(f32_to_f16_bits(v)), v);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 10k-sample numeric sweep - slow under Miri.
    fn f16_relative_error_bound() {
        let mut rng = Pcg64::new(0xF16);
        for _ in 0..10_000 {
            let v = (rng.gen_f32() - 0.5) * 1000.0;
            let r = f16_bits_to_f32(f32_to_f16_bits(v));
            let rel = ((r - v) / v.abs().max(1e-6)).abs();
            assert!(rel < 1e-3 || (r - v).abs() < 1e-3, "v={v} r={r}");
        }
    }

    #[test]
    fn f16_subnormals() {
        let tiny = 3.0e-5f32; // subnormal in half precision
        let r = f16_bits_to_f32(f32_to_f16_bits(tiny));
        assert!((r - tiny).abs() / tiny < 0.05, "tiny={tiny} r={r}");
    }

    #[test]
    fn align_byte() {
        let mut w = BitWriter::new();
        w.write(0b1, 1);
        w.align_byte();
        w.write(0xFF, 8);
        let bytes = w.into_bytes();
        assert_eq!(bytes.len(), 2);
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(1), 1);
        r.align_byte();
        assert_eq!(r.read(8), 0xFF);
    }

    #[test]
    fn seek() {
        let mut w = BitWriter::new();
        for i in 0..16u64 {
            w.write(i, 4);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        r.seek(4 * 7);
        assert_eq!(r.read(4), 7);
        r.seek(0);
        assert_eq!(r.read(4), 0);
    }
}
