//! Hand-rolled CLI argument parsing (no `clap` offline; DESIGN.md §6).

use std::collections::HashMap;

/// Parsed command line: a subcommand plus `--key value` flags.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: String,
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse `argv[1..]`: first token is the subcommand, the rest are
    /// `--key value` pairs (or bare `--switch`, stored as "true").
    pub fn parse(argv: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        args.command = it.next().cloned().unwrap_or_default();
        while let Some(tok) = it.next() {
            let Some(key) = tok.strip_prefix("--") else {
                return Err(format!("unexpected positional argument `{tok}`"));
            };
            let value = match it.peek() {
                Some(v) if !v.starts_with("--") => it.next().unwrap().clone(),
                _ => "true".to_string(),
            };
            args.flags.insert(key.to_string(), value);
        }
        Ok(args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: invalid integer `{v}`")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: invalid number `{v}`")),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

/// Resolve a dataset name to its generator.
pub fn dataset_by_name(name: &str) -> Option<crate::data::synth::PaperDataset> {
    use crate::data::synth::PaperDataset as P;
    Some(match name {
        "covtype" => P::Covertype,
        "covtype_binary" => P::CovertypeBinary,
        "california_housing" => P::CaliforniaHousing,
        "kin8nm" => P::Kin8nm,
        "mushroom" => P::Mushroom,
        "wine_quality" => P::WineQuality,
        "kr_vs_kp" => P::KrVsKp,
        "breastcancer" => P::BreastCancer,
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_command_and_flags() {
        let a = Args::parse(&argv("train --dataset breastcancer --rounds 32 --verbose")).unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.get("dataset"), Some("breastcancer"));
        assert_eq!(a.get_usize("rounds", 0).unwrap(), 32);
        assert!(a.get_bool("verbose"));
        assert_eq!(a.get_or("depth", "4"), "4");
    }

    #[test]
    fn rejects_positional() {
        assert!(Args::parse(&argv("train oops")).is_err());
    }

    #[test]
    fn invalid_numbers_error() {
        let a = Args::parse(&argv("t --rounds abc")).unwrap();
        assert!(a.get_usize("rounds", 1).is_err());
        assert!(a.get_f64("rounds", 1.0).is_err());
    }

    #[test]
    fn dataset_lookup() {
        assert!(dataset_by_name("breastcancer").is_some());
        assert!(dataset_by_name("kin8nm").is_some());
        assert!(dataset_by_name("unknown").is_none());
    }
}
