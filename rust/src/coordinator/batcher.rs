//! Dynamic batching over the XLA predict engine.
//!
//! PJRT artifacts are compiled at a fixed batch size, so the gateway
//! collects incoming rows until either the batch is full or a deadline
//! expires, then runs one padded execution and fans the results back
//! out. PJRT handles are not `Send`, so the engine lives entirely inside
//! the worker thread; requests and responses cross via channels.

use crate::runtime::tensorize::{eval_tensor_model, TensorModel};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Flush when this many requests are pending (must equal the
    /// artifact's compiled batch for the XLA backend).
    pub max_batch: usize,
    /// Flush a partial batch after this long.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 32, max_wait: Duration::from_millis(2) }
    }
}

/// One in-flight request.
struct Request {
    row: Vec<f32>,
    reply: Sender<Vec<f64>>,
}

/// Handle to a batching worker.
pub struct Batcher {
    tx: Option<Sender<Request>>,
    worker: Option<JoinHandle<()>>,
}

/// Which backend executes the batches.
pub enum Backend {
    /// XLA predict artifact from this directory (compiled in-thread).
    Xla { artifacts_dir: std::path::PathBuf, features: usize },
    /// Pure-Rust evaluation of the tensorized model (no artifacts
    /// needed; used in tests and as a fallback).
    Native,
}

impl Batcher {
    /// Spawn a batching worker for `tensors` with the given `backend`.
    pub fn spawn(tensors: TensorModel, config: BatcherConfig, backend: Backend) -> Batcher {
        let (tx, rx) = channel::<Request>();
        let worker = std::thread::spawn(move || worker_loop(tensors, config, backend, rx));
        Batcher { tx: Some(tx), worker: Some(worker) }
    }

    /// Submit a row; the returned receiver yields the raw scores.
    pub fn submit(&self, row: Vec<f32>) -> Receiver<Vec<f64>> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .as_ref()
            .expect("batcher running")
            .send(Request { row, reply: reply_tx })
            .expect("worker alive");
        reply_rx
    }

    /// Convenience: submit and wait.
    pub fn predict(&self, row: Vec<f32>) -> Vec<f64> {
        self.submit(row).recv().expect("worker reply")
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes the channel; worker drains + exits
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    tensors: TensorModel,
    config: BatcherConfig,
    backend: Backend,
    rx: Receiver<Request>,
) {
    // The XLA engine must be constructed inside the thread (not Send).
    enum Engine {
        Xla(crate::runtime::PredictEngine),
        Native(TensorModel),
    }
    let engine = match backend {
        Backend::Xla { artifacts_dir, features } => {
            let rt = crate::runtime::XlaRuntime::open(&artifacts_dir)
                .expect("open artifacts for batcher");
            Engine::Xla(
                crate::runtime::PredictEngine::new(&rt, tensors, config.max_batch, features)
                    .expect("compile predict engine"),
            )
        }
        Backend::Native => Engine::Native(tensors),
    };

    let mut engine = engine;
    let mut pending: Vec<Request> = Vec::with_capacity(config.max_batch);
    let mut deadline: Option<Instant> = None;
    loop {
        let timeout = match deadline {
            Some(d) => d.saturating_duration_since(Instant::now()),
            None => Duration::from_millis(50),
        };
        match rx.recv_timeout(timeout) {
            Ok(req) => {
                if pending.is_empty() {
                    deadline = Some(Instant::now() + config.max_wait);
                }
                pending.push(req);
                if pending.len() >= config.max_batch {
                    flush(&mut engine, &mut pending);
                    deadline = None;
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                if !pending.is_empty() && deadline.is_some_and(|d| Instant::now() >= d) {
                    flush(&mut engine, &mut pending);
                    deadline = None;
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                if !pending.is_empty() {
                    flush(&mut engine, &mut pending);
                }
                return;
            }
        }
    }

    fn flush(engine: &mut Engine, pending: &mut Vec<Request>) {
        let rows: Vec<Vec<f32>> = pending.iter().map(|r| r.row.clone()).collect();
        let outputs: Vec<Vec<f64>> = match engine {
            Engine::Xla(e) => e.predict(&rows).expect("xla predict"),
            Engine::Native(tm) => rows
                .iter()
                .map(|r| {
                    let mut x = r.clone();
                    // Native path needs explicit feature padding to the
                    // tensor model's expectation; features beyond the
                    // row length read as 0 (tree features are in range).
                    let max_f = tm
                        .feat
                        .iter()
                        .map(|&f| f as usize + 1)
                        .max()
                        .unwrap_or(0)
                        .max(x.len());
                    x.resize(max_f, 0.0);
                    eval_tensor_model(tm, &x)
                })
                .collect(),
        };
        for (req, out) in pending.drain(..).zip(outputs) {
            // A dropped receiver just means the client went away.
            let _ = req.reply.send(out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::PaperDataset;
    use crate::gbdt::{self, GbdtParams};
    use crate::runtime::tensorize;

    fn tensors() -> (TensorModel, crate::data::Dataset, crate::gbdt::GbdtModel) {
        let data = PaperDataset::BreastCancer.generate(71).select(&(0..300).collect::<Vec<_>>());
        let model = gbdt::booster::train(&data, GbdtParams::paper(8, 2));
        let tm = tensorize(&model, 32, 4, 64, 1).unwrap();
        (tm, data, model)
    }

    #[test]
    fn native_batcher_matches_model() {
        let (tm, data, model) = tensors();
        let b = Batcher::spawn(
            tm,
            BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
            Backend::Native,
        );
        for i in 0..20 {
            let row = data.row(i);
            let got = b.predict(row.clone());
            let want = model.predict_raw(&row)[0];
            assert!((got[0] - want).abs() < 1e-4, "row {i}: {} vs {want}", got[0]);
        }
    }

    #[test]
    fn partial_batches_flush_on_deadline() {
        let (tm, data, _) = tensors();
        let b = Batcher::spawn(
            tm,
            BatcherConfig { max_batch: 1000, max_wait: Duration::from_millis(5) },
            Backend::Native,
        );
        let start = Instant::now();
        let out = b.predict(data.row(0));
        assert_eq!(out.len(), 1);
        assert!(start.elapsed() < Duration::from_millis(500), "deadline flush too slow");
    }

    #[test]
    fn request_response_mapping_is_stable() {
        // Submit distinct rows concurrently; every reply must match its
        // own row's prediction (no cross-wiring in the batcher).
        let (tm, data, model) = tensors();
        let b = Batcher::spawn(
            tm,
            BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
            Backend::Native,
        );
        let rxs: Vec<_> = (0..16).map(|i| (i, b.submit(data.row(i)))).collect();
        for (i, rx) in rxs {
            let got = rx.recv().unwrap();
            let want = model.predict_raw(&data.row(i))[0];
            assert!((got[0] - want).abs() < 1e-4, "row {i} cross-wired");
        }
    }

    #[test]
    fn drop_drains_pending() {
        let (tm, data, _) = tensors();
        let rx;
        {
            let b = Batcher::spawn(
                tm,
                BatcherConfig { max_batch: 1000, max_wait: Duration::from_secs(10) },
                Backend::Native,
            );
            rx = b.submit(data.row(0));
            // b dropped here with the request still pending
        }
        let out = rx.recv().expect("pending request must be served on shutdown");
        assert_eq!(out.len(), 1);
    }
}
