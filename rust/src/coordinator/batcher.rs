//! Dynamic batching gateway over the batched inference engines.
//!
//! The gateway collects incoming rows until either the batch is full or
//! a deadline expires, then runs one batched execution and fans the
//! results back out. Three backends exist:
//!
//! * [`Backend::Native`] — the flattened SoA engine
//!   ([`crate::inference::FlatModel`]): the default, dependency-free
//!   batched serving path (tree-outer/row-inner blocked kernel).
//! * [`Backend::Quantized`] — the quantized-threshold flat engine
//!   ([`crate::inference::QuantizedFlatModel`]): the worker assembles
//!   the pending queue directly into a columnar block (one `Vec` per
//!   feature, short rows zero-padded as they are appended) and calls
//!   the zero-gather `predict_batch_columns` kernel — each feature
//!   column is binned once and descents run on `u16` compares with
//!   interleaved lanes; bit-identical outputs to `Native`, smaller
//!   per-node streams — the pick for memory-bound batch serving.
//! * `Backend::Xla` (`xla` feature) — the AOT-compiled PJRT artifact.
//!   Artifacts are compiled at a fixed batch size, and PJRT handles are
//!   not `Send`, so the engine lives entirely inside the worker thread;
//!   requests and responses cross via channels.

use crate::inference::{FlatModel, QuantizedFlatModel};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Flush when this many requests are pending (must equal the
    /// artifact's compiled batch for the XLA backend).
    pub max_batch: usize,
    /// Flush a partial batch after this long.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 32, max_wait: Duration::from_millis(2) }
    }
}

/// One in-flight request.
struct Request {
    row: Vec<f32>,
    reply: Sender<Vec<f64>>,
}

/// Handle to a batching worker.
pub struct Batcher {
    tx: Option<Sender<Request>>,
    worker: Option<JoinHandle<()>>,
}

/// Which engine executes the batches.
pub enum Backend {
    /// Blocked batched prediction on the flattened native engine.
    Native(FlatModel),
    /// Blocked batched prediction on the quantized-threshold engine
    /// (pre-binned rows, u16 compares, interleaved lanes).
    Quantized(QuantizedFlatModel),
    /// XLA predict artifact from this directory (compiled in-thread).
    #[cfg(feature = "xla")]
    Xla {
        artifacts_dir: std::path::PathBuf,
        features: usize,
        tensors: crate::runtime::TensorModel,
    },
}

impl Batcher {
    /// Spawn a batching worker for the given `backend`.
    pub fn spawn(config: BatcherConfig, backend: Backend) -> Batcher {
        let (tx, rx) = channel::<Request>();
        let worker = std::thread::spawn(move || worker_loop(config, backend, rx));
        Batcher { tx: Some(tx), worker: Some(worker) }
    }

    /// Submit a row; the returned receiver yields the raw scores.
    ///
    /// Ownership contract: `row` is moved into the gateway — the caller
    /// keeps nothing and the batcher never clones it. At flush time the
    /// `Native` backend takes each row out of its request to build the
    /// row batch, while the `Quantized` backend reads the rows straight
    /// into the columnar block (zero-padding short rows on the fly) and
    /// drops them when the queue drains. Rows longer than the model's
    /// feature count are truncated; both backends index only
    /// `0..n_features`.
    pub fn submit(&self, row: Vec<f32>) -> Receiver<Vec<f64>> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .as_ref()
            .expect("batcher running")
            .send(Request { row, reply: reply_tx })
            .expect("worker alive");
        reply_rx
    }

    /// Convenience: submit and wait.
    pub fn predict(&self, row: Vec<f32>) -> Vec<f64> {
        self.submit(row).recv().expect("worker reply")
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes the channel; worker drains + exits
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(config: BatcherConfig, backend: Backend, rx: Receiver<Request>) {
    // The XLA engine must be constructed inside the thread (not Send);
    // the native engine is just moved in.
    enum Engine {
        Native(FlatModel),
        Quantized(QuantizedFlatModel),
        #[cfg(feature = "xla")]
        Xla(crate::runtime::PredictEngine),
    }
    let mut engine = match backend {
        Backend::Native(flat) => Engine::Native(flat),
        Backend::Quantized(quant) => Engine::Quantized(quant),
        #[cfg(feature = "xla")]
        Backend::Xla { artifacts_dir, features, tensors } => {
            let rt = crate::runtime::XlaRuntime::open(&artifacts_dir)
                .expect("open artifacts for batcher");
            Engine::Xla(
                crate::runtime::PredictEngine::new(&rt, tensors, config.max_batch, features)
                    .expect("compile predict engine"),
            )
        }
    };

    let mut pending: Vec<Request> = Vec::with_capacity(config.max_batch);
    let mut deadline: Option<Instant> = None;
    loop {
        let timeout = match deadline {
            Some(d) => d.saturating_duration_since(Instant::now()),
            None => Duration::from_millis(50),
        };
        match rx.recv_timeout(timeout) {
            Ok(req) => {
                if pending.is_empty() {
                    deadline = Some(Instant::now() + config.max_wait);
                }
                pending.push(req);
                if pending.len() >= config.max_batch {
                    flush(&mut engine, &mut pending);
                    deadline = None;
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                if !pending.is_empty() && deadline.is_some_and(|d| Instant::now() >= d) {
                    flush(&mut engine, &mut pending);
                    deadline = None;
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                if !pending.is_empty() {
                    flush(&mut engine, &mut pending);
                }
                return;
            }
        }
    }

    /// Clients may send short rows; the native engines index up to
    /// `n_features`, so zero-pad at the gateway boundary (the XLA
    /// engine zero-pads internally).
    fn pad(mut rows: Vec<Vec<f32>>, nf: usize) -> Vec<Vec<f32>> {
        for r in &mut rows {
            if r.len() < nf {
                r.resize(nf, 0.0);
            }
        }
        rows
    }

    fn flush(engine: &mut Engine, pending: &mut Vec<Request>) {
        let outputs: Vec<Vec<f64>> = match engine {
            Engine::Native(flat) => {
                // Take the rows out instead of cloning — `pending` is
                // drained right after, and only the reply channel is
                // needed then.
                let rows: Vec<Vec<f32>> =
                    pending.iter_mut().map(|r| std::mem::take(&mut r.row)).collect();
                flat.predict_batch(&pad(rows, flat.n_features()))
            }
            Engine::Quantized(quant) => {
                // Assemble the pending queue directly into the columnar
                // block the engine's zero-gather kernel consumes: one
                // Vec per feature, short rows zero-padded on the fly —
                // no per-request row clone or zero-pad pass.
                let nf = quant.n_features();
                let n = pending.len();
                let mut cols: Vec<Vec<f32>> =
                    (0..nf).map(|_| Vec::with_capacity(n)).collect();
                for req in pending.iter() {
                    for (f, col) in cols.iter_mut().enumerate() {
                        col.push(req.row.get(f).copied().unwrap_or(0.0));
                    }
                }
                let col_refs: Vec<&[f32]> = cols.iter().map(|c| c.as_slice()).collect();
                quant.predict_batch_columns(&col_refs, n)
            }
            #[cfg(feature = "xla")]
            Engine::Xla(e) => {
                let rows: Vec<Vec<f32>> =
                    pending.iter_mut().map(|r| std::mem::take(&mut r.row)).collect();
                e.predict(&rows).expect("xla predict")
            }
        };
        for (req, out) in pending.drain(..).zip(outputs) {
            // A dropped receiver just means the client went away.
            let _ = req.reply.send(out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::PaperDataset;
    use crate::gbdt::{self, GbdtParams};

    fn fixtures() -> (FlatModel, crate::data::Dataset, crate::gbdt::GbdtModel) {
        let data = PaperDataset::BreastCancer.generate(71).select(&(0..300).collect::<Vec<_>>());
        let model = gbdt::booster::train(&data, GbdtParams::paper(8, 2));
        let flat = model.flatten();
        (flat, data, model)
    }

    #[test]
    fn native_batcher_matches_model() {
        let (flat, data, model) = fixtures();
        let b = Batcher::spawn(
            BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
            Backend::Native(flat),
        );
        for i in 0..20 {
            let row = data.row(i);
            let got = b.predict(row.clone());
            let want = model.predict_raw(&row)[0];
            assert_eq!(got[0], want, "row {i}: flat gateway must match the source model");
        }
    }

    #[test]
    fn quantized_batcher_matches_model_including_short_rows() {
        let (_, data, model) = fixtures();
        let b = Batcher::spawn(
            BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
            Backend::Quantized(model.quantize()),
        );
        for i in 0..20 {
            let row = data.row(i);
            let got = b.predict(row.clone());
            let want = model.predict_raw(&row)[0];
            assert_eq!(got[0], want, "row {i}: quantized gateway must match the source model");
        }
        // Short rows are zero-padded at the gateway, same as Native.
        let mut short = data.row(0);
        short.truncate(3);
        let mut padded = short.clone();
        padded.resize(data.n_features(), 0.0);
        assert_eq!(b.predict(short), model.predict_raw(&padded));
    }

    #[test]
    fn quantized_gateway_serves_partially_filled_final_block() {
        // 70 pending rows flush as one columnar batch: a full 64-row
        // descent block plus a 6-row final block (queue length not a
        // multiple of the engine's block size). Every reply must match
        // its own row.
        let (_, data, model) = fixtures();
        let b = Batcher::spawn(
            BatcherConfig { max_batch: 70, max_wait: Duration::from_secs(5) },
            Backend::Quantized(model.quantize()),
        );
        let rxs: Vec<_> = (0..70).map(|i| (i, b.submit(data.row(i)))).collect();
        for (i, rx) in rxs {
            let got = rx.recv().unwrap();
            assert_eq!(
                got,
                model.predict_raw(&data.row(i)),
                "row {i}: partial-final-block reply mismatch"
            );
        }
    }

    #[test]
    fn partial_batches_flush_on_deadline() {
        let (flat, data, _) = fixtures();
        let b = Batcher::spawn(
            BatcherConfig { max_batch: 1000, max_wait: Duration::from_millis(5) },
            Backend::Native(flat),
        );
        let start = Instant::now();
        let out = b.predict(data.row(0));
        assert_eq!(out.len(), 1);
        assert!(start.elapsed() < Duration::from_millis(500), "deadline flush too slow");
    }

    #[test]
    fn request_response_mapping_is_stable() {
        // Submit distinct rows concurrently; every reply must match its
        // own row's prediction (no cross-wiring in the batcher).
        let (flat, data, model) = fixtures();
        let b = Batcher::spawn(
            BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
            Backend::Native(flat),
        );
        let rxs: Vec<_> = (0..16).map(|i| (i, b.submit(data.row(i)))).collect();
        for (i, rx) in rxs {
            let got = rx.recv().unwrap();
            let want = model.predict_raw(&data.row(i))[0];
            assert_eq!(got[0], want, "row {i} cross-wired");
        }
    }

    #[test]
    fn drop_drains_pending() {
        let (flat, data, _) = fixtures();
        let rx;
        {
            let b = Batcher::spawn(
                BatcherConfig { max_batch: 1000, max_wait: Duration::from_secs(10) },
                Backend::Native(flat),
            );
            rx = b.submit(data.row(0));
            // b dropped here with the request still pending
        }
        let out = rx.recv().expect("pending request must be served on shutdown");
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn short_rows_are_zero_padded_not_fatal() {
        let (flat, data, model) = fixtures();
        let b = Batcher::spawn(
            BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
            Backend::Native(flat),
        );
        // A truncated (even empty) row must be served as if zero-padded,
        // and must not kill the worker for subsequent requests.
        let mut short = data.row(0);
        short.truncate(3);
        let mut padded = short.clone();
        padded.resize(data.n_features(), 0.0);
        assert_eq!(b.predict(short), model.predict_raw(&padded));
        assert_eq!(b.predict(Vec::new()).len(), 1);
        let row = data.row(1);
        assert_eq!(b.predict(row.clone()), model.predict_raw(&row));
    }

    #[test]
    fn multiclass_gateway_serves_all_outputs() {
        let data = PaperDataset::WineQuality.generate(72).select(&(0..400).collect::<Vec<_>>());
        let model = gbdt::booster::train(&data, GbdtParams::paper(3, 2));
        let b = Batcher::spawn(
            BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
            Backend::Native(model.flatten()),
        );
        let got = b.predict(data.row(0));
        assert_eq!(got.len(), 7);
        assert_eq!(got, model.predict_raw(&data.row(0)));
    }
}
