//! Dynamic batching gateway over the batched inference engines.
//!
//! The gateway holds incoming rows in a **bounded** queue until either
//! a full batch accumulates or a deadline expires, then runs one
//! batched execution and fans the results back out. Admission control
//! is explicit: when the queue is at [`BatcherConfig::queue_depth`],
//! [`Batcher::submit`] returns [`SubmitError::Overloaded`] immediately
//! instead of growing an unbounded channel — callers shed load at the
//! front door rather than buffering latency.
//!
//! `submit` takes `&self` and the handle is `Send + Sync`: any number
//! of serving threads push into one gateway concurrently.
//!
//! Four backends exist:
//!
//! * [`Backend::Native`] — the flattened SoA engine
//!   ([`crate::inference::FlatModel`]): the dependency-free batched
//!   serving path (tree-outer/row-inner blocked kernel).
//! * [`Backend::Quantized`] — the quantized-threshold flat engine
//!   ([`crate::inference::QuantizedFlatModel`]): the worker assembles
//!   the pending queue directly into a columnar block and calls the
//!   zero-gather `predict_batch_columns` kernel; bit-identical outputs
//!   to `Native` — the pick for memory-bound batch serving.
//! * [`Backend::Registry`] — hot-swappable serving: each flush resolves
//!   the *current* deployment for its key from a shared
//!   [`ModelRegistry`](super::registry::ModelRegistry) and runs the
//!   columnar kernel on it. A [`registry publish`](
//!   super::registry::ModelRegistry::publish) between flushes swaps the
//!   engine without pausing the worker; a batch in flight finishes on
//!   the `Arc` it cloned. Replies carry the serving version.
//! * `Backend::Xla` (`xla` feature) — the AOT-compiled PJRT artifact.
//!   Artifacts are compiled at a fixed batch size, and PJRT handles are
//!   not `Send`, so the engine lives entirely inside the worker thread.
//!
//! Each gateway carries an [`AdaptivePolicy`] ([`BatcherConfig::policy`]):
//! quantized flushes route through the margin-bounded early-exit kernel,
//! and every [`BatchReply`] reports how many trees its row actually
//! walked. Spawning several gateways over one registry key with
//! different tolerances serves one published model to multiple device
//! classes at different accuracy/latency points.
//!
//! The queue/close protocol — admission control (`enqueue`), the
//! worker's wait-and-drain step (`next_batch`), and the worker-exit
//! guard (`CloseOnExit`) — is factored into free functions over
//! [`Shared`] so the loom models (`loom_` tests, run with
//! `RUSTFLAGS="--cfg loom"`) can drive it exhaustively without real
//! engines or timing, via the [`crate::sync`] shim.

use super::registry::ModelRegistry;
use crate::inference::{AdaptiveBatch, AdaptivePolicy, FlatModel, QuantizedFlatModel};
use crate::sync::mpsc::{channel, Receiver, Sender};
use crate::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::collections::VecDeque;
use std::fmt;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Flush when this many requests are pending (must equal the
    /// artifact's compiled batch for the XLA backend).
    pub max_batch: usize,
    /// Flush a partial batch after this long.
    pub max_wait: Duration,
    /// Admission bound: requests queued but not yet flushed. A submit
    /// beyond this returns [`SubmitError::Overloaded`] immediately.
    pub queue_depth: usize,
    /// Adaptive early-exit policy applied by the quantized backends
    /// (`Quantized` and `Registry` flushes): the per-device-class exit
    /// tolerance of this gateway. One published model can be served to
    /// several device classes through gateways that differ only here.
    /// Non-quantized backends evaluate fully regardless. Default:
    /// [`AdaptivePolicy::Exact`].
    pub policy: AdaptivePolicy,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            queue_depth: 1024,
            policy: AdaptivePolicy::Exact,
        }
    }
}

/// Why a submit was refused at the front door.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded queue is full — shed load or retry after a flush.
    Overloaded {
        /// The configured [`BatcherConfig::queue_depth`].
        depth: usize,
    },
    /// The gateway is shutting down and accepts no new work.
    Shutdown,
    /// No deployment target is registered for this model key.
    NoRoute,
    /// The routed target exists but has no model deployed on it.
    NoModel,
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::Overloaded { depth } => {
                write!(f, "gateway overloaded: bounded queue of {depth} requests is full")
            }
            SubmitError::Shutdown => write!(f, "gateway is shutting down"),
            SubmitError::NoRoute => write!(f, "no deployment target for this model"),
            SubmitError::NoModel => write!(f, "routed target has no model deployed"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// A served prediction: raw scores plus the registry version that
/// produced them (0 for static, non-registry backends) and the number
/// of trees the serving engine actually walked for *this* row.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchReply {
    pub scores: Vec<f64>,
    pub version: u64,
    /// Trees evaluated for this request's row. Equals the model's tree
    /// count on non-adaptive backends or an unarmed policy; under
    /// [`AdaptivePolicy::Margin`] it is the row's actual early-exit
    /// depth. Only real rows ever produce a reply, so per-class
    /// mean-trees statistics aggregated from replies are never skewed
    /// by block padding.
    pub trees_evaluated: u32,
}

/// One in-flight request.
struct Request {
    row: Vec<f32>,
    reply: Sender<BatchReply>,
}

/// The bounded pending queue shared by submitters and the worker.
struct QueueState {
    pending: VecDeque<Request>,
    /// When the oldest pending request arrived (drives the deadline).
    first_at: Option<Instant>,
    closed: bool,
}

struct Shared {
    queue: Mutex<QueueState>,
    /// Signals the worker: new request, or shutdown.
    wake: Condvar,
}

impl Shared {
    fn new(capacity: usize) -> Arc<Shared> {
        Arc::new(Shared {
            queue: Mutex::new(QueueState {
                pending: VecDeque::with_capacity(capacity),
                first_at: None,
                closed: false,
            }),
            wake: Condvar::new(),
        })
    }

    fn lock(&self) -> MutexGuard<'_, QueueState> {
        self.queue.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Worker-exit guard. If the worker dies — normal shutdown or an
/// engine panic mid-flush — close the queue and drop any pending reply
/// senders, so blocked clients see a disconnect instead of hanging and
/// new submits are refused with [`SubmitError::Shutdown`].
///
/// Loom-verified: `loom_batcher_worker_exit_never_hangs_clients`
/// checks that after the guard runs, no admitted request's receiver
/// can block forever.
struct CloseOnExit(Arc<Shared>);

impl Drop for CloseOnExit {
    fn drop(&mut self) {
        let mut q = self.0.lock();
        q.closed = true;
        q.pending.clear();
    }
}

/// Admission control: the body of [`Batcher::submit`], factored over
/// [`Shared`] so the loom models can race it against close/drain
/// without a spawned worker. Refuses with `Shutdown` once closed and
/// with `Overloaded` at `queue_depth` pending requests; otherwise
/// pushes the request, stamps the deadline clock if the queue was
/// empty, and wakes the worker.
fn enqueue(
    shared: &Shared,
    queue_depth: usize,
    row: Vec<f32>,
) -> Result<Receiver<BatchReply>, SubmitError> {
    let (reply_tx, reply_rx) = channel();
    let mut q = shared.lock();
    if q.closed {
        return Err(SubmitError::Shutdown);
    }
    if q.pending.len() >= queue_depth {
        return Err(SubmitError::Overloaded { depth: queue_depth });
    }
    if q.pending.is_empty() {
        q.first_at = Some(Instant::now());
    }
    q.pending.push_back(Request { row, reply: reply_tx });
    drop(q);
    shared.wake.notify_one();
    Ok(reply_rx)
}

/// The worker's wait-and-drain step: block until a batch is due —
/// full (`flush_at`), past its deadline (`max_wait` since the oldest
/// pending request), or the gateway is closing — then drain up to
/// `max_batch` requests. Returns `None` exactly when the gateway is
/// closed *and* drained, i.e. when the worker should exit.
///
/// Every wait is inside a predicate-recheck loop and every state
/// change (enqueue, close) notifies the condvar, so no wakeup can be
/// lost; with `max_wait == Duration::ZERO` a non-empty queue is always
/// immediately due, which is how the loom models keep the clock out of
/// the explored state space.
fn next_batch(
    shared: &Shared,
    flush_at: usize,
    max_batch: usize,
    max_wait: Duration,
) -> Option<Vec<Request>> {
    let mut q = shared.lock();
    loop {
        if q.closed || q.pending.len() >= flush_at {
            break;
        }
        match q.first_at {
            Some(t0) => {
                let deadline = t0 + max_wait;
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                q = crate::sync::wait_timeout(&shared.wake, q, deadline - now);
            }
            None => {
                q = shared.wake.wait(q).unwrap_or_else(|e| e.into_inner());
            }
        }
    }
    if q.closed && q.pending.is_empty() {
        return None;
    }
    let take = q.pending.len().min(max_batch.max(1));
    let batch: Vec<Request> = q.pending.drain(..take).collect();
    // Requests left behind restart the deadline clock — they still
    // flush within `max_wait` of this drain.
    q.first_at = if q.pending.is_empty() { None } else { Some(Instant::now()) };
    Some(batch)
}

/// Handle to a batching worker. `Send + Sync`: clone-free concurrent
/// submission from any number of threads.
pub struct Batcher {
    shared: Arc<Shared>,
    config: BatcherConfig,
    worker: Option<JoinHandle<()>>,
}

/// Which engine executes the batches.
pub enum Backend {
    /// Blocked batched prediction on the flattened native engine.
    Native(FlatModel),
    /// Blocked batched prediction on the quantized-threshold engine
    /// (pre-binned columns, u16 compares, interleaved lanes).
    Quantized(QuantizedFlatModel),
    /// Hot-swappable: resolve `key` in the registry at every flush.
    Registry { registry: Arc<ModelRegistry>, key: String },
    /// XLA predict artifact from this directory (compiled in-thread).
    #[cfg(feature = "xla")]
    Xla {
        artifacts_dir: std::path::PathBuf,
        features: usize,
        tensors: crate::runtime::TensorModel,
    },
}

impl Batcher {
    /// Spawn a batching worker for the given `backend`.
    pub fn spawn(config: BatcherConfig, backend: Backend) -> Batcher {
        let shared = Shared::new(config.max_batch);
        let worker_shared = Arc::clone(&shared);
        let worker = std::thread::spawn(move || {
            let _guard = CloseOnExit(Arc::clone(&worker_shared));
            worker_loop(config, backend, worker_shared);
        });
        Batcher { shared, config, worker: Some(worker) }
    }

    /// Submit a row; the returned receiver yields the scores and the
    /// serving version. Refuses immediately with
    /// [`SubmitError::Overloaded`] when the bounded queue is full.
    ///
    /// Ownership contract: `row` is moved into the gateway — the caller
    /// keeps nothing and the batcher never clones it. Short rows are
    /// zero-padded at flush time; rows longer than the model's feature
    /// count are truncated (both backends index only `0..n_features`).
    pub fn submit(&self, row: Vec<f32>) -> Result<Receiver<BatchReply>, SubmitError> {
        enqueue(&self.shared, self.config.queue_depth, row)
    }

    /// Convenience: submit and wait for the scores.
    pub fn predict(&self, row: Vec<f32>) -> Result<Vec<f64>, SubmitError> {
        let rx = self.submit(row)?;
        // A dropped reply sender on a *live* gateway means the registry
        // had no deployment for the key (retired or never published) —
        // a publish recovers it, so report `NoModel`, not `Shutdown`.
        rx.recv().map(|r| r.scores).map_err(|_| {
            if self.shared.lock().closed {
                SubmitError::Shutdown
            } else {
                SubmitError::NoModel
            }
        })
    }

    /// Number of requests currently queued (for tests/monitoring).
    pub fn queued(&self) -> usize {
        self.shared.lock().pending.len()
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shared.lock().closed = true;
        self.shared.wake.notify_all(); // worker drains + exits
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(config: BatcherConfig, backend: Backend, shared: Arc<Shared>) {
    // The XLA engine must be constructed inside the thread (not Send);
    // the native engines are just moved in.
    enum Engine {
        Native(FlatModel),
        Quantized(QuantizedFlatModel),
        Registry { registry: Arc<ModelRegistry>, key: String },
        #[cfg(feature = "xla")]
        Xla(crate::runtime::PredictEngine),
    }
    let mut engine = match backend {
        Backend::Native(flat) => Engine::Native(flat),
        Backend::Quantized(quant) => Engine::Quantized(quant),
        Backend::Registry { registry, key } => Engine::Registry { registry, key },
        #[cfg(feature = "xla")]
        Backend::Xla { artifacts_dir, features, tensors } => {
            let rt = crate::runtime::XlaRuntime::open(&artifacts_dir)
                .expect("open artifacts for batcher");
            Engine::Xla(
                crate::runtime::PredictEngine::new(&rt, tensors, config.max_batch, features)
                    .expect("compile predict engine"),
            )
        }
    };

    // A batch is due at `max_batch` — or already when the bounded
    // queue is full: with `queue_depth < max_batch` the size trigger
    // could otherwise never fire, and a full queue would shed load for
    // a whole `max_wait` while the engine sat idle. (`.max(1)` guards
    // degenerate zero configs from busy-spinning on empty batches.)
    let flush_at = config.max_batch.min(config.queue_depth).max(1);
    loop {
        // Phase 1: wait until a batch is due — full, past its deadline,
        // or the gateway is closing (then drain what remains).
        let Some(mut batch) = next_batch(&shared, flush_at, config.max_batch, config.max_wait)
        else {
            return;
        };
        if !batch.is_empty() {
            flush(&mut engine, &mut batch, config.policy);
        }
    }

    /// Clients may send short rows; the native engines index up to
    /// `n_features`, so zero-pad at the gateway boundary (the XLA
    /// engine zero-pads internally).
    fn pad(mut rows: Vec<Vec<f32>>, nf: usize) -> Vec<Vec<f32>> {
        for r in &mut rows {
            if r.len() < nf {
                r.resize(nf, 0.0);
            }
        }
        rows
    }

    /// Assemble the pending queue directly into the columnar block the
    /// quantized engine's zero-gather kernel consumes: one Vec per
    /// feature, short rows zero-padded on the fly — no per-request row
    /// clone or zero-pad pass. The adaptive entry point reports a
    /// trees-evaluated count for exactly the `batch.len()` real rows —
    /// the engine's internal descent blocks may be ragged, but no
    /// padded row ever reaches the per-row statistics.
    fn flush_columnar(
        quant: &QuantizedFlatModel,
        batch: &[Request],
        policy: AdaptivePolicy,
    ) -> AdaptiveBatch {
        let nf = quant.n_features();
        let n = batch.len();
        let mut cols: Vec<Vec<f32>> = (0..nf).map(|_| Vec::with_capacity(n)).collect();
        for req in batch.iter() {
            for (f, col) in cols.iter_mut().enumerate() {
                col.push(req.row.get(f).copied().unwrap_or(0.0));
            }
        }
        let col_refs: Vec<&[f32]> = cols.iter().map(|c| c.as_slice()).collect();
        quant.predict_batch_columns_adaptive(&col_refs, n, policy)
    }

    fn flush(engine: &mut Engine, batch: &mut Vec<Request>, policy: AdaptivePolicy) {
        let mut version = 0u64;
        let outputs: AdaptiveBatch = match engine {
            Engine::Native(flat) => {
                // Take the rows out instead of cloning — `batch` is
                // drained right after, and only the reply channel is
                // needed then.
                let rows: Vec<Vec<f32>> =
                    batch.iter_mut().map(|r| std::mem::take(&mut r.row)).collect();
                let scores = flat.predict_batch(&pad(rows, flat.n_features()));
                AdaptiveBatch {
                    trees_evaluated: vec![flat.n_trees() as u32; scores.len()],
                    scores,
                }
            }
            Engine::Quantized(quant) => flush_columnar(quant, batch, policy),
            Engine::Registry { registry, key } => {
                // Resolve the live deployment once per flush: the whole
                // batch is served by one version, and a publish landing
                // mid-flush swaps the *next* batch, not this one.
                let Some(dep) = registry.current(key) else {
                    // No deployment: drop the reply senders, so every
                    // waiting client sees a disconnect ("model retired
                    // or never published") instead of hanging.
                    batch.clear();
                    return;
                };
                version = dep.version;
                flush_columnar(&dep.engine, batch, policy)
            }
            #[cfg(feature = "xla")]
            Engine::Xla(e) => {
                let rows: Vec<Vec<f32>> =
                    batch.iter_mut().map(|r| std::mem::take(&mut r.row)).collect();
                let scores = e.predict(&rows).expect("xla predict");
                // The dense tensor kernel always walks every tree.
                AdaptiveBatch {
                    trees_evaluated: vec![e.tensors().n_trees as u32; scores.len()],
                    scores,
                }
            }
        };
        let replies = batch.drain(..).zip(outputs.scores.into_iter().zip(outputs.trees_evaluated));
        for (req, (scores, trees_evaluated)) in replies {
            // A dropped receiver just means the client went away.
            let _ = req.reply.send(BatchReply { scores, version, trees_evaluated });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::planner::ModelCard;
    use crate::data::synth::PaperDataset;
    use crate::gbdt::{self, GbdtParams};

    fn fixtures() -> (FlatModel, crate::data::Dataset, crate::gbdt::GbdtModel) {
        let data = PaperDataset::BreastCancer.generate(71).select(&(0..300).collect::<Vec<_>>());
        let model = gbdt::booster::train(&data, GbdtParams::paper(8, 2));
        let flat = model.flatten();
        (flat, data, model)
    }

    #[test]
    #[cfg_attr(miri, ignore)] // trains a real model — minutes under Miri
    fn native_batcher_matches_model() {
        let (flat, data, model) = fixtures();
        let b = Batcher::spawn(
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                queue_depth: 64,
                ..Default::default()
            },
            Backend::Native(flat),
        );
        for i in 0..20 {
            let row = data.row(i);
            let got = b.predict(row.clone()).unwrap();
            let want = model.predict_raw(&row)[0];
            assert_eq!(got[0], want, "row {i}: flat gateway must match the source model");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // trains a real model — minutes under Miri
    fn quantized_batcher_matches_model_including_short_rows() {
        let (_, data, model) = fixtures();
        let b = Batcher::spawn(
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                queue_depth: 64,
                ..Default::default()
            },
            Backend::Quantized(model.quantize()),
        );
        for i in 0..20 {
            let row = data.row(i);
            let got = b.predict(row.clone()).unwrap();
            let want = model.predict_raw(&row)[0];
            assert_eq!(got[0], want, "row {i}: quantized gateway must match the source model");
        }
        // Short rows are zero-padded at the gateway, same as Native.
        let mut short = data.row(0);
        short.truncate(3);
        let mut padded = short.clone();
        padded.resize(data.n_features(), 0.0);
        assert_eq!(b.predict(short).unwrap(), model.predict_raw(&padded));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // trains a real model — minutes under Miri
    fn quantized_gateway_serves_partially_filled_final_block() {
        // 70 pending rows flush as one columnar batch: a full 64-row
        // descent block plus a 6-row final block (queue length not a
        // multiple of the engine's block size). Every reply must match
        // its own row.
        let (_, data, model) = fixtures();
        let b = Batcher::spawn(
            BatcherConfig {
                max_batch: 70,
                max_wait: Duration::from_secs(5),
                queue_depth: 128,
                ..Default::default()
            },
            Backend::Quantized(model.quantize()),
        );
        let rxs: Vec<_> = (0..70).map(|i| (i, b.submit(data.row(i)).unwrap())).collect();
        for (i, rx) in rxs {
            let got = rx.recv().unwrap();
            assert_eq!(
                got.scores,
                model.predict_raw(&data.row(i)),
                "row {i}: partial-final-block reply mismatch"
            );
            assert_eq!(got.version, 0, "static backend reports version 0");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // trains a real model — minutes under Miri
    fn partial_batches_flush_on_deadline() {
        let (flat, data, _) = fixtures();
        let b = Batcher::spawn(
            BatcherConfig {
                max_batch: 1000,
                max_wait: Duration::from_millis(5),
                queue_depth: 2000,
                ..Default::default()
            },
            Backend::Native(flat),
        );
        let start = Instant::now();
        let out = b.predict(data.row(0)).unwrap();
        assert_eq!(out.len(), 1);
        assert!(start.elapsed() < Duration::from_millis(500), "deadline flush too slow");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // trains a real model — minutes under Miri
    fn request_response_mapping_is_stable() {
        // Submit distinct rows concurrently; every reply must match its
        // own row's prediction (no cross-wiring in the batcher).
        let (flat, data, model) = fixtures();
        let b = Batcher::spawn(
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                queue_depth: 64,
                ..Default::default()
            },
            Backend::Native(flat),
        );
        let rxs: Vec<_> = (0..16).map(|i| (i, b.submit(data.row(i)).unwrap())).collect();
        for (i, rx) in rxs {
            let got = rx.recv().unwrap();
            let want = model.predict_raw(&data.row(i))[0];
            assert_eq!(got.scores[0], want, "row {i} cross-wired");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // trains a real model — minutes under Miri
    fn overloaded_queue_rejects_then_recovers() {
        // A tiny bound and a tight submit loop: the submitter enqueues
        // in nanoseconds while every flush runs a real batch, so the
        // queue refills during each flush and the bound must trip.
        // Everything that *was* admitted must still be served.
        let (flat, data, _) = fixtures();
        let b = Batcher::spawn(
            BatcherConfig {
                max_batch: 64,
                max_wait: Duration::from_secs(30),
                queue_depth: 2,
                ..Default::default()
            },
            Backend::Native(flat),
        );
        let mut rxs = Vec::new();
        let mut shed = 0usize;
        for i in 0..50_000 {
            match b.submit(data.row(i % 300)) {
                Ok(rx) => rxs.push(rx),
                Err(err) => {
                    assert_eq!(err, SubmitError::Overloaded { depth: 2 });
                    shed += 1;
                    if shed > 8 {
                        break; // backpressure observed repeatedly
                    }
                }
            }
        }
        assert!(shed > 0, "bounded queue never pushed back under a tight submit loop");
        assert!(b.queued() <= 2, "queue must never exceed its bound");
        // Shutdown drains the queue: every admitted request is served.
        drop(b);
        for rx in rxs {
            assert_eq!(rx.recv().expect("admitted request served").scores.len(), 1);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // trains a real model — minutes under Miri
    fn full_queue_flushes_without_waiting_for_deadline() {
        // queue_depth < max_batch: a *full* queue must flush
        // immediately instead of idling out the 30 s deadline while
        // shedding all further traffic. (A queue below the bound still
        // waits for the deadline — that is the batching contract.)
        let (flat, data, model) = fixtures();
        let b = Batcher::spawn(
            BatcherConfig {
                max_batch: 64,
                max_wait: Duration::from_secs(30),
                queue_depth: 4,
                ..Default::default()
            },
            Backend::Native(flat),
        );
        let rxs: Vec<_> = (0..4).map(|i| (i, b.submit(data.row(i)).unwrap())).collect();
        let start = Instant::now();
        for (i, rx) in rxs {
            let got = rx
                .recv_timeout(Duration::from_secs(5))
                .expect("full queue must flush long before the deadline");
            assert_eq!(got.scores[0], model.predict_raw(&data.row(i))[0], "row {i}");
        }
        assert!(start.elapsed() < Duration::from_secs(10), "flush waited for the deadline");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // trains a real model — minutes under Miri
    fn concurrent_submitters_share_one_gateway() {
        let (flat, data, model) = fixtures();
        let b = Batcher::spawn(
            BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_millis(1),
                queue_depth: 256,
                ..Default::default()
            },
            Backend::Native(flat),
        );
        std::thread::scope(|s| {
            for t in 0..4 {
                let b = &b;
                let data = &data;
                let model = &model;
                s.spawn(move || {
                    for i in 0..25 {
                        let row = data.row((t * 25 + i) % data.n_rows());
                        let want = model.predict_raw(&row)[0];
                        let got = b.predict(row).unwrap();
                        assert_eq!(got[0], want, "thread {t} req {i}");
                    }
                });
            }
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)] // trains a real model — minutes under Miri
    fn drop_drains_pending() {
        let (flat, data, _) = fixtures();
        let rx;
        {
            let b = Batcher::spawn(
                BatcherConfig {
                    max_batch: 1000,
                    max_wait: Duration::from_secs(10),
                    queue_depth: 2000,
                    ..Default::default()
                },
                Backend::Native(flat),
            );
            rx = b.submit(data.row(0)).unwrap();
            // b dropped here with the request still pending
        }
        let out = rx.recv().expect("pending request must be served on shutdown");
        assert_eq!(out.scores.len(), 1);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // trains a real model — minutes under Miri
    fn short_rows_are_zero_padded_not_fatal() {
        let (flat, data, model) = fixtures();
        let b = Batcher::spawn(
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                queue_depth: 64,
                ..Default::default()
            },
            Backend::Native(flat),
        );
        // A truncated (even empty) row must be served as if zero-padded,
        // and must not kill the worker for subsequent requests.
        let mut short = data.row(0);
        short.truncate(3);
        let mut padded = short.clone();
        padded.resize(data.n_features(), 0.0);
        assert_eq!(b.predict(short).unwrap(), model.predict_raw(&padded));
        assert_eq!(b.predict(Vec::new()).unwrap().len(), 1);
        let row = data.row(1);
        assert_eq!(b.predict(row.clone()).unwrap(), model.predict_raw(&row));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // trains a real model — minutes under Miri
    fn multiclass_gateway_serves_all_outputs() {
        let data = PaperDataset::WineQuality.generate(72).select(&(0..400).collect::<Vec<_>>());
        let model = gbdt::booster::train(&data, GbdtParams::paper(3, 2));
        let b = Batcher::spawn(
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                queue_depth: 64,
                ..Default::default()
            },
            Backend::Native(model.flatten()),
        );
        let got = b.predict(data.row(0)).unwrap();
        assert_eq!(got.len(), 7);
        assert_eq!(got, model.predict_raw(&data.row(0)));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // trains a real model — minutes under Miri
    fn exact_policy_replies_report_full_depth() {
        let (_, data, model) = fixtures();
        let quant = model.quantize();
        let n_trees = crate::inference::Predictor::n_trees(&quant) as u32;
        let b = Batcher::spawn(
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                queue_depth: 64,
                ..Default::default()
            },
            Backend::Quantized(quant),
        );
        let got = b.submit(data.row(0)).unwrap().recv().unwrap();
        assert_eq!(got.trees_evaluated, n_trees, "Exact gateway must walk every tree");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // trains a real model — minutes under Miri
    fn margin_gateway_early_exits_and_preserves_classes() {
        // A near-separable task served through a Margin gateway: across
        // a 70-row flush (full 64-row block + ragged 6-row tail) most
        // rows must exit before the last tree, every reply must keep
        // its row's predicted class, and `trees_evaluated` must count
        // only real rows (never the block padding).
        let data = PaperDataset::Mushroom.generate(73).select(&(0..300).collect::<Vec<_>>());
        let model = gbdt::booster::train(&data, GbdtParams::paper(8, 2));
        let quant = model.quantize();
        let n_trees = crate::inference::Predictor::n_trees(&quant) as u32;
        let b = Batcher::spawn(
            BatcherConfig {
                max_batch: 70,
                max_wait: Duration::from_secs(5),
                queue_depth: 128,
                policy: AdaptivePolicy::Margin(1e-6),
            },
            Backend::Quantized(quant),
        );
        let rxs: Vec<_> = (0..70).map(|i| (i, b.submit(data.row(i)).unwrap())).collect();
        let mut total_trees = 0u64;
        for (i, rx) in rxs {
            let got = rx.recv().unwrap();
            assert!(
                (1..=n_trees).contains(&got.trees_evaluated),
                "row {i}: trees_evaluated {} out of range 1..={n_trees}",
                got.trees_evaluated
            );
            let full = model.predict_raw(&data.row(i))[0];
            assert_eq!(
                got.scores[0] > 0.0,
                full > 0.0,
                "row {i}: early exit flipped the predicted class"
            );
            total_trees += u64::from(got.trees_evaluated);
        }
        assert!(
            total_trees < u64::from(n_trees) * 70,
            "separable task through a Margin gateway never exited early"
        );
    }

    #[test]
    #[cfg_attr(miri, ignore)] // trains a real model — minutes under Miri
    fn registry_backend_swaps_between_flushes() {
        let (_, data, model_a) = fixtures();
        let small = data.select(&(0..200).collect::<Vec<_>>());
        let model_b = gbdt::booster::train(&small, GbdtParams::paper(4, 2));
        let registry = Arc::new(ModelRegistry::new());
        let b = Batcher::spawn(
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                queue_depth: 64,
                ..Default::default()
            },
            Backend::Registry { registry: Arc::clone(&registry), key: "m".into() },
        );

        // Nothing published yet: the reply channel disconnects and the
        // live gateway reports the recoverable `NoModel`, not Shutdown.
        assert_eq!(b.predict(data.row(0)).unwrap_err(), SubmitError::NoModel);

        let card = |id: &str| ModelCard { id: id.into(), score: 0.9, size_bytes: 1, blob: vec![] };
        let d1 = registry.publish("m", card("a"), model_a.quantize());
        let r1 = b.submit(data.row(0)).unwrap().recv().unwrap();
        assert_eq!(r1.version, d1.version);
        assert_eq!(r1.scores, model_a.predict_raw(&data.row(0)));

        let d2 = registry.publish("m", card("b"), model_b.quantize());
        let r2 = b.submit(data.row(0)).unwrap().recv().unwrap();
        assert_eq!(r2.version, d2.version, "publish must swap the serving version");
        assert_eq!(r2.scores, model_b.predict_raw(&data.row(0)));

        registry.retire("m");
        assert_eq!(b.predict(data.row(0)).unwrap_err(), SubmitError::NoModel);
    }
}

// Exhaustive interleaving models for the queue/close protocol. Run
// with `RUSTFLAGS="--cfg loom" cargo test --release loom_`; under that
// cfg the `crate::sync` shim swaps the Mutex/Condvar for loom's
// instrumented twins and `loom::model` explores every schedule. The
// models drive `enqueue`/`next_batch`/`CloseOnExit` directly — no
// spawned std worker, no engine, `max_wait = ZERO` so the wall clock
// never enters the explored state space (a non-empty queue is always
// immediately "due").
#[cfg(all(loom, test))]
mod loom_tests {
    use super::*;
    use crate::sync::mpsc::TryRecvError;
    use loom::thread;

    fn reply() -> BatchReply {
        BatchReply { scores: vec![0.0], version: 0, trees_evaluated: 1 }
    }

    /// After the worker has exited, an admitted request's receiver must
    /// be resolved: either a reply was sent or its sender was dropped.
    /// `Err(Empty)` here is exactly "the client blocks forever".
    fn assert_resolved(rx: &Receiver<BatchReply>) {
        match rx.try_recv() {
            Ok(_) | Err(TryRecvError::Disconnected) => {}
            Err(TryRecvError::Empty) => {
                panic!("admitted request neither served nor disconnected: client would hang")
            }
        }
    }

    /// Normal shutdown: a client admits a request while another thread
    /// closes the gateway (the `Drop for Batcher` sequence). The
    /// worker must drain and exit, and the admitted request must be
    /// served — never abandoned in the queue.
    #[test]
    fn loom_batcher_close_drains_admitted_requests() {
        loom::model(|| {
            let shared = Shared::new(2);

            let worker_shared = Arc::clone(&shared);
            let worker = thread::spawn(move || {
                let _guard = CloseOnExit(Arc::clone(&worker_shared));
                while let Some(batch) = next_batch(&worker_shared, 1, 1, Duration::ZERO) {
                    for req in batch {
                        let _ = req.reply.send(reply());
                    }
                }
            });

            let client_shared = Arc::clone(&shared);
            let client = thread::spawn(move || {
                let rx = enqueue(&client_shared, 2, vec![1.0])
                    .expect("gateway is open and the queue is empty: must admit");
                // The `Drop for Batcher` close sequence.
                client_shared.lock().closed = true;
                client_shared.wake.notify_all();
                rx
            });

            let rx = client.join().unwrap();
            worker.join().unwrap();

            let q = shared.lock();
            assert!(q.closed, "guard must leave the queue closed");
            assert!(q.pending.is_empty(), "worker exited with requests still pending");
            drop(q);
            // The worker serves the request (Ok) unless the guard beat
            // it to the drain after close — then the sender was dropped
            // (Disconnected). Both resolve the client; Empty never can.
            assert_resolved(&rx);
        });
    }

    /// Worker death mid-flush (an engine panic): the worker takes a
    /// batch and dies without replying. `CloseOnExit` must close the
    /// queue and drop pending senders so the client is disconnected,
    /// and later submits must be refused with `Shutdown`.
    #[test]
    fn loom_batcher_worker_exit_never_hangs_clients() {
        loom::model(|| {
            let shared = Shared::new(2);

            let worker_shared = Arc::clone(&shared);
            let worker = thread::spawn(move || {
                let _guard = CloseOnExit(Arc::clone(&worker_shared));
                // Take (at most) one batch and exit without replying —
                // the moral equivalent of `flush` panicking. Dropping
                // the batch drops its reply senders.
                let _batch = next_batch(&worker_shared, 1, 1, Duration::ZERO);
            });

            let rx = enqueue(&shared, 2, vec![1.0])
                .expect("gateway is open and the queue is empty: must admit");
            worker.join().unwrap();

            let q = shared.lock();
            assert!(q.closed, "guard must close the queue on worker death");
            assert!(q.pending.is_empty(), "guard must drop pending requests");
            drop(q);
            // The worker never sends, so the only legal outcome is a
            // dropped sender — from the drained batch or the guard.
            assert_eq!(
                rx.try_recv(),
                Err(TryRecvError::Disconnected),
                "client of a dead worker must see a disconnect"
            );
            // A dead gateway refuses new work instead of queueing it.
            match enqueue(&shared, 2, vec![2.0]) {
                Err(SubmitError::Shutdown) => {}
                other => panic!("submit after worker death must be Shutdown, got {other:?}"),
            }
        });
    }

    /// Close racing a submit: whichever order the schedule picks, the
    /// submit either lands before the close (and must then be served by
    /// the drain) or observes the close and is refused — there is no
    /// third outcome where it is admitted and then ignored.
    #[test]
    fn loom_batcher_close_races_submit() {
        loom::model(|| {
            let shared = Shared::new(1);

            let submitter_shared = Arc::clone(&shared);
            let submitter = thread::spawn(move || enqueue(&submitter_shared, 1, vec![1.0]));

            // Main thread plays `Drop for Batcher` + the worker's final
            // drain: close, wake, then drain until closed-and-empty.
            shared.lock().closed = true;
            shared.wake.notify_all();
            while let Some(batch) = next_batch(&shared, 1, 1, Duration::ZERO) {
                for req in batch {
                    let _ = req.reply.send(reply());
                }
            }

            match submitter.join().unwrap() {
                // Admitted before the close: the drain must have served it.
                Ok(rx) => assert_eq!(
                    rx.try_recv().map(|r| r.trees_evaluated),
                    Ok(1),
                    "request admitted before close was not served by the drain"
                ),
                // Observed the close: refused outright, nothing queued.
                Err(SubmitError::Shutdown) => {}
                Err(other) => panic!("unexpected submit refusal: {other:?}"),
            }
            let q = shared.lock();
            assert!(q.closed && q.pending.is_empty());
        });
    }
}
