//! Simulated memory-constrained devices.
//!
//! A [`SimulatedDevice`] models a microcontroller: a flash/RAM byte
//! budget, an optional deployed packed model, and MCU-model time
//! accounting per prediction. Deployment fails if the blob exceeds the
//! budget — the paper's central feasibility criterion ("the model size
//! determines whether a deployment is feasible", §3 footnote).

use crate::layout::PackedModel;
use crate::mcu::McuSpec;
use std::fmt;

/// Device profiles used in the experiments.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeviceKind {
    /// Seeed XIAO ESP32-S3 (8 MB flash; we model a 64 KB model budget).
    Esp32S3,
    /// Arduino Nano 33 BLE (1 MB flash; 64 KB model budget modeled).
    Nano33Ble,
    /// Arduino Uno R4 Minima: 32 KB RAM / 256 KB flash; the paper's
    /// reference target with a 32 KB model budget.
    UnoR4,
    /// A deliberately tiny profile for the 0.5–2 KB experiments.
    TinyNode,
}

impl DeviceKind {
    pub fn mcu(&self) -> McuSpec {
        match self {
            DeviceKind::Esp32S3 => crate::mcu::ESP32_S3,
            DeviceKind::Nano33Ble => crate::mcu::NANO_33_BLE,
            DeviceKind::UnoR4 | DeviceKind::TinyNode => crate::mcu::UNO_R4,
        }
    }

    /// Default model byte budget.
    pub fn model_budget(&self) -> usize {
        match self {
            DeviceKind::Esp32S3 | DeviceKind::Nano33Ble => 64 * 1024,
            DeviceKind::UnoR4 => 32 * 1024,
            DeviceKind::TinyNode => 1024,
        }
    }
}

#[derive(Debug)]
pub enum DeviceError {
    OverBudget { model: usize, budget: usize },
    CorruptBlob(String),
    NoModel,
}

impl fmt::Display for DeviceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DeviceError::OverBudget { model, budget } => {
                write!(f, "model of {model} bytes exceeds device budget of {budget} bytes")
            }
            DeviceError::CorruptBlob(why) => write!(f, "corrupt model blob: {why}"),
            DeviceError::NoModel => write!(f, "no model deployed"),
        }
    }
}

impl std::error::Error for DeviceError {}

/// One simulated sensor node.
pub struct SimulatedDevice {
    pub id: usize,
    pub kind: DeviceKind,
    pub budget_bytes: usize,
    model: Option<PackedModel>,
    /// Accumulated simulated busy-time (seconds) from the MCU model.
    sim_busy_s: f64,
    predictions: u64,
}

impl SimulatedDevice {
    pub fn new(id: usize, kind: DeviceKind) -> SimulatedDevice {
        SimulatedDevice {
            id,
            kind,
            budget_bytes: kind.model_budget(),
            model: None,
            sim_busy_s: 0.0,
            predictions: 0,
        }
    }

    /// Override the default budget (e.g. OS/sensing reservations).
    pub fn with_budget(mut self, bytes: usize) -> SimulatedDevice {
        self.budget_bytes = bytes;
        self
    }

    pub fn has_model(&self) -> bool {
        self.model.is_some()
    }

    pub fn model_size(&self) -> Option<usize> {
        self.model.as_ref().map(|m| m.size_bytes())
    }

    /// Trees in the deployed model (`None` until something is
    /// deployed). On-device descent always walks every tree, so this is
    /// also the per-prediction trees-evaluated count.
    pub fn model_trees(&self) -> Option<usize> {
        self.model.as_ref().map(crate::inference::Predictor::n_trees)
    }

    /// Deploy a packed blob; fails if it does not fit or is corrupt
    /// (blobs travel over flaky links in the field — validate before
    /// interpreting them from flash).
    pub fn deploy(&mut self, blob: Vec<u8>) -> Result<(), DeviceError> {
        if blob.len() > self.budget_bytes {
            return Err(DeviceError::OverBudget { model: blob.len(), budget: self.budget_bytes });
        }
        crate::layout::toad_format::validate_blob(&blob).map_err(DeviceError::CorruptBlob)?;
        self.model = Some(PackedModel::from_bytes(blob));
        Ok(())
    }

    /// Run one local prediction, accounting simulated MCU time.
    pub fn predict(&mut self, x: &[f32]) -> Result<Vec<f64>, DeviceError> {
        let model = self.model.as_ref().ok_or(DeviceError::NoModel)?;
        let out = model.predict_raw(x);
        self.sim_busy_s += self.kind.mcu().toad_latency(model, x);
        self.predictions += 1;
        Ok(out)
    }

    /// Simulated seconds spent predicting so far.
    pub fn sim_busy_seconds(&self) -> f64 {
        self.sim_busy_s
    }

    pub fn predictions(&self) -> u64 {
        self.predictions
    }
}

#[cfg(test)]
#[cfg(not(miri))] // trains models / generates datasets - too slow under the Miri interpreter
mod tests {
    use super::*;
    use crate::data::synth::PaperDataset;
    use crate::gbdt::{self, GbdtParams};
    use crate::layout::{encode, EncodeOptions, FeatureInfo};

    fn blob(rounds: usize, depth: usize) -> (Vec<u8>, Vec<f32>) {
        let data = PaperDataset::BreastCancer.generate(61).select(&(0..300).collect::<Vec<_>>());
        let m = gbdt::booster::train(&data, GbdtParams::paper(rounds, depth));
        let finfo = FeatureInfo::from_dataset(&data);
        (encode(&m, &finfo, &EncodeOptions::default()).unwrap(), data.row(0))
    }

    #[test]
    fn deploy_within_budget() {
        let (b, x) = blob(4, 2);
        let mut dev = SimulatedDevice::new(0, DeviceKind::UnoR4);
        assert!(b.len() <= dev.budget_bytes);
        dev.deploy(b).unwrap();
        assert!(dev.has_model());
        let out = dev.predict(&x).unwrap();
        assert_eq!(out.len(), 1);
        assert!(dev.sim_busy_seconds() > 0.0);
        assert_eq!(dev.predictions(), 1);
    }

    #[test]
    fn deploy_over_budget_fails() {
        let (b, _) = blob(32, 4);
        let mut dev = SimulatedDevice::new(1, DeviceKind::TinyNode).with_budget(64);
        let err = dev.deploy(b).unwrap_err();
        assert!(matches!(err, DeviceError::OverBudget { .. }));
        assert!(!dev.has_model());
    }

    #[test]
    fn deploy_corrupt_blob_fails() {
        let (mut b, _) = blob(4, 2);
        // Flip bytes in the middle (simulated radio corruption of the
        // tree section lengths / header).
        b[2] ^= 0xFF;
        b[3] ^= 0xFF;
        let mut dev = SimulatedDevice::new(3, DeviceKind::UnoR4);
        // Either rejected as corrupt, or — if the flip happens to stay
        // structurally valid — accepted; it must never panic.
        let _ = dev.deploy(b);
    }

    #[test]
    fn deploy_truncated_blob_fails() {
        let (b, _) = blob(4, 2);
        let mut dev = SimulatedDevice::new(4, DeviceKind::UnoR4);
        let err = dev.deploy(b[..b.len() / 2].to_vec()).unwrap_err();
        assert!(matches!(err, DeviceError::CorruptBlob(_)), "{err}");
    }

    #[test]
    fn predict_without_model_fails() {
        let mut dev = SimulatedDevice::new(2, DeviceKind::Esp32S3);
        assert!(matches!(dev.predict(&[0.0]).unwrap_err(), DeviceError::NoModel));
    }

    #[test]
    fn budgets_match_hardware() {
        assert_eq!(DeviceKind::UnoR4.model_budget(), 32 * 1024);
        assert_eq!(DeviceKind::TinyNode.model_budget(), 1024);
    }
}
