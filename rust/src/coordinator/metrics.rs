//! Latency and throughput recording for the serving path.
//!
//! [`LatencyRecorder`] is a thread-safe fixed-bucket histogram: many
//! serving threads record into the same recorder through `&self`, and
//! a metrics scrape reads percentiles in one O(buckets) pass — no
//! per-request allocation, no unbounded sample vector, no re-sort per
//! query (the old recorder cloned and sorted every sample on every
//! `percentile_us` call, which was quadratic across a scrape).
//! Recording is lock-free: relaxed atomic adds for the histogram, and
//! an insert-only open-addressed atomic table for the per-version
//! counters (a mutex-guarded overflow map exists only for the
//! pathological case of more than [`VERSION_SLOTS`] distinct versions
//! hitting one recorder).
//!
//! Buckets are log-scaled with 8 sub-buckets per power of two (values
//! below 16 µs get exact one-µs buckets), so a reported percentile is
//! within one bucket width — at most 1/8th — of the true sample value,
//! over the full `u64` microsecond range in a fixed 496-slot table.
//! Per-version counters track how many requests each registry version
//! served, which is how the hot-swap example and stress test observe a
//! live swap.

use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::Mutex;
use std::collections::HashMap;
use std::time::Duration;

/// Values below this get exact one-microsecond buckets.
const LINEAR_MAX: u64 = 16;
/// Sub-buckets per power of two above `LINEAR_MAX` (3 bits).
const SUB_BITS: u32 = 3;
/// 16 exact buckets + 8 sub-buckets for each of the 60 octaves 2^4..2^63.
const N_BUCKETS: usize = LINEAR_MAX as usize + 60 * (1 << SUB_BITS);

/// Bucket index for a latency in microseconds.
fn bucket_index(us: u64) -> usize {
    if us < LINEAR_MAX {
        return us as usize;
    }
    let msb = 63 - us.leading_zeros() as u64; // >= 4
    let sub = (us >> (msb - SUB_BITS as u64)) & ((1 << SUB_BITS) - 1);
    (LINEAR_MAX + (msb - 4) * (1 << SUB_BITS) + sub) as usize
}

/// Lower bound (µs) of a bucket — the value a percentile query reports.
fn bucket_floor(idx: usize) -> u64 {
    let idx = idx as u64;
    if idx < LINEAR_MAX {
        return idx;
    }
    let octave = (idx - LINEAR_MAX) >> SUB_BITS;
    let sub = (idx - LINEAR_MAX) & ((1 << SUB_BITS) - 1);
    let msb = octave + 4;
    ((1 << SUB_BITS) + sub) << (msb - SUB_BITS as u64)
}

/// Fast-path slots for per-version counters; registries hand out few
/// distinct versions per recorder lifetime, so collisions are rare.
const VERSION_SLOTS: usize = 64;

/// Insert-only open-addressed `(version, count)` table on atomics —
/// recording a version is a probe plus a relaxed add, no lock. Slots
/// store `version + 1` (0 = empty) so version 0 is representable.
#[derive(Debug)]
struct VersionCounters {
    slots: Box<[(AtomicU64, AtomicU64)]>,
    /// Cold path: only reached when every slot holds some *other*
    /// version (> [`VERSION_SLOTS`] distinct versions on one recorder).
    /// A version that failed to claim a slot lands here consistently —
    /// slots are never freed, so its probes keep failing the same way.
    overflow: Mutex<HashMap<u64, u64>>,
}

impl VersionCounters {
    fn new() -> Self {
        Self::with_slots(VERSION_SLOTS)
    }

    /// Build a table of `n_slots` slots. Production uses
    /// [`VERSION_SLOTS`]; the loom models shrink the table to 1–2
    /// slots so collision and overflow interleavings stay tractable
    /// for exhaustive exploration.
    fn with_slots(n_slots: usize) -> Self {
        let slots: Vec<(AtomicU64, AtomicU64)> =
            (0..n_slots).map(|_| (AtomicU64::new(0), AtomicU64::new(0))).collect();
        VersionCounters { slots: slots.into_boxed_slice(), overflow: Mutex::new(HashMap::new()) }
    }

    /// Memory-ordering contract (loom-verified in `loom_tests` below):
    /// every atomic here is `Relaxed`, because the protocol is
    /// *value-based*. A slot tag is written exactly once (0 → tag,
    /// insert-only, never freed), so per-object coherence alone
    /// guarantees that any thread reading a nonzero tag reads *the*
    /// tag, and every `fetch_add` on the paired count atomic belongs to
    /// that tag's version forever. No non-atomic data is published
    /// through the tag, so there is no happens-before edge to
    /// establish and nothing for acquire/release to order. (The
    /// previous revision used `Acquire`/`AcqRel` here; loom passes the
    /// same lossless/no-double-count models with `Relaxed`, and the
    /// downgrade removes fence traffic from the per-request hot path
    /// on weakly-ordered targets.)
    fn record(&self, version: u64) {
        let tag = version.wrapping_add(1);
        let n = self.slots.len();
        let start = version as usize % n;
        for off in 0..n {
            let (v, c) = &self.slots[(start + off) % n];
            // Relaxed: tag compared by value only; write-once slots
            // make any nonzero read final (coherence, not ordering).
            let cur = v.load(Ordering::Relaxed);
            if cur == tag {
                // Relaxed: independent monotonic counter; attribution
                // to `tag` is fixed by the slot, not by ordering.
                c.fetch_add(1, Ordering::Relaxed);
                return;
            }
            if cur == 0 {
                // Relaxed success + failure: claiming a slot publishes
                // only the tag value itself — the CAS's atomicity (not
                // its ordering) is what makes the claim exclusive.
                match v.compare_exchange(0, tag, Ordering::Relaxed, Ordering::Relaxed) {
                    // Won the slot, or lost it to a concurrent recorder
                    // of the *same* version — count there either way.
                    Ok(_) => {
                        // Relaxed: see above.
                        c.fetch_add(1, Ordering::Relaxed);
                        return;
                    }
                    Err(found) if found == tag => {
                        // Relaxed: see above.
                        c.fetch_add(1, Ordering::Relaxed);
                        return;
                    }
                    Err(_) => continue, // another version claimed it
                }
            }
        }
        let mut of = self.overflow.lock().unwrap_or_else(|e| e.into_inner());
        *of.entry(version).or_insert(0) += 1;
    }

    /// Point-in-time view of the counters. The snapshot may be *torn*
    /// with respect to concurrent recorders: a version whose claim or
    /// increment is still in flight can be missing or under-counted,
    /// and two versions may be observed at counts from slightly
    /// different instants. It is never *wrong*: slots are insert-only,
    /// so a count is always attributed to the version that owns its
    /// slot, and re-reading after recorders quiesce yields exact
    /// totals (the lossless property the loom models check).
    fn snapshot(&self) -> Vec<(u64, u64)> {
        let of = self.overflow.lock().unwrap_or_else(|e| e.into_inner());
        let mut out: Vec<(u64, u64)> = of.iter().map(|(&v, &c)| (v, c)).collect();
        for (v, c) in self.slots.iter() {
            // Relaxed: write-once tag — a nonzero read is final.
            let tag = v.load(Ordering::Relaxed);
            if tag != 0 {
                // Relaxed: may lag in-flight increments (torn snapshot
                // contract above), never misattributes.
                out.push((tag - 1, c.load(Ordering::Relaxed)));
            }
        }
        out.sort_unstable();
        out
    }
}

/// Records request latencies and computes percentiles/throughput.
/// All methods take `&self`; recording takes no lock.
#[derive(Debug)]
pub struct LatencyRecorder {
    buckets: Box<[AtomicU64; N_BUCKETS]>,
    count: AtomicU64,
    sum_us: AtomicU64,
    /// Requests served per registry version (version 0 = a static,
    /// non-registry deployment).
    version_counts: VersionCounters,
}

impl Default for LatencyRecorder {
    fn default() -> Self {
        // `AtomicU64` is not Copy; build the array through a Vec.
        let buckets: Vec<AtomicU64> = (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect();
        let buckets: Box<[AtomicU64; N_BUCKETS]> =
            buckets.into_boxed_slice().try_into().expect("bucket count is fixed");
        LatencyRecorder {
            buckets,
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            version_counts: VersionCounters::new(),
        }
    }
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a latency against a static (version-0) deployment.
    pub fn record(&self, latency: Duration) {
        self.record_version(latency, 0);
    }

    /// Record a latency for a request served by `version`.
    pub fn record_version(&self, latency: Duration, version: u64) {
        let us = latency.as_micros().min(u64::MAX as u128) as u64;
        // Relaxed (all three): independent monotonic counters. Nothing
        // non-atomic is published, and the scrape side explicitly
        // accepts torn cross-counter views (see `percentile_us`), so
        // no release pairing is needed — each add only has to be
        // atomic and eventually visible.
        self.buckets[bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.version_counts.record(version);
    }

    pub fn count(&self) -> usize {
        // Relaxed: monotonic counter read for monitoring.
        self.count.load(Ordering::Relaxed) as usize
    }

    /// `(version, requests served)` pairs, sorted by version.
    pub fn version_counts(&self) -> Vec<(u64, u64)> {
        self.version_counts.snapshot()
    }

    /// Percentile in microseconds (nearest-rank over the histogram).
    ///
    /// The reported value is the floor of the bucket holding the
    /// nearest-rank sample, so it matches the exact nearest-rank answer
    /// to within one bucket width (≤ 1/8th of the value; exact below
    /// 16 µs).
    ///
    /// **Torn-snapshot contract.** All reads here are `Relaxed` and the
    /// scrape is not a consistent cut: recorders racing the scan can
    /// make `count` and the bucket sums disagree by the handful of
    /// requests in flight during the O(buckets) pass. That skews the
    /// rank by at most those in-flight samples — bounded, transient,
    /// and irrelevant for a monitoring read (the next scrape sees
    /// them). The alternatives are a lock on the record path or a
    /// seqlock retry loop; both buy a consistency nobody consuming a
    /// latency dashboard needs. Two hard guarantees survive any race,
    /// pinned by `percentile_is_sane_under_concurrent_recording`: the
    /// result is always the floor of some *recorded* bucket (never
    /// garbage), and a quiesced recorder reports exact nearest-rank
    /// semantics to within one bucket width.
    pub fn percentile_us(&self, p: f64) -> u64 {
        assert!((0.0..=100.0).contains(&p));
        // Relaxed: see the torn-snapshot contract above.
        let n = self.count.load(Ordering::Relaxed);
        if n == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * (n as f64 - 1.0)).round() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            // Relaxed: bucket sums may lag `count`; handled below.
            seen += b.load(Ordering::Relaxed);
            if seen > rank {
                return bucket_floor(i);
            }
        }
        // Racing recorders can grow `count` after we read it (or a
        // bucket add can still be in flight behind its count add); the
        // last non-empty bucket is still the right answer.
        bucket_floor(
            self.buckets
                .iter()
                .rposition(|b| b.load(Ordering::Relaxed) > 0)
                .unwrap_or(0),
        )
    }

    /// Mean latency. Same torn-snapshot contract as
    /// [`LatencyRecorder::percentile_us`]: `sum_us` and `count` are
    /// read independently, so a racing recorder can contribute a count
    /// without its sum (or vice versa), perturbing the mean by at most
    /// the in-flight samples; a quiesced recorder's mean is exact.
    pub fn mean_us(&self) -> f64 {
        // Relaxed (both): see the torn-snapshot contract above.
        let n = self.count.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Requests/second given the wall-clock span of the run.
    pub fn throughput(&self, wall: Duration) -> f64 {
        if wall.is_zero() {
            return 0.0;
        }
        self.count() as f64 / wall.as_secs_f64()
    }

    /// One-line human summary.
    pub fn summary(&self, wall: Duration) -> String {
        format!(
            "n={} p50={}us p95={}us p99={}us mean={:.0}us throughput={:.0}/s",
            self.count(),
            self.percentile_us(50.0),
            self.percentile_us(95.0),
            self.percentile_us(99.0),
            self.mean_us(),
            self.throughput(wall),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The old recorder's exact nearest-rank percentile, as the oracle.
    fn nearest_rank(samples: &[u64], p: f64) -> u64 {
        let mut s = samples.to_vec();
        s.sort_unstable();
        let rank = ((p / 100.0) * (s.len() as f64 - 1.0)).round() as usize;
        s[rank]
    }

    /// Width (µs) of the bucket holding `us`.
    fn bucket_width(us: u64) -> u64 {
        let idx = bucket_index(us);
        if idx + 1 >= N_BUCKETS {
            // Top bucket: its upper bound (2^64) is not representable,
            // but its width is — [15·2^60, 2^64) spans 2^60.
            return 1 << 60;
        }
        bucket_floor(idx + 1).saturating_sub(bucket_floor(idx)).max(1)
    }

    #[test]
    fn bucket_roundtrip_is_monotone_and_tight() {
        let mut probes: Vec<u64> = (0..200).collect();
        for shift in 4..63 {
            for delta in [0u64, 1, 3] {
                probes.push((1u64 << shift) + delta);
                probes.push((1u64 << shift).wrapping_sub(delta + 1).max(1));
            }
        }
        probes.push(u64::MAX);
        for &v in &probes {
            let idx = bucket_index(v);
            assert!(idx < N_BUCKETS, "index {idx} out of range for {v}");
            let floor = bucket_floor(idx);
            assert!(floor <= v, "floor {floor} above sample {v}");
            assert!(v - floor < bucket_width(v), "sample {v} outside its bucket");
            // Monotone: the next bucket starts above this sample (the
            // top bucket has no successor to compare against).
            assert!(idx + 1 == N_BUCKETS || bucket_floor(idx + 1) > v);
        }
    }

    #[test]
    fn percentiles() {
        let r = LatencyRecorder::new();
        for us in [100u64, 200, 300, 400, 500, 600, 700, 800, 900, 1000] {
            r.record(Duration::from_micros(us));
        }
        assert_eq!(r.count(), 10);
        // Values this small sit in 1/8th-wide buckets: p0/p100 within
        // one bucket width of the exact answers.
        assert!(r.percentile_us(0.0) <= 100 && r.percentile_us(0.0) > 100 - bucket_width(100));
        assert!(r.percentile_us(100.0) <= 1000);
        assert!(r.percentile_us(100.0) > 1000 - bucket_width(1000));
        let p50 = r.percentile_us(50.0);
        assert!((400..=600).contains(&p50), "p50 {p50}");
        assert!((r.mean_us() - 550.0).abs() < 1e-9, "mean stays exact");
    }

    /// Satellite regression: the histogram percentile must match the
    /// old sort-every-call nearest-rank semantics to within one bucket
    /// width, across distributions and percentiles.
    #[test]
    fn percentile_matches_nearest_rank_within_one_bucket() {
        let mut rng = crate::prng::Pcg64::new(9);
        let mut samples: Vec<u64> = Vec::new();
        // Mixed distribution: tight cluster, long tail, exact-bucket
        // small values.
        for i in 0..400 {
            let v = match i % 4 {
                0 => rng.next_u64() % 16,                  // exact buckets
                1 => 80 + rng.next_u64() % 40,             // tight cluster
                2 => 1_000 + rng.next_u64() % 9_000,       // medium
                _ => 100_000 + rng.next_u64() % 3_000_000, // tail
            };
            samples.push(v);
        }
        let r = LatencyRecorder::new();
        for &s in &samples {
            r.record(Duration::from_micros(s));
        }
        for p in [0.0, 1.0, 10.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0, 100.0] {
            let want = nearest_rank(&samples, p);
            let got = r.percentile_us(p);
            assert!(
                got <= want && want - got < bucket_width(want),
                "p{p}: histogram {got} vs nearest-rank {want} (width {})",
                bucket_width(want)
            );
        }
    }

    #[test]
    fn empty_recorder() {
        let r = LatencyRecorder::new();
        assert_eq!(r.percentile_us(50.0), 0);
        assert_eq!(r.mean_us(), 0.0);
        assert_eq!(r.throughput(Duration::from_secs(1)), 0.0);
        assert!(r.version_counts().is_empty());
    }

    #[test]
    fn throughput() {
        let r = LatencyRecorder::new();
        for _ in 0..100 {
            r.record(Duration::from_micros(10));
        }
        assert!((r.throughput(Duration::from_secs(2)) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn per_version_counters() {
        let r = LatencyRecorder::new();
        r.record(Duration::from_micros(5));
        r.record_version(Duration::from_micros(6), 3);
        r.record_version(Duration::from_micros(7), 3);
        assert_eq!(r.version_counts(), vec![(0, 1), (3, 2)]);
        assert_eq!(r.count(), 3);
    }

    #[test]
    fn version_counters_survive_collisions_and_overflow() {
        let r = LatencyRecorder::new();
        // 3 × VERSION_SLOTS distinct versions: same-slot collisions
        // probe onward, the table fills, and the rest take the
        // overflow path; every count must still be exact.
        let n_versions = 3 * VERSION_SLOTS as u64;
        for v in 0..n_versions {
            for _ in 0..=(v % 3) {
                r.record_version(Duration::from_micros(10), v);
            }
        }
        let vc = r.version_counts();
        assert_eq!(vc.len(), n_versions as usize);
        for &(v, c) in &vc {
            assert_eq!(c, v % 3 + 1, "version {v} count");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // threaded stress test — minutes under Miri
    fn concurrent_recording_is_lossless() {
        let r = LatencyRecorder::new();
        std::thread::scope(|s| {
            for t in 0..4 {
                let r = &r;
                s.spawn(move || {
                    for i in 0..1000u64 {
                        r.record_version(Duration::from_micros(10 + i % 90), t);
                    }
                });
            }
        });
        assert_eq!(r.count(), 4000);
        let vc = r.version_counts();
        assert_eq!(vc.len(), 4);
        assert!(vc.iter().all(|&(_, c)| c == 1000));
        assert!(r.percentile_us(50.0) >= 10);
        assert!(r.percentile_us(100.0) < 100 + bucket_width(100));
    }

    /// Pin of the torn-snapshot contract on `percentile_us`/`mean_us`:
    /// scrapes racing a storm of recorders must always return the
    /// floor of a bucket that a recorded sample can occupy — in range,
    /// never garbage, never a panic — and the quiesced read afterwards
    /// must be exact nearest-rank to within one bucket width.
    #[test]
    #[cfg_attr(miri, ignore)] // threaded stress test — minutes under Miri
    fn percentile_is_sane_under_concurrent_recording() {
        let r = LatencyRecorder::new();
        let hi_floor = bucket_floor(bucket_index(5_000));
        std::thread::scope(|s| {
            for t in 0..3u64 {
                let r = &r;
                s.spawn(move || {
                    for i in 0..2000u64 {
                        r.record(Duration::from_micros(20 + (i * (t + 1)) % 4980));
                    }
                });
            }
            let r = &r;
            s.spawn(move || {
                for _ in 0..500 {
                    for p in [0.0, 50.0, 99.0, 100.0] {
                        let v = r.percentile_us(p);
                        assert!(v <= hi_floor, "p{p} scrape {v} above any recorded bucket");
                    }
                    // The torn contract bounds *sanity*, not the value:
                    // `sum_us` and `count` are read at independent
                    // points of their histories, so mid-storm means can
                    // overshoot — they must only stay finite and
                    // non-negative.
                    let m = r.mean_us();
                    assert!(m.is_finite() && m >= 0.0, "torn mean {m}");
                }
            });
        });
        // Quiesced: exact semantics return.
        assert_eq!(r.count(), 6000);
        assert!(r.percentile_us(0.0) >= 20 - bucket_width(20));
        assert!(r.percentile_us(100.0) <= hi_floor);
        assert!((20.0..5_000.0).contains(&r.mean_us()));
    }
}

/// Exhaustive interleaving models of the lock-free version-counter
/// table. Run with `RUSTFLAGS="--cfg loom" cargo test --release loom_`;
/// loom explores every schedule *and* every relaxed-memory outcome the
/// C++11 model allows for the all-`Relaxed` protocol in
/// [`VersionCounters::record`].
#[cfg(all(loom, test))]
mod loom_tests {
    use super::*;
    use crate::sync::Arc;
    use loom::thread;

    /// Three recorders (two spawned + the model's main thread) racing
    /// on versions 0 and 2, which collide in a 2-slot table: every
    /// increment must land exactly once — claims, lost-CAS-same-tag
    /// continuations, and probe-past-a-foreign-slot all included.
    #[test]
    fn loom_version_counters_never_lose_or_double_count() {
        loom::model(|| {
            let vc = Arc::new(VersionCounters::with_slots(2));
            let a = {
                let vc = Arc::clone(&vc);
                thread::spawn(move || vc.record(0))
            };
            let b = {
                let vc = Arc::clone(&vc);
                thread::spawn(move || vc.record(2))
            };
            vc.record(0);
            a.join().unwrap();
            b.join().unwrap();
            assert_eq!(vc.snapshot(), vec![(0, 2), (2, 1)]);
        });
    }

    /// Two recorders of the *same* version race for the single empty
    /// slot: whichever CAS loses must detect its own tag in the slot
    /// and count there — never double-claim, never spill to overflow.
    #[test]
    fn loom_version_counters_same_version_cas_race() {
        loom::model(|| {
            let vc = Arc::new(VersionCounters::with_slots(1));
            let a = {
                let vc = Arc::clone(&vc);
                thread::spawn(move || vc.record(7))
            };
            vc.record(7);
            a.join().unwrap();
            assert_eq!(vc.snapshot(), vec![(7, 2)]);
            let of = vc.overflow.lock().unwrap_or_else(|e| e.into_inner());
            assert!(of.is_empty(), "same-version race must share the slot, not overflow");
        });
    }

    /// Two *different* versions race for a 1-slot table: exactly one
    /// wins the slot, the other must take the overflow path — and the
    /// merged snapshot is exact either way.
    #[test]
    fn loom_version_counters_overflow_when_table_full() {
        loom::model(|| {
            let vc = Arc::new(VersionCounters::with_slots(1));
            let a = {
                let vc = Arc::clone(&vc);
                thread::spawn(move || vc.record(1))
            };
            vc.record(2);
            a.join().unwrap();
            assert_eq!(vc.snapshot(), vec![(1, 1), (2, 1)]);
        });
    }

    /// A snapshot racing one recorder: torn views are allowed (the
    /// version may be absent or show 0), but an *observed* count must
    /// never exceed the true total, and the quiesced snapshot is exact.
    #[test]
    fn loom_snapshot_never_overcounts() {
        loom::model(|| {
            let vc = Arc::new(VersionCounters::with_slots(2));
            let a = {
                let vc = Arc::clone(&vc);
                thread::spawn(move || vc.record(5))
            };
            for (v, c) in vc.snapshot() {
                assert_eq!(v, 5, "only version 5 is ever recorded");
                assert!(c <= 1, "snapshot overcounted: {c}");
            }
            a.join().unwrap();
            assert_eq!(vc.snapshot(), vec![(5, 1)]);
        });
    }
}
