//! Latency and throughput recording for the serving path.

use std::time::Duration;

/// Records request latencies and computes percentiles/throughput.
#[derive(Default, Clone, Debug)]
pub struct LatencyRecorder {
    /// Latencies in microseconds.
    samples_us: Vec<u64>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, latency: Duration) {
        self.samples_us.push(latency.as_micros() as u64);
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    /// Percentile in microseconds (nearest-rank).
    pub fn percentile_us(&self, p: f64) -> u64 {
        assert!((0.0..=100.0).contains(&p));
        if self.samples_us.is_empty() {
            return 0;
        }
        let mut s = self.samples_us.clone();
        s.sort_unstable();
        let rank = ((p / 100.0) * (s.len() as f64 - 1.0)).round() as usize;
        s[rank]
    }

    pub fn mean_us(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<u64>() as f64 / self.samples_us.len() as f64
    }

    /// Requests/second given the wall-clock span of the run.
    pub fn throughput(&self, wall: Duration) -> f64 {
        if wall.is_zero() {
            return 0.0;
        }
        self.count() as f64 / wall.as_secs_f64()
    }

    /// One-line human summary.
    pub fn summary(&self, wall: Duration) -> String {
        format!(
            "n={} p50={}us p95={}us p99={}us mean={:.0}us throughput={:.0}/s",
            self.count(),
            self.percentile_us(50.0),
            self.percentile_us(95.0),
            self.percentile_us(99.0),
            self.mean_us(),
            self.throughput(wall),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut r = LatencyRecorder::new();
        for us in [100u64, 200, 300, 400, 500, 600, 700, 800, 900, 1000] {
            r.record(Duration::from_micros(us));
        }
        assert_eq!(r.count(), 10);
        assert_eq!(r.percentile_us(0.0), 100);
        assert_eq!(r.percentile_us(100.0), 1000);
        let p50 = r.percentile_us(50.0);
        assert!((500..=600).contains(&p50));
        assert!((r.mean_us() - 550.0).abs() < 1e-9);
    }

    #[test]
    fn empty_recorder() {
        let r = LatencyRecorder::new();
        assert_eq!(r.percentile_us(50.0), 0);
        assert_eq!(r.mean_us(), 0.0);
        assert_eq!(r.throughput(Duration::from_secs(1)), 0.0);
    }

    #[test]
    fn throughput() {
        let mut r = LatencyRecorder::new();
        for _ in 0..100 {
            r.record(Duration::from_micros(10));
        }
        assert!((r.throughput(Duration::from_secs(2)) - 50.0).abs() < 1e-9);
    }
}
