//! Layer-3 coordination: the IoT fleet runtime.
//!
//! The paper's deployment story (Figure 1) is a fleet of
//! memory-constrained sensor nodes running compressed models locally and
//! transmitting only relevant events. This module provides the
//! server-side counterpart plus a device simulation:
//!
//! * [`device`] — simulated microcontrollers with byte budgets that run
//!   the packed (bit-level) model, with MCU-model latency accounting.
//! * [`planner`] — picks, from a sweep's model candidates, the best
//!   scorer that fits a device's memory budget (paper §4.2: "best model
//!   with memory ≤ limit").
//! * [`batcher`] — dynamic batching worker feeding a batched engine:
//!   the native flattened model by default, or the XLA predict engine
//!   with the `xla` feature (gateway-side inference for fleets too
//!   small to deploy on).
//! * [`router`] — routes requests to deployments by model key.
//! * [`metrics`] — latency/throughput recording.
//! * [`server`] — ties devices + gateway batching into one front door.

pub mod batcher;
pub mod device;
pub mod metrics;
pub mod planner;
pub mod router;
pub mod server;

pub use batcher::{Batcher, BatcherConfig};
pub use device::{DeviceKind, SimulatedDevice};
pub use metrics::LatencyRecorder;
pub use planner::{DeploymentPlanner, ModelCard};
pub use router::Router;
pub use server::FleetServer;
