//! Layer-3 coordination: the IoT fleet runtime.
//!
//! The paper's deployment story (Figure 1) is a fleet of
//! memory-constrained sensor nodes running compressed models locally and
//! transmitting only relevant events. This module provides the
//! server-side counterpart plus a device simulation, built as a
//! **concurrent serving tier**: many threads drive one
//! [`FleetServer`] through `&self`, and a published registry version
//! hot-swaps the serving engine without draining traffic.
//!
//! * [`device`] — simulated microcontrollers with byte budgets that run
//!   the packed (bit-level) model, with MCU-model latency accounting.
//! * [`registry`] — versioned model registry: immutable
//!   [`DeployedModel`] artifacts behind atomic publish/retire;
//!   in-flight batches finish on the version they started with.
//! * [`planner`] — picks, from a sweep's model candidates, the best
//!   scorer that fits a device's memory budget (paper §4.2),
//!   [`DeploymentPlanner::replan`] publishes live upgrades into the
//!   registry, and [`DeploymentPlanner::replan_classes`] derives
//!   per-device-class gateway configs (one model, per-class adaptive
//!   exit tolerances).
//! * [`batcher`] — dynamic batching worker with bounded-queue admission
//!   control ([`SubmitError::Overloaded`] backpressure) feeding a
//!   batched engine: native flat, quantized columnar, registry-resolved
//!   (hot-swappable), or the XLA predict engine (`xla` feature).
//! * [`router`] — routes requests to deployments by model key
//!   (lock-free atomic round-robin over replicas).
//! * [`metrics`] — thread-safe log-bucket latency histogram with
//!   per-version counters.
//! * [`server`] — ties devices + gateway batching into one `Send +
//!   Sync` front door.

pub mod batcher;
pub mod device;
pub mod metrics;
pub mod planner;
pub mod registry;
pub mod router;
pub mod server;

pub use batcher::{BatchReply, Batcher, BatcherConfig, SubmitError};
pub use device::{DeviceKind, SimulatedDevice};
pub use metrics::LatencyRecorder;
pub use planner::{ClassAssignment, DeploymentPlanner, ModelCard};
pub use registry::{DeployedModel, ModelRegistry};
pub use router::Router;
pub use server::{FleetServer, Ticket};
