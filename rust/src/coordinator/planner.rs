//! Deployment planning: best model under a byte budget.
//!
//! The paper's Figure 4 protocol — "the best-performing models with a
//! memory consumption ≤ the respective upper limit were chosen from the
//! grid search results" — is exactly the planner's query, applied at
//! deployment time: given the candidate models a sweep produced, pick
//! the best scorer that fits each device.

use super::batcher::BatcherConfig;
use super::device::SimulatedDevice;
use super::registry::{DeployedModel, ModelRegistry};
use crate::inference::AdaptivePolicy;
use std::fmt;
use std::sync::Arc;

/// A candidate model produced by a training sweep.
#[derive(Clone, Debug)]
pub struct ModelCard {
    pub id: String,
    /// Validation/test score (higher is better: accuracy or R²).
    pub score: f64,
    pub size_bytes: usize,
    /// The encoded ToaD blob.
    pub blob: Vec<u8>,
}

/// One device class in a fleet plan: every class serves the *same*
/// published model; classes differ only in the adaptive exit tolerance
/// their gateway applies ([`AdaptivePolicy`]). A low-power sensor class
/// might run `Margin(0.05)` while a line-powered hub runs `Exact` —
/// same bytes in flash, different accuracy/latency point.
#[derive(Clone, Debug)]
pub struct ClassAssignment {
    pub class: String,
    pub policy: AdaptivePolicy,
}

#[derive(Debug)]
pub enum PlanError {
    NothingFits { budget: usize, smallest: usize },
    Empty,
    DeployFailed { id: String, reason: String },
}

impl fmt::Display for PlanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlanError::NothingFits { budget, smallest } => write!(
                f,
                "no candidate fits the budget of {budget} bytes (smallest is {smallest})"
            ),
            PlanError::Empty => write!(f, "no candidates registered"),
            PlanError::DeployFailed { id, reason } => {
                write!(f, "deploying `{id}` failed: {reason}")
            }
        }
    }
}

impl std::error::Error for PlanError {}

/// Picks deployments from a candidate pool.
#[derive(Default)]
pub struct DeploymentPlanner {
    candidates: Vec<ModelCard>,
}

impl DeploymentPlanner {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_candidate(&mut self, card: ModelCard) {
        self.candidates.push(card);
    }

    pub fn candidates(&self) -> &[ModelCard] {
        &self.candidates
    }

    /// Best-scoring candidate with `size <= budget`; ties break toward
    /// the smaller model (cheaper deployment, same quality).
    pub fn best_under(&self, budget: usize) -> Result<&ModelCard, PlanError> {
        if self.candidates.is_empty() {
            return Err(PlanError::Empty);
        }
        self.candidates
            .iter()
            .filter(|c| c.size_bytes <= budget)
            .max_by(|a, b| {
                a.score
                    .partial_cmp(&b.score)
                    .unwrap()
                    .then(b.size_bytes.cmp(&a.size_bytes))
            })
            .ok_or_else(|| PlanError::NothingFits {
                budget,
                smallest: self.candidates.iter().map(|c| c.size_bytes).min().unwrap(),
            })
    }

    /// Plan and deploy onto a device; returns the chosen card id.
    /// Fitting is guaranteed by construction; corrupt blobs surface as
    /// [`PlanError::DeployFailed`].
    pub fn deploy_to(&self, device: &mut SimulatedDevice) -> Result<String, PlanError> {
        let card = self.best_under(device.budget_bytes)?;
        device.deploy(card.blob.clone()).map_err(|e| PlanError::DeployFailed {
            id: card.id.clone(),
            reason: e.to_string(),
        })?;
        Ok(card.id.clone())
    }

    /// Close the Fig. 4 loop live: diff the candidate pool against the
    /// registry's current deployment for `key` and publish an upgrade —
    /// the best candidate under `budget` — when it beats what is
    /// serving (higher score, or same score in fewer bytes).
    ///
    /// Returns the newly published deployment, or `None` when the
    /// current deployment is already the best fit. Traffic through a
    /// registry-backed gateway swaps to the new version at its next
    /// flush; in-flight batches finish on the version they started
    /// with.
    ///
    /// The engine is decoded from the candidate's packed blob — the
    /// gateway serves exactly the artifact a device deployment would
    /// execute, not a retrained lookalike.
    pub fn replan(
        &self,
        registry: &ModelRegistry,
        key: &str,
        budget: usize,
    ) -> Result<Option<Arc<DeployedModel>>, PlanError> {
        let best = self.best_under(budget)?;
        if let Some(cur) = registry.current(key) {
            let better = best.score > cur.card.score
                || (best.score == cur.card.score && best.size_bytes < cur.card.size_bytes);
            if !better {
                return Ok(None);
            }
        }
        // Candidate blobs can be untrusted (flaky links, hand-built
        // cards): a corrupt winner must surface as an error, not kill
        // the serving thread that drove the replan.
        let model = crate::layout::toad_format::try_decode(&best.blob).map_err(|e| {
            PlanError::DeployFailed { id: best.id.clone(), reason: e }
        })?;
        Ok(Some(registry.publish(key, best.clone(), model.quantize())))
    }

    /// Plan one model for a heterogeneous fleet: [`replan`](Self::replan)
    /// the best candidate under `budget` onto `key`, then derive one
    /// gateway config per device class — identical except for the
    /// class's adaptive exit tolerance.
    ///
    /// Returns the deployment that serves (freshly published, or the
    /// incumbent when it is already the best fit) and
    /// `(class, BatcherConfig)` pairs ready for
    /// [`FleetServer::add_class_gateways`](
    /// super::server::FleetServer::add_class_gateways). The model is
    /// chosen *once* — per-class tolerance is a serving knob, not a
    /// second model search.
    pub fn replan_classes(
        &self,
        registry: &ModelRegistry,
        key: &str,
        budget: usize,
        classes: &[ClassAssignment],
    ) -> Result<(Arc<DeployedModel>, Vec<(String, BatcherConfig)>), PlanError> {
        let dep = match self.replan(registry, key, budget)? {
            Some(dep) => dep,
            // `replan` returns `None` only when a current deployment is
            // already the best fit, so `current` must resolve.
            None => registry.current(key).expect("replan(None) implies a live deployment"),
        };
        let gateways = classes
            .iter()
            .map(|c| (c.class.clone(), BatcherConfig { policy: c.policy, ..Default::default() }))
            .collect();
        Ok((dep, gateways))
    }

    /// The quality-vs-memory Pareto frontier of the candidate pool
    /// (nondominated solutions, paper §4.4), sorted by size.
    pub fn pareto_frontier(&self) -> Vec<&ModelCard> {
        let mut sorted: Vec<&ModelCard> = self.candidates.iter().collect();
        sorted.sort_by(|a, b| {
            a.size_bytes.cmp(&b.size_bytes).then(b.score.partial_cmp(&a.score).unwrap())
        });
        let mut out: Vec<&ModelCard> = Vec::new();
        let mut best = f64::NEG_INFINITY;
        for c in sorted {
            if c.score > best {
                best = c.score;
                out.push(c);
            }
        }
        out
    }
}

#[cfg(test)]
#[cfg(not(miri))] // trains models / generates datasets - too slow under the Miri interpreter
mod tests {
    use super::*;
    use crate::coordinator::device::DeviceKind;

    fn card(id: &str, score: f64, size: usize) -> ModelCard {
        ModelCard { id: id.into(), score, size_bytes: size, blob: vec![0u8; size] }
    }

    fn pool() -> DeploymentPlanner {
        let mut p = DeploymentPlanner::new();
        p.add_candidate(card("tiny", 0.80, 300));
        p.add_candidate(card("small", 0.88, 900));
        p.add_candidate(card("medium", 0.92, 4_000));
        p.add_candidate(card("large", 0.95, 40_000));
        p
    }

    #[test]
    fn picks_best_that_fits() {
        let p = pool();
        assert_eq!(p.best_under(1024).unwrap().id, "small");
        assert_eq!(p.best_under(10_000).unwrap().id, "medium");
        assert_eq!(p.best_under(100_000).unwrap().id, "large");
    }

    #[test]
    fn nothing_fits() {
        let p = pool();
        let err = p.best_under(100).unwrap_err();
        assert!(matches!(err, PlanError::NothingFits { smallest: 300, .. }));
        let empty = DeploymentPlanner::new();
        assert!(matches!(empty.best_under(100).unwrap_err(), PlanError::Empty));
    }

    #[test]
    fn tie_breaks_to_smaller() {
        let mut p = DeploymentPlanner::new();
        p.add_candidate(card("big", 0.9, 2000));
        p.add_candidate(card("small", 0.9, 500));
        assert_eq!(p.best_under(10_000).unwrap().id, "small");
    }

    #[test]
    fn deploy_respects_device_budget() {
        // Use real encoded blobs: deployment validates them.
        use crate::data::synth::PaperDataset;
        use crate::gbdt::{self, GbdtParams};
        use crate::layout::{encode, EncodeOptions, FeatureInfo};
        let data =
            PaperDataset::BreastCancer.generate(77).select(&(0..250).collect::<Vec<_>>());
        let finfo = FeatureInfo::from_dataset(&data);
        let mut p = DeploymentPlanner::new();
        for (id, rounds, score) in [("small", 4usize, 0.9), ("large", 64, 0.95)] {
            let m = gbdt::booster::train(&data, GbdtParams::paper(rounds, 2));
            let blob = encode(&m, &finfo, &EncodeOptions::default()).unwrap();
            p.add_candidate(ModelCard { id: id.into(), score, size_bytes: blob.len(), blob });
        }
        let small_size = p.candidates()[0].size_bytes;
        let mut dev = super::super::device::SimulatedDevice::new(0, DeviceKind::TinyNode)
            .with_budget(small_size + 16); // only `small` fits
        let id = p.deploy_to(&mut dev).unwrap();
        assert_eq!(id, "small");
        assert!(dev.model_size().unwrap() <= dev.budget_bytes);
    }

    #[test]
    fn deploy_corrupt_candidate_surfaces_error() {
        let mut p = DeploymentPlanner::new();
        p.add_candidate(card("junk", 0.9, 64)); // zero-filled, invalid blob
        let mut dev = super::super::device::SimulatedDevice::new(1, DeviceKind::UnoR4);
        let err = p.deploy_to(&mut dev).unwrap_err();
        assert!(matches!(err, PlanError::DeployFailed { .. }), "{err}");
    }

    #[test]
    fn replan_publishes_only_upgrades() {
        use crate::coordinator::registry::ModelRegistry;
        use crate::data::synth::PaperDataset;
        use crate::gbdt::{self, GbdtParams};
        use crate::layout::{encode, EncodeOptions, FeatureInfo};
        let data = PaperDataset::BreastCancer.generate(79).select(&(0..250).collect::<Vec<_>>());
        let finfo = FeatureInfo::from_dataset(&data);
        let mut p = DeploymentPlanner::new();
        for (id, rounds, score) in [("small", 4usize, 0.90), ("large", 32, 0.95)] {
            let m = gbdt::booster::train(&data, GbdtParams::paper(rounds, 2));
            let blob = encode(&m, &finfo, &EncodeOptions::default()).unwrap();
            p.add_candidate(ModelCard { id: id.into(), score, size_bytes: blob.len(), blob });
        }
        let reg = ModelRegistry::new();
        let small_size = p.candidates()[0].size_bytes;

        // Budget admits only `small`: the first replan publishes it.
        let d1 = p.replan(&reg, "bc", small_size + 8).unwrap().unwrap();
        assert_eq!(d1.card.id, "small");
        assert_eq!(reg.version_of("bc"), Some(d1.version));
        // Same budget again: what's serving is already the best fit.
        assert!(p.replan(&reg, "bc", small_size + 8).unwrap().is_none());
        // A bigger budget admits `large` (higher score): hot upgrade.
        let d2 = p.replan(&reg, "bc", usize::MAX).unwrap().unwrap();
        assert_eq!(d2.card.id, "large");
        assert!(d2.version > d1.version, "upgrades must move the version forward");
        // The published engine decodes from the blob and serves.
        assert!(d2.engine.predict_raw(&data.row(0))[0].is_finite());
        // Nothing fits → the planner error propagates, nothing changes.
        assert!(matches!(p.replan(&reg, "bc", 1), Err(PlanError::NothingFits { .. })));
        assert_eq!(reg.version_of("bc"), Some(d2.version));
    }

    #[test]
    fn replan_classes_shares_one_model_across_tolerances() {
        use crate::coordinator::registry::ModelRegistry;
        use crate::data::synth::PaperDataset;
        use crate::gbdt::{self, GbdtParams};
        use crate::layout::{encode, EncodeOptions, FeatureInfo};
        let data = PaperDataset::BreastCancer.generate(85).select(&(0..250).collect::<Vec<_>>());
        let finfo = FeatureInfo::from_dataset(&data);
        let mut p = DeploymentPlanner::new();
        let m = gbdt::booster::train(&data, GbdtParams::paper(8, 2));
        let blob = encode(&m, &finfo, &EncodeOptions::default()).unwrap();
        p.add_candidate(ModelCard { id: "m".into(), score: 0.9, size_bytes: blob.len(), blob });

        let reg = ModelRegistry::new();
        let classes = [
            ClassAssignment { class: "sensor".into(), policy: AdaptivePolicy::Margin(0.05) },
            ClassAssignment { class: "hub".into(), policy: AdaptivePolicy::Exact },
        ];
        let (dep, gateways) = p.replan_classes(&reg, "bc", usize::MAX, &classes).unwrap();
        assert_eq!(dep.card.id, "m");
        assert_eq!(gateways.len(), 2);
        assert_eq!(gateways[0].0, "sensor");
        assert_eq!(gateways[0].1.policy, AdaptivePolicy::Margin(0.05));
        assert_eq!(gateways[1].0, "hub");
        assert_eq!(gateways[1].1.policy, AdaptivePolicy::Exact);
        // Classes share one deployment: a second plan with the same
        // budget reuses the incumbent instead of republishing.
        let (dep2, _) = p.replan_classes(&reg, "bc", usize::MAX, &classes).unwrap();
        assert_eq!(dep2.version, dep.version, "no spurious republish");
    }

    #[test]
    fn replan_corrupt_winner_errors_instead_of_panicking() {
        use crate::coordinator::registry::ModelRegistry;
        let mut p = DeploymentPlanner::new();
        p.add_candidate(card("junk", 0.99, 64)); // zero-filled blob
        let reg = ModelRegistry::new();
        let err = p.replan(&reg, "bc", 1024).unwrap_err();
        assert!(matches!(err, PlanError::DeployFailed { .. }), "{err}");
        assert!(reg.current("bc").is_none(), "nothing may be published on failure");
    }

    #[test]
    fn pareto_frontier_is_monotone() {
        let mut p = pool();
        p.add_candidate(card("dominated", 0.70, 5_000)); // worse & bigger than medium
        let front = p.pareto_frontier();
        let ids: Vec<&str> = front.iter().map(|c| c.id.as_str()).collect();
        assert_eq!(ids, vec!["tiny", "small", "medium", "large"]);
        for w in front.windows(2) {
            assert!(w[1].score > w[0].score && w[1].size_bytes > w[0].size_bytes);
        }
    }
}
