//! Versioned model registry: immutable deployment artifacts with
//! atomic hot-swap.
//!
//! The paper's deployment protocol (Fig. 4: "the best-performing model
//! with memory ≤ the limit") only pays off at fleet scale if a
//! better-fitting model can replace a live one without draining
//! traffic. The registry makes that a data-structure property instead
//! of a coordination protocol:
//!
//! * A [`DeployedModel`] is **immutable**: the decoded serving engine,
//!   the packed ToaD blob it was built from, the sweep's [`ModelCard`]
//!   metadata, and a version number, bundled once at publish time and
//!   never mutated afterwards.
//! * The [`ModelRegistry`] maps model keys to `Arc<DeployedModel>`
//!   behind a [`RwLock`]. Readers ([`ModelRegistry::current`]) take the
//!   read lock just long enough to clone the `Arc` — a swap in progress
//!   never blocks them behind model decoding, and a reader holding a
//!   deployment keeps it alive for as long as its batch needs it.
//! * [`ModelRegistry::publish`] installs a new version atomically:
//!   every request flushed after the swap sees the new deployment;
//!   batches already in flight finish on the `Arc` they cloned — the
//!   version they started with. Nothing is torn, dropped, or blocked.
//!
//! Versions are monotonic across the whole registry (a global counter),
//! so "newer" is well-defined even across keys and re-publishes.

use super::planner::ModelCard;
use crate::inference::QuantizedFlatModel;
use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::{Arc, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::collections::HashMap;

/// One immutable serving artifact: engine + blob + metadata + version.
///
/// The engine is the quantized-threshold flat model (the batch serving
/// engine); the blob is the packed ToaD encoding the planner selected —
/// kept alongside so a device deployment and the gateway serve the same
/// artifact.
#[derive(Debug)]
pub struct DeployedModel {
    /// Registry-wide monotonic version, assigned at publish time.
    pub version: u64,
    /// Sweep metadata (id, score, size) plus the packed ToaD blob.
    pub card: ModelCard,
    /// The decoded batch-serving engine.
    pub engine: QuantizedFlatModel,
}

impl DeployedModel {
    /// The packed ToaD blob this deployment was built from.
    pub fn blob(&self) -> &[u8] {
        &self.card.blob
    }
}

/// Versioned key → deployment map with atomic hot-swap.
#[derive(Debug, Default)]
pub struct ModelRegistry {
    deployments: RwLock<HashMap<String, Arc<DeployedModel>>>,
    /// Next version to assign; versions start at 1 so 0 can mean
    /// "static deployment, not registry-managed" in metrics.
    next_version: AtomicU64,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry { deployments: RwLock::new(HashMap::new()), next_version: AtomicU64::new(0) }
    }

    /// Publish a new deployment for `key`, returning the installed
    /// artifact. The swap is atomic: concurrent [`ModelRegistry::current`]
    /// calls see either the previous deployment or this one, never a
    /// partial state. In-flight batches holding the previous `Arc`
    /// finish on it undisturbed.
    pub fn publish(
        &self,
        key: &str,
        card: ModelCard,
        engine: QuantizedFlatModel,
    ) -> Arc<DeployedModel> {
        // Assign the version while holding the write lock: two racing
        // publishes to the same key are thereby serialized, so the one
        // installed last always carries the higher version and the live
        // version per key never regresses. (Assigning before locking
        // allowed thread A to draw version v, lose the lock race to
        // thread B's v+1, and then overwrite B — leaving the older
        // deployment live.)
        let mut map = self.write();
        // Relaxed: the write lock (not this atomic) serializes racing
        // publishes and publishes the map — the counter only needs
        // atomicity so lock-free `latest_version` readers see whole
        // values. Monotonicity per key follows from assignment inside
        // the critical section (loom-verified:
        // `loom_registry_publish_versions_are_monotonic_per_key`).
        let version = self.next_version.fetch_add(1, Ordering::Relaxed) + 1;
        let dep = Arc::new(DeployedModel { version, card, engine });
        map.insert(key.to_string(), Arc::clone(&dep));
        dep
    }

    /// The live deployment for `key`, if any. Clones the `Arc` under a
    /// briefly-held read lock — never blocks behind engine construction.
    pub fn current(&self, key: &str) -> Option<Arc<DeployedModel>> {
        self.read().get(key).cloned()
    }

    /// Remove `key` from service. Requests flushed afterwards fail
    /// ("no model deployed"); batches already holding the `Arc` finish
    /// normally. Returns the retired deployment.
    pub fn retire(&self, key: &str) -> Option<Arc<DeployedModel>> {
        self.write().remove(key)
    }

    /// The live version for `key`, if any.
    pub fn version_of(&self, key: &str) -> Option<u64> {
        self.read().get(key).map(|d| d.version)
    }

    /// Keys with a live deployment.
    pub fn keys(&self) -> Vec<String> {
        self.read().keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.read().len()
    }

    pub fn is_empty(&self) -> bool {
        self.read().is_empty()
    }

    /// Highest version assigned so far (0 = nothing ever published).
    pub fn latest_version(&self) -> u64 {
        // Relaxed: monotonic counter read for monitoring — the value
        // stands alone, no non-atomic data rides on it.
        self.next_version.load(Ordering::Relaxed)
    }

    fn read(&self) -> RwLockReadGuard<'_, HashMap<String, Arc<DeployedModel>>> {
        // A poisoned lock means a panic elsewhere; the map itself is
        // always in a consistent state (single-call inserts/removes),
        // so serving continues rather than cascading the panic.
        self.deployments.read().unwrap_or_else(|e| e.into_inner())
    }

    fn write(&self) -> RwLockWriteGuard<'_, HashMap<String, Arc<DeployedModel>>> {
        self.deployments.write().unwrap_or_else(|e| e.into_inner())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::PaperDataset;
    use crate::gbdt::{self, GbdtParams};
    use crate::layout::{encode, EncodeOptions, FeatureInfo};

    fn deployment(seed: u64, rounds: usize, score: f64) -> (ModelCard, QuantizedFlatModel) {
        let data = PaperDataset::BreastCancer.generate(seed).select(&(0..200).collect::<Vec<_>>());
        let model = gbdt::booster::train(&data, GbdtParams::paper(rounds, 2));
        let finfo = FeatureInfo::from_dataset(&data);
        let blob = encode(&model, &finfo, &EncodeOptions::default()).unwrap();
        let card = ModelCard { id: format!("m{rounds}"), score, size_bytes: blob.len(), blob };
        (card, model.quantize())
    }

    #[test]
    #[cfg_attr(miri, ignore)] // trains a real model — minutes under Miri
    fn publish_assigns_monotonic_versions() {
        let reg = ModelRegistry::new();
        assert!(reg.current("a").is_none());
        assert_eq!(reg.latest_version(), 0);
        let (c1, e1) = deployment(1, 2, 0.8);
        let (c2, e2) = deployment(2, 4, 0.9);
        let d1 = reg.publish("a", c1, e1);
        let d2 = reg.publish("a", c2, e2);
        assert!(d2.version > d1.version, "versions must be monotonic");
        assert_eq!(reg.version_of("a"), Some(d2.version));
        assert_eq!(reg.current("a").unwrap().card.id, d2.card.id);
        assert_eq!(reg.latest_version(), d2.version);
        assert_eq!(reg.len(), 1);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // trains a real model — minutes under Miri
    fn inflight_arc_survives_swap_and_retire() {
        let reg = ModelRegistry::new();
        let (c1, e1) = deployment(3, 2, 0.8);
        reg.publish("a", c1, e1);
        // An "in-flight batch" holds the deployment across a swap.
        let held = reg.current("a").unwrap();
        let v1 = held.version;
        let (c2, e2) = deployment(4, 4, 0.9);
        reg.publish("a", c2, e2);
        assert_eq!(held.version, v1, "held deployment must be immutable");
        assert!(held.engine.n_outputs() >= 1);
        let retired = reg.retire("a").unwrap();
        assert!(retired.version > v1);
        assert!(reg.current("a").is_none(), "retired key no longer serves");
        // The held Arc still predicts after retire: in-flight work
        // finishes on the version it started with.
        assert_eq!(held.engine.predict_raw(&[0.0; 30]).len(), 1);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // trains a real model — minutes under Miri
    fn keys_are_independent() {
        let reg = ModelRegistry::new();
        let (c1, e1) = deployment(5, 2, 0.8);
        let (c2, e2) = deployment(6, 2, 0.8);
        reg.publish("a", c1, e1);
        reg.publish("b", c2, e2);
        let mut keys = reg.keys();
        keys.sort();
        assert_eq!(keys, vec!["a", "b"]);
        reg.retire("a");
        assert!(reg.current("b").is_some());
        assert_eq!(reg.len(), 1);
    }
}
