//! Request routing: model key → deployment target(s).
//!
//! Deployments are either on-device (a simulated node runs the packed
//! model locally) or gateway-side (a [`super::batcher::Batcher`] over a
//! batched engine, possibly registry-backed for hot-swap). The router
//! resolves a model key to a target and round-robins across replicas
//! on a relaxed atomic counter — [`Router::route`] takes `&self` and
//! is called concurrently from every serving thread with no lock and
//! no contention beyond the counter itself. Routes are registered
//! during server setup (`&mut self`) and immutable while serving.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};

/// An opaque deployment target id registered with the router.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TargetId(pub usize);

/// Maps model keys to deployment targets with round-robin replica
/// selection.
#[derive(Default)]
pub struct Router {
    routes: HashMap<String, Vec<TargetId>>,
    counters: HashMap<String, AtomicUsize>,
}

impl Router {
    pub fn new() -> Router {
        Router::default()
    }

    /// Register a replica target for a model key.
    pub fn add_route(&mut self, model: &str, target: TargetId) {
        self.routes.entry(model.to_string()).or_default().push(target);
        self.counters.entry(model.to_string()).or_insert_with(|| AtomicUsize::new(0));
    }

    pub fn models(&self) -> Vec<&str> {
        self.routes.keys().map(|s| s.as_str()).collect()
    }

    pub fn replicas(&self, model: &str) -> usize {
        self.routes.get(model).map_or(0, |v| v.len())
    }

    /// Next target for a model (round-robin), if any replica exists.
    pub fn route(&self, model: &str) -> Option<TargetId> {
        let targets = self.routes.get(model)?;
        if targets.is_empty() {
            return None;
        }
        let c = self.counters.get(model)?;
        let i = c.fetch_add(1, Ordering::Relaxed);
        Some(targets[i % targets.len()])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_over_replicas() {
        let mut r = Router::new();
        r.add_route("m", TargetId(10));
        r.add_route("m", TargetId(11));
        r.add_route("m", TargetId(12));
        let picks: Vec<usize> = (0..6).map(|_| r.route("m").unwrap().0).collect();
        assert_eq!(picks, vec![10, 11, 12, 10, 11, 12]);
    }

    #[test]
    fn concurrent_routing_balances_replicas() {
        // 4 threads × 300 routes over 3 replicas: the atomic counter
        // must hand out every pick exactly once, so the replica counts
        // sum to 1200 and are perfectly balanced (each counter value in
        // 0..1200 maps to exactly one replica).
        let mut r = Router::new();
        for t in 0..3 {
            r.add_route("m", TargetId(t));
        }
        let counts = std::sync::Mutex::new([0usize; 3]);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    let mut local = [0usize; 3];
                    for _ in 0..300 {
                        local[r.route("m").unwrap().0] += 1;
                    }
                    let mut c = counts.lock().unwrap();
                    for (a, b) in c.iter_mut().zip(local) {
                        *a += b;
                    }
                });
            }
        });
        let c = counts.into_inner().unwrap();
        assert_eq!(c.iter().sum::<usize>(), 1200);
        assert_eq!(c, [400, 400, 400], "round-robin must balance exactly");
    }

    #[test]
    fn unknown_model_is_none() {
        let r = Router::new();
        assert!(r.route("nope").is_none());
    }

    #[test]
    fn models_and_replicas() {
        let mut r = Router::new();
        r.add_route("a", TargetId(0));
        r.add_route("b", TargetId(1));
        r.add_route("b", TargetId(2));
        assert_eq!(r.replicas("a"), 1);
        assert_eq!(r.replicas("b"), 2);
        assert_eq!(r.replicas("c"), 0);
        let mut models = r.models();
        models.sort_unstable();
        assert_eq!(models, vec!["a", "b"]);
    }
}
