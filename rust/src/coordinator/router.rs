//! Request routing: model key → deployment target(s).
//!
//! Deployments are either on-device (a simulated node runs the packed
//! model locally) or gateway-side (a [`super::batcher::Batcher`] feeding
//! the XLA engine). The router resolves a model key to a target and
//! round-robins across replicas.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};

/// An opaque deployment target id registered with the router.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TargetId(pub usize);

/// Maps model keys to deployment targets with round-robin replica
/// selection.
#[derive(Default)]
pub struct Router {
    routes: HashMap<String, Vec<TargetId>>,
    counters: HashMap<String, AtomicUsize>,
}

impl Router {
    pub fn new() -> Router {
        Router::default()
    }

    /// Register a replica target for a model key.
    pub fn add_route(&mut self, model: &str, target: TargetId) {
        self.routes.entry(model.to_string()).or_default().push(target);
        self.counters.entry(model.to_string()).or_insert_with(|| AtomicUsize::new(0));
    }

    pub fn models(&self) -> Vec<&str> {
        self.routes.keys().map(|s| s.as_str()).collect()
    }

    pub fn replicas(&self, model: &str) -> usize {
        self.routes.get(model).map_or(0, |v| v.len())
    }

    /// Next target for a model (round-robin), if any replica exists.
    pub fn route(&self, model: &str) -> Option<TargetId> {
        let targets = self.routes.get(model)?;
        if targets.is_empty() {
            return None;
        }
        let c = self.counters.get(model)?;
        let i = c.fetch_add(1, Ordering::Relaxed);
        Some(targets[i % targets.len()])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_over_replicas() {
        let mut r = Router::new();
        r.add_route("m", TargetId(10));
        r.add_route("m", TargetId(11));
        r.add_route("m", TargetId(12));
        let picks: Vec<usize> = (0..6).map(|_| r.route("m").unwrap().0).collect();
        assert_eq!(picks, vec![10, 11, 12, 10, 11, 12]);
    }

    #[test]
    fn unknown_model_is_none() {
        let r = Router::new();
        assert!(r.route("nope").is_none());
    }

    #[test]
    fn models_and_replicas() {
        let mut r = Router::new();
        r.add_route("a", TargetId(0));
        r.add_route("b", TargetId(1));
        r.add_route("b", TargetId(2));
        assert_eq!(r.replicas("a"), 1);
        assert_eq!(r.replicas("b"), 2);
        assert_eq!(r.replicas("c"), 0);
        let mut models = r.models();
        models.sort_unstable();
        assert_eq!(models, vec!["a", "b"]);
    }
}
