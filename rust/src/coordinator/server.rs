//! The fleet front door: devices + gateway batchers behind one API.
//!
//! A [`FleetServer`] owns simulated devices (on-device inference) and
//! gateway batchers (XLA-backed batched inference), a [`Router`] mapping
//! model keys to them, and a latency recorder per model. This is the
//! component the end-to-end example (`examples/iot_fleet.rs`) drives.

use super::batcher::Batcher;
use super::device::SimulatedDevice;
use super::metrics::LatencyRecorder;
use super::router::{Router, TargetId};
use crate::anyhow;
use crate::error::Result;
use std::collections::HashMap;
use std::time::Instant;

enum Target {
    Device(SimulatedDevice),
    Gateway(Batcher),
}

/// Fleet coordinator: routes rows to deployments and records latency.
pub struct FleetServer {
    targets: Vec<Target>,
    router: Router,
    metrics: HashMap<String, LatencyRecorder>,
}

impl Default for FleetServer {
    fn default() -> Self {
        Self::new()
    }
}

impl FleetServer {
    pub fn new() -> FleetServer {
        FleetServer { targets: Vec::new(), router: Router::new(), metrics: HashMap::new() }
    }

    /// Register an on-device deployment for `model`.
    pub fn add_device(&mut self, model: &str, device: SimulatedDevice) -> TargetId {
        let id = TargetId(self.targets.len());
        self.targets.push(Target::Device(device));
        self.router.add_route(model, id);
        self.metrics.entry(model.to_string()).or_default();
        id
    }

    /// Register a gateway batcher for `model`.
    pub fn add_gateway(&mut self, model: &str, batcher: Batcher) -> TargetId {
        let id = TargetId(self.targets.len());
        self.targets.push(Target::Gateway(batcher));
        self.router.add_route(model, id);
        self.metrics.entry(model.to_string()).or_default();
        id
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Serve one request synchronously; records wall-clock latency.
    pub fn predict(&mut self, model: &str, row: Vec<f32>) -> Result<Vec<f64>> {
        let target = self.router.route(model).ok_or_else(|| anyhow!("no route for {model}"))?;
        let start = Instant::now();
        let out = match &mut self.targets[target.0] {
            Target::Device(dev) => dev.predict(&row).map_err(|e| anyhow!(e))?,
            Target::Gateway(b) => b.predict(row),
        };
        self.metrics.get_mut(model).unwrap().record(start.elapsed());
        Ok(out)
    }

    pub fn metrics(&self, model: &str) -> Option<&LatencyRecorder> {
        self.metrics.get(model)
    }

    /// Sum of simulated on-device busy seconds across the fleet.
    pub fn fleet_sim_busy_seconds(&self) -> f64 {
        self.targets
            .iter()
            .map(|t| match t {
                Target::Device(d) => d.sim_busy_seconds(),
                Target::Gateway(_) => 0.0,
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::{Backend, BatcherConfig};
    use crate::coordinator::device::DeviceKind;
    use crate::data::synth::PaperDataset;
    use crate::gbdt::{self, GbdtParams};
    use crate::layout::{encode, EncodeOptions, FeatureInfo};

    #[test]
    fn device_and_gateway_routes_agree() {
        let data = PaperDataset::BreastCancer.generate(81).select(&(0..300).collect::<Vec<_>>());
        let model = gbdt::booster::train(&data, GbdtParams::paper(8, 2));
        let finfo = FeatureInfo::from_dataset(&data);
        let blob = encode(&model, &finfo, &EncodeOptions { allow_f16: false, ..Default::default() })
            .unwrap();

        let mut server = FleetServer::new();
        let mut dev = SimulatedDevice::new(0, DeviceKind::UnoR4);
        dev.deploy(blob).unwrap();
        server.add_device("bc", dev);
        server.add_gateway(
            "bc",
            Batcher::spawn(
                BatcherConfig { max_batch: 4, max_wait: std::time::Duration::from_millis(1) },
                Backend::Native(model.flatten()),
            ),
        );

        // Round-robin alternates device / gateway; both must agree with
        // the source model.
        for i in 0..10 {
            let row = data.row(i);
            let want = model.predict_raw(&row)[0];
            let got = server.predict("bc", row).unwrap();
            assert!((got[0] - want).abs() < 1e-4, "req {i}");
        }
        let m = server.metrics("bc").unwrap();
        assert_eq!(m.count(), 10);
        assert!(server.fleet_sim_busy_seconds() > 0.0);
    }

    #[test]
    fn unknown_model_errors() {
        let mut server = FleetServer::new();
        assert!(server.predict("ghost", vec![0.0]).is_err());
    }
}
