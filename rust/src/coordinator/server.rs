//! The fleet front door: devices + gateway batchers behind one API.
//!
//! A [`FleetServer`] owns simulated devices (on-device inference),
//! gateway batchers (batched engine inference), a [`Router`] mapping
//! model keys to them, a shared [`ModelRegistry`] for hot-swappable
//! gateway deployments, and a latency recorder per model.
//!
//! Serving is concurrent: [`FleetServer::submit`] and
//! [`FleetServer::predict`] take `&self` and the server is
//! `Send + Sync`, so any number of threads drive one server (the
//! stress test in `tests/serving_concurrency.rs` and the hot-swap demo
//! in `examples/iot_fleet.rs` both do). Registration (`add_device`,
//! `add_gateway`) is the setup phase and keeps `&mut self`.

use super::batcher::{BatchReply, Batcher, BatcherConfig, SubmitError};
use super::device::SimulatedDevice;
use super::metrics::LatencyRecorder;
use super::registry::ModelRegistry;
use super::router::{Router, TargetId};
use crate::anyhow;
use crate::error::Result;
use std::collections::HashMap;
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

enum Target {
    /// Devices mutate per-prediction state (MCU time accounting), so
    /// each gets its own lock; different replicas serve in parallel.
    Device(Mutex<SimulatedDevice>),
    Gateway(Batcher),
}

/// Fleet coordinator: routes rows to deployments and records latency.
/// Shareable across serving threads (`&self` end-to-end).
pub struct FleetServer {
    targets: Vec<Target>,
    router: Router,
    registry: Arc<ModelRegistry>,
    metrics: HashMap<String, LatencyRecorder>,
}

/// An in-flight request: resolve with [`Ticket::wait`] to get the
/// scores + serving version and record the request's latency.
pub struct Ticket<'a> {
    inner: TicketInner,
    recorder: &'a LatencyRecorder,
    start: Instant,
}

enum TicketInner {
    /// Device predictions complete synchronously at submit time.
    Ready(BatchReply),
    /// Gateway predictions resolve when the worker flushes the batch.
    Pending(Receiver<BatchReply>),
}

impl Ticket<'_> {
    /// Block until the reply is ready; records latency on completion.
    pub fn wait(self) -> Result<BatchReply> {
        let reply = match self.inner {
            TicketInner::Ready(r) => r,
            TicketInner::Pending(rx) => rx
                .recv()
                .map_err(|_| anyhow!("model retired or gateway shut down mid-flight"))?,
        };
        self.recorder.record_version(self.start.elapsed(), reply.version);
        Ok(reply)
    }
}

impl Default for FleetServer {
    fn default() -> Self {
        Self::new()
    }
}

impl FleetServer {
    pub fn new() -> FleetServer {
        FleetServer::with_registry(Arc::new(ModelRegistry::new()))
    }

    /// Build a server around an existing (possibly shared) registry —
    /// e.g. one a planner publishes into.
    pub fn with_registry(registry: Arc<ModelRegistry>) -> FleetServer {
        FleetServer {
            targets: Vec::new(),
            router: Router::new(),
            registry,
            metrics: HashMap::new(),
        }
    }

    /// The registry backing this server's hot-swappable gateways.
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// Register an on-device deployment for `model`.
    pub fn add_device(&mut self, model: &str, device: SimulatedDevice) -> TargetId {
        let id = TargetId(self.targets.len());
        self.targets.push(Target::Device(Mutex::new(device)));
        self.router.add_route(model, id);
        self.metrics.entry(model.to_string()).or_default();
        id
    }

    /// Register a gateway batcher for `model`.
    pub fn add_gateway(&mut self, model: &str, batcher: Batcher) -> TargetId {
        let id = TargetId(self.targets.len());
        self.targets.push(Target::Gateway(batcher));
        self.router.add_route(model, id);
        self.metrics.entry(model.to_string()).or_default();
        id
    }

    /// Register a hot-swappable gateway: a batcher that resolves
    /// `model` in this server's registry at every flush, so a
    /// [`ModelRegistry::publish`] swaps the serving engine mid-traffic.
    pub fn add_registry_gateway(&mut self, model: &str, config: BatcherConfig) -> TargetId {
        let backend = super::batcher::Backend::Registry {
            registry: Arc::clone(&self.registry),
            key: model.to_string(),
        };
        self.add_gateway(model, Batcher::spawn(config, backend))
    }

    /// Register one hot-swappable gateway per device class, every class
    /// resolving the *same* registry key but applying its own
    /// [`BatcherConfig`] — in particular its own adaptive exit
    /// tolerance ([`BatcherConfig::policy`]). Requests route per class
    /// under the key `"{model}@{class}"`, with a latency recorder per
    /// class, while a single [`ModelRegistry::publish`] on `model`
    /// hot-swaps all of them at once.
    ///
    /// This is the serving half of
    /// [`DeploymentPlanner::replan_classes`](
    /// super::planner::DeploymentPlanner::replan_classes): plan one
    /// model under the budget, then serve it to heterogeneous device
    /// classes at per-class accuracy/latency points.
    pub fn add_class_gateways(
        &mut self,
        model: &str,
        classes: &[(String, BatcherConfig)],
    ) -> Vec<TargetId> {
        classes
            .iter()
            .map(|(class, config)| {
                let backend = super::batcher::Backend::Registry {
                    registry: Arc::clone(&self.registry),
                    key: model.to_string(),
                };
                let route = format!("{model}@{class}");
                self.add_gateway(&route, Batcher::spawn(*config, backend))
            })
            .collect()
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Route one request and start serving it. Returns a [`Ticket`]
    /// immediately; gateway backpressure surfaces as
    /// [`SubmitError::Overloaded`] here, before any work is queued.
    pub fn submit(
        &self,
        model: &str,
        row: Vec<f32>,
    ) -> std::result::Result<Ticket<'_>, SubmitError> {
        let target = self.router.route(model).ok_or(SubmitError::NoRoute)?;
        let recorder = self.metrics.get(model).expect("route implies recorder");
        let start = Instant::now();
        let inner = match &self.targets[target.0] {
            Target::Device(dev) => {
                let mut d = lock(dev);
                let scores = d.predict(&row).map_err(|_| SubmitError::NoModel)?;
                // On-device descent always walks the whole ensemble.
                let trees_evaluated = d.model_trees().unwrap_or(0) as u32;
                TicketInner::Ready(BatchReply { scores, version: 0, trees_evaluated })
            }
            Target::Gateway(b) => TicketInner::Pending(b.submit(row)?),
        };
        Ok(Ticket { inner, recorder, start })
    }

    /// Serve one request synchronously; records wall-clock latency.
    pub fn predict(&self, model: &str, row: Vec<f32>) -> Result<Vec<f64>> {
        let ticket = self.submit(model, row).map_err(|e| anyhow!("{model}: {e}"))?;
        Ok(ticket.wait()?.scores)
    }

    pub fn metrics(&self, model: &str) -> Option<&LatencyRecorder> {
        self.metrics.get(model)
    }

    /// Sum of simulated on-device busy seconds across the fleet.
    pub fn fleet_sim_busy_seconds(&self) -> f64 {
        self.targets
            .iter()
            .map(|t| match t {
                Target::Device(d) => lock(d).sim_busy_seconds(),
                Target::Gateway(_) => 0.0,
            })
            .sum()
    }
}

fn lock(dev: &Mutex<SimulatedDevice>) -> MutexGuard<'_, SimulatedDevice> {
    dev.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
#[cfg(not(miri))] // trains models / generates datasets - too slow under the Miri interpreter
mod tests {
    use super::*;
    use crate::coordinator::batcher::{Backend, BatcherConfig};
    use crate::coordinator::device::DeviceKind;
    use crate::coordinator::planner::ModelCard;
    use crate::data::synth::PaperDataset;
    use crate::gbdt::{self, GbdtParams};
    use crate::layout::{encode, EncodeOptions, FeatureInfo};

    fn assert_server_is_send_sync() {
        fn check<T: Send + Sync>() {}
        check::<FleetServer>();
        check::<Batcher>();
        check::<ModelRegistry>();
        check::<LatencyRecorder>();
    }

    #[test]
    fn server_types_are_send_sync() {
        assert_server_is_send_sync();
    }

    #[test]
    fn device_and_gateway_routes_agree() {
        let data = PaperDataset::BreastCancer.generate(81).select(&(0..300).collect::<Vec<_>>());
        let model = gbdt::booster::train(&data, GbdtParams::paper(8, 2));
        let finfo = FeatureInfo::from_dataset(&data);
        let blob = encode(&model, &finfo, &EncodeOptions { allow_f16: false, ..Default::default() })
            .unwrap();

        let mut server = FleetServer::new();
        let mut dev = SimulatedDevice::new(0, DeviceKind::UnoR4);
        dev.deploy(blob).unwrap();
        server.add_device("bc", dev);
        server.add_gateway(
            "bc",
            Batcher::spawn(
                BatcherConfig {
                    max_batch: 4,
                    max_wait: std::time::Duration::from_millis(1),
                    queue_depth: 64,
                    ..Default::default()
                },
                Backend::Native(model.flatten()),
            ),
        );

        // Round-robin alternates device / gateway; both must agree with
        // the source model.
        for i in 0..10 {
            let row = data.row(i);
            let want = model.predict_raw(&row)[0];
            let got = server.predict("bc", row).unwrap();
            assert!((got[0] - want).abs() < 1e-4, "req {i}");
        }
        let m = server.metrics("bc").unwrap();
        assert_eq!(m.count(), 10);
        assert!(server.fleet_sim_busy_seconds() > 0.0);
    }

    #[test]
    fn unknown_model_errors() {
        let server = FleetServer::new();
        assert!(server.predict("ghost", vec![0.0]).is_err());
        assert_eq!(server.submit("ghost", vec![0.0]).err(), Some(SubmitError::NoRoute));
    }

    #[test]
    fn class_gateways_serve_one_model_at_distinct_tolerances() {
        use crate::inference::AdaptivePolicy;
        // One published model, two device classes: the `hub` class runs
        // Exact (full depth, bit-exact scores), the `sensor` class runs
        // a Margin tolerance (may exit early, never flips the class).
        let data = PaperDataset::Mushroom.generate(87).select(&(0..300).collect::<Vec<_>>());
        let model = gbdt::booster::train(&data, GbdtParams::paper(8, 2));
        let n_trees = model.n_trees() as u32;

        let mut server = FleetServer::new();
        let gateway = |policy| BatcherConfig {
            max_batch: 4,
            max_wait: std::time::Duration::from_millis(1),
            queue_depth: 64,
            policy,
        };
        server.add_class_gateways(
            "mush",
            &[
                ("sensor".to_string(), gateway(AdaptivePolicy::Margin(1e-6))),
                ("hub".to_string(), gateway(AdaptivePolicy::Exact)),
            ],
        );
        let card = ModelCard { id: "m".into(), score: 0.9, size_bytes: 1, blob: vec![] };
        server.registry().publish("mush", card, model.quantize());

        let mut sensor_trees = 0u64;
        for i in 0..20 {
            let row = data.row(i);
            let want = model.predict_raw(&row)[0];
            let hub = server.submit("mush@hub", row.clone()).unwrap().wait().unwrap();
            assert_eq!(hub.scores[0], want, "row {i}: Exact class must be bit-identical");
            assert_eq!(hub.trees_evaluated, n_trees);
            let sensor = server.submit("mush@sensor", row).unwrap().wait().unwrap();
            assert_eq!(sensor.scores[0] > 0.0, want > 0.0, "row {i}: class flipped");
            sensor_trees += u64::from(sensor.trees_evaluated);
        }
        assert!(
            sensor_trees < u64::from(n_trees) * 20,
            "Margin class never exited early on a separable task"
        );
        // Per-class latency recorders exist independently.
        assert_eq!(server.metrics("mush@hub").unwrap().count(), 20);
        assert_eq!(server.metrics("mush@sensor").unwrap().count(), 20);
    }

    #[test]
    fn registry_gateway_hot_swaps_and_counts_versions() {
        let data = PaperDataset::BreastCancer.generate(83).select(&(0..250).collect::<Vec<_>>());
        let m1 = gbdt::booster::train(&data, GbdtParams::paper(4, 2));
        let m2 = gbdt::booster::train(&data, GbdtParams::paper(8, 2));
        let card = |id: &str, score: f64| ModelCard {
            id: id.into(),
            score,
            size_bytes: 1,
            blob: vec![],
        };

        let mut server = FleetServer::new();
        server.add_registry_gateway(
            "bc",
            BatcherConfig {
                max_batch: 4,
                max_wait: std::time::Duration::from_millis(1),
                queue_depth: 64,
                ..Default::default()
            },
        );
        let d1 = server.registry().publish("bc", card("m1", 0.9), m1.quantize());
        let r1 = server.submit("bc", data.row(0)).unwrap().wait().unwrap();
        assert_eq!(r1.version, d1.version);
        assert_eq!(r1.scores, m1.predict_raw(&data.row(0)));

        let d2 = server.registry().publish("bc", card("m2", 0.95), m2.quantize());
        let r2 = server.submit("bc", data.row(0)).unwrap().wait().unwrap();
        assert_eq!(r2.version, d2.version, "publish must hot-swap the gateway");
        assert_eq!(r2.scores, m2.predict_raw(&data.row(0)));

        let counts = server.metrics("bc").unwrap().version_counts();
        assert_eq!(counts, vec![(d1.version, 1), (d2.version, 1)]);
    }
}
