//! `BinMatrix` — the single bin-code arena shared by training and serving.
//!
//! Before this module the repo carried three independent bin
//! representations: `Vec<Vec<u16>>` columns for histogram training, a
//! transient per-block re-binning buffer inside the quantized engine's
//! batch loop, and per-row `Vec<f32>` gathers in the coordinator
//! batcher. PACSET (Madhyastha et al., 2020) and LIMITS (Sliwa et al.,
//! 2020) both argue the train-time and deploy-time layouts should be
//! co-designed; this type is that co-design:
//!
//! * **One contiguous arena.** All bin codes live in a single
//!   column-major buffer (`arena[f * n_rows + i]` is feature `f` of row
//!   `i`), so a feature column is one contiguous slice — the shape the
//!   histogram kernels stream.
//! * **Adaptive width.** Storage is `u8` when *every* feature has at
//!   most [`U8_MAX_BINS`] bins (the common case: the trainer's default
//!   `max_bins = 255`), halving the training set's bin footprint and
//!   doubling the codes per cache line; otherwise `u16`. Consumers
//!   dispatch once per build via [`BinMatrix::columns`] and run
//!   monomorphized kernels — no per-access branching.
//! * **On-demand row-major mirror.** Inference descends trees row by
//!   row (random feature order), the opposite access pattern, so
//!   [`BinMatrix::to_row_major`] materializes a `u16` row-major mirror
//!   when an engine wants to bin once and descend many times (see
//!   `QuantizedFlatModel::predict_batch_columns`).
//!
//! [`crate::data::Binner`] is the sole fit/transform entry point that
//! produces training matrices (`Binner::bin_matrix` /
//! `Binner::bin_columns`); the quantized engine builds its own over the
//! model's threshold tables. Both go through [`BinMatrix::from_fn`].
//!
//! * **Mixed sparse/dense columns.** A mostly-absent feature (density
//!   below `binning::SPARSE_DENSITY_THRESHOLD`) is stored as a
//!   [`SparseBinColumn`]: the ascending present-row index list, the
//!   present entries' codes, and the feature's **default bin** — the
//!   bin the implicit value `0.0` falls in, which every absent row
//!   carries without being stored. Dense columns of the same matrix
//!   keep the contiguous arena; a per-feature slot table dispatches
//!   ([`BinMatrix::col_view`]). Every dense-only constructor produces
//!   the identity mapping, so the legacy layout (and every consumer of
//!   it) is byte-for-byte unchanged when no column is sparse.
//!
//! For datasets that do not fit in RAM, [`ChunkedBinMatrix`] stores the
//! same arena in an on-disk file split into fixed-size row blocks
//! (column-major *within* each block), and [`BinSource`] lets the
//! grower and histogram pool run off either backing store. The chunked
//! store remains dense-only (a sparse out-of-core arena is a ROADMAP
//! follow-up).

use crate::error::{Context, Result};
use std::io::Write;

/// Largest per-feature bin count representable in the `u8` arena.
pub const U8_MAX_BINS: usize = 256;

/// Borrowed view of the whole column-major arena, dispatched once per
/// kernel so the accumulation loops monomorphize over the code width.
/// Feature `f` occupies `arena[f * n_rows..(f + 1) * n_rows]`.
#[derive(Clone, Copy, Debug)]
pub enum BinColumns<'a> {
    U8(&'a [u8]),
    U16(&'a [u16]),
}

#[derive(Clone, Debug)]
enum Store {
    U8(Vec<u8>),
    U16(Vec<u16>),
}

/// One mostly-absent feature column: present entries only, plus the
/// default bin every absent row implicitly carries.
///
/// `rows` is strictly ascending (derived from an in-order CSR walk) and
/// `codes[k]` is the bin of present entry `rows[k]` — including
/// explicit zeros (which bin to `default_bin`) and NaNs (top bin),
/// stored verbatim so a sparse column reproduces the densified
/// column's codes cell for cell.
#[derive(Clone, Debug)]
pub struct SparseBinColumn {
    rows: Vec<u32>,
    codes: Vec<u16>,
    default_bin: u16,
}

impl SparseBinColumn {
    /// Number of present entries.
    pub fn nnz(&self) -> usize {
        self.rows.len()
    }

    /// The bin of the implicit value `0.0` — what every absent row
    /// reads as.
    pub fn default_bin(&self) -> u16 {
        self.default_bin
    }

    /// Ascending present-row indices.
    pub(crate) fn present_rows(&self) -> &[u32] {
        &self.rows
    }

    /// Codes of the present entries, parallel to `present_rows`.
    pub(crate) fn present_codes(&self) -> &[u16] {
        &self.codes
    }

    /// The code of row `i` (binary search; absent rows read the
    /// default bin).
    pub fn code_at(&self, i: u32) -> u16 {
        match self.rows.binary_search(&i) {
            Ok(k) => self.codes[k],
            Err(_) => self.default_bin,
        }
    }

    /// Order-preserving split of `rows` on `code <= bin` — the sparse
    /// twin of [`route_rows`]: a merge walk over the ascending leaf
    /// rows and the ascending present rows, so the emitted
    /// `left`/`right` sequences are identical to routing the densified
    /// column.
    fn route_rows(&self, bin: u16, rows: &[u32], left: &mut Vec<u32>, right: &mut Vec<u32>) {
        let mut p = 0usize;
        for &i in rows {
            while p < self.rows.len() && self.rows[p] < i {
                p += 1;
            }
            let code = if p < self.rows.len() && self.rows[p] == i {
                self.codes[p]
            } else {
                self.default_bin
            };
            if code <= bin {
                left.push(i);
            } else {
                right.push(i);
            }
        }
    }
}

/// Where feature `f`'s codes live: a dense arena slot or the sparse
/// side table.
#[derive(Clone, Copy, Debug)]
enum ColSlot {
    Dense(u32),
    Sparse(u32),
}

/// Borrowed per-feature view, dispatched by [`BinMatrix::col_view`].
#[derive(Clone, Copy, Debug)]
pub(crate) enum ColView<'a> {
    U8(&'a [u8]),
    U16(&'a [u16]),
    Sparse(&'a SparseBinColumn),
}

/// One column handed to the mixed-arena constructor.
pub(crate) enum MixedCol {
    Dense(Vec<u16>),
    Sparse { rows: Vec<u32>, codes: Vec<u16>, default_bin: u16 },
}

/// A dataset mapped to bin codes: one contiguous column-major arena
/// with adaptive u8/u16 element width. See the module docs.
#[derive(Clone, Debug)]
pub struct BinMatrix {
    n_rows: usize,
    /// `bins_per_feature[f]` bounds the codes of feature `f`
    /// (`bin(f, i) < bins_per_feature[f]`).
    bins_per_feature: Vec<usize>,
    store: Store,
    /// Per-feature dispatch. Empty means the identity dense mapping
    /// (feature `f` at arena slot `f`) — what every dense-only
    /// constructor produces.
    slots: Vec<ColSlot>,
    /// Side table of sparse columns (empty for dense-only matrices).
    sparse: Vec<SparseBinColumn>,
}

impl BinMatrix {
    /// Build a matrix by evaluating `fill(feature, row)` for every cell,
    /// feature-major (so per-feature state in `fill` stays hot). Picks
    /// the `u8` arena exactly when every feature has ≤ [`U8_MAX_BINS`]
    /// bins. Every produced code must be `< bins_per_feature[feature]`.
    pub fn from_fn(
        n_rows: usize,
        bins_per_feature: &[usize],
        mut fill: impl FnMut(usize, usize) -> u16,
    ) -> BinMatrix {
        let nf = bins_per_feature.len();
        let store = if bins_per_feature.iter().all(|&b| b <= U8_MAX_BINS) {
            let mut arena = Vec::with_capacity(n_rows * nf);
            for f in 0..nf {
                for i in 0..n_rows {
                    let code = fill(f, i);
                    debug_assert!(
                        (code as usize) < bins_per_feature[f],
                        "bin code {code} out of range for feature {f} ({} bins)",
                        bins_per_feature[f]
                    );
                    arena.push(code as u8);
                }
            }
            Store::U8(arena)
        } else {
            let mut arena = Vec::with_capacity(n_rows * nf);
            for f in 0..nf {
                for i in 0..n_rows {
                    let code = fill(f, i);
                    debug_assert!(
                        (code as usize) < bins_per_feature[f],
                        "bin code {code} out of range for feature {f} ({} bins)",
                        bins_per_feature[f]
                    );
                    arena.push(code);
                }
            }
            Store::U16(arena)
        };
        BinMatrix {
            n_rows,
            bins_per_feature: bins_per_feature.to_vec(),
            store,
            slots: Vec::new(),
            sparse: Vec::new(),
        }
    }

    /// Build a mixed matrix: dense columns are packed into the
    /// contiguous arena (in feature order), sparse columns go to the
    /// side table. The arena width rule is the same global predicate as
    /// [`BinMatrix::from_fn`] — `u8` iff *every* feature (sparse ones
    /// included) has ≤ [`U8_MAX_BINS`] bins — so `is_u8` keeps its
    /// meaning across representations. Sparse present-row lists must be
    /// strictly ascending.
    pub(crate) fn from_mixed_cols(
        n_rows: usize,
        bins_per_feature: &[usize],
        cols: Vec<MixedCol>,
    ) -> BinMatrix {
        let nf = bins_per_feature.len();
        assert_eq!(cols.len(), nf);
        let u8_arena = bins_per_feature.iter().all(|&b| b <= U8_MAX_BINS);
        let mut slots = Vec::with_capacity(nf);
        let mut sparse: Vec<SparseBinColumn> = Vec::new();
        let mut arena8: Vec<u8> = Vec::new();
        let mut arena16: Vec<u16> = Vec::new();
        let mut dense_slots = 0u32;
        for (f, col) in cols.into_iter().enumerate() {
            match col {
                MixedCol::Dense(codes) => {
                    assert_eq!(codes.len(), n_rows, "dense column {f} length mismatch");
                    debug_assert!(codes.iter().all(|&c| (c as usize) < bins_per_feature[f]));
                    slots.push(ColSlot::Dense(dense_slots));
                    dense_slots += 1;
                    if u8_arena {
                        arena8.extend(codes.iter().map(|&c| c as u8));
                    } else {
                        arena16.extend_from_slice(&codes);
                    }
                }
                MixedCol::Sparse { rows, codes, default_bin } => {
                    assert_eq!(rows.len(), codes.len(), "sparse column {f} shape mismatch");
                    debug_assert!(rows.windows(2).all(|w| w[0] < w[1]));
                    debug_assert!(rows.iter().all(|&r| (r as usize) < n_rows));
                    debug_assert!(codes
                        .iter()
                        .chain(std::iter::once(&default_bin))
                        .all(|&c| (c as usize) < bins_per_feature[f]));
                    slots.push(ColSlot::Sparse(sparse.len() as u32));
                    sparse.push(SparseBinColumn { rows, codes, default_bin });
                }
            }
        }
        let store = if u8_arena { Store::U8(arena8) } else { Store::U16(arena16) };
        BinMatrix { n_rows, bins_per_feature: bins_per_feature.to_vec(), store, slots, sparse }
    }

    /// Adopt ready-made `u16` columns (tests, hand-built fixtures). Bin
    /// counts are inferred as `max code + 1` per feature, so storage
    /// width adapts exactly as for [`BinMatrix::from_fn`].
    pub fn from_u16_columns(cols: Vec<Vec<u16>>) -> BinMatrix {
        let n_rows = cols.first().map_or(0, |c| c.len());
        for (f, c) in cols.iter().enumerate() {
            assert_eq!(c.len(), n_rows, "column {f} length mismatch");
        }
        let bins_per_feature: Vec<usize> = cols
            .iter()
            .map(|c| c.iter().copied().max().map_or(1, |m| m as usize + 1))
            .collect();
        BinMatrix::from_fn(n_rows, &bins_per_feature, |f, i| cols[f][i])
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_features(&self) -> usize {
        self.bins_per_feature.len()
    }

    /// Number of bins of feature `f` (codes are `0..n_bins(f)`).
    pub fn n_bins(&self, f: usize) -> usize {
        self.bins_per_feature[f]
    }

    pub fn bins_per_feature(&self) -> &[usize] {
        &self.bins_per_feature
    }

    /// Whether the arena stores `u8` codes (every feature fits).
    pub fn is_u8(&self) -> bool {
        matches!(self.store, Store::U8(_))
    }

    /// Arena bytes (introspection: the u8 arena halves this; sparse
    /// columns contribute their index + code storage).
    pub fn arena_bytes(&self) -> usize {
        let dense = match &self.store {
            Store::U8(a) => a.len(),
            Store::U16(a) => 2 * a.len(),
        };
        dense + self.sparse.iter().map(|s| 4 * s.rows.len() + 2 * s.codes.len()).sum::<usize>()
    }

    /// Whether any column is stored sparse (side-table dispatch).
    pub fn has_sparse(&self) -> bool {
        !self.sparse.is_empty()
    }

    /// Whether feature `f` is stored as a [`SparseBinColumn`].
    pub fn is_sparse_col(&self, f: usize) -> bool {
        matches!(self.slot(f), ColSlot::Sparse(_))
    }

    /// Number of sparse-stored columns.
    pub fn n_sparse_cols(&self) -> usize {
        self.sparse.len()
    }

    #[inline]
    fn slot(&self, f: usize) -> ColSlot {
        if self.slots.is_empty() {
            ColSlot::Dense(f as u32)
        } else {
            self.slots[f]
        }
    }

    /// Per-feature dispatched view — the entry point every
    /// sparse-aware consumer (histogram build, partition, transpose)
    /// branches on once per column.
    #[inline]
    pub(crate) fn col_view(&self, f: usize) -> ColView<'_> {
        match self.slot(f) {
            ColSlot::Dense(s) => {
                let (cs, ce) = (s as usize * self.n_rows, (s as usize + 1) * self.n_rows);
                match &self.store {
                    Store::U8(a) => ColView::U8(&a[cs..ce]),
                    Store::U16(a) => ColView::U16(&a[cs..ce]),
                }
            }
            ColSlot::Sparse(s) => ColView::Sparse(&self.sparse[s as usize]),
        }
    }

    /// Random-access lookup (baselines, per-row routing). Hot kernels
    /// should dispatch once via [`BinMatrix::columns`] (dense-only
    /// matrices) or per column via `col_view` instead. Sparse columns
    /// answer through a binary search over their present rows.
    #[inline]
    pub fn bin(&self, f: usize, i: usize) -> u16 {
        debug_assert!(i < self.n_rows);
        match self.slot(f) {
            ColSlot::Dense(s) => {
                let idx = s as usize * self.n_rows + i;
                match &self.store {
                    Store::U8(a) => a[idx] as u16,
                    Store::U16(a) => a[idx],
                }
            }
            ColSlot::Sparse(s) => self.sparse[s as usize].code_at(i as u32),
        }
    }

    /// The whole column-major arena, width-dispatched. Only meaningful
    /// for dense-only matrices — the arena of a mixed matrix holds only
    /// its dense columns, so this asserts `!has_sparse()` (mixed
    /// consumers dispatch per column via `col_view`).
    #[inline]
    pub fn columns(&self) -> BinColumns<'_> {
        assert!(!self.has_sparse(), "columns() on a mixed sparse/dense matrix");
        match &self.store {
            Store::U8(a) => BinColumns::U8(a),
            Store::U16(a) => BinColumns::U16(a),
        }
    }

    /// Materialize the row-major `u16` mirror (`out[i * n_features + f]`)
    /// — the orientation tree descent wants. Built on demand; the
    /// column arena stays the source of truth. Sparse columns fill
    /// their default bin first, then scatter the present entries.
    pub fn to_row_major(&self) -> Vec<u16> {
        let nf = self.n_features();
        let mut out = vec![0u16; self.n_rows * nf];
        if !self.has_sparse() {
            match &self.store {
                Store::U8(a) => transpose_into(a, self.n_rows, nf, &mut out),
                Store::U16(a) => transpose_into(a, self.n_rows, nf, &mut out),
            }
            return out;
        }
        for f in 0..nf {
            match self.col_view(f) {
                ColView::U8(col) => {
                    for (i, &v) in col.iter().enumerate() {
                        out[i * nf + f] = v as u16;
                    }
                }
                ColView::U16(col) => {
                    for (i, &v) in col.iter().enumerate() {
                        out[i * nf + f] = v;
                    }
                }
                ColView::Sparse(sc) => {
                    if sc.default_bin != 0 {
                        for i in 0..self.n_rows {
                            out[i * nf + f] = sc.default_bin;
                        }
                    }
                    for (k, &r) in sc.rows.iter().enumerate() {
                        out[r as usize * nf + f] = sc.codes[k];
                    }
                }
            }
        }
        out
    }

    /// Order-preserving split of `rows` on `code(feature) <= bin`,
    /// dispatched per representation — dense columns route through the
    /// arena slice exactly as before, sparse columns through the merge
    /// walk of [`SparseBinColumn::route_rows`].
    pub(crate) fn partition_col(
        &self,
        feature: usize,
        bin: u16,
        rows: &[u32],
        left: &mut Vec<u32>,
        right: &mut Vec<u32>,
    ) {
        match self.col_view(feature) {
            ColView::U8(col) => route_rows(col, bin, rows, 0, left, right),
            ColView::U16(col) => route_rows(col, bin, rows, 0, left, right),
            ColView::Sparse(sc) => sc.route_rows(bin, rows, left, right),
        }
    }

    /// Widen back to plain `u16` columns (XLA tensor staging, tests).
    pub fn to_u16_columns(&self) -> Vec<Vec<u16>> {
        (0..self.n_features())
            .map(|f| (0..self.n_rows).map(|i| self.bin(f, i)).collect())
            .collect()
    }

    /// Adopt a ready-made column-major `u8` arena (chunk loading). The
    /// caller guarantees `arena[f * n_rows + i]` layout and in-range
    /// codes; `bins_per_feature` must all fit the `u8` width so the
    /// store matches what [`BinMatrix::from_fn`] would have picked.
    pub(crate) fn from_u8_arena(
        n_rows: usize,
        bins_per_feature: &[usize],
        arena: Vec<u8>,
    ) -> BinMatrix {
        assert_eq!(arena.len(), n_rows * bins_per_feature.len());
        assert!(bins_per_feature.iter().all(|&b| b <= U8_MAX_BINS));
        BinMatrix {
            n_rows,
            bins_per_feature: bins_per_feature.to_vec(),
            store: Store::U8(arena),
            slots: Vec::new(),
            sparse: Vec::new(),
        }
    }

    /// `u16` twin of [`BinMatrix::from_u8_arena`]; requires at least one
    /// feature wider than the `u8` arena (width-choice parity).
    pub(crate) fn from_u16_arena(
        n_rows: usize,
        bins_per_feature: &[usize],
        arena: Vec<u16>,
    ) -> BinMatrix {
        assert_eq!(arena.len(), n_rows * bins_per_feature.len());
        assert!(bins_per_feature.iter().any(|&b| b > U8_MAX_BINS));
        BinMatrix {
            n_rows,
            bins_per_feature: bins_per_feature.to_vec(),
            store: Store::U16(arena),
            slots: Vec::new(),
            sparse: Vec::new(),
        }
    }
}

/// Route `rows` by comparing each row's code in `col` against the split
/// bin: `code <= bin` goes left, else right. `base` is the global row
/// id of `col[0]` (0 for a whole in-RAM column; the chunk's first row
/// for a chunk-local column). Row order is preserved, which is what
/// keeps every downstream histogram build order-identical.
#[inline]
pub(crate) fn route_rows<T: Copy>(
    col: &[T],
    bin: u16,
    rows: &[u32],
    base: u32,
    left: &mut Vec<u32>,
    right: &mut Vec<u32>,
) where
    u16: From<T>,
{
    for &i in rows {
        if u16::from(col[(i - base) as usize]) <= bin {
            left.push(i);
        } else {
            right.push(i);
        }
    }
}

// ---------------------------------------------------------------------
// On-disk chunked arena
// ---------------------------------------------------------------------

/// Magic prefix of the on-disk arena format (version 1).
pub const ARENA_MAGIC: [u8; 8] = *b"TOADBIN1";

/// Fixed-size header prefix: magic (8) + width (1) + n_rows (u64) +
/// chunk_rows (u64) + n_features (u32); followed by `n_features` u32
/// bin counts. All integers little-endian.
const ARENA_PREFIX_BYTES: u64 = 8 + 1 + 8 + 8 + 4;

/// Hard cap on the header's feature count: rejects absurd headers
/// before any allocation is sized from them (the per-feature bin table
/// alone would be `4 * n_features` bytes).
const ARENA_MAX_FEATURES: u64 = 1 << 24;

/// The same bin arena as [`BinMatrix`], backed by an on-disk file of
/// fixed-size row blocks so training memory is bounded by one block
/// (plus model state) instead of the whole matrix.
///
/// Layout: the header above, then the blocks in row order. Block `c`
/// covers global rows `c * chunk_rows .. min((c + 1) * chunk_rows,
/// n_rows)` and is stored column-major *within* the block
/// (`block[f * rows_in_block + i]`), i.e. each block is a serialized
/// [`BinMatrix`] over its rows — [`ChunkedBinMatrix::load_chunk`]
/// rehydrates exactly that. Codes are `u8` or `u16` little-endian by
/// the same width rule as the in-RAM arena.
///
/// Reads go through positional I/O (`read_exact_at`), so a shared
/// `&ChunkedBinMatrix` is usable from several worker threads at once.
#[derive(Debug)]
pub struct ChunkedBinMatrix {
    file: std::fs::File,
    n_rows: usize,
    chunk_rows: usize,
    bins_per_feature: Vec<usize>,
    /// Bytes per code: 1 (`u8` arena) or 2 (`u16`).
    width: usize,
    header_bytes: u64,
}

impl ChunkedBinMatrix {
    /// Open and fully validate an arena file. Any malformed header —
    /// bad magic, impossible width, zero block size, a bin count that
    /// contradicts the stored width, a size that does not match the
    /// dimensions exactly — is a clean `Err`. Nothing is allocated
    /// before the file's byte length has vouched for the dimensions,
    /// so a hostile header cannot OOM the process (same discipline as
    /// `layout::validate_blob`).
    pub fn open(path: impl AsRef<std::path::Path>) -> Result<ChunkedBinMatrix> {
        use std::io::Read;

        let path = path.as_ref();
        let file = std::fs::File::open(path)
            .with_context(|| format!("open bin arena {}", path.display()))?;
        let file_len = file.metadata().context("stat bin arena")?.len();
        crate::ensure!(
            file_len >= ARENA_PREFIX_BYTES,
            "bin arena truncated: {} bytes, header needs at least {}",
            file_len,
            ARENA_PREFIX_BYTES
        );
        // Sequential reads here (positional reads only start in
        // `load_chunk`): this keeps header validation — and with it the
        // malformed-file regression tests — runnable under Miri.
        let mut prefix = [0u8; ARENA_PREFIX_BYTES as usize];
        (&file).read_exact(&mut prefix).context("read bin arena header")?;
        crate::ensure!(
            prefix[..8] == ARENA_MAGIC,
            "bin arena magic mismatch: got {:02x?}",
            &prefix[..8]
        );
        let width = prefix[8] as usize;
        crate::ensure!(width == 1 || width == 2, "bin arena width must be 1 or 2, got {width}");
        let n_rows = u64::from_le_bytes(prefix[9..17].try_into().expect("8-byte slice"));
        let chunk_rows = u64::from_le_bytes(prefix[17..25].try_into().expect("8-byte slice"));
        let n_features = u32::from_le_bytes(prefix[25..29].try_into().expect("4-byte slice"));
        crate::ensure!(chunk_rows > 0, "bin arena chunk_rows must be positive");
        crate::ensure!(
            u64::from(n_features) <= ARENA_MAX_FEATURES,
            "bin arena claims {n_features} features (cap {ARENA_MAX_FEATURES})"
        );

        // Vouch for the dimensions with the actual file length before
        // reading the bin table or sizing anything from the header.
        let header_bytes = ARENA_PREFIX_BYTES + 4 * u64::from(n_features);
        let body_bytes = n_rows
            .checked_mul(u64::from(n_features))
            .and_then(|cells| cells.checked_mul(width as u64))
            .ok_or_else(|| crate::error::Error::msg("bin arena dimensions overflow"))?;
        let expect = header_bytes
            .checked_add(body_bytes)
            .ok_or_else(|| crate::error::Error::msg("bin arena dimensions overflow"))?;
        crate::ensure!(
            file_len == expect,
            "bin arena size mismatch: file is {file_len} bytes, dims say {expect}"
        );

        let mut bins_raw = vec![0u8; 4 * n_features as usize];
        (&file).read_exact(&mut bins_raw).context("read bin table")?;
        let bins_per_feature: Vec<usize> = bins_raw
            .chunks_exact(4)
            .map(|b| u32::from_le_bytes(b.try_into().expect("4-byte chunk")) as usize)
            .collect();
        for (f, &b) in bins_per_feature.iter().enumerate() {
            crate::ensure!(b >= 1, "feature {f} claims zero bins");
            crate::ensure!(b <= u16::MAX as usize + 1, "feature {f} claims {b} bins (u16 codes)");
        }
        // The stored width must be exactly what `BinMatrix::from_fn`
        // would derive, so loaded chunks are indistinguishable from the
        // in-RAM arena (this is load-bearing for bit-parity).
        let fits_u8 = bins_per_feature.iter().all(|&b| b <= U8_MAX_BINS);
        crate::ensure!(
            (width == 1) == fits_u8,
            "bin arena width {width} contradicts bin counts (u8-compatible: {fits_u8})"
        );

        Ok(ChunkedBinMatrix {
            file,
            n_rows: n_rows.try_into().context("n_rows exceeds usize")?,
            chunk_rows: chunk_rows.try_into().context("chunk_rows exceeds usize")?,
            bins_per_feature,
            width,
            header_bytes,
        })
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_features(&self) -> usize {
        self.bins_per_feature.len()
    }

    pub fn n_bins(&self, f: usize) -> usize {
        self.bins_per_feature[f]
    }

    pub fn bins_per_feature(&self) -> &[usize] {
        &self.bins_per_feature
    }

    /// Rows per block (the last block may be ragged).
    pub fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    /// Whether blocks decode into the `u8` arena.
    pub fn is_u8(&self) -> bool {
        self.width == 1
    }

    pub fn n_chunks(&self) -> usize {
        self.n_rows.div_ceil(self.chunk_rows)
    }

    /// Global row range covered by block `c`.
    pub fn chunk_range(&self, c: usize) -> std::ops::Range<usize> {
        let start = c * self.chunk_rows;
        start..(start + self.chunk_rows).min(self.n_rows)
    }

    /// Read block `c` back into an in-RAM [`BinMatrix`] over its rows.
    ///
    /// # Panics
    /// On I/O errors: `open` already vouched for the file's size and
    /// header, so a failed read mid-training means the file was
    /// truncated or the device failed underneath us — there is no
    /// useful recovery for a half-built tree.
    pub fn load_chunk(&self, c: usize) -> BinMatrix {
        use std::os::unix::fs::FileExt;

        let range = self.chunk_range(c);
        let rows = range.len();
        let nf = self.n_features();
        let offset = self.header_bytes + (range.start * nf * self.width) as u64;
        let mut raw = vec![0u8; rows * nf * self.width];
        self.file
            .read_exact_at(&mut raw, offset)
            .expect("bin arena read failed mid-training (file truncated or device error)");
        if self.width == 1 {
            BinMatrix::from_u8_arena(rows, &self.bins_per_feature, raw)
        } else {
            let arena: Vec<u16> = raw
                .chunks_exact(2)
                .map(|b| u16::from_le_bytes(b.try_into().expect("2-byte chunk")))
                .collect();
            BinMatrix::from_u16_arena(rows, &self.bins_per_feature, arena)
        }
    }
}

/// Streaming writer for the on-disk arena: header first, then one
/// column-major block per [`ArenaWriter::write_chunk`] call, in row
/// order. Used by `Binner::fit_transform_to_disk`.
pub(crate) struct ArenaWriter {
    out: std::io::BufWriter<std::fs::File>,
    bins_per_feature: Vec<usize>,
    n_rows: usize,
    rows_written: usize,
    chunk_rows: usize,
}

impl ArenaWriter {
    pub(crate) fn create(
        path: impl AsRef<std::path::Path>,
        n_rows: usize,
        chunk_rows: usize,
        bins_per_feature: &[usize],
    ) -> Result<ArenaWriter> {
        assert!(chunk_rows > 0, "chunk_rows must be positive");
        let path = path.as_ref();
        let file = std::fs::File::create(path)
            .with_context(|| format!("create bin arena {}", path.display()))?;
        let mut out = std::io::BufWriter::new(file);
        let width: u8 = if bins_per_feature.iter().all(|&b| b <= U8_MAX_BINS) { 1 } else { 2 };
        out.write_all(&ARENA_MAGIC)?;
        out.write_all(&[width])?;
        out.write_all(&(n_rows as u64).to_le_bytes())?;
        out.write_all(&(chunk_rows as u64).to_le_bytes())?;
        out.write_all(&(u32::try_from(bins_per_feature.len()).context("too many features")?)
            .to_le_bytes())?;
        for &b in bins_per_feature {
            out.write_all(&(b as u32).to_le_bytes())?;
        }
        Ok(ArenaWriter {
            out,
            bins_per_feature: bins_per_feature.to_vec(),
            n_rows,
            rows_written: 0,
            chunk_rows,
        })
    }

    /// Append the next block. Every block but the last must hold
    /// exactly `chunk_rows` rows.
    pub(crate) fn write_chunk(&mut self, chunk: &BinMatrix) -> Result<()> {
        assert_eq!(chunk.bins_per_feature(), &self.bins_per_feature[..]);
        let rows = chunk.n_rows();
        assert!(
            rows == self.chunk_rows || self.rows_written + rows == self.n_rows,
            "only the final block may be ragged"
        );
        match chunk.columns() {
            BinColumns::U8(a) => self.out.write_all(a)?,
            BinColumns::U16(a) => {
                for &code in a {
                    self.out.write_all(&code.to_le_bytes())?;
                }
            }
        }
        self.rows_written += rows;
        Ok(())
    }

    pub(crate) fn finish(mut self) -> Result<()> {
        assert_eq!(self.rows_written, self.n_rows, "arena writer closed early");
        self.out.flush().context("flush bin arena")?;
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Backing-store dispatch
// ---------------------------------------------------------------------

/// The trainer's view over either backing store. The grower and the
/// histogram pool take a `BinSource` and never know whether columns
/// come from RAM or from disk blocks; both paths visit rows in the
/// same ascending order, which is what keeps them bit-identical.
#[derive(Clone, Copy, Debug)]
pub enum BinSource<'a> {
    Ram(&'a BinMatrix),
    Chunked(&'a ChunkedBinMatrix),
}

impl BinSource<'_> {
    pub fn n_rows(&self) -> usize {
        match self {
            BinSource::Ram(m) => m.n_rows(),
            BinSource::Chunked(m) => m.n_rows(),
        }
    }

    pub fn n_features(&self) -> usize {
        match self {
            BinSource::Ram(m) => m.n_features(),
            BinSource::Chunked(m) => m.n_features(),
        }
    }

    pub fn bins_per_feature(&self) -> &[usize] {
        match self {
            BinSource::Ram(m) => m.bins_per_feature(),
            BinSource::Chunked(m) => m.bins_per_feature(),
        }
    }

    /// Split `rows` (ascending global ids) on `code(feature) <= bin`,
    /// preserving order. In-RAM routes against the resident column; the
    /// chunked store streams exactly the blocks that overlap `rows` and
    /// routes each block's sub-range with chunk-local indices — the
    /// emitted `left`/`right` sequences are identical either way.
    pub fn partition(
        &self,
        feature: usize,
        bin: u16,
        rows: &[u32],
        left: &mut Vec<u32>,
        right: &mut Vec<u32>,
    ) {
        match self {
            BinSource::Ram(m) => m.partition_col(feature, bin, rows, left, right),
            BinSource::Chunked(m) => {
                let mut done = 0usize;
                while done < rows.len() {
                    let c = rows[done] as usize / m.chunk_rows();
                    let range = m.chunk_range(c);
                    let end = done
                        + rows[done..].partition_point(|&r| (r as usize) < range.end);
                    let chunk = m.load_chunk(c);
                    let rows_in = chunk.n_rows();
                    let (cs, ce) = (feature * rows_in, (feature + 1) * rows_in);
                    let base = range.start as u32;
                    let sub = &rows[done..end];
                    match chunk.columns() {
                        BinColumns::U8(a) => route_rows(&a[cs..ce], bin, sub, base, left, right),
                        BinColumns::U16(a) => route_rows(&a[cs..ce], bin, sub, base, left, right),
                    }
                    done = end;
                }
            }
        }
    }
}

fn transpose_into<T: Copy>(arena: &[T], n_rows: usize, nf: usize, out: &mut [u16])
where
    u16: From<T>,
{
    for f in 0..nf {
        let col = &arena[f * n_rows..(f + 1) * n_rows];
        for (i, &v) in col.iter().enumerate() {
            out[i * nf + f] = u16::from(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u8_arena_selected_when_every_feature_fits() {
        let bm = BinMatrix::from_fn(4, &[256, 3], |f, i| ((f * 4 + i) % 3) as u16);
        assert!(bm.is_u8());
        assert_eq!(bm.arena_bytes(), 8);
        assert_eq!(bm.n_rows(), 4);
        assert_eq!(bm.n_features(), 2);
        assert_eq!(bm.n_bins(0), 256);
    }

    #[test]
    fn u16_arena_selected_when_any_feature_overflows_u8() {
        let bm = BinMatrix::from_fn(4, &[257, 3], |_, i| (i % 3) as u16);
        assert!(!bm.is_u8());
        assert_eq!(bm.arena_bytes(), 16);
    }

    #[test]
    fn bin_and_columns_agree_with_fill_order() {
        let bm = BinMatrix::from_fn(3, &[3, 13], |f, i| (10 * f + i) as u16);
        assert_eq!(bm.bin(0, 2), 2);
        assert_eq!(bm.bin(1, 0), 10);
        match bm.columns() {
            BinColumns::U8(a) => assert_eq!(a, &[0, 1, 2, 10, 11, 12]),
            BinColumns::U16(_) => panic!("13 bins must pick the u8 arena"),
        }
    }

    #[test]
    fn row_major_mirror_transposes() {
        let bm = BinMatrix::from_u16_columns(vec![vec![0, 1, 2], vec![5, 4, 3]]);
        assert_eq!(bm.to_row_major(), vec![0, 5, 1, 4, 2, 3]);
        assert_eq!(bm.to_u16_columns(), vec![vec![0, 1, 2], vec![5, 4, 3]]);
    }

    #[test]
    fn from_u16_columns_infers_bin_counts() {
        let bm = BinMatrix::from_u16_columns(vec![vec![0, 300], vec![1, 0]]);
        assert_eq!(bm.bins_per_feature(), &[301, 2]);
        assert!(!bm.is_u8(), "301 bins must force the u16 arena");
        assert_eq!(bm.bin(0, 1), 300);
    }

    #[test]
    fn empty_matrix_is_well_formed() {
        let bm = BinMatrix::from_u16_columns(vec![]);
        assert_eq!(bm.n_rows(), 0);
        assert_eq!(bm.n_features(), 0);
        assert!(bm.to_row_major().is_empty());
    }

    /// A 3-column mixed matrix: dense, sparse (default bin 1), dense.
    /// Dense twin: f0 = [0,1,2,3], f1 = [1,5,1,2], f2 = [3,2,1,0].
    fn mixed_fixture() -> (BinMatrix, BinMatrix) {
        let mixed = BinMatrix::from_mixed_cols(
            4,
            &[4, 6, 4],
            vec![
                MixedCol::Dense(vec![0, 1, 2, 3]),
                MixedCol::Sparse { rows: vec![1, 3], codes: vec![5, 2], default_bin: 1 },
                MixedCol::Dense(vec![3, 2, 1, 0]),
            ],
        );
        let dense = BinMatrix::from_u16_columns(vec![
            vec![0, 1, 2, 3],
            vec![1, 5, 1, 2],
            vec![3, 2, 1, 0],
        ]);
        (mixed, dense)
    }

    #[test]
    fn mixed_matrix_bin_matches_dense_twin() {
        let (mixed, dense) = mixed_fixture();
        assert!(mixed.has_sparse());
        assert!(!mixed.is_sparse_col(0));
        assert!(mixed.is_sparse_col(1));
        assert_eq!(mixed.n_sparse_cols(), 1);
        for f in 0..3 {
            for i in 0..4 {
                assert_eq!(mixed.bin(f, i), dense.bin(f, i), "f={f} i={i}");
            }
        }
        assert_eq!(mixed.to_row_major(), dense.to_row_major());
    }

    #[test]
    fn mixed_matrix_partitions_like_dense_twin() {
        let (mixed, dense) = mixed_fixture();
        let rows: Vec<u32> = vec![0, 1, 2, 3];
        for f in 0..3 {
            for bin in 0..6u16 {
                let (mut ml, mut mr) = (Vec::new(), Vec::new());
                let (mut dl, mut dr) = (Vec::new(), Vec::new());
                mixed.partition_col(f, bin, &rows, &mut ml, &mut mr);
                dense.partition_col(f, bin, &rows, &mut dl, &mut dr);
                assert_eq!((ml, mr), (dl, dr), "f={f} bin={bin}");
            }
        }
    }

    #[test]
    fn mixed_matrix_arena_width_follows_global_rule() {
        // All bin counts fit u8 → dense columns land in a u8 arena even
        // though a sparse column sits between them.
        let bm = BinMatrix::from_mixed_cols(
            2,
            &[4, 4],
            vec![
                MixedCol::Sparse { rows: vec![1], codes: vec![3], default_bin: 0 },
                MixedCol::Dense(vec![2, 0]),
            ],
        );
        assert!(bm.is_u8());
        assert_eq!(bm.bin(0, 0), 0);
        assert_eq!(bm.bin(0, 1), 3);
        assert_eq!(bm.bin(1, 0), 2);
        // 1 dense col (2 bytes) + sparse col (4 + 2 bytes).
        assert_eq!(bm.arena_bytes(), 2 + 6);
    }

    #[test]
    #[should_panic(expected = "mixed sparse/dense")]
    fn columns_rejects_mixed_matrix() {
        let (mixed, _) = mixed_fixture();
        let _ = mixed.columns();
    }
}
