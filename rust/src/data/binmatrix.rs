//! `BinMatrix` — the single bin-code arena shared by training and serving.
//!
//! Before this module the repo carried three independent bin
//! representations: `Vec<Vec<u16>>` columns for histogram training, a
//! transient per-block re-binning buffer inside the quantized engine's
//! batch loop, and per-row `Vec<f32>` gathers in the coordinator
//! batcher. PACSET (Madhyastha et al., 2020) and LIMITS (Sliwa et al.,
//! 2020) both argue the train-time and deploy-time layouts should be
//! co-designed; this type is that co-design:
//!
//! * **One contiguous arena.** All bin codes live in a single
//!   column-major buffer (`arena[f * n_rows + i]` is feature `f` of row
//!   `i`), so a feature column is one contiguous slice — the shape the
//!   histogram kernels stream.
//! * **Adaptive width.** Storage is `u8` when *every* feature has at
//!   most [`U8_MAX_BINS`] bins (the common case: the trainer's default
//!   `max_bins = 255`), halving the training set's bin footprint and
//!   doubling the codes per cache line; otherwise `u16`. Consumers
//!   dispatch once per build via [`BinMatrix::columns`] and run
//!   monomorphized kernels — no per-access branching.
//! * **On-demand row-major mirror.** Inference descends trees row by
//!   row (random feature order), the opposite access pattern, so
//!   [`BinMatrix::to_row_major`] materializes a `u16` row-major mirror
//!   when an engine wants to bin once and descend many times (see
//!   `QuantizedFlatModel::predict_batch_columns`).
//!
//! [`crate::data::Binner`] is the sole fit/transform entry point that
//! produces training matrices (`Binner::bin_matrix` /
//! `Binner::bin_columns`); the quantized engine builds its own over the
//! model's threshold tables. Both go through [`BinMatrix::from_fn`].

/// Largest per-feature bin count representable in the `u8` arena.
pub const U8_MAX_BINS: usize = 256;

/// Borrowed view of the whole column-major arena, dispatched once per
/// kernel so the accumulation loops monomorphize over the code width.
/// Feature `f` occupies `arena[f * n_rows..(f + 1) * n_rows]`.
#[derive(Clone, Copy, Debug)]
pub enum BinColumns<'a> {
    U8(&'a [u8]),
    U16(&'a [u16]),
}

#[derive(Clone, Debug)]
enum Store {
    U8(Vec<u8>),
    U16(Vec<u16>),
}

/// A dataset mapped to bin codes: one contiguous column-major arena
/// with adaptive u8/u16 element width. See the module docs.
#[derive(Clone, Debug)]
pub struct BinMatrix {
    n_rows: usize,
    /// `bins_per_feature[f]` bounds the codes of feature `f`
    /// (`bin(f, i) < bins_per_feature[f]`).
    bins_per_feature: Vec<usize>,
    store: Store,
}

impl BinMatrix {
    /// Build a matrix by evaluating `fill(feature, row)` for every cell,
    /// feature-major (so per-feature state in `fill` stays hot). Picks
    /// the `u8` arena exactly when every feature has ≤ [`U8_MAX_BINS`]
    /// bins. Every produced code must be `< bins_per_feature[feature]`.
    pub fn from_fn(
        n_rows: usize,
        bins_per_feature: &[usize],
        mut fill: impl FnMut(usize, usize) -> u16,
    ) -> BinMatrix {
        let nf = bins_per_feature.len();
        let store = if bins_per_feature.iter().all(|&b| b <= U8_MAX_BINS) {
            let mut arena = Vec::with_capacity(n_rows * nf);
            for f in 0..nf {
                for i in 0..n_rows {
                    let code = fill(f, i);
                    debug_assert!(
                        (code as usize) < bins_per_feature[f],
                        "bin code {code} out of range for feature {f} ({} bins)",
                        bins_per_feature[f]
                    );
                    arena.push(code as u8);
                }
            }
            Store::U8(arena)
        } else {
            let mut arena = Vec::with_capacity(n_rows * nf);
            for f in 0..nf {
                for i in 0..n_rows {
                    let code = fill(f, i);
                    debug_assert!(
                        (code as usize) < bins_per_feature[f],
                        "bin code {code} out of range for feature {f} ({} bins)",
                        bins_per_feature[f]
                    );
                    arena.push(code);
                }
            }
            Store::U16(arena)
        };
        BinMatrix { n_rows, bins_per_feature: bins_per_feature.to_vec(), store }
    }

    /// Adopt ready-made `u16` columns (tests, hand-built fixtures). Bin
    /// counts are inferred as `max code + 1` per feature, so storage
    /// width adapts exactly as for [`BinMatrix::from_fn`].
    pub fn from_u16_columns(cols: Vec<Vec<u16>>) -> BinMatrix {
        let n_rows = cols.first().map_or(0, |c| c.len());
        for (f, c) in cols.iter().enumerate() {
            assert_eq!(c.len(), n_rows, "column {f} length mismatch");
        }
        let bins_per_feature: Vec<usize> = cols
            .iter()
            .map(|c| c.iter().copied().max().map_or(1, |m| m as usize + 1))
            .collect();
        BinMatrix::from_fn(n_rows, &bins_per_feature, |f, i| cols[f][i])
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_features(&self) -> usize {
        self.bins_per_feature.len()
    }

    /// Number of bins of feature `f` (codes are `0..n_bins(f)`).
    pub fn n_bins(&self, f: usize) -> usize {
        self.bins_per_feature[f]
    }

    pub fn bins_per_feature(&self) -> &[usize] {
        &self.bins_per_feature
    }

    /// Whether the arena stores `u8` codes (every feature fits).
    pub fn is_u8(&self) -> bool {
        matches!(self.store, Store::U8(_))
    }

    /// Arena bytes (introspection: the u8 arena halves this).
    pub fn arena_bytes(&self) -> usize {
        match &self.store {
            Store::U8(a) => a.len(),
            Store::U16(a) => 2 * a.len(),
        }
    }

    /// Random-access lookup (baselines, per-row routing). Hot kernels
    /// should dispatch once via [`BinMatrix::columns`] instead.
    #[inline]
    pub fn bin(&self, f: usize, i: usize) -> u16 {
        debug_assert!(i < self.n_rows);
        let idx = f * self.n_rows + i;
        match &self.store {
            Store::U8(a) => a[idx] as u16,
            Store::U16(a) => a[idx],
        }
    }

    /// The whole column-major arena, width-dispatched.
    #[inline]
    pub fn columns(&self) -> BinColumns<'_> {
        match &self.store {
            Store::U8(a) => BinColumns::U8(a),
            Store::U16(a) => BinColumns::U16(a),
        }
    }

    /// Materialize the row-major `u16` mirror (`out[i * n_features + f]`)
    /// — the orientation tree descent wants. Built on demand; the
    /// column arena stays the source of truth.
    pub fn to_row_major(&self) -> Vec<u16> {
        let nf = self.n_features();
        let mut out = vec![0u16; self.n_rows * nf];
        match &self.store {
            Store::U8(a) => transpose_into(a, self.n_rows, nf, &mut out),
            Store::U16(a) => transpose_into(a, self.n_rows, nf, &mut out),
        }
        out
    }

    /// Widen back to plain `u16` columns (XLA tensor staging, tests).
    pub fn to_u16_columns(&self) -> Vec<Vec<u16>> {
        (0..self.n_features())
            .map(|f| (0..self.n_rows).map(|i| self.bin(f, i)).collect())
            .collect()
    }
}

fn transpose_into<T: Copy>(arena: &[T], n_rows: usize, nf: usize, out: &mut [u16])
where
    u16: From<T>,
{
    for f in 0..nf {
        let col = &arena[f * n_rows..(f + 1) * n_rows];
        for (i, &v) in col.iter().enumerate() {
            out[i * nf + f] = u16::from(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u8_arena_selected_when_every_feature_fits() {
        let bm = BinMatrix::from_fn(4, &[256, 3], |f, i| ((f * 4 + i) % 3) as u16);
        assert!(bm.is_u8());
        assert_eq!(bm.arena_bytes(), 8);
        assert_eq!(bm.n_rows(), 4);
        assert_eq!(bm.n_features(), 2);
        assert_eq!(bm.n_bins(0), 256);
    }

    #[test]
    fn u16_arena_selected_when_any_feature_overflows_u8() {
        let bm = BinMatrix::from_fn(4, &[257, 3], |_, i| (i % 3) as u16);
        assert!(!bm.is_u8());
        assert_eq!(bm.arena_bytes(), 16);
    }

    #[test]
    fn bin_and_columns_agree_with_fill_order() {
        let bm = BinMatrix::from_fn(3, &[3, 13], |f, i| (10 * f + i) as u16);
        assert_eq!(bm.bin(0, 2), 2);
        assert_eq!(bm.bin(1, 0), 10);
        match bm.columns() {
            BinColumns::U8(a) => assert_eq!(a, &[0, 1, 2, 10, 11, 12]),
            BinColumns::U16(_) => panic!("13 bins must pick the u8 arena"),
        }
    }

    #[test]
    fn row_major_mirror_transposes() {
        let bm = BinMatrix::from_u16_columns(vec![vec![0, 1, 2], vec![5, 4, 3]]);
        assert_eq!(bm.to_row_major(), vec![0, 5, 1, 4, 2, 3]);
        assert_eq!(bm.to_u16_columns(), vec![vec![0, 1, 2], vec![5, 4, 3]]);
    }

    #[test]
    fn from_u16_columns_infers_bin_counts() {
        let bm = BinMatrix::from_u16_columns(vec![vec![0, 300], vec![1, 0]]);
        assert_eq!(bm.bins_per_feature(), &[301, 2]);
        assert!(!bm.is_u8(), "301 bins must force the u16 arena");
        assert_eq!(bm.bin(0, 1), 300);
    }

    #[test]
    fn empty_matrix_is_well_formed() {
        let bm = BinMatrix::from_u16_columns(vec![]);
        assert_eq!(bm.n_rows(), 0);
        assert_eq!(bm.n_features(), 0);
        assert!(bm.to_row_major().is_empty());
    }
}
