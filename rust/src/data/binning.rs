//! Histogram binning (the LightGBM-style discretization substrate).
//!
//! GBDT split finding enumerates candidate thresholds. Exact enumeration
//! over all distinct values is quadratic-ish and cache-hostile; LightGBM
//! instead *bins* every feature into at most `max_bins` quantile buckets
//! and restricts candidate thresholds to bucket boundaries. ToaD inherits
//! this discretization: the identity of a threshold for the reuse penalty
//! (paper §3.1) is the pair *(feature, boundary index)*, and the boundary
//! *value* is what ends up in the global threshold array of the memory
//! layout (§3.2.2).

use super::binmatrix::{ArenaWriter, BinMatrix, ChunkedBinMatrix, MixedCol};
use super::dataset::Dataset;
use super::sparse::{CsrMatrix, SparseDataset};
use crate::error::Result;

/// A feature column is stored sparse (present-rows + codes side table)
/// when its density is strictly below this fraction of `n_rows`; denser
/// columns are materialized into the contiguous dense arena. 0.35 is
/// the break-even of the sparse histogram walk (one index load + one
/// code load + correction amortization) against the dense scatter on
/// the row counts the benches cover; both representations bin to
/// identical codes, so the threshold only moves cost, never results.
pub const SPARSE_DENSITY_THRESHOLD: f64 = 0.35;

/// Per-feature binning rule learned from training data.
#[derive(Clone, Debug)]
pub struct Binner {
    /// `boundaries[f][b]` is the split value between bin `b` and `b+1`;
    /// a row goes left when `x[f] <= boundaries[f][b]`.
    pub boundaries: Vec<Vec<f32>>,
}

impl Binner {
    /// Learn quantile bin boundaries from the columns of `data`.
    ///
    /// For each feature the distinct sorted values are walked and up to
    /// `max_bins - 1` boundaries are placed at (approximately) equal-mass
    /// quantiles, always *between* two distinct values so that binning is
    /// exact on training data.
    ///
    /// NaN values (e.g. from a dirty CSV) are treated as *missing*: they
    /// contribute nothing to boundary placement, and [`Binner::bin_value`]
    /// routes them to the top bin — the same "right at every split"
    /// direction the inference engines give NaN (where `x ≤ t` is
    /// false) — so dirty rows degrade gracefully instead of panicking.
    pub fn fit(data: &Dataset, max_bins: usize) -> Binner {
        assert!(max_bins >= 2, "need at least 2 bins");
        let boundaries = data
            .features
            .iter()
            .map(|col| {
                // Sort a copy, ignoring NaNs (missing values).
                let mut v: Vec<f32> = col.iter().copied().filter(|x| !x.is_nan()).collect();
                let n = v.len();
                v.sort_by(f32::total_cmp);
                let mut distinct: Vec<(f32, usize)> = Vec::new();
                for &x in &v {
                    match distinct.last_mut() {
                        Some((d, c)) if *d == x => *c += 1,
                        _ => distinct.push((x, 1)),
                    }
                }
                boundaries_from_distinct(&distinct, n, max_bins)
            })
            .collect();
        Binner { boundaries }
    }

    /// Sparse twin of [`Binner::fit`]: learn boundaries from a CSR
    /// matrix without densifying it. Per feature the present non-NaN
    /// values are sorted and `==`-merged exactly like `fit`, then the
    /// implicit value `0.0` is merged in with multiplicity `n_rows −
    /// present`, so the resulting distinct (value, count) list — and
    /// therefore every boundary — is bit-identical to running `fit` on
    /// the densified matrix. Present NaN entries count as *missing*
    /// (they are neither zeros nor boundary mass), matching how `fit`
    /// filters NaN from a densified column.
    pub fn fit_sparse(data: &SparseDataset, max_bins: usize) -> Binner {
        assert!(max_bins >= 2, "need at least 2 bins");
        let n_rows = data.n_rows();
        let boundaries = data
            .x
            .to_columns()
            .into_iter()
            .map(|(rows, vals)| {
                let present = rows.len();
                let mut v: Vec<f32> = vals.iter().copied().filter(|x| !x.is_nan()).collect();
                let n_present = v.len();
                v.sort_by(f32::total_cmp);
                let mut distinct: Vec<(f32, usize)> = Vec::new();
                for &x in &v {
                    match distinct.last_mut() {
                        Some((d, c)) if *d == x => *c += 1,
                        _ => distinct.push((x, 1)),
                    }
                }
                // Merge the implicit zeros. `== 0.0` matches an explicit
                // -0.0 entry too, keeping its representative — the same
                // value `fit` would keep after total_cmp-sorting the
                // densified column (-0.0 sorts before 0.0, first wins).
                let n_implicit = n_rows - present;
                if n_implicit > 0 {
                    if let Some((_, c)) = distinct.iter_mut().find(|(d, _)| *d == 0.0) {
                        *c += n_implicit;
                    } else {
                        let at = distinct.partition_point(|(d, _)| {
                            d.total_cmp(&0.0) == std::cmp::Ordering::Less
                        });
                        distinct.insert(at, (0.0, n_implicit));
                    }
                }
                boundaries_from_distinct(&distinct, n_present + n_implicit, max_bins)
            })
            .collect();
        Binner { boundaries }
    }

    /// Two-pass streaming fit + transform that never materializes the
    /// float matrix: pass 1 streams row blocks and folds each feature
    /// into an exact sorted value→count sketch, pass 2 re-streams the
    /// same blocks, bins them, and appends them to the on-disk arena at
    /// `path`. Returns the fitted binner and the opened (re-validated)
    /// [`ChunkedBinMatrix`].
    ///
    /// `source(range)` must yield the feature columns of exactly the
    /// rows in `range` (column-major: `cols[f][i]` is feature `f` of
    /// global row `range.start + i`) and must be deterministic — it is
    /// called once per block per pass, in ascending row order.
    ///
    /// The sketch is *exact*, not approximate: [`Binner::fit`] only
    /// consumes the sorted distinct (value, count) list per feature,
    /// and that list is reproduced here verbatim (same `total_cmp`
    /// order, same `==`-merge of `-0.0`/`0.0` keeping the first
    /// representative), so the boundaries are bit-identical to an
    /// in-RAM `fit` on the same rows. Memory scales with the number of
    /// *distinct* values per feature, not with `n_rows` — sensors,
    /// counters, and pre-quantized telemetry stay tiny.
    pub fn fit_transform_to_disk<C: AsRef<[f32]>>(
        path: impl AsRef<std::path::Path>,
        n_rows: usize,
        n_features: usize,
        max_bins: usize,
        chunk_rows: usize,
        mut source: impl FnMut(std::ops::Range<usize>) -> Vec<C>,
    ) -> Result<(Binner, ChunkedBinMatrix)> {
        assert!(max_bins >= 2, "need at least 2 bins");
        assert!(chunk_rows > 0, "chunk_rows must be positive");

        // Pass 1: exact per-feature sketches, keyed so that ascending
        // u32 key order == `f32::total_cmp` order (sign-aware bit flip).
        let mut sketches: Vec<std::collections::BTreeMap<u32, usize>> =
            (0..n_features).map(|_| std::collections::BTreeMap::new()).collect();
        let mut counts = vec![0usize; n_features];
        let mut start = 0usize;
        while start < n_rows {
            let range = start..(start + chunk_rows).min(n_rows);
            let cols = source(range.clone());
            assert_eq!(cols.len(), n_features, "source yielded wrong feature count");
            for (f, col) in cols.iter().enumerate() {
                let col = col.as_ref();
                assert_eq!(col.len(), range.len(), "source yielded wrong row count");
                for &x in col {
                    if !x.is_nan() {
                        *sketches[f].entry(total_cmp_key(x)).or_insert(0) += 1;
                        counts[f] += 1;
                    }
                }
            }
            start = range.end;
        }
        let boundaries: Vec<Vec<f32>> = sketches
            .iter()
            .zip(&counts)
            .map(|(sketch, &n)| {
                // Ascending key walk == total_cmp-sorted values; merge
                // `==`-equal neighbours (-0.0/0.0) exactly like `fit`.
                let mut distinct: Vec<(f32, usize)> = Vec::with_capacity(sketch.len());
                for (&k, &c) in sketch {
                    let x = total_cmp_key_inv(k);
                    match distinct.last_mut() {
                        Some((d, dc)) if *d == x => *dc += c,
                        _ => distinct.push((x, c)),
                    }
                }
                boundaries_from_distinct(&distinct, n, max_bins)
            })
            .collect();
        let binner = Binner { boundaries };

        // Pass 2: bin each block and append it to the arena file.
        let bins_per_feature: Vec<usize> =
            (0..n_features).map(|f| binner.n_bins(f)).collect();
        let mut writer = ArenaWriter::create(&path, n_rows, chunk_rows, &bins_per_feature)?;
        let mut start = 0usize;
        while start < n_rows {
            let range = start..(start + chunk_rows).min(n_rows);
            let cols = source(range.clone());
            writer.write_chunk(&binner.bin_columns(&cols, range.len()))?;
            start = range.end;
        }
        writer.finish()?;
        let chunked = ChunkedBinMatrix::open(&path)?;
        Ok((binner, chunked))
    }

    pub fn n_features(&self) -> usize {
        self.boundaries.len()
    }

    /// Number of bins for feature `f` (boundaries + 1).
    pub fn n_bins(&self, f: usize) -> usize {
        self.boundaries[f].len() + 1
    }

    /// Largest bin count over all features.
    pub fn max_bin_count(&self) -> usize {
        (0..self.n_features()).map(|f| self.n_bins(f)).max().unwrap_or(1)
    }

    /// Bin a single value of feature `f` (binary search over boundaries).
    ///
    /// NaN maps to the top bin: every split sends bins `≤ b` left, so
    /// the top bin routes right at every boundary — exactly how the
    /// inference engines route NaN (`x ≤ t` is false). Training-time
    /// binned routing and float-threshold inference therefore agree on
    /// dirty rows too.
    #[inline]
    pub fn bin_value(&self, f: usize, x: f32) -> u16 {
        let b = &self.boundaries[f];
        if x.is_nan() {
            return b.len() as u16;
        }
        // partition_point: first boundary >= x fails `x <= bound` check…
        // we want the count of boundaries strictly below x, i.e. the
        // number of `bound < x`.
        b.partition_point(|&bound| bound < x) as u16
    }

    /// Bulk transform: bin raw feature columns into the shared
    /// [`BinMatrix`] arena (`u8` codes when every feature has ≤ 256
    /// bins, `u16` otherwise). `cols[f]` must hold feature `f` for all
    /// `n_rows` rows; this is the one transform entry point — training,
    /// baselines, and benches all consume the matrix it produces.
    pub fn bin_columns<C: AsRef<[f32]>>(&self, cols: &[C], n_rows: usize) -> BinMatrix {
        bin_columns_over_tables(&self.boundaries, cols, n_rows)
    }

    /// Bin an entire dataset (column-major arena, same orientation as
    /// the input's feature columns).
    pub fn bin_matrix(&self, data: &Dataset) -> BinMatrix {
        self.bin_columns(&data.features, data.n_rows())
    }

    /// The bin of feature `f`'s implicit value `0.0` — what every
    /// absent cell of a sparse matrix reads as.
    #[inline]
    pub fn default_bin(&self, f: usize) -> u16 {
        self.bin_value(f, 0.0)
    }

    /// Bin a CSR matrix into a (possibly mixed) [`BinMatrix`] without
    /// densifying: per feature, present entries are binned by the exact
    /// [`Binner::bin_value`] rule (explicit `0.0` lands in the default
    /// bin, present NaN in the top bin) and the column is stored as a
    /// [`super::binmatrix::SparseBinColumn`] when its density is below
    /// [`SPARSE_DENSITY_THRESHOLD`], or materialized into the dense
    /// arena (absent rows filled with the default bin) otherwise. Cell
    /// for cell the result equals `bin_matrix` on the densified input.
    pub fn bin_sparse(&self, x: &CsrMatrix) -> BinMatrix {
        assert_eq!(x.n_cols, self.n_features(), "feature count mismatch");
        let n_rows = x.n_rows;
        let bins_per_feature: Vec<usize> =
            (0..self.n_features()).map(|f| self.n_bins(f)).collect();
        let cols = x.to_columns();
        let mixed: Vec<MixedCol> = cols
            .into_iter()
            .enumerate()
            .map(|(f, (rows, vals))| {
                let codes: Vec<u16> =
                    vals.iter().map(|&v| self.bin_value(f, v)).collect();
                let default_bin = self.default_bin(f);
                if (rows.len() as f64) < SPARSE_DENSITY_THRESHOLD * n_rows as f64 {
                    MixedCol::Sparse { rows, codes, default_bin }
                } else {
                    let mut col = vec![default_bin; n_rows];
                    for (k, &r) in rows.iter().enumerate() {
                        col[r as usize] = codes[k];
                    }
                    MixedCol::Dense(col)
                }
            })
            .collect();
        BinMatrix::from_mixed_cols(n_rows, &bins_per_feature, mixed)
    }

    /// The threshold *value* represented by boundary index `b` of feature
    /// `f` — this is what the ToaD global threshold array stores.
    #[inline]
    pub fn threshold_value(&self, f: usize, b: usize) -> f32 {
        self.boundaries[f][b]
    }
}

/// Bin raw feature columns against ascending per-feature boundary
/// tables into a [`BinMatrix`]: `code = #{boundaries < x}`, NaN to the
/// top bin `tables[f].len()` (right at every split, like `x ≤ t` being
/// false). This is THE binning rule — [`Binner::bin_columns`] applies
/// it to quantile boundaries and the quantized engine's columnar
/// pre-binning applies it to the model's distinct-threshold tables, so
/// the two can never drift apart.
pub fn bin_columns_over_tables<C: AsRef<[f32]>>(
    tables: &[Vec<f32>],
    cols: &[C],
    n_rows: usize,
) -> BinMatrix {
    assert_eq!(cols.len(), tables.len(), "need one column per table");
    let bins_per_feature: Vec<usize> = tables.iter().map(|t| t.len() + 1).collect();
    BinMatrix::from_fn(n_rows, &bins_per_feature, |f, i| {
        let x = cols[f].as_ref()[i];
        let t = &tables[f];
        if x.is_nan() {
            t.len() as u16
        } else {
            t.partition_point(|&b| b < x) as u16
        }
    })
}

/// Boundary placement over a feature's sorted distinct (value, count)
/// list — the single fold shared by [`Binner::fit`] and the streaming
/// [`Binner::fit_transform_to_disk`], so the two can never drift.
/// `n` is the feature's non-NaN row count.
fn boundaries_from_distinct(distinct: &[(f32, usize)], n: usize, max_bins: usize) -> Vec<f32> {
    if distinct.len() <= 1 {
        return Vec::new(); // constant feature: no candidate splits
    }
    if distinct.len() <= max_bins {
        // One bin per distinct value; boundary at midpoints.
        return distinct.windows(2).map(|w| midpoint(w[0].0, w[1].0)).collect();
    }
    // Equal-mass quantile placement over distinct values.
    let n_bounds = max_bins - 1;
    let mut bounds = Vec::with_capacity(n_bounds);
    let mut cum = 0usize;
    let mut target_idx = 1usize;
    for w in distinct.windows(2) {
        cum += w[0].1;
        let target = target_idx * n / max_bins;
        if cum >= target && bounds.len() < n_bounds {
            bounds.push(midpoint(w[0].0, w[1].0));
            while target_idx * n / max_bins <= cum {
                target_idx += 1;
            }
        }
    }
    bounds
}

/// Order-preserving `f32 → u32` key: ascending `u32` order equals
/// `f32::total_cmp` order (flip all bits of negatives, flip the sign
/// bit of non-negatives). NaNs are filtered before keying.
#[inline]
fn total_cmp_key(x: f32) -> u32 {
    let b = x.to_bits();
    if b >> 31 == 1 {
        !b
    } else {
        b ^ 0x8000_0000
    }
}

/// Inverse of [`total_cmp_key`].
#[inline]
fn total_cmp_key_inv(k: u32) -> f32 {
    if k >> 31 == 1 {
        f32::from_bits(k ^ 0x8000_0000)
    } else {
        f32::from_bits(!k)
    }
}

#[inline]
fn midpoint(a: f32, b: f32) -> f32 {
    let m = a + (b - a) * 0.5;
    // Guard against rounding collapsing onto `b` (then `x <= m` would
    // misroute the right value); bias to `a` which keeps binning exact.
    if m >= b {
        a
    } else {
        m
    }
}

#[cfg(test)]
#[cfg(not(miri))] // trains models / generates datasets - too slow under the Miri interpreter
mod tests {
    use super::*;
    use crate::data::dataset::Task;
    use crate::prng::Pcg64;

    fn ds(cols: Vec<Vec<f32>>) -> Dataset {
        let n = cols[0].len();
        Dataset {
            name: "t".into(),
            features: cols,
            targets: vec![0.0; n],
            labels: vec![],
            task: Task::Regression,
        }
    }

    #[test]
    fn constant_feature_has_no_boundaries() {
        let d = ds(vec![vec![5.0; 10]]);
        let b = Binner::fit(&d, 16);
        assert!(b.boundaries[0].is_empty());
        assert_eq!(b.n_bins(0), 1);
        assert_eq!(b.bin_value(0, 5.0), 0);
    }

    #[test]
    fn few_distinct_values_get_exact_bins() {
        let d = ds(vec![vec![0.0, 1.0, 0.0, 2.0, 1.0, 2.0]]);
        let b = Binner::fit(&d, 16);
        assert_eq!(b.n_bins(0), 3);
        assert_eq!(b.bin_value(0, 0.0), 0);
        assert_eq!(b.bin_value(0, 1.0), 1);
        assert_eq!(b.bin_value(0, 2.0), 2);
    }

    #[test]
    fn binning_is_monotone() {
        let mut rng = Pcg64::new(21);
        let col: Vec<f32> = (0..500).map(|_| rng.gen_f32() * 10.0).collect();
        let d = ds(vec![col.clone()]);
        let b = Binner::fit(&d, 32);
        let mut pairs: Vec<(f32, u16)> = col.iter().map(|&x| (x, b.bin_value(0, x))).collect();
        pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for w in pairs.windows(2) {
            assert!(w[0].1 <= w[1].1, "bins must be monotone in the value");
        }
    }

    #[test]
    fn bin_count_respects_max() {
        let mut rng = Pcg64::new(22);
        let col: Vec<f32> = (0..10_000).map(|_| rng.gen_f32()).collect();
        let d = ds(vec![col]);
        let b = Binner::fit(&d, 255);
        assert!(b.n_bins(0) <= 255);
        assert!(b.n_bins(0) >= 200, "should use most of the budget, got {}", b.n_bins(0));
    }

    #[test]
    fn bins_roughly_equal_mass() {
        let mut rng = Pcg64::new(23);
        let col: Vec<f32> = (0..8_000).map(|_| rng.gen_f32()).collect();
        let d = ds(vec![col.clone()]);
        let b = Binner::fit(&d, 16);
        let binned = b.bin_matrix(&d);
        let mut counts = vec![0usize; b.n_bins(0)];
        for i in 0..binned.n_rows() {
            counts[binned.bin(0, i) as usize] += 1;
        }
        let expect = 8_000 / 16;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                c > expect / 3 && c < expect * 3,
                "bin {i} count {c} far from equal mass {expect}"
            );
        }
    }

    #[test]
    fn threshold_value_separates_bins() {
        let mut rng = Pcg64::new(24);
        let col: Vec<f32> = (0..1000).map(|_| rng.gen_f32() * 5.0).collect();
        let d = ds(vec![col.clone()]);
        let b = Binner::fit(&d, 16);
        // Every training value in bin <= k must satisfy x <= threshold(k),
        // and every value in bin > k must violate it.
        for k in 0..b.boundaries[0].len() {
            let thr = b.threshold_value(0, k);
            for &x in &col {
                let bin = b.bin_value(0, x);
                if (bin as usize) <= k {
                    assert!(x <= thr, "x={x} bin={bin} thr={thr} k={k}");
                } else {
                    assert!(x > thr, "x={x} bin={bin} thr={thr} k={k}");
                }
            }
        }
    }

    #[test]
    fn nan_values_do_not_panic_and_bin_to_top() {
        // A dirty column (NaN mixed in) must fit without panicking,
        // place the same boundaries as the clean column, and send NaN
        // to the top bin (right at every split, like the engines).
        let clean = vec![0.0f32, 1.0, 2.0, 3.0, 1.0, 2.0];
        let mut dirty = clean.clone();
        dirty[2] = f32::NAN;
        dirty.push(f32::NAN);
        let bc = Binner::fit(&ds(vec![clean.clone()]), 16);
        let bd = Binner::fit(&ds(vec![dirty]), 16);
        // The remaining distinct values {0,1,2,3} still all appear.
        assert_eq!(bc.boundaries[0], bd.boundaries[0]);
        let top = bd.boundaries[0].len() as u16;
        assert_eq!(bd.bin_value(0, f32::NAN), top);
        // NaN routes right of every boundary, like `x <= t == false`.
        for k in 0..bd.boundaries[0].len() {
            assert!(bd.bin_value(0, f32::NAN) > k as u16);
        }
    }

    #[test]
    fn training_survives_nan_features() {
        // End-to-end: a dirty CSV-like dataset must train without
        // panicking, and binned routing must match float routing on the
        // NaN rows (both send NaN right at every split).
        use crate::gbdt::{self, GbdtParams};
        let mut rng = Pcg64::new(26);
        let n = 400;
        let mut cols: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..n).map(|_| rng.gen_f32() * 4.0 - 2.0).collect())
            .collect();
        let targets: Vec<f64> =
            (0..n).map(|i| (cols[0][i] * 1.5 - cols[2][i]) as f64).collect();
        for i in (0..n).step_by(17) {
            cols[i % 3][i] = f32::NAN; // sprinkle missing values
        }
        let data = Dataset {
            name: "dirty".into(),
            features: cols,
            targets,
            labels: vec![],
            task: Task::Regression,
        };
        let mut b = gbdt::booster::Booster::new(
            &data,
            GbdtParams::paper(8, 3),
            crate::gbdt::splitter::NoPenalty,
        );
        b.run();
        // Route through the *training* binner: binned descent and
        // float-threshold descent must agree even on NaN rows.
        let binned = b.binner().bin_matrix(&data);
        let model = b.into_model();
        assert!(model.n_trees() > 0);
        for i in 0..n {
            assert_eq!(
                model.predict_raw_binned(&binned, i),
                model.predict_raw(&data.row(i)),
                "row {i}: binned and float routing diverged"
            );
        }
    }

    /// The production lookup is a `partition_point` binary search; pin
    /// it against the naive linear scan (count boundaries strictly
    /// below `x`, NaN to the top bin) on random and NaN probes — both
    /// on-data and off-data values, including exact boundary hits.
    #[test]
    fn prop_bin_value_matches_linear_scan() {
        use crate::testutil::prop::run_prop;
        run_prop("bin_value binary search == linear scan", 60, |g| {
            let n = g.usize_in(2, 300);
            let col: Vec<f32> = (0..n)
                .map(|_| if g.bool(0.03) { f32::NAN } else { g.f64_in(-5.0, 5.0) as f32 })
                .collect();
            let d = ds(vec![col.clone()]);
            let b = Binner::fit(&d, g.usize_in(2, 64));
            let scan = |x: f32| -> u16 {
                let bounds = &b.boundaries[0];
                if x.is_nan() {
                    return bounds.len() as u16;
                }
                let mut c = 0u16;
                for &t in bounds {
                    if t < x {
                        c += 1;
                    }
                }
                c
            };
            for &x in &col {
                assert_eq!(b.bin_value(0, x), scan(x), "training value {x}");
            }
            for &x in &b.boundaries[0] {
                assert_eq!(b.bin_value(0, x), scan(x), "boundary value {x}");
            }
            for _ in 0..40 {
                let x = if g.bool(0.1) { f32::NAN } else { g.f64_in(-6.0, 6.0) as f32 };
                assert_eq!(b.bin_value(0, x), scan(x), "probe {x}");
            }
        });
    }

    /// `bin_columns` must agree cell-for-cell with per-value
    /// `bin_value`, in both arena widths.
    #[test]
    fn bin_columns_matches_bin_value_in_both_widths() {
        let mut rng = Pcg64::new(27);
        for max_bins in [16usize, 300] {
            let n = 500;
            let cols: Vec<Vec<f32>> = (0..3)
                .map(|_| (0..n).map(|_| rng.gen_f32() * 8.0 - 4.0).collect())
                .collect();
            let d = ds(cols.clone());
            let b = Binner::fit(&d, max_bins);
            let bm = b.bin_matrix(&d);
            assert_eq!(bm.is_u8(), b.max_bin_count() <= 256);
            for f in 0..3 {
                assert_eq!(bm.n_bins(f), b.n_bins(f));
                for i in 0..n {
                    assert_eq!(bm.bin(f, i), b.bin_value(f, cols[f][i]), "f={f} i={i}");
                }
            }
        }
    }

    fn sparse_fixture(density: f64, n: usize, seed: u64) -> crate::data::sparse::SparseDataset {
        use crate::data::sparse::{CsrMatrix, SparseDataset};
        let mut rng = Pcg64::new(seed);
        let mut x = CsrMatrix::empty(4);
        for _ in 0..n {
            let mut row: Vec<(u32, f32)> = Vec::new();
            for f in 0..4u32 {
                if (rng.gen_range(1000) as f64) < density * 1000.0 {
                    // Values straddle 0.0 so the default bin is interior;
                    // draw 512 produces an explicit 0.0, and a rare NaN
                    // exercises the present-NaN path.
                    let v = if rng.gen_range(100) == 0 {
                        f32::NAN
                    } else {
                        (rng.gen_range(1024) as f32 - 512.0) / 1024.0
                    };
                    row.push((f, v));
                }
            }
            x.push_row(&row);
        }
        let targets = vec![0.0; n];
        SparseDataset { name: "s".into(), x, targets, labels: vec![], task: Task::Regression }
    }

    #[test]
    fn fit_sparse_boundaries_match_fit_on_densified() {
        for density in [0.01, 0.2, 0.9] {
            let sd = sparse_fixture(density, 600, 31);
            let dense = sd.densify();
            for max_bins in [16usize, 255, 400] {
                let bs = Binner::fit_sparse(&sd, max_bins);
                let bd = Binner::fit(&dense, max_bins);
                for f in 0..4 {
                    assert_eq!(
                        bs.boundaries[f]
                            .iter()
                            .map(|b| b.to_bits())
                            .collect::<Vec<u32>>(),
                        bd.boundaries[f]
                            .iter()
                            .map(|b| b.to_bits())
                            .collect::<Vec<u32>>(),
                        "density={density} max_bins={max_bins} f={f}"
                    );
                }
            }
        }
    }

    #[test]
    fn bin_sparse_matches_densified_bin_matrix_cell_for_cell() {
        for density in [0.01, 0.2, 0.9] {
            let sd = sparse_fixture(density, 500, 33);
            let dense = sd.densify();
            let b = Binner::fit_sparse(&sd, 64);
            let ms = b.bin_sparse(&sd.x);
            let md = b.bin_matrix(&dense);
            assert_eq!(ms.n_rows(), md.n_rows());
            // Low densities store sparse columns, 0.9 stays fully dense.
            assert_eq!(ms.has_sparse(), density < SPARSE_DENSITY_THRESHOLD);
            for f in 0..4 {
                for i in 0..ms.n_rows() {
                    assert_eq!(ms.bin(f, i), md.bin(f, i), "density={density} f={f} i={i}");
                }
            }
            assert_eq!(ms.to_row_major(), md.to_row_major());
        }
    }

    #[test]
    fn sparse_default_bin_is_bin_of_zero_and_interior() {
        let sd = sparse_fixture(0.1, 800, 35);
        let b = Binner::fit_sparse(&sd, 32);
        for f in 0..4 {
            assert_eq!(b.default_bin(f), b.bin_value(f, 0.0));
            // Values straddle zero, so zero's bin must not be bin 0 or
            // the top bin (the correction must hit an interior bin).
            assert!(b.default_bin(f) > 0, "f={f}");
            assert!((b.default_bin(f) as usize) < b.n_bins(f) - 1, "f={f}");
        }
    }

    #[test]
    fn present_nan_bins_to_top_not_default() {
        use crate::data::sparse::CsrMatrix;
        let mut x = CsrMatrix::empty(1);
        for v in [1.0f32, 2.0, 3.0, 4.0] {
            x.push_row(&[(0, v)]);
        }
        x.push_row(&[(0, f32::NAN)]);
        x.push_row(&[]); // absent → 0.0
        let sd = crate::data::sparse::SparseDataset {
            name: "nan".into(),
            x,
            targets: vec![0.0; 6],
            labels: vec![],
            task: Task::Regression,
        };
        let b = Binner::fit_sparse(&sd, 16);
        let m = b.bin_sparse(&sd.x);
        let top = b.boundaries[0].len() as u16;
        assert_eq!(m.bin(0, 4), top, "present NaN routes to the top bin");
        assert_eq!(m.bin(0, 5), b.default_bin(0), "absent row reads the default bin");
        assert_ne!(top, b.default_bin(0));
    }

    #[test]
    fn duplicate_heavy_distribution() {
        // 90% zeros, 10% spread: boundary placement must not panic and
        // must keep monotonicity.
        let mut rng = Pcg64::new(25);
        let col: Vec<f32> = (0..2000)
            .map(|_| if rng.gen_bool(0.9) { 0.0 } else { rng.gen_f32() })
            .collect();
        let d = ds(vec![col]);
        let b = Binner::fit(&d, 8);
        assert!(b.n_bins(0) >= 2);
        assert_eq!(b.bin_value(0, 0.0), 0);
    }
}
