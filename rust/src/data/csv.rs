//! Minimal CSV reader/writer for [`Dataset`]s, plus a libsvm/svmlight
//! loader for sparse data.
//!
//! Real data can be dropped into the experiments through this module
//! (replacing the synthetic generators) — the dense format is a plain
//! numeric CSV with a header row; the label/target column is named
//! `target`. The sparse format is standard libsvm: one `label
//! idx:value ...` line per row with 1-based strictly increasing
//! indices. No external parsing crate is available offline, so both
//! are small, strict parsers: malformed lines are a clean `Err`, never
//! a panic.

use super::dataset::{Dataset, Task};
use super::sparse::SparseDataset;
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

/// Read a dataset from CSV. The column named `target` (any position)
/// becomes the label/target; `task` tells how to interpret it.
pub fn read_csv(path: &Path, name: &str, task: Task) -> crate::error::Result<Dataset> {
    let file = std::fs::File::open(path)?;
    let mut lines = BufReader::new(file).lines();
    let header = lines.next().ok_or_else(|| crate::anyhow!("empty csv"))??;
    let cols: Vec<&str> = header.split(',').map(|s| s.trim()).collect();
    let target_idx = cols
        .iter()
        .position(|&c| c == "target")
        .ok_or_else(|| crate::anyhow!("no `target` column in {path:?}"))?;
    let n_features = cols.len() - 1;

    let mut features: Vec<Vec<f32>> = vec![Vec::new(); n_features];
    let mut targets: Vec<f64> = Vec::new();
    let mut labels: Vec<usize> = Vec::new();

    for (lineno, line) in lines.enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(|s| s.trim()).collect();
        if fields.len() != cols.len() {
            crate::bail!("line {}: {} fields, expected {}", lineno + 2, fields.len(), cols.len());
        }
        let mut fi = 0usize;
        for (c, field) in fields.iter().enumerate() {
            if c == target_idx {
                match task {
                    Task::Regression => targets.push(field.parse::<f64>()?),
                    _ => labels.push(field.parse::<f64>()? as usize),
                }
            } else {
                features[fi].push(field.parse::<f32>()?);
                fi += 1;
            }
        }
    }
    let ds = Dataset { name: name.to_string(), features, targets, labels, task };
    ds.validate().map_err(|e| crate::anyhow!(e))?;
    Ok(ds)
}

/// Read a sparse dataset in libsvm/svmlight format: one row per line,
/// `label idx:value idx:value ...`, indices 1-based and strictly
/// increasing within a line. Blank lines and lines starting with `#`
/// are skipped; anything else malformed (truncated `idx:` pairs,
/// non-numeric fields, index 0, out-of-order indices, labels that do
/// not fit `task`) is a clean `Err` naming the line. The feature count
/// is the largest index seen; `values` accepts anything `f32` parses,
/// including `nan` (a present NaN, which bins to the top bin — it is
/// *not* an absent cell).
pub fn read_libsvm(path: &Path, name: &str, task: Task) -> crate::error::Result<SparseDataset> {
    let file = std::fs::File::open(path)?;
    let mut x = super::sparse::CsrMatrix::empty(0);
    let mut targets: Vec<f64> = Vec::new();
    let mut labels: Vec<usize> = Vec::new();
    let mut max_col = 0u32;

    for (lineno, line) in BufReader::new(file).lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let bad = |what: &str| crate::anyhow!("line {}: {what}: {line:?}", lineno + 1);
        let mut fields = line.split_ascii_whitespace();
        let label = fields.next().ok_or_else(|| bad("missing label"))?;
        let label: f64 = label.parse().map_err(|_| bad("unparseable label"))?;
        match task {
            Task::Regression => targets.push(label),
            Task::Binary => labels.push(if label > 0.0 { 1 } else { 0 }),
            Task::Multiclass(c) => {
                if label.fract() != 0.0 || label < 0.0 || label >= c as f64 {
                    return Err(bad(&format!("label out of range for {c} classes")));
                }
                labels.push(label as usize);
            }
        }
        let mut row: Vec<(u32, f32)> = Vec::new();
        for pair in fields {
            let (idx, val) =
                pair.split_once(':').ok_or_else(|| bad("feature without `idx:value`"))?;
            let idx: u32 = idx.parse().map_err(|_| bad("unparseable feature index"))?;
            if idx == 0 {
                return Err(bad("libsvm indices are 1-based; found index 0"));
            }
            let val: f32 = val.parse().map_err(|_| bad("unparseable feature value"))?;
            let col = idx - 1;
            if let Some(&(prev, _)) = row.last() {
                if prev >= col {
                    return Err(bad("feature indices must be strictly increasing"));
                }
            }
            max_col = max_col.max(col);
            row.push((col, val));
        }
        x.push_row(&row);
    }
    x.n_cols = if x.nnz() == 0 { 0 } else { max_col as usize + 1 };
    let ds = SparseDataset { name: name.to_string(), x, targets, labels, task };
    ds.validate().map_err(|e| crate::anyhow!("{}: {e}", path.display()))?;
    Ok(ds)
}

/// Write a dataset as CSV (feature columns `f0..f{d-1}` plus `target`).
pub fn write_csv(data: &Dataset, path: &Path) -> crate::error::Result<()> {
    let mut out = std::io::BufWriter::new(std::fs::File::create(path)?);
    let header: Vec<String> =
        (0..data.n_features()).map(|f| format!("f{f}")).chain(["target".to_string()]).collect();
    writeln!(out, "{}", header.join(","))?;
    for i in 0..data.n_rows() {
        let mut fields: Vec<String> =
            data.features.iter().map(|col| format!("{}", col[i])).collect();
        let target = match data.task {
            Task::Regression => format!("{}", data.targets[i]),
            _ => format!("{}", data.labels[i]),
        };
        fields.push(target);
        writeln!(out, "{}", fields.join(","))?;
    }
    Ok(())
}

#[cfg(test)]
#[cfg(not(miri))] // trains models / generates datasets - too slow under the Miri interpreter
mod tests {
    use super::*;
    use crate::data::synth::PaperDataset;

    #[test]
    fn roundtrip_classification() {
        let d = PaperDataset::BreastCancer.generate(1);
        let dir = std::env::temp_dir();
        let path = dir.join("toad_test_bc.csv");
        write_csv(&d, &path).unwrap();
        let r = read_csv(&path, "breastcancer", Task::Binary).unwrap();
        assert_eq!(r.n_rows(), d.n_rows());
        assert_eq!(r.n_features(), d.n_features());
        assert_eq!(r.labels, d.labels);
        assert_eq!(r.features[3], d.features[3]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn roundtrip_regression() {
        let mut d = PaperDataset::Kin8nm.generate(2);
        // shrink for test speed
        let idx: Vec<usize> = (0..200).collect();
        d = d.select(&idx);
        let path = std::env::temp_dir().join("toad_test_kin.csv");
        write_csv(&d, &path).unwrap();
        let r = read_csv(&path, "kin8nm", Task::Regression).unwrap();
        assert_eq!(r.n_rows(), 200);
        for (a, b) in r.targets.iter().zip(&d.targets) {
            assert!((a - b).abs() < 1e-9 || (a - b).abs() / b.abs() < 1e-6);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn target_only_csv_reports_rows() {
        // A CSV with only the `target` column parses to a feature-less
        // dataset; `Dataset::n_rows` must fall back to the target count
        // rather than reporting 0 rows.
        let path = std::env::temp_dir().join("toad_test_target_only.csv");
        std::fs::write(&path, "target\n1.5\n2.5\n3.5\n").unwrap();
        let d = read_csv(&path, "t", Task::Regression).unwrap();
        assert_eq!(d.n_features(), 0);
        assert_eq!(d.n_rows(), 3);
        assert_eq!(d.targets, vec![1.5, 2.5, 3.5]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_missing_target() {
        let path = std::env::temp_dir().join("toad_test_bad.csv");
        std::fs::write(&path, "a,b\n1,2\n").unwrap();
        assert!(read_csv(&path, "x", Task::Binary).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_ragged_rows() {
        let path = std::env::temp_dir().join("toad_test_ragged.csv");
        std::fs::write(&path, "f0,target\n1,0\n1,2,3\n").unwrap();
        assert!(read_csv(&path, "x", Task::Binary).is_err());
        std::fs::remove_file(&path).ok();
    }

    fn libsvm_file(tag: &str, body: &str) -> std::path::PathBuf {
        let path = std::env::temp_dir().join(format!("toad_test_libsvm_{tag}.txt"));
        std::fs::write(&path, body).unwrap();
        path
    }

    #[test]
    fn libsvm_parses_regression_rows() {
        let path = libsvm_file(
            "reg",
            "# comment line\n1.5 1:0.5 3:-2.0\n\n-0.25 2:1.0\n0 \n",
        );
        let d = read_libsvm(&path, "reg", Task::Regression).unwrap();
        assert_eq!(d.n_rows(), 3);
        assert_eq!(d.n_features(), 3); // max index 3 → 3 columns
        assert_eq!(d.targets, vec![1.5, -0.25, 0.0]);
        assert_eq!(d.x.row(0), (&[0u32, 2][..], &[0.5f32, -2.0][..]));
        assert_eq!(d.x.row(1), (&[1u32][..], &[1.0f32][..]));
        assert_eq!(d.x.row(2), (&[][..], &[][..]));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn libsvm_binary_maps_signed_labels() {
        let path = libsvm_file("bin", "+1 1:2.0\n-1 2:3.0\n");
        let d = read_libsvm(&path, "bin", Task::Binary).unwrap();
        assert_eq!(d.labels, vec![1, 0]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn libsvm_nan_value_is_present_not_absent() {
        let path = libsvm_file("nan", "1.0 1:nan 2:1.0\n");
        let d = read_libsvm(&path, "nan", Task::Regression).unwrap();
        assert!(d.x.row(0).1[0].is_nan());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn libsvm_rejects_malformed_lines_cleanly() {
        // Each malformed body must produce an `Err` (never a panic) that
        // names the offending line.
        let cases: &[(&str, &str)] = &[
            ("truncated", "1.0 3:\n"),
            ("no_colon", "1.0 3\n"),
            ("garbage", "1.0 banana\n"),
            ("garbage_idx", "1.0 x:1.5\n"),
            ("idx_zero", "1.0 0:1.5\n"),
            ("out_of_order", "1.0 2:1.0 2:2.0\n"),
            ("decreasing", "1.0 3:1.0 1:2.0\n"),
            ("bad_label", "cat 1:1.0\n"),
            ("empty_line_label", "1:1.0\n"), // bare pair: label slot unparseable
        ];
        for (tag, body) in cases {
            let path = libsvm_file(tag, body);
            let err = read_libsvm(&path, "x", Task::Regression).unwrap_err();
            assert!(
                err.to_string().contains("line 1"),
                "{tag}: error should name the line, got: {err}"
            );
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn libsvm_rejects_out_of_range_multiclass_label() {
        let path = libsvm_file("mc", "3 1:1.0\n");
        assert!(read_libsvm(&path, "mc", Task::Multiclass(3)).is_err());
        let path2 = libsvm_file("mc_ok", "2 1:1.0\n0 2:1.0\n");
        let d = read_libsvm(&path2, "mc", Task::Multiclass(3)).unwrap();
        assert_eq!(d.labels, vec![2, 0]);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&path2).ok();
    }
}
