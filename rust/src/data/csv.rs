//! Minimal CSV reader/writer for [`Dataset`]s.
//!
//! Real data can be dropped into the experiments through this module
//! (replacing the synthetic generators) — the format is a plain numeric
//! CSV with a header row; the label/target column is named `target`.
//! No external CSV crate is available offline, so this is a small,
//! strict parser: numeric fields only, comma separator, no quoting.

use super::dataset::{Dataset, Task};
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

/// Read a dataset from CSV. The column named `target` (any position)
/// becomes the label/target; `task` tells how to interpret it.
pub fn read_csv(path: &Path, name: &str, task: Task) -> crate::error::Result<Dataset> {
    let file = std::fs::File::open(path)?;
    let mut lines = BufReader::new(file).lines();
    let header = lines.next().ok_or_else(|| crate::anyhow!("empty csv"))??;
    let cols: Vec<&str> = header.split(',').map(|s| s.trim()).collect();
    let target_idx = cols
        .iter()
        .position(|&c| c == "target")
        .ok_or_else(|| crate::anyhow!("no `target` column in {path:?}"))?;
    let n_features = cols.len() - 1;

    let mut features: Vec<Vec<f32>> = vec![Vec::new(); n_features];
    let mut targets: Vec<f64> = Vec::new();
    let mut labels: Vec<usize> = Vec::new();

    for (lineno, line) in lines.enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(|s| s.trim()).collect();
        if fields.len() != cols.len() {
            crate::bail!("line {}: {} fields, expected {}", lineno + 2, fields.len(), cols.len());
        }
        let mut fi = 0usize;
        for (c, field) in fields.iter().enumerate() {
            if c == target_idx {
                match task {
                    Task::Regression => targets.push(field.parse::<f64>()?),
                    _ => labels.push(field.parse::<f64>()? as usize),
                }
            } else {
                features[fi].push(field.parse::<f32>()?);
                fi += 1;
            }
        }
    }
    let ds = Dataset { name: name.to_string(), features, targets, labels, task };
    ds.validate().map_err(|e| crate::anyhow!(e))?;
    Ok(ds)
}

/// Write a dataset as CSV (feature columns `f0..f{d-1}` plus `target`).
pub fn write_csv(data: &Dataset, path: &Path) -> crate::error::Result<()> {
    let mut out = std::io::BufWriter::new(std::fs::File::create(path)?);
    let header: Vec<String> =
        (0..data.n_features()).map(|f| format!("f{f}")).chain(["target".to_string()]).collect();
    writeln!(out, "{}", header.join(","))?;
    for i in 0..data.n_rows() {
        let mut fields: Vec<String> =
            data.features.iter().map(|col| format!("{}", col[i])).collect();
        let target = match data.task {
            Task::Regression => format!("{}", data.targets[i]),
            _ => format!("{}", data.labels[i]),
        };
        fields.push(target);
        writeln!(out, "{}", fields.join(","))?;
    }
    Ok(())
}

#[cfg(test)]
#[cfg(not(miri))] // trains models / generates datasets - too slow under the Miri interpreter
mod tests {
    use super::*;
    use crate::data::synth::PaperDataset;

    #[test]
    fn roundtrip_classification() {
        let d = PaperDataset::BreastCancer.generate(1);
        let dir = std::env::temp_dir();
        let path = dir.join("toad_test_bc.csv");
        write_csv(&d, &path).unwrap();
        let r = read_csv(&path, "breastcancer", Task::Binary).unwrap();
        assert_eq!(r.n_rows(), d.n_rows());
        assert_eq!(r.n_features(), d.n_features());
        assert_eq!(r.labels, d.labels);
        assert_eq!(r.features[3], d.features[3]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn roundtrip_regression() {
        let mut d = PaperDataset::Kin8nm.generate(2);
        // shrink for test speed
        let idx: Vec<usize> = (0..200).collect();
        d = d.select(&idx);
        let path = std::env::temp_dir().join("toad_test_kin.csv");
        write_csv(&d, &path).unwrap();
        let r = read_csv(&path, "kin8nm", Task::Regression).unwrap();
        assert_eq!(r.n_rows(), 200);
        for (a, b) in r.targets.iter().zip(&d.targets) {
            assert!((a - b).abs() < 1e-9 || (a - b).abs() / b.abs() < 1e-6);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn target_only_csv_reports_rows() {
        // A CSV with only the `target` column parses to a feature-less
        // dataset; `Dataset::n_rows` must fall back to the target count
        // rather than reporting 0 rows.
        let path = std::env::temp_dir().join("toad_test_target_only.csv");
        std::fs::write(&path, "target\n1.5\n2.5\n3.5\n").unwrap();
        let d = read_csv(&path, "t", Task::Regression).unwrap();
        assert_eq!(d.n_features(), 0);
        assert_eq!(d.n_rows(), 3);
        assert_eq!(d.targets, vec![1.5, 2.5, 3.5]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_missing_target() {
        let path = std::env::temp_dir().join("toad_test_bad.csv");
        std::fs::write(&path, "a,b\n1,2\n").unwrap();
        assert!(read_csv(&path, "x", Task::Binary).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_ragged_rows() {
        let path = std::env::temp_dir().join("toad_test_ragged.csv");
        std::fs::write(&path, "f0,target\n1,0\n1,2,3\n").unwrap();
        assert!(read_csv(&path, "x", Task::Binary).is_err());
        std::fs::remove_file(&path).ok();
    }
}
