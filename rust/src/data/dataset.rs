//! Core dataset representation.
//!
//! Features are stored **column-major** (`features[f][i]`): histogram
//! construction, binning, and split finding all scan one feature at a
//! time, so this is the cache-friendly orientation for the training path.

/// Learning task of a dataset. The paper uses accuracy for the two
/// classification flavours and R² for regression (§4.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    Regression,
    Binary,
    /// Multiclass with the given number of classes; boosted trees train
    /// one ensemble per class (one-vs-all softmax), as the paper notes.
    Multiclass(usize),
}

impl Task {
    /// Number of boosting ensembles the task requires.
    pub fn n_ensembles(&self) -> usize {
        match self {
            Task::Regression | Task::Binary => 1,
            Task::Multiclass(c) => *c,
        }
    }

    pub fn is_classification(&self) -> bool {
        !matches!(self, Task::Regression)
    }

    pub fn n_classes(&self) -> usize {
        match self {
            Task::Regression => 0,
            Task::Binary => 2,
            Task::Multiclass(c) => *c,
        }
    }
}

/// An in-memory tabular dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    /// Column-major feature matrix: `features[f][i]` is feature `f` of row `i`.
    pub features: Vec<Vec<f32>>,
    /// Regression targets (empty for classification).
    pub targets: Vec<f64>,
    /// Class labels in `0..n_classes` (empty for regression).
    pub labels: Vec<usize>,
    pub task: Task,
}

impl Dataset {
    /// Row count. With no feature columns (degenerate but reachable —
    /// e.g. a CSV holding only the `target` column) the count falls
    /// back to the target/label length instead of reporting 0 rows.
    pub fn n_rows(&self) -> usize {
        self.features
            .first()
            .map_or_else(|| self.targets.len().max(self.labels.len()), |c| c.len())
    }

    pub fn n_features(&self) -> usize {
        self.features.len()
    }

    /// Row accessor (allocates); the hot paths never use this — they scan
    /// columns — but examples and the serving path do.
    pub fn row(&self, i: usize) -> Vec<f32> {
        self.features.iter().map(|c| c[i]).collect()
    }

    /// Select a subset of rows by index, preserving order.
    pub fn select(&self, idx: &[usize]) -> Dataset {
        Dataset {
            name: self.name.clone(),
            features: self
                .features
                .iter()
                .map(|col| idx.iter().map(|&i| col[i]).collect())
                .collect(),
            targets: if self.targets.is_empty() {
                vec![]
            } else {
                idx.iter().map(|&i| self.targets[i]).collect()
            },
            labels: if self.labels.is_empty() {
                vec![]
            } else {
                idx.iter().map(|&i| self.labels[i]).collect()
            },
            task: self.task,
        }
    }

    /// Validate internal consistency (row counts, label ranges).
    pub fn validate(&self) -> Result<(), String> {
        let n = self.n_rows();
        for (f, col) in self.features.iter().enumerate() {
            if col.len() != n {
                return Err(format!("feature {f} has {} rows, expected {n}", col.len()));
            }
        }
        match self.task {
            Task::Regression => {
                if self.targets.len() != n {
                    return Err(format!("targets {} != rows {n}", self.targets.len()));
                }
            }
            Task::Binary | Task::Multiclass(_) => {
                if self.labels.len() != n {
                    return Err(format!("labels {} != rows {n}", self.labels.len()));
                }
                let c = self.task.n_classes();
                if let Some(&bad) = self.labels.iter().find(|&&l| l >= c) {
                    return Err(format!("label {bad} out of range 0..{c}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        Dataset {
            name: "toy".into(),
            features: vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]],
            targets: vec![],
            labels: vec![0, 1, 0],
            task: Task::Binary,
        }
    }

    #[test]
    fn shape_accessors() {
        let d = toy();
        assert_eq!(d.n_rows(), 3);
        assert_eq!(d.n_features(), 2);
        assert_eq!(d.row(1), vec![2.0, 5.0]);
        d.validate().unwrap();
    }

    #[test]
    fn select_preserves_order() {
        let d = toy();
        let s = d.select(&[2, 0]);
        assert_eq!(s.n_rows(), 2);
        assert_eq!(s.features[0], vec![3.0, 1.0]);
        assert_eq!(s.labels, vec![0, 0]);
    }

    #[test]
    fn validate_catches_bad_label() {
        let mut d = toy();
        d.labels[0] = 7;
        assert!(d.validate().is_err());
    }

    #[test]
    fn validate_catches_ragged() {
        let mut d = toy();
        d.features[1].pop();
        assert!(d.validate().is_err());
    }

    #[test]
    fn n_rows_falls_back_to_targets_or_labels_without_features() {
        // Regression shape: feature-less dataset must still report its
        // row count (previously 0, which made validate() pass vacuously
        // and downstream loops silently skip every row).
        let d = Dataset {
            name: "no-features".into(),
            features: vec![],
            targets: vec![1.0, 2.0, 3.0],
            labels: vec![],
            task: Task::Regression,
        };
        assert_eq!(d.n_rows(), 3);
        d.validate().unwrap();

        let c = Dataset {
            name: "no-features-cls".into(),
            features: vec![],
            targets: vec![],
            labels: vec![0, 1],
            task: Task::Binary,
        };
        assert_eq!(c.n_rows(), 2);
        c.validate().unwrap();
        assert!(c.row(0).is_empty());
    }

    #[test]
    fn task_ensembles() {
        assert_eq!(Task::Regression.n_ensembles(), 1);
        assert_eq!(Task::Binary.n_ensembles(), 1);
        assert_eq!(Task::Multiclass(7).n_ensembles(), 7);
        assert_eq!(Task::Multiclass(7).n_classes(), 7);
        assert!(Task::Binary.is_classification());
        assert!(!Task::Regression.is_classification());
    }
}
