//! Dataset substrate: representation, binning, splits, synthesis, I/O.
//!
//! The paper evaluates on eight public tabular datasets (Appendix B).
//! This environment is offline, so [`synth`] re-creates each dataset's
//! *schema and learning character* (feature count, feature kinds, task,
//! size, noise/redundancy profile) with deterministic generators — see
//! DESIGN.md §5 for the substitution rationale. Everything downstream
//! (trainers, sweeps, benches) consumes the same [`Dataset`] type, so
//! real CSV data can be dropped in via [`csv`].

pub mod binmatrix;
pub mod binning;
pub mod csv;
pub mod dataset;
pub mod sparse;
pub mod splits;
pub mod synth;

pub use binmatrix::{BinColumns, BinMatrix, BinSource, ChunkedBinMatrix};
pub use binning::{Binner, SPARSE_DENSITY_THRESHOLD};
pub use dataset::{Dataset, Task};
pub use sparse::{train_test_split_sparse, CsrMatrix, SparseDataset};
pub use splits::{kfold, train_test_split, train_valid_test_split};
