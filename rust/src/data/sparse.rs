//! Sparse (CSR) feature matrices and datasets.
//!
//! High-dimensional telemetry (one-hot/categorical-heavy, hashed
//! features) is mostly *absent*: a cell that is not stored carries the
//! implicit value `0.0`. [`CsrMatrix`] is the standard compressed
//! sparse row triple (`row_ptr` / `col_idx` / `values`) over `f32`
//! values, and [`SparseDataset`] pairs it with the same target/label/
//! task fields as the dense [`Dataset`] so the training and scoring
//! surfaces mirror each other.
//!
//! Semantics pinned here and relied on by the whole sparse pipeline
//! (`data::binning`, `gbdt::histogram`, `inference::quantized`):
//!
//! * an **absent** cell means exactly `0.0` — densifying and training
//!   dense must see the same values the sparse path sees;
//! * a **present** `0.0` (an explicitly stored zero) is legal and
//!   equivalent to an absent cell value-wise; it is kept verbatim in
//!   the stored representation;
//! * a **present NaN is not an absent cell**: NaN keeps its dense
//!   meaning (skipped by the binner fit, routed to the top bin when
//!   binned) and never collapses to the implicit `0.0`;
//! * column indices within a row are **strictly increasing** — the
//!   loaders and generators produce this order and [`CsrMatrix::validate`]
//!   enforces it, so per-column row lists derived from a CSR walk are
//!   ascending by construction (the add order every sparse kernel pins).

use super::dataset::{Dataset, Task};

/// Compressed sparse row matrix over `f32` values.
///
/// `row_ptr` has `n_rows + 1` entries; row `i` owns
/// `col_idx[row_ptr[i]..row_ptr[i+1]]` / `values[..]`, with strictly
/// increasing column indices inside each row.
#[derive(Clone, Debug, Default)]
pub struct CsrMatrix {
    pub n_rows: usize,
    pub n_cols: usize,
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<u32>,
    pub values: Vec<f32>,
}

impl CsrMatrix {
    /// An empty matrix with `n_cols` columns and no rows.
    pub fn empty(n_cols: usize) -> CsrMatrix {
        CsrMatrix { n_rows: 0, n_cols, row_ptr: vec![0], col_idx: Vec::new(), values: Vec::new() }
    }

    /// Number of stored (present) entries.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Fraction of stored cells, `nnz / (rows × cols)` (`0.0` when
    /// either dimension is zero).
    pub fn density(&self) -> f64 {
        let cells = self.n_rows * self.n_cols;
        if cells == 0 {
            0.0
        } else {
            self.nnz() as f64 / cells as f64
        }
    }

    /// The stored entries of row `i` as `(column indices, values)`.
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (s, e) = (self.row_ptr[i], self.row_ptr[i + 1]);
        (&self.col_idx[s..e], &self.values[s..e])
    }

    /// Append one row given its `(col, value)` pairs (columns must be
    /// strictly increasing and `< n_cols`; checked by `validate`, not
    /// here).
    pub fn push_row(&mut self, entries: &[(u32, f32)]) {
        for &(c, v) in entries {
            self.col_idx.push(c);
            self.values.push(v);
        }
        self.n_rows += 1;
        self.row_ptr.push(self.col_idx.len());
    }

    /// Structural invariants: pointer shape, monotone `row_ptr`,
    /// in-range and strictly increasing column indices per row.
    pub fn validate(&self) -> Result<(), String> {
        if self.row_ptr.len() != self.n_rows + 1 {
            return Err(format!(
                "row_ptr has {} entries, expected n_rows + 1 = {}",
                self.row_ptr.len(),
                self.n_rows + 1
            ));
        }
        if self.row_ptr[0] != 0 || *self.row_ptr.last().unwrap() != self.col_idx.len() {
            return Err("row_ptr must start at 0 and end at nnz".into());
        }
        if self.col_idx.len() != self.values.len() {
            return Err("col_idx and values lengths differ".into());
        }
        for i in 0..self.n_rows {
            let (s, e) = (self.row_ptr[i], self.row_ptr[i + 1]);
            if s > e {
                return Err(format!("row_ptr not monotone at row {i}"));
            }
            let cols = &self.col_idx[s..e];
            for (k, &c) in cols.iter().enumerate() {
                if c as usize >= self.n_cols {
                    return Err(format!("row {i}: column {c} out of range ({})", self.n_cols));
                }
                if k > 0 && cols[k - 1] >= c {
                    return Err(format!(
                        "row {i}: column indices not strictly increasing ({} then {c})",
                        cols[k - 1]
                    ));
                }
            }
        }
        Ok(())
    }

    /// Column-major view: per column, the `(ascending row indices,
    /// values)` of its present entries. One counting pass over the CSR
    /// body — rows are walked in order, so each column's row list comes
    /// out ascending (the order the sparse kernels pin).
    pub fn to_columns(&self) -> Vec<(Vec<u32>, Vec<f32>)> {
        let mut counts = vec![0usize; self.n_cols];
        for &c in &self.col_idx {
            counts[c as usize] += 1;
        }
        let mut out: Vec<(Vec<u32>, Vec<f32>)> = counts
            .iter()
            .map(|&c| (Vec::with_capacity(c), Vec::with_capacity(c)))
            .collect();
        for i in 0..self.n_rows {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                let slot = &mut out[c as usize];
                slot.0.push(i as u32);
                slot.1.push(v);
            }
        }
        out
    }

    /// Dense column-major mirror: absent cells become `0.0`, present
    /// entries (including explicit zeros and NaNs) are kept verbatim.
    pub fn densify(&self) -> Vec<Vec<f32>> {
        let mut cols = vec![vec![0f32; self.n_rows]; self.n_cols];
        for i in 0..self.n_rows {
            let (cidx, vals) = self.row(i);
            for (&c, &v) in cidx.iter().zip(vals) {
                cols[c as usize][i] = v;
            }
        }
        cols
    }

    /// Rows `idx` (in the given order) as a new matrix.
    pub fn select(&self, idx: &[usize]) -> CsrMatrix {
        let mut out = CsrMatrix::empty(self.n_cols);
        for &i in idx {
            let (s, e) = (self.row_ptr[i], self.row_ptr[i + 1]);
            out.col_idx.extend_from_slice(&self.col_idx[s..e]);
            out.values.extend_from_slice(&self.values[s..e]);
            out.n_rows += 1;
            out.row_ptr.push(out.col_idx.len());
        }
        out
    }
}

/// A sparse dataset: CSR features plus the same target/label/task
/// fields as [`Dataset`]. [`SparseDataset::densify`] produces the exact
/// dense equivalent (absent → `0.0`), which is what every bit-parity
/// test trains against.
#[derive(Clone, Debug)]
pub struct SparseDataset {
    pub name: String,
    pub x: CsrMatrix,
    pub targets: Vec<f64>,
    pub labels: Vec<usize>,
    pub task: Task,
}

impl SparseDataset {
    pub fn n_rows(&self) -> usize {
        self.x.n_rows
    }

    pub fn n_features(&self) -> usize {
        self.x.n_cols
    }

    /// The dense equivalent dataset: absent cells become `0.0`,
    /// everything else (name, targets, labels, task) is carried over.
    pub fn densify(&self) -> Dataset {
        Dataset {
            name: self.name.clone(),
            features: self.x.densify(),
            targets: self.targets.clone(),
            labels: self.labels.clone(),
            task: self.task,
        }
    }

    /// Rows `idx` (in the given order) as a new dataset.
    pub fn select(&self, idx: &[usize]) -> SparseDataset {
        SparseDataset {
            name: self.name.clone(),
            x: self.x.select(idx),
            targets: idx.iter().map(|&i| self.targets.get(i).copied().unwrap_or(0.0)).collect(),
            labels: if self.labels.is_empty() {
                Vec::new()
            } else {
                idx.iter().map(|&i| self.labels[i]).collect()
            },
            task: self.task,
        }
    }

    /// Widen the feature space to `n` columns (feature alignment when a
    /// libsvm test file mentions fewer indices than the train file).
    /// Errors if the matrix already has more columns than `n`.
    pub fn pad_features(&mut self, n: usize) -> Result<(), String> {
        if self.x.n_cols > n {
            return Err(format!(
                "cannot shrink feature space: have {} columns, requested {n}",
                self.x.n_cols
            ));
        }
        self.x.n_cols = n;
        Ok(())
    }

    /// Structural + label invariants, mirroring [`Dataset::validate`].
    pub fn validate(&self) -> Result<(), String> {
        self.x.validate()?;
        match self.task {
            Task::Regression => {
                if self.targets.len() != self.x.n_rows {
                    return Err(format!(
                        "{} targets for {} rows",
                        self.targets.len(),
                        self.x.n_rows
                    ));
                }
            }
            _ => {
                if self.labels.len() != self.x.n_rows {
                    return Err(format!("{} labels for {} rows", self.labels.len(), self.x.n_rows));
                }
                let c = self.task.n_classes();
                if let Some(&bad) = self.labels.iter().find(|&&l| l >= c) {
                    return Err(format!("label {bad} out of range for {c} classes"));
                }
            }
        }
        Ok(())
    }
}

/// Random train/test split of a sparse dataset — the **same** shuffle
/// as [`super::splits::train_test_split`] (same seed mix, same index
/// permutation, same rounding), so splitting a sparse dataset and
/// splitting its densified twin select identical rows.
pub fn train_test_split_sparse(
    data: &SparseDataset,
    test_frac: f64,
    seed: u64,
) -> (SparseDataset, SparseDataset) {
    assert!((0.0..1.0).contains(&test_frac));
    let n = data.n_rows();
    let mut idx: Vec<usize> = (0..n).collect();
    let mut rng = crate::prng::Pcg64::new(seed ^ 0x5111_7000);
    rng.shuffle(&mut idx);
    let n_test = ((n as f64) * test_frac).round() as usize;
    let (test_idx, train_idx) = idx.split_at(n_test);
    (data.select(train_idx), data.select(test_idx))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrMatrix {
        let mut m = CsrMatrix::empty(4);
        m.push_row(&[(0, 1.0), (2, -2.0)]);
        m.push_row(&[]);
        m.push_row(&[(1, 0.0), (3, f32::NAN)]);
        m
    }

    #[test]
    fn csr_shape_and_access() {
        let m = sample();
        m.validate().unwrap();
        assert_eq!(m.n_rows, 3);
        assert_eq!(m.nnz(), 4);
        assert_eq!(m.row(0), (&[0u32, 2][..], &[1.0f32, -2.0][..]));
        assert_eq!(m.row(1), (&[][..], &[][..]));
        let cols = m.to_columns();
        assert_eq!(cols[0], (vec![0], vec![1.0]));
        assert_eq!(cols[1], (vec![2], vec![0.0]));
        assert_eq!(cols[2], (vec![0], vec![-2.0]));
        assert_eq!(cols[3].0, vec![2]);
        assert!(cols[3].1[0].is_nan());
    }

    #[test]
    fn densify_fills_absent_with_zero_and_keeps_nan() {
        let d = sample().densify();
        assert_eq!(d[0], vec![1.0, 0.0, 0.0]);
        assert_eq!(d[1], vec![0.0, 0.0, 0.0]); // explicit 0.0 present
        assert_eq!(d[2], vec![-2.0, 0.0, 0.0]);
        assert!(d[3][2].is_nan());
        assert_eq!(d[3][0], 0.0);
    }

    #[test]
    fn validate_rejects_malformed() {
        let mut m = sample();
        m.col_idx[1] = 9; // out of range
        assert!(m.validate().is_err());
        let mut m = sample();
        m.col_idx[1] = 0; // duplicates column 0 in row 0
        assert!(m.validate().is_err());
        let mut m = sample();
        m.row_ptr[1] = 5; // not monotone / past nnz
        assert!(m.validate().is_err());
    }

    #[test]
    fn select_reorders_rows() {
        let m = sample();
        let s = m.select(&[2, 0]);
        s.validate().unwrap();
        assert_eq!(s.n_rows, 2);
        assert_eq!(s.row(1), m.row(0));
        assert_eq!(s.row(0).0, m.row(2).0);
    }

    #[test]
    fn sparse_split_matches_dense_split_rows() {
        // Same permutation as `train_test_split` on the densified twin.
        let mut x = CsrMatrix::empty(3);
        for i in 0..20u32 {
            x.push_row(&[(i % 3, i as f32)]);
        }
        let ds = SparseDataset {
            name: "s".into(),
            x,
            targets: (0..20).map(|i| i as f64).collect(),
            labels: vec![],
            task: Task::Regression,
        };
        let dense = ds.densify();
        let (tr_s, te_s) = train_test_split_sparse(&ds, 0.25, 7);
        let (tr_d, te_d) = super::super::splits::train_test_split(&dense, 0.25, 7);
        assert_eq!(tr_s.targets, tr_d.targets);
        assert_eq!(te_s.targets, te_d.targets);
        assert_eq!(tr_s.densify().features, tr_d.features);
    }
}
