//! Train/validation/test splitting and k-fold cross-validation.
//!
//! The paper uses an 80/20 train/test split with seeds 1–12, 5-fold CV on
//! the two smallest datasets, and a 10% validation carve-out for the
//! bigger ones (§4.1). These helpers reproduce that protocol.

use super::dataset::Dataset;
use crate::prng::Pcg64;

/// Shuffled 80/20-style split; `test_frac` of the rows go to the test set.
pub fn train_test_split(data: &Dataset, test_frac: f64, seed: u64) -> (Dataset, Dataset) {
    assert!((0.0..1.0).contains(&test_frac));
    let n = data.n_rows();
    let mut idx: Vec<usize> = (0..n).collect();
    let mut rng = Pcg64::new(seed ^ 0x5111_7000);
    rng.shuffle(&mut idx);
    let n_test = ((n as f64) * test_frac).round() as usize;
    let (test_idx, train_idx) = idx.split_at(n_test);
    (data.select(train_idx), data.select(test_idx))
}

/// Train / validation / test split matching the paper's protocol for the
/// larger datasets: `test_frac` test, then `valid_frac` of the remaining
/// training rows as validation.
pub fn train_valid_test_split(
    data: &Dataset,
    test_frac: f64,
    valid_frac: f64,
    seed: u64,
) -> (Dataset, Dataset, Dataset) {
    let (train_all, test) = train_test_split(data, test_frac, seed);
    let n = train_all.n_rows();
    let mut idx: Vec<usize> = (0..n).collect();
    let mut rng = Pcg64::new(seed ^ 0x0A11_D000);
    rng.shuffle(&mut idx);
    let n_valid = ((n as f64) * valid_frac).round() as usize;
    let (valid_idx, train_idx) = idx.split_at(n_valid);
    (train_all.select(train_idx), train_all.select(valid_idx), test)
}

/// K-fold cross-validation index sets: returns `k` (train, valid) pairs.
pub fn kfold(n: usize, k: usize, seed: u64) -> Vec<(Vec<usize>, Vec<usize>)> {
    assert!(k >= 2 && k <= n);
    let mut idx: Vec<usize> = (0..n).collect();
    let mut rng = Pcg64::new(seed ^ 0xF01D);
    rng.shuffle(&mut idx);
    (0..k)
        .map(|fold| {
            let lo = fold * n / k;
            let hi = (fold + 1) * n / k;
            let valid: Vec<usize> = idx[lo..hi].to_vec();
            let train: Vec<usize> =
                idx[..lo].iter().chain(idx[hi..].iter()).copied().collect();
            (train, valid)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Task;

    fn ds(n: usize) -> Dataset {
        Dataset {
            name: "t".into(),
            features: vec![(0..n).map(|i| i as f32).collect()],
            targets: (0..n).map(|i| i as f64).collect(),
            labels: vec![],
            task: Task::Regression,
        }
    }

    #[test]
    fn split_sizes_and_disjoint() {
        let d = ds(100);
        let (tr, te) = train_test_split(&d, 0.2, 1);
        assert_eq!(tr.n_rows(), 80);
        assert_eq!(te.n_rows(), 20);
        let mut all: Vec<i64> =
            tr.features[0].iter().chain(te.features[0].iter()).map(|&x| x as i64).collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<i64>>());
    }

    #[test]
    fn split_deterministic_per_seed() {
        let d = ds(50);
        let (a, _) = train_test_split(&d, 0.2, 7);
        let (b, _) = train_test_split(&d, 0.2, 7);
        assert_eq!(a.features[0], b.features[0]);
        let (c, _) = train_test_split(&d, 0.2, 8);
        assert_ne!(a.features[0], c.features[0]);
    }

    #[test]
    fn three_way_split_partitions() {
        let d = ds(200);
        let (tr, va, te) = train_valid_test_split(&d, 0.2, 0.1, 3);
        assert_eq!(te.n_rows(), 40);
        assert_eq!(va.n_rows(), 16);
        assert_eq!(tr.n_rows(), 144);
        let mut all: Vec<i64> = tr.features[0]
            .iter()
            .chain(va.features[0].iter())
            .chain(te.features[0].iter())
            .map(|&x| x as i64)
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..200).collect::<Vec<i64>>());
    }

    #[test]
    fn kfold_partitions_everything() {
        let folds = kfold(103, 5, 9);
        assert_eq!(folds.len(), 5);
        let mut seen: Vec<usize> = folds.iter().flat_map(|(_, v)| v.clone()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..103).collect::<Vec<usize>>());
        for (tr, va) in &folds {
            assert_eq!(tr.len() + va.len(), 103);
            // train and valid are disjoint
            for i in va {
                assert!(!tr.contains(i));
            }
        }
    }
}
