//! Synthetic re-creations of the paper's eight evaluation datasets.
//!
//! The build environment has no network access, so the UCI/OpenML
//! datasets of Appendix B (Table 1) cannot be downloaded. Each generator
//! here reproduces a dataset's *learning character* rather than its rows:
//!
//! * the exact **feature count** and feature *kinds* (continuous,
//!   boolean, small-integer categorical) of the original,
//! * the **task** (regression / binary / multiclass with the original
//!   class count),
//! * a comparable **size** (huge datasets are scaled down; the relative
//!   ordering of dataset sizes is preserved),
//! * a ground truth of tree-like structure (axis-aligned interactions of
//!   a subset of *relevant* features) plus irrelevant/redundant features
//!   and label noise tuned so that achievable test accuracy is in the
//!   ballpark the paper reports.
//!
//! The experiments in the paper measure *relative* behaviour — which
//! method reaches which score under a memory budget, and how penalties
//! move feature/threshold counts — which depends on these structural
//! properties, not on the literal UCI rows (DESIGN.md §5).

use super::dataset::{Dataset, Task};
use crate::prng::Pcg64;

/// Identifiers for the eight paper datasets (Table 1) plus the binary
/// Covertype variant used in Figure 4 and Table 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PaperDataset {
    /// Covertype, 54 features, 7-class (paper: 581,012 rows; scaled down).
    Covertype,
    /// Binary variant of Covertype (class 2 vs rest), as in Fig. 4/Table 2.
    CovertypeBinary,
    /// California Housing, 8 features, regression.
    CaliforniaHousing,
    /// kin8nm robot-arm dynamics, 8 features, regression (highly nonlinear).
    Kin8nm,
    /// Mushroom, 22 categorical features, binary, ~perfectly separable.
    Mushroom,
    /// Wine Quality, 11 features, multiclass (7 ordinal quality levels).
    WineQuality,
    /// kr-vs-kp chess endgames, 36 boolean-ish features, binary.
    KrVsKp,
    /// Breast Cancer Wisconsin (diagnostic), 30 features, binary.
    BreastCancer,
}

impl PaperDataset {
    /// The eight distinct datasets of Table 1 (plus the binary Covertype
    /// variant used by Figure 4 and Table 2).
    pub const TABLE1: [PaperDataset; 8] = [
        PaperDataset::Covertype,
        PaperDataset::CaliforniaHousing,
        PaperDataset::Kin8nm,
        PaperDataset::Mushroom,
        PaperDataset::WineQuality,
        PaperDataset::KrVsKp,
        PaperDataset::BreastCancer,
        PaperDataset::CovertypeBinary,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            PaperDataset::Covertype => "covtype",
            PaperDataset::CovertypeBinary => "covtype_binary",
            PaperDataset::CaliforniaHousing => "california_housing",
            PaperDataset::Kin8nm => "kin8nm",
            PaperDataset::Mushroom => "mushroom",
            PaperDataset::WineQuality => "wine_quality",
            PaperDataset::KrVsKp => "kr_vs_kp",
            PaperDataset::BreastCancer => "breastcancer",
        }
    }

    /// Paper row counts (Table 1); the generator scales huge ones down.
    pub fn paper_rows(&self) -> usize {
        match self {
            PaperDataset::Covertype | PaperDataset::CovertypeBinary => 581_012,
            PaperDataset::CaliforniaHousing => 20_640,
            PaperDataset::Kin8nm => 8_192,
            PaperDataset::Mushroom => 8_124,
            PaperDataset::WineQuality => 6_497,
            PaperDataset::KrVsKp => 3_196,
            PaperDataset::BreastCancer => 569,
        }
    }

    /// Rows actually generated (Covertype scaled to keep sweeps tractable).
    pub fn gen_rows(&self) -> usize {
        match self {
            PaperDataset::Covertype | PaperDataset::CovertypeBinary => 24_000,
            other => other.paper_rows(),
        }
    }

    pub fn n_features(&self) -> usize {
        match self {
            PaperDataset::Covertype | PaperDataset::CovertypeBinary => 54,
            PaperDataset::CaliforniaHousing => 8,
            PaperDataset::Kin8nm => 8,
            PaperDataset::Mushroom => 22,
            PaperDataset::WineQuality => 11,
            PaperDataset::KrVsKp => 36,
            PaperDataset::BreastCancer => 30,
        }
    }

    pub fn task(&self) -> Task {
        match self {
            PaperDataset::Covertype => Task::Multiclass(7),
            PaperDataset::CovertypeBinary => Task::Binary,
            PaperDataset::CaliforniaHousing | PaperDataset::Kin8nm => Task::Regression,
            PaperDataset::Mushroom | PaperDataset::KrVsKp | PaperDataset::BreastCancer => {
                Task::Binary
            }
            PaperDataset::WineQuality => Task::Multiclass(7),
        }
    }

    /// Generate the synthetic stand-in with a deterministic seed.
    pub fn generate(&self, seed: u64) -> Dataset {
        let mut rng = Pcg64::new(seed ^ fxhash(self.name()));
        match self {
            PaperDataset::Covertype => gen_covertype(&mut rng, self.gen_rows(), false),
            PaperDataset::CovertypeBinary => gen_covertype(&mut rng, self.gen_rows(), true),
            PaperDataset::CaliforniaHousing => gen_california(&mut rng, self.gen_rows()),
            PaperDataset::Kin8nm => gen_kin8nm(&mut rng, self.gen_rows()),
            PaperDataset::Mushroom => gen_mushroom(&mut rng, self.gen_rows()),
            PaperDataset::WineQuality => gen_wine(&mut rng, self.gen_rows()),
            PaperDataset::KrVsKp => gen_krvskp(&mut rng, self.gen_rows()),
            PaperDataset::BreastCancer => gen_breast_cancer(&mut rng, self.gen_rows()),
        }
    }
}

/// Tiny FNV-style string hash to decorrelate per-dataset seeds.
fn fxhash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Covertype: 10 continuous terrain features + 4 wilderness one-hot +
/// 40 soil-type one-hot; 7 forest cover classes driven by elevation
/// bands, slope/aspect interactions, and soil groups. Binary variant
/// predicts class 1 (lodgepole pine, the majority class) vs rest.
fn gen_covertype(rng: &mut Pcg64, n: usize, binary: bool) -> Dataset {
    let d = 54;
    let mut features = vec![vec![0f32; n]; d];
    let mut labels = vec![0usize; n];
    for i in 0..n {
        let elevation = 1800.0 + 1600.0 * rng.gen_f64(); // meters
        let aspect = 360.0 * rng.gen_f64();
        let slope = 35.0 * rng.gen_f64().powi(2);
        let h_dist_water = 600.0 * rng.gen_f64();
        let v_dist_water = 200.0 * rng.gen_f64() - 50.0;
        let h_dist_road = 3000.0 * rng.gen_f64();
        let hillshade_9 = 150.0 + 100.0 * rng.gen_f64();
        let hillshade_noon = 180.0 + 70.0 * rng.gen_f64();
        let hillshade_3 = 100.0 + 140.0 * rng.gen_f64();
        let h_dist_fire = 3500.0 * rng.gen_f64();
        let wilderness = rng.gen_range(4);
        // Soil correlates with elevation band, as in the real data.
        let band = ((elevation - 1800.0) / 400.0) as usize; // 0..4
        let soil = (band * 10 + rng.gen_range(10)).min(39);

        let cont = [
            elevation,
            aspect,
            slope,
            h_dist_water,
            v_dist_water,
            h_dist_road,
            hillshade_9,
            hillshade_noon,
            hillshade_3,
            h_dist_fire,
        ];
        for (f, &v) in cont.iter().enumerate() {
            features[f][i] = v as f32;
        }
        features[10 + wilderness][i] = 1.0;
        features[14 + soil][i] = 1.0;

        // Class logic: elevation bands dominate (as in the real data,
        // where elevation is by far the most important feature), modified
        // by moisture (water distances), wilderness area and soil group.
        let moisture = 1.0 - (h_dist_water / 600.0) * 0.5 - (v_dist_water.max(0.0) / 200.0) * 0.5;
        let score = elevation + 80.0 * moisture * 100.0 / 100.0 + 30.0 * (soil / 10) as f64
            - 2.0 * slope
            + 40.0 * wilderness as f64;
        let noisy = score + 90.0 * rng.gen_normal();
        let class = if noisy < 2050.0 {
            2 // ponderosa / low-elevation species
        } else if noisy < 2250.0 {
            if slope > 12.0 { 3 } else { 2 }
        } else if noisy < 2550.0 {
            if moisture > 0.55 { 1 } else { 5 }
        } else if noisy < 2900.0 {
            1 // lodgepole: the big middle band (majority class)
        } else if noisy < 3150.0 {
            if wilderness == 0 { 0 } else { 6 }
        } else if noisy < 3300.0 {
            0 // spruce/fir
        } else {
            4 // krummholz
        };
        labels[i] = if binary { (class == 1) as usize } else { class };
    }
    Dataset {
        name: if binary { "covtype_binary".into() } else { "covtype".into() },
        features,
        targets: vec![],
        labels,
        task: if binary { Task::Binary } else { Task::Multiclass(7) },
    }
}

/// California Housing: 8 continuous features; median house value driven
/// mostly by median income with location/age/occupancy modifiers,
/// heteroscedastic noise and a value cap — mirroring the real dataset.
fn gen_california(rng: &mut Pcg64, n: usize) -> Dataset {
    let d = 8;
    let mut features = vec![vec![0f32; n]; d];
    let mut targets = vec![0f64; n];
    for i in 0..n {
        let med_inc = 0.5 + 14.5 * rng.gen_f64().powf(1.8); // skewed like the real MedInc
        let house_age = 1.0 + 51.0 * rng.gen_f64();
        let ave_rooms = 3.0 + 5.0 * rng.gen_f64() + 0.2 * med_inc;
        let ave_bedrms = 0.8 + 0.4 * rng.gen_f64();
        let population = 3.0 + 3000.0 * rng.gen_f64().powi(2);
        let ave_occup = 1.5 + 4.0 * rng.gen_f64().powi(3);
        let latitude = 32.5 + 9.5 * rng.gen_f64();
        let longitude = -124.3 + 10.0 * rng.gen_f64();

        // Coastal premium: closer to the coast line lat+long relation.
        let coast = (-(longitude + 118.0).abs() / 3.0).exp();
        let v = 0.45 * med_inc + 1.6 * coast + 0.008 * house_age - 0.15 * (ave_occup - 2.5).max(0.0)
            + 0.05 * (ave_rooms - 5.0)
            + 0.25 * rng.gen_normal();
        let v = v.clamp(0.15, 5.0); // the real target is capped at 5.0 ($500k)
        let row = [med_inc, house_age, ave_rooms, ave_bedrms, population, ave_occup, latitude, longitude];
        for (f, &x) in row.iter().enumerate() {
            features[f][i] = x as f32;
        }
        targets[i] = v;
    }
    Dataset {
        name: "california_housing".into(),
        features,
        targets,
        labels: vec![],
        task: Task::Regression,
    }
}

/// kin8nm: forward kinematics of an 8-link robot arm, "nm" = nonlinear,
/// medium noise. We use the actual generative form: end-effector distance
/// from a sum of link rotations with 8 joint angles.
fn gen_kin8nm(rng: &mut Pcg64, n: usize) -> Dataset {
    let d = 8;
    let mut features = vec![vec![0f32; n]; d];
    let mut targets = vec![0f64; n];
    // Fixed link lengths as in the DELVE kin family.
    let links = [0.35, 0.25, 0.2, 0.15, 0.1, 0.08, 0.06, 0.05];
    for i in 0..n {
        let mut x = 0.0f64;
        let mut y = 0.0f64;
        let mut angle = 0.0f64;
        for f in 0..d {
            let theta = (rng.gen_f64() - 0.5) * std::f64::consts::PI; // [-pi/2, pi/2]
            features[f][i] = theta as f32;
            angle += theta;
            x += links[f] * angle.cos();
            y += links[f] * angle.sin();
        }
        let dist = (x * x + y * y).sqrt();
        targets[i] = dist + 0.02 * rng.gen_normal(); // medium noise
    }
    Dataset { name: "kin8nm".into(), features, targets, labels: vec![], task: Task::Regression }
}

/// Mushroom: 22 small-integer categorical features; edibility is an
/// almost-deterministic function of a handful of features (odor dominates
/// in the real data — a single feature nearly separates the classes).
fn gen_mushroom(rng: &mut Pcg64, n: usize) -> Dataset {
    let d = 22;
    let cardinalities: [usize; 22] =
        [6, 4, 10, 2, 9, 2, 2, 2, 12, 2, 5, 4, 4, 9, 9, 2, 4, 3, 5, 9, 6, 7];
    let mut features = vec![vec![0f32; n]; d];
    let mut labels = vec![0usize; n];
    for i in 0..n {
        let mut row = [0usize; 22];
        for f in 0..d {
            row[f] = rng.gen_range(cardinalities[f]);
        }
        // odor (feature 4): values {0..3} ~ pleasant/none, {4..8} ~ foul.
        // Poisonous iff foul odor, or (no odor and spore-print (19) in a
        // bad group and population (20) sparse) — echoing the real rules.
        let odor_foul = row[4] >= 4;
        let spore_bad = row[19] >= 6;
        let pop_sparse = row[20] <= 1;
        let poisonous = odor_foul || (row[4] == 0 && spore_bad && pop_sparse);
        // 0.3% label noise so the task is not literally trivial.
        let flip = rng.gen_bool(0.003);
        labels[i] = (poisonous ^ flip) as usize;
        for f in 0..d {
            features[f][i] = row[f] as f32;
        }
    }
    Dataset { name: "mushroom".into(), features, targets: vec![], labels, task: Task::Binary }
}

/// Wine Quality (red+white merged): 11 physico-chemical features; quality
/// scores form 7 ordinal classes (3–9 mapped to 0–6) with heavy class
/// imbalance centered on medium quality, driven by alcohol and acidity.
fn gen_wine(rng: &mut Pcg64, n: usize) -> Dataset {
    let d = 11;
    let mut features = vec![vec![0f32; n]; d];
    let mut labels = vec![0usize; n];
    for i in 0..n {
        let fixed_acidity = 4.0 + 8.0 * rng.gen_f64();
        let volatile_acidity = 0.1 + 1.0 * rng.gen_f64().powi(2);
        let citric_acid = 0.5 * rng.gen_f64();
        let residual_sugar = 0.5 + 20.0 * rng.gen_f64().powi(3);
        let chlorides = 0.01 + 0.1 * rng.gen_f64().powi(2);
        let free_so2 = 2.0 + 70.0 * rng.gen_f64();
        let total_so2 = free_so2 + 150.0 * rng.gen_f64();
        let density = 0.990 + 0.012 * rng.gen_f64();
        let ph = 2.9 + 0.8 * rng.gen_f64();
        let sulphates = 0.3 + 1.0 * rng.gen_f64().powi(2);
        let alcohol = 8.0 + 6.5 * rng.gen_f64().powf(1.5);

        // Quality: alcohol up, volatile acidity down, sulphates up.
        let q = 5.1 + 0.45 * (alcohol - 10.5) - 2.2 * (volatile_acidity - 0.35)
            + 1.1 * (sulphates - 0.5)
            - 8.0 * (chlorides - 0.05)
            + 0.55 * rng.gen_normal();
        let qi = q.round().clamp(3.0, 9.0) as usize - 3; // 0..6
        labels[i] = qi;
        let row = [
            fixed_acidity, volatile_acidity, citric_acid, residual_sugar, chlorides, free_so2,
            total_so2, density, ph, sulphates, alcohol,
        ];
        for (f, &x) in row.iter().enumerate() {
            features[f][i] = x as f32;
        }
    }
    Dataset {
        name: "wine_quality".into(),
        features,
        targets: vec![],
        labels,
        task: Task::Multiclass(7),
    }
}

/// kr-vs-kp: 36 boolean board-state attributes; "white can win" is a
/// deterministic rule set over attribute conjunctions (the real dataset
/// is noise-free and decision trees reach ~99.5%).
fn gen_krvskp(rng: &mut Pcg64, n: usize) -> Dataset {
    let d = 36;
    let mut features = vec![vec![0f32; n]; d];
    let mut labels = vec![0usize; n];
    for i in 0..n {
        let mut row = [false; 36];
        for (f, r) in row.iter_mut().enumerate() {
            // Some attributes are rare in the real data.
            let p = match f % 5 {
                0 => 0.5,
                1 => 0.35,
                2 => 0.65,
                3 => 0.2,
                _ => 0.5,
            };
            *r = rng.gen_bool(p);
        }
        // Won iff a small DNF over the attributes holds — conjunctions of
        // 2-3 literals, echoing the rule-like structure of the original.
        let won = (row[0] && !row[7] && row[13])
            || (row[4] && row[20])
            || (!row[2] && row[9] && !row[27])
            || (row[31] && row[5] && row[16]);
        labels[i] = won as usize;
        for f in 0..d {
            features[f][i] = row[f] as u8 as f32;
        }
    }
    Dataset { name: "kr_vs_kp".into(), features, targets: vec![], labels, task: Task::Binary }
}

/// Breast Cancer Wisconsin (diagnostic): 30 continuous features in 10
/// correlated triples (mean / SE / worst of each cell-nucleus
/// measurement); malignancy driven by size & concavity, ~97% separable.
fn gen_breast_cancer(rng: &mut Pcg64, n: usize) -> Dataset {
    let d = 30;
    let mut features = vec![vec![0f32; n]; d];
    let mut labels = vec![0usize; n];
    for i in 0..n {
        let malignant = rng.gen_bool(0.37); // real prevalence ~37%
        let shift = if malignant { 1.0 } else { 0.0 };
        // 10 latent measurements; malignant cases are larger/more concave.
        let mut row = [0f64; 30];
        for m in 0..10 {
            let effect: f64 = match m {
                0 | 2 | 3 => 1.6, // radius, perimeter, area: strong
                6 | 7 => 1.3,     // concavity, concave points: strong
                1 | 4 => 0.5,     // texture, smoothness: weak
                _ => 0.25,        // the rest: mostly noise
            };
            let base = rng.gen_normal() + shift * effect;
            row[m] = base; // mean
            row[10 + m] = 0.3 * base.abs() + 0.2 * rng.gen_normal().abs(); // SE
            row[20 + m] = base + 0.5 * rng.gen_normal().abs() + shift * 0.4 * effect;
            // "worst"
        }
        labels[i] = malignant as usize;
        for f in 0..d {
            features[f][i] = row[f] as f32;
        }
    }
    Dataset { name: "breastcancer".into(), features, targets: vec![], labels, task: Task::Binary }
}

/// Feature count of [`synth_rows`].
pub const SYNTH_ROWS_FEATURES: usize = 16;

/// Range-restartable streaming row generator for the out-of-core paths
/// (regression, [`SYNTH_ROWS_FEATURES`] features).
///
/// Each row is generated by a fresh [`Pcg64`] seeded from
/// `(seed, global row index)`, so any block decomposition concatenates
/// to the same rows: `synth_rows(s, a..b)` followed by
/// `synth_rows(s, b..c)` is bit-identical to `synth_rows(s, a..c)`.
/// That is exactly what `Binner::fit_transform_to_disk` needs from its
/// block source — and it means arbitrarily large datasets can be
/// streamed without ever holding more than one block in memory (the CI
/// out-of-core smoke job trains a dataset bigger than its address-space
/// cap this way).
///
/// Feature values are quantized to a 1024-level grid in `[0, 1)`, so
/// per-feature distinct counts are bounded: fitting with
/// `max_bins ≤ 255` yields a u8 arena and `max_bins ≥ 257` (e.g. 400) a
/// u16 arena, letting tests exercise both code widths from one
/// generator. The target is a smooth interaction of the first five
/// features — tree-learnable, exercising non-trivial splits.
///
/// Returns `(column-major features, targets)` for the requested rows.
pub fn synth_rows(seed: u64, range: std::ops::Range<usize>) -> (Vec<Vec<f32>>, Vec<f64>) {
    let d = SYNTH_ROWS_FEATURES;
    let n = range.len();
    let mut features = vec![vec![0f32; n]; d];
    let mut targets = vec![0f64; n];
    for (i, row) in range.enumerate() {
        let row_salt = (row as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let mut rng = Pcg64::new(seed ^ fxhash("synth_rows") ^ row_salt);
        let mut vals = [0f32; SYNTH_ROWS_FEATURES];
        for v in vals.iter_mut() {
            *v = rng.gen_range(1024) as f32 / 1024.0;
        }
        let t = (vals[0] as f64 * 4.0).sin()
            + vals[1] as f64 * 3.0
            + vals[2] as f64 * vals[3] as f64
            - 0.5 * vals[4] as f64;
        for f in 0..d {
            features[f][i] = vals[f];
        }
        targets[i] = t;
    }
    (features, targets)
}

/// Range-restartable streaming **sparse** row generator — the CSR twin
/// of [`synth_rows`], for sparse parity tests and benches.
///
/// Each row draws from a fresh [`Pcg64`] seeded from `(seed, global row
/// index)`, so any block decomposition concatenates exactly:
/// `synth_sparse_rows(s, a..b, ..)` then `synth_sparse_rows(s, b..c, ..)`
/// appends to the same matrix as `synth_sparse_rows(s, a..c, ..)`.
///
/// Per feature, the cell is present with probability `density`; a value
/// is only drawn when present (presence and value draws stay aligned
/// across block boundaries). Values are quantized to a 1024-level grid
/// in `[-0.5, 0.5)`, straddling the implicit `0.0` so the default bin
/// is *interior* — the histogram correction and split routing around it
/// get exercised, not just the degenerate "zero is the lowest bin"
/// case. Draw 512 produces an explicit `0.0`: a present cell whose
/// value equals the implicit one, stored verbatim. The target is the
/// same smooth interaction as `synth_rows`, evaluated over the
/// implicit-zero-filled values.
pub fn synth_sparse_rows(
    seed: u64,
    range: std::ops::Range<usize>,
    n_features: usize,
    density: f64,
) -> (super::sparse::CsrMatrix, Vec<f64>) {
    assert!((0.0..=1.0).contains(&density));
    let present_cut = (density * 1e6) as usize;
    let mut x = super::sparse::CsrMatrix::empty(n_features);
    let mut targets = Vec::with_capacity(range.len());
    let mut row_buf: Vec<(u32, f32)> = Vec::with_capacity(n_features);
    for row in range {
        let row_salt = (row as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let mut rng = Pcg64::new(seed ^ fxhash("synth_sparse_rows") ^ row_salt);
        row_buf.clear();
        let mut vals = [0f32; 5];
        for f in 0..n_features {
            if rng.gen_range(1_000_000) < present_cut {
                let v = (rng.gen_range(1024) as f32 - 512.0) / 1024.0;
                row_buf.push((f as u32, v));
                if f < 5 {
                    vals[f] = v;
                }
            }
        }
        let t = (vals[0] as f64 * 4.0).sin()
            + vals[1] as f64 * 3.0
            + vals[2] as f64 * vals[3] as f64
            - 0.5 * vals[4] as f64;
        x.push_row(&row_buf);
        targets.push(t);
    }
    (x, targets)
}

#[cfg(test)]
#[cfg(not(miri))] // trains models / generates datasets - too slow under the Miri interpreter
mod tests {
    use super::*;

    #[test]
    fn schemas_match_table1() {
        for ds in PaperDataset::TABLE1 {
            let d = ds.generate(1);
            d.validate().unwrap();
            assert_eq!(d.n_features(), ds.n_features(), "{}", ds.name());
            assert_eq!(d.n_rows(), ds.gen_rows(), "{}", ds.name());
            assert_eq!(d.task, ds.task(), "{}", ds.name());
        }
    }

    #[test]
    fn synth_rows_blocks_concatenate_exactly() {
        let (full_x, full_y) = synth_rows(9, 0..100);
        for splits in [vec![0, 1, 100], vec![0, 37, 64, 100], vec![0, 100]] {
            let mut x = vec![Vec::new(); SYNTH_ROWS_FEATURES];
            let mut y = Vec::new();
            for w in splits.windows(2) {
                let (bx, by) = synth_rows(9, w[0]..w[1]);
                for (acc, col) in x.iter_mut().zip(bx) {
                    acc.extend(col);
                }
                y.extend(by);
            }
            assert_eq!(x, full_x);
            assert_eq!(y, full_y);
        }
    }

    #[test]
    fn synth_sparse_rows_blocks_concatenate_exactly() {
        let (full_x, full_y) = synth_sparse_rows(9, 0..100, 24, 0.15);
        full_x.validate().unwrap();
        for splits in [vec![0, 1, 100], vec![0, 37, 64, 100], vec![0, 100]] {
            let mut x = crate::data::sparse::CsrMatrix::empty(24);
            let mut y = Vec::new();
            for w in splits.windows(2) {
                let (bx, by) = synth_sparse_rows(9, w[0]..w[1], 24, 0.15);
                for i in 0..bx.n_rows {
                    let (cols, vals) = bx.row(i);
                    let entries: Vec<(u32, f32)> =
                        cols.iter().zip(vals).map(|(&c, &v)| (c, v)).collect();
                    x.push_row(&entries);
                }
                y.extend(by);
            }
            assert_eq!(x.row_ptr, full_x.row_ptr);
            assert_eq!(x.col_idx, full_x.col_idx);
            assert_eq!(
                x.values.iter().map(|v| v.to_bits()).collect::<Vec<u32>>(),
                full_x.values.iter().map(|v| v.to_bits()).collect::<Vec<u32>>()
            );
            assert_eq!(y, full_y);
        }
    }

    #[test]
    fn synth_sparse_rows_hits_requested_density() {
        let (x, _) = synth_sparse_rows(3, 0..4000, 32, 0.05);
        let d = x.density();
        assert!((0.03..0.07).contains(&d), "density {d} far from 0.05");
        // Values straddle zero (both signs occur).
        assert!(x.values.iter().any(|&v| v < 0.0));
        assert!(x.values.iter().any(|&v| v > 0.0));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = PaperDataset::BreastCancer.generate(5);
        let b = PaperDataset::BreastCancer.generate(5);
        assert_eq!(a.features[0], b.features[0]);
        assert_eq!(a.labels, b.labels);
        let c = PaperDataset::BreastCancer.generate(6);
        assert_ne!(a.features[0], c.features[0]);
    }

    #[test]
    fn class_coverage() {
        // Every declared class must actually occur.
        for ds in [PaperDataset::Covertype, PaperDataset::WineQuality] {
            let d = ds.generate(2);
            let c = d.task.n_classes();
            let mut seen = vec![0usize; c];
            for &l in &d.labels {
                seen[l] += 1;
            }
            for (k, &cnt) in seen.iter().enumerate() {
                assert!(cnt > 0, "{}: class {k} empty", ds.name());
            }
        }
    }

    #[test]
    fn binary_datasets_are_not_degenerate() {
        for ds in [
            PaperDataset::CovertypeBinary,
            PaperDataset::Mushroom,
            PaperDataset::KrVsKp,
            PaperDataset::BreastCancer,
        ] {
            let d = ds.generate(3);
            let pos: usize = d.labels.iter().sum();
            let frac = pos as f64 / d.n_rows() as f64;
            assert!(
                (0.05..=0.95).contains(&frac),
                "{}: positive fraction {frac}",
                ds.name()
            );
        }
    }

    #[test]
    fn regression_targets_have_variance() {
        for ds in [PaperDataset::CaliforniaHousing, PaperDataset::Kin8nm] {
            let d = ds.generate(4);
            let (m, s) = crate::metrics::mean_std(&d.targets);
            assert!(s > 0.05 * m.abs().max(0.1), "{}: std {s} mean {m}", ds.name());
        }
    }

    #[test]
    fn boolean_features_are_binary() {
        let d = PaperDataset::KrVsKp.generate(7);
        for col in &d.features {
            assert!(col.iter().all(|&x| x == 0.0 || x == 1.0));
        }
    }

    #[test]
    fn covertype_onehots_valid() {
        let d = PaperDataset::Covertype.generate(8);
        for i in (0..d.n_rows()).step_by(997) {
            let wsum: f32 = (10..14).map(|f| d.features[f][i]).sum();
            let ssum: f32 = (14..54).map(|f| d.features[f][i]).sum();
            assert_eq!(wsum, 1.0);
            assert_eq!(ssum, 1.0);
        }
    }
}
