//! Crate-local error handling — a minimal `anyhow` stand-in.
//!
//! The offline build environment carries no external crates, so the
//! ergonomic error idioms the codebase uses (`anyhow!`, `bail!`,
//! `ensure!`, `.context(..)`) are provided here over a simple
//! message-carrying [`Error`]. Like `anyhow::Error`, this type
//! deliberately does **not** implement `std::error::Error`, which frees
//! the blanket `From<E: std::error::Error>` conversion that makes `?`
//! work on `io::Error` (and, with the `xla` feature, the PJRT binding
//! errors) without per-type impls.

use std::fmt;

/// A boxed, message-carrying error.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything printable.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// Crate-wide result alias (mirrors `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap the error (or `None`) with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Format an [`Error`] in place (mirrors `anyhow::anyhow!`): accepts a
/// format string + args, or any single `Display` expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::error::Error::msg(format!($msg)) };
    ($err:expr $(,)?) => { $crate::error::Error::msg($err) };
    ($fmt:literal, $($arg:tt)*) => { $crate::error::Error::msg(format!($fmt, $($arg)*)) };
}

/// Early-return with a formatted [`Error`] (mirrors `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => { return Err($crate::anyhow!($($t)*)) };
}

/// Early-return unless a condition holds (mirrors `anyhow::ensure!`).
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            $crate::bail!($($t)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/path")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn context_wraps_results_and_options() {
        let r: std::result::Result<(), String> = Err("inner".into());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
        assert_eq!(Some(3).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros_format_messages() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
        assert!(f(11).is_err());

        fn bare(x: u32) -> Result<u32> {
            ensure!(x % 2 == 0);
            Ok(x)
        }
        assert!(bare(3).unwrap_err().to_string().contains("x % 2"));
    }
}
