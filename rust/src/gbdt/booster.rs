//! The boosting loop: K rounds of tree growth over all output streams.
//!
//! [`Booster`] exposes an *incremental* API (`boost_round`) so callers
//! can interleave training with external stopping criteria. The ToaD
//! `toad_forestsize` feature (train until a byte budget is exhausted,
//! paper §4.1) is built exactly this way: `toad::train_with_budget`
//! drives rounds and measures the encoded model size after each one.

use super::grower::{grow_tree, resolve_thresholds, GrowerParams, GrowthMode};
use super::histogram::HistogramPool;
use super::loss::Objective;
use super::model::GbdtModel;
use super::splitter::{NoPenalty, SplitParams, SplitPenalty};
use super::tree::{Node, Tree};
use crate::data::{BinMatrix, BinSource, Binner, ChunkedBinMatrix, Dataset, SparseDataset, Task};

/// Hyperparameters of a boosting run. Field names follow the paper's
/// grid (§4): `n_rounds` = "maximum number of iterations", `max_depth` =
/// "maximum depth per tree".
#[derive(Clone, Copy, Debug)]
pub struct GbdtParams {
    pub n_rounds: usize,
    pub max_depth: usize,
    /// Leaf cap; defaults to the complete-tree count `2^max_depth`.
    pub max_leaves: usize,
    pub learning_rate: f64,
    pub lambda: f64,
    pub gamma: f64,
    pub min_data_in_leaf: u32,
    pub min_hess_in_leaf: f64,
    pub max_bins: usize,
    /// Worker threads for the feature-sharded histogram build
    /// (`HistogramSet::build_sharded`). `0` (the default) auto-selects
    /// from the dataset width and `available_parallelism()` (see
    /// [`super::histogram::auto_shards`]); `1` forces sequential; any
    /// other value is used as-is. Bit-identical models for every
    /// value — this is purely a wall-clock knob for wide datasets.
    /// Leaves smaller than `histogram::SHARD_MIN_ROWS` rows always
    /// build sequentially, so deep-tree tail leaves never pay
    /// thread-spawn overhead.
    pub histogram_shards: usize,
    /// Worker threads for the *row*-sharded histogram reduction
    /// ([`super::distributed`]). `0` (the default) keeps the plain
    /// sequential fold — bit-identical to every earlier release. Any
    /// `K ≥ 1` routes big-leaf builds through the fixed-grid banded
    /// fold: models are bit-identical for **every** `K ≥ 1` (the
    /// reduction grid never depends on the worker count), but differ in
    /// the last ulp from `K = 0` on non-integer statistics because the
    /// same f64 adds are grouped differently. Composes freely with
    /// `histogram_shards` and with the out-of-core store.
    pub row_workers: usize,
    /// Tree growth strategy: leaf-wise best-first (the default) or
    /// CatBoost-style oblivious level-shared splits
    /// ([`GrowthMode::Oblivious`]), which emit perfect complete trees
    /// eligible for the compact oblivious ToaD body and the
    /// table-lookup SIMD descent.
    pub growth: GrowthMode,
}

impl Default for GbdtParams {
    fn default() -> Self {
        GbdtParams {
            n_rounds: 100,
            max_depth: 6,
            max_leaves: 64,
            learning_rate: 0.1,
            lambda: 1e-3,
            gamma: 0.0,
            min_data_in_leaf: 20,
            min_hess_in_leaf: 1e-3,
            max_bins: 255,
            histogram_shards: 0,
            row_workers: 0,
            growth: GrowthMode::Leafwise,
        }
    }
}

impl GbdtParams {
    /// Paper-style constructor: iterations × depth, complete-tree leaves.
    pub fn paper(n_rounds: usize, max_depth: usize) -> GbdtParams {
        GbdtParams {
            n_rounds,
            max_depth,
            max_leaves: 1usize << max_depth.min(16),
            ..Default::default()
        }
    }

    /// The shard count [`Booster::new`] hands the histogram pool:
    /// `histogram_shards` itself when set, otherwise the automatic
    /// width × parallelism choice of [`super::histogram::auto_shards`].
    pub fn resolved_shards(&self, n_features: usize) -> usize {
        match self.histogram_shards {
            0 => super::histogram::auto_shards(n_features),
            k => k,
        }
    }

    fn grower(&self) -> GrowerParams {
        GrowerParams {
            split: SplitParams {
                lambda: self.lambda,
                gamma: self.gamma,
                min_data_in_leaf: self.min_data_in_leaf,
                min_hess_in_leaf: self.min_hess_in_leaf,
            },
            max_depth: self.max_depth,
            max_leaves: self.max_leaves,
            learning_rate: self.learning_rate,
            mode: self.growth,
        }
    }
}

/// Where the binned training matrix lives: fully resident (the
/// historical path, produced by [`Binner::bin_matrix`]) or an on-disk
/// chunked arena streamed block-by-block
/// ([`Binner::fit_transform_to_disk`]). Training is bit-identical over
/// both — histograms accumulate the same f64 adds in the same order and
/// partitioning routes the same rows — so the store is purely a memory
/// knob.
pub enum BinStore {
    Ram(BinMatrix),
    Chunked(ChunkedBinMatrix),
}

impl BinStore {
    fn source(&self) -> BinSource<'_> {
        match self {
            BinStore::Ram(m) => BinSource::Ram(m),
            BinStore::Chunked(m) => BinSource::Chunked(m),
        }
    }

    fn n_rows(&self) -> usize {
        self.source().n_rows()
    }

    fn n_features(&self) -> usize {
        self.source().n_features()
    }
}

/// Incremental boosting state.
pub struct Booster<P: SplitPenalty> {
    params: GbdtParams,
    objective: Objective,
    binner: Binner,
    store: BinStore,
    /// Reused per-leaf histogram buffers + gather scratch, shared across
    /// every tree of every round.
    pool: HistogramPool,
    targets: Vec<f64>,
    labels: Vec<usize>,
    /// Current raw scores, `[output][row]`.
    raw: Vec<Vec<f64>>,
    grad: Vec<Vec<f64>>,
    hess: Vec<Vec<f64>>,
    penalty: P,
    model: GbdtModel,
    rounds_done: usize,
}

impl<P: SplitPenalty> Booster<P> {
    /// Bin the training data and initialize raw scores at the base score.
    pub fn new(train: &Dataset, params: GbdtParams, penalty: P) -> Booster<P> {
        train.validate().expect("invalid training dataset");
        let binner = Binner::fit(train, params.max_bins);
        let store = BinStore::Ram(binner.bin_matrix(train));
        Booster::from_parts(
            binner,
            store,
            train.targets.clone(),
            train.labels.clone(),
            train.task,
            train.name.clone(),
            params,
            penalty,
        )
    }

    /// Sparse constructor: fit the binner over the CSR matrix without
    /// densifying ([`Binner::fit_sparse`]) and bin it into a mixed
    /// sparse/dense arena ([`Binner::bin_sparse`]); training then runs
    /// the O(nnz) sparse histogram kernel on the sparse-stored columns.
    /// Boundaries are bit-identical to fitting the densified twin, and
    /// on integer-exact statistics the grown model matches the dense
    /// path bit for bit (see the contract in [`super::histogram`];
    /// pinned in `tests/sparse_parity.rs`).
    pub fn from_sparse(train: &SparseDataset, params: GbdtParams, penalty: P) -> Booster<P> {
        train.validate().expect("invalid sparse training dataset");
        let binner = Binner::fit_sparse(train, params.max_bins);
        let store = BinStore::Ram(binner.bin_sparse(&train.x));
        Booster::from_parts(
            binner,
            store,
            train.targets.clone(),
            train.labels.clone(),
            train.task,
            train.name.clone(),
            params,
            penalty,
        )
    }

    /// Out-of-core constructor: train from an on-disk chunked arena (and
    /// its fitted binner), both produced by
    /// [`Binner::fit_transform_to_disk`], without ever materializing the
    /// resident bin matrix. Targets and labels stay resident — they are
    /// O(n), small next to the n×d feature matrix that streaming avoids.
    /// Training is bit-identical to the in-RAM path for any block size.
    #[allow(clippy::too_many_arguments)]
    pub fn from_chunked(
        binner: Binner,
        chunked: ChunkedBinMatrix,
        targets: Vec<f64>,
        labels: Vec<usize>,
        task: Task,
        name: String,
        params: GbdtParams,
        penalty: P,
    ) -> Booster<P> {
        assert_eq!(chunked.n_features(), binner.n_features(), "arena/binner feature mismatch");
        assert_eq!(chunked.n_rows(), targets.len(), "arena/targets row mismatch");
        Booster::from_parts(
            binner,
            BinStore::Chunked(chunked),
            targets,
            labels,
            task,
            name,
            params,
            penalty,
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn from_parts(
        binner: Binner,
        store: BinStore,
        targets: Vec<f64>,
        labels: Vec<usize>,
        task: Task,
        name: String,
        params: GbdtParams,
        penalty: P,
    ) -> Booster<P> {
        let objective = Objective::for_task(task);
        let bins_per_feature: Vec<usize> =
            (0..binner.n_features()).map(|f| binner.n_bins(f)).collect();
        let n = store.n_rows();
        let n_features = store.n_features();
        let n_out = objective.n_outputs();
        let base = objective.base_scores(&targets, &labels);
        let raw: Vec<Vec<f64>> = base.iter().map(|&b| vec![b; n]).collect();
        let model = GbdtModel {
            objective,
            base_scores: base,
            trees: vec![Vec::new(); n_out],
            n_features,
            name,
        };
        let mut pool =
            HistogramPool::with_shards(&bins_per_feature, params.resolved_shards(n_features));
        if params.row_workers > 0 {
            pool.set_row_sharding(n, params.row_workers);
        }
        Booster {
            params,
            objective,
            binner,
            store,
            pool,
            targets,
            labels,
            raw,
            grad: vec![vec![0.0; n]; n_out],
            hess: vec![vec![0.0; n]; n_out],
            penalty,
            model,
            rounds_done: 0,
        }
    }

    pub fn rounds_done(&self) -> usize {
        self.rounds_done
    }

    pub fn model(&self) -> &GbdtModel {
        &self.model
    }

    pub fn penalty(&self) -> &P {
        &self.penalty
    }

    pub fn binner(&self) -> &Binner {
        &self.binner
    }

    /// Run one boosting round where each grown tree is first passed
    /// through `map` (e.g. a pruning pass) before being committed; the
    /// raw-score update then re-routes rows through the mapped tree.
    /// Used by the CCP baseline. Requires a resident bin matrix (the
    /// mapping pass re-reads arbitrary rows); panics on a chunked store.
    pub fn boost_round_map(
        &mut self,
        mut map: impl FnMut(&BinMatrix, &[f64], &[f64], Tree) -> Tree,
    ) -> bool {
        self.objective.grad_hess(
            &self.raw,
            &self.targets,
            &self.labels,
            &mut self.grad,
            &mut self.hess,
        );
        let BinStore::Ram(binned) = &self.store else {
            panic!("boost_round_map requires a resident bin matrix; train CCP in RAM")
        };
        let grower = self.params.grower();
        let n = binned.n_rows();
        let mut any_split = false;
        for k in 0..self.objective.n_outputs() {
            let rows: Vec<u32> = (0..n as u32).collect();
            let grown = grow_tree(
                BinSource::Ram(binned),
                &mut self.pool,
                rows,
                &self.grad[k],
                &self.hess[k],
                &grower,
                &mut self.penalty,
            );
            let mut tree = map(binned, &self.grad[k], &self.hess[k], grown.tree);
            resolve_thresholds(&mut tree, |f, b| self.binner.threshold_value(f, b as usize));
            any_split |= tree.n_internal() > 0;
            for i in 0..n {
                self.raw[k][i] += super::model::predict_binned(&tree, binned, i);
            }
            self.model.trees[k].push(tree);
        }
        self.rounds_done += 1;
        any_split
    }

    /// Run one boosting round (one new tree per output stream).
    /// Returns `false` when every new tree degenerated to a bare leaf
    /// with no improvement — the natural stopping point.
    pub fn boost_round(&mut self) -> bool {
        self.objective.grad_hess(
            &self.raw,
            &self.targets,
            &self.labels,
            &mut self.grad,
            &mut self.hess,
        );
        let grower = self.params.grower();
        let n = self.store.n_rows();
        let mut any_split = false;
        for k in 0..self.objective.n_outputs() {
            let rows: Vec<u32> = (0..n as u32).collect();
            let grown = grow_tree(
                self.store.source(),
                &mut self.pool,
                rows,
                &self.grad[k],
                &self.hess[k],
                &grower,
                &mut self.penalty,
            );
            let mut tree = grown.tree;
            resolve_thresholds(&mut tree, |f, b| self.binner.threshold_value(f, b as usize));
            any_split |= tree.n_internal() > 0;
            // O(n) raw-score update from the final leaf partitions.
            for (node_idx, rows) in &grown.leaf_rows {
                let Node::Leaf { value } = tree.nodes[*node_idx] else {
                    panic!("leaf_rows must reference leaves")
                };
                for &i in rows {
                    self.raw[k][i as usize] += value;
                }
            }
            self.model.trees[k].push(tree);
        }
        self.rounds_done += 1;
        any_split
    }

    /// Run all remaining rounds, stopping early once a round yields no
    /// split anywhere (every further round would be an identical bare
    /// leaf — LightGBM's "no further splits with positive gain" stop).
    pub fn run(&mut self) {
        while self.rounds_done < self.params.n_rounds {
            if !self.boost_round() {
                break;
            }
        }
    }

    pub fn into_model(self) -> GbdtModel {
        self.model
    }

    /// Current training loss (for debugging / convergence tests).
    pub fn train_loss(&self) -> f64 {
        match self.objective {
            Objective::L2 => {
                let n = self.targets.len();
                self.targets
                    .iter()
                    .enumerate()
                    .map(|(i, &y)| {
                        let d = self.raw[0][i] - y;
                        d * d
                    })
                    .sum::<f64>()
                    / n as f64
            }
            Objective::Logistic => {
                let p: Vec<f64> =
                    self.raw[0].iter().map(|&r| super::loss::sigmoid(r)).collect();
                crate::metrics::binary_logloss(&self.labels, &p)
            }
            Objective::Softmax { n_classes } => {
                let n = self.labels.len();
                let probs: Vec<Vec<f64>> = (0..n)
                    .map(|i| {
                        let mx = (0..n_classes)
                            .map(|k| self.raw[k][i])
                            .fold(f64::NEG_INFINITY, f64::max);
                        let e: Vec<f64> =
                            (0..n_classes).map(|k| (self.raw[k][i] - mx).exp()).collect();
                        let z: f64 = e.iter().sum();
                        e.iter().map(|&x| x / z).collect()
                    })
                    .collect();
                crate::metrics::multiclass_logloss(&self.labels, &probs)
            }
        }
    }
}

/// One-shot training without penalties.
pub fn train(data: &Dataset, params: GbdtParams) -> GbdtModel {
    let mut b = Booster::new(data, params, NoPenalty);
    b.run();
    b.into_model()
}

/// One-shot out-of-core training without penalties, from a chunked
/// on-disk arena and its fitted binner
/// ([`Binner::fit_transform_to_disk`]). Produces the same model bytes
/// as [`train`] on the equivalent resident dataset, for any block size.
pub fn train_chunked(
    binner: Binner,
    chunked: ChunkedBinMatrix,
    targets: Vec<f64>,
    labels: Vec<usize>,
    task: Task,
    name: &str,
    params: GbdtParams,
) -> GbdtModel {
    let mut b = Booster::from_chunked(
        binner,
        chunked,
        targets,
        labels,
        task,
        name.to_string(),
        params,
        NoPenalty,
    );
    b.run();
    b.into_model()
}

/// One-shot training with a custom penalty.
pub fn train_with_penalty<P: SplitPenalty>(
    data: &Dataset,
    params: GbdtParams,
    penalty: P,
) -> (GbdtModel, P) {
    let mut b = Booster::new(data, params, penalty);
    b.run();
    let Booster { model, penalty, .. } = b;
    (model, penalty)
}

/// One-shot sparse training without penalties ([`Booster::from_sparse`]).
pub fn train_sparse(data: &SparseDataset, params: GbdtParams) -> GbdtModel {
    let mut b = Booster::from_sparse(data, params, NoPenalty);
    b.run();
    b.into_model()
}

/// One-shot sparse training with a custom penalty.
pub fn train_sparse_with_penalty<P: SplitPenalty>(
    data: &SparseDataset,
    params: GbdtParams,
    penalty: P,
) -> (GbdtModel, P) {
    let mut b = Booster::from_sparse(data, params, penalty);
    b.run();
    let Booster { model, penalty, .. } = b;
    (model, penalty)
}

#[cfg(test)]
#[cfg(not(miri))] // trains models / generates datasets - too slow under the Miri interpreter
mod tests {
    use super::*;
    use crate::data::synth::PaperDataset;
    use crate::data::train_test_split;

    fn small(ds: PaperDataset, n: usize) -> Dataset {
        let full = ds.generate(1);
        let idx: Vec<usize> = (0..n.min(full.n_rows())).collect();
        full.select(&idx)
    }

    #[test]
    fn regression_loss_decreases_monotonically_in_training() {
        let data = small(PaperDataset::Kin8nm, 2000);
        let mut b = Booster::new(
            &data,
            GbdtParams { n_rounds: 30, max_depth: 4, max_leaves: 16, ..Default::default() },
            NoPenalty,
        );
        let mut prev = f64::INFINITY;
        for _ in 0..30 {
            b.boost_round();
            let loss = b.train_loss();
            assert!(loss <= prev + 1e-9, "train loss must not increase: {prev} -> {loss}");
            prev = loss;
        }
    }

    #[test]
    fn binary_classification_beats_majority() {
        let data = small(PaperDataset::BreastCancer, 569);
        let (train_set, test_set) = train_test_split(&data, 0.2, 1);
        let model = train(
            &train_set,
            GbdtParams { n_rounds: 50, max_depth: 3, max_leaves: 8, ..Default::default() },
        );
        let acc = model.score(&test_set);
        assert!(acc > 0.9, "breast cancer accuracy {acc} too low");
    }

    #[test]
    fn multiclass_learns() {
        let data = small(PaperDataset::WineQuality, 3000);
        let (train_set, test_set) = train_test_split(&data, 0.2, 2);
        let model = train(
            &train_set,
            GbdtParams { n_rounds: 30, max_depth: 3, max_leaves: 8, ..Default::default() },
        );
        // Majority class baseline
        let mut counts = vec![0usize; 7];
        for &l in &train_set.labels {
            counts[l] += 1;
        }
        let maj = counts.iter().enumerate().max_by_key(|(_, &c)| c).unwrap().0;
        let maj_acc = test_set.labels.iter().filter(|&&l| l == maj).count() as f64
            / test_set.n_rows() as f64;
        let acc = model.score(&test_set);
        assert!(acc > maj_acc + 0.03, "multiclass acc {acc} vs majority {maj_acc}");
        assert_eq!(model.n_outputs(), 7);
        assert_eq!(model.n_trees(), 30 * 7);
    }

    #[test]
    fn regression_r2_reasonable() {
        let data = small(PaperDataset::CaliforniaHousing, 4000);
        let (train_set, test_set) = train_test_split(&data, 0.2, 3);
        let model = train(
            &train_set,
            GbdtParams { n_rounds: 100, max_depth: 4, max_leaves: 16, ..Default::default() },
        );
        let r2 = model.score(&test_set);
        assert!(r2 > 0.5, "california R² {r2} too low");
    }

    #[test]
    fn rounds_and_tree_counts() {
        let data = small(PaperDataset::BreastCancer, 300);
        let model = train(&data, GbdtParams::paper(8, 2));
        assert_eq!(model.n_rounds(), 8);
        assert_eq!(model.n_trees(), 8);
        assert!(model.max_depth() <= 2);
    }

    #[test]
    fn incremental_api_matches_one_shot() {
        let data = small(PaperDataset::BreastCancer, 300);
        let p = GbdtParams::paper(5, 2);
        let one = train(&data, p);
        let mut b = Booster::new(&data, p, NoPenalty);
        for _ in 0..5 {
            b.boost_round();
        }
        let inc = b.into_model();
        assert_eq!(one.n_trees(), inc.n_trees());
        // identical predictions
        for i in (0..data.n_rows()).step_by(37) {
            let x = data.row(i);
            assert_eq!(one.predict_raw(&x), inc.predict_raw(&x));
        }
    }

    #[test]
    fn sharded_histogram_training_is_bit_identical() {
        // `histogram_shards` is a wall-clock knob only: the sharded
        // build is bit-identical to the sequential one, so the grown
        // model must match exactly, tree for tree — including the
        // auto-selected count (0, the default).
        let data = small(PaperDataset::BreastCancer, 300);
        let p = GbdtParams::paper(6, 3);
        let base = train(&data, GbdtParams { histogram_shards: 1, ..p });
        for shards in [0usize, 3] {
            let sharded = train(&data, GbdtParams { histogram_shards: shards, ..p });
            assert_eq!(base.n_trees(), sharded.n_trees());
            for i in (0..data.n_rows()).step_by(29) {
                let x = data.row(i);
                let want = base.predict_raw(&x);
                assert_eq!(want, sharded.predict_raw(&x), "shards={shards} row {i}");
            }
        }
    }

    #[test]
    fn auto_shard_resolution_bounds() {
        let p = GbdtParams::default();
        assert_eq!(p.histogram_shards, 0, "default is auto");
        // Never wider than the feature count, never zero, capped.
        assert_eq!(p.resolved_shards(0), 1);
        assert_eq!(p.resolved_shards(1), 1);
        for d in [2usize, 5, 30, 1000] {
            let k = p.resolved_shards(d);
            assert!(k >= 1 && k <= d, "resolved {k} for {d} features");
            assert!(k <= crate::gbdt::histogram::AUTO_SHARD_MAX);
        }
        // An explicit count is taken verbatim.
        assert_eq!(GbdtParams { histogram_shards: 7, ..p }.resolved_shards(2), 7);
    }

    #[test]
    fn oblivious_growth_trains_level_uniform_trees_end_to_end() {
        let data = small(PaperDataset::BreastCancer, 400);
        let (train_set, test_set) = train_test_split(&data, 0.2, 9);
        let model = train(
            &train_set,
            GbdtParams { growth: GrowthMode::Oblivious, ..GbdtParams::paper(20, 3) },
        );
        let mut grew = 0usize;
        for tree in model.trees.iter().flatten() {
            if tree.depth() == 0 {
                continue; // a degenerate round may emit a bare leaf
            }
            grew += 1;
            let levels = tree.oblivious_levels();
            assert!(levels.is_some(), "oblivious growth must emit level-uniform trees");
            assert_eq!(tree.n_leaves(), 1 << tree.depth(), "perfect complete tree");
        }
        assert!(grew > 0, "at least one tree must actually split");
        let acc = model.score(&test_set);
        assert!(acc > 0.85, "oblivious breast cancer accuracy {acc} too low");
        // The quantized engine routes every grown tree through the
        // oblivious fast path.
        let quant = crate::inference::QuantizedFlatModel::from_model(&model);
        assert_eq!(quant.n_oblivious_trees(), grew);
        for i in (0..test_set.n_rows()).step_by(17) {
            let x = test_set.row(i);
            assert_eq!(quant.predict_raw(&x), model.predict_raw(&x), "row {i}");
        }
    }

    #[test]
    fn depth_zero_trains_base_only() {
        let data = small(PaperDataset::Kin8nm, 500);
        let model = train(&data, GbdtParams::paper(4, 0));
        // All trees are bare leaves; prediction is constant.
        let a = model.predict_value(&data.row(0));
        let b = model.predict_value(&data.row(1));
        assert!((a - b).abs() < 1e-12);
    }
}
