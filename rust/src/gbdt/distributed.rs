//! Row-sharded multi-worker training — the distributed reduction layer.
//!
//! MemoryConstrainedTreeBoosting.jl's recipe ("use all the memory on
//! your machine, or several machines") applies directly to this stack:
//! bin codes are compact (u8/u16 arena, optionally on disk via
//! [`crate::data::ChunkedBinMatrix`]), and histograms are *additive* —
//! for a leaf with rows `I` split into disjoint row shards `I_j`,
//! `hist(I) = Σ_j hist(I_j)` bin-for-bin. PR 3 proved the feature-axis
//! version of this (disjoint feature ranges, no merge needed); this
//! module adds the row axis, where partials overlap every bin and a
//! reduction ([`HistogramSet::merge`]) sums them.
//!
//! # Determinism: the fixed reduction grid
//!
//! f64 addition commutes but does **not** associate, so "split rows
//! across K workers, sum K partials" produces K-dependent last-ulp
//! results if the split depends on K. We pin the summation tree
//! instead: rows are always split at [`REDUCE_SHARDS`] *fixed* global
//! row bounds ([`shard_bounds`]), workers are assigned whole cells, and
//! the reducer folds cell partials in ascending cell order, seeding
//! with a copy of the first non-empty cell ([`HistogramSet::copy_from`]
//! — adding onto zeros could flip a `-0.0` sum's sign). Every quantity
//! in that pipeline is independent of the worker count and of the
//! backing store, so row-sharded training is bit-identical for every
//! `K ≥ 1`, in RAM or out-of-core, at any block size — "single-node"
//! is just `K = 1`. (It is *not* bit-identical to `row_workers = 0`,
//! which keeps the historical ungrouped fold; on integer-exact
//! statistics the two coincide, pinned in `tests/out_of_core_parity.rs`.)
//!
//! # Topology
//!
//! Workers are `std::thread::scope` threads owning disjoint contiguous
//! cell ranges of the shared (`Sync`) bin source; the reducer runs in
//! the calling thread ([`SumReducer`]). The [`Reducer`] trait is the
//! seam for a socket transport later: a remote worker would serialize
//! its cell partials and a network reducer would `absorb` them in the
//! same ascending cell order — the determinism argument only needs the
//! fold order, not shared memory. That follow-up is noted in
//! ROADMAP.md; nothing here assumes locality beyond the trait.

use super::booster::{train, GbdtParams};
use super::histogram::HistogramSet;
use super::model::GbdtModel;
use crate::data::Dataset;

/// Number of fixed row-range cells every row-sharded build reduces
/// over, independent of the worker count (workers clamp to this). 8
/// cells keep the merge overhead at ≤ 7 histogram adds per big-leaf
/// build while allowing up to 8-way row parallelism; the bounds come
/// from [`shard_bounds`].
pub const REDUCE_SHARDS: usize = 8;

/// The fixed global row bounds of the reduction grid: cell `j` covers
/// rows `bounds[j]..bounds[j + 1]`, with `bounds[j] = j·n / 8`. A
/// leaf's ascending row list splits into cells by binary search; the
/// bounds depend only on `n_rows`, never on the worker count.
pub fn shard_bounds(n_rows: usize) -> [u32; REDUCE_SHARDS + 1] {
    let mut bounds = [0u32; REDUCE_SHARDS + 1];
    for (j, b) in bounds.iter_mut().enumerate() {
        *b = (j * n_rows / REDUCE_SHARDS) as u32;
    }
    bounds
}

/// The reduction seam of row-sharded training. The in-process
/// implementation is [`SumReducer`]; a socket transport slots in by
/// implementing this over deserialized partials. Contract: `absorb`
/// is called once per **non-empty** cell, in ascending cell order —
/// implementations must preserve that order (it is what makes the
/// reduction worker-count-independent).
pub trait Reducer {
    /// Fold in the next cell partial (ascending cell order).
    fn absorb(&mut self, cell: &HistogramSet);
    /// Complete the reduction and yield the leaf histogram.
    fn finish(self) -> HistogramSet;
}

/// In-process reducer: seed by copying the first partial, then
/// [`HistogramSet::merge`] the rest. The accumulator is caller-provided
/// (a pool checkout), so steady-state reduction allocates nothing.
pub struct SumReducer {
    acc: HistogramSet,
    seeded: bool,
}

impl SumReducer {
    /// `acc` is the buffer the reduction folds into; its prior contents
    /// are ignored (overwritten by the first `absorb`, zeroed by
    /// `finish` if nothing was absorbed).
    pub fn new(acc: HistogramSet) -> SumReducer {
        SumReducer { acc, seeded: false }
    }
}

impl Reducer for SumReducer {
    fn absorb(&mut self, cell: &HistogramSet) {
        if self.seeded {
            self.acc.merge(cell);
        } else {
            self.acc.copy_from(cell);
            self.seeded = true;
        }
    }

    fn finish(mut self) -> HistogramSet {
        if !self.seeded {
            self.acc.reset();
        }
        self.acc
    }
}

/// Train with `workers` row-shard threads: convenience wrapper that
/// sets [`GbdtParams::row_workers`] and runs the standard trainer. The
/// returned model is bit-identical for every `workers ≥ 1` (see the
/// module docs); `workers = 0` is the plain single-threaded path.
pub fn train_row_sharded(data: &Dataset, params: GbdtParams, workers: usize) -> GbdtModel {
    let mut p = params;
    p.row_workers = workers;
    train(data, p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_bounds_cover_and_are_monotone() {
        for n in [0usize, 1, 7, 8, 9, 4096, 6001] {
            let b = shard_bounds(n);
            assert_eq!(b[0], 0);
            assert_eq!(b[REDUCE_SHARDS] as usize, n);
            for w in b.windows(2) {
                assert!(w[0] <= w[1]);
            }
        }
    }

    #[test]
    fn sum_reducer_seeds_then_merges() {
        let mut a = HistogramSet::new(&[2]);
        let toy = crate::data::BinMatrix::from_u16_columns(vec![vec![0, 1, 1]]);
        a.build(&toy, &[0, 1], &[1.0, 2.0, 4.0], &[1.0; 3]);
        let mut b = HistogramSet::new(&[2]);
        b.build(&toy, &[2], &[1.0, 2.0, 4.0], &[1.0; 3]);
        let mut red = SumReducer::new(HistogramSet::new(&[2]));
        red.absorb(&a);
        red.absorb(&b);
        let out = red.finish();
        assert_eq!(out.bin(0, 0), (1.0, 1.0, 1));
        assert_eq!(out.bin(0, 1), (6.0, 2.0, 2));
    }

    #[test]
    fn empty_reduction_yields_zeros() {
        let mut dirty = HistogramSet::new(&[3]);
        let toy = crate::data::BinMatrix::from_u16_columns(vec![vec![2, 0, 1]]);
        dirty.build(&toy, &[0, 1, 2], &[1.0; 3], &[1.0; 3]);
        let out = SumReducer::new(dirty).finish();
        for b in 0..3 {
            assert_eq!(out.bin(0, b), (0.0, 0.0, 0));
        }
    }
}
