//! Leaf-wise (best-first) tree growth with penalty-aware split selection.
//!
//! The grower repeatedly splits the open leaf with the highest penalized
//! gain, as LightGBM does, bounded by `max_depth` and `max_leaves`.
//!
//! Reuse penalties make stored candidate gains *stale*: when a split is
//! applied elsewhere, a feature/threshold that was "new" (and therefore
//! charged ι/ξ) may become "used" and free. Stored gains are then lower
//! bounds. The grower handles this exactly with lazy revalidation: every
//! candidate records the penalty registry version it was computed under;
//! on pop, a stale candidate is recomputed against the current registry
//! and re-queued. The loop only ever *applies* a candidate whose version
//! is current, so the applied split is always the true argmax.

use super::histogram::{HistogramPool, HistogramSet};
use super::splitter::{best_split, leaf_weight, SplitInfo, SplitParams, SplitPenalty};
use super::tree::{Node, Tree};
use crate::data::{BinColumns, BinMatrix};
use std::collections::BinaryHeap;

/// Parameters controlling the growth of a single tree.
#[derive(Clone, Copy, Debug)]
pub struct GrowerParams {
    pub split: SplitParams,
    /// Maximum tree depth (0 = a bare leaf, 1 = a single stump, …).
    pub max_depth: usize,
    /// Maximum number of leaves (LightGBM `num_leaves`).
    pub max_leaves: usize,
    /// Shrinkage applied to leaf values.
    pub learning_rate: f64,
}

impl Default for GrowerParams {
    fn default() -> Self {
        GrowerParams {
            split: SplitParams::default(),
            max_depth: 6,
            max_leaves: 31,
            learning_rate: 0.1,
        }
    }
}

/// Heap entry: candidate split for an open leaf.
struct Candidate {
    leaf_id: usize,
    gain: f64,
    version: u64,
}

impl PartialEq for Candidate {
    fn eq(&self, other: &Self) -> bool {
        self.gain == other.gain
    }
}
impl Eq for Candidate {}
impl PartialOrd for Candidate {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Candidate {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.gain.partial_cmp(&other.gain).unwrap_or(std::cmp::Ordering::Equal)
    }
}

/// State of an open (splittable) leaf during growth.
struct LeafState {
    /// Rows routed to this leaf.
    rows: Vec<u32>,
    hist: HistogramSet,
    totals: (f64, f64, u32),
    depth: usize,
    /// Index of the placeholder `Node::Leaf` in the tree being built.
    node_idx: usize,
    /// Best split under the registry version `version`, if any.
    best: Option<SplitInfo>,
    consumed: bool,
}

/// A grown tree together with its final leaf partitions, so the booster
/// can update raw scores in O(n) without re-traversing the tree.
pub struct GrownTree {
    pub tree: Tree,
    /// `(leaf node index, rows routed to it)`; the row sets partition the
    /// tree's training rows.
    pub leaf_rows: Vec<(usize, Vec<u32>)>,
}

/// Grow one regression tree on the given gradient/hessian statistics.
///
/// `rows` selects the training rows this tree sees (all rows, or a
/// subsample). `penalty` carries reuse registries across trees: applied
/// splits are reported via [`SplitPenalty::on_split`]. `pool` supplies
/// per-leaf histogram buffers (checked out on split, recycled when the
/// tree is done) and the shared gather scratch; the booster keeps one
/// pool alive across all rounds so steady-state growth allocates
/// nothing on the histogram path.
pub fn grow_tree(
    binned: &BinMatrix,
    pool: &mut HistogramPool,
    rows: Vec<u32>,
    grad: &[f64],
    hess: &[f64],
    params: &GrowerParams,
    penalty: &mut dyn SplitPenalty,
) -> GrownTree {
    let (gt, ht): (f64, f64) = rows
        .iter()
        .fold((0.0, 0.0), |(g, h), &i| (g + grad[i as usize], h + hess[i as usize]));
    let root_value = leaf_weight(gt, ht, params.split.lambda) * params.learning_rate;

    let mut tree = Tree { nodes: vec![Node::Leaf { value: root_value }] };
    if params.max_depth == 0 || params.max_leaves <= 1 || rows.is_empty() {
        return GrownTree { tree, leaf_rows: vec![(0, rows)] };
    }

    let hist = pool.build(binned, &rows, grad, hess);
    let totals = (gt, ht, rows.len() as u32);

    let mut leaves: Vec<LeafState> = Vec::new();
    let mut heap: BinaryHeap<Candidate> = BinaryHeap::new();

    let root_best = best_split(&hist, totals, &params.split, penalty);
    leaves.push(LeafState {
        rows,
        hist,
        totals,
        depth: 0,
        node_idx: 0,
        best: root_best,
        consumed: false,
    });
    if let Some(s) = root_best {
        heap.push(Candidate { leaf_id: 0, gain: s.gain, version: penalty.version() });
    }

    let mut n_leaves = 1usize;
    while n_leaves < params.max_leaves {
        // Pop candidates until one is current; recompute stale ones.
        let leaf_id = loop {
            let Some(c) = heap.pop() else { break usize::MAX };
            if leaves[c.leaf_id].consumed {
                continue;
            }
            let v = penalty.version();
            if c.version != v {
                // Recompute against the current registries and requeue.
                let leaf = &mut leaves[c.leaf_id];
                leaf.best = best_split(&leaf.hist, leaf.totals, &params.split, penalty);
                if let Some(s) = leaf.best {
                    heap.push(Candidate { leaf_id: c.leaf_id, gain: s.gain, version: v });
                }
                continue;
            }
            break c.leaf_id;
        };
        if leaf_id == usize::MAX {
            break; // no positive-gain candidate remains
        }

        // ---- apply the split ----
        let (split, depth, node_idx) = {
            let leaf = &mut leaves[leaf_id];
            leaf.consumed = true;
            (leaf.best.expect("queued candidate must have a split"), leaf.depth, leaf.node_idx)
        };
        penalty.on_split(split.feature, split.bin);

        // Partition rows by the split predicate (u8/u16 monomorphized
        // over the arena's code width).
        let parent_rows = std::mem::take(&mut leaves[leaf_id].rows);
        let mut left_rows = Vec::with_capacity(split.left_count as usize);
        let mut right_rows = Vec::with_capacity(split.right_count as usize);
        let n = binned.n_rows();
        let (cs, ce) = (split.feature * n, (split.feature + 1) * n);
        match binned.columns() {
            BinColumns::U8(a) => {
                partition_rows(&a[cs..ce], split.bin, &parent_rows, &mut left_rows, &mut right_rows)
            }
            BinColumns::U16(a) => {
                partition_rows(&a[cs..ce], split.bin, &parent_rows, &mut left_rows, &mut right_rows)
            }
        }
        debug_assert_eq!(left_rows.len() as u32, split.left_count);
        debug_assert_eq!(right_rows.len() as u32, split.right_count);

        // Child leaf values.
        let lv = leaf_weight(split.left_grad, split.left_hess, params.split.lambda)
            * params.learning_rate;
        let rv = leaf_weight(split.right_grad, split.right_hess, params.split.lambda)
            * params.learning_rate;
        let left_idx = tree.nodes.len();
        tree.nodes.push(Node::Leaf { value: lv });
        let right_idx = tree.nodes.len();
        tree.nodes.push(Node::Leaf { value: rv });
        // Threshold value must be resolved by the caller's binner; we
        // store the bin and patch the float threshold via the closure
        // below. (The binned dataset does not carry boundary values, so
        // growers receive them lazily through `thresholds`.)
        tree.nodes[node_idx] = Node::Internal {
            feature: split.feature,
            bin: split.bin,
            threshold: f32::NAN, // patched by `resolve_thresholds`
            left: left_idx,
            right: right_idx,
        };

        // Child histograms: build the smaller from the pool, then turn
        // the parent's buffer into the larger sibling in place (no third
        // buffer, no copy).
        let child_depth = depth + 1;
        let parent_hist = std::mem::replace(
            &mut leaves[leaf_id].hist,
            HistogramSet::new(&[]), // placeholder; parent is consumed
        );
        let (small_rows, large_rows, small_is_left) = if left_rows.len() <= right_rows.len() {
            (left_rows, right_rows, true)
        } else {
            (right_rows, left_rows, false)
        };
        let small_hist = pool.build(binned, &small_rows, grad, hess);
        let mut large_hist = parent_hist;
        large_hist.subtract_assign(&small_hist);

        let (l_totals, r_totals) = (
            (split.left_grad, split.left_hess, split.left_count),
            (split.right_grad, split.right_hess, split.right_count),
        );
        let mk_leaf = |rows: Vec<u32>, hist: HistogramSet, totals, node_idx| LeafState {
            rows,
            hist,
            totals,
            depth: child_depth,
            node_idx,
            best: None,
            consumed: false,
        };
        let (lh, rh, lr, rr) = if small_is_left {
            (small_hist, large_hist, small_rows, large_rows)
        } else {
            (large_hist, small_hist, large_rows, small_rows)
        };
        let left_leaf = mk_leaf(lr, lh, l_totals, left_idx);
        let right_leaf = mk_leaf(rr, rh, r_totals, right_idx);

        n_leaves += 1;
        for mut leaf in [left_leaf, right_leaf] {
            if leaf.depth < params.max_depth {
                leaf.best = best_split(&leaf.hist, leaf.totals, &params.split, penalty);
                if let Some(s) = leaf.best {
                    heap.push(Candidate {
                        leaf_id: leaves.len(),
                        gain: s.gain,
                        version: penalty.version(),
                    });
                }
            }
            leaves.push(leaf);
        }
    }

    // Hand every live histogram buffer back to the pool (consumed
    // leaves hold empty placeholders, which `recycle` drops).
    let mut leaf_rows = Vec::new();
    for l in leaves {
        pool.recycle(l.hist);
        if !l.consumed {
            leaf_rows.push((l.node_idx, l.rows));
        }
    }
    GrownTree { tree, leaf_rows }
}

/// Route each of `rows` left (`code ≤ bin`) or right, reading one
/// contiguous feature column of the arena.
fn partition_rows<T: Copy>(
    col: &[T],
    bin: u16,
    rows: &[u32],
    left: &mut Vec<u32>,
    right: &mut Vec<u32>,
) where
    u16: From<T>,
{
    for &i in rows {
        if u16::from(col[i as usize]) <= bin {
            left.push(i);
        } else {
            right.push(i);
        }
    }
}

/// Patch the float threshold values into a grown tree using the binner's
/// boundary table (`thresholds(feature, bin)`).
pub fn resolve_thresholds(tree: &mut Tree, thresholds: impl Fn(usize, u16) -> f32) {
    for node in &mut tree.nodes {
        if let Node::Internal { feature, bin, threshold, .. } = node {
            *threshold = thresholds(*feature, *bin);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Binner, Dataset, Task};
    use crate::gbdt::splitter::NoPenalty;
    use crate::prng::Pcg64;

    /// Dataset where y = sign(x0 > 0) is perfectly learnable by a stump.
    fn stump_data(n: usize, seed: u64) -> (Dataset, Vec<f64>, Vec<f64>) {
        let mut rng = Pcg64::new(seed);
        let x: Vec<f32> = (0..n).map(|_| (rng.gen_f32() - 0.5) * 2.0).collect();
        let y: Vec<f64> = x.iter().map(|&v| if v > 0.0 { 1.0 } else { -1.0 }).collect();
        let ds = Dataset {
            name: "stump".into(),
            features: vec![x],
            targets: y.clone(),
            labels: vec![],
            task: Task::Regression,
        };
        // L2 loss at F=0: grad = -y, hess = 1.
        let grad: Vec<f64> = y.iter().map(|&v| -v).collect();
        let hess = vec![1.0; n];
        (ds, grad, hess)
    }

    fn grow_on(
        ds: &Dataset,
        grad: &[f64],
        hess: &[f64],
        params: &GrowerParams,
    ) -> (Tree, Binner) {
        let binner = Binner::fit(ds, 64);
        let binned = binner.bin_matrix(ds);
        let bins: Vec<usize> = (0..binner.n_features()).map(|f| binner.n_bins(f)).collect();
        let mut pool = HistogramPool::new(&bins);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let grown = grow_tree(&binned, &mut pool, rows, grad, hess, params, &mut NoPenalty);
        // Every checked-out leaf histogram must be back on the free list
        // afterwards (the bare-leaf early return never checks one out).
        assert!(
            pool.free_count() == grown.leaf_rows.len() || grown.tree.n_nodes() == 1,
            "pool leak: {} free for {} leaves",
            pool.free_count(),
            grown.leaf_rows.len()
        );
        // Invariant: leaf_rows partitions the training rows.
        let mut all: Vec<u32> =
            grown.leaf_rows.iter().flat_map(|(_, r)| r.iter().copied()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..ds.n_rows() as u32).collect::<Vec<_>>());
        let mut tree = grown.tree;
        resolve_thresholds(&mut tree, |f, b| binner.threshold_value(f, b as usize));
        (tree, binner)
    }

    #[test]
    fn learns_a_stump() {
        let (ds, grad, hess) = stump_data(500, 1);
        let params = GrowerParams {
            split: SplitParams { lambda: 0.0, gamma: 0.0, min_data_in_leaf: 5, min_hess_in_leaf: 0.0 },
            max_depth: 1,
            max_leaves: 2,
            learning_rate: 1.0,
        };
        let (tree, _) = grow_on(&ds, &grad, &hess, &params);
        assert_eq!(tree.depth(), 1);
        assert_eq!(tree.n_leaves(), 2);
        // Predicts close to ±1 on each side.
        assert!((tree.predict_row(&[-0.5]) + 1.0).abs() < 0.05);
        assert!((tree.predict_row(&[0.5]) - 1.0).abs() < 0.05);
    }

    #[test]
    fn respects_max_depth_and_leaves() {
        let mut rng = Pcg64::new(2);
        let n = 800;
        let x0: Vec<f32> = (0..n).map(|_| rng.gen_f32()).collect();
        let x1: Vec<f32> = (0..n).map(|_| rng.gen_f32()).collect();
        let y: Vec<f64> = x0
            .iter()
            .zip(&x1)
            .map(|(&a, &b)| (a * 4.0).sin() as f64 + (b * 3.0) as f64)
            .collect();
        let ds = Dataset {
            name: "t".into(),
            features: vec![x0, x1],
            targets: y.clone(),
            labels: vec![],
            task: Task::Regression,
        };
        let grad: Vec<f64> = y.iter().map(|&v| -v).collect();
        let hess = vec![1.0; n];
        for max_depth in [1usize, 2, 3, 5] {
            let params = GrowerParams {
                split: SplitParams { min_data_in_leaf: 5, ..Default::default() },
                max_depth,
                max_leaves: 1 << max_depth,
                learning_rate: 0.5,
            };
            let (tree, _) = grow_on(&ds, &grad, &hess, &params);
            assert!(tree.depth() <= max_depth, "depth {} > {}", tree.depth(), max_depth);
            assert!(tree.n_leaves() <= 1 << max_depth);
        }
    }

    #[test]
    fn max_depth_zero_is_bare_leaf() {
        let (ds, grad, hess) = stump_data(100, 3);
        let params = GrowerParams { max_depth: 0, ..Default::default() };
        let (tree, _) = grow_on(&ds, &grad, &hess, &params);
        assert_eq!(tree.n_nodes(), 1);
        // value = -G/(H+λ)·lr ≈ mean(y)·lr ≈ 0 for balanced ±1
        assert!(tree.predict_row(&[0.0]).abs() < 0.2);
    }

    #[test]
    fn thresholds_resolved() {
        let (ds, grad, hess) = stump_data(300, 4);
        let params = GrowerParams {
            split: SplitParams { min_data_in_leaf: 5, ..Default::default() },
            max_depth: 3,
            max_leaves: 8,
            learning_rate: 1.0,
        };
        let (tree, _) = grow_on(&ds, &grad, &hess, &params);
        for (_, _, thr) in tree.splits() {
            assert!(thr.is_finite(), "threshold not resolved");
        }
    }

    #[test]
    fn splits_reported_to_penalty() {
        struct Recorder {
            splits: Vec<(usize, u16)>,
        }
        impl SplitPenalty for Recorder {
            fn penalty(&self, _f: usize, _b: u16) -> f64 {
                0.0
            }
            fn on_split(&mut self, f: usize, b: u16) {
                self.splits.push((f, b));
            }
            fn version(&self) -> u64 {
                self.splits.len() as u64
            }
        }
        let (ds, grad, hess) = stump_data(400, 5);
        let binner = Binner::fit(&ds, 32);
        let binned = binner.bin_matrix(&ds);
        let bins: Vec<usize> = (0..binner.n_features()).map(|f| binner.n_bins(f)).collect();
        let mut pool = HistogramPool::new(&bins);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let mut rec = Recorder { splits: vec![] };
        let params = GrowerParams {
            split: SplitParams { min_data_in_leaf: 5, ..Default::default() },
            max_depth: 3,
            max_leaves: 8,
            learning_rate: 1.0,
        };
        let grown = grow_tree(&binned, &mut pool, rows, &grad, &hess, &params, &mut rec);
        assert_eq!(rec.splits.len(), grown.tree.n_internal());
        assert_eq!(grown.leaf_rows.len(), grown.tree.n_leaves());
    }
}
