//! Leaf-wise (best-first) tree growth with penalty-aware split selection,
//! plus the level-synchronous *oblivious* grower.
//!
//! The default grower repeatedly splits the open leaf with the highest
//! penalized gain, as LightGBM does, bounded by `max_depth` and
//! `max_leaves`.
//!
//! Reuse penalties make stored candidate gains *stale*: when a split is
//! applied elsewhere, a feature/threshold that was "new" (and therefore
//! charged ι/ξ) may become "used" and free. Stored gains are then lower
//! bounds. The grower handles this exactly with lazy revalidation: every
//! candidate records the penalty registry version it was computed under;
//! on pop, a stale candidate is recomputed against the current registry
//! and re-queued. The loop only ever *applies* a candidate whose version
//! is current, so the applied split is always the true argmax.
//!
//! [`GrowthMode::Oblivious`] selects the CatBoost-style alternative:
//! every level of the tree shares a single `(feature, boundary)` split,
//! chosen to maximize the *summed* penalized gain across all frontier
//! leaves at once (histograms are additive, so each leaf's contribution
//! is its ordinary gain scan at that candidate). The resulting tree is a
//! perfect complete tree describable by `depth` split pairs plus a
//! `2^depth` leaf table — the shape [`super::tree::Tree::oblivious_levels`]
//! detects, the ToaD blob stores in the compact oblivious body, and
//! [`crate::simd::descend_oblivious`] serves with a table-lookup descent.

use super::histogram::{HistogramPool, HistogramSet};
use super::splitter::{best_split, leaf_weight, score, SplitInfo, SplitParams, SplitPenalty};
use super::tree::{Node, Tree};
use crate::data::BinSource;
use std::collections::BinaryHeap;

/// Which growth strategy [`grow_tree`] runs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum GrowthMode {
    /// Best-first leaf-wise growth (LightGBM-style) — the default.
    #[default]
    Leafwise,
    /// Level-shared splits (CatBoost-style oblivious trees): one
    /// `(feature, boundary)` pair per level, applied to every frontier
    /// leaf, scored by summed gain across the level.
    Oblivious,
}

/// Parameters controlling the growth of a single tree.
#[derive(Clone, Copy, Debug)]
pub struct GrowerParams {
    pub split: SplitParams,
    /// Maximum tree depth (0 = a bare leaf, 1 = a single stump, …).
    pub max_depth: usize,
    /// Maximum number of leaves (LightGBM `num_leaves`).
    pub max_leaves: usize,
    /// Shrinkage applied to leaf values.
    pub learning_rate: f64,
    /// Growth strategy (leaf-wise or oblivious).
    pub mode: GrowthMode,
}

impl Default for GrowerParams {
    fn default() -> Self {
        GrowerParams {
            split: SplitParams::default(),
            max_depth: 6,
            max_leaves: 31,
            learning_rate: 0.1,
            mode: GrowthMode::Leafwise,
        }
    }
}

/// Heap entry: candidate split for an open leaf.
struct Candidate {
    leaf_id: usize,
    gain: f64,
    version: u64,
}

impl PartialEq for Candidate {
    fn eq(&self, other: &Self) -> bool {
        self.gain == other.gain
    }
}
impl Eq for Candidate {}
impl PartialOrd for Candidate {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Candidate {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.gain.partial_cmp(&other.gain).unwrap_or(std::cmp::Ordering::Equal)
    }
}

/// State of an open (splittable) leaf during growth.
struct LeafState {
    /// Rows routed to this leaf.
    rows: Vec<u32>,
    hist: HistogramSet,
    totals: (f64, f64, u32),
    depth: usize,
    /// Index of the placeholder `Node::Leaf` in the tree being built.
    node_idx: usize,
    /// Best split under the registry version `version`, if any.
    best: Option<SplitInfo>,
    consumed: bool,
}

/// A grown tree together with its final leaf partitions, so the booster
/// can update raw scores in O(n) without re-traversing the tree.
pub struct GrownTree {
    pub tree: Tree,
    /// `(leaf node index, rows routed to it)`; the row sets partition the
    /// tree's training rows.
    pub leaf_rows: Vec<(usize, Vec<u32>)>,
}

/// Grow one regression tree on the given gradient/hessian statistics.
///
/// `rows` selects the training rows this tree sees (all rows, or a
/// subsample). `penalty` carries reuse registries across trees: applied
/// splits are reported via [`SplitPenalty::on_split`]. `pool` supplies
/// per-leaf histogram buffers (checked out on split, recycled when the
/// tree is done) and the shared gather scratch; the booster keeps one
/// pool alive across all rounds so steady-state growth allocates
/// nothing on the histogram path.
///
/// `src` is either backing store ([`BinSource`]): the grower only ever
/// builds histograms and partitions ascending row lists, and both
/// operations are bit-identical between the resident and the chunked
/// on-disk arena, so the grown tree is too.
pub fn grow_tree(
    src: BinSource<'_>,
    pool: &mut HistogramPool,
    rows: Vec<u32>,
    grad: &[f64],
    hess: &[f64],
    params: &GrowerParams,
    penalty: &mut dyn SplitPenalty,
) -> GrownTree {
    match params.mode {
        GrowthMode::Leafwise => grow_tree_leafwise(src, pool, rows, grad, hess, params, penalty),
        GrowthMode::Oblivious => grow_tree_oblivious(src, pool, rows, grad, hess, params, penalty),
    }
}

fn grow_tree_leafwise(
    src: BinSource<'_>,
    pool: &mut HistogramPool,
    rows: Vec<u32>,
    grad: &[f64],
    hess: &[f64],
    params: &GrowerParams,
    penalty: &mut dyn SplitPenalty,
) -> GrownTree {
    let (gt, ht): (f64, f64) = rows
        .iter()
        .fold((0.0, 0.0), |(g, h), &i| (g + grad[i as usize], h + hess[i as usize]));
    let root_value = leaf_weight(gt, ht, params.split.lambda) * params.learning_rate;

    let mut tree = Tree { nodes: vec![Node::Leaf { value: root_value }] };
    if params.max_depth == 0 || params.max_leaves <= 1 || rows.is_empty() {
        return GrownTree { tree, leaf_rows: vec![(0, rows)] };
    }

    let hist = pool.build_source(src, &rows, grad, hess);
    let totals = (gt, ht, rows.len() as u32);

    let mut leaves: Vec<LeafState> = Vec::new();
    let mut heap: BinaryHeap<Candidate> = BinaryHeap::new();

    let root_best = best_split(&hist, totals, &params.split, penalty);
    leaves.push(LeafState {
        rows,
        hist,
        totals,
        depth: 0,
        node_idx: 0,
        best: root_best,
        consumed: false,
    });
    if let Some(s) = root_best {
        heap.push(Candidate { leaf_id: 0, gain: s.gain, version: penalty.version() });
    }

    let mut n_leaves = 1usize;
    while n_leaves < params.max_leaves {
        // Pop candidates until one is current; recompute stale ones.
        let leaf_id = loop {
            let Some(c) = heap.pop() else { break usize::MAX };
            if leaves[c.leaf_id].consumed {
                continue;
            }
            let v = penalty.version();
            if c.version != v {
                // Recompute against the current registries and requeue.
                let leaf = &mut leaves[c.leaf_id];
                leaf.best = best_split(&leaf.hist, leaf.totals, &params.split, penalty);
                if let Some(s) = leaf.best {
                    heap.push(Candidate { leaf_id: c.leaf_id, gain: s.gain, version: v });
                }
                continue;
            }
            break c.leaf_id;
        };
        if leaf_id == usize::MAX {
            break; // no positive-gain candidate remains
        }

        // ---- apply the split ----
        let (split, depth, node_idx) = {
            let leaf = &mut leaves[leaf_id];
            leaf.consumed = true;
            (leaf.best.expect("queued candidate must have a split"), leaf.depth, leaf.node_idx)
        };
        penalty.on_split(split.feature, split.bin);

        // Partition rows by the split predicate (u8/u16 monomorphized
        // over the arena's code width, chunk-by-chunk when out-of-core).
        let parent_rows = std::mem::take(&mut leaves[leaf_id].rows);
        let mut left_rows = Vec::with_capacity(split.left_count as usize);
        let mut right_rows = Vec::with_capacity(split.right_count as usize);
        src.partition(split.feature, split.bin, &parent_rows, &mut left_rows, &mut right_rows);
        debug_assert_eq!(left_rows.len() as u32, split.left_count);
        debug_assert_eq!(right_rows.len() as u32, split.right_count);

        // Child leaf values.
        let lv = leaf_weight(split.left_grad, split.left_hess, params.split.lambda)
            * params.learning_rate;
        let rv = leaf_weight(split.right_grad, split.right_hess, params.split.lambda)
            * params.learning_rate;
        let left_idx = tree.nodes.len();
        tree.nodes.push(Node::Leaf { value: lv });
        let right_idx = tree.nodes.len();
        tree.nodes.push(Node::Leaf { value: rv });
        // Threshold value must be resolved by the caller's binner; we
        // store the bin and patch the float threshold via the closure
        // below. (The binned dataset does not carry boundary values, so
        // growers receive them lazily through `thresholds`.)
        tree.nodes[node_idx] = Node::Internal {
            feature: split.feature,
            bin: split.bin,
            threshold: f32::NAN, // patched by `resolve_thresholds`
            left: left_idx,
            right: right_idx,
        };

        // Child histograms: build the smaller from the pool, then turn
        // the parent's buffer into the larger sibling in place (no third
        // buffer, no copy).
        let child_depth = depth + 1;
        let parent_hist = std::mem::replace(
            &mut leaves[leaf_id].hist,
            HistogramSet::new(&[]), // placeholder; parent is consumed
        );
        let (small_rows, large_rows, small_is_left) = if left_rows.len() <= right_rows.len() {
            (left_rows, right_rows, true)
        } else {
            (right_rows, left_rows, false)
        };
        let small_hist = pool.build_source(src, &small_rows, grad, hess);
        let mut large_hist = parent_hist;
        large_hist.subtract_assign(&small_hist);

        let (l_totals, r_totals) = (
            (split.left_grad, split.left_hess, split.left_count),
            (split.right_grad, split.right_hess, split.right_count),
        );
        let mk_leaf = |rows: Vec<u32>, hist: HistogramSet, totals, node_idx| LeafState {
            rows,
            hist,
            totals,
            depth: child_depth,
            node_idx,
            best: None,
            consumed: false,
        };
        let (lh, rh, lr, rr) = if small_is_left {
            (small_hist, large_hist, small_rows, large_rows)
        } else {
            (large_hist, small_hist, large_rows, small_rows)
        };
        let left_leaf = mk_leaf(lr, lh, l_totals, left_idx);
        let right_leaf = mk_leaf(rr, rh, r_totals, right_idx);

        n_leaves += 1;
        for mut leaf in [left_leaf, right_leaf] {
            if leaf.depth < params.max_depth {
                leaf.best = best_split(&leaf.hist, leaf.totals, &params.split, penalty);
                if let Some(s) = leaf.best {
                    heap.push(Candidate {
                        leaf_id: leaves.len(),
                        gain: s.gain,
                        version: penalty.version(),
                    });
                }
            }
            leaves.push(leaf);
        }
    }

    // Hand every live histogram buffer back to the pool (consumed
    // leaves hold empty placeholders, which `recycle` drops).
    let mut leaf_rows = Vec::new();
    for l in leaves {
        pool.recycle(l.hist);
        if !l.consumed {
            leaf_rows.push((l.node_idx, l.rows));
        }
    }
    GrownTree { tree, leaf_rows }
}

/// A frontier leaf of the level-synchronous oblivious grower.
struct ObliviousLeaf {
    /// Index of the placeholder `Node::Leaf` in the tree being built.
    node_idx: usize,
    rows: Vec<u32>,
    totals: (f64, f64, u32),
    /// Present while this leaf can still be scored (dropped on the last
    /// level, where children are final leaves and need no histogram).
    hist: Option<HistogramSet>,
}

/// Gradient/hessian/count prefix of `hist`'s feature `f` through
/// boundary `bin` — the left-side totals of splitting at `(f, bin)`.
fn prefix_totals(hist: &HistogramSet, f: usize, bin: u16) -> (f64, f64, u32) {
    let (mut g, mut h, mut c) = (0.0f64, 0.0f64, 0u32);
    for tri in hist.feature_bins(f).chunks_exact(3).take(bin as usize + 1) {
        g += tri[0];
        h += tri[1];
        c += tri[2] as u32;
    }
    (g, h, c)
}

/// Grow one *oblivious* tree: every level shares a single
/// `(feature, boundary)` split, applied to all frontier leaves.
///
/// Per level the grower scores every candidate pair by its **summed**
/// penalized gain across the frontier — histograms are additive, so each
/// leaf contributes its ordinary gain-scan term at that candidate (zero
/// when the leaf's side constraints fail, mirroring the leaf-wise scan
/// skipping that boundary), while the reuse penalty is charged **once**
/// for the whole level (the level shares one feature and one threshold,
/// which is exactly why oblivious bodies are cheap to store). The
/// winning pair is applied to every frontier leaf, splittable or not, so
/// the tree stays a perfect complete tree; rows that cannot reach a side
/// leave an empty cell whose value is `leaf_weight(0, 0, λ) = 0`.
/// Growth stops at `max_depth` (clamped so `2^depth ≤ max_leaves`) or as
/// soon as no candidate has positive summed gain.
fn grow_tree_oblivious(
    src: BinSource<'_>,
    pool: &mut HistogramPool,
    rows: Vec<u32>,
    grad: &[f64],
    hess: &[f64],
    params: &GrowerParams,
    penalty: &mut dyn SplitPenalty,
) -> GrownTree {
    let (gt, ht): (f64, f64) = rows
        .iter()
        .fold((0.0, 0.0), |(g, h), &i| (g + grad[i as usize], h + hess[i as usize]));
    let root_value = leaf_weight(gt, ht, params.split.lambda) * params.learning_rate;

    let mut tree = Tree { nodes: vec![Node::Leaf { value: root_value }] };
    // Depth bound honoring both knobs: a depth-d oblivious tree has
    // exactly 2^d leaves.
    let depth_cap = params.max_depth.min(params.max_leaves.max(1).ilog2() as usize);
    if depth_cap == 0 || rows.is_empty() {
        return GrownTree { tree, leaf_rows: vec![(0, rows)] };
    }

    let hist = pool.build_source(src, &rows, grad, hess);
    let n_rows_total = rows.len() as u32;
    let mut frontier = vec![ObliviousLeaf {
        node_idx: 0,
        rows,
        totals: (gt, ht, n_rows_total),
        hist: Some(hist),
    }];

    let lambda = params.split.lambda;
    for level in 0..depth_cap {
        // ---- score: summed penalized gain per (feature, boundary) ----
        let hist0 = frontier[0].hist.as_ref().expect("frontier leaves carry histograms");
        let n_features = hist0.n_features();
        let offsets: Vec<usize> = {
            let mut off = Vec::with_capacity(n_features + 1);
            let mut acc = 0usize;
            for f in 0..n_features {
                off.push(acc);
                acc += hist0.n_bins(f).saturating_sub(1);
            }
            off.push(acc);
            off
        };
        let mut acc = vec![0.0f64; offsets[n_features]];
        for leaf in &frontier {
            let hist = leaf.hist.as_ref().expect("frontier leaves carry histograms");
            let (lg, lh, lc) = leaf.totals;
            if lc < 2 * params.split.min_data_in_leaf {
                continue; // no boundary of this leaf can satisfy both sides
            }
            let parent_score = score(lg, lh, lambda);
            for f in 0..n_features {
                let n_bins = hist.n_bins(f);
                if n_bins < 2 {
                    continue;
                }
                let tri = hist.feature_bins(f);
                let (mut gl, mut hl, mut cl) = (0.0f64, 0.0f64, 0u32);
                let base = offsets[f];
                for (b, bin) in tri.chunks_exact(3).take(n_bins - 1).enumerate() {
                    gl += bin[0];
                    hl += bin[1];
                    cl += bin[2] as u32;
                    let cr = lc - cl;
                    if cl < params.split.min_data_in_leaf {
                        continue;
                    }
                    if cr < params.split.min_data_in_leaf {
                        break; // right side only shrinks from here on
                    }
                    let (gr, hr) = (lg - gl, lh - hl);
                    if hl < params.split.min_hess_in_leaf || hr < params.split.min_hess_in_leaf {
                        continue;
                    }
                    acc[base + b] += 0.5
                        * (score(gl, hl, lambda) + score(gr, hr, lambda) - parent_score)
                        - params.split.gamma;
                }
            }
        }
        let mut best: Option<(usize, u16, f64)> = None;
        for f in 0..n_features {
            for b in 0..offsets[f + 1] - offsets[f] {
                let gain = acc[offsets[f] + b] - penalty.penalty(f, b as u16);
                if gain > 0.0 && best.is_none_or(|(_, _, g)| gain > g) {
                    best = Some((f, b as u16, gain));
                }
            }
        }
        let Some((bf, bb, _)) = best else {
            break; // no level-wide positive gain — the tree ends here
        };
        penalty.on_split(bf, bb);

        // ---- apply the winning pair to every frontier leaf ----
        let last_level = level + 1 == depth_cap;
        let mut next = Vec::with_capacity(frontier.len() * 2);
        for leaf in frontier {
            let ObliviousLeaf { node_idx, rows, totals, hist } = leaf;
            let hist = hist.expect("frontier leaves carry histograms");
            let (lg, lh, lc) = totals;
            let (gl, hl, cl) = prefix_totals(&hist, bf, bb);
            let (gr, hr, cr) = (lg - gl, lh - hl, lc - cl);
            let mut left_rows = Vec::with_capacity(cl as usize);
            let mut right_rows = Vec::with_capacity(cr as usize);
            src.partition(bf, bb, &rows, &mut left_rows, &mut right_rows);
            debug_assert_eq!(left_rows.len() as u32, cl);
            debug_assert_eq!(right_rows.len() as u32, cr);

            let lv = leaf_weight(gl, hl, lambda) * params.learning_rate;
            let rv = leaf_weight(gr, hr, lambda) * params.learning_rate;
            let left_idx = tree.nodes.len();
            tree.nodes.push(Node::Leaf { value: lv });
            let right_idx = tree.nodes.len();
            tree.nodes.push(Node::Leaf { value: rv });
            tree.nodes[node_idx] = Node::Internal {
                feature: bf,
                bin: bb,
                threshold: f32::NAN, // patched by `resolve_thresholds`
                left: left_idx,
                right: right_idx,
            };

            // Child histograms only if another level will be scored:
            // smaller side from the pool, larger sibling by in-place
            // subtraction from the parent's buffer (same trick as the
            // leaf-wise grower).
            let (lhist, rhist) = if last_level {
                pool.recycle(hist);
                (None, None)
            } else {
                let left_smaller = left_rows.len() <= right_rows.len();
                let small_rows = if left_smaller { &left_rows } else { &right_rows };
                let small = pool.build_source(src, small_rows, grad, hess);
                let mut large = hist;
                large.subtract_assign(&small);
                if left_smaller {
                    (Some(small), Some(large))
                } else {
                    (Some(large), Some(small))
                }
            };
            next.push(ObliviousLeaf {
                node_idx: left_idx,
                rows: left_rows,
                totals: (gl, hl, cl),
                hist: lhist,
            });
            next.push(ObliviousLeaf {
                node_idx: right_idx,
                rows: right_rows,
                totals: (gr, hr, cr),
                hist: rhist,
            });
        }
        frontier = next;
    }

    let mut leaf_rows = Vec::with_capacity(frontier.len());
    for leaf in frontier {
        if let Some(h) = leaf.hist {
            pool.recycle(h);
        }
        leaf_rows.push((leaf.node_idx, leaf.rows));
    }
    GrownTree { tree, leaf_rows }
}

/// Patch the float threshold values into a grown tree using the binner's
/// boundary table (`thresholds(feature, bin)`).
pub fn resolve_thresholds(tree: &mut Tree, thresholds: impl Fn(usize, u16) -> f32) {
    for node in &mut tree.nodes {
        if let Node::Internal { feature, bin, threshold, .. } = node {
            *threshold = thresholds(*feature, *bin);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Binner, Dataset, Task};
    use crate::gbdt::splitter::NoPenalty;
    use crate::prng::Pcg64;

    /// Dataset where y = sign(x0 > 0) is perfectly learnable by a stump.
    fn stump_data(n: usize, seed: u64) -> (Dataset, Vec<f64>, Vec<f64>) {
        let mut rng = Pcg64::new(seed);
        let x: Vec<f32> = (0..n).map(|_| (rng.gen_f32() - 0.5) * 2.0).collect();
        let y: Vec<f64> = x.iter().map(|&v| if v > 0.0 { 1.0 } else { -1.0 }).collect();
        let ds = Dataset {
            name: "stump".into(),
            features: vec![x],
            targets: y.clone(),
            labels: vec![],
            task: Task::Regression,
        };
        // L2 loss at F=0: grad = -y, hess = 1.
        let grad: Vec<f64> = y.iter().map(|&v| -v).collect();
        let hess = vec![1.0; n];
        (ds, grad, hess)
    }

    fn grow_on(
        ds: &Dataset,
        grad: &[f64],
        hess: &[f64],
        params: &GrowerParams,
    ) -> (Tree, Binner) {
        let binner = Binner::fit(ds, 64);
        let binned = binner.bin_matrix(ds);
        let bins: Vec<usize> = (0..binner.n_features()).map(|f| binner.n_bins(f)).collect();
        let mut pool = HistogramPool::new(&bins);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let grown =
            grow_tree(BinSource::Ram(&binned), &mut pool, rows, grad, hess, params, &mut NoPenalty);
        // Every checked-out leaf histogram must be back on the free list
        // afterwards (the bare-leaf early return never checks one out).
        assert!(
            pool.free_count() == grown.leaf_rows.len() || grown.tree.n_nodes() == 1,
            "pool leak: {} free for {} leaves",
            pool.free_count(),
            grown.leaf_rows.len()
        );
        // Invariant: leaf_rows partitions the training rows.
        let mut all: Vec<u32> =
            grown.leaf_rows.iter().flat_map(|(_, r)| r.iter().copied()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..ds.n_rows() as u32).collect::<Vec<_>>());
        let mut tree = grown.tree;
        resolve_thresholds(&mut tree, |f, b| binner.threshold_value(f, b as usize));
        (tree, binner)
    }

    #[test]
    fn learns_a_stump() {
        let (ds, grad, hess) = stump_data(500, 1);
        let params = GrowerParams {
            split: SplitParams { lambda: 0.0, gamma: 0.0, min_data_in_leaf: 5, min_hess_in_leaf: 0.0 },
            max_depth: 1,
            max_leaves: 2,
            learning_rate: 1.0,
            mode: GrowthMode::Leafwise,
        };
        let (tree, _) = grow_on(&ds, &grad, &hess, &params);
        assert_eq!(tree.depth(), 1);
        assert_eq!(tree.n_leaves(), 2);
        // Predicts close to ±1 on each side.
        assert!((tree.predict_row(&[-0.5]) + 1.0).abs() < 0.05);
        assert!((tree.predict_row(&[0.5]) - 1.0).abs() < 0.05);
    }

    #[test]
    fn respects_max_depth_and_leaves() {
        let mut rng = Pcg64::new(2);
        let n = 800;
        let x0: Vec<f32> = (0..n).map(|_| rng.gen_f32()).collect();
        let x1: Vec<f32> = (0..n).map(|_| rng.gen_f32()).collect();
        let y: Vec<f64> = x0
            .iter()
            .zip(&x1)
            .map(|(&a, &b)| (a * 4.0).sin() as f64 + (b * 3.0) as f64)
            .collect();
        let ds = Dataset {
            name: "t".into(),
            features: vec![x0, x1],
            targets: y.clone(),
            labels: vec![],
            task: Task::Regression,
        };
        let grad: Vec<f64> = y.iter().map(|&v| -v).collect();
        let hess = vec![1.0; n];
        for max_depth in [1usize, 2, 3, 5] {
            let params = GrowerParams {
                split: SplitParams { min_data_in_leaf: 5, ..Default::default() },
                max_depth,
                max_leaves: 1 << max_depth,
                learning_rate: 0.5,
                mode: GrowthMode::Leafwise,
            };
            let (tree, _) = grow_on(&ds, &grad, &hess, &params);
            assert!(tree.depth() <= max_depth, "depth {} > {}", tree.depth(), max_depth);
            assert!(tree.n_leaves() <= 1 << max_depth);
        }
    }

    #[test]
    fn max_depth_zero_is_bare_leaf() {
        let (ds, grad, hess) = stump_data(100, 3);
        let params = GrowerParams { max_depth: 0, ..Default::default() };
        let (tree, _) = grow_on(&ds, &grad, &hess, &params);
        assert_eq!(tree.n_nodes(), 1);
        // value = -G/(H+λ)·lr ≈ mean(y)·lr ≈ 0 for balanced ±1
        assert!(tree.predict_row(&[0.0]).abs() < 0.2);
    }

    #[test]
    fn thresholds_resolved() {
        let (ds, grad, hess) = stump_data(300, 4);
        let params = GrowerParams {
            split: SplitParams { min_data_in_leaf: 5, ..Default::default() },
            max_depth: 3,
            max_leaves: 8,
            learning_rate: 1.0,
            mode: GrowthMode::Leafwise,
        };
        let (tree, _) = grow_on(&ds, &grad, &hess, &params);
        for (_, _, thr) in tree.splits() {
            assert!(thr.is_finite(), "threshold not resolved");
        }
    }

    #[test]
    fn oblivious_mode_grows_level_uniform_complete_trees() {
        struct Recorder {
            splits: Vec<(usize, u16)>,
        }
        impl SplitPenalty for Recorder {
            fn penalty(&self, _f: usize, _b: u16) -> f64 {
                0.0
            }
            fn on_split(&mut self, f: usize, b: u16) {
                self.splits.push((f, b));
            }
            fn version(&self) -> u64 {
                self.splits.len() as u64
            }
        }
        let mut rng = Pcg64::new(7);
        let n = 800;
        let x0: Vec<f32> = (0..n).map(|_| rng.gen_f32()).collect();
        let x1: Vec<f32> = (0..n).map(|_| rng.gen_f32()).collect();
        let y: Vec<f64> = x0
            .iter()
            .zip(&x1)
            .map(|(&a, &b)| (a * 4.0).sin() as f64 + (b * 3.0) as f64)
            .collect();
        let ds = Dataset {
            name: "obl".into(),
            features: vec![x0, x1],
            targets: y.clone(),
            labels: vec![],
            task: Task::Regression,
        };
        let grad: Vec<f64> = y.iter().map(|&v| -v).collect();
        let hess = vec![1.0; n];
        let binner = Binner::fit(&ds, 32);
        let binned = binner.bin_matrix(&ds);
        let bins: Vec<usize> = (0..binner.n_features()).map(|f| binner.n_bins(f)).collect();
        let mut pool = HistogramPool::new(&bins);
        let rows: Vec<u32> = (0..n as u32).collect();
        let mut rec = Recorder { splits: vec![] };
        let max_depth = 3usize;
        let params = GrowerParams {
            split: SplitParams { min_data_in_leaf: 5, ..Default::default() },
            max_depth,
            max_leaves: 1 << max_depth,
            learning_rate: 0.5,
            mode: GrowthMode::Oblivious,
        };
        let grown =
            grow_tree(BinSource::Ram(&binned), &mut pool, rows, &grad, &hess, &params, &mut rec);
        let mut tree = grown.tree;
        resolve_thresholds(&mut tree, |f, b| binner.threshold_value(f, b as usize));
        let depth = tree.depth();
        assert!(depth >= 1, "the continuous target must admit at least one split");
        assert!(depth <= max_depth);
        // Perfect complete tree: 2^depth leaves, and every level shares
        // one split — the shape the oblivious fast paths key on.
        assert_eq!(tree.n_leaves(), 1 << depth);
        let levels = tree.oblivious_levels().expect("oblivious mode must emit uniform levels");
        assert_eq!(levels.len(), depth);
        for (_, _, thr) in tree.splits() {
            assert!(thr.is_finite(), "threshold not resolved");
        }
        // The penalty hook fires exactly once per level, in level order.
        assert_eq!(rec.splits.len(), depth);
        for (lvl, &(f, b)) in rec.splits.iter().enumerate() {
            assert_eq!((levels[lvl].0, levels[lvl].1), (f, b), "level {lvl}");
        }
        // leaf_rows partitions the training rows across the 2^depth leaves.
        assert_eq!(grown.leaf_rows.len(), 1 << depth);
        let mut all: Vec<u32> =
            grown.leaf_rows.iter().flat_map(|(_, r)| r.iter().copied()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..n as u32).collect::<Vec<_>>());
        // Every checked-out histogram buffer was recycled: depth-capped
        // growth never builds hists for the final level's children.
        let expected_buffers =
            if depth == max_depth { 1 << (depth - 1) } else { 1 << depth };
        assert_eq!(pool.free_count(), expected_buffers, "histogram pool leak");
    }

    #[test]
    fn oblivious_mode_respects_max_leaves_cap() {
        let (ds, grad, hess) = stump_data(400, 11);
        let binner = Binner::fit(&ds, 32);
        let binned = binner.bin_matrix(&ds);
        let bins: Vec<usize> = (0..binner.n_features()).map(|f| binner.n_bins(f)).collect();
        let mut pool = HistogramPool::new(&bins);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        // max_leaves 2 clamps a depth-4 request to a stump (2^1 leaves).
        let params = GrowerParams {
            split: SplitParams { min_data_in_leaf: 5, ..Default::default() },
            max_depth: 4,
            max_leaves: 2,
            learning_rate: 1.0,
            mode: GrowthMode::Oblivious,
        };
        let grown = grow_tree(
            BinSource::Ram(&binned),
            &mut pool,
            rows,
            &grad,
            &hess,
            &params,
            &mut NoPenalty,
        );
        assert!(grown.tree.depth() <= 1);
        assert!(grown.tree.n_leaves() <= 2);
    }

    #[test]
    fn splits_reported_to_penalty() {
        struct Recorder {
            splits: Vec<(usize, u16)>,
        }
        impl SplitPenalty for Recorder {
            fn penalty(&self, _f: usize, _b: u16) -> f64 {
                0.0
            }
            fn on_split(&mut self, f: usize, b: u16) {
                self.splits.push((f, b));
            }
            fn version(&self) -> u64 {
                self.splits.len() as u64
            }
        }
        let (ds, grad, hess) = stump_data(400, 5);
        let binner = Binner::fit(&ds, 32);
        let binned = binner.bin_matrix(&ds);
        let bins: Vec<usize> = (0..binner.n_features()).map(|f| binner.n_bins(f)).collect();
        let mut pool = HistogramPool::new(&bins);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let mut rec = Recorder { splits: vec![] };
        let params = GrowerParams {
            split: SplitParams { min_data_in_leaf: 5, ..Default::default() },
            max_depth: 3,
            max_leaves: 8,
            learning_rate: 1.0,
            mode: GrowthMode::Leafwise,
        };
        let grown =
            grow_tree(BinSource::Ram(&binned), &mut pool, rows, &grad, &hess, &params, &mut rec);
        assert_eq!(rec.splits.len(), grown.tree.n_internal());
        assert_eq!(grown.leaf_rows.len(), grown.tree.n_leaves());
    }
}
