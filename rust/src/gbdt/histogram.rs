//! Gradient/hessian histograms — the GBDT training hot path.
//!
//! For a leaf with row set `I`, split finding needs, for every feature
//! `f` and bin `b`, the sums `Σ g_i`, `Σ h_i` and the count over rows in
//! `I` whose feature `f` falls in bin `b`. Histograms for sibling leaves
//! satisfy `hist(parent) = hist(left) + hist(right)`, so the larger
//! sibling is obtained by subtraction (the classic LightGBM trick) —
//! see [`HistogramSet::subtract_into`] / [`HistogramSet::subtract_assign`].
//!
//! Storage is a single flat `(grad, hess, count)` triple array over all
//! features (per-feature offsets), which keeps leaf histogram
//! construction memory-local and makes the sets poolable across leaves.
//!
//! # The columnar kernel (§Perf iteration 4)
//!
//! The original scalar path (kept as [`HistogramSet::build_scalar`], the
//! parity oracle and bench baseline) random-accessed three arrays per
//! `(row, feature)` update. The optimized [`HistogramSet::build`] path
//! restructures the work around memory layout:
//!
//! * **Ordered gather** — for a leaf's row subset, `grad`/`hess` are
//!   gathered *once* into contiguous scratch, so the per-feature
//!   accumulation streams statistics sequentially instead of
//!   random-accessing them `n_features` times per row.
//! * **Dense fast path** — when the row set is the whole dataset (the
//!   root leaf of every tree; row sets are always distinct indices), the
//!   row-index indirection drops out entirely and each feature column is
//!   a straight sequential sweep.
//! * **4-way unrolled accumulation** — the bin-column walk keeps four
//!   independent bin updates in flight per iteration, hiding the
//!   latency of the scattered read-modify-write into the triple array.
//!   (Since §Perf iteration 6 this unrolled loop is the **scalar
//!   tier** of the SIMD accumulators below.)
//!
//! [`HistogramPool`] owns the gather scratch and a free list of
//! histogram buffers so the grower checks out per-leaf histograms
//! instead of allocating `3 × total_bins` doubles per node.
//!
//! # The BinMatrix arena (§Perf iteration 5)
//!
//! Bin codes come from the shared [`BinMatrix`] arena: one contiguous
//! column-major buffer with adaptive `u8`/`u16` element width. Every
//! kernel here is generic over the code width and dispatched once per
//! build via [`BinMatrix::columns`], so the common `max_bins ≤ 256`
//! case streams half the bytes per (row, feature) update with zero
//! per-access branching.
//!
//! # The feature-sharded parallel build
//!
//! [`HistogramSet::build_sharded`] partitions features into contiguous
//! ranges (split evenly by feature count — per-feature accumulation
//! cost is one update per row regardless of bin count) and accumulates
//! each range on its own `std::thread::scope` worker. Per-feature histogram regions are
//! disjoint slices of the flat triple array, so shards write without
//! locks or a merge step; the gradient/hessian gather is done once up
//! front and shared read-only. Accumulation order per feature is
//! identical to [`HistogramSet::build`]/[`HistogramSet::build_scalar`],
//! so the result is bit-identical for any shard count (property-tested
//! in `tests/histogram_parity.rs`).
//!
//! # The SIMD accumulators (§Perf iteration 6)
//!
//! The per-feature accumulation loops live in [`crate::simd::hist`]:
//! bin codes stream in as full vectors (dense path) or a software
//! gather (leaf subsets), the `3·code` triple-offset arithmetic runs in
//! vector registers (AVX2/SSE2, runtime-dispatched once per process via
//! [`crate::simd::tier`]), and the conflict-unsafe `(g, h, 1)` scatter
//! stays scalar **in row order** — which is exactly what keeps every
//! tier bit-identical to [`HistogramSet::build_scalar`]. The scalar
//! tier runs the 4-way unrolled twins this module shipped with before
//! the SIMD layer; [`HistogramSet::build_with_tier`] forces a tier for
//! parity tests and benches, and the sharded build composes with the
//! SIMD kernels (each worker runs the same tier-dispatched loops over
//! its feature range).
//!
//! # The sparse kernel (§Perf iteration 10)
//!
//! Columns a [`BinMatrix`] stores sparse (`SparseBinColumn`: present
//! rows + codes + default bin) accumulate in O(leaf-local nnz) instead
//! of O(|leaf|): only present entries scatter, then one closed-form
//! **default-bin correction** per statistic lands everything absent —
//! `hist[default] += (leaf_total − present_sum)` for grad, hess, and
//! count (every absent cell is exactly the implicit `0.0`, so they all
//! share one bin). The add order is pinned:
//!
//! 1. per feature, present entries in **ascending row order** (the
//!    merge-advance intersection of the ascending leaf rows with the
//!    ascending present rows — sparse-aware builds require ascending
//!    row sets, which leaf row sets always are);
//! 2. then exactly **one** correction add per statistic into the
//!    default bin, computed from leaf totals folded **once** per build
//!    in ascending row order and shared by every feature and every
//!    shard (so the feature-sharded build is bit-identical for every
//!    shard count).
//!
//! Sparse columns take this scalar walk on *every* SIMD tier (the tier
//! only dispatches the dense columns of a mixed matrix), so all (tier,
//! shard count) combinations are bit-identical **within the sparse
//! family**. The result is *not* claimed bit-identical to densifying
//! and running the dense kernel on arbitrary floats: `fl(T − P)`
//! regroups the f64 adds the dense path performs row by row, and f64
//! addition is not associative. On integer-exact statistics the two
//! families coincide exactly — pinned in `tests/sparse_parity.rs`, the
//! same contract `tests/out_of_core_parity.rs` pins for the row-sharded
//! fold. The row-sharded build composes too: each grid cell corrects
//! from its own sub-range's totals, so the per-worker-count invariance
//! argument of [`HistogramPool::build_row_sharded`] carries over
//! unchanged.

use crate::data::binmatrix::{ColView, SparseBinColumn};
use crate::data::{BinColumns, BinMatrix, BinSource, ChunkedBinMatrix};
use crate::gbdt::distributed::{shard_bounds, SumReducer, Reducer, REDUCE_SHARDS};
use crate::simd::{self, Code, Tier};

/// Row-count threshold below which [`HistogramPool::build`] ignores the
/// configured shard count and stays sequential: a scoped spawn/join
/// cycle costs tens of microseconds, which dwarfs accumulation over the
/// small row sets of leaves near the bottom of a tree. Explicit
/// [`HistogramSet::build_sharded`] calls are not gated (parity tests
/// exercise the threaded path on tiny inputs deliberately).
pub const SHARD_MIN_ROWS: usize = 4096;

/// Upper bound for the auto-selected shard count: feature sharding
/// splits per-feature work, and past this many workers the scoped
/// spawn/join cost and memory-bandwidth contention win over extra
/// cores even on very wide datasets.
pub const AUTO_SHARD_MAX: usize = 16;

/// Auto-select a shard count for the feature-sharded histogram build:
/// one worker per available core, clamped to the feature count (one
/// feature cannot be split across shards) and [`AUTO_SHARD_MAX`].
/// Datasets too narrow to amortize a spawn (`< 2` features) stay
/// sequential, and the [`SHARD_MIN_ROWS`] gate in
/// [`HistogramPool::build`] keeps small leaves sequential regardless
/// of what this resolves to. Purely a wall-clock knob: the sharded
/// build is bit-identical for any count.
pub fn auto_shards(n_features: usize) -> usize {
    if n_features < 2 {
        return 1;
    }
    // `available_parallelism` can hit procfs/sysfs on every call and is
    // re-resolved per `Booster::new`; the machine's core count does not
    // change under us, so probe once per process.
    static CORES: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    let cores = *CORES
        .get_or_init(|| std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1));
    cores.min(n_features).min(AUTO_SHARD_MAX)
}

/// Flat histogram over all features of a dataset.
///
/// Storage is an interleaved `[grad, hess, count]` f64 triple per bin:
/// one histogram update touches a single 24-byte span (≤ 2 cache
/// lines) instead of three separate arrays (§Perf iteration 3; counts
/// are exact in f64 far beyond any dataset size here).
#[derive(Clone, Debug)]
pub struct HistogramSet {
    /// Per-feature start offset into the flat triple array (in bins).
    offsets: Vec<usize>,
    /// `3 * total_bins` values: `[g, h, c]` per bin.
    data: Vec<f64>,
}

/// One shard's share of a sharded build: accumulate the features of
/// `range` into `chunk`, whose triples start at `offsets[range.start]`
/// in the full set. Runs on a scoped worker thread; composes with the
/// SIMD layer by running the same tier-dispatched accumulators
/// ([`crate::simd::hist`], monomorphized per bin-code width,
/// bit-identical on every tier) over its feature range.
#[allow(clippy::too_many_arguments)]
fn accumulate_shard<T: Code>(
    tier: Tier,
    chunk: &mut [f64],
    offsets: &[usize],
    range: std::ops::Range<usize>,
    arena: &[T],
    n_rows: usize,
    dense: bool,
    rows: &[u32],
    grad: &[f64],
    hess: &[f64],
    og: &[f64],
    oh: &[f64],
) {
    let base = offsets[range.start];
    for f in range {
        let off = offsets[f] - base;
        let col = &arena[f * n_rows..(f + 1) * n_rows];
        if dense {
            simd::accumulate_dense(tier, chunk, off, col, grad, hess);
        } else {
            simd::accumulate_gathered(tier, chunk, off, col, rows, og, oh);
        }
    }
}

/// [`accumulate_shard`]'s twin for mixed sparse/dense matrices: dense
/// columns run the same tier-dispatched SIMD accumulators, sparse
/// columns run [`accumulate_sparse`]. `totals` is the leaf's `(G, H,
/// count)` fold, computed once by the caller and shared across every
/// feature and shard (see the module docs' pinned-order contract).
#[allow(clippy::too_many_arguments)]
fn accumulate_shard_mixed(
    tier: Tier,
    chunk: &mut [f64],
    offsets: &[usize],
    range: std::ops::Range<usize>,
    binned: &BinMatrix,
    dense: bool,
    rows: &[u32],
    grad: &[f64],
    hess: &[f64],
    og: &[f64],
    oh: &[f64],
    totals: (f64, f64, f64),
) {
    debug_assert!(
        dense || rows.windows(2).all(|w| w[0] < w[1]),
        "sparse-aware builds require ascending row sets"
    );
    let base = offsets[range.start];
    for f in range {
        let off = offsets[f] - base;
        match binned.col_view(f) {
            ColView::U8(col) => {
                if dense {
                    simd::accumulate_dense(tier, chunk, off, col, grad, hess);
                } else {
                    simd::accumulate_gathered(tier, chunk, off, col, rows, og, oh);
                }
            }
            ColView::U16(col) => {
                if dense {
                    simd::accumulate_dense(tier, chunk, off, col, grad, hess);
                } else {
                    simd::accumulate_gathered(tier, chunk, off, col, rows, og, oh);
                }
            }
            ColView::Sparse(sc) => {
                accumulate_sparse(chunk, off, sc, dense, rows, grad, hess, totals);
            }
        }
    }
}

/// The O(leaf-local nnz) sparse column kernel: scatter the present
/// entries that fall in the leaf (ascending row order — a merge-advance
/// intersection when the leaf is a subset, a straight sweep when it is
/// the whole dataset), tallying their `(G, H, count)` sums on the way,
/// then land everything absent in the default bin with one correction
/// add per statistic: `leaf totals − present sums`. Scalar on every
/// SIMD tier, which is what makes all tiers bit-identical here.
#[allow(clippy::too_many_arguments)]
fn accumulate_sparse(
    chunk: &mut [f64],
    off: usize,
    sc: &SparseBinColumn,
    dense: bool,
    rows: &[u32],
    grad: &[f64],
    hess: &[f64],
    totals: (f64, f64, f64),
) {
    let prows = sc.present_rows();
    let codes = sc.present_codes();
    let (mut pg, mut ph, mut pc) = (0.0f64, 0.0f64, 0.0f64);
    if dense {
        // Whole leaf: every present entry is in the row set.
        for (k, &r) in prows.iter().enumerate() {
            let i = r as usize;
            let (g, h) = (grad[i], hess[i]);
            let b = 3 * (off + codes[k] as usize);
            chunk[b] += g;
            chunk[b + 1] += h;
            chunk[b + 2] += 1.0;
            pg += g;
            ph += h;
            pc += 1.0;
        }
    } else {
        let mut p = 0usize;
        for &i in rows {
            while p < prows.len() && prows[p] < i {
                p += 1;
            }
            if p == prows.len() {
                break;
            }
            if prows[p] == i {
                let (g, h) = (grad[i as usize], hess[i as usize]);
                let b = 3 * (off + codes[p] as usize);
                chunk[b] += g;
                chunk[b + 1] += h;
                chunk[b + 2] += 1.0;
                pg += g;
                ph += h;
                pc += 1.0;
            }
        }
    }
    let db = 3 * (off + sc.default_bin() as usize);
    chunk[db] += totals.0 - pg;
    chunk[db + 1] += totals.1 - ph;
    chunk[db + 2] += totals.2 - pc;
}

/// The leaf's `(G, H, count)` totals as one ascending-row f64 fold —
/// the shared input of every sparse column's default-bin correction.
/// Folded over `0..n` when the leaf is the whole dataset (row *order*
/// is then irrelevant to the dense kernels, so the fold must not depend
/// on it either) and over the ascending `rows` otherwise.
fn leaf_totals(n: usize, rows: &[u32], grad: &[f64], hess: &[f64]) -> (f64, f64, f64) {
    let (mut g, mut h) = (0.0f64, 0.0f64);
    if rows.len() == n {
        for i in 0..n {
            g += grad[i];
            h += hess[i];
        }
    } else {
        for &i in rows {
            g += grad[i as usize];
            h += hess[i as usize];
        }
    }
    (g, h, rows.len() as f64)
}

impl HistogramSet {
    /// Allocate for the given per-feature bin counts.
    pub fn new(bins_per_feature: &[usize]) -> HistogramSet {
        let mut offsets = Vec::with_capacity(bins_per_feature.len() + 1);
        let mut total = 0usize;
        for &b in bins_per_feature {
            offsets.push(total);
            total += b;
        }
        offsets.push(total);
        HistogramSet { offsets, data: vec![0.0; 3 * total] }
    }

    pub fn n_features(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn n_bins(&self, f: usize) -> usize {
        self.offsets[f + 1] - self.offsets[f]
    }

    /// Zero all bins (before rebuilding into a pooled set).
    pub fn reset(&mut self) {
        self.data.iter_mut().for_each(|v| *v = 0.0);
    }

    /// Accumulate the histogram for the rows of one leaf.
    ///
    /// `rows` are (distinct) indices into the binned dataset;
    /// `grad`/`hess` are the per-row boosting statistics of the current
    /// round. Standalone entry point that allocates its own gather
    /// scratch — the training loop goes through [`HistogramPool::build`]
    /// which reuses scratch across leaves. Runs the SIMD accumulators
    /// on the CPU's best detected tier ([`crate::simd::tier`]).
    pub fn build(&mut self, binned: &BinMatrix, rows: &[u32], grad: &[f64], hess: &[f64]) {
        self.build_with_tier(binned, rows, grad, hess, simd::tier());
    }

    /// [`HistogramSet::build`] on an explicit dispatch tier — the
    /// forced-scalar twin for parity tests and the before/after pairs
    /// in `benches/perf_hotpaths.rs`. Unsupported tiers clamp to the
    /// detected one; every tier is bit-identical.
    pub fn build_with_tier(
        &mut self,
        binned: &BinMatrix,
        rows: &[u32],
        grad: &[f64],
        hess: &[f64],
        tier: Tier,
    ) {
        let mut og = Vec::new();
        let mut oh = Vec::new();
        self.build_with_scratch(binned, rows, grad, hess, tier, &mut og, &mut oh);
    }

    /// [`HistogramSet::build_with_tier`] with caller-provided gather
    /// scratch.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn build_with_scratch(
        &mut self,
        binned: &BinMatrix,
        rows: &[u32],
        grad: &[f64],
        hess: &[f64],
        tier: Tier,
        og: &mut Vec<f64>,
        oh: &mut Vec<f64>,
    ) {
        self.reset();
        let n = binned.n_rows();
        if rows.len() == n && !binned.has_sparse() {
            // Row sets hold distinct indices, so full length ⇒ the whole
            // dataset: iteration order is free (sums commute up to fp
            // rounding) and the indirection drops out.
            match binned.columns() {
                BinColumns::U8(a) => self.dense_cols(tier, a, n, grad, hess),
                BinColumns::U16(a) => self.dense_cols(tier, a, n, grad, hess),
            }
            return;
        }
        if binned.has_sparse() && rows.len() == n {
            let totals = leaf_totals(n, rows, grad, hess);
            let nf = self.n_features();
            let HistogramSet { offsets, data } = self;
            accumulate_shard_mixed(
                tier, data, offsets, 0..nf, binned, true, rows, grad, hess, &[], &[], totals,
            );
            return;
        }
        // Ordered gather: one random-access pass over grad/hess instead
        // of one per feature. Bounds-checked indexing here also validates
        // every row index once, up front.
        og.clear();
        oh.clear();
        og.reserve(rows.len());
        oh.reserve(rows.len());
        for &i in rows {
            og.push(grad[i as usize]);
            oh.push(hess[i as usize]);
        }
        if binned.has_sparse() {
            let totals = leaf_totals(n, rows, grad, hess);
            let nf = self.n_features();
            let HistogramSet { offsets, data } = self;
            accumulate_shard_mixed(
                tier, data, offsets, 0..nf, binned, false, rows, grad, hess, og, oh, totals,
            );
            return;
        }
        match binned.columns() {
            BinColumns::U8(a) => self.gathered_cols(tier, a, n, rows, og, oh),
            BinColumns::U16(a) => self.gathered_cols(tier, a, n, rows, og, oh),
        }
    }

    fn dense_cols<T: Code>(
        &mut self,
        tier: Tier,
        arena: &[T],
        n: usize,
        grad: &[f64],
        hess: &[f64],
    ) {
        for f in 0..self.n_features() {
            let col = &arena[f * n..(f + 1) * n];
            simd::accumulate_dense(tier, &mut self.data, self.offsets[f], col, grad, hess);
        }
    }

    fn gathered_cols<T: Code>(
        &mut self,
        tier: Tier,
        arena: &[T],
        n: usize,
        rows: &[u32],
        og: &[f64],
        oh: &[f64],
    ) {
        for f in 0..self.n_features() {
            let col = &arena[f * n..(f + 1) * n];
            simd::accumulate_gathered(tier, &mut self.data, self.offsets[f], col, rows, og, oh);
        }
    }

    /// The original one-update-per-(row, feature) scalar loop, kept as
    /// the parity oracle for the columnar and sharded kernels and as
    /// the "before" baseline in `benches/perf_hotpaths.rs`.
    pub fn build_scalar(&mut self, binned: &BinMatrix, rows: &[u32], grad: &[f64], hess: &[f64]) {
        self.reset();
        let n = binned.n_rows();
        if binned.has_sparse() {
            // Densified oracle over a mixed matrix: one random-access
            // `bin` lookup per (row, feature), scattering in row order
            // exactly like the dense scalar loop would on the densified
            // twin — the O(rows × features) reference the O(nnz) kernel
            // is checked against.
            for f in 0..self.n_features() {
                let off = self.offsets[f];
                let data = &mut self.data;
                for &i in rows {
                    let i = i as usize;
                    let b = 3 * (off + binned.bin(f, i) as usize);
                    data[b] += grad[i];
                    data[b + 1] += hess[i];
                    data[b + 2] += 1.0;
                }
            }
            return;
        }
        match binned.columns() {
            BinColumns::U8(a) => self.scalar_cols(a, n, rows, grad, hess),
            BinColumns::U16(a) => self.scalar_cols(a, n, rows, grad, hess),
        }
    }

    fn scalar_cols<T: Copy>(
        &mut self,
        arena: &[T],
        n: usize,
        rows: &[u32],
        grad: &[f64],
        hess: &[f64],
    ) where
        usize: From<T>,
    {
        for f in 0..self.n_features() {
            let off = self.offsets[f];
            let col = &arena[f * n..(f + 1) * n];
            let data = &mut self.data;
            for &i in rows {
                let i = i as usize;
                let b = 3 * (off + usize::from(col[i]));
                data[b] += grad[i];
                data[b + 1] += hess[i];
                data[b + 2] += 1.0;
            }
        }
    }

    /// Feature-sharded parallel build over up to `n_shards` scoped
    /// worker threads (`std::thread::scope`, zero dependencies).
    ///
    /// Features are partitioned into contiguous ranges of (nearly)
    /// equal feature count; each shard owns the disjoint slice of the
    /// flat triple array covering its features, so there is no locking
    /// and no merge step. The gradient/hessian gather happens once up front and is
    /// shared read-only by every shard. Within each feature the
    /// accumulation order matches [`HistogramSet::build`] and
    /// [`HistogramSet::build_scalar`] exactly, so results are
    /// bit-identical for every shard count. `n_shards ≤ 1` (or a
    /// single-feature set) degrades to the sequential columnar build.
    pub fn build_sharded(
        &mut self,
        binned: &BinMatrix,
        rows: &[u32],
        grad: &[f64],
        hess: &[f64],
        n_shards: usize,
    ) {
        self.build_sharded_with_tier(binned, rows, grad, hess, n_shards, simd::tier());
    }

    /// [`HistogramSet::build_sharded`] on an explicit dispatch tier
    /// (parity tests, benches). Unsupported tiers clamp to the detected
    /// one; every (tier, shard count) combination is bit-identical.
    pub fn build_sharded_with_tier(
        &mut self,
        binned: &BinMatrix,
        rows: &[u32],
        grad: &[f64],
        hess: &[f64],
        n_shards: usize,
        tier: Tier,
    ) {
        let mut og = Vec::new();
        let mut oh = Vec::new();
        self.build_sharded_with_scratch(binned, rows, grad, hess, n_shards, tier, &mut og, &mut oh);
    }

    /// [`HistogramSet::build_sharded_with_tier`] with caller-provided
    /// gather scratch (the [`HistogramPool`] path).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn build_sharded_with_scratch(
        &mut self,
        binned: &BinMatrix,
        rows: &[u32],
        grad: &[f64],
        hess: &[f64],
        n_shards: usize,
        tier: Tier,
        og: &mut Vec<f64>,
        oh: &mut Vec<f64>,
    ) {
        let nf = self.n_features();
        let k = n_shards.clamp(1, nf.max(1));
        if k <= 1 {
            self.build_with_scratch(binned, rows, grad, hess, tier, og, oh);
            return;
        }
        self.reset();
        let n = binned.n_rows();
        let dense = rows.len() == n;
        if !dense {
            og.clear();
            oh.clear();
            og.reserve(rows.len());
            oh.reserve(rows.len());
            for &i in rows {
                og.push(grad[i as usize]);
                oh.push(hess[i as usize]);
            }
        }
        let og: &[f64] = og;
        let oh: &[f64] = oh;
        // Leaf totals for the sparse columns' default-bin correction:
        // folded once here, shared read-only by every shard, so the
        // correction is identical for every shard count.
        let has_sparse = binned.has_sparse();
        let totals =
            if has_sparse { leaf_totals(n, rows, grad, hess) } else { (0.0, 0.0, 0.0) };
        let HistogramSet { offsets, data } = self;
        let offsets: &[usize] = offsets;

        // Contiguous feature ranges of (nearly) equal feature count —
        // NOT bin count: one histogram update costs the same for every
        // feature (one bump per row; a feature's bin count only sets
        // its buffer size), so an even feature split is what balances
        // shard wall-clock. Every shard gets at least one feature
        // (`k ≤ nf`).
        let mut shards: Vec<(std::ops::Range<usize>, &mut [f64])> = Vec::with_capacity(k);
        let mut rest: &mut [f64] = data;
        let mut fstart = 0usize;
        for s in 0..k {
            let fend = if s + 1 == k { nf } else { fstart + (nf - fstart) / (k - s) };
            let len = 3 * (offsets[fend] - offsets[fstart]);
            // Move `rest` out before splitting so the halves keep the
            // long lifetime (a plain reborrow would pin them to this
            // iteration).
            let taken = std::mem::take(&mut rest);
            let (head, tail) = taken.split_at_mut(len);
            shards.push((fstart..fend, head));
            rest = tail;
            fstart = fend;
        }

        std::thread::scope(|scope| {
            for (range, chunk) in shards {
                scope.spawn(move || {
                    if has_sparse {
                        accumulate_shard_mixed(
                            tier, chunk, offsets, range, binned, dense, rows, grad, hess, og,
                            oh, totals,
                        );
                        return;
                    }
                    match binned.columns() {
                        BinColumns::U8(a) => accumulate_shard(
                            tier, chunk, offsets, range, a, n, dense, rows, grad, hess, og, oh,
                        ),
                        BinColumns::U16(a) => accumulate_shard(
                            tier, chunk, offsets, range, a, n, dense, rows, grad, hess, og, oh,
                        ),
                    }
                });
            }
        });
    }

    /// `self += other` bin-for-bin — the reduction step of row-sharded
    /// training (`hist(leaf) = Σ hist(leaf ∩ row shard)`, the same
    /// additivity that powers the subtraction trick). Plain f64 adds in
    /// storage order; any fixed merge order is deterministic, and the
    /// fixed-grid fold in [`HistogramPool::build_source`] makes the
    /// result independent of the worker count.
    pub fn merge(&mut self, other: &HistogramSet) {
        assert_eq!(self.offsets, other.offsets, "merging differently-shaped histograms");
        for (d, s) in self.data.iter_mut().zip(&other.data) {
            *d += *s;
        }
    }

    /// `self = other` (same shape). Seeding a reduction by copying the
    /// first partial — rather than `reset()` then `merge` — keeps the
    /// fold bit-exact: IEEE-754 has `0.0 + (-0.0) == +0.0`, so adding
    /// onto a zeroed buffer could flip the sign of a `-0.0` sum.
    pub fn copy_from(&mut self, other: &HistogramSet) {
        assert_eq!(self.offsets, other.offsets, "copying differently-shaped histograms");
        self.data.copy_from_slice(&other.data);
    }

    /// Continue accumulating the rows of `sub` into `self` — **no
    /// reset** — from either backing store. `sub` must be sorted
    /// ascending (leaf row sets always are: the root is `0..n` and
    /// partitioning preserves order).
    ///
    /// This is the out-of-core primitive: per bin, the add sequence is
    /// the ascending-row sequence — *literally the same* f64 adds, in
    /// the same order, as the resident-matrix build — so chaining it
    /// over disk blocks is bit-identical to [`HistogramSet::build`] on
    /// the whole matrix, for any block size. (A per-block build + merge
    /// would not be: f64 addition is not associative.)
    fn accumulate_rows(
        &mut self,
        src: BinSource<'_>,
        sub: &[u32],
        grad: &[f64],
        hess: &[f64],
        tier: Tier,
        scr: &mut RowScratch,
    ) {
        debug_assert!(sub.windows(2).all(|w| w[0] < w[1]), "row sets must be ascending");
        match src {
            BinSource::Ram(m) => {
                let n = m.n_rows();
                if m.has_sparse() {
                    // Mixed matrix: continued accumulation with the
                    // correction computed from *this call's* rows — in
                    // the row-sharded fold each grid cell corrects from
                    // its own sub-range, which keeps the per-cell sums
                    // independent of the worker schedule.
                    let dense = sub.len() == n;
                    let totals = leaf_totals(n, sub, grad, hess);
                    if !dense {
                        scr.og.clear();
                        scr.oh.clear();
                        scr.og.reserve(sub.len());
                        scr.oh.reserve(sub.len());
                        for &i in sub {
                            scr.og.push(grad[i as usize]);
                            scr.oh.push(hess[i as usize]);
                        }
                    }
                    let nf = self.n_features();
                    let HistogramSet { offsets, data } = self;
                    accumulate_shard_mixed(
                        tier, data, offsets, 0..nf, m, dense, sub, grad, hess, &scr.og,
                        &scr.oh, totals,
                    );
                    return;
                }
                if sub.len() == n {
                    match m.columns() {
                        BinColumns::U8(a) => self.dense_cols(tier, a, n, grad, hess),
                        BinColumns::U16(a) => self.dense_cols(tier, a, n, grad, hess),
                    }
                    return;
                }
                scr.og.clear();
                scr.oh.clear();
                scr.og.reserve(sub.len());
                scr.oh.reserve(sub.len());
                for &i in sub {
                    scr.og.push(grad[i as usize]);
                    scr.oh.push(hess[i as usize]);
                }
                match m.columns() {
                    BinColumns::U8(a) => self.gathered_cols(tier, a, n, sub, &scr.og, &scr.oh),
                    BinColumns::U16(a) => self.gathered_cols(tier, a, n, sub, &scr.og, &scr.oh),
                }
            }
            BinSource::Chunked(m) => self.accumulate_chunked(m, sub, grad, hess, tier, scr),
        }
    }

    /// Chunked-store body of [`HistogramSet::accumulate_rows`]: stream
    /// exactly the disk blocks that overlap `rows`, in ascending order,
    /// continuing the accumulation across blocks. A fully-selected
    /// block takes the dense sweep (`grad`/`hess` sliced at the block's
    /// global offset); a partial block gathers with chunk-local row
    /// ids. Both scatter in row order, so per bin the add sequence is
    /// identical to the in-RAM build over the same rows.
    fn accumulate_chunked(
        &mut self,
        m: &ChunkedBinMatrix,
        rows: &[u32],
        grad: &[f64],
        hess: &[f64],
        tier: Tier,
        scr: &mut RowScratch,
    ) {
        let mut done = 0usize;
        while done < rows.len() {
            let c = rows[done] as usize / m.chunk_rows();
            let range = m.chunk_range(c);
            let end = done + rows[done..].partition_point(|&r| (r as usize) < range.end);
            let sub = &rows[done..end];
            let chunk = m.load_chunk(c);
            let rows_in = chunk.n_rows();
            if sub.len() == rows_in {
                let (gs, hs) = (&grad[range.clone()], &hess[range.clone()]);
                match chunk.columns() {
                    BinColumns::U8(a) => self.dense_cols(tier, a, rows_in, gs, hs),
                    BinColumns::U16(a) => self.dense_cols(tier, a, rows_in, gs, hs),
                }
            } else {
                let base = range.start as u32;
                scr.og.clear();
                scr.oh.clear();
                scr.lrows.clear();
                scr.og.reserve(sub.len());
                scr.oh.reserve(sub.len());
                scr.lrows.reserve(sub.len());
                for &i in sub {
                    scr.og.push(grad[i as usize]);
                    scr.oh.push(hess[i as usize]);
                    scr.lrows.push(i - base);
                }
                match chunk.columns() {
                    BinColumns::U8(a) => {
                        self.gathered_cols(tier, a, rows_in, &scr.lrows, &scr.og, &scr.oh)
                    }
                    BinColumns::U16(a) => {
                        self.gathered_cols(tier, a, rows_in, &scr.lrows, &scr.og, &scr.oh)
                    }
                }
            }
            done = end;
        }
    }

    /// `self = parent − sibling`, the histogram-subtraction trick.
    pub fn subtract_into(&mut self, parent: &HistogramSet, sibling: &HistogramSet) {
        debug_assert_eq!(self.data.len(), parent.data.len());
        debug_assert_eq!(self.data.len(), sibling.data.len());
        for i in 0..self.data.len() {
            self.data[i] = parent.data[i] - sibling.data[i];
        }
    }

    /// `self -= sibling` in place: turns a parent histogram into the
    /// larger sibling without touching a third buffer (the pooled
    /// grower's no-copy variant of the subtraction trick).
    pub fn subtract_assign(&mut self, sibling: &HistogramSet) {
        debug_assert_eq!(self.data.len(), sibling.data.len());
        for (d, s) in self.data.iter_mut().zip(&sibling.data) {
            *d -= *s;
        }
    }

    /// Bin accessors for random lookups and tests.
    #[inline]
    pub fn bin(&self, f: usize, b: usize) -> (f64, f64, u32) {
        let i = 3 * (self.offsets[f] + b);
        (self.data[i], self.data[i + 1], self.data[i + 2] as u32)
    }

    /// The contiguous `[g, h, c]` triples of feature `f` — lets the
    /// splitter's left-to-right scan walk one slice without re-deriving
    /// the offset per bin.
    #[inline]
    pub fn feature_bins(&self, f: usize) -> &[f64] {
        &self.data[3 * self.offsets[f]..3 * self.offsets[f + 1]]
    }

    /// Total (G, H, count) over the bins of feature `f` — identical for
    /// all features of the same leaf, used as the leaf totals.
    pub fn totals(&self, f: usize) -> (f64, f64, u32) {
        let (mut g, mut h, mut c) = (0.0, 0.0, 0u32);
        for b in 0..self.n_bins(f) {
            let (bg, bh, bc) = self.bin(f, b);
            g += bg;
            h += bh;
            c += bc;
        }
        (g, h, c)
    }
}

/// Per-worker gather scratch for the continued-accumulation paths:
/// ordered grad/hess plus (chunked store only) chunk-local row ids.
#[derive(Debug, Default)]
struct RowScratch {
    og: Vec<f64>,
    oh: Vec<f64>,
    lrows: Vec<u32>,
}

/// A checkout pool of histogram buffers plus the shared gather scratch.
///
/// Leaf-wise growth builds one histogram per open leaf; before the pool,
/// every node allocated (and dropped) a fresh `3 × total_bins` f64
/// buffer. The pool keeps returned buffers on a free list — steady-state
/// tree growth does no histogram allocation at all — and owns the
/// ordered-gather scratch so it is reused across every leaf of every
/// tree of every boosting round.
#[derive(Debug)]
pub struct HistogramPool {
    bins_per_feature: Vec<usize>,
    free: Vec<HistogramSet>,
    og: Vec<f64>,
    oh: Vec<f64>,
    /// Worker threads for [`HistogramSet::build_sharded`]; 1 = the
    /// sequential columnar kernel.
    shards: usize,
    /// Row-sharded reduction mode ([`HistogramPool::set_row_sharding`]);
    /// 0 = off. When on, big-leaf builds go through the fixed-grid
    /// banded fold of [`HistogramPool::build_source`].
    row_workers: usize,
    /// The [`REDUCE_SHARDS`] + 1 global row bounds of the reduction
    /// grid (empty when row sharding is off). Fixed at setup — *not*
    /// derived from the worker count — so the banded fold sums the same
    /// partials in the same order for every `row_workers` value.
    row_bounds: Vec<u32>,
    /// One gather scratch per row-shard worker.
    wscratch: Vec<RowScratch>,
    /// Gather scratch of the sequential chunked (out-of-core) build.
    seq_scratch: RowScratch,
}

impl HistogramPool {
    pub fn new(bins_per_feature: &[usize]) -> HistogramPool {
        HistogramPool::with_shards(bins_per_feature, 1)
    }

    /// Pool whose [`HistogramPool::build`] runs the feature-sharded
    /// parallel kernel on `shards` scoped threads (bit-identical to the
    /// sequential build for any count; `≤ 1` stays sequential).
    pub fn with_shards(bins_per_feature: &[usize], shards: usize) -> HistogramPool {
        HistogramPool {
            bins_per_feature: bins_per_feature.to_vec(),
            free: Vec::new(),
            og: Vec::new(),
            oh: Vec::new(),
            shards: shards.max(1),
            row_workers: 0,
            row_bounds: Vec::new(),
            wscratch: Vec::new(),
            seq_scratch: RowScratch::default(),
        }
    }

    /// Arm (or with `workers == 0`, disarm) the row-sharded reduction
    /// mode: big-leaf [`HistogramPool::build_source`] calls split the
    /// leaf's rows at [`REDUCE_SHARDS`] fixed global row bounds over
    /// `0..n_rows`, accumulate each cell on up to `workers` scoped
    /// threads, and fold the cells in ascending order. The grid is
    /// fixed, so results are bit-identical for every worker count.
    pub fn set_row_sharding(&mut self, n_rows: usize, workers: usize) {
        self.row_workers = workers;
        self.row_bounds =
            if workers > 0 { shard_bounds(n_rows).to_vec() } else { Vec::new() };
    }

    pub fn row_workers(&self) -> usize {
        self.row_workers
    }

    pub fn set_shards(&mut self, shards: usize) {
        self.shards = shards.max(1);
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn bins_per_feature(&self) -> &[usize] {
        &self.bins_per_feature
    }

    /// Number of buffers currently parked on the free list (for tests).
    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    /// Take a buffer of this pool's shape. Contents are unspecified —
    /// every write path (`build*`, `subtract_into`) fully overwrites.
    pub fn checkout(&mut self) -> HistogramSet {
        self.free.pop().unwrap_or_else(|| HistogramSet::new(&self.bins_per_feature))
    }

    /// Checkout + build in one step, reusing the pool's gather scratch.
    /// Runs sharded when the pool was configured with more than one
    /// shard (see [`HistogramPool::with_shards`]) and the leaf is big
    /// enough to amortize thread spawn ([`SHARD_MIN_ROWS`]); the
    /// accumulators run on the CPU's best detected SIMD tier.
    pub fn build(
        &mut self,
        binned: &BinMatrix,
        rows: &[u32],
        grad: &[f64],
        hess: &[f64],
    ) -> HistogramSet {
        self.build_with_tier(binned, rows, grad, hess, simd::tier())
    }

    /// [`HistogramPool::build`] on an explicit dispatch tier (parity
    /// tests, benches). Unsupported tiers clamp to the detected one;
    /// every tier is bit-identical.
    pub fn build_with_tier(
        &mut self,
        binned: &BinMatrix,
        rows: &[u32],
        grad: &[f64],
        hess: &[f64],
        tier: Tier,
    ) -> HistogramSet {
        let shards = if rows.len() >= SHARD_MIN_ROWS { self.shards } else { 1 };
        let mut h = self.checkout();
        h.build_sharded_with_scratch(
            binned,
            rows,
            grad,
            hess,
            shards,
            tier,
            &mut self.og,
            &mut self.oh,
        );
        h
    }

    /// [`HistogramPool::build`] over either backing store — the entry
    /// point the grower uses. Dispatch:
    ///
    /// * row sharding armed and the leaf spans ≥ [`SHARD_MIN_ROWS`]
    ///   rows → the fixed-grid banded fold (below), in RAM or chunked;
    /// * in-RAM otherwise → exactly the pre-existing
    ///   [`HistogramPool::build`] path (dense/gathered, feature-sharded
    ///   when configured) — untouched, bit-identical;
    /// * chunked otherwise → one sequential continued accumulation over
    ///   the overlapping disk blocks, bit-identical to the in-RAM build
    ///   by the argument on [`HistogramSet::accumulate_rows`].
    ///
    /// `rows` must be ascending (leaf row sets always are).
    pub fn build_source(
        &mut self,
        src: BinSource<'_>,
        rows: &[u32],
        grad: &[f64],
        hess: &[f64],
    ) -> HistogramSet {
        self.build_source_with_tier(src, rows, grad, hess, simd::tier())
    }

    /// [`HistogramPool::build_source`] on an explicit dispatch tier
    /// (parity tests, benches).
    pub fn build_source_with_tier(
        &mut self,
        src: BinSource<'_>,
        rows: &[u32],
        grad: &[f64],
        hess: &[f64],
        tier: Tier,
    ) -> HistogramSet {
        if self.row_workers > 0 && rows.len() >= SHARD_MIN_ROWS {
            return self.build_row_sharded(src, rows, grad, hess, tier);
        }
        match src {
            BinSource::Ram(m) => self.build_with_tier(m, rows, grad, hess, tier),
            BinSource::Chunked(_) => {
                let mut h = self.checkout();
                h.reset();
                h.accumulate_rows(src, rows, grad, hess, tier, &mut self.seq_scratch);
                h
            }
        }
    }

    /// The row-sharded build: split the leaf's ascending rows at the
    /// pool's fixed [`REDUCE_SHARDS`] global row bounds, accumulate
    /// each non-trivial cell into its own pooled partial on up to
    /// `row_workers` scoped threads (each worker owns a contiguous cell
    /// range), then fold the non-empty cells ascending through a
    /// [`SumReducer`].
    ///
    /// Determinism: the cell boundaries come from `n_rows` alone, each
    /// cell is accumulated sequentially in ascending row order, and the
    /// fold order is ascending cell index with empty cells skipped
    /// (emptiness is decided by the data, not the schedule) — so the
    /// result is bit-identical for every worker count, over both
    /// backing stores, for any block size. It is *not* claimed
    /// bit-identical to the unsharded build on arbitrary data: the
    /// banded fold groups the same f64 adds differently, and f64
    /// addition is not associative. On integer-exact statistics the two
    /// families coincide exactly (pinned in `tests/out_of_core_parity.rs`).
    fn build_row_sharded(
        &mut self,
        src: BinSource<'_>,
        rows: &[u32],
        grad: &[f64],
        hess: &[f64],
        tier: Tier,
    ) -> HistogramSet {
        debug_assert_eq!(self.row_bounds.len(), REDUCE_SHARDS + 1);
        // Leaf rows are ascending, so each grid cell is one contiguous
        // sub-slice, found by binary search on the fixed bounds.
        let mut spans = [(0usize, 0usize); REDUCE_SHARDS];
        let mut s = 0usize;
        for (j, span) in spans.iter_mut().enumerate() {
            let e = s + rows[s..].partition_point(|&r| r < self.row_bounds[j + 1]);
            *span = (s, e);
            s = e;
        }
        debug_assert_eq!(s, rows.len(), "rows outside the sharding grid");

        let workers = self.row_workers.clamp(1, REDUCE_SHARDS);
        let mut cells: Vec<HistogramSet> = Vec::with_capacity(REDUCE_SHARDS);
        for _ in 0..REDUCE_SHARDS {
            let c = self.checkout();
            cells.push(c);
        }
        while self.wscratch.len() < workers {
            self.wscratch.push(RowScratch::default());
        }
        {
            let wscratch = &mut self.wscratch[..workers];
            let spans = &spans;
            std::thread::scope(|scope| {
                let mut rest: &mut [HistogramSet] = &mut cells;
                let mut start = 0usize;
                for (w, scr) in wscratch.iter_mut().enumerate() {
                    let end = ((w + 1) * REDUCE_SHARDS) / workers;
                    // Move `rest` out before splitting so the halves
                    // keep the long lifetime.
                    let taken = std::mem::take(&mut rest);
                    let (head, tail) = taken.split_at_mut(end - start);
                    rest = tail;
                    scope.spawn(move || {
                        for (j, cell) in (start..end).zip(head.iter_mut()) {
                            let (cs, ce) = spans[j];
                            cell.reset();
                            if cs < ce {
                                cell.accumulate_rows(src, &rows[cs..ce], grad, hess, tier, scr);
                            }
                        }
                    });
                    start = end;
                }
            });
        }
        let mut red = SumReducer::new(self.checkout());
        for (j, cell) in cells.iter().enumerate() {
            if spans[j].0 < spans[j].1 {
                red.absorb(cell);
            }
        }
        let out = red.finish();
        for cell in cells {
            self.recycle(cell);
        }
        out
    }

    /// Return a buffer to the free list. Buffers of a different shape
    /// (e.g. the grower's empty placeholders) are silently dropped.
    pub fn recycle(&mut self, h: HistogramSet) {
        let matches = h.offsets.len() == self.bins_per_feature.len() + 1
            && (0..h.n_features()).all(|f| h.n_bins(f) == self.bins_per_feature[f]);
        if matches {
            self.free.push(h);
        }
    }
}

#[cfg(test)]
#[cfg(not(miri))] // trains models / generates datasets - too slow under the Miri interpreter
mod tests {
    use super::*;
    use crate::prng::Pcg64;
    use crate::testutil::prop::run_prop;

    fn toy_binned() -> BinMatrix {
        // 2 features, 6 rows.
        BinMatrix::from_u16_columns(vec![vec![0, 1, 2, 0, 1, 2], vec![1, 1, 0, 0, 1, 1]])
    }

    #[test]
    fn build_counts_and_sums() {
        let binned = toy_binned();
        let mut h = HistogramSet::new(&[3, 2]);
        let grad = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let hess = vec![1.0; 6];
        let rows: Vec<u32> = (0..6).collect();
        h.build(&binned, &rows, &grad, &hess);
        assert_eq!(h.bin(0, 0), (5.0, 2.0, 2)); // rows 0,3
        assert_eq!(h.bin(0, 1), (7.0, 2.0, 2)); // rows 1,4
        assert_eq!(h.bin(0, 2), (9.0, 2.0, 2)); // rows 2,5
        assert_eq!(h.bin(1, 0), (7.0, 2.0, 2)); // rows 2,3
        assert_eq!(h.bin(1, 1), (14.0, 4.0, 4));
        assert_eq!(h.totals(0), (21.0, 6.0, 6));
        assert_eq!(h.totals(1), (21.0, 6.0, 6));
    }

    #[test]
    fn build_subset_of_rows() {
        let binned = toy_binned();
        let mut h = HistogramSet::new(&[3, 2]);
        let grad = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let hess = vec![0.5; 6];
        h.build(&binned, &[1, 2], &grad, &hess);
        assert_eq!(h.bin(0, 0), (0.0, 0.0, 0));
        assert_eq!(h.bin(0, 1), (2.0, 0.5, 1));
        assert_eq!(h.bin(0, 2), (3.0, 0.5, 1));
    }

    #[test]
    fn feature_bins_matches_bin_accessor() {
        let binned = toy_binned();
        let mut h = HistogramSet::new(&[3, 2]);
        let rows: Vec<u32> = (0..6).collect();
        h.build(&binned, &rows, &[1.0; 6], &[2.0; 6]);
        for f in 0..2 {
            let tri = h.feature_bins(f);
            assert_eq!(tri.len(), 3 * h.n_bins(f));
            for b in 0..h.n_bins(f) {
                let (g, hh, c) = h.bin(f, b);
                assert_eq!(tri[3 * b], g);
                assert_eq!(tri[3 * b + 1], hh);
                assert_eq!(tri[3 * b + 2] as u32, c);
            }
        }
    }

    /// The columnar kernel (dense + gathered paths, unroll remainders,
    /// both u8 and u16 arenas) must agree with the scalar oracle on
    /// random inputs.
    #[test]
    fn prop_columnar_matches_scalar() {
        run_prop("columnar histogram == scalar histogram", 60, |g| {
            let n = g.usize_in(1, 300);
            let d = g.usize_in(1, 6);
            // Occasionally force a wide feature so the u16 arena (and
            // its monomorphized kernels) are exercised too.
            let bins_per: Vec<usize> = (0..d)
                .map(|_| if g.bool(0.15) { g.usize_in(260, 400) } else { g.usize_in(2, 16) })
                .collect();
            let binned =
                BinMatrix::from_fn(n, &bins_per, |f, _| g.usize(bins_per[f]) as u16);
            let grad: Vec<f64> = (0..n).map(|_| g.normal()).collect();
            let hess: Vec<f64> = (0..n).map(|_| g.f64_in(0.01, 2.0)).collect();
            // Random subset (sometimes everything → dense path).
            let k = g.usize_in(0, n);
            let mut rows: Vec<u32> = (0..n as u32).collect();
            let mut rng = Pcg64::new(g.case_seed ^ 0x51);
            rng.shuffle(&mut rows);
            rows.truncate(if g.bool(0.3) { n } else { k });

            let mut pool = HistogramPool::new(&bins_per);
            let fast = pool.build(&binned, &rows, &grad, &hess);
            let mut slow = HistogramSet::new(&bins_per);
            slow.build_scalar(&binned, &rows, &grad, &hess);
            for f in 0..d {
                for b in 0..bins_per[f] {
                    let (g1, h1, c1) = fast.bin(f, b);
                    let (g2, h2, c2) = slow.bin(f, b);
                    assert_eq!(c1, c2, "count mismatch f={f} b={b}");
                    assert!((g1 - g2).abs() < 1e-9, "grad mismatch {g1} {g2}");
                    assert!((h1 - h2).abs() < 1e-9, "hess mismatch {h1} {h2}");
                }
            }
        });
    }

    #[test]
    fn prop_subtraction_equals_direct_build() {
        run_prop("histogram subtraction == direct build", 60, |g| {
            let n = g.usize_in(10, 200);
            let d = g.usize_in(1, 6);
            let bins_per: Vec<usize> = (0..d).map(|_| g.usize_in(2, 16)).collect();
            let binned =
                BinMatrix::from_fn(n, &bins_per, |f, _| g.usize(bins_per[f]) as u16);
            let grad: Vec<f64> = (0..n).map(|_| g.normal()).collect();
            let hess: Vec<f64> = (0..n).map(|_| g.f64_in(0.01, 2.0)).collect();
            // random partition of rows
            let split = g.usize_in(0, n);
            let mut rows: Vec<u32> = (0..n as u32).collect();
            let mut rng = Pcg64::new(g.case_seed ^ 0xA5);
            rng.shuffle(&mut rows);
            let (left, right) = rows.split_at(split);
            let all: Vec<u32> = rows.clone();

            let mut hp = HistogramSet::new(&bins_per);
            hp.build(&binned, &all, &grad, &hess);
            let mut hl = HistogramSet::new(&bins_per);
            hl.build(&binned, left, &grad, &hess);
            let mut hr_direct = HistogramSet::new(&bins_per);
            hr_direct.build(&binned, right, &grad, &hess);
            let mut hr_sub = HistogramSet::new(&bins_per);
            hr_sub.subtract_into(&hp, &hl);
            // In-place variant must agree with the three-buffer one.
            let mut hr_assign = hp.clone();
            hr_assign.subtract_assign(&hl);

            for f in 0..d {
                for b in 0..bins_per[f] {
                    let (g1, h1, c1) = hr_direct.bin(f, b);
                    let (g2, h2, c2) = hr_sub.bin(f, b);
                    let (g3, h3, _) = hr_assign.bin(f, b);
                    assert_eq!(c1, c2);
                    assert!((g1 - g2).abs() < 1e-9, "grad mismatch {g1} {g2}");
                    assert!((h1 - h2).abs() < 1e-9);
                    assert_eq!(g2.to_bits(), g3.to_bits());
                    assert_eq!(h2.to_bits(), h3.to_bits());
                }
            }
        });
    }

    #[test]
    fn reset_zeroes() {
        let binned = toy_binned();
        let mut h = HistogramSet::new(&[3, 2]);
        h.build(&binned, &[0, 1, 2], &[1.0; 6], &[1.0; 6]);
        h.reset();
        for f in 0..2 {
            for b in 0..h.n_bins(f) {
                assert_eq!(h.bin(f, b), (0.0, 0.0, 0));
            }
        }
    }

    #[test]
    fn pool_reuses_buffers() {
        let binned = toy_binned();
        let grad = vec![1.0; 6];
        let hess = vec![1.0; 6];
        let mut pool = HistogramPool::new(&[3, 2]);
        let a = pool.build(&binned, &[0, 1], &grad, &hess);
        let b = pool.build(&binned, &[2, 3], &grad, &hess);
        assert_eq!(pool.free_count(), 0);
        pool.recycle(a);
        pool.recycle(b);
        assert_eq!(pool.free_count(), 2);
        // Checked-out buffers come off the free list and build correctly
        // even though their previous contents were nonzero.
        let c = pool.build(&binned, &[4, 5], &grad, &hess);
        assert_eq!(pool.free_count(), 1);
        assert_eq!(c.bin(0, 1), (1.0, 1.0, 1)); // row 4
        assert_eq!(c.bin(0, 2), (1.0, 1.0, 1)); // row 5
        assert_eq!(c.totals(0), (2.0, 2.0, 2));
        // Foreign-shaped buffers are dropped, not pooled.
        pool.recycle(HistogramSet::new(&[]));
        pool.recycle(HistogramSet::new(&[5]));
        assert_eq!(pool.free_count(), 1);
    }

    /// The O(nnz) sparse kernel on a mixed matrix must equal the
    /// densified scalar oracle bit-for-bit on integer-exact statistics,
    /// for every tier and shard count, on whole-leaf and subset row
    /// sets (the module-doc contract).
    #[test]
    fn sparse_kernel_matches_densified_oracle_on_integer_stats() {
        use crate::data::binmatrix::MixedCol;
        let n = 40usize;
        // f0 sparse (default bin 1 — interior), f1 dense, f2 sparse
        // with explicit default-bin codes among the present entries.
        let (mut r0, mut c0) = (Vec::new(), Vec::new());
        let (mut r2, mut c2) = (Vec::new(), Vec::new());
        for i in (0..n).step_by(3) {
            r0.push(i as u32);
            c0.push(((i / 3) % 4) as u16);
        }
        for i in (0..n).step_by(7) {
            r2.push(i as u32);
            c2.push(if i % 2 == 0 { 2u16 } else { 3u16 }); // 2 == default
        }
        let mid: Vec<u16> = (0..n).map(|i| (i % 5) as u16).collect();
        let mixed = BinMatrix::from_mixed_cols(
            n,
            &[4, 5, 4],
            vec![
                MixedCol::Sparse { rows: r0, codes: c0, default_bin: 1 },
                MixedCol::Dense(mid),
                MixedCol::Sparse { rows: r2, codes: c2, default_bin: 2 },
            ],
        );
        let grad: Vec<f64> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let hess = vec![1.0; n];
        let all: Vec<u32> = (0..n as u32).collect();
        let subset: Vec<u32> = (0..n as u32).filter(|i| i % 3 != 1).collect();
        for rows in [&all[..], &subset[..]] {
            let mut want = HistogramSet::new(&[4, 5, 4]);
            want.build_scalar(&mixed, rows, &grad, &hess);
            for tier in crate::simd::available_tiers() {
                for k in [1usize, 2, 3] {
                    let mut got = HistogramSet::new(&[4, 5, 4]);
                    got.build_sharded_with_tier(&mixed, rows, &grad, &hess, k, tier);
                    for f in 0..3 {
                        for b in 0..want.n_bins(f) {
                            let (g0, h0, c0) = want.bin(f, b);
                            let (g1, h1, c1) = got.bin(f, b);
                            assert_eq!(c0, c1, "tier={tier:?} k={k} f={f} b={b}");
                            assert_eq!(g0.to_bits(), g1.to_bits(), "tier={tier:?} k={k} f={f} b={b}");
                            assert_eq!(h0.to_bits(), h1.to_bits(), "tier={tier:?} k={k} f={f} b={b}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn sharded_build_is_bit_identical_on_toy() {
        let binned = toy_binned();
        let grad = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let hess = vec![0.5; 6];
        let rows: Vec<u32> = (0..6).collect();
        let mut want = HistogramSet::new(&[3, 2]);
        want.build(&binned, &rows, &grad, &hess);
        // More shards than features clamps; 1 degrades to sequential.
        for k in [1usize, 2, 5] {
            let mut got = HistogramSet::new(&[3, 2]);
            got.build_sharded(&binned, &rows, &grad, &hess, k);
            let mut pool = HistogramPool::with_shards(&[3, 2], k);
            assert_eq!(pool.shards(), k.max(1));
            let pooled = pool.build(&binned, &rows, &grad, &hess);
            for f in 0..2 {
                for b in 0..want.n_bins(f) {
                    let (g0, h0, c0) = want.bin(f, b);
                    let (g1, h1, c1) = got.bin(f, b);
                    let (g2, h2, c2) = pooled.bin(f, b);
                    assert_eq!(c0, c1);
                    assert_eq!(c0, c2);
                    assert_eq!(g0.to_bits(), g1.to_bits(), "k={k} f={f} b={b}");
                    assert_eq!(h0.to_bits(), h1.to_bits());
                    assert_eq!(g0.to_bits(), g2.to_bits());
                    assert_eq!(h0.to_bits(), h2.to_bits());
                }
            }
        }
    }
}
