//! Gradient/hessian histograms — the GBDT training hot path.
//!
//! For a leaf with row set `I`, split finding needs, for every feature
//! `f` and bin `b`, the sums `Σ g_i`, `Σ h_i` and the count over rows in
//! `I` whose feature `f` falls in bin `b`. Histograms for sibling leaves
//! satisfy `hist(parent) = hist(left) + hist(right)`, so the larger
//! sibling is obtained by subtraction (the classic LightGBM trick) —
//! see [`HistogramSet::subtract_into`].
//!
//! Storage is a single flat `(grad, hess, count)` triple array over all
//! features (per-feature offsets), which keeps leaf histogram
//! construction memory-local and makes the pool reusable across leaves.

use crate::data::BinnedDataset;

/// Flat histogram over all features of a dataset.
///
/// Storage is an interleaved `[grad, hess, count]` f64 triple per bin:
/// one histogram update touches a single 24-byte span (≤ 2 cache
/// lines) instead of three separate arrays (§Perf iteration 3; counts
/// are exact in f64 far beyond any dataset size here).
#[derive(Clone, Debug)]
pub struct HistogramSet {
    /// Per-feature start offset into the flat triple array (in bins).
    offsets: Vec<usize>,
    /// `3 * total_bins` values: `[g, h, c]` per bin.
    data: Vec<f64>,
}

impl HistogramSet {
    /// Allocate for the given per-feature bin counts.
    pub fn new(bins_per_feature: &[usize]) -> HistogramSet {
        let mut offsets = Vec::with_capacity(bins_per_feature.len() + 1);
        let mut total = 0usize;
        for &b in bins_per_feature {
            offsets.push(total);
            total += b;
        }
        offsets.push(total);
        HistogramSet { offsets, data: vec![0.0; 3 * total] }
    }

    pub fn n_features(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn n_bins(&self, f: usize) -> usize {
        self.offsets[f + 1] - self.offsets[f]
    }

    /// Zero all bins (before rebuilding into a pooled set).
    pub fn reset(&mut self) {
        self.data.iter_mut().for_each(|v| *v = 0.0);
    }

    /// Accumulate the histogram for the rows of one leaf.
    ///
    /// `rows` are indices into the binned dataset; `grad`/`hess` are the
    /// per-row boosting statistics of the current round.
    pub fn build(&mut self, binned: &BinnedDataset, rows: &[u32], grad: &[f64], hess: &[f64]) {
        self.reset();
        for f in 0..self.n_features() {
            let off = self.offsets[f];
            let col = &binned.bins[f];
            let data = &mut self.data;
            // Hot loop: one 24-byte random-access update per
            // (row, feature).
            for &i in rows {
                let i = i as usize;
                let b = 3 * (off + col[i] as usize);
                data[b] += grad[i];
                data[b + 1] += hess[i];
                data[b + 2] += 1.0;
            }
        }
    }

    /// `self = parent − sibling`, the histogram-subtraction trick.
    pub fn subtract_into(&mut self, parent: &HistogramSet, sibling: &HistogramSet) {
        debug_assert_eq!(self.data.len(), parent.data.len());
        debug_assert_eq!(self.data.len(), sibling.data.len());
        for i in 0..self.data.len() {
            self.data[i] = parent.data[i] - sibling.data[i];
        }
    }

    /// Bin accessors for the splitter's left-to-right scan.
    #[inline]
    pub fn bin(&self, f: usize, b: usize) -> (f64, f64, u32) {
        let i = 3 * (self.offsets[f] + b);
        (self.data[i], self.data[i + 1], self.data[i + 2] as u32)
    }

    /// Total (G, H, count) over the bins of feature `f` — identical for
    /// all features of the same leaf, used as the leaf totals.
    pub fn totals(&self, f: usize) -> (f64, f64, u32) {
        let (mut g, mut h, mut c) = (0.0, 0.0, 0u32);
        for b in 0..self.n_bins(f) {
            let (bg, bh, bc) = self.bin(f, b);
            g += bg;
            h += bh;
            c += bc;
        }
        (g, h, c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg64;
    use crate::testutil::prop::run_prop;

    fn toy_binned() -> BinnedDataset {
        // 2 features, 6 rows.
        BinnedDataset {
            bins: vec![vec![0, 1, 2, 0, 1, 2], vec![1, 1, 0, 0, 1, 1]],
            n_rows: 6,
        }
    }

    #[test]
    fn build_counts_and_sums() {
        let binned = toy_binned();
        let mut h = HistogramSet::new(&[3, 2]);
        let grad = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let hess = vec![1.0; 6];
        let rows: Vec<u32> = (0..6).collect();
        h.build(&binned, &rows, &grad, &hess);
        assert_eq!(h.bin(0, 0), (5.0, 2.0, 2)); // rows 0,3
        assert_eq!(h.bin(0, 1), (7.0, 2.0, 2)); // rows 1,4
        assert_eq!(h.bin(0, 2), (9.0, 2.0, 2)); // rows 2,5
        assert_eq!(h.bin(1, 0), (7.0, 2.0, 2)); // rows 2,3
        assert_eq!(h.bin(1, 1), (14.0, 4.0, 4));
        assert_eq!(h.totals(0), (21.0, 6.0, 6));
        assert_eq!(h.totals(1), (21.0, 6.0, 6));
    }

    #[test]
    fn build_subset_of_rows() {
        let binned = toy_binned();
        let mut h = HistogramSet::new(&[3, 2]);
        let grad = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let hess = vec![0.5; 6];
        h.build(&binned, &[1, 2], &grad, &hess);
        assert_eq!(h.bin(0, 0), (0.0, 0.0, 0));
        assert_eq!(h.bin(0, 1), (2.0, 0.5, 1));
        assert_eq!(h.bin(0, 2), (3.0, 0.5, 1));
    }

    #[test]
    fn prop_subtraction_equals_direct_build() {
        run_prop("histogram subtraction == direct build", 60, |g| {
            let n = g.usize_in(10, 200);
            let d = g.usize_in(1, 6);
            let bins_per: Vec<usize> = (0..d).map(|_| g.usize_in(2, 16)).collect();
            let binned = BinnedDataset {
                bins: (0..d)
                    .map(|f| (0..n).map(|_| g.usize(bins_per[f]) as u16).collect())
                    .collect(),
                n_rows: n,
            };
            let grad: Vec<f64> = (0..n).map(|_| g.normal()).collect();
            let hess: Vec<f64> = (0..n).map(|_| g.f64_in(0.01, 2.0)).collect();
            // random partition of rows
            let split = g.usize_in(0, n);
            let mut rows: Vec<u32> = (0..n as u32).collect();
            let mut rng = Pcg64::new(g.case_seed ^ 0xA5);
            rng.shuffle(&mut rows);
            let (left, right) = rows.split_at(split);
            let all: Vec<u32> = rows.clone();

            let mut hp = HistogramSet::new(&bins_per);
            hp.build(&binned, &all, &grad, &hess);
            let mut hl = HistogramSet::new(&bins_per);
            hl.build(&binned, left, &grad, &hess);
            let mut hr_direct = HistogramSet::new(&bins_per);
            hr_direct.build(&binned, right, &grad, &hess);
            let mut hr_sub = HistogramSet::new(&bins_per);
            hr_sub.subtract_into(&hp, &hl);

            for f in 0..d {
                for b in 0..bins_per[f] {
                    let (g1, h1, c1) = hr_direct.bin(f, b);
                    let (g2, h2, c2) = hr_sub.bin(f, b);
                    assert_eq!(c1, c2);
                    assert!((g1 - g2).abs() < 1e-9, "grad mismatch {g1} {g2}");
                    assert!((h1 - h2).abs() < 1e-9);
                }
            }
        });
    }

    #[test]
    fn reset_zeroes() {
        let binned = toy_binned();
        let mut h = HistogramSet::new(&[3, 2]);
        h.build(&binned, &[0, 1, 2], &[1.0; 6], &[1.0; 6]);
        h.reset();
        for f in 0..2 {
            for b in 0..h.n_bins(f) {
                assert_eq!(h.bin(f, b), (0.0, 0.0, 0));
            }
        }
    }
}
