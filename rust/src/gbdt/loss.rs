//! Boosting objectives: gradients/hessians of the training losses.
//!
//! Boosting works on *raw scores* `F(x)`; each objective defines how raw
//! scores map to predictions, the base (round-0) score, and the
//! first/second derivatives `g_i, h_i` used by the simplified objective
//! (paper Eq. 6 / Appendix A).

use crate::data::Task;

/// Objective kind; carries no state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// ½(y − F)² — regression.
    L2,
    /// log(1 + e^{−yF}) — binary classification, labels {0, 1}.
    Logistic,
    /// Softmax cross-entropy with one ensemble (raw score) per class.
    Softmax { n_classes: usize },
}

impl Objective {
    pub fn for_task(task: Task) -> Objective {
        match task {
            Task::Regression => Objective::L2,
            Task::Binary => Objective::Logistic,
            Task::Multiclass(c) => Objective::Softmax { n_classes: c },
        }
    }

    /// Number of parallel raw-score streams (ensembles).
    pub fn n_outputs(&self) -> usize {
        match self {
            Objective::Softmax { n_classes } => *n_classes,
            _ => 1,
        }
    }

    /// Initial raw score per output, from the label distribution.
    pub fn base_scores(&self, targets: &[f64], labels: &[usize]) -> Vec<f64> {
        match self {
            Objective::L2 => {
                let mean = targets.iter().sum::<f64>() / targets.len().max(1) as f64;
                vec![mean]
            }
            Objective::Logistic => {
                let p = labels.iter().sum::<usize>() as f64 / labels.len().max(1) as f64;
                let p = p.clamp(1e-6, 1.0 - 1e-6);
                vec![(p / (1.0 - p)).ln()]
            }
            Objective::Softmax { n_classes } => {
                // Log-priors (uniform fallback for empty classes).
                let mut counts = vec![0usize; *n_classes];
                for &l in labels {
                    counts[l] += 1;
                }
                let n = labels.len().max(1) as f64;
                counts
                    .iter()
                    .map(|&c| ((c as f64 / n).max(1e-6)).ln())
                    .collect()
            }
        }
    }

    /// Compute gradients and hessians in-place.
    ///
    /// `raw` is `[n_outputs][n_rows]` of current raw scores; `grad`/`hess`
    /// have the same shape. For L2 / Logistic only stream 0 is used.
    pub fn grad_hess(
        &self,
        raw: &[Vec<f64>],
        targets: &[f64],
        labels: &[usize],
        grad: &mut [Vec<f64>],
        hess: &mut [Vec<f64>],
    ) {
        match self {
            Objective::L2 => {
                for i in 0..targets.len() {
                    grad[0][i] = raw[0][i] - targets[i];
                    hess[0][i] = 1.0;
                }
            }
            Objective::Logistic => {
                for i in 0..labels.len() {
                    let p = sigmoid(raw[0][i]);
                    grad[0][i] = p - labels[i] as f64;
                    hess[0][i] = (p * (1.0 - p)).max(1e-16);
                }
            }
            Objective::Softmax { n_classes } => {
                let n = labels.len();
                for i in 0..n {
                    // Stable softmax over the class scores of row i.
                    let mut mx = f64::NEG_INFINITY;
                    for k in 0..*n_classes {
                        mx = mx.max(raw[k][i]);
                    }
                    let mut z = 0.0;
                    for k in 0..*n_classes {
                        z += (raw[k][i] - mx).exp();
                    }
                    for k in 0..*n_classes {
                        let p = (raw[k][i] - mx).exp() / z;
                        let y = (labels[i] == k) as usize as f64;
                        grad[k][i] = p - y;
                        // LightGBM's multiclass hessian factor 2·p(1−p)… we
                        // use the plain diagonal p(1−p) with a floor.
                        hess[k][i] = (p * (1.0 - p)).max(1e-16);
                    }
                }
            }
        }
    }

    /// Map raw scores to the task's prediction:
    /// regression value, or the argmax class.
    pub fn predict_class(&self, raw_row: &[f64]) -> usize {
        match self {
            Objective::L2 => panic!("predict_class on regression"),
            Objective::Logistic => (raw_row[0] > 0.0) as usize,
            Objective::Softmax { .. } => {
                let mut best = 0;
                for (k, &v) in raw_row.iter().enumerate() {
                    if v > raw_row[best] {
                        best = k;
                    }
                }
                best
            }
        }
    }

    /// Positive-class probability (binary) from a raw score.
    pub fn proba_binary(&self, raw: f64) -> f64 {
        sigmoid(raw)
    }
}

#[inline]
pub fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l2_grad_is_residual() {
        let obj = Objective::L2;
        let raw = vec![vec![1.0, 2.0]];
        let mut g = vec![vec![0.0; 2]];
        let mut h = vec![vec![0.0; 2]];
        obj.grad_hess(&raw, &[3.0, 2.0], &[], &mut g, &mut h);
        assert_eq!(g[0], vec![-2.0, 0.0]);
        assert_eq!(h[0], vec![1.0, 1.0]);
    }

    #[test]
    fn logistic_grad_signs() {
        let obj = Objective::Logistic;
        let raw = vec![vec![0.0, 0.0]];
        let mut g = vec![vec![0.0; 2]];
        let mut h = vec![vec![0.0; 2]];
        obj.grad_hess(&raw, &[], &[1, 0], &mut g, &mut h);
        assert!((g[0][0] + 0.5).abs() < 1e-12); // p=0.5, y=1 -> -0.5
        assert!((g[0][1] - 0.5).abs() < 1e-12);
        assert!((h[0][0] - 0.25).abs() < 1e-12);
    }

    #[test]
    fn softmax_grads_sum_to_zero() {
        let obj = Objective::Softmax { n_classes: 3 };
        let raw = vec![vec![0.3], vec![-0.1], vec![1.2]];
        let mut g = vec![vec![0.0]; 3];
        let mut h = vec![vec![0.0]; 3];
        obj.grad_hess(&raw, &[], &[2], &mut g, &mut h);
        let s: f64 = (0..3).map(|k| g[k][0]).sum();
        assert!(s.abs() < 1e-12, "softmax grads sum to 0 across classes");
        assert!(g[2][0] < 0.0, "true class gradient is negative");
        assert!(h.iter().all(|hk| hk[0] > 0.0));
    }

    #[test]
    fn base_scores_match_priors() {
        let obj = Objective::Logistic;
        let b = obj.base_scores(&[], &[1, 1, 1, 0]);
        assert!((sigmoid(b[0]) - 0.75).abs() < 1e-9);

        let obj = Objective::Softmax { n_classes: 2 };
        let b = obj.base_scores(&[], &[0, 0, 1, 1]);
        assert!((b[0] - b[1]).abs() < 1e-12);

        let obj = Objective::L2;
        let b = obj.base_scores(&[2.0, 4.0], &[]);
        assert_eq!(b, vec![3.0]);
    }

    #[test]
    fn predict_class_argmax() {
        let obj = Objective::Softmax { n_classes: 3 };
        assert_eq!(obj.predict_class(&[0.1, 0.9, -0.5]), 1);
        let obj = Objective::Logistic;
        assert_eq!(obj.predict_class(&[0.2]), 1);
        assert_eq!(obj.predict_class(&[-0.2]), 0);
    }

    #[test]
    fn for_task_mapping() {
        assert_eq!(Objective::for_task(Task::Regression), Objective::L2);
        assert_eq!(Objective::for_task(Task::Binary), Objective::Logistic);
        assert_eq!(
            Objective::for_task(Task::Multiclass(7)),
            Objective::Softmax { n_classes: 7 }
        );
        assert_eq!(Objective::Softmax { n_classes: 7 }.n_outputs(), 7);
    }
}
