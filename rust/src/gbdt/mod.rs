//! Histogram-based gradient-boosted decision trees, from scratch.
//!
//! This is the LightGBM-equivalent substrate the paper builds on: the
//! second-order boosting objective of Chen & Guestrin (2016) (paper
//! Eq. 1/6), leaf-wise best-first tree growth bounded by `max_depth`,
//! and histogram split finding over quantile-binned features.
//!
//! The ToaD extension hooks in through [`splitter::SplitPenalty`]: every
//! candidate split's gain can be charged an extra cost (paper Eq. 3:
//! `Δ_l = Δ − s_f·ι − s_t·ξ`), and applied splits are reported back so
//! reuse registries stay current. The same hook implements the CEGB
//! baseline (Peter et al., 2017).

pub mod booster;
pub mod distributed;
pub mod grower;
pub mod histogram;
pub mod loss;
pub mod model;
pub mod splitter;
pub mod tree;

pub use booster::{
    train_sparse, train_sparse_with_penalty, BinStore, Booster, GbdtParams,
};
pub use distributed::{train_row_sharded, Reducer, SumReducer, REDUCE_SHARDS};
pub use grower::GrowthMode;
pub use model::GbdtModel;
pub use splitter::{NoPenalty, SplitPenalty};
pub use tree::{Node, Tree};
