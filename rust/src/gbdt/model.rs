//! The trained ensemble model and its prediction paths.

use super::loss::Objective;
use super::tree::Tree;
use crate::data::{BinMatrix, Dataset};

/// A trained gradient-boosted ensemble.
///
/// For multiclass tasks the model carries `n_outputs` parallel tree
/// sequences (one ensemble per class, as the paper notes in §4.2);
/// regression and binary tasks have a single sequence.
#[derive(Clone, Debug)]
pub struct GbdtModel {
    pub objective: Objective,
    /// Round-0 raw score per output stream.
    pub base_scores: Vec<f64>,
    /// `trees[output][round]`.
    pub trees: Vec<Vec<Tree>>,
    pub n_features: usize,
    pub name: String,
}

impl GbdtModel {
    pub fn n_outputs(&self) -> usize {
        self.trees.len()
    }

    /// Total number of trees across all outputs.
    pub fn n_trees(&self) -> usize {
        self.trees.iter().map(|t| t.len()).sum()
    }

    /// Boosting rounds completed (trees per output).
    pub fn n_rounds(&self) -> usize {
        self.trees.first().map_or(0, |t| t.len())
    }

    pub fn max_depth(&self) -> usize {
        self.trees.iter().flatten().map(|t| t.depth()).max().unwrap_or(0)
    }

    /// Raw scores for one dense row (one value per output stream).
    pub fn predict_raw(&self, x: &[f32]) -> Vec<f64> {
        let mut out = self.base_scores.clone();
        for (k, trees) in self.trees.iter().enumerate() {
            for t in trees {
                out[k] += t.predict_row(x);
            }
        }
        out
    }

    /// Regression prediction.
    pub fn predict_value(&self, x: &[f32]) -> f64 {
        debug_assert_eq!(self.objective, Objective::L2);
        self.predict_raw(x)[0]
    }

    /// Class prediction (binary or multiclass).
    pub fn predict_class(&self, x: &[f32]) -> usize {
        let raw = self.predict_raw(x);
        self.objective.predict_class(&raw)
    }

    /// Flatten into the SoA serving engine
    /// ([`crate::inference::FlatModel`]): branchless complete-tree
    /// descent + blocked batch prediction, bit-identical raw scores.
    pub fn flatten(&self) -> crate::inference::FlatModel {
        crate::inference::FlatModel::from_model(self)
    }

    /// Quantize into the rank-threshold serving engine
    /// ([`crate::inference::QuantizedFlatModel`]): `u16` threshold
    /// ranks, pre-binned rows, multi-row interleaved descent —
    /// bit-identical raw scores.
    pub fn quantize(&self) -> crate::inference::QuantizedFlatModel {
        crate::inference::QuantizedFlatModel::from_model(self)
    }

    /// Evaluate the task metric on a dataset: accuracy for
    /// classification, R² for regression (paper §4.1).
    ///
    /// Routed through the quantized flat batch engine — sweeps score
    /// whole grids of models, so dataset-scale evaluation takes the
    /// blocked multi-row path rather than walking pointer trees row by
    /// row. Predictions are bit-identical to the pointer traversal (and
    /// to [`GbdtModel::flatten`]'s engine), so metric values are
    /// unchanged by the routing.
    pub fn score(&self, data: &Dataset) -> f64 {
        crate::inference::Predictor::score(&self.quantize(), data)
    }

    /// [`GbdtModel::score`] under an adaptive early-exit policy:
    /// quantizes once and scores through the margin-bounded engine,
    /// reporting the mean trees evaluated per row alongside the metric.
    /// [`crate::inference::AdaptivePolicy::Exact`] reproduces `score`
    /// bit-identically at full depth.
    pub fn score_adaptive(
        &self,
        data: &Dataset,
        policy: crate::inference::AdaptivePolicy,
    ) -> crate::inference::AdaptiveScore {
        crate::inference::Predictor::score_adaptive(&self.quantize(), data, policy)
    }

    /// Raw-score prediction over binned data (training-path shortcut:
    /// routing by bin index is exact on rows binned with the same
    /// binner).
    pub fn predict_raw_binned(&self, binned: &BinMatrix, i: usize) -> Vec<f64> {
        let mut out = self.base_scores.clone();
        for (k, trees) in self.trees.iter().enumerate() {
            for t in trees {
                out[k] += predict_binned(t, binned, i);
            }
        }
        out
    }
}

/// Traverse a tree using bin indices instead of float thresholds.
#[inline]
pub fn predict_binned(tree: &Tree, binned: &BinMatrix, i: usize) -> f64 {
    use super::tree::Node;
    let mut idx = 0usize;
    loop {
        match &tree.nodes[idx] {
            Node::Leaf { value } => return *value,
            Node::Internal { feature, bin, left, right, .. } => {
                idx = if binned.bin(*feature, i) <= *bin { *left } else { *right };
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gbdt::tree::Node;

    fn two_tree_model() -> GbdtModel {
        let t1 = Tree {
            nodes: vec![
                Node::Internal { feature: 0, bin: 0, threshold: 0.0, left: 1, right: 2 },
                Node::Leaf { value: -1.0 },
                Node::Leaf { value: 1.0 },
            ],
        };
        let t2 = Tree::leaf(0.5);
        GbdtModel {
            objective: Objective::L2,
            base_scores: vec![10.0],
            trees: vec![vec![t1, t2]],
            n_features: 1,
            name: "m".into(),
        }
    }

    #[test]
    fn raw_is_base_plus_trees() {
        let m = two_tree_model();
        assert_eq!(m.predict_raw(&[-1.0]), vec![9.5]);
        assert_eq!(m.predict_raw(&[1.0]), vec![11.5]);
        assert_eq!(m.n_trees(), 2);
        assert_eq!(m.n_rounds(), 2);
        assert_eq!(m.max_depth(), 1);
    }

    #[test]
    fn binary_class_prediction() {
        let mut m = two_tree_model();
        m.objective = Objective::Logistic;
        m.base_scores = vec![0.0];
        assert_eq!(m.predict_class(&[1.0]), 1); // raw = 1.5 > 0
        assert_eq!(m.predict_class(&[-10.0]), 0); // raw = -0.5
    }

    #[test]
    fn binned_prediction_matches() {
        let m = two_tree_model();
        let binned = BinMatrix::from_u16_columns(vec![vec![0, 1]]);
        // bin 0 <= 0 -> left; bin 1 > 0 -> right
        assert_eq!(m.predict_raw_binned(&binned, 0), vec![9.5]);
        assert_eq!(m.predict_raw_binned(&binned, 1), vec![11.5]);
    }
}
