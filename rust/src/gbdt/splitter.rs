//! Split finding: the gain scan over leaf histograms, with the penalty
//! hook that carries the paper's contribution.
//!
//! For a leaf with totals `(G, H)`, splitting feature `i` at boundary
//! `µ` gives (paper Eq. 7):
//!
//! ```text
//! Δ_l(I, i, µ) = ½ (G_L²/(H_L+λ) + G_R²/(H_R+λ) − G²/(H+λ)) − γ − s_f·ι − s_t·ξ
//! ```
//!
//! The `− s_f·ι − s_t·ξ` term is abstracted behind [`SplitPenalty`]:
//! ToaD charges new features/thresholds (and the CEGB baseline charges
//! feature acquisition), while the plain trainer uses [`NoPenalty`].

use super::histogram::HistogramSet;

/// Pluggable gain penalty (paper Eq. 3). Implementations must be cheap:
/// `penalty` is called once per candidate `(feature, boundary)` pair.
pub trait SplitPenalty {
    /// Extra cost subtracted from the raw gain for splitting `feature`
    /// at boundary index `bin`.
    fn penalty(&self, feature: usize, bin: u16) -> f64;

    /// Called when a split is actually applied, so reuse registries can
    /// absorb the new feature/threshold.
    fn on_split(&mut self, feature: usize, bin: u16);

    /// Monotone counter bumped whenever registry state changes in a way
    /// that can alter future `penalty` values. The grower uses this to
    /// lazily recompute stale candidate splits.
    fn version(&self) -> u64;
}

/// The unpenalized baseline: plain LightGBM-style gain.
#[derive(Default, Clone, Debug)]
pub struct NoPenalty;

impl SplitPenalty for NoPenalty {
    #[inline]
    fn penalty(&self, _feature: usize, _bin: u16) -> f64 {
        0.0
    }
    fn on_split(&mut self, _feature: usize, _bin: u16) {}
    fn version(&self) -> u64 {
        0
    }
}

/// Structural regularization of the underlying booster.
#[derive(Clone, Copy, Debug)]
pub struct SplitParams {
    /// L2 leaf-value regularization λ.
    pub lambda: f64,
    /// Per-leaf cost γ (a split adds one leaf, so it is charged once).
    pub gamma: f64,
    /// Minimum rows on each side of a split.
    pub min_data_in_leaf: u32,
    /// Minimum hessian mass on each side.
    pub min_hess_in_leaf: f64,
}

impl Default for SplitParams {
    fn default() -> Self {
        SplitParams { lambda: 1e-3, gamma: 0.0, min_data_in_leaf: 20, min_hess_in_leaf: 1e-3 }
    }
}

/// A chosen candidate split for a leaf.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SplitInfo {
    pub feature: usize,
    /// Boundary index: rows with `bin <= this` go left.
    pub bin: u16,
    /// Penalized gain Δ_l.
    pub gain: f64,
    pub left_grad: f64,
    pub left_hess: f64,
    pub left_count: u32,
    pub right_grad: f64,
    pub right_hess: f64,
    pub right_count: u32,
}

/// Leaf-objective contribution `G²/(H+λ)` (×½ applied by the caller).
/// `pub(crate)` so the oblivious grower's level scorer charges gains
/// with the exact same formula as the leaf-wise scan here.
#[inline]
pub(crate) fn score(g: f64, h: f64, lambda: f64) -> f64 {
    g * g / (h + lambda)
}

/// Optimal leaf weight `−G/(H+λ)`.
#[inline]
pub fn leaf_weight(g: f64, h: f64, lambda: f64) -> f64 {
    -g / (h + lambda)
}

/// Scan all features/bins of a leaf histogram and return the best
/// positive-gain split under `params` and `penalty`, if any.
pub fn best_split(
    hist: &HistogramSet,
    totals: (f64, f64, u32),
    params: &SplitParams,
    penalty: &dyn SplitPenalty,
) -> Option<SplitInfo> {
    let (gt, ht, ct) = totals;
    let parent_score = score(gt, ht, params.lambda);
    let mut best: Option<SplitInfo> = None;

    for f in 0..hist.n_features() {
        let n_bins = hist.n_bins(f);
        if n_bins < 2 {
            continue; // constant feature
        }
        // One contiguous `[g, h, c]` triple slice per feature: the scan
        // walks it linearly instead of re-deriving the flat offset (and
        // re-checking bounds) per bin.
        let tri = hist.feature_bins(f);
        let (mut gl, mut hl, mut cl) = (0.0f64, 0.0f64, 0u32);
        // Boundary b separates bins [0..=b] from (b..): the last bin can
        // never be a left side on its own, hence `n_bins - 1` boundaries.
        for (b, bin) in tri.chunks_exact(3).take(n_bins - 1).enumerate() {
            let (bg, bh, bc) = (bin[0], bin[1], bin[2] as u32);
            gl += bg;
            hl += bh;
            cl += bc;
            let cr = ct - cl;
            if cl < params.min_data_in_leaf {
                continue;
            }
            if cr < params.min_data_in_leaf {
                break; // right side only shrinks from here on
            }
            let gr = gt - gl;
            let hr = ht - hl;
            if hl < params.min_hess_in_leaf || hr < params.min_hess_in_leaf {
                continue;
            }
            let raw_gain = 0.5 * (score(gl, hl, params.lambda) + score(gr, hr, params.lambda)
                - parent_score)
                - params.gamma;
            let gain = raw_gain - penalty.penalty(f, b as u16);
            if gain > 0.0 && best.map_or(true, |s| gain > s.gain) {
                best = Some(SplitInfo {
                    feature: f,
                    bin: b as u16,
                    gain,
                    left_grad: gl,
                    left_hess: hl,
                    left_count: cl,
                    right_grad: gr,
                    right_hess: hr,
                    right_count: cr,
                });
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::BinMatrix;

    /// Build a histogram where feature 0 perfectly separates gradients.
    fn separable_hist() -> (HistogramSet, (f64, f64, u32)) {
        let binned = BinMatrix::from_u16_columns(vec![
            vec![0, 0, 0, 1, 1, 1], // perfect separation at boundary 0
            vec![0, 1, 0, 1, 0, 1], // uninformative
        ]);
        let grad = vec![-1.0, -1.0, -1.0, 1.0, 1.0, 1.0];
        let hess = vec![1.0; 6];
        let mut h = HistogramSet::new(&[2, 2]);
        let rows: Vec<u32> = (0..6).collect();
        h.build(&binned, &rows, &grad, &hess);
        (h, (0.0, 6.0, 6))
    }

    fn loose() -> SplitParams {
        SplitParams { lambda: 1.0, gamma: 0.0, min_data_in_leaf: 1, min_hess_in_leaf: 0.0 }
    }

    #[test]
    fn finds_separating_split() {
        let (h, totals) = separable_hist();
        let s = best_split(&h, totals, &loose(), &NoPenalty).unwrap();
        assert_eq!(s.feature, 0);
        assert_eq!(s.bin, 0);
        assert_eq!(s.left_count, 3);
        assert_eq!(s.right_count, 3);
        // gain = 0.5*(9/4 + 9/4 - 0) = 2.25
        assert!((s.gain - 2.25).abs() < 1e-12);
    }

    #[test]
    fn gamma_reduces_gain() {
        let (h, totals) = separable_hist();
        let mut p = loose();
        p.gamma = 1.0;
        let s = best_split(&h, totals, &p, &NoPenalty).unwrap();
        assert!((s.gain - 1.25).abs() < 1e-12);
        p.gamma = 3.0; // exceeds raw gain -> no split
        assert!(best_split(&h, totals, &p, &NoPenalty).is_none());
    }

    #[test]
    fn min_data_blocks_small_sides() {
        let (h, totals) = separable_hist();
        let mut p = loose();
        p.min_data_in_leaf = 4; // both sides have 3
        assert!(best_split(&h, totals, &p, &NoPenalty).is_none());
    }

    #[test]
    fn penalty_changes_choice() {
        // Forbidding feature 0 redirects the split to the weaker
        // feature 1 (gain 0.25); forbidding feature 1 keeps feature 0.
        struct Forbid(usize);
        impl SplitPenalty for Forbid {
            fn penalty(&self, f: usize, _b: u16) -> f64 {
                if f == self.0 {
                    1e9
                } else {
                    0.0
                }
            }
            fn on_split(&mut self, _f: usize, _b: u16) {}
            fn version(&self) -> u64 {
                0
            }
        }
        let (h, totals) = separable_hist();
        let s1 = best_split(&h, totals, &loose(), &Forbid(0)).unwrap();
        assert_eq!(s1.feature, 1);
        assert!((s1.gain - 0.25).abs() < 1e-12);
        let s0 = best_split(&h, totals, &loose(), &Forbid(1)).unwrap();
        assert_eq!(s0.feature, 0);
    }

    #[test]
    fn penalized_gain_never_exceeds_raw() {
        struct Flat(f64);
        impl SplitPenalty for Flat {
            fn penalty(&self, _f: usize, _b: u16) -> f64 {
                self.0
            }
            fn on_split(&mut self, _f: usize, _b: u16) {}
            fn version(&self) -> u64 {
                0
            }
        }
        let (h, totals) = separable_hist();
        let raw = best_split(&h, totals, &loose(), &NoPenalty).unwrap();
        let pen = best_split(&h, totals, &loose(), &Flat(0.5)).unwrap();
        assert!((raw.gain - pen.gain - 0.5).abs() < 1e-12);
    }

    #[test]
    fn leaf_weight_formula() {
        assert_eq!(leaf_weight(-2.0, 3.0, 1.0), 0.5);
        assert_eq!(leaf_weight(0.0, 1.0, 1.0), 0.0);
    }
}
