//! Decision-tree representation shared by the trainer, the layouts, and
//! the native inference engines.
//!
//! Trees are stored as a flat node vector with explicit child indices
//! (root at index 0). Internal nodes carry both the split *threshold
//! value* (used at inference time) and the *boundary bin index* it came
//! from (the threshold's identity for the ToaD reuse registries and the
//! global threshold table, paper §3.1/§3.2.2).

/// One node of a decision tree.
#[derive(Clone, Debug, PartialEq)]
pub enum Node {
    Internal {
        /// Feature the node splits on.
        feature: usize,
        /// Boundary index within the feature's binning — the threshold's
        /// identity for reuse accounting.
        bin: u16,
        /// The split value; a row goes left iff `x[feature] <= threshold`.
        threshold: f32,
        /// Index of the left child in [`Tree::nodes`].
        left: usize,
        /// Index of the right child in [`Tree::nodes`].
        right: usize,
    },
    Leaf {
        /// Additive contribution of this leaf (shrinkage already applied).
        value: f64,
    },
}

/// A single decision tree. `nodes[0]` is the root; a tree that is a bare
/// leaf has exactly one node.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct Tree {
    pub nodes: Vec<Node>,
}

impl Tree {
    /// A tree consisting of a single leaf.
    pub fn leaf(value: f64) -> Tree {
        Tree { nodes: vec![Node::Leaf { value }] }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn n_leaves(&self) -> usize {
        self.nodes.iter().filter(|n| matches!(n, Node::Leaf { .. })).count()
    }

    pub fn n_internal(&self) -> usize {
        self.nodes.len() - self.n_leaves()
    }

    /// Maximum root-to-leaf edge count.
    pub fn depth(&self) -> usize {
        fn go(tree: &Tree, idx: usize) -> usize {
            match &tree.nodes[idx] {
                Node::Leaf { .. } => 0,
                Node::Internal { left, right, .. } => 1 + go(tree, *left).max(go(tree, *right)),
            }
        }
        if self.nodes.is_empty() {
            0
        } else {
            go(self, 0)
        }
    }

    /// Evaluate the tree on a dense feature row.
    #[inline]
    pub fn predict_row(&self, x: &[f32]) -> f64 {
        let mut idx = 0usize;
        loop {
            match &self.nodes[idx] {
                Node::Leaf { value } => return *value,
                Node::Internal { feature, threshold, left, right, .. } => {
                    idx = if x[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }

    /// Iterate over `(feature, bin, threshold)` of all internal nodes.
    pub fn splits(&self) -> impl Iterator<Item = (usize, u16, f32)> + '_ {
        self.nodes.iter().filter_map(|n| match n {
            Node::Internal { feature, bin, threshold, .. } => Some((*feature, *bin, *threshold)),
            Node::Leaf { .. } => None,
        })
    }

    /// Iterate over all leaf values.
    pub fn leaf_values(&self) -> impl Iterator<Item = f64> + '_ {
        self.nodes.iter().filter_map(|n| match n {
            Node::Leaf { value } => Some(*value),
            Node::Internal { .. } => None,
        })
    }

    /// Lay the tree out as a *complete* binary tree of its depth:
    /// position 0 is the root, children of position `i` are `2i+1` and
    /// `2i+2` (paper §3.2.1). Leaves shallower than the full depth are
    /// replicated into their would-be subtree so every slot is filled.
    /// Returns `(internal_slots, leaf_slots)` where `internal_slots` has
    /// `2^depth - 1` entries of `Option<(feature, bin, threshold)>`
    /// (`None` = pass-through slot under an early leaf) and `leaf_slots`
    /// has `2^depth` leaf values.
    pub fn to_complete(&self) -> (Vec<Option<(usize, u16, f32)>>, Vec<f64>) {
        self.to_complete_at(self.depth())
    }

    /// If every level of the complete layout shares one `(feature, bin,
    /// threshold)` split — a CatBoost-style *oblivious* tree — return
    /// the per-level splits, root level first. `None` for bare leaves,
    /// for trees with pass-through slots (an early leaf means part of a
    /// level has no split to share), and for any level whose slots mix
    /// splits. This is the single eligibility predicate shared by the
    /// ToaD encoder's oblivious sub-format, its size model, and the
    /// quantized engine's table-lookup descent, so the three can never
    /// disagree about which trees are oblivious.
    pub fn oblivious_levels(&self) -> Option<Vec<(usize, u16, f32)>> {
        let d = self.depth();
        if d == 0 {
            return None;
        }
        let (internal, _) = self.to_complete();
        let mut levels = Vec::with_capacity(d);
        for lvl in 0..d {
            let start = (1usize << lvl) - 1;
            let end = (1usize << (lvl + 1)) - 1;
            let first = internal[start]?;
            for slot in &internal[start + 1..end] {
                let (f, b, t) = (*slot)?;
                if f != first.0 || b != first.1 || t.to_bits() != first.2.to_bits() {
                    return None;
                }
            }
            levels.push(first);
        }
        Some(levels)
    }

    /// Like [`Tree::to_complete`] but padded to a caller-chosen depth
    /// `d >= self.depth()` (used to tensorize ensembles to a fixed shape
    /// for the XLA runtime).
    pub fn to_complete_at(&self, d: usize) -> (Vec<Option<(usize, u16, f32)>>, Vec<f64>) {
        assert!(d >= self.depth(), "target depth {d} < tree depth {}", self.depth());
        let n_internal = (1usize << d) - 1;
        let n_leaves = 1usize << d;
        let mut internal: Vec<Option<(usize, u16, f32)>> = vec![None; n_internal];
        let mut leaves = vec![0f64; n_leaves];

        // Walk (tree node, complete-slot, depth); early leaves fill the
        // whole leaf range under their slot.
        fn go(
            tree: &Tree,
            node: usize,
            slot: usize,
            depth_left: usize,
            internal: &mut [Option<(usize, u16, f32)>],
            leaves: &mut [f64],
        ) {
            match &tree.nodes[node] {
                Node::Leaf { value } => {
                    // All leaf slots in this subtree take this value.
                    // slot is relative to a complete tree with
                    // `depth_left` levels remaining below.
                    fill_leaves(slot, depth_left, *value, leaves, internal.len());
                }
                Node::Internal { feature, bin, threshold, left, right } => {
                    debug_assert!(depth_left > 0);
                    internal[slot] = Some((*feature, *bin, *threshold));
                    go(tree, *left, 2 * slot + 1, depth_left - 1, internal, leaves);
                    go(tree, *right, 2 * slot + 2, depth_left - 1, internal, leaves);
                }
            }
        }

        /// Fill every leaf slot reachable from `slot` with `value`.
        fn fill_leaves(
            slot: usize,
            depth_left: usize,
            value: f64,
            leaves: &mut [f64],
            n_internal: usize,
        ) {
            if depth_left == 0 {
                // `slot` indexes the heap array; leaf positions start at
                // n_internal.
                leaves[slot - n_internal] = value;
            } else {
                fill_leaves(2 * slot + 1, depth_left - 1, value, leaves, n_internal);
                fill_leaves(2 * slot + 2, depth_left - 1, value, leaves, n_internal);
            }
        }

        if d == 0 {
            // Bare leaf: one leaf slot, no internals.
            if let Node::Leaf { value } = self.nodes[0] {
                leaves[0] = value;
            }
            return (internal, leaves);
        }
        go(self, 0, 0, d, &mut internal, &mut leaves);
        (internal, leaves)
    }
}

/// Evaluate a complete-layout tree (as produced by [`Tree::to_complete`])
/// on a row — the pointer-less descent `i ← 2i+1+(x>µ)` of paper §3.2.1.
/// Pass-through slots (`None`) route left, matching the replication done
/// by `to_complete`.
#[inline]
pub fn predict_complete(
    internal: &[Option<(usize, u16, f32)>],
    leaves: &[f64],
    x: &[f32],
) -> f64 {
    let n_internal = internal.len();
    let mut i = 0usize;
    while i < n_internal {
        i = match internal[i] {
            Some((f, _, thr)) => {
                if x[f] <= thr {
                    2 * i + 1
                } else {
                    2 * i + 2
                }
            }
            None => 2 * i + 1,
        };
    }
    leaves[i - n_internal]
}

#[cfg(test)]
#[cfg(not(miri))] // trains models / generates datasets - too slow under the Miri interpreter
mod tests {
    use super::*;
    use crate::prng::Pcg64;
    use crate::testutil::prop::run_prop;

    /// x0 <= 0.5 ? (x1 <= 2.0 ? 1.0 : 2.0) : 3.0
    fn sample_tree() -> Tree {
        Tree {
            nodes: vec![
                Node::Internal { feature: 0, bin: 3, threshold: 0.5, left: 1, right: 2 },
                Node::Internal { feature: 1, bin: 7, threshold: 2.0, left: 3, right: 4 },
                Node::Leaf { value: 3.0 },
                Node::Leaf { value: 1.0 },
                Node::Leaf { value: 2.0 },
            ],
        }
    }

    #[test]
    fn predict_routes_correctly() {
        let t = sample_tree();
        assert_eq!(t.predict_row(&[0.4, 1.0]), 1.0);
        assert_eq!(t.predict_row(&[0.4, 3.0]), 2.0);
        assert_eq!(t.predict_row(&[0.6, 0.0]), 3.0);
        // boundary goes left
        assert_eq!(t.predict_row(&[0.5, 2.0]), 1.0);
    }

    #[test]
    fn counts_and_depth() {
        let t = sample_tree();
        assert_eq!(t.n_nodes(), 5);
        assert_eq!(t.n_leaves(), 3);
        assert_eq!(t.n_internal(), 2);
        assert_eq!(t.depth(), 2);
        assert_eq!(Tree::leaf(7.0).depth(), 0);
        assert_eq!(Tree::leaf(7.0).n_leaves(), 1);
    }

    #[test]
    fn splits_iterator() {
        let t = sample_tree();
        let s: Vec<_> = t.splits().collect();
        assert_eq!(s, vec![(0, 3, 0.5), (1, 7, 2.0)]);
        let lv: Vec<f64> = t.leaf_values().collect();
        assert_eq!(lv, vec![3.0, 1.0, 2.0]);
    }

    #[test]
    fn complete_layout_matches_pointer_tree() {
        let t = sample_tree();
        let (internal, leaves) = t.to_complete();
        assert_eq!(internal.len(), 3);
        assert_eq!(leaves.len(), 4);
        // The early leaf (value 3.0) is replicated under slot 2.
        assert_eq!(internal[2], None);
        for x in [[0.4f32, 1.0], [0.4, 3.0], [0.6, 0.0], [0.5, 2.0], [0.9, 9.9]] {
            assert_eq!(predict_complete(&internal, &leaves, &x), t.predict_row(&x));
        }
    }

    #[test]
    fn complete_at_padded_depth_is_equivalent() {
        let t = sample_tree(); // depth 2
        let (internal, leaves) = t.to_complete_at(4);
        assert_eq!(internal.len(), 15);
        assert_eq!(leaves.len(), 16);
        for x in [[0.4f32, 1.0], [0.4, 3.0], [0.6, 0.0], [0.5, 2.0]] {
            assert_eq!(predict_complete(&internal, &leaves, &x), t.predict_row(&x));
        }
    }

    #[test]
    #[should_panic(expected = "target depth")]
    fn complete_at_too_shallow_panics() {
        sample_tree().to_complete_at(1);
    }

    #[test]
    fn bare_leaf_complete() {
        let t = Tree::leaf(42.0);
        let (internal, leaves) = t.to_complete();
        assert!(internal.is_empty());
        assert_eq!(leaves, vec![42.0]);
        assert_eq!(predict_complete(&internal, &leaves, &[1.0]), 42.0);
    }

    /// A depth-2 oblivious tree: both level-1 slots share (1, 7, 2.0).
    fn oblivious_tree() -> Tree {
        Tree {
            nodes: vec![
                Node::Internal { feature: 0, bin: 3, threshold: 0.5, left: 1, right: 2 },
                Node::Internal { feature: 1, bin: 7, threshold: 2.0, left: 3, right: 4 },
                Node::Internal { feature: 1, bin: 7, threshold: 2.0, left: 5, right: 6 },
                Node::Leaf { value: 1.0 },
                Node::Leaf { value: 2.0 },
                Node::Leaf { value: 3.0 },
                Node::Leaf { value: 4.0 },
            ],
        }
    }

    #[test]
    fn oblivious_levels_detects_level_uniform_trees() {
        assert_eq!(
            oblivious_tree().oblivious_levels(),
            Some(vec![(0, 3, 0.5), (1, 7, 2.0)])
        );
        // A stump is a one-level oblivious tree.
        let stump = Tree {
            nodes: vec![
                Node::Internal { feature: 2, bin: 1, threshold: 4.0, left: 1, right: 2 },
                Node::Leaf { value: -1.0 },
                Node::Leaf { value: 1.0 },
            ],
        };
        assert_eq!(stump.oblivious_levels(), Some(vec![(2, 1, 4.0)]));
        // Bare leaves, early leaves (pass-through slots), and levels
        // mixing splits are all non-oblivious.
        assert_eq!(Tree::leaf(0.5).oblivious_levels(), None);
        assert_eq!(sample_tree().oblivious_levels(), None, "early leaf disqualifies");
        let mut mixed = oblivious_tree();
        mixed.nodes[2] = Node::Internal { feature: 0, bin: 3, threshold: 0.5, left: 5, right: 6 };
        assert_eq!(mixed.oblivious_levels(), None, "mixed level disqualifies");
    }

    /// Build a random tree over `d` features with random structure.
    fn random_tree(rng: &mut Pcg64, d: usize, max_depth: usize) -> Tree {
        fn grow(rng: &mut Pcg64, d: usize, depth: usize, max_depth: usize, nodes: &mut Vec<Node>) -> usize {
            let idx = nodes.len();
            if depth >= max_depth || rng.gen_bool(0.3) {
                nodes.push(Node::Leaf { value: rng.gen_uniform(-2.0, 2.0) });
                return idx;
            }
            nodes.push(Node::Leaf { value: 0.0 }); // placeholder
            let feature = rng.gen_range(d);
            let bin = rng.gen_range(32) as u16;
            let threshold = rng.gen_uniform(-1.0, 1.0) as f32;
            let left = grow(rng, d, depth + 1, max_depth, nodes);
            let right = grow(rng, d, depth + 1, max_depth, nodes);
            nodes[idx] = Node::Internal { feature, bin, threshold, left, right };
            idx
        }
        let mut nodes = Vec::new();
        grow(rng, d, 0, max_depth, &mut nodes);
        Tree { nodes }
    }

    #[test]
    fn prop_complete_layout_equivalence() {
        // Property: for any tree and any input, the complete-array
        // descent returns the same value as pointer traversal — the
        // invariant the whole ToaD layout rests on.
        run_prop("complete layout equivalence", 200, |g| {
            let d = g.usize_in(1, 8);
            let max_depth = g.usize_in(0, 5);
            let t = random_tree(g.rng(), d, max_depth);
            let (internal, leaves) = t.to_complete();
            assert_eq!(internal.len() + 1, leaves.len());
            for _ in 0..16 {
                let x: Vec<f32> =
                    (0..d).map(|_| g.f64_in(-1.5, 1.5) as f32).collect();
                assert_eq!(predict_complete(&internal, &leaves, &x), t.predict_row(&x));
            }
        });
    }
}
