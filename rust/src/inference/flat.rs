//! `FlatModel` — the flattened, cache-conscious native inference engine.
//!
//! [`crate::gbdt::GbdtModel`] stores trees as vectors of enum nodes:
//! every step of a descent chases a pointer into a 48-byte `Node` and
//! branches on the variant. That is fine for debugging and for the
//! layouts, but it is the wrong shape for a serving hot path. This
//! module rebuilds a trained ensemble into structure-of-arrays form
//! (PACSET-style cache-conscious serialization):
//!
//! * **Complete-tree fast path** — trees that are (nearly) complete are
//!   stored as pointer-less heap arrays: contiguous `u16` feature ids
//!   and `f32` thresholds for the `2^d − 1` internal slots, `f64`
//!   values for the `2^d` leaves. The descent is the branchless
//!   `i ← 2i + 2 − (x[f] ≤ t)` of the paper's §3.2.1 — no child
//!   indices, no leaf test, no unpredictable branch. The predicate is
//!   the exact `x ≤ t` the pointer trees use, so NaN inputs route right
//!   identically. Early leaves are replicated by [`Tree::to_complete`];
//!   pass-through slots get a `+∞` threshold so ordered values route
//!   left (a NaN falls right into a replica of the same leaf value).
//! * **General node path** — deep, sparse trees (where completing would
//!   blow up memory) are flattened into parallel `feat`/`thr`/
//!   `children`/`leaf` arrays with siblings adjacent, so one `u32`
//!   child index serves both directions (`left + (x[f] > t)`).
//! * **Blocked batch API** — [`FlatModel::predict_batch`] iterates
//!   tree-outer / row-inner over [`BLOCK_ROWS`]-row blocks: each tree's
//!   arrays are pulled into cache once and amortized over the whole
//!   block instead of being re-fetched per row (Daghero et al.'s batch
//!   regime for edge inference).
//!
//! Predictions are bit-identical to `GbdtModel::predict_raw`: the same
//! comparisons route the same way and leaf contributions are summed in
//! the same order.

use crate::gbdt::loss::Objective;
use crate::gbdt::tree::{Node, Tree};
use crate::gbdt::GbdtModel;

/// Rows per block of the batched predict loop. 64 rows × 54 features of
/// f32 is ~13.5 KB — a block of inputs and its accumulators stay L1/L2
/// resident while an entire tree is streamed over them.
pub const BLOCK_ROWS: usize = 64;

/// Sentinel feature id marking a leaf slot in the general node arrays.
const LEAF: u16 = u16::MAX;

/// Upper depth bound for the complete-tree layout (2^d slots).
const MAX_COMPLETE_DEPTH: usize = 10;

/// Layout policy shared by the flat and quantized engines: a tree takes
/// the complete fast path when its depth is bounded and leaf
/// replication blows up the node count at most 4×. Keeping this in one
/// place guarantees both engines route every tree through equivalent
/// layouts (an invariant the parity tests rely on).
#[inline]
pub(crate) fn complete_layout_ok(depth: usize, n_nodes: usize) -> bool {
    depth <= MAX_COMPLETE_DEPTH && (1usize << depth) <= 4 * n_nodes
}

/// Where one tree lives inside the model's arrays. Shared with the
/// quantized engine ([`crate::inference::QuantizedFlatModel`]), which
/// uses the same two layouts over rank-quantized threshold arrays.
#[derive(Clone, Copy, Debug)]
pub(crate) enum TreeRef {
    /// Complete heap layout: `2^depth − 1` internal slots at `ioff`
    /// (in `cfeat`/`cthr`), `2^depth` leaf slots at `loff` (in `cleaf`).
    Complete { ioff: u32, loff: u32, depth: u8 },
    /// General layout: node-local indices based at `off` in
    /// `feat`/`thr`/`children`.
    Nodes { off: u32 },
    /// Oblivious (level-shared) layout: `depth` per-level split records
    /// at `ooff` in the quantized engine's level arrays, `2^depth` leaf
    /// slots at `loff` in `cleaf`. Only `QuantizedFlatModel` constructs
    /// this variant — the float engine keeps oblivious trees on the
    /// `Complete` path (its descent is threshold-value based, where the
    /// level sharing buys nothing).
    Oblivious { ooff: u32, loff: u32, depth: u8 },
}

/// A trained ensemble flattened for serving. Build one with
/// [`FlatModel::from_model`] (or [`GbdtModel::flatten`]) and keep it for
/// the model's serving lifetime — construction walks every node once.
#[derive(Clone, Debug)]
pub struct FlatModel {
    objective: Objective,
    base_scores: Vec<f64>,
    n_features: usize,
    /// `trees[output][round]`, same order as the source model.
    trees: Vec<Vec<TreeRef>>,
    // Complete-layout storage.
    cfeat: Vec<u16>,
    cthr: Vec<f32>,
    cleaf: Vec<f64>,
    // General node storage (siblings adjacent; `children[i]` is the
    // node-local left-child index, or the `leaf` index when
    // `feat[i] == LEAF`).
    feat: Vec<u16>,
    thr: Vec<f32>,
    children: Vec<u32>,
    leaf: Vec<f64>,
}

/// Flatten `tree` into the general node arrays (siblings adjacent) and
/// return its base offset.
fn flatten_nodes(
    tree: &Tree,
    feat: &mut Vec<u16>,
    thr: &mut Vec<f32>,
    children: &mut Vec<u32>,
    leaf: &mut Vec<f64>,
) -> u32 {
    let start = feat.len();
    let n = tree.nodes.len();
    feat.resize(start + n, LEAF);
    thr.resize(start + n, 0.0);
    children.resize(start + n, 0);
    // Local slot 0 is the root; each internal node claims the next two
    // slots for its children so `right == left + 1` by construction.
    let mut next_local = 1usize;
    let mut stack = vec![(0usize, 0usize)]; // (source node, local slot)
    while let Some((ti, li)) = stack.pop() {
        match &tree.nodes[ti] {
            Node::Leaf { value } => {
                feat[start + li] = LEAF;
                children[start + li] = leaf.len() as u32;
                leaf.push(*value);
            }
            Node::Internal { feature, threshold, left, right, .. } => {
                feat[start + li] = *feature as u16;
                thr[start + li] = *threshold;
                let cl = next_local;
                next_local += 2;
                children[start + li] = cl as u32;
                stack.push((*right, cl + 1));
                stack.push((*left, cl));
            }
        }
    }
    debug_assert_eq!(next_local, n, "every node must land in exactly one slot");
    start as u32
}

impl FlatModel {
    /// Flatten a trained model. Chooses per tree between the complete
    /// fast path (bounded depth, ≤ 4× node blow-up from leaf
    /// replication) and the general node layout.
    pub fn from_model(model: &GbdtModel) -> FlatModel {
        assert!(
            model.n_features < LEAF as usize,
            "feature ids must fit u16 below the leaf sentinel"
        );
        let mut flat = FlatModel {
            objective: model.objective,
            base_scores: model.base_scores.clone(),
            n_features: model.n_features,
            trees: Vec::with_capacity(model.trees.len()),
            cfeat: Vec::new(),
            cthr: Vec::new(),
            cleaf: Vec::new(),
            feat: Vec::new(),
            thr: Vec::new(),
            children: Vec::new(),
            leaf: Vec::new(),
        };
        for trees in &model.trees {
            let mut refs = Vec::with_capacity(trees.len());
            for tree in trees {
                let depth = tree.depth();
                if complete_layout_ok(depth, tree.n_nodes()) {
                    let (internal, leaves) = tree.to_complete();
                    let ioff = flat.cfeat.len() as u32;
                    let loff = flat.cleaf.len() as u32;
                    for slot in &internal {
                        match slot {
                            Some((f, _, t)) => {
                                flat.cfeat.push(*f as u16);
                                flat.cthr.push(*t);
                            }
                            None => {
                                // Pass-through under an early leaf:
                                // x[0] <= +∞ routes left (NaN routes
                                // right into a replica of the same
                                // value), matching `Tree::to_complete`'s
                                // replication.
                                flat.cfeat.push(0);
                                flat.cthr.push(f32::INFINITY);
                            }
                        }
                    }
                    flat.cleaf.extend_from_slice(&leaves);
                    refs.push(TreeRef::Complete { ioff, loff, depth: depth as u8 });
                } else {
                    let off = flatten_nodes(
                        tree,
                        &mut flat.feat,
                        &mut flat.thr,
                        &mut flat.children,
                        &mut flat.leaf,
                    );
                    refs.push(TreeRef::Nodes { off });
                }
            }
            flat.trees.push(refs);
        }
        flat
    }

    pub fn objective(&self) -> Objective {
        self.objective
    }

    pub fn n_outputs(&self) -> usize {
        self.trees.len()
    }

    pub fn n_features(&self) -> usize {
        self.n_features
    }

    pub fn n_trees(&self) -> usize {
        self.trees.iter().map(|t| t.len()).sum()
    }

    /// How many trees took the complete fast path (introspection/tests).
    pub fn n_complete_trees(&self) -> usize {
        self.trees
            .iter()
            .flatten()
            .filter(|t| matches!(t, TreeRef::Complete { .. }))
            .count()
    }

    #[inline]
    fn eval_nodes(&self, off: usize, x: &[f32]) -> f64 {
        let mut i = off;
        loop {
            let f = self.feat[i];
            if f == LEAF {
                return self.leaf[self.children[i] as usize];
            }
            // `!(x <= t)` (not `x > t`): identical for ordered values,
            // and routes NaN right exactly like `Tree::predict_row`.
            let right = !(x[f as usize] <= self.thr[i]) as usize;
            i = off + self.children[i] as usize + right;
        }
    }

    #[inline]
    fn eval_complete(&self, ioff: usize, loff: usize, depth: usize, x: &[f32]) -> f64 {
        let n_internal = (1usize << depth) - 1;
        let feat = &self.cfeat[ioff..ioff + n_internal];
        let thr = &self.cthr[ioff..ioff + n_internal];
        let mut i = 0usize;
        while i < n_internal {
            i = 2 * i + 2 - (x[feat[i] as usize] <= thr[i]) as usize;
        }
        self.cleaf[loff + i - n_internal]
    }

    #[inline]
    fn eval_tree(&self, tref: TreeRef, x: &[f32]) -> f64 {
        match tref {
            TreeRef::Complete { ioff, loff, depth } => {
                self.eval_complete(ioff as usize, loff as usize, depth as usize, x)
            }
            TreeRef::Nodes { off } => self.eval_nodes(off as usize, x),
            // `from_model` above routes every tree to Complete or
            // Nodes; only the quantized engine builds Oblivious refs.
            TreeRef::Oblivious { .. } => {
                unreachable!("FlatModel never constructs TreeRef::Oblivious")
            }
        }
    }

    /// Raw scores for one dense row (one value per output stream).
    /// Bit-identical to `GbdtModel::predict_raw`.
    pub fn predict_raw(&self, x: &[f32]) -> Vec<f64> {
        let mut out = self.base_scores.clone();
        for (k, trees) in self.trees.iter().enumerate() {
            for &tref in trees {
                out[k] += self.eval_tree(tref, x);
            }
        }
        out
    }

    /// Batched raw scores: tree-outer / row-inner over 64-row blocks.
    ///
    /// Returns one `Vec<f64>` of raw scores per input row, in order —
    /// numerically identical to calling [`FlatModel::predict_raw`] per
    /// row (same comparison routing, same summation order), just with
    /// each tree's arrays fetched once per block instead of once per
    /// row.
    pub fn predict_batch(&self, rows: &[Vec<f32>]) -> Vec<Vec<f64>> {
        let mut out: Vec<Vec<f64>> = rows.iter().map(|_| self.base_scores.clone()).collect();
        for start in (0..rows.len()).step_by(BLOCK_ROWS) {
            let end = (start + BLOCK_ROWS).min(rows.len());
            let block = &rows[start..end];
            for (k, trees) in self.trees.iter().enumerate() {
                for &tref in trees {
                    match tref {
                        TreeRef::Complete { ioff, loff, depth } => {
                            let (ioff, loff, depth) =
                                (ioff as usize, loff as usize, depth as usize);
                            let n_internal = (1usize << depth) - 1;
                            let feat = &self.cfeat[ioff..ioff + n_internal];
                            let thr = &self.cthr[ioff..ioff + n_internal];
                            let leaf = &self.cleaf[loff..loff + (1usize << depth)];
                            for (r, x) in block.iter().enumerate() {
                                let mut i = 0usize;
                                while i < n_internal {
                                    i = 2 * i + 2 - (x[feat[i] as usize] <= thr[i]) as usize;
                                }
                                out[start + r][k] += leaf[i - n_internal];
                            }
                        }
                        TreeRef::Nodes { off } => {
                            let off = off as usize;
                            for (r, x) in block.iter().enumerate() {
                                out[start + r][k] += self.eval_nodes(off, x);
                            }
                        }
                        // See `eval_tree`: this engine never builds
                        // Oblivious refs.
                        TreeRef::Oblivious { .. } => {
                            unreachable!("FlatModel never constructs TreeRef::Oblivious")
                        }
                    }
                }
            }
        }
        out
    }
}

impl From<&GbdtModel> for FlatModel {
    fn from(model: &GbdtModel) -> FlatModel {
        FlatModel::from_model(model)
    }
}

#[cfg(test)]
#[cfg(not(miri))] // trains models / generates datasets - too slow under the Miri interpreter
mod tests {
    use super::*;
    use crate::data::synth::PaperDataset;
    use crate::gbdt::{self, GbdtParams};
    use crate::prng::Pcg64;
    use crate::testutil::prop::run_prop;

    fn wrap(trees: Vec<Tree>, n_features: usize) -> GbdtModel {
        GbdtModel {
            objective: Objective::L2,
            base_scores: vec![0.25],
            trees: vec![trees],
            n_features,
            name: "flat-test".into(),
        }
    }

    /// x0 <= 0.5 ? (x1 <= 2.0 ? 1.0 : 2.0) : 3.0
    fn sample_tree() -> Tree {
        Tree {
            nodes: vec![
                Node::Internal { feature: 0, bin: 3, threshold: 0.5, left: 1, right: 2 },
                Node::Internal { feature: 1, bin: 7, threshold: 2.0, left: 3, right: 4 },
                Node::Leaf { value: 3.0 },
                Node::Leaf { value: 1.0 },
                Node::Leaf { value: 2.0 },
            ],
        }
    }

    /// A left-leaning chain deeper than the complete-layout cutoff, so
    /// it must take the general node path.
    fn chain_tree(depth: usize) -> Tree {
        let mut nodes = Vec::new();
        for d in 0..depth {
            let idx = nodes.len();
            nodes.push(Node::Internal {
                feature: 0,
                bin: d as u16,
                threshold: -(d as f32) * 0.1,
                left: idx + 2,
                right: idx + 1,
            });
            nodes.push(Node::Leaf { value: d as f64 });
        }
        nodes.push(Node::Leaf { value: -7.0 });
        Tree { nodes }
    }

    #[test]
    fn matches_pointer_trees_on_handmade_model() {
        let model = wrap(vec![sample_tree(), Tree::leaf(0.5), chain_tree(14)], 2);
        let flat = FlatModel::from_model(&model);
        assert_eq!(flat.n_trees(), 3);
        assert_eq!(flat.n_complete_trees(), 2); // the chain is too deep
        for x in [
            [0.4f32, 1.0],
            [0.4, 3.0],
            [0.6, 0.0],
            [0.5, 2.0],
            [-0.35, 9.0],
            [-2.0, -2.0],
        ] {
            let want = model.predict_raw(&x);
            assert_eq!(flat.predict_raw(&x), want);
            assert_eq!(flat.predict_batch(&[x.to_vec()])[0], want);
        }
    }

    #[test]
    fn batch_equals_single_row_exactly() {
        let data = PaperDataset::BreastCancer.generate(31).select(&(0..300).collect::<Vec<_>>());
        let model = gbdt::booster::train(&data, GbdtParams::paper(12, 3));
        let flat = FlatModel::from_model(&model);
        let rows: Vec<Vec<f32>> = (0..data.n_rows()).map(|i| data.row(i)).collect();
        let batch = flat.predict_batch(&rows);
        assert_eq!(batch.len(), rows.len());
        for (i, row) in rows.iter().enumerate() {
            let single = flat.predict_raw(row);
            let pointer = model.predict_raw(row);
            assert_eq!(batch[i], single, "row {i}: batch vs single");
            assert_eq!(batch[i], pointer, "row {i}: flat vs pointer");
        }
    }

    #[test]
    fn prop_flat_matches_pointer_on_random_trees() {
        run_prop("flat engine == pointer trees", 80, |g| {
            let d = g.usize_in(1, 8);
            let n_trees = g.usize_in(1, 6);
            let mut rng = Pcg64::new(g.case_seed ^ 0x77);
            let trees: Vec<Tree> =
                (0..n_trees).map(|_| random_tree(&mut rng, d, g.usize_in(0, 6))).collect();
            let model = wrap(trees, d);
            let flat = FlatModel::from_model(&model);
            let rows: Vec<Vec<f32>> = (0..g.usize_in(1, 70))
                .map(|_| (0..d).map(|_| g.f64_in(-1.5, 1.5) as f32).collect())
                .collect();
            let batch = flat.predict_batch(&rows);
            for (i, row) in rows.iter().enumerate() {
                assert_eq!(batch[i], model.predict_raw(row), "row {i}");
            }
        });
    }

    /// Random tree with arbitrary (non-adjacent-sibling) node order, so
    /// flattening actually has to re-lay things out.
    fn random_tree(rng: &mut Pcg64, d: usize, max_depth: usize) -> Tree {
        fn grow(
            rng: &mut Pcg64,
            d: usize,
            depth: usize,
            max_depth: usize,
            nodes: &mut Vec<Node>,
        ) -> usize {
            let idx = nodes.len();
            if depth >= max_depth || rng.gen_bool(0.3) {
                nodes.push(Node::Leaf { value: rng.gen_uniform(-2.0, 2.0) });
                return idx;
            }
            nodes.push(Node::Leaf { value: 0.0 }); // placeholder
            let feature = rng.gen_range(d);
            let bin = rng.gen_range(32) as u16;
            let threshold = rng.gen_uniform(-1.0, 1.0) as f32;
            let left = grow(rng, d, depth + 1, max_depth, nodes);
            let right = grow(rng, d, depth + 1, max_depth, nodes);
            nodes[idx] = Node::Internal { feature, bin, threshold, left, right };
            idx
        }
        let mut nodes = Vec::new();
        grow(rng, d, 0, max_depth, &mut nodes);
        Tree { nodes }
    }

    #[test]
    fn nan_inputs_route_like_pointer_trees() {
        // `x <= t` is false for NaN, so pointer trees send NaN right;
        // the flat engine must agree on both of its layouts.
        let model = wrap(vec![sample_tree(), chain_tree(14)], 2);
        let flat = FlatModel::from_model(&model);
        for x in [
            [f32::NAN, 1.0],
            [0.4, f32::NAN],
            [f32::NAN, f32::NAN],
        ] {
            let want = model.predict_raw(&x);
            assert_eq!(flat.predict_raw(&x), want);
            assert_eq!(flat.predict_batch(&[x.to_vec()])[0], want);
        }
    }

    #[test]
    fn multiclass_outputs_preserved() {
        let data = PaperDataset::WineQuality.generate(32).select(&(0..600).collect::<Vec<_>>());
        let model = gbdt::booster::train(&data, GbdtParams::paper(4, 2));
        let flat = FlatModel::from_model(&model);
        assert_eq!(flat.n_outputs(), 7);
        for i in (0..data.n_rows()).step_by(53) {
            let row = data.row(i);
            assert_eq!(flat.predict_raw(&row), model.predict_raw(&row));
        }
    }

    #[test]
    fn empty_model_returns_base_scores() {
        let model = wrap(Vec::new(), 3);
        let flat = FlatModel::from_model(&model);
        assert_eq!(flat.predict_raw(&[0.0, 0.0, 0.0]), vec![0.25]);
        assert_eq!(flat.predict_batch(&[]).len(), 0);
    }
}
