//! Native inference engines and the unified predictor interface.
//!
//! Three prediction paths exist in the system, all agreeing numerically
//! (integration-tested):
//!
//! 1. decoded pointer trees ([`crate::gbdt::GbdtModel`]) — fastest on a
//!    host CPU,
//! 2. direct bit-packed traversal ([`crate::layout::PackedModel`]) —
//!    what a microcontroller with the blob in flash executes,
//! 3. the XLA runtime ([`crate::runtime::PredictEngine`]) — the batched
//!    serving path.
//!
//! [`Predictor`] abstracts over the single-row paths so the coordinator
//! and benches can swap engines.

use crate::data::{Dataset, Task};
use crate::gbdt::loss::Objective;
use crate::gbdt::GbdtModel;
use crate::layout::PackedModel;

/// A single-row raw-score predictor.
pub trait Predictor {
    fn predict_raw(&self, x: &[f32]) -> Vec<f64>;
    fn n_outputs(&self) -> usize;
    fn objective(&self) -> Objective;

    /// Task-level prediction: class index (classification) packed as
    /// `f64`, or the regression value.
    fn predict_task(&self, x: &[f32]) -> f64 {
        let raw = self.predict_raw(x);
        match self.objective() {
            Objective::L2 => raw[0],
            obj => obj.predict_class(&raw) as f64,
        }
    }

    /// Dataset score: accuracy (classification) or R² (regression).
    fn score(&self, data: &Dataset) -> f64 {
        match data.task {
            Task::Regression => {
                let preds: Vec<f64> =
                    (0..data.n_rows()).map(|i| self.predict_raw(&data.row(i))[0]).collect();
                crate::metrics::r2_score(&data.targets, &preds)
            }
            _ => {
                let preds: Vec<usize> = (0..data.n_rows())
                    .map(|i| {
                        let raw = self.predict_raw(&data.row(i));
                        self.objective().predict_class(&raw)
                    })
                    .collect();
                crate::metrics::accuracy(&data.labels, &preds)
            }
        }
    }
}

impl Predictor for GbdtModel {
    fn predict_raw(&self, x: &[f32]) -> Vec<f64> {
        GbdtModel::predict_raw(self, x)
    }
    fn n_outputs(&self) -> usize {
        GbdtModel::n_outputs(self)
    }
    fn objective(&self) -> Objective {
        self.objective
    }
}

impl Predictor for PackedModel {
    fn predict_raw(&self, x: &[f32]) -> Vec<f64> {
        PackedModel::predict_raw(self, x)
    }
    fn n_outputs(&self) -> usize {
        PackedModel::n_outputs(self)
    }
    fn objective(&self) -> Objective {
        PackedModel::objective(self)
    }
}

/// Batch helper over any predictor.
pub fn predict_batch(p: &dyn Predictor, rows: &[Vec<f32>]) -> Vec<Vec<f64>> {
    rows.iter().map(|r| p.predict_raw(r)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::PaperDataset;
    use crate::gbdt::{self, GbdtParams};
    use crate::layout::{encode, EncodeOptions, FeatureInfo};

    #[test]
    fn predictor_paths_agree() {
        let data = PaperDataset::BreastCancer.generate(41).select(&(0..400).collect::<Vec<_>>());
        let model = gbdt::booster::train(&data, GbdtParams::paper(10, 3));
        let finfo = FeatureInfo::from_dataset(&data);
        let blob = encode(&model, &finfo, &EncodeOptions { allow_f16: false, ..Default::default() });
        let packed = PackedModel::from_bytes(blob);

        let s1 = Predictor::score(&model, &data);
        let s2 = Predictor::score(&packed, &data);
        assert!((s1 - s2).abs() < 1e-9, "decoded {s1} vs packed {s2}");

        let rows: Vec<Vec<f32>> = (0..8).map(|i| data.row(i)).collect();
        let a = predict_batch(&model, &rows);
        let b = predict_batch(&packed, &rows);
        for (x, y) in a.iter().zip(&b) {
            assert!((x[0] - y[0]).abs() < 1e-5);
        }
    }

    #[test]
    fn predict_task_regression_vs_classification() {
        let reg = PaperDataset::Kin8nm.generate(42).select(&(0..300).collect::<Vec<_>>());
        let m = gbdt::booster::train(&reg, GbdtParams::paper(5, 2));
        let v = m.predict_task(&reg.row(0));
        assert!(v.is_finite());

        let cls = PaperDataset::Mushroom.generate(43).select(&(0..300).collect::<Vec<_>>());
        let mc = gbdt::booster::train(&cls, GbdtParams::paper(5, 2));
        let c = mc.predict_task(&cls.row(0));
        assert!(c == 0.0 || c == 1.0);
    }
}
