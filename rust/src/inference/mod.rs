//! Native inference engines and the unified predictor interface.
//!
//! Four prediction paths exist in the system, all agreeing numerically
//! (integration-tested):
//!
//! 1. the flattened SoA engine ([`FlatModel`]) — branchless
//!    complete-tree descent plus a blocked tree-outer/row-inner batch
//!    API; bit-identical to the decoded pointer trees
//!    ([`crate::gbdt::GbdtModel`]),
//! 2. the quantized-threshold flat engine ([`QuantizedFlatModel`]) —
//!    the same layouts with `u16` threshold *ranks* instead of `f32`
//!    values: rows are pre-binned once per block and descents run a
//!    lane group of rows per tree walk through the runtime-dispatched
//!    SIMD kernel ([`crate::simd`]: AVX2/SSE2 vectors, scalar
//!    fallback); also bit-identical (on every dispatch tier), and the
//!    default dataset-scoring path,
//! 3. direct bit-packed traversal ([`crate::layout::PackedModel`]) —
//!    what a microcontroller with the blob in flash executes,
//! 4. the XLA runtime ([`crate::runtime`], `xla` feature) — the
//!    accelerator-offload serving path.
//!
//! [`Predictor`] abstracts over the native paths so the coordinator and
//! benches can swap engines; `predict_raw_batch` has a row-loop default
//! so single-row engines participate in batch serving, while
//! [`FlatModel`] and [`QuantizedFlatModel`] override it with their
//! blocked kernels. `predict_raw_columns` is the column-major entry
//! point (the orientation datasets and the gateway batcher already
//! hold): the default gathers rows, and [`QuantizedFlatModel`]
//! overrides it with a zero-gather kernel that bins each column once
//! into the shared `BinMatrix` arena.
//!
//! Batch entry points additionally come in `_adaptive` twins taking an
//! [`AdaptivePolicy`]: under [`AdaptivePolicy::Margin`] the quantized
//! engine retires rows whose outcome is already decided by the
//! precomputed suffix bounds (see [`quantized`]), returning per-row
//! trees-evaluated counts alongside the scores ([`AdaptiveBatch`]);
//! under [`AdaptivePolicy::Exact`] — and on engines without an
//! early-exit kernel — they are bit-identical to the plain entry
//! points at full depth.

pub mod flat;
pub mod quantized;

pub use flat::FlatModel;
pub use quantized::QuantizedFlatModel;

use crate::data::{Dataset, Task};
use crate::gbdt::loss::Objective;
use crate::gbdt::GbdtModel;
use crate::layout::PackedModel;

/// How a batched prediction may finish rows before walking every tree.
///
/// The quantized engine precomputes, per output stream, the min/max
/// total contribution of every tree suffix (from per-tree leaf
/// extrema). After tree `t`, a row's full raw score provably lies in
/// `[partial + lo, partial + hi]` where `(lo, hi)` bound trees `t+1..`;
/// the policy decides what to do with that interval:
///
/// * [`AdaptivePolicy::Exact`]: nothing — every tree is walked and the
///   output is bit-identical to the non-adaptive entry points on every
///   SIMD tier.
/// * [`AdaptivePolicy::Margin`]`(eps)`: retire a row once its interval
///   no longer straddles the decision boundary (binary classification:
///   the sign — provably the same class as full evaluation), or once
///   the interval is narrower than `eps` (raw-score units: the
///   completed score errs by less than `eps / 2`, so a class flip is
///   only possible for rows whose full score lies within `eps` of the
///   boundary). Retired rows are completed with the interval midpoint.
///
/// `Margin(0.0)` admits no score deviation and therefore routes to the
/// exact kernel, as do non-positive/NaN tolerances, multi-output
/// ensembles (no single sign to bound), and empty ensembles.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum AdaptivePolicy {
    /// Walk every tree for every row.
    #[default]
    Exact,
    /// Early-exit with tolerance `eps` in raw-score units.
    Margin(f32),
}

impl AdaptivePolicy {
    /// The armed tolerance: `Some(eps)` iff this policy permits early
    /// exit at all. Only a strictly positive, non-NaN `eps` arms the
    /// adaptive kernel — everything else is `Exact` by construction.
    pub fn tolerance(self) -> Option<f64> {
        match self {
            AdaptivePolicy::Exact => None,
            AdaptivePolicy::Margin(eps) if eps > 0.0 => Some(eps as f64),
            AdaptivePolicy::Margin(_) => None,
        }
    }
}

/// Scores plus per-row evaluation depth from an adaptive batch call.
#[derive(Clone, Debug)]
pub struct AdaptiveBatch {
    /// Raw scores in original row order (one inner vec per row).
    pub scores: Vec<Vec<f64>>,
    /// Trees actually walked per row — equal to the model's total tree
    /// count whenever the row never exited (or the policy was exact).
    pub trees_evaluated: Vec<u32>,
}

impl AdaptiveBatch {
    /// Mean trees walked per row (`0.0` for an empty batch).
    pub fn mean_trees(&self) -> f64 {
        if self.trees_evaluated.is_empty() {
            return 0.0;
        }
        self.trees_evaluated.iter().map(|&t| t as f64).sum::<f64>()
            / self.trees_evaluated.len() as f64
    }
}

/// A dataset metric plus the evaluation-depth statistic that produced
/// it — the two axes of the sweep's accuracy-vs-trees-evaluated curve.
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveScore {
    /// Accuracy (classification) or R² (regression).
    pub score: f64,
    /// Mean trees evaluated per row.
    pub mean_trees: f64,
}

/// A raw-score predictor.
pub trait Predictor {
    fn predict_raw(&self, x: &[f32]) -> Vec<f64>;
    fn n_outputs(&self) -> usize;
    fn objective(&self) -> Objective;

    /// Total trees in the ensemble (across output streams) — the
    /// denominator of the adaptive mean-trees statistic.
    fn n_trees(&self) -> usize;

    /// Raw scores for a batch of rows. Default: one row at a time;
    /// engines with a real batch kernel (e.g. [`FlatModel`]) override.
    fn predict_raw_batch(&self, rows: &[Vec<f32>]) -> Vec<Vec<f64>> {
        rows.iter().map(|r| self.predict_raw(r)).collect()
    }

    /// Raw scores for a column-major batch: `cols[f][i]` is feature `f`
    /// of row `i` — the orientation [`Dataset`] already stores. The
    /// default gathers rows and delegates to
    /// [`Predictor::predict_raw_batch`]; engines with a native columnar
    /// kernel ([`QuantizedFlatModel`]) override to skip the gather
    /// entirely.
    fn predict_raw_columns(&self, cols: &[&[f32]], n_rows: usize) -> Vec<Vec<f64>> {
        let rows: Vec<Vec<f32>> =
            (0..n_rows).map(|i| cols.iter().map(|c| c[i]).collect()).collect();
        self.predict_raw_batch(&rows)
    }

    /// Task-level prediction: class index (classification) packed as
    /// `f64`, or the regression value.
    fn predict_task(&self, x: &[f32]) -> f64 {
        let raw = self.predict_raw(x);
        match self.objective() {
            Objective::L2 => raw[0],
            obj => obj.predict_class(&raw) as f64,
        }
    }

    /// [`Predictor::predict_raw_batch`] under an adaptive exit policy,
    /// with per-row trees-evaluated counts. The default evaluates
    /// fully and reports full depth for every row — only engines with
    /// a real early-exit kernel ([`QuantizedFlatModel`]) override.
    /// [`AdaptivePolicy::Exact`] is always bit-identical to
    /// `predict_raw_batch`.
    fn predict_raw_batch_adaptive(
        &self,
        rows: &[Vec<f32>],
        policy: AdaptivePolicy,
    ) -> AdaptiveBatch {
        let _ = policy;
        let scores = self.predict_raw_batch(rows);
        AdaptiveBatch { trees_evaluated: vec![self.n_trees() as u32; scores.len()], scores }
    }

    /// Column-major twin of [`Predictor::predict_raw_batch_adaptive`].
    fn predict_raw_columns_adaptive(
        &self,
        cols: &[&[f32]],
        n_rows: usize,
        policy: AdaptivePolicy,
    ) -> AdaptiveBatch {
        let _ = policy;
        let scores = self.predict_raw_columns(cols, n_rows);
        AdaptiveBatch { trees_evaluated: vec![self.n_trees() as u32; scores.len()], scores }
    }

    /// Dataset score: accuracy (classification) or R² (regression).
    /// Feeds the dataset's feature columns straight into the columnar
    /// batch path in bounded chunks — engines with a columnar kernel
    /// never materialize a row, and peak memory stays at one chunk of
    /// outputs rather than the whole dataset.
    fn score(&self, data: &Dataset) -> f64 {
        self.score_adaptive(data, AdaptivePolicy::Exact).score
    }

    /// [`Predictor::score`] under an adaptive exit policy, also
    /// reporting the mean evaluation depth — one point of the
    /// accuracy-vs-trees-evaluated curve. Same chunked columnar walk
    /// as `score` (which is this method at `Exact`).
    fn score_adaptive(&self, data: &Dataset, policy: AdaptivePolicy) -> AdaptiveScore {
        const CHUNK: usize = 4 * flat::BLOCK_ROWS;
        let n = data.n_rows();
        let obj = self.objective();
        let mut reg_preds: Vec<f64> = Vec::new();
        let mut cls_preds: Vec<usize> = Vec::new();
        let mut trees_total = 0.0f64;
        let mut start = 0usize;
        while start < n {
            let end = (start + CHUNK).min(n);
            let cols: Vec<&[f32]> = data.features.iter().map(|c| &c[start..end]).collect();
            let batch = self.predict_raw_columns_adaptive(&cols, end - start, policy);
            trees_total += batch.trees_evaluated.iter().map(|&t| t as f64).sum::<f64>();
            match data.task {
                Task::Regression => reg_preds.extend(batch.scores.iter().map(|r| r[0])),
                _ => cls_preds.extend(batch.scores.iter().map(|r| obj.predict_class(r))),
            }
            start = end;
        }
        let score = match data.task {
            Task::Regression => crate::metrics::r2_score(&data.targets, &reg_preds),
            _ => crate::metrics::accuracy(&data.labels, &cls_preds),
        };
        let mean_trees = if n == 0 { 0.0 } else { trees_total / n as f64 };
        AdaptiveScore { score, mean_trees }
    }
}

impl Predictor for GbdtModel {
    fn predict_raw(&self, x: &[f32]) -> Vec<f64> {
        GbdtModel::predict_raw(self, x)
    }
    fn n_outputs(&self) -> usize {
        GbdtModel::n_outputs(self)
    }
    fn n_trees(&self) -> usize {
        GbdtModel::n_trees(self)
    }
    fn objective(&self) -> Objective {
        self.objective
    }
}

impl Predictor for PackedModel {
    fn predict_raw(&self, x: &[f32]) -> Vec<f64> {
        PackedModel::predict_raw(self, x)
    }
    fn n_outputs(&self) -> usize {
        PackedModel::n_outputs(self)
    }
    fn n_trees(&self) -> usize {
        PackedModel::n_trees(self)
    }
    fn objective(&self) -> Objective {
        PackedModel::objective(self)
    }
}

impl Predictor for FlatModel {
    fn predict_raw(&self, x: &[f32]) -> Vec<f64> {
        FlatModel::predict_raw(self, x)
    }
    fn predict_raw_batch(&self, rows: &[Vec<f32>]) -> Vec<Vec<f64>> {
        self.predict_batch(rows)
    }
    fn n_outputs(&self) -> usize {
        FlatModel::n_outputs(self)
    }
    fn n_trees(&self) -> usize {
        FlatModel::n_trees(self)
    }
    fn objective(&self) -> Objective {
        FlatModel::objective(self)
    }
}

impl Predictor for QuantizedFlatModel {
    fn predict_raw(&self, x: &[f32]) -> Vec<f64> {
        QuantizedFlatModel::predict_raw(self, x)
    }
    fn predict_raw_batch(&self, rows: &[Vec<f32>]) -> Vec<Vec<f64>> {
        self.predict_batch(rows)
    }
    fn predict_raw_columns(&self, cols: &[&[f32]], n_rows: usize) -> Vec<Vec<f64>> {
        self.predict_batch_columns(cols, n_rows)
    }
    fn predict_raw_batch_adaptive(
        &self,
        rows: &[Vec<f32>],
        policy: AdaptivePolicy,
    ) -> AdaptiveBatch {
        self.predict_batch_adaptive(rows, policy)
    }
    fn predict_raw_columns_adaptive(
        &self,
        cols: &[&[f32]],
        n_rows: usize,
        policy: AdaptivePolicy,
    ) -> AdaptiveBatch {
        self.predict_batch_columns_adaptive(cols, n_rows, policy)
    }
    fn n_outputs(&self) -> usize {
        QuantizedFlatModel::n_outputs(self)
    }
    fn n_trees(&self) -> usize {
        QuantizedFlatModel::n_trees(self)
    }
    fn objective(&self) -> Objective {
        QuantizedFlatModel::objective(self)
    }
}

/// Batch helper over any predictor (delegates to the engine's batch
/// kernel when it has one).
pub fn predict_batch(p: &dyn Predictor, rows: &[Vec<f32>]) -> Vec<Vec<f64>> {
    p.predict_raw_batch(rows)
}

#[cfg(test)]
#[cfg(not(miri))] // trains models / generates datasets - too slow under the Miri interpreter
mod tests {
    use super::*;
    use crate::data::synth::PaperDataset;
    use crate::gbdt::{self, GbdtParams};
    use crate::layout::{encode, EncodeOptions, FeatureInfo};

    #[test]
    fn predictor_paths_agree() {
        let data = PaperDataset::BreastCancer.generate(41).select(&(0..400).collect::<Vec<_>>());
        let model = gbdt::booster::train(&data, GbdtParams::paper(10, 3));
        let finfo = FeatureInfo::from_dataset(&data);
        let blob = encode(&model, &finfo, &EncodeOptions { allow_f16: false, ..Default::default() })
            .unwrap();
        let packed = PackedModel::from_bytes(blob);
        let flat = FlatModel::from_model(&model);
        let quant = QuantizedFlatModel::from_model(&model);

        let s1 = Predictor::score(&model, &data);
        let s2 = Predictor::score(&packed, &data);
        let s3 = Predictor::score(&flat, &data);
        let s4 = Predictor::score(&quant, &data);
        assert!((s1 - s2).abs() < 1e-9, "decoded {s1} vs packed {s2}");
        assert!((s1 - s3).abs() < 1e-12, "decoded {s1} vs flat {s3}");
        assert_eq!(s3, s4, "flat {s3} vs quantized {s4}");

        let rows: Vec<Vec<f32>> = (0..8).map(|i| data.row(i)).collect();
        let a = predict_batch(&model, &rows);
        let b = predict_batch(&packed, &rows);
        let c = predict_batch(&flat, &rows);
        let q = predict_batch(&quant, &rows);
        for (((x, y), z), w) in a.iter().zip(&b).zip(&c).zip(&q) {
            assert!((x[0] - y[0]).abs() < 1e-5);
            assert_eq!(x[0], z[0], "flat batch must match pointer exactly");
            assert_eq!(z, w, "quantized batch must match flat exactly");
        }

        // Columnar entry point: zero-gather override and the row-gather
        // default must both reproduce the row batch exactly.
        let cols: Vec<&[f32]> = data.features.iter().map(|c| &c[..8]).collect();
        let qc = quant.predict_raw_columns(&cols, 8);
        let fc = flat.predict_raw_columns(&cols, 8);
        assert_eq!(qc, q, "columnar quantized must match row batch exactly");
        assert_eq!(fc, c, "default columnar path must match row batch exactly");
    }

    #[test]
    fn predict_task_regression_vs_classification() {
        let reg = PaperDataset::Kin8nm.generate(42).select(&(0..300).collect::<Vec<_>>());
        let m = gbdt::booster::train(&reg, GbdtParams::paper(5, 2));
        let v = m.predict_task(&reg.row(0));
        assert!(v.is_finite());

        let cls = PaperDataset::Mushroom.generate(43).select(&(0..300).collect::<Vec<_>>());
        let mc = gbdt::booster::train(&cls, GbdtParams::paper(5, 2));
        let c = mc.predict_task(&cls.row(0));
        assert!(c == 0.0 || c == 1.0);

        let flat = FlatModel::from_model(&mc);
        assert_eq!(flat.predict_task(&cls.row(0)), c);
        let quant = QuantizedFlatModel::from_model(&mc);
        assert_eq!(quant.predict_task(&cls.row(0)), c);
    }
}
