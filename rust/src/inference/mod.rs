//! Native inference engines and the unified predictor interface.
//!
//! Four prediction paths exist in the system, all agreeing numerically
//! (integration-tested):
//!
//! 1. the flattened SoA engine ([`FlatModel`]) — branchless
//!    complete-tree descent plus a blocked tree-outer/row-inner batch
//!    API; bit-identical to the decoded pointer trees
//!    ([`crate::gbdt::GbdtModel`]),
//! 2. the quantized-threshold flat engine ([`QuantizedFlatModel`]) —
//!    the same layouts with `u16` threshold *ranks* instead of `f32`
//!    values: rows are pre-binned once per block and descents run a
//!    lane group of rows per tree walk through the runtime-dispatched
//!    SIMD kernel ([`crate::simd`]: AVX2/SSE2 vectors, scalar
//!    fallback); also bit-identical (on every dispatch tier), and the
//!    default dataset-scoring path,
//! 3. direct bit-packed traversal ([`crate::layout::PackedModel`]) —
//!    what a microcontroller with the blob in flash executes,
//! 4. the XLA runtime ([`crate::runtime`], `xla` feature) — the
//!    accelerator-offload serving path.
//!
//! [`Predictor`] abstracts over the native paths so the coordinator and
//! benches can swap engines; `predict_raw_batch` has a row-loop default
//! so single-row engines participate in batch serving, while
//! [`FlatModel`] and [`QuantizedFlatModel`] override it with their
//! blocked kernels. `predict_raw_columns` is the column-major entry
//! point (the orientation datasets and the gateway batcher already
//! hold): the default gathers rows, and [`QuantizedFlatModel`]
//! overrides it with a zero-gather kernel that bins each column once
//! into the shared `BinMatrix` arena.

pub mod flat;
pub mod quantized;

pub use flat::FlatModel;
pub use quantized::QuantizedFlatModel;

use crate::data::{Dataset, Task};
use crate::gbdt::loss::Objective;
use crate::gbdt::GbdtModel;
use crate::layout::PackedModel;

/// A raw-score predictor.
pub trait Predictor {
    fn predict_raw(&self, x: &[f32]) -> Vec<f64>;
    fn n_outputs(&self) -> usize;
    fn objective(&self) -> Objective;

    /// Raw scores for a batch of rows. Default: one row at a time;
    /// engines with a real batch kernel (e.g. [`FlatModel`]) override.
    fn predict_raw_batch(&self, rows: &[Vec<f32>]) -> Vec<Vec<f64>> {
        rows.iter().map(|r| self.predict_raw(r)).collect()
    }

    /// Raw scores for a column-major batch: `cols[f][i]` is feature `f`
    /// of row `i` — the orientation [`Dataset`] already stores. The
    /// default gathers rows and delegates to
    /// [`Predictor::predict_raw_batch`]; engines with a native columnar
    /// kernel ([`QuantizedFlatModel`]) override to skip the gather
    /// entirely.
    fn predict_raw_columns(&self, cols: &[&[f32]], n_rows: usize) -> Vec<Vec<f64>> {
        let rows: Vec<Vec<f32>> =
            (0..n_rows).map(|i| cols.iter().map(|c| c[i]).collect()).collect();
        self.predict_raw_batch(&rows)
    }

    /// Task-level prediction: class index (classification) packed as
    /// `f64`, or the regression value.
    fn predict_task(&self, x: &[f32]) -> f64 {
        let raw = self.predict_raw(x);
        match self.objective() {
            Objective::L2 => raw[0],
            obj => obj.predict_class(&raw) as f64,
        }
    }

    /// Dataset score: accuracy (classification) or R² (regression).
    /// Feeds the dataset's feature columns straight into the columnar
    /// batch path in bounded chunks — engines with a columnar kernel
    /// never materialize a row, and peak memory stays at one chunk of
    /// outputs rather than the whole dataset.
    fn score(&self, data: &Dataset) -> f64 {
        const CHUNK: usize = 4 * flat::BLOCK_ROWS;
        let n = data.n_rows();
        let obj = self.objective();
        let mut reg_preds: Vec<f64> = Vec::new();
        let mut cls_preds: Vec<usize> = Vec::new();
        let mut start = 0usize;
        while start < n {
            let end = (start + CHUNK).min(n);
            let cols: Vec<&[f32]> =
                data.features.iter().map(|c| &c[start..end]).collect();
            let raw = self.predict_raw_columns(&cols, end - start);
            match data.task {
                Task::Regression => reg_preds.extend(raw.iter().map(|r| r[0])),
                _ => cls_preds.extend(raw.iter().map(|r| obj.predict_class(r))),
            }
            start = end;
        }
        match data.task {
            Task::Regression => crate::metrics::r2_score(&data.targets, &reg_preds),
            _ => crate::metrics::accuracy(&data.labels, &cls_preds),
        }
    }
}

impl Predictor for GbdtModel {
    fn predict_raw(&self, x: &[f32]) -> Vec<f64> {
        GbdtModel::predict_raw(self, x)
    }
    fn n_outputs(&self) -> usize {
        GbdtModel::n_outputs(self)
    }
    fn objective(&self) -> Objective {
        self.objective
    }
}

impl Predictor for PackedModel {
    fn predict_raw(&self, x: &[f32]) -> Vec<f64> {
        PackedModel::predict_raw(self, x)
    }
    fn n_outputs(&self) -> usize {
        PackedModel::n_outputs(self)
    }
    fn objective(&self) -> Objective {
        PackedModel::objective(self)
    }
}

impl Predictor for FlatModel {
    fn predict_raw(&self, x: &[f32]) -> Vec<f64> {
        FlatModel::predict_raw(self, x)
    }
    fn predict_raw_batch(&self, rows: &[Vec<f32>]) -> Vec<Vec<f64>> {
        self.predict_batch(rows)
    }
    fn n_outputs(&self) -> usize {
        FlatModel::n_outputs(self)
    }
    fn objective(&self) -> Objective {
        FlatModel::objective(self)
    }
}

impl Predictor for QuantizedFlatModel {
    fn predict_raw(&self, x: &[f32]) -> Vec<f64> {
        QuantizedFlatModel::predict_raw(self, x)
    }
    fn predict_raw_batch(&self, rows: &[Vec<f32>]) -> Vec<Vec<f64>> {
        self.predict_batch(rows)
    }
    fn predict_raw_columns(&self, cols: &[&[f32]], n_rows: usize) -> Vec<Vec<f64>> {
        self.predict_batch_columns(cols, n_rows)
    }
    fn n_outputs(&self) -> usize {
        QuantizedFlatModel::n_outputs(self)
    }
    fn objective(&self) -> Objective {
        QuantizedFlatModel::objective(self)
    }
}

/// Batch helper over any predictor (delegates to the engine's batch
/// kernel when it has one).
pub fn predict_batch(p: &dyn Predictor, rows: &[Vec<f32>]) -> Vec<Vec<f64>> {
    p.predict_raw_batch(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::PaperDataset;
    use crate::gbdt::{self, GbdtParams};
    use crate::layout::{encode, EncodeOptions, FeatureInfo};

    #[test]
    fn predictor_paths_agree() {
        let data = PaperDataset::BreastCancer.generate(41).select(&(0..400).collect::<Vec<_>>());
        let model = gbdt::booster::train(&data, GbdtParams::paper(10, 3));
        let finfo = FeatureInfo::from_dataset(&data);
        let blob = encode(&model, &finfo, &EncodeOptions { allow_f16: false, ..Default::default() })
            .unwrap();
        let packed = PackedModel::from_bytes(blob);
        let flat = FlatModel::from_model(&model);
        let quant = QuantizedFlatModel::from_model(&model);

        let s1 = Predictor::score(&model, &data);
        let s2 = Predictor::score(&packed, &data);
        let s3 = Predictor::score(&flat, &data);
        let s4 = Predictor::score(&quant, &data);
        assert!((s1 - s2).abs() < 1e-9, "decoded {s1} vs packed {s2}");
        assert!((s1 - s3).abs() < 1e-12, "decoded {s1} vs flat {s3}");
        assert_eq!(s3, s4, "flat {s3} vs quantized {s4}");

        let rows: Vec<Vec<f32>> = (0..8).map(|i| data.row(i)).collect();
        let a = predict_batch(&model, &rows);
        let b = predict_batch(&packed, &rows);
        let c = predict_batch(&flat, &rows);
        let q = predict_batch(&quant, &rows);
        for (((x, y), z), w) in a.iter().zip(&b).zip(&c).zip(&q) {
            assert!((x[0] - y[0]).abs() < 1e-5);
            assert_eq!(x[0], z[0], "flat batch must match pointer exactly");
            assert_eq!(z, w, "quantized batch must match flat exactly");
        }

        // Columnar entry point: zero-gather override and the row-gather
        // default must both reproduce the row batch exactly.
        let cols: Vec<&[f32]> = data.features.iter().map(|c| &c[..8]).collect();
        let qc = quant.predict_raw_columns(&cols, 8);
        let fc = flat.predict_raw_columns(&cols, 8);
        assert_eq!(qc, q, "columnar quantized must match row batch exactly");
        assert_eq!(fc, c, "default columnar path must match row batch exactly");
    }

    #[test]
    fn predict_task_regression_vs_classification() {
        let reg = PaperDataset::Kin8nm.generate(42).select(&(0..300).collect::<Vec<_>>());
        let m = gbdt::booster::train(&reg, GbdtParams::paper(5, 2));
        let v = m.predict_task(&reg.row(0));
        assert!(v.is_finite());

        let cls = PaperDataset::Mushroom.generate(43).select(&(0..300).collect::<Vec<_>>());
        let mc = gbdt::booster::train(&cls, GbdtParams::paper(5, 2));
        let c = mc.predict_task(&cls.row(0));
        assert!(c == 0.0 || c == 1.0);

        let flat = FlatModel::from_model(&mc);
        assert_eq!(flat.predict_task(&cls.row(0)), c);
        let quant = QuantizedFlatModel::from_model(&mc);
        assert_eq!(quant.predict_task(&cls.row(0)), c);
    }
}
