//! `QuantizedFlatModel` — the quantized-threshold flat engine.
//!
//! [`crate::inference::FlatModel`] already gives the branchless
//! complete-tree descent over structure-of-arrays storage; this engine
//! applies the paper's threshold-quantization idea (§3.2.1, the same
//! observation the ToaD layout's per-feature threshold tables rest on)
//! to the *serving* hot path:
//!
//! * **u16 thresholds.** Every split threshold is replaced by its rank
//!   in the per-feature sorted table of distinct thresholds the model
//!   uses — the serving-side analogue of the boundary-index encoding in
//!   [`crate::layout::feature_info`]. The `thr` array shrinks from
//!   `f32` to `u16` (half the node bytes of the descent's hottest
//!   stream), and comparisons become integer compares.
//! * **Pre-binned rows.** An incoming row is binned once per
//!   prediction: `xb[f] = #{thresholds of f strictly below x[f]}`. For
//!   a threshold with rank `k` the predicate `x ≤ t` is then *exactly*
//!   `xb[f] ≤ k` — for every real `x`, not just training values — so
//!   routing (and therefore raw scores) stays bit-identical to
//!   [`FlatModel`] and the pointer trees. A NaN input maps to the
//!   dedicated bin [`NAN_BIN`], which compares greater than every real
//!   rank and so routes right, exactly like `!(x ≤ t)` on floats.
//! * **Vectorized multi-row descent.** A complete tree's descent runs
//!   a fixed `depth` iterations, so [`QuantizedFlatModel::predict_batch`]
//!   walks a whole lane group of rows per tree in lockstep: one level
//!   of all lanes, then the next. The lane kernel is the explicit SIMD
//!   one in [`crate::simd::descend_complete`] — 16 `u16` lanes on AVX2,
//!   8 on the SSE2 x86-64 baseline, and the [`LANES`]-way interleaved
//!   scalar twin elsewhere — dispatched once per process
//!   ([`crate::simd::tier`]) and bit-identical across tiers; block
//!   tails and the single-row path share one scalar per-row routine
//!   ([`crate::simd::descend_row`]), so the kernels cannot drift.
//!
//! * **Zero-gather columnar batches.** Column-major callers (the
//!   dataset scorer, the coordinator batcher) skip the per-row gather
//!   entirely: [`QuantizedFlatModel::predict_batch_columns`] bins each
//!   feature column once into the shared [`crate::data::BinMatrix`]
//!   arena and descends over its row-major mirror with the exact same
//!   blocked kernel — bin once, descend many.
//!
//! * **Adaptive early exit.** At quantize time the engine also
//!   precomputes per-tree *suffix bounds* — the min/max total
//!   contribution of every tree suffix, from per-tree leaf extrema —
//!   so the `_adaptive` batch entry points can retire a row as soon as
//!   its partial score ± the remaining bound can no longer change the
//!   predicted sign (binary classification) or move by the policy's
//!   tolerance. Retired rows are swap-compacted out of the active lane
//!   set ([`crate::simd::descend_complete_gather`]), so survivors stay
//!   densely packed in full hardware lane groups; work scales with row
//!   difficulty instead of ensemble size.
//!
//! Compared to [`FlatModel`], each block pays one extra binning pass
//! (a binary search per used feature) and then descends on u16
//! compares; the win grows with ensemble size, since binning is
//! amortized over every tree while the per-node stream is half as wide
//! — the memory-bound MCU-batch regime the paper targets.
//!
//! * **Oblivious fast path.** A tree whose levels each share a single
//!   `(feature, threshold)` split ([`crate::gbdt::tree::Tree::oblivious_levels`],
//!   the CatBoost shape the `GrowthMode::Oblivious` grower emits) is
//!   stored as just `depth` level pairs plus a `2^depth` leaf table and
//!   descends through [`crate::simd::descend_oblivious`]: per level one
//!   broadcast threshold, one shared-column code load per lane, a
//!   vector compare, and a shift into the per-lane leaf index — no
//!   per-lane node fetches at all, the one fully-vector descent in the
//!   system. Leaf indices agree bit-for-bit with the `Complete` layout
//!   of the same tree (both are the MSB-first path-bit integer), so
//!   parity with the other engines is preserved by construction, and
//!   the suffix-bound adaptive machinery applies unchanged.
//!
//! [`FlatModel`]: crate::inference::FlatModel

use super::flat::{complete_layout_ok, TreeRef};
use super::{AdaptiveBatch, AdaptivePolicy};
use crate::data::{CsrMatrix, SparseDataset, Task};
use crate::gbdt::loss::Objective;
use crate::gbdt::tree::{Node, Tree};
use crate::gbdt::GbdtModel;
use crate::simd::{self, Tier};

/// Rows per block of the batched predict loop (shared with the flat
/// engine so the two batch kernels are directly comparable).
pub use super::flat::BLOCK_ROWS;

/// Rows interleaved per tree walk by the **scalar** descent tier (the
/// SIMD tiers widen to 8/16 hardware lanes — see [`crate::simd`]).
pub const LANES: usize = simd::SCALAR_LANES;

/// Rows binned per chunk of the columnar batch path: bounds the
/// transient bin arena + row-major mirror to chunk-sized buffers on
/// arbitrarily large batches. A multiple of [`BLOCK_ROWS`], so the
/// descent's block partition (and therefore every output bit) is
/// identical to an unchunked pass.
const COLUMNAR_CHUNK_ROWS: usize = 64 * BLOCK_ROWS;

/// Sentinel feature id marking a leaf slot in the general node arrays.
const LEAF: u16 = u16::MAX;

/// Bin assigned to NaN inputs: compares greater than every stored rank,
/// so NaN routes right at every real split — identical to `!(x ≤ t)` on
/// floats in the other engines.
const NAN_BIN: u16 = u16::MAX;

/// Threshold rank stored in pass-through complete-tree slots. Every bin
/// (including [`NAN_BIN`]) satisfies `xb ≤ PASS`, so pass-through slots
/// route left unconditionally; the leaves below are replicas of the
/// same value, so this agrees with [`FlatModel`]'s `+∞` slots (which
/// send NaN right — into a replica of the same value).
const PASS: u16 = u16::MAX;

/// Deepest tree eligible for the oblivious layout: the SIMD descent
/// accumulates the leaf index in `u16` lanes, so indices must stay
/// below `2^16` (`2^depth ≤ 2^15`). Trained oblivious trees are far
/// shallower; this guard only matters for hand-built models.
const MAX_OBLIVIOUS_DEPTH: usize = 15;

/// A trained ensemble with rank-quantized thresholds. Build one with
/// [`QuantizedFlatModel::from_model`] (or [`GbdtModel::quantize`]) and
/// keep it for the model's serving lifetime.
#[derive(Clone, Debug)]
pub struct QuantizedFlatModel {
    objective: Objective,
    base_scores: Vec<f64>,
    n_features: usize,
    /// `bounds[f]` is the ascending table of distinct thresholds the
    /// model uses on input feature `f`; node thresholds are stored as
    /// ranks into this table.
    bounds: Vec<Vec<f32>>,
    /// `trees[output][round]`, same order as the source model.
    trees: Vec<Vec<TreeRef>>,
    // Complete-layout storage (u16 threshold ranks).
    cfeat: Vec<u16>,
    cthr: Vec<u16>,
    cleaf: Vec<f64>,
    // Oblivious-layout storage: one (feature, threshold-rank) pair per
    // level, root level first; leaf tables live in `cleaf` like the
    // complete layout's.
    ofeat: Vec<u16>,
    othr: Vec<u16>,
    // General node storage (siblings adjacent, as in the flat engine).
    feat: Vec<u16>,
    thr: Vec<u16>,
    children: Vec<u32>,
    leaf: Vec<f64>,
    /// Per-stream suffix bounds over per-tree leaf extrema, computed
    /// once at quantize time: `suffix_lo[k][t]` is the minimum possible
    /// total contribution of trees `t..` of stream `k` (the sum of each
    /// tree's smallest leaf), `suffix_hi` the maximum. Length
    /// `trees[k].len() + 1` with a trailing `0.0`, so after evaluating
    /// tree `t` the not-yet-walked remainder of a row's raw score lies
    /// in `[suffix_lo[k][t+1], suffix_hi[k][t+1]]` — the interval the
    /// adaptive early-exit kernel tests.
    suffix_lo: Vec<Vec<f64>>,
    suffix_hi: Vec<Vec<f64>>,
}

/// Rank of threshold `t` in the ascending table `bounds` (which must
/// contain it — the table is built from the same splits).
#[inline]
fn rank_of(bounds: &[f32], t: f32) -> u16 {
    let r = bounds.partition_point(|&v| v < t);
    debug_assert!(r < bounds.len() && bounds[r] == t, "threshold {t} missing from table");
    r as u16
}

/// Flatten `tree` into the general node arrays with rank-quantized
/// thresholds; returns its base offset. Mirrors the flat engine's
/// layout (siblings adjacent, `right == left + 1`).
fn flatten_nodes(
    tree: &Tree,
    bounds: &[Vec<f32>],
    feat: &mut Vec<u16>,
    thr: &mut Vec<u16>,
    children: &mut Vec<u32>,
    leaf: &mut Vec<f64>,
) -> u32 {
    let start = feat.len();
    let n = tree.nodes.len();
    feat.resize(start + n, LEAF);
    thr.resize(start + n, 0);
    children.resize(start + n, 0);
    let mut next_local = 1usize;
    let mut stack = vec![(0usize, 0usize)]; // (source node, local slot)
    while let Some((ti, li)) = stack.pop() {
        match &tree.nodes[ti] {
            Node::Leaf { value } => {
                feat[start + li] = LEAF;
                children[start + li] = leaf.len() as u32;
                leaf.push(*value);
            }
            Node::Internal { feature, threshold, left, right, .. } => {
                feat[start + li] = *feature as u16;
                thr[start + li] = rank_of(&bounds[*feature], *threshold);
                let cl = next_local;
                next_local += 2;
                children[start + li] = cl as u32;
                stack.push((*right, cl + 1));
                stack.push((*left, cl));
            }
        }
    }
    debug_assert_eq!(next_local, n, "every node must land in exactly one slot");
    start as u32
}

impl QuantizedFlatModel {
    /// Quantize a trained model. Chooses per tree between the complete
    /// fast path and the general node layout with the same policy as
    /// [`FlatModel`](crate::inference::FlatModel), so the two engines
    /// route every tree through equivalent layouts.
    pub fn from_model(model: &GbdtModel) -> QuantizedFlatModel {
        assert!(
            model.n_features < LEAF as usize,
            "feature ids must fit u16 below the leaf sentinel"
        );
        // Pass 1: per-feature tables of distinct thresholds.
        let mut bounds: Vec<Vec<f32>> = vec![Vec::new(); model.n_features];
        for tree in model.trees.iter().flatten() {
            for (f, _, t) in tree.splits() {
                debug_assert!(!t.is_nan(), "split thresholds are never NaN");
                bounds[f].push(t);
            }
        }
        for b in &mut bounds {
            b.sort_by(f32::total_cmp);
            b.dedup();
            assert!(
                b.len() <= u16::MAX as usize,
                "per-feature threshold count {} exceeds u16 ranks",
                b.len()
            );
        }

        // Pass 2: flatten trees with rank-quantized thresholds.
        let mut trees = Vec::with_capacity(model.trees.len());
        let mut cfeat = Vec::new();
        let mut cthr = Vec::new();
        let mut cleaf = Vec::new();
        let mut feat = Vec::new();
        let mut thr = Vec::new();
        let mut children = Vec::new();
        let mut leaf = Vec::new();
        let mut ofeat = Vec::new();
        let mut othr = Vec::new();
        for stream in &model.trees {
            let mut refs = Vec::with_capacity(stream.len());
            for tree in stream {
                let depth = tree.depth();
                let levels = if depth > 0 && depth <= MAX_OBLIVIOUS_DEPTH {
                    tree.oblivious_levels()
                } else {
                    None
                };
                if let Some(levels) = levels {
                    let ooff = ofeat.len() as u32;
                    let loff = cleaf.len() as u32;
                    for &(f, _, t) in &levels {
                        ofeat.push(f as u16);
                        othr.push(rank_of(&bounds[f], t));
                    }
                    let (_, leaves) = tree.to_complete();
                    cleaf.extend_from_slice(&leaves);
                    refs.push(TreeRef::Oblivious { ooff, loff, depth: depth as u8 });
                } else if complete_layout_ok(depth, tree.n_nodes()) {
                    let (internal, leaves) = tree.to_complete();
                    let ioff = cfeat.len() as u32;
                    let loff = cleaf.len() as u32;
                    for slot in &internal {
                        match slot {
                            Some((f, _, t)) => {
                                cfeat.push(*f as u16);
                                cthr.push(rank_of(&bounds[*f], *t));
                            }
                            None => {
                                cfeat.push(0);
                                cthr.push(PASS);
                            }
                        }
                    }
                    cleaf.extend_from_slice(&leaves);
                    refs.push(TreeRef::Complete { ioff, loff, depth: depth as u8 });
                } else {
                    let off = flatten_nodes(
                        tree,
                        &bounds,
                        &mut feat,
                        &mut thr,
                        &mut children,
                        &mut leaf,
                    );
                    refs.push(TreeRef::Nodes { off });
                }
            }
            trees.push(refs);
        }

        // Pass 3: suffix bounds from per-tree leaf extrema — the
        // adaptive early-exit kernel's "what can the remaining trees
        // still do" interval, paid once per quantize instead of once
        // per row.
        let mut suffix_lo = Vec::with_capacity(model.trees.len());
        let mut suffix_hi = Vec::with_capacity(model.trees.len());
        for stream in &model.trees {
            let mut lo = vec![0.0f64; stream.len() + 1];
            let mut hi = vec![0.0f64; stream.len() + 1];
            for (t, tree) in stream.iter().enumerate().rev() {
                let mut tmin = f64::INFINITY;
                let mut tmax = f64::NEG_INFINITY;
                for v in tree.leaf_values() {
                    tmin = tmin.min(v);
                    tmax = tmax.max(v);
                }
                lo[t] = lo[t + 1] + tmin;
                hi[t] = hi[t + 1] + tmax;
            }
            suffix_lo.push(lo);
            suffix_hi.push(hi);
        }

        QuantizedFlatModel {
            objective: model.objective,
            base_scores: model.base_scores.clone(),
            n_features: model.n_features,
            bounds,
            trees,
            cfeat,
            cthr,
            cleaf,
            ofeat,
            othr,
            feat,
            thr,
            children,
            leaf,
            suffix_lo,
            suffix_hi,
        }
    }

    pub fn objective(&self) -> Objective {
        self.objective
    }

    pub fn n_outputs(&self) -> usize {
        self.trees.len()
    }

    pub fn n_features(&self) -> usize {
        self.n_features
    }

    pub fn n_trees(&self) -> usize {
        self.trees.iter().map(|t| t.len()).sum()
    }

    /// Total distinct thresholds across all per-feature tables.
    pub fn n_thresholds(&self) -> usize {
        self.bounds.iter().map(|b| b.len()).sum()
    }

    /// The adaptive early-exit bound tables for output stream `k`:
    /// `(lo, hi)` with `lo[t] = Σ_{u ≥ t}` (min leaf of tree `u`),
    /// resp. max — length `n trees + 1`, trailing `0.0`. After walking
    /// tree `t`, a row's not-yet-evaluated remainder lies in
    /// `[lo[t+1], hi[t+1]]`.
    pub fn suffix_bounds(&self, k: usize) -> (&[f64], &[f64]) {
        (&self.suffix_lo[k], &self.suffix_hi[k])
    }

    /// How many trees took the complete fast path (introspection/tests).
    pub fn n_complete_trees(&self) -> usize {
        self.trees
            .iter()
            .flatten()
            .filter(|t| matches!(t, TreeRef::Complete { .. }))
            .count()
    }

    /// How many trees took the oblivious fast path (introspection/tests).
    pub fn n_oblivious_trees(&self) -> usize {
        self.trees
            .iter()
            .flatten()
            .filter(|t| matches!(t, TreeRef::Oblivious { .. }))
            .count()
    }

    /// Bin one dense row against the per-feature threshold tables.
    /// `out[f] ≤ k ⇔ x[f] ≤ bounds[f][k]` for every real `x[f]`; NaN
    /// maps to [`NAN_BIN`]. The rank count runs through the
    /// tier-dispatched [`crate::simd::count_lt`] (vector compare +
    /// popcount on short tables, binary search beyond); every tier
    /// produces the same bin, so forcing [`Tier::Scalar`] is the
    /// historical binary-search twin exactly.
    #[inline]
    fn bin_row(&self, x: &[f32], out: &mut [u16], tier: Tier) {
        for f in 0..self.n_features {
            let v = x[f];
            out[f] = if v.is_nan() {
                NAN_BIN
            } else {
                simd::count_lt(tier, &self.bounds[f], v) as u16
            };
        }
    }

    #[inline]
    fn eval_nodes(&self, off: usize, xb: &[u16]) -> f64 {
        let mut i = off;
        loop {
            let f = self.feat[i];
            if f == LEAF {
                return self.leaf[self.children[i] as usize];
            }
            let right = (xb[f as usize] > self.thr[i]) as usize;
            i = off + self.children[i] as usize + right;
        }
    }

    #[inline]
    fn eval_complete(&self, ioff: usize, loff: usize, depth: usize, xb: &[u16]) -> f64 {
        let n_internal = (1usize << depth) - 1;
        let feat = &self.cfeat[ioff..ioff + n_internal];
        let thr = &self.cthr[ioff..ioff + n_internal];
        // The same per-row routine the block kernels use for their
        // tails ([`crate::simd::descend_row`]), so single-row and
        // batched descents cannot drift.
        self.cleaf[loff + simd::descend_row(feat, thr, xb)]
    }

    #[inline]
    fn eval_oblivious(&self, ooff: usize, loff: usize, depth: usize, xb: &[u16]) -> f64 {
        let feat = &self.ofeat[ooff..ooff + depth];
        let thr = &self.othr[ooff..ooff + depth];
        // The same per-row routine the oblivious block kernel uses for
        // its tails ([`crate::simd::descend_oblivious_row`]).
        self.cleaf[loff + simd::descend_oblivious_row(feat, thr, xb)]
    }

    #[inline]
    fn eval_tree(&self, tref: TreeRef, xb: &[u16]) -> f64 {
        match tref {
            TreeRef::Complete { ioff, loff, depth } => {
                self.eval_complete(ioff as usize, loff as usize, depth as usize, xb)
            }
            TreeRef::Oblivious { ooff, loff, depth } => {
                self.eval_oblivious(ooff as usize, loff as usize, depth as usize, xb)
            }
            TreeRef::Nodes { off } => self.eval_nodes(off as usize, xb),
        }
    }

    /// Raw scores for one dense row (one value per output stream).
    /// Bit-identical to `GbdtModel::predict_raw` and
    /// `FlatModel::predict_raw`.
    pub fn predict_raw(&self, x: &[f32]) -> Vec<f64> {
        let mut xb = vec![0u16; self.n_features];
        self.bin_row(x, &mut xb, simd::tier());
        let mut out = self.base_scores.clone();
        for (k, trees) in self.trees.iter().enumerate() {
            for &tref in trees {
                out[k] += self.eval_tree(tref, &xb);
            }
        }
        out
    }

    /// Walk every tree over one row-major binned block, adding leaf
    /// contributions into the block's output rows. `xb` holds
    /// `out.len() × nf` codes (`xb[r * nf + f]`). This is the one
    /// descent kernel behind both [`QuantizedFlatModel::predict_batch`]
    /// and [`QuantizedFlatModel::predict_batch_columns`], so the two
    /// entry points are bit-identical by construction. Complete trees
    /// run the tier-dispatched lane kernel
    /// ([`crate::simd::descend_complete`]); leaf contributions are then
    /// added in row order, so the summation order (and therefore every
    /// output bit) is identical on every tier.
    fn descend_block_tiered(&self, xb: &[u16], nf: usize, out: &mut [Vec<f64>], tier: Tier) {
        let n_rows = out.len();
        debug_assert_eq!(xb.len(), n_rows * nf);
        assert!(n_rows <= BLOCK_ROWS, "descend_block operates on one block at a time");
        let mut idx = [0u32; BLOCK_ROWS];
        let idx = &mut idx[..n_rows];
        for (k, trees) in self.trees.iter().enumerate() {
            for &tref in trees {
                match tref {
                    TreeRef::Complete { ioff, loff, depth } => {
                        let (ioff, loff, depth) = (ioff as usize, loff as usize, depth as usize);
                        let n_internal = (1usize << depth) - 1;
                        let feat = &self.cfeat[ioff..ioff + n_internal];
                        let thr = &self.cthr[ioff..ioff + n_internal];
                        let leaf = &self.cleaf[loff..loff + (1usize << depth)];
                        simd::descend_complete(tier, feat, thr, depth, xb, nf, idx);
                        for (o, &i) in out.iter_mut().zip(idx.iter()) {
                            o[k] += leaf[i as usize];
                        }
                    }
                    TreeRef::Oblivious { ooff, loff, depth } => {
                        let (ooff, loff, depth) = (ooff as usize, loff as usize, depth as usize);
                        let feat = &self.ofeat[ooff..ooff + depth];
                        let thr = &self.othr[ooff..ooff + depth];
                        let leaf = &self.cleaf[loff..loff + (1usize << depth)];
                        simd::descend_oblivious(tier, feat, thr, xb, nf, idx);
                        for (o, &i) in out.iter_mut().zip(idx.iter()) {
                            o[k] += leaf[i as usize];
                        }
                    }
                    TreeRef::Nodes { off } => {
                        let off = off as usize;
                        for (r, o) in out.iter_mut().enumerate() {
                            o[k] += self.eval_nodes(off, &xb[r * nf..(r + 1) * nf]);
                        }
                    }
                }
            }
        }
    }

    /// Whether `policy` arms early exit on this model, and with which
    /// semantics: `Some((eps, sign_exit))` for a strictly positive
    /// tolerance on a single-output model with at least one tree
    /// (`sign_exit` is true for binary classification, where the sign
    /// test applies). Everything else — `Exact`, `Margin(0.0)` (zero
    /// tolerance admits no score deviation), non-positive/NaN `eps`,
    /// multi-output ensembles (no single sign to bound), empty
    /// ensembles — routes to the exact kernel.
    fn adaptive_mode(&self, policy: AdaptivePolicy) -> Option<(f64, bool)> {
        let eps = policy.tolerance()?;
        if self.trees.len() != 1 || self.trees[0].is_empty() {
            return None;
        }
        Some((eps, matches!(self.objective, Objective::Logistic)))
    }

    /// Adaptive twin of [`descend_block_tiered`] for single-output
    /// models: trees are walked in order, but after each tree every
    /// still-active row is tested against the precomputed suffix bound
    /// and retired once its outcome can no longer change:
    ///
    /// * **sign-decided** (`sign_exit`, binary classification): the
    ///   interval `[s + lo, s + hi]` no longer straddles zero, so the
    ///   predicted class provably equals full evaluation's;
    /// * **bounded** (any objective): `hi − lo < eps`, so the final
    ///   raw score cannot move by `eps` or more — the midpoint
    ///   completion errs by less than `eps / 2`.
    ///
    /// Retired rows are completed with `s + (lo + hi) / 2` (which
    /// keeps the decided sign: the midpoint lies inside the interval)
    /// and swap-compacted out of the active index list, so survivors
    /// keep filling whole hardware lane groups of the gather kernel
    /// ([`crate::simd::descend_complete_gather`]) instead of idling as
    /// masked lanes. Outputs land at their original row positions, so
    /// row order is preserved by construction. Rows that never retire
    /// accumulate the same leaf adds in the same order as the exact
    /// kernel and are bit-identical to it. No exit test runs after the
    /// last tree: a fully walked row's score is never adjusted (not
    /// even by `+0.0`, which could flip a `-0.0` sum).
    ///
    /// `trees_eval[r]` receives the number of trees row `r` actually
    /// walked. Caller guarantees `self.adaptive_mode(..)` returned
    /// `Some` (single output stream, `eps > 0`).
    #[allow(clippy::too_many_arguments)]
    fn descend_block_adaptive(
        &self,
        xb: &[u16],
        nf: usize,
        out: &mut [Vec<f64>],
        tier: Tier,
        eps: f64,
        sign_exit: bool,
        trees_eval: &mut [u32],
    ) {
        let n_rows = out.len();
        debug_assert_eq!(xb.len(), n_rows * nf);
        debug_assert_eq!(trees_eval.len(), n_rows);
        assert!(n_rows <= BLOCK_ROWS, "descend_block operates on one block at a time");
        let stream = &self.trees[0];
        let n_trees = stream.len();
        let (suffix_lo, suffix_hi) = (&self.suffix_lo[0], &self.suffix_hi[0]);
        let mut active = [0u32; BLOCK_ROWS];
        for (r, slot) in active.iter_mut().enumerate().take(n_rows) {
            *slot = r as u32;
        }
        let mut n_active = n_rows;
        let mut idx = [0u32; BLOCK_ROWS];
        trees_eval[..n_rows].fill(n_trees as u32);
        for (t, &tref) in stream.iter().enumerate() {
            let rows = &active[..n_active];
            match tref {
                TreeRef::Complete { ioff, loff, depth } => {
                    let (ioff, loff, depth) = (ioff as usize, loff as usize, depth as usize);
                    let n_internal = (1usize << depth) - 1;
                    let feat = &self.cfeat[ioff..ioff + n_internal];
                    let thr = &self.cthr[ioff..ioff + n_internal];
                    let leaf = &self.cleaf[loff..loff + (1usize << depth)];
                    simd::descend_complete_gather(
                        tier,
                        feat,
                        thr,
                        depth,
                        xb,
                        nf,
                        rows,
                        &mut idx[..n_active],
                    );
                    for (l, &r) in rows.iter().enumerate() {
                        out[r as usize][0] += leaf[idx[l] as usize];
                    }
                }
                TreeRef::Oblivious { ooff, loff, depth } => {
                    let (ooff, loff, depth) = (ooff as usize, loff as usize, depth as usize);
                    let feat = &self.ofeat[ooff..ooff + depth];
                    let thr = &self.othr[ooff..ooff + depth];
                    let leaf = &self.cleaf[loff..loff + (1usize << depth)];
                    simd::descend_oblivious_gather(
                        tier,
                        feat,
                        thr,
                        xb,
                        nf,
                        rows,
                        &mut idx[..n_active],
                    );
                    for (l, &r) in rows.iter().enumerate() {
                        out[r as usize][0] += leaf[idx[l] as usize];
                    }
                }
                TreeRef::Nodes { off } => {
                    let off = off as usize;
                    for &r in rows {
                        let r = r as usize;
                        out[r][0] += self.eval_nodes(off, &xb[r * nf..(r + 1) * nf]);
                    }
                }
            }
            if t + 1 >= n_trees {
                break; // remaining interval is empty — nothing to test
            }
            let (lo, hi) = (suffix_lo[t + 1], suffix_hi[t + 1]);
            let width_done = hi - lo < eps;
            let mid = (lo + hi) * 0.5;
            let mut l = 0usize;
            while l < n_active {
                let r = active[l] as usize;
                let s = out[r][0];
                let decided = sign_exit && (s + lo > 0.0 || s + hi <= 0.0);
                if decided || width_done {
                    out[r][0] = s + mid;
                    trees_eval[r] = (t + 1) as u32;
                    n_active -= 1;
                    active[l] = active[n_active]; // swap-remove; recheck slot l
                } else {
                    l += 1;
                }
            }
            if n_active == 0 {
                break;
            }
        }
    }

    /// Batched raw scores: rows are binned once per [`BLOCK_ROWS`]-row
    /// block, then each tree walks the block a lane group at a time
    /// through the tier-dispatched SIMD kernel — numerically identical
    /// to per-row [`QuantizedFlatModel::predict_raw`] (same routing,
    /// same summation order). Runs on the CPU's best detected tier
    /// ([`crate::simd::tier`]).
    pub fn predict_batch(&self, rows: &[Vec<f32>]) -> Vec<Vec<f64>> {
        self.predict_batch_with_tier(rows, simd::tier())
    }

    /// [`QuantizedFlatModel::predict_batch`] on an explicit dispatch
    /// tier — the forced-scalar twin for parity tests and the
    /// before/after pairs in `benches/perf_hotpaths.rs`. Unsupported
    /// tiers clamp to the detected one; every tier is bit-identical.
    pub fn predict_batch_with_tier(&self, rows: &[Vec<f32>], tier: Tier) -> Vec<Vec<f64>> {
        let nf = self.n_features;
        let mut out: Vec<Vec<f64>> = rows.iter().map(|_| self.base_scores.clone()).collect();
        let mut binned = vec![0u16; BLOCK_ROWS * nf];
        for start in (0..rows.len()).step_by(BLOCK_ROWS) {
            let end = (start + BLOCK_ROWS).min(rows.len());
            let block = &rows[start..end];
            for (r, x) in block.iter().enumerate() {
                self.bin_row(x, &mut binned[r * nf..(r + 1) * nf], tier);
            }
            self.descend_block_tiered(&binned[..block.len() * nf], nf, &mut out[start..end], tier);
        }
        out
    }

    /// [`QuantizedFlatModel::predict_batch`] under an adaptive exit
    /// policy, with per-row trees-evaluated counts. Policies that do
    /// not arm early exit on this model (see `adaptive_mode`) — in
    /// particular [`AdaptivePolicy::Exact`] and `Margin(0.0)` — route
    /// to the exact kernel and are bit-identical to `predict_batch` at
    /// full depth. Runs on the CPU's best detected tier.
    pub fn predict_batch_adaptive(
        &self,
        rows: &[Vec<f32>],
        policy: AdaptivePolicy,
    ) -> AdaptiveBatch {
        self.predict_batch_adaptive_with_tier(rows, policy, simd::tier())
    }

    /// [`QuantizedFlatModel::predict_batch_adaptive`] on an explicit
    /// dispatch tier (parity tests, benches). Unsupported tiers clamp
    /// to the detected one; every tier is bit-identical — the exit
    /// test reads partial sums that are themselves tier-independent.
    pub fn predict_batch_adaptive_with_tier(
        &self,
        rows: &[Vec<f32>],
        policy: AdaptivePolicy,
        tier: Tier,
    ) -> AdaptiveBatch {
        let Some((eps, sign_exit)) = self.adaptive_mode(policy) else {
            return AdaptiveBatch {
                trees_evaluated: vec![self.n_trees() as u32; rows.len()],
                scores: self.predict_batch_with_tier(rows, tier),
            };
        };
        let nf = self.n_features;
        let mut out: Vec<Vec<f64>> = rows.iter().map(|_| self.base_scores.clone()).collect();
        let mut trees_evaluated = vec![0u32; rows.len()];
        let mut binned = vec![0u16; BLOCK_ROWS * nf];
        for start in (0..rows.len()).step_by(BLOCK_ROWS) {
            let end = (start + BLOCK_ROWS).min(rows.len());
            let block = &rows[start..end];
            for (r, x) in block.iter().enumerate() {
                self.bin_row(x, &mut binned[r * nf..(r + 1) * nf], tier);
            }
            self.descend_block_adaptive(
                &binned[..block.len() * nf],
                nf,
                &mut out[start..end],
                tier,
                eps,
                sign_exit,
                &mut trees_evaluated[start..end],
            );
        }
        AdaptiveBatch { scores: out, trees_evaluated }
    }

    /// Columnar batched raw scores: `cols[f][i]` is feature `f` of row
    /// `i` — the orientation [`crate::data::Dataset`] already stores,
    /// so dataset-scale scoring needs **no per-row gather at all**.
    /// Each column is binned exactly once (one threshold table hot in
    /// cache per column) into a [`crate::data::BinMatrix`] via the one
    /// shared binning rule
    /// ([`crate::data::binning::bin_columns_over_tables`]
    /// over the model's distinct-threshold tables — NaN's top bin
    /// exceeds every stored rank, so it routes right exactly like
    /// [`NAN_BIN`] on the row path); descent then runs over the
    /// row-major mirror through the same blocked interleaved kernel as
    /// [`QuantizedFlatModel::predict_batch`]. Outputs are bit-identical
    /// to `predict_batch`/`predict_raw` on the same rows
    /// (property-tested in `tests/engine_parity.rs`, NaN included).
    /// Columns beyond the model's feature count are ignored, mirroring
    /// the row path (which reads only `x[0..n_features]`).
    pub fn predict_batch_columns(&self, cols: &[&[f32]], n_rows: usize) -> Vec<Vec<f64>> {
        self.predict_batch_columns_with_tier(cols, n_rows, simd::tier())
    }

    /// [`QuantizedFlatModel::predict_batch_columns`] on an explicit
    /// dispatch tier (parity tests, benches). Unsupported tiers clamp
    /// to the detected one; every tier is bit-identical.
    pub fn predict_batch_columns_with_tier(
        &self,
        cols: &[&[f32]],
        n_rows: usize,
        tier: Tier,
    ) -> Vec<Vec<f64>> {
        let nf = self.n_features;
        assert!(
            cols.len() >= nf,
            "need one column per model feature: got {}, model has {nf}",
            cols.len()
        );
        let cols = &cols[..nf];
        for (f, c) in cols.iter().enumerate() {
            assert_eq!(c.len(), n_rows, "column {f} has {} rows, expected {n_rows}", c.len());
        }
        let mut out: Vec<Vec<f64>> = (0..n_rows).map(|_| self.base_scores.clone()).collect();
        // Chunked so the transient arena + mirror stay bounded on huge
        // batches; chunk starts are multiples of BLOCK_ROWS, so the
        // block partition matches an unchunked pass exactly.
        for cstart in (0..n_rows).step_by(COLUMNAR_CHUNK_ROWS) {
            let cend = (cstart + COLUMNAR_CHUNK_ROWS).min(n_rows);
            let chunk: Vec<&[f32]> = cols.iter().map(|c| &c[cstart..cend]).collect();
            let binned =
                crate::data::binning::bin_columns_over_tables(&self.bounds, &chunk, cend - cstart);
            let xb = binned.to_row_major();
            for start in (0..cend - cstart).step_by(BLOCK_ROWS) {
                let end = (start + BLOCK_ROWS).min(cend - cstart);
                let rows = &mut out[cstart + start..cstart + end];
                self.descend_block_tiered(&xb[start * nf..end * nf], nf, rows, tier);
            }
        }
        out
    }

    /// [`QuantizedFlatModel::predict_batch_columns`] under an adaptive
    /// exit policy — the entry point the gateway batcher serves
    /// through. Non-arming policies route to the exact columnar kernel
    /// at full depth; armed policies bin columns identically and run
    /// the early-exit block kernel, so row routing (and every
    /// non-exited row's score) matches the exact path bit-for-bit.
    pub fn predict_batch_columns_adaptive(
        &self,
        cols: &[&[f32]],
        n_rows: usize,
        policy: AdaptivePolicy,
    ) -> AdaptiveBatch {
        self.predict_batch_columns_adaptive_with_tier(cols, n_rows, policy, simd::tier())
    }

    /// [`QuantizedFlatModel::predict_batch_columns_adaptive`] on an
    /// explicit dispatch tier (parity tests, benches). Unsupported
    /// tiers clamp; every tier is bit-identical.
    pub fn predict_batch_columns_adaptive_with_tier(
        &self,
        cols: &[&[f32]],
        n_rows: usize,
        policy: AdaptivePolicy,
        tier: Tier,
    ) -> AdaptiveBatch {
        let Some((eps, sign_exit)) = self.adaptive_mode(policy) else {
            return AdaptiveBatch {
                trees_evaluated: vec![self.n_trees() as u32; n_rows],
                scores: self.predict_batch_columns_with_tier(cols, n_rows, tier),
            };
        };
        let nf = self.n_features;
        assert!(
            cols.len() >= nf,
            "need one column per model feature: got {}, model has {nf}",
            cols.len()
        );
        let cols = &cols[..nf];
        for (f, c) in cols.iter().enumerate() {
            assert_eq!(c.len(), n_rows, "column {f} has {} rows, expected {n_rows}", c.len());
        }
        let mut out: Vec<Vec<f64>> = (0..n_rows).map(|_| self.base_scores.clone()).collect();
        let mut trees_evaluated = vec![0u32; n_rows];
        for cstart in (0..n_rows).step_by(COLUMNAR_CHUNK_ROWS) {
            let cend = (cstart + COLUMNAR_CHUNK_ROWS).min(n_rows);
            let chunk: Vec<&[f32]> = cols.iter().map(|c| &c[cstart..cend]).collect();
            let binned =
                crate::data::binning::bin_columns_over_tables(&self.bounds, &chunk, cend - cstart);
            let xb = binned.to_row_major();
            for start in (0..cend - cstart).step_by(BLOCK_ROWS) {
                let end = (start + BLOCK_ROWS).min(cend - cstart);
                self.descend_block_adaptive(
                    &xb[start * nf..end * nf],
                    nf,
                    &mut out[cstart + start..cstart + end],
                    tier,
                    eps,
                    sign_exit,
                    &mut trees_evaluated[cstart + start..cstart + end],
                );
            }
        }
        AdaptiveBatch { scores: out, trees_evaluated }
    }

    /// Columnar batched raw scores over a sparse (CSR) matrix. Absent
    /// entries are the implicit `0.0`, so each chunk's row-major bin
    /// block is seeded with every feature's **default code** — the bin
    /// of `0.0` under the model's distinct-threshold table — in one
    /// pass, and only present entries are binned and scattered on top
    /// (a present NaN takes the top bin `bounds[f].len()`, exactly the
    /// dense rule). One binary search per present entry, an O(nnz)
    /// scatter per chunk, then the identical blocked descent as
    /// [`QuantizedFlatModel::predict_batch_columns`] — so outputs are
    /// bit-identical to densifying the matrix and running the dense
    /// columnar path (pinned in `tests/sparse_parity.rs`). Columns
    /// beyond the model's feature count are ignored, mirroring the
    /// dense paths.
    pub fn predict_batch_columns_sparse(&self, x: &CsrMatrix) -> Vec<Vec<f64>> {
        self.predict_batch_columns_sparse_with_tier(x, simd::tier())
    }

    /// [`QuantizedFlatModel::predict_batch_columns_sparse`] on an
    /// explicit dispatch tier (parity tests, benches). Unsupported
    /// tiers clamp to the detected one; every tier is bit-identical.
    pub fn predict_batch_columns_sparse_with_tier(
        &self,
        x: &CsrMatrix,
        tier: Tier,
    ) -> Vec<Vec<f64>> {
        let nf = self.n_features;
        assert!(
            x.n_cols >= nf,
            "need one column per model feature: got {}, model has {nf}",
            x.n_cols
        );
        let n_rows = x.n_rows;
        // The code every absent entry bins to: `#{bounds[f] < 0.0}`,
        // identical to feeding an explicit 0.0 through the dense rule.
        let default_codes: Vec<u16> = self
            .bounds
            .iter()
            .map(|t| t.partition_point(|&b| b < 0.0) as u16)
            .collect();
        let mut out: Vec<Vec<f64>> = (0..n_rows).map(|_| self.base_scores.clone()).collect();
        let mut xb = vec![0u16; COLUMNAR_CHUNK_ROWS.min(n_rows) * nf];
        for cstart in (0..n_rows).step_by(COLUMNAR_CHUNK_ROWS) {
            let cend = (cstart + COLUMNAR_CHUNK_ROWS).min(n_rows);
            let xb = &mut xb[..(cend - cstart) * nf];
            for (r, row) in xb.chunks_exact_mut(nf).enumerate() {
                row.copy_from_slice(&default_codes);
                let (idx, vals) = x.row(cstart + r);
                for (&f, &v) in idx.iter().zip(vals) {
                    let f = f as usize;
                    if f >= nf {
                        break; // column indices ascend; the rest are extras
                    }
                    let t = &self.bounds[f];
                    row[f] = if v.is_nan() {
                        t.len() as u16
                    } else {
                        t.partition_point(|&b| b < v) as u16
                    };
                }
            }
            for start in (0..cend - cstart).step_by(BLOCK_ROWS) {
                let end = (start + BLOCK_ROWS).min(cend - cstart);
                let rows = &mut out[cstart + start..cstart + end];
                self.descend_block_tiered(&xb[start * nf..end * nf], nf, rows, tier);
            }
        }
        out
    }

    /// Dataset score over a sparse test set: accuracy (classification)
    /// or R² (regression), computed exactly like
    /// [`crate::inference::Predictor::score`] but served through
    /// [`QuantizedFlatModel::predict_batch_columns_sparse`] — the CSR
    /// rows are binned straight into the chunked columnar descent, so
    /// no dense float matrix is ever materialized.
    pub fn score_sparse(&self, data: &SparseDataset) -> f64 {
        let scores = self.predict_batch_columns_sparse(&data.x);
        match data.task {
            Task::Regression => {
                let preds: Vec<f64> = scores.iter().map(|r| r[0]).collect();
                crate::metrics::r2_score(&data.targets, &preds)
            }
            _ => {
                let preds: Vec<usize> =
                    scores.iter().map(|r| self.objective.predict_class(r)).collect();
                crate::metrics::accuracy(&data.labels, &preds)
            }
        }
    }
}

impl From<&GbdtModel> for QuantizedFlatModel {
    fn from(model: &GbdtModel) -> QuantizedFlatModel {
        QuantizedFlatModel::from_model(model)
    }
}

#[cfg(test)]
#[cfg(not(miri))] // trains models / generates datasets - too slow under the Miri interpreter
mod tests {
    use super::*;
    use crate::data::synth::PaperDataset;
    use crate::gbdt::{self, GbdtParams};
    use crate::inference::FlatModel;
    use crate::prng::Pcg64;
    use crate::testutil::prop::run_prop;

    fn wrap(trees: Vec<Tree>, n_features: usize) -> GbdtModel {
        GbdtModel {
            objective: Objective::L2,
            base_scores: vec![0.25],
            trees: vec![trees],
            n_features,
            name: "quant-test".into(),
        }
    }

    /// x0 <= 0.5 ? (x1 <= 2.0 ? 1.0 : 2.0) : 3.0
    fn sample_tree() -> Tree {
        Tree {
            nodes: vec![
                Node::Internal { feature: 0, bin: 3, threshold: 0.5, left: 1, right: 2 },
                Node::Internal { feature: 1, bin: 7, threshold: 2.0, left: 3, right: 4 },
                Node::Leaf { value: 3.0 },
                Node::Leaf { value: 1.0 },
                Node::Leaf { value: 2.0 },
            ],
        }
    }

    /// Complete pointer tree whose levels each share one
    /// `(feature, bin, threshold)` split — the shape
    /// [`Tree::oblivious_levels`] detects. `leaves[s]` lands in leaf
    /// slot `s` (MSB-first path bits, the leaf-table order).
    fn oblivious_pointer_tree(splits: &[(usize, u16, f32)], leaves: &[f64]) -> Tree {
        fn grow(
            level: usize,
            slot: usize,
            splits: &[(usize, u16, f32)],
            leaves: &[f64],
            nodes: &mut Vec<Node>,
        ) -> usize {
            let idx = nodes.len();
            if level == splits.len() {
                nodes.push(Node::Leaf { value: leaves[slot] });
                return idx;
            }
            let (feature, bin, threshold) = splits[level];
            nodes.push(Node::Leaf { value: 0.0 }); // placeholder
            let left = grow(level + 1, slot * 2, splits, leaves, nodes);
            let right = grow(level + 1, slot * 2 + 1, splits, leaves, nodes);
            nodes[idx] = Node::Internal { feature, bin, threshold, left, right };
            idx
        }
        assert_eq!(leaves.len(), 1 << splits.len());
        let mut nodes = Vec::new();
        grow(0, 0, splits, leaves, &mut nodes);
        Tree { nodes }
    }

    /// A left-leaning chain deeper than the complete-layout cutoff, so
    /// it must take the general node path.
    fn chain_tree(depth: usize) -> Tree {
        let mut nodes = Vec::new();
        for d in 0..depth {
            let idx = nodes.len();
            nodes.push(Node::Internal {
                feature: 0,
                bin: d as u16,
                threshold: -(d as f32) * 0.1,
                left: idx + 2,
                right: idx + 1,
            });
            nodes.push(Node::Leaf { value: d as f64 });
        }
        nodes.push(Node::Leaf { value: -7.0 });
        Tree { nodes }
    }

    #[test]
    fn matches_pointer_and_flat_on_handmade_model() {
        let model = wrap(vec![sample_tree(), Tree::leaf(0.5), chain_tree(14)], 2);
        let quant = QuantizedFlatModel::from_model(&model);
        let flat = FlatModel::from_model(&model);
        assert_eq!(quant.n_trees(), 3);
        assert_eq!(quant.n_complete_trees(), 2); // the chain is too deep
        assert_eq!(quant.n_thresholds(), 1 + 14 + 1); // f0: {0.5}∪chain(14), f1: {2.0}
        for x in [
            [0.4f32, 1.0],
            [0.4, 3.0],
            [0.6, 0.0],
            [0.5, 2.0], // boundary: exact threshold value routes left
            [-0.35, 9.0],
            [-2.0, -2.0],
        ] {
            let want = model.predict_raw(&x);
            assert_eq!(quant.predict_raw(&x), want);
            assert_eq!(quant.predict_raw(&x), flat.predict_raw(&x));
            assert_eq!(quant.predict_batch(&[x.to_vec()])[0], want);
        }
    }

    #[test]
    fn oblivious_trees_take_the_oblivious_path_and_match_the_other_engines() {
        // Level 0 splits on x0 ≤ 0.5, level 1 on x1 ≤ 2.0; leaf slot s
        // is the MSB-first path-bit integer, so the leaf values below
        // pin the bit order as well as the routing.
        let obl = oblivious_pointer_tree(
            &[(0, 3, 0.5), (1, 7, 2.0)],
            &[10.0, 20.0, 30.0, 40.0],
        );
        let model = wrap(vec![obl, sample_tree(), Tree::leaf(0.5), chain_tree(14)], 2);
        let quant = QuantizedFlatModel::from_model(&model);
        let flat = FlatModel::from_model(&model);
        assert_eq!(quant.n_oblivious_trees(), 1);
        assert_eq!(quant.n_complete_trees(), 2); // sample_tree + the bare leaf
        for x in [
            [0.4f32, 1.0],  // left-left  → 10.0 from the oblivious tree
            [0.4, 3.0],     // left-right → 20.0
            [0.6, 0.0],     // right-left → 30.0
            [0.6, 3.0],     // right-right → 40.0
            [0.5, 2.0],     // boundary: exact threshold routes left
            [f32::NAN, 1.0],
            [0.4, f32::NAN],
            [f32::NAN, f32::NAN],
        ] {
            let want = model.predict_raw(&x);
            assert_eq!(quant.predict_raw(&x), want);
            assert_eq!(quant.predict_raw(&x), flat.predict_raw(&x));
            assert_eq!(quant.predict_batch(&[x.to_vec()])[0], want);
        }
        // The tiered block kernel (full lane groups + tail) agrees with
        // the per-row path on every tier the CPU supports.
        let mut rng = Pcg64::new(0xb0b);
        let mut rows: Vec<Vec<f32>> = (0..70)
            .map(|_| (0..2).map(|_| rng.gen_uniform(-1.0, 4.0) as f32).collect())
            .collect();
        rows[7][0] = f32::NAN;
        rows[66][1] = f32::NAN;
        let want = quant.predict_batch_with_tier(&rows, Tier::Scalar);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(want[i], model.predict_raw(row), "row {i} vs pointer");
        }
        for tier in crate::simd::available_tiers() {
            let got = quant.predict_batch_with_tier(&rows, tier);
            assert_eq!(got, want, "tier {}", tier.name());
        }
    }

    #[test]
    fn prop_oblivious_models_match_pointer_on_random_level_splits() {
        run_prop("oblivious quantized engine == pointer", 40, |g| {
            let nf = g.usize_in(1, 5);
            let mut rng = Pcg64::new(g.case_seed ^ 0x0b1);
            let tables: Vec<Vec<f32>> = (0..nf)
                .map(|_| {
                    let mut t: Vec<f32> = (0..1 + rng.gen_range(9))
                        .map(|_| rng.gen_uniform(-1.0, 1.0) as f32)
                        .collect();
                    t.sort_by(f32::total_cmp);
                    t.dedup();
                    t
                })
                .collect();
            let n_trees = g.usize_in(1, 4);
            let trees: Vec<Tree> = (0..n_trees)
                .map(|_| {
                    let depth = g.usize_in(1, 5);
                    let splits: Vec<(usize, u16, f32)> = (0..depth)
                        .map(|_| {
                            let f = rng.gen_range(nf);
                            let b = rng.gen_range(tables[f].len());
                            (f, b as u16, tables[f][b])
                        })
                        .collect();
                    let leaves: Vec<f64> =
                        (0..1usize << depth).map(|_| rng.gen_uniform(-2.0, 2.0)).collect();
                    oblivious_pointer_tree(&splits, &leaves)
                })
                .collect();
            let model = wrap(trees, nf);
            let quant = QuantizedFlatModel::from_model(&model);
            assert_eq!(quant.n_oblivious_trees(), n_trees, "every tree is level-uniform");
            let rows: Vec<Vec<f32>> = (0..g.usize_in(1, 70))
                .map(|_| {
                    (0..nf)
                        .map(|_| {
                            if g.bool(0.07) {
                                f32::NAN
                            } else {
                                g.f64_in(-1.5, 1.5) as f32
                            }
                        })
                        .collect()
                })
                .collect();
            let batch = quant.predict_batch(&rows);
            for (i, row) in rows.iter().enumerate() {
                assert_eq!(batch[i], model.predict_raw(row), "row {i} vs pointer");
                assert_eq!(batch[i], quant.predict_raw(row), "row {i} batch vs single");
            }
        });
    }

    #[test]
    fn adaptive_policies_behave_identically_on_oblivious_models() {
        // Margin(0.0) and Exact stay bit-identical to the exact batch
        // on an all-oblivious ensemble; an armed width policy routes
        // through the oblivious gather kernel and still matches the
        // exact kernel for rows that never exit (eps too small).
        let splits_a = [(0usize, 3u16, 0.5f32), (1, 7, 2.0)];
        let splits_b = [(1usize, 2u16, 1.0f32), (0, 5, -0.25)];
        let trees = vec![
            oblivious_pointer_tree(&splits_a, &[1.0, 2.0, 3.0, 4.0]),
            oblivious_pointer_tree(&splits_b, &[-1.0, 0.5, 0.25, 2.0]),
        ];
        let model = wrap(trees, 2);
        let quant = QuantizedFlatModel::from_model(&model);
        assert_eq!(quant.n_oblivious_trees(), 2);
        let mut rng = Pcg64::new(0xada);
        let mut rows: Vec<Vec<f32>> = (0..70)
            .map(|_| (0..2).map(|_| rng.gen_uniform(-1.0, 3.0) as f32).collect())
            .collect();
        rows[11][1] = f32::NAN;
        let want = quant.predict_batch(&rows);
        for policy in [
            AdaptivePolicy::Exact,
            AdaptivePolicy::Margin(0.0),
            AdaptivePolicy::Margin(1e-12), // armed, but the interval never narrows enough
        ] {
            let ab = quant.predict_batch_adaptive(&rows, policy);
            assert_eq!(ab.scores, want, "{policy:?} must match the exact kernel");
            assert!(ab.trees_evaluated.iter().all(|&t| t as usize == quant.n_trees()));
        }
        // A huge tolerance retires every row after tree 0 with the
        // midpoint completion, through the oblivious gather arm.
        let ab = quant.predict_batch_adaptive(&rows, AdaptivePolicy::Margin(100.0));
        assert!(ab.trees_evaluated.iter().all(|&t| t == 1));
        let (lo, hi) = quant.suffix_bounds(0);
        let mid = (lo[1] + hi[1]) * 0.5;
        let one = QuantizedFlatModel::from_model(&wrap(
            vec![oblivious_pointer_tree(&splits_a, &[1.0, 2.0, 3.0, 4.0])],
            2,
        ));
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(ab.scores[i][0], one.predict_raw(row)[0] + mid, "row {i}");
        }
    }

    #[test]
    fn nan_inputs_route_like_pointer_trees() {
        // NaN bins to NAN_BIN, which exceeds every real rank: routes
        // right at every split, exactly like `x <= t` being false.
        let model = wrap(vec![sample_tree(), chain_tree(14)], 2);
        let quant = QuantizedFlatModel::from_model(&model);
        for x in [[f32::NAN, 1.0], [0.4, f32::NAN], [f32::NAN, f32::NAN]] {
            let want = model.predict_raw(&x);
            assert_eq!(quant.predict_raw(&x), want);
            assert_eq!(quant.predict_batch(&[x.to_vec()])[0], want);
        }
    }

    #[test]
    fn batch_interleave_and_tail_equal_single_row_exactly() {
        let data = PaperDataset::BreastCancer.generate(33).select(&(0..300).collect::<Vec<_>>());
        let model = gbdt::booster::train(&data, GbdtParams::paper(12, 3));
        let quant = QuantizedFlatModel::from_model(&model);
        let flat = FlatModel::from_model(&model);
        // 70 rows: a full 64-row block (8 lane groups) plus a 6-row
        // block that exercises the scalar tail.
        let rows: Vec<Vec<f32>> = (0..70).map(|i| data.row(i)).collect();
        let batch = quant.predict_batch(&rows);
        let fbatch = flat.predict_batch(&rows);
        assert_eq!(batch.len(), rows.len());
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(batch[i], quant.predict_raw(row), "row {i}: batch vs single");
            assert_eq!(batch[i], fbatch[i], "row {i}: quantized vs flat");
            assert_eq!(batch[i], model.predict_raw(row), "row {i}: quantized vs pointer");
        }
    }

    /// Random tree whose (feature, threshold) pairs are drawn from a
    /// shared per-feature table, mimicking trained models (where many
    /// nodes reuse the same boundary values).
    fn random_tree(rng: &mut Pcg64, tables: &[Vec<f32>], max_depth: usize) -> Tree {
        fn grow(
            rng: &mut Pcg64,
            tables: &[Vec<f32>],
            depth: usize,
            max_depth: usize,
            nodes: &mut Vec<Node>,
        ) -> usize {
            let idx = nodes.len();
            if depth >= max_depth || rng.gen_bool(0.3) {
                nodes.push(Node::Leaf { value: rng.gen_uniform(-2.0, 2.0) });
                return idx;
            }
            nodes.push(Node::Leaf { value: 0.0 }); // placeholder
            let feature = rng.gen_range(tables.len());
            let bin = rng.gen_range(tables[feature].len());
            let threshold = tables[feature][bin];
            let left = grow(rng, tables, depth + 1, max_depth, nodes);
            let right = grow(rng, tables, depth + 1, max_depth, nodes);
            nodes[idx] =
                Node::Internal { feature, bin: bin as u16, threshold, left, right };
            idx
        }
        let mut nodes = Vec::new();
        grow(rng, tables, 0, max_depth, &mut nodes);
        Tree { nodes }
    }

    #[test]
    fn prop_quantized_matches_flat_and_pointer_on_random_trees() {
        run_prop("quantized engine == flat == pointer", 60, |g| {
            let d = g.usize_in(1, 6);
            let mut rng = Pcg64::new(g.case_seed ^ 0x51);
            let tables: Vec<Vec<f32>> = (0..d)
                .map(|_| {
                    let mut t: Vec<f32> = (0..1 + rng.gen_range(12))
                        .map(|_| rng.gen_uniform(-1.0, 1.0) as f32)
                        .collect();
                    t.sort_by(f32::total_cmp);
                    t.dedup();
                    t
                })
                .collect();
            let n_trees = g.usize_in(1, 6);
            let trees: Vec<Tree> = (0..n_trees)
                .map(|_| random_tree(&mut rng, &tables, g.usize_in(0, 6)))
                .collect();
            let model = wrap(trees, d);
            let quant = QuantizedFlatModel::from_model(&model);
            let flat = FlatModel::from_model(&model);
            let rows: Vec<Vec<f32>> = (0..g.usize_in(1, 70))
                .map(|_| {
                    (0..d)
                        .map(|_| {
                            if g.bool(0.05) {
                                f32::NAN
                            } else {
                                g.f64_in(-1.5, 1.5) as f32
                            }
                        })
                        .collect()
                })
                .collect();
            let batch = quant.predict_batch(&rows);
            for (i, row) in rows.iter().enumerate() {
                assert_eq!(batch[i], model.predict_raw(row), "row {i} vs pointer");
                assert_eq!(batch[i], flat.predict_raw(row), "row {i} vs flat");
            }
        });
    }

    /// Transpose row-major test rows into feature columns.
    fn to_cols(rows: &[Vec<f32>], nf: usize) -> Vec<Vec<f32>> {
        (0..nf).map(|f| rows.iter().map(|r| r[f]).collect()).collect()
    }

    #[test]
    fn columnar_batch_equals_row_batch_including_partial_block() {
        let data = PaperDataset::BreastCancer.generate(35).select(&(0..300).collect::<Vec<_>>());
        let model = gbdt::booster::train(&data, GbdtParams::paper(12, 3));
        let quant = QuantizedFlatModel::from_model(&model);
        // 70 rows: one full 64-row block plus a 6-row partial block
        // that exercises the scalar lane tail.
        let rows: Vec<Vec<f32>> = (0..70).map(|i| data.row(i)).collect();
        let cols = to_cols(&rows, data.n_features());
        let col_refs: Vec<&[f32]> = cols.iter().map(|c| c.as_slice()).collect();
        let from_cols = quant.predict_batch_columns(&col_refs, rows.len());
        let from_rows = quant.predict_batch(&rows);
        assert_eq!(from_cols.len(), rows.len());
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(from_cols[i], from_rows[i], "row {i}: columnar vs row batch");
            assert_eq!(from_cols[i], model.predict_raw(row), "row {i}: columnar vs pointer");
        }
        // Zero rows is a valid (empty) columnar batch.
        let empty_refs: Vec<&[f32]> = vec![&[]; cols.len()];
        assert!(quant.predict_batch_columns(&empty_refs, 0).is_empty());
        // Trailing columns beyond n_features are ignored, like the row
        // path ignores trailing row entries (datasets wider than the
        // model still score).
        let junk: Vec<f32> = vec![9.9; rows.len()];
        let mut wide_refs = col_refs.clone();
        wide_refs.push(&junk);
        let wide = quant.predict_batch_columns(&wide_refs, rows.len());
        assert_eq!(wide, from_cols, "extra columns must not change outputs");
    }

    #[test]
    fn columnar_batch_handles_nan_rows() {
        let model = wrap(vec![sample_tree(), chain_tree(14)], 2);
        let quant = QuantizedFlatModel::from_model(&model);
        let rows = vec![
            vec![f32::NAN, 1.0],
            vec![0.4, f32::NAN],
            vec![f32::NAN, f32::NAN],
            vec![0.4, 1.0],
        ];
        let cols = to_cols(&rows, 2);
        let col_refs: Vec<&[f32]> = cols.iter().map(|c| c.as_slice()).collect();
        let got = quant.predict_batch_columns(&col_refs, rows.len());
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(got[i], model.predict_raw(row), "NaN row {i}");
        }
    }

    #[test]
    fn threshold_boundary_values_route_exactly() {
        // The rank predicate must agree with the float predicate *at*
        // the threshold values themselves (x == t routes left) and at
        // the adjacent representable floats.
        let t = 0.37f32;
        let tree = Tree {
            nodes: vec![
                Node::Internal { feature: 0, bin: 0, threshold: t, left: 1, right: 2 },
                Node::Leaf { value: -1.0 },
                Node::Leaf { value: 1.0 },
            ],
        };
        let model = wrap(vec![tree], 1);
        let quant = QuantizedFlatModel::from_model(&model);
        let below = f32::from_bits(t.to_bits() - 1);
        let above = f32::from_bits(t.to_bits() + 1);
        for x in [below, t, above, f32::NEG_INFINITY, f32::INFINITY] {
            assert_eq!(quant.predict_raw(&[x]), model.predict_raw(&[x]), "x={x}");
        }
    }

    #[test]
    fn forced_tiers_are_bit_identical_on_trained_model() {
        let data = PaperDataset::BreastCancer.generate(36).select(&(0..300).collect::<Vec<_>>());
        let model = gbdt::booster::train(&data, GbdtParams::paper(12, 3));
        let quant = QuantizedFlatModel::from_model(&model);
        // 70 rows = one full block + a 6-row tail; a couple of NaN rows.
        let mut rows: Vec<Vec<f32>> = (0..70).map(|i| data.row(i)).collect();
        rows[3][0] = f32::NAN;
        rows[68][1] = f32::NAN;
        let want = quant.predict_batch_with_tier(&rows, crate::simd::Tier::Scalar);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(want[i], model.predict_raw(row), "scalar tier vs pointer, row {i}");
        }
        for tier in crate::simd::available_tiers() {
            let got = quant.predict_batch_with_tier(&rows, tier);
            assert_eq!(got, want, "tier {}", tier.name());
        }
        // Forcing a tier the CPU lacks clamps instead of crashing.
        let forced = quant.predict_batch_with_tier(&rows, crate::simd::Tier::Avx2);
        assert_eq!(forced, want);
    }

    #[test]
    fn multiclass_outputs_preserved() {
        let data = PaperDataset::WineQuality.generate(34).select(&(0..600).collect::<Vec<_>>());
        let model = gbdt::booster::train(&data, GbdtParams::paper(4, 2));
        let quant = QuantizedFlatModel::from_model(&model);
        assert_eq!(quant.n_outputs(), 7);
        for i in (0..data.n_rows()).step_by(53) {
            let row = data.row(i);
            assert_eq!(quant.predict_raw(&row), model.predict_raw(&row));
        }
    }

    #[test]
    fn empty_model_returns_base_scores() {
        let model = wrap(Vec::new(), 3);
        let quant = QuantizedFlatModel::from_model(&model);
        assert_eq!(quant.predict_raw(&[0.0, 0.0, 0.0]), vec![0.25]);
        assert_eq!(quant.predict_batch(&[]).len(), 0);
        assert_eq!(quant.n_thresholds(), 0);
        // An empty ensemble never arms early exit; the adaptive entry
        // point degrades to the exact kernel at depth 0.
        let ab = quant.predict_batch_adaptive(&[vec![0.0, 0.0, 0.0]], AdaptivePolicy::Margin(0.5));
        assert_eq!(ab.scores, vec![vec![0.25]]);
        assert_eq!(ab.trees_evaluated, vec![0]);
    }

    #[test]
    fn suffix_bounds_are_suffix_sums_of_leaf_extrema() {
        // sample_tree leaves {1, 2, 3}, constant leaf 0.5,
        // chain_tree(14) leaves {0..13} ∪ {−7}.
        let model = wrap(vec![sample_tree(), Tree::leaf(0.5), chain_tree(14)], 2);
        let quant = QuantizedFlatModel::from_model(&model);
        let (lo, hi) = quant.suffix_bounds(0);
        assert_eq!(lo, &[-5.5, -6.5, -7.0, 0.0]);
        assert_eq!(hi, &[16.5, 13.5, 13.0, 0.0]);
    }

    #[test]
    fn unarmed_policies_match_plain_batch_bit_for_bit() {
        let data = PaperDataset::BreastCancer.generate(37).select(&(0..300).collect::<Vec<_>>());
        let model = gbdt::booster::train(&data, GbdtParams::paper(12, 3));
        let quant = QuantizedFlatModel::from_model(&model);
        let mut rows: Vec<Vec<f32>> = (0..70).map(|i| data.row(i)).collect();
        rows[5][0] = f32::NAN;
        let want = quant.predict_batch(&rows);
        for policy in [
            AdaptivePolicy::Exact,
            AdaptivePolicy::Margin(0.0),
            AdaptivePolicy::Margin(-1.0),
            AdaptivePolicy::Margin(f32::NAN),
        ] {
            let ab = quant.predict_batch_adaptive(&rows, policy);
            assert_eq!(ab.scores, want, "{policy:?} must be exact");
            assert!(
                ab.trees_evaluated.iter().all(|&t| t as usize == quant.n_trees()),
                "{policy:?} must report full depth"
            );
        }
        // Multi-output ensembles never arm, even with a positive eps.
        let wine = PaperDataset::WineQuality.generate(34).select(&(0..400).collect::<Vec<_>>());
        let mc = gbdt::booster::train(&wine, GbdtParams::paper(4, 2));
        let mq = QuantizedFlatModel::from_model(&mc);
        let wrows: Vec<Vec<f32>> = (0..20).map(|i| wine.row(i)).collect();
        let ab = mq.predict_batch_adaptive(&wrows, AdaptivePolicy::Margin(0.5));
        assert_eq!(ab.scores, mq.predict_batch(&wrows));
        assert!(ab.trees_evaluated.iter().all(|&t| t as usize == mq.n_trees()));
    }

    #[test]
    fn width_exit_on_l2_reports_depth_and_bounded_completion() {
        // L2 objective: only the bounded (width) exit applies. With a
        // huge tolerance the interval after tree 0 (width 20) is
        // already narrow enough, so every row retires at depth 1 with
        // the midpoint completion (−6.5 + 13.5)/2 = 3.5.
        let model = wrap(vec![sample_tree(), Tree::leaf(0.5), chain_tree(14)], 2);
        let quant = QuantizedFlatModel::from_model(&model);
        let rows = vec![vec![0.4f32, 1.0], vec![0.6, 0.0], vec![f32::NAN, 3.0]];
        let full = quant.predict_batch(&rows);
        let ab = quant.predict_batch_adaptive(&rows, AdaptivePolicy::Margin(1000.0));
        assert_eq!(ab.trees_evaluated, vec![1, 1, 1]);
        assert!((ab.mean_trees() - 1.0).abs() < 1e-12);
        // A one-tree model gives the exact depth-1 partial score
        // (same base, same first tree).
        let one = QuantizedFlatModel::from_model(&wrap(vec![sample_tree()], 2));
        for (i, row) in rows.iter().enumerate() {
            let partial = one.predict_raw(row)[0];
            assert_eq!(ab.scores[i][0], partial + 3.5, "row {i}: midpoint completion");
            // The completion errs by at most half the interval width.
            assert!((ab.scores[i][0] - full[i][0]).abs() <= 10.0, "row {i}");
        }
    }
}
