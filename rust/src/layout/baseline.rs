//! Size models of the baseline memory layouts (paper §4.2).
//!
//! Following the paper (and Buschjäger & Morik 2023):
//!
//! * **float32 pointer layout** — 128 bits per node: one feature
//!   identifier, one threshold, and two child pointers, each 32 bits.
//!   Leaf-ness is encoded in the feature/child identifiers, so no extra
//!   boolean is charged; boosted trees store no class info in leaves.
//! * **quantized (fp16) pointer layout** — 64 bits per node (all four
//!   fields halved; thresholds and leaf values at 16-bit precision).
//! * **array-based layout** — pointer-less complete trees as in §3.2.1:
//!   per tree of depth `D`, `2^D − 1` internal slots of (feature id,
//!   threshold) and `2^D` leaf-value slots, each field `value_bits`
//!   wide (32 for float32, 16 for the quantized variant).

use crate::gbdt::GbdtModel;

/// Bytes of the float32 pointer layout: 128 bits × all nodes.
pub fn pointer_f32_bytes(model: &GbdtModel) -> usize {
    let nodes: usize = model.trees.iter().flatten().map(|t| t.n_nodes()).sum();
    nodes * 128 / 8
}

/// Bytes of the quantized (16-bit) pointer layout: 64 bits × all nodes.
pub fn pointer_f16_bytes(model: &GbdtModel) -> usize {
    let nodes: usize = model.trees.iter().flatten().map(|t| t.n_nodes()).sum();
    nodes * 64 / 8
}

/// Bytes of the pointer-less array layout at `value_bits` per field.
///
/// Each tree is padded to a complete tree of its own depth; internal
/// slots store (feature id, threshold) and leaf slots one value.
pub fn array_bytes(model: &GbdtModel, value_bits: usize) -> usize {
    let bits: usize = model
        .trees
        .iter()
        .flatten()
        .map(|t| {
            let d = t.depth();
            let internal = (1usize << d) - 1;
            let leaves = 1usize << d;
            internal * 2 * value_bits + leaves * value_bits
        })
        .sum();
    (bits + 7) / 8
}

/// Convenience: float32 array layout (the paper's "array-based LightGBM").
pub fn array_f32_bytes(model: &GbdtModel) -> usize {
    array_bytes(model, 32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gbdt::loss::Objective;
    use crate::gbdt::tree::{Node, Tree};

    fn model(trees: Vec<Tree>) -> GbdtModel {
        GbdtModel {
            objective: Objective::L2,
            base_scores: vec![0.0],
            trees: vec![trees],
            n_features: 4,
            name: "m".into(),
        }
    }

    fn stump() -> Tree {
        Tree {
            nodes: vec![
                Node::Internal { feature: 0, bin: 0, threshold: 0.5, left: 1, right: 2 },
                Node::Leaf { value: 1.0 },
                Node::Leaf { value: 2.0 },
            ],
        }
    }

    #[test]
    fn pointer_sizes() {
        let m = model(vec![stump()]); // 3 nodes
        assert_eq!(pointer_f32_bytes(&m), 3 * 16);
        assert_eq!(pointer_f16_bytes(&m), 3 * 8);
    }

    #[test]
    fn array_size_complete_stump() {
        let m = model(vec![stump()]); // depth 1: 1 internal + 2 leaves
        // internal: 2 fields × 32 bits; leaves: 2 × 32 bits => 128 bits
        assert_eq!(array_f32_bytes(&m), 16);
        assert_eq!(array_bytes(&m, 16), 8);
    }

    #[test]
    fn array_pads_incomplete_trees() {
        // Depth-2 tree with only 2 leaves on one side (3 leaves total).
        let t = Tree {
            nodes: vec![
                Node::Internal { feature: 0, bin: 0, threshold: 0.5, left: 1, right: 2 },
                Node::Internal { feature: 1, bin: 0, threshold: 0.1, left: 3, right: 4 },
                Node::Leaf { value: 3.0 },
                Node::Leaf { value: 1.0 },
                Node::Leaf { value: 2.0 },
            ],
        };
        let m = model(vec![t]);
        // Complete depth-2: 3 internal × 64 + 4 leaves × 32 = 320 bits
        assert_eq!(array_f32_bytes(&m), 40);
    }

    #[test]
    fn bare_leaf_tree() {
        let m = model(vec![Tree::leaf(1.0)]);
        assert_eq!(pointer_f32_bytes(&m), 16);
        assert_eq!(array_f32_bytes(&m), 4); // one 32-bit leaf slot
    }
}
