//! Per-feature value characteristics for threshold-width selection.
//!
//! The ToaD layout stores thresholds at per-feature minimal widths
//! (paper §3.2.1, item (b)): 1-bit booleans, 2/4-bit small integers, or
//! 8/16/32-bit integers and floats. Which width is safe depends on the
//! *feature's* values, not just the threshold: for an integer-valued
//! feature, `x ≤ 2.5` routes identically to `x ≤ 2`, so the threshold
//! can be floored and stored as an integer.

use crate::data::Dataset;

/// Value characteristics of one input feature.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FeatureInfo {
    /// All observed values are non-negative integers.
    pub is_integer: bool,
    pub min: f32,
    pub max: f32,
}

impl FeatureInfo {
    /// Derive characteristics for every feature of a dataset.
    pub fn from_dataset(data: &Dataset) -> Vec<FeatureInfo> {
        data.features
            .iter()
            .map(|col| {
                let mut min = f32::INFINITY;
                let mut max = f32::NEG_INFINITY;
                let mut is_integer = true;
                for &x in col {
                    min = min.min(x);
                    max = max.max(x);
                    if x < 0.0 || x.fract() != 0.0 {
                        is_integer = false;
                    }
                }
                if col.is_empty() {
                    min = 0.0;
                    max = 0.0;
                }
                FeatureInfo { is_integer, min, max }
            })
            .collect()
    }

    /// Fallback when no dataset is available: treat as generic float.
    pub fn generic_float() -> FeatureInfo {
        FeatureInfo { is_integer: false, min: f32::NEG_INFINITY, max: f32::INFINITY }
    }
}

/// How a feature's thresholds are stored.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ThresholdEncoding {
    /// Unsigned integer of the given width ∈ {1, 2, 4, 8, 16, 32} bits;
    /// the stored value is `floor(µ)` (routing-equivalent on integer
    /// features).
    Uint { width: u32 },
    /// IEEE-754 half precision (16 bits).
    F16,
    /// IEEE-754 single precision (32 bits).
    F32,
}

impl ThresholdEncoding {
    pub fn width_bits(&self) -> u32 {
        match self {
            ThresholdEncoding::Uint { width } => *width,
            ThresholdEncoding::F16 => 16,
            ThresholdEncoding::F32 => 32,
        }
    }

    /// Power-of-two exponent stored in the map (3 bits; paper item (b)).
    pub fn width_exponent(&self) -> u32 {
        self.width_bits().trailing_zeros()
    }

    /// Map-stored numeric-type bit (paper item (c)): 0 = integer, 1 = float.
    pub fn is_float(&self) -> bool {
        !matches!(self, ThresholdEncoding::Uint { .. })
    }

    pub fn from_exponent(exp: u32, is_float: bool) -> ThresholdEncoding {
        let width = 1u32 << exp;
        if is_float {
            match width {
                16 => ThresholdEncoding::F16,
                32 => ThresholdEncoding::F32,
                _ => panic!("invalid float width {width}"),
            }
        } else {
            ThresholdEncoding::Uint { width }
        }
    }
}

/// Pick the minimal safe encoding for a feature's used thresholds.
///
/// `allow_f16` gates the lossy half-precision path (used by the encoder's
/// options); when a float threshold does not round-trip through f16
/// within a relative error of 1e-3, f32 is used.
pub fn select_encoding(
    info: &FeatureInfo,
    thresholds: &[f32],
    allow_f16: bool,
) -> ThresholdEncoding {
    if info.is_integer {
        // Floored thresholds are routing-equivalent for integer features.
        let max_floor = thresholds.iter().map(|&t| t.floor().max(0.0) as u64).max().unwrap_or(0);
        let needed = 64 - max_floor.leading_zeros().min(63);
        let width = [1u32, 2, 4, 8, 16, 32]
            .into_iter()
            .find(|&w| w >= needed.max(1))
            .unwrap_or(32);
        if max_floor < (1u64 << width) {
            return ThresholdEncoding::Uint { width };
        }
        // Integer too large for 32 bits — fall through to float.
    }
    if allow_f16 {
        let ok = thresholds.iter().all(|&t| {
            let r = crate::bitio::f16_bits_to_f32(crate::bitio::f32_to_f16_bits(t));
            (r - t).abs() <= 1e-3 * t.abs().max(1e-3)
        });
        if ok {
            return ThresholdEncoding::F16;
        }
    }
    ThresholdEncoding::F32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Task;

    #[test]
    fn detects_integer_features() {
        let ds = Dataset {
            name: "t".into(),
            features: vec![vec![0.0, 1.0, 2.0], vec![0.5, 1.0, 2.0], vec![-1.0, 0.0, 1.0]],
            targets: vec![0.0; 3],
            labels: vec![],
            task: Task::Regression,
        };
        let info = FeatureInfo::from_dataset(&ds);
        assert!(info[0].is_integer);
        assert!(!info[1].is_integer); // fractional value
        assert!(!info[2].is_integer); // negative value
        assert_eq!(info[0].min, 0.0);
        assert_eq!(info[0].max, 2.0);
    }

    #[test]
    fn boolean_feature_gets_one_bit() {
        let info = FeatureInfo { is_integer: true, min: 0.0, max: 1.0 };
        let enc = select_encoding(&info, &[0.5], true);
        assert_eq!(enc, ThresholdEncoding::Uint { width: 1 });
        assert_eq!(enc.width_exponent(), 0);
        assert!(!enc.is_float());
    }

    #[test]
    fn small_int_widths() {
        let info = FeatureInfo { is_integer: true, min: 0.0, max: 11.0 };
        // floor(2.5)=2 -> needs 2 bits
        assert_eq!(select_encoding(&info, &[2.5], true), ThresholdEncoding::Uint { width: 2 });
        // floor(9.5)=9 -> needs 4 bits
        assert_eq!(select_encoding(&info, &[9.5, 2.5], true), ThresholdEncoding::Uint { width: 4 });
        // floor(300.0)=300 -> 16 bits (9 needed, next pow2 width is 16)
        assert_eq!(
            select_encoding(&info, &[300.0], true),
            ThresholdEncoding::Uint { width: 16 }
        );
    }

    #[test]
    fn float_f16_when_safe() {
        let info = FeatureInfo { is_integer: false, min: -10.0, max: 10.0 };
        // 0.5 is exactly representable in f16.
        assert_eq!(select_encoding(&info, &[0.5, 1.5], true), ThresholdEncoding::F16);
        // f16 disabled -> f32.
        assert_eq!(select_encoding(&info, &[0.5], false), ThresholdEncoding::F32);
    }

    #[test]
    fn float_f32_when_f16_lossy() {
        let info = FeatureInfo { is_integer: false, min: 0.0, max: 1e6 };
        // 100000.7 far exceeds f16 range -> f32 required.
        assert_eq!(select_encoding(&info, &[100000.7], true), ThresholdEncoding::F32);
    }

    #[test]
    fn exponent_roundtrip() {
        for enc in [
            ThresholdEncoding::Uint { width: 1 },
            ThresholdEncoding::Uint { width: 4 },
            ThresholdEncoding::Uint { width: 32 },
            ThresholdEncoding::F16,
            ThresholdEncoding::F32,
        ] {
            let e = enc.width_exponent();
            let f = enc.is_float();
            assert_eq!(ThresholdEncoding::from_exponent(e, f), enc);
        }
    }
}
