//! Model memory layouts and their exact size models.
//!
//! * [`feature_info`] — per-feature value characteristics (integer vs
//!   float, value range) used to pick minimal threshold bit-widths.
//! * [`toad_format`] — the paper's five-component bit-wise layout
//!   (§3.2, Figures 2 and 3): metadata, Feature & Threshold Map, Global
//!   Features & Thresholds, Global Leaf Values, and pointer-less
//!   complete-tree arrays. Encoder, decoder, and a [`PackedModel`] view
//!   that predicts *directly from the packed bits* (what an MCU runs).
//! * [`baseline`] — size models of the comparison layouts in §4.2:
//!   float32 pointer nodes (128 bits/node), quantized pointer nodes
//!   (64 bits/node), and the pointer-less array layout.

pub mod baseline;
pub mod feature_info;
pub mod toad_format;

pub use feature_info::FeatureInfo;
pub use toad_format::{decode, encode, EncodeOptions, PackedModel, SizeBreakdown};
