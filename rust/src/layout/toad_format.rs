//! The ToaD bit-wise memory layout (paper §3.2, Figures 2–3).
//!
//! Five components, bit-packed back to back:
//!
//! 1. **Metadata** — task, output count, rounds `K`, maximum tree depth,
//!    input feature count `d`, `|F_U|`, `max_f |T^f|`, global leaf-value
//!    count, and the per-output base scores.
//! 2. **Feature & Threshold Map** — per used feature: input feature
//!    index (`⌈log₂ d⌉` bits), threshold bit-width as a power-of-two
//!    exponent (3 bits), numeric type (1 bit), threshold count − 1
//!    (`⌈log₂ maxT⌉` bits). (Paper §3.2.1 items (a)–(d).)
//! 3. **Global Features & Thresholds** — per-feature threshold value
//!    arrays at the feature's width, concatenated.
//! 4. **Global Leaf Values** — deduplicated leaf values, fixed 32-bit
//!    floats (paper §3.2.2), shared across all trees.
//! 5. **Trees** — per tree: a 1-bit *oblivious* flag, its depth, then
//!    one of two bodies. Flag 0 (general): the pointer-less complete
//!    array (`2^depth − 1` internal slots of feature-ref + threshold-ref,
//!    `2^depth` leaf slots of leaf-value refs; child of slot `i` is
//!    `2i+1` / `2i+2`). Flag 1 (oblivious, CatBoost-style): every level
//!    shares one split, so the body stores just `depth` (feature-ref,
//!    threshold-ref) pairs — root level first — followed by the same
//!    `2^depth` leaf refs; descent is `idx ← 2·idx + (x > µ_level)` and
//!    one leaf-table lookup. The encoder picks the flag per tree by
//!    [`Tree::oblivious_levels`], the limit of the paper's reuse idea:
//!    a level-uniform depth-d tree costs d node records instead of
//!    `2^d − 1`.
//!
//! Early leaves of non-complete trees are *replicated* into their
//! subtree: the pass-through internal slot stores the dummy reference
//! `(0, 0)` and every leaf slot below carries the same value, so the
//! descent lands correctly without a leaf-marker bit (cf. the paper's
//! remark that leaf-ness needs no extra boolean).

use super::feature_info::{select_encoding, FeatureInfo, ThresholdEncoding};
use crate::bitio::{bits_for, BitReader, BitWriter};
use crate::error::Result;
use crate::gbdt::loss::Objective;
use crate::gbdt::tree::{Node, Tree};
use crate::gbdt::GbdtModel;
use std::collections::BTreeMap;

/// Encoder options.
#[derive(Clone, Copy, Debug)]
pub struct EncodeOptions {
    /// Allow lossy 16-bit float thresholds when they round-trip within
    /// 1e-3 relative error. Disable for bit-exact threshold round-trips.
    pub allow_f16: bool,
    /// Leaf-value *sharing* (paper's future-work direction "reuse leaf
    /// values more effectively"): truncate leaf-value mantissas to this
    /// many bits before deduplication, merging near-identical leaves so
    /// more references point at fewer global values. `None` keeps full
    /// f32 precision (the paper's configuration).
    pub leaf_mantissa_bits: Option<u32>,
}

impl Default for EncodeOptions {
    fn default() -> Self {
        EncodeOptions { allow_f16: true, leaf_mantissa_bits: None }
    }
}

/// Truncate an f32 mantissa to `bits` (0..=23), round-to-zero — cheap
/// leaf-value clustering for the sharing option.
fn truncate_mantissa(v: f32, bits: u32) -> f32 {
    debug_assert!(bits <= 23);
    let mask = !((1u32 << (23 - bits)) - 1);
    f32::from_bits(v.to_bits() & mask)
}

/// Apply the leaf-sharing quantization configured in `opts`.
#[inline]
fn quantize_leaf(v: f32, opts: &EncodeOptions) -> f32 {
    match opts.leaf_mantissa_bits {
        Some(bits) => truncate_mantissa(v, bits.min(23)),
        None => v,
    }
}

/// Bit sizes of the five layout components.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SizeBreakdown {
    pub header_bits: usize,
    pub map_bits: usize,
    pub thresholds_bits: usize,
    pub leaf_values_bits: usize,
    pub trees_bits: usize,
}

impl SizeBreakdown {
    pub fn total_bits(&self) -> usize {
        self.header_bits + self.map_bits + self.thresholds_bits + self.leaf_values_bits
            + self.trees_bits
    }

    pub fn total_bytes(&self) -> usize {
        (self.total_bits() + 7) / 8
    }
}

// Fixed header field widths.
const W_TASK: u32 = 2;
const W_OUTPUTS: u32 = 8;
const W_ROUNDS: u32 = 16;
const W_DEPTH: u32 = 4;
const W_D: u32 = 16;
const W_FU: u32 = 16;
const W_MAXT: u32 = 16;
const W_NLEAF: u32 = 24;

/// Everything the encoder derives from a model before packing bits.
struct EncodePlan {
    /// Used features ascending; `per_feature[i]` lists `(bin, value)`
    /// ascending by bin.
    features: Vec<usize>,
    per_feature: Vec<Vec<(u16, f32)>>,
    encodings: Vec<ThresholdEncoding>,
    /// Deduplicated leaf values (first-use order) and value → index.
    leaf_values: Vec<f32>,
    leaf_index: BTreeMap<u32, usize>,
    max_t: usize,
    max_depth: usize,
}

fn plan(model: &GbdtModel, finfo: &[FeatureInfo], opts: &EncodeOptions) -> EncodePlan {
    let mut thr: BTreeMap<usize, BTreeMap<u16, f32>> = BTreeMap::new();
    let mut leaf_values: Vec<f32> = Vec::new();
    let mut leaf_index: BTreeMap<u32, usize> = BTreeMap::new();
    let mut max_depth = 0usize;
    for tree in model.trees.iter().flatten() {
        max_depth = max_depth.max(tree.depth());
        for (f, b, v) in tree.splits() {
            thr.entry(f).or_default().insert(b, v);
        }
        for v in tree.leaf_values() {
            let q = quantize_leaf(v as f32, opts);
            leaf_index.entry(q.to_bits()).or_insert_with(|| {
                leaf_values.push(q);
                leaf_values.len() - 1
            });
        }
    }
    let features: Vec<usize> = thr.keys().copied().collect();
    let per_feature: Vec<Vec<(u16, f32)>> = features
        .iter()
        .map(|f| thr[f].iter().map(|(&b, &v)| (b, v)).collect())
        .collect();
    let encodings: Vec<ThresholdEncoding> = features
        .iter()
        .zip(&per_feature)
        .map(|(&f, list)| {
            let vals: Vec<f32> = list.iter().map(|&(_, v)| v).collect();
            let info = finfo.get(f).copied().unwrap_or_else(FeatureInfo::generic_float);
            select_encoding(&info, &vals, opts.allow_f16)
        })
        .collect();
    let max_t = per_feature.iter().map(|l| l.len()).max().unwrap_or(0);
    EncodePlan { features, per_feature, encodings, leaf_values, leaf_index, max_t, max_depth }
}

/// Exact size of the encoded model, by component, without encoding.
pub fn size_breakdown(
    model: &GbdtModel,
    finfo: &[FeatureInfo],
    opts: &EncodeOptions,
) -> SizeBreakdown {
    let p = plan(model, finfo, opts);
    breakdown_from_plan(model, &p)
}

fn breakdown_from_plan(model: &GbdtModel, p: &EncodePlan) -> SizeBreakdown {
    let wd = bits_for(model.n_features);
    let wc = bits_for(p.max_t);
    let w_f = bits_for(p.features.len());
    let w_t = bits_for(p.max_t);
    let w_l = bits_for(p.leaf_values.len());
    let w_dep = bits_for(p.max_depth + 1);

    let header_bits =
        (W_TASK + W_OUTPUTS + W_ROUNDS + W_DEPTH + W_D + W_FU + W_MAXT + W_NLEAF) as usize
            + 32 * model.n_outputs();
    let map_bits = p.features.len() * (wd + 3 + 1 + wc) as usize;
    let thresholds_bits: usize = p
        .per_feature
        .iter()
        .zip(&p.encodings)
        .map(|(list, enc)| list.len() * enc.width_bits() as usize)
        .sum();
    let leaf_values_bits = p.leaf_values.len() * 32;
    let trees_bits: usize = model
        .trees
        .iter()
        .flatten()
        .map(|t| {
            let d = t.depth();
            // Mirrors the encoder's per-tree choice: oblivious bodies
            // store d (feature, threshold) pairs, general bodies the
            // full 2^d − 1 slots; both prepend a 1-bit flag.
            let n_pairs = if t.oblivious_levels().is_some() { d } else { (1usize << d) - 1 };
            let n_leaves = 1usize << d;
            1 + w_dep as usize + n_pairs * (w_f + w_t) as usize + n_leaves * w_l as usize
        })
        .sum();
    SizeBreakdown { header_bits, map_bits, thresholds_bits, leaf_values_bits, trees_bits }
}

/// Check every fixed-width header field against its width *before* any
/// bits are packed. [`crate::bitio::BitWriter::write`] masks oversized
/// values deterministically, so without this gate a depth-16 model
/// would encode as depth 0 and decode into garbage — silently, in both
/// debug and release builds.
fn validate_header_widths(model: &GbdtModel, p: &EncodePlan) -> Result<()> {
    fn fits(value: usize, width: u32) -> bool {
        (value as u64) < (1u64 << width)
    }
    crate::ensure!(
        fits(model.n_outputs(), W_OUTPUTS),
        "n_outputs {} exceeds the {W_OUTPUTS}-bit header field (max {})",
        model.n_outputs(),
        (1u64 << W_OUTPUTS) - 1
    );
    crate::ensure!(
        fits(model.n_rounds(), W_ROUNDS),
        "n_rounds {} exceeds the {W_ROUNDS}-bit header field (max {})",
        model.n_rounds(),
        (1u64 << W_ROUNDS) - 1
    );
    crate::ensure!(
        fits(p.max_depth, W_DEPTH),
        "max tree depth {} exceeds the {W_DEPTH}-bit header field (max {})",
        p.max_depth,
        (1u64 << W_DEPTH) - 1
    );
    crate::ensure!(
        fits(model.n_features, W_D),
        "n_features {} exceeds the {W_D}-bit header field (max {})",
        model.n_features,
        (1u64 << W_D) - 1
    );
    crate::ensure!(
        fits(p.features.len(), W_FU),
        "|F_U| = {} exceeds the {W_FU}-bit header field (max {})",
        p.features.len(),
        (1u64 << W_FU) - 1
    );
    crate::ensure!(
        fits(p.max_t, W_MAXT),
        "max_f |T^f| = {} exceeds the {W_MAXT}-bit header field (max {})",
        p.max_t,
        (1u64 << W_MAXT) - 1
    );
    crate::ensure!(
        fits(p.leaf_values.len(), W_NLEAF),
        "{} global leaf values exceed the {W_NLEAF}-bit header field (max {})",
        p.leaf_values.len(),
        (1u64 << W_NLEAF) - 1
    );
    Ok(())
}

/// Encode a trained model into the ToaD bit-wise layout.
///
/// Errors when any fixed header field is out of its width (e.g. a tree
/// deeper than 15 against the 4-bit depth field) — the blob would
/// otherwise be silently corrupt.
pub fn encode(model: &GbdtModel, finfo: &[FeatureInfo], opts: &EncodeOptions) -> Result<Vec<u8>> {
    let p = plan(model, finfo, opts);
    validate_header_widths(model, &p)?;
    let mut w = BitWriter::new();

    // -- 1. metadata --
    let task_code: u64 = match model.objective {
        Objective::L2 => 0,
        Objective::Logistic => 1,
        Objective::Softmax { .. } => 2,
    };
    w.write(task_code, W_TASK);
    w.write(model.n_outputs() as u64, W_OUTPUTS);
    w.write(model.n_rounds() as u64, W_ROUNDS);
    w.write(p.max_depth as u64, W_DEPTH);
    w.write(model.n_features as u64, W_D);
    w.write(p.features.len() as u64, W_FU);
    w.write(p.max_t as u64, W_MAXT);
    w.write(p.leaf_values.len() as u64, W_NLEAF);
    for &b in &model.base_scores {
        w.write_f32(b as f32);
    }

    // -- 2. feature & threshold map --
    let wd = bits_for(model.n_features);
    let wc = bits_for(p.max_t);
    for (i, &f) in p.features.iter().enumerate() {
        w.write(f as u64, wd);
        w.write(p.encodings[i].width_exponent() as u64, 3);
        w.write(p.encodings[i].is_float() as u64, 1);
        w.write((p.per_feature[i].len() - 1) as u64, wc);
    }

    // -- 3. global thresholds --
    for (i, list) in p.per_feature.iter().enumerate() {
        for &(_, v) in list {
            write_threshold(&mut w, v, p.encodings[i]);
        }
    }

    // -- 4. global leaf values --
    for &v in &p.leaf_values {
        w.write_f32(v);
    }

    // -- 5. trees --
    let w_f = bits_for(p.features.len());
    let w_t = bits_for(p.max_t);
    let w_l = bits_for(p.leaf_values.len());
    let w_dep = bits_for(p.max_depth + 1);
    let feat_rank: BTreeMap<usize, usize> =
        p.features.iter().enumerate().map(|(i, &f)| (f, i)).collect();
    let bin_rank: Vec<BTreeMap<u16, usize>> = p
        .per_feature
        .iter()
        .map(|list| list.iter().enumerate().map(|(i, &(b, _))| (b, i)).collect())
        .collect();

    for tree in model.trees.iter().flatten() {
        let d = tree.depth();
        let (internal, leaves) = tree.to_complete();
        if let Some(levels) = tree.oblivious_levels() {
            // Oblivious body: d shared (feature, threshold) pairs, root
            // level first, instead of 2^d − 1 per-slot records.
            w.write(1, 1);
            w.write(d as u64, w_dep);
            for &(f, b, _) in &levels {
                let fr = feat_rank[&f];
                let tr = bin_rank[fr][&b];
                w.write(fr as u64, w_f);
                w.write(tr as u64, w_t);
            }
        } else {
            w.write(0, 1);
            w.write(d as u64, w_dep);
            for slot in &internal {
                match slot {
                    Some((f, b, _)) => {
                        let fr = feat_rank[f];
                        let tr = bin_rank[fr][b];
                        w.write(fr as u64, w_f);
                        w.write(tr as u64, w_t);
                    }
                    None => {
                        // Pass-through: dummy reference; leaves below are
                        // replicated so routing is unaffected.
                        w.write(0, w_f);
                        w.write(0, w_t);
                    }
                }
            }
        }
        for &v in &leaves {
            let idx = p.leaf_index[&quantize_leaf(v as f32, opts).to_bits()];
            w.write(idx as u64, w_l);
        }
    }

    Ok(w.into_bytes())
}

fn write_threshold(w: &mut BitWriter, v: f32, enc: ThresholdEncoding) {
    match enc {
        ThresholdEncoding::Uint { width } => w.write(v.floor().max(0.0) as u64, width),
        ThresholdEncoding::F16 => w.write_f16(v),
        ThresholdEncoding::F32 => w.write_f32(v),
    }
}

fn read_threshold(r: &mut BitReader, enc: ThresholdEncoding) -> f32 {
    match enc {
        ThresholdEncoding::Uint { width } => r.read(width) as f32,
        ThresholdEncoding::F16 => r.read_f16(),
        ThresholdEncoding::F32 => r.read_f32(),
    }
}

/// Parsed header + map of a packed model; shared by [`decode`] and
/// [`PackedModel`].
#[derive(Clone, Debug)]
struct Parsed {
    objective: Objective,
    n_outputs: usize,
    n_rounds: usize,
    max_depth: usize,
    n_features: usize,
    base_scores: Vec<f64>,
    /// Per used feature: input index, encoding, threshold count.
    map: Vec<(usize, ThresholdEncoding, usize)>,
    /// Bit offset of each feature's threshold array.
    thr_offsets: Vec<usize>,
    /// Bit offset of the global leaf values.
    leaf_off: usize,
    n_leaf_values: usize,
    /// Bit offset where tree data starts.
    trees_off: usize,
    max_t: usize,
}

fn parse(bytes: &[u8]) -> Parsed {
    let mut r = BitReader::new(bytes);
    let task_code = r.read(W_TASK);
    let n_outputs = r.read(W_OUTPUTS) as usize;
    let n_rounds = r.read(W_ROUNDS) as usize;
    let max_depth = r.read(W_DEPTH) as usize;
    let n_features = r.read(W_D) as usize;
    let n_used = r.read(W_FU) as usize;
    let max_t = r.read(W_MAXT) as usize;
    let n_leaf_values = r.read(W_NLEAF) as usize;
    let base_scores: Vec<f64> = (0..n_outputs).map(|_| r.read_f32() as f64).collect();
    let objective = match task_code {
        0 => Objective::L2,
        1 => Objective::Logistic,
        2 => Objective::Softmax { n_classes: n_outputs },
        _ => panic!("bad task code {task_code}"),
    };

    let wd = bits_for(n_features);
    let wc = bits_for(max_t);
    let mut map = Vec::with_capacity(n_used);
    for _ in 0..n_used {
        let f = r.read(wd) as usize;
        let exp = r.read(3) as u32;
        let is_float = r.read(1) == 1;
        let count = r.read(wc) as usize + 1;
        map.push((f, ThresholdEncoding::from_exponent(exp, is_float), count));
    }

    // Threshold arrays begin right after the map.
    let mut off = r.bit_pos();
    let mut thr_offsets = Vec::with_capacity(n_used);
    for &(_, enc, count) in &map {
        thr_offsets.push(off);
        off += count * enc.width_bits() as usize;
    }
    let leaf_off = off;
    let trees_off = leaf_off + n_leaf_values * 32;

    Parsed {
        objective,
        n_outputs,
        n_rounds,
        max_depth,
        n_features,
        base_scores,
        map,
        thr_offsets,
        leaf_off,
        n_leaf_values,
        trees_off,
        max_t,
    }
}

/// Validate that a blob is structurally sound: the header parses, every
/// component lies within the buffer, reference widths are consistent,
/// and every stored reference (map feature index, per-node feature ref
/// and threshold rank, leaf-value ref) is in range — [`decode`] and
/// [`PackedModel`] index their tables with these, so an unchecked
/// reference would turn a single flipped bit into a panic. Returns the
/// total bit length on success. Cost is `O(total bits)` (the tree
/// bodies are walked node by node, not skipped by size). Run this
/// before [`decode`]/[`PackedModel::from_bytes`] on untrusted bytes
/// (e.g. a blob read back from device flash).
pub fn validate_blob(bytes: &[u8]) -> Result<usize, String> {
    let total_bits = bytes.len() * 8;
    let header_min = (W_TASK + W_OUTPUTS + W_ROUNDS + W_DEPTH + W_D + W_FU + W_MAXT + W_NLEAF)
        as usize;
    if total_bits < header_min {
        return Err(format!("blob too small: {total_bits} bits < header {header_min}"));
    }
    let mut r = BitReader::new(bytes);
    let task = r.read(W_TASK);
    if task > 2 {
        return Err(format!("invalid task code {task}"));
    }
    let n_outputs = r.read(W_OUTPUTS) as usize;
    if n_outputs == 0 {
        return Err("zero outputs".into());
    }
    if task < 2 && n_outputs != 1 {
        return Err(format!("task {task} requires 1 output, found {n_outputs}"));
    }
    let n_rounds = r.read(W_ROUNDS) as usize;
    let max_depth = r.read(W_DEPTH) as usize;
    let n_features = r.read(W_D) as usize;
    let n_used = r.read(W_FU) as usize;
    if n_used > n_features {
        return Err(format!("|F_U|={n_used} exceeds d={n_features}"));
    }
    let max_t = r.read(W_MAXT) as usize;
    if n_used > 0 && max_t == 0 {
        return Err("used features but no thresholds".into());
    }
    let n_leaf_values = r.read(W_NLEAF) as usize;
    if n_leaf_values == 0 && n_rounds > 0 {
        return Err("trees without leaf values".into());
    }
    // Walk the map, thresholds, leaves, and trees checking bounds.
    let wd = bits_for(n_features);
    let wc = bits_for(max_t);
    let need =
        r.bit_pos() + 32 * n_outputs + n_used * (wd + 3 + 1 + wc) as usize;
    if need > total_bits {
        return Err("map truncated".into());
    }
    r.seek(r.bit_pos() + 32 * n_outputs);
    let mut thr_bits = 0usize;
    let mut counts = Vec::with_capacity(n_used);
    for i in 0..n_used {
        let f = r.read(wd) as usize;
        if f >= n_features {
            return Err(format!("map[{i}]: feature {f} out of range"));
        }
        let exp = r.read(3) as u32;
        let is_float = r.read(1) == 1;
        if is_float && !(4..=5).contains(&exp) {
            return Err(format!("map[{i}]: invalid float width 2^{exp}"));
        }
        // Legal integer widths are {1, 2, 4, 8, 16, 32} (exp 0..=5);
        // exp 6/7 would make readers pull 64/128-bit threshold fields —
        // 128 exceeds `BitReader::read`'s width contract.
        if !is_float && exp > 5 {
            return Err(format!("map[{i}]: invalid integer width 2^{exp}"));
        }
        let count = r.read(wc) as usize + 1;
        if count > max_t {
            return Err(format!("map[{i}]: count {count} > maxT {max_t}"));
        }
        counts.push(count);
        thr_bits += count * (1usize << exp);
    }
    let w_f = bits_for(n_used);
    let w_t = bits_for(max_t);
    let w_l = bits_for(n_leaf_values);
    let w_dep = bits_for(max_depth + 1);
    let mut pos = r.bit_pos() + thr_bits + n_leaf_values * 32;
    if pos > total_bits {
        return Err("threshold/leaf tables truncated".into());
    }
    let mut r2 = BitReader::new(bytes);
    for t in 0..n_outputs * n_rounds {
        if pos + 1 + w_dep as usize > total_bits {
            return Err(format!("tree {t}: flag/depth fields truncated"));
        }
        r2.seek(pos);
        let oblivious = r2.read(1) == 1;
        let d = r2.read(w_dep) as usize;
        if d > max_depth {
            return Err(format!("tree {t}: depth {d} > max {max_depth}"));
        }
        // Oblivious bodies store one (feature, threshold) pair per
        // level; general bodies store the full complete array.
        let n_pairs = if oblivious { d } else { (1usize << d) - 1 };
        pos = r2.bit_pos()
            + n_pairs * (w_f + w_t) as usize
            + (1usize << d) * w_l as usize;
        if pos > total_bits {
            return Err(format!("tree {t}: body truncated"));
        }
        // The body fits — now check every stored reference. Reference
        // fields are packed at power-of-two-rounded widths, so a blob
        // can pass every size check yet hold an index past its table
        // (one flipped bit is enough whenever the table length is not a
        // power of two); `decode` and `PackedModel` index the map, the
        // threshold tables, and the leaf-value table with these.
        for s in 0..n_pairs {
            let fr = r2.read(w_f) as usize;
            let tr = r2.read(w_t) as usize;
            if fr >= n_used {
                return Err(format!("tree {t} node {s}: feature ref {fr} >= |F_U| {n_used}"));
            }
            // Encoded slots (real and dummy alike) always store a rank
            // below the feature's threshold count.
            if tr >= counts[fr] {
                return Err(format!(
                    "tree {t} node {s}: threshold rank {tr} >= count {}",
                    counts[fr]
                ));
            }
        }
        for s in 0..(1usize << d) {
            let lr = r2.read(w_l) as usize;
            if lr >= n_leaf_values {
                return Err(format!(
                    "tree {t} leaf {s}: leaf ref {lr} >= table {n_leaf_values}"
                ));
            }
        }
    }
    Ok(pos)
}

/// Decode a packed blob back into a [`GbdtModel`].
///
/// Decoded trees are *complete* trees of their stored depth (replicated
/// early leaves become real leaves), so node counts can exceed the
/// original; predictions are identical up to threshold quantization.
/// The synthetic `bin` stored on decoded nodes is the per-feature
/// threshold rank, not the original training-bin index.
///
/// Panics on malformed input — run [`validate_blob`] first on untrusted
/// bytes, or use [`try_decode`].
pub fn decode(bytes: &[u8]) -> GbdtModel {
    let p = parse(bytes);
    let mut r = BitReader::new(bytes);

    // Load threshold tables and leaf values eagerly.
    let thresholds: Vec<Vec<f32>> = p
        .map
        .iter()
        .enumerate()
        .map(|(i, &(_, enc, count))| {
            r.seek(p.thr_offsets[i]);
            (0..count).map(|_| read_threshold(&mut r, enc)).collect()
        })
        .collect();
    r.seek(p.leaf_off);
    let leaf_values: Vec<f32> = (0..p.n_leaf_values).map(|_| r.read_f32()).collect();

    let w_f = bits_for(p.map.len());
    let w_t = bits_for(p.max_t);
    let w_l = bits_for(p.n_leaf_values);
    let w_dep = bits_for(p.max_depth + 1);

    r.seek(p.trees_off);
    let mut trees: Vec<Vec<Tree>> = vec![Vec::with_capacity(p.n_rounds); p.n_outputs];
    for out in trees.iter_mut() {
        for _ in 0..p.n_rounds {
            let oblivious = r.read(1) == 1;
            let d = r.read(w_dep) as usize;
            let n_internal = (1usize << d) - 1;
            let n_leaves = 1usize << d;
            let mut internal = Vec::with_capacity(n_internal);
            if oblivious {
                // d shared pairs, root level first: replicate the level
                // split into every complete-array slot of that level
                // (slot s lives on level ⌊log₂(s+1)⌋), then reuse the
                // general reconstruction below unchanged.
                let pairs: Vec<(usize, usize)> = (0..d)
                    .map(|_| (r.read(w_f) as usize, r.read(w_t) as usize))
                    .collect();
                for s in 0..n_internal {
                    internal.push(pairs[(s + 1).ilog2() as usize]);
                }
            } else {
                for _ in 0..n_internal {
                    let fr = r.read(w_f) as usize;
                    let tr = r.read(w_t) as usize;
                    internal.push((fr, tr));
                }
            }
            let mut leaves = Vec::with_capacity(n_leaves);
            for _ in 0..n_leaves {
                let lr = r.read(w_l) as usize;
                leaves.push(leaf_values[lr] as f64);
            }
            out.push(complete_to_tree(&internal, &leaves, &p, &thresholds));
        }
    }

    GbdtModel {
        objective: p.objective,
        base_scores: p.base_scores,
        trees,
        n_features: p.n_features,
        name: "decoded".into(),
    }
}

/// Validated decode for untrusted bytes.
pub fn try_decode(bytes: &[u8]) -> Result<GbdtModel, String> {
    validate_blob(bytes)?;
    Ok(decode(bytes))
}

/// Rebuild a pointer [`Tree`] from a complete-array representation.
fn complete_to_tree(
    internal: &[(usize, usize)],
    leaves: &[f64],
    p: &Parsed,
    thresholds: &[Vec<f32>],
) -> Tree {
    fn build(
        slot: usize,
        internal: &[(usize, usize)],
        leaves: &[f64],
        p: &Parsed,
        thresholds: &[Vec<f32>],
        nodes: &mut Vec<Node>,
    ) -> usize {
        let idx = nodes.len();
        if slot >= internal.len() {
            nodes.push(Node::Leaf { value: leaves[slot - internal.len()] });
            return idx;
        }
        let (fr, tr) = internal[slot];
        let (f, _, count) = p.map[fr];
        // Guard decoded references (dummy slots always store (0,0),
        // which is valid whenever any feature exists).
        let tr = tr.min(count - 1);
        let threshold = thresholds[fr][tr];
        nodes.push(Node::Leaf { value: 0.0 }); // placeholder
        let left = build(2 * slot + 1, internal, leaves, p, thresholds, nodes);
        let right = build(2 * slot + 2, internal, leaves, p, thresholds, nodes);
        nodes[idx] = Node::Internal { feature: f, bin: tr as u16, threshold, left, right };
        idx
    }
    if internal.is_empty() {
        return Tree::leaf(leaves[0]);
    }
    let mut nodes = Vec::new();
    build(0, internal, leaves, p, thresholds, &mut nodes);
    Tree { nodes }
}

/// A zero-copy view over a packed blob that predicts **directly from the
/// bits** — node references, thresholds, and leaf values are extracted
/// with bit reads on every access, exactly as a microcontroller with the
/// blob in flash would operate. Used for the Table 2 latency comparison
/// and by the [`crate::mcu`] cost model.
pub struct PackedModel {
    bytes: Vec<u8>,
    p: Parsed,
    /// Per-tree (depth, internal bit offset, leaf bit offset, oblivious
    /// flag), in `[output][round]` order flattened.
    tree_offsets: Vec<(usize, usize, usize, bool)>,
    /// Load-time flat per-used-feature geometry: (input feature,
    /// encoding, max threshold index, threshold array bit offset).
    /// Avoids re-deriving map entries on every node visit (§Perf
    /// iteration 2).
    feat_table: Vec<(usize, ThresholdEncoding, usize, usize)>,
    w_f: u32,
    w_t: u32,
    w_l: u32,
}

impl PackedModel {
    pub fn from_bytes(bytes: Vec<u8>) -> PackedModel {
        let p = parse(&bytes);
        let w_f = bits_for(p.map.len());
        let w_t = bits_for(p.max_t);
        let w_l = bits_for(p.n_leaf_values);
        let w_dep = bits_for(p.max_depth + 1);
        let mut r = BitReader::new(&bytes);
        r.seek(p.trees_off);
        let n_trees = p.n_outputs * p.n_rounds;
        let mut tree_offsets = Vec::with_capacity(n_trees);
        for _ in 0..n_trees {
            let obl = r.read(1) == 1;
            let d = r.read(w_dep) as usize;
            let internal_off = r.bit_pos();
            let n_pairs = if obl { d } else { (1usize << d) - 1 };
            let leaf_off = internal_off + n_pairs * (w_f + w_t) as usize;
            let end = leaf_off + (1usize << d) * w_l as usize;
            tree_offsets.push((d, internal_off, leaf_off, obl));
            r.seek(end);
        }
        let feat_table = p
            .map
            .iter()
            .zip(&p.thr_offsets)
            .map(|(&(f, enc, count), &off)| (f, enc, count - 1, off))
            .collect();
        PackedModel { bytes, p, tree_offsets, feat_table, w_f, w_t, w_l }
    }

    pub fn n_outputs(&self) -> usize {
        self.p.n_outputs
    }

    /// Total trees in the blob (`n_outputs × n_rounds`).
    pub fn n_trees(&self) -> usize {
        self.p.n_outputs * self.p.n_rounds
    }

    pub fn n_features(&self) -> usize {
        self.p.n_features
    }

    pub fn objective(&self) -> Objective {
        self.p.objective
    }

    pub fn size_bytes(&self) -> usize {
        self.bytes.len()
    }

    /// The underlying packed blob.
    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }

    /// Trees stored in the oblivious sub-format (flag bit set).
    pub fn n_oblivious_trees(&self) -> usize {
        self.tree_offsets.iter().filter(|&&(_, _, _, obl)| obl).count()
    }

    /// Bit cost of tree `i` as actually packed — flag + depth field +
    /// body — measured from the blob's offsets rather than recomputed
    /// from a formula, so reports can't drift from the format.
    pub fn tree_bits(&self, i: usize) -> usize {
        let (d, internal_off, leaf_off, _) = self.tree_offsets[i];
        let start = internal_off - bits_for(self.p.max_depth + 1) as usize - 1;
        leaf_off + (1usize << d) * self.w_l as usize - start
    }

    /// Read threshold `tr` of used-feature `fr` straight from the bits.
    #[inline]
    fn threshold(&self, fr: usize, tr: usize) -> f32 {
        let (_, enc, _) = self.p.map[fr];
        let mut r = BitReader::new(&self.bytes);
        r.seek(self.p.thr_offsets[fr] + tr * enc.width_bits() as usize);
        read_threshold(&mut r, enc)
    }

    /// Raw scores for one row, traversing the packed bits.
    pub fn predict_raw(&self, x: &[f32]) -> Vec<f64> {
        let mut out = self.p.base_scores.clone();
        let mut r = BitReader::new(&self.bytes);
        let node_w = (self.w_f + self.w_t) as usize;
        for k in 0..self.p.n_outputs {
            for t in 0..self.p.n_rounds {
                let (d, internal_off, leaf_off, obl) =
                    self.tree_offsets[k * self.p.n_rounds + t];
                let leaf_slot = if obl {
                    // Oblivious descent: d sequential pair reads (no
                    // per-node offset arithmetic), each compare shifts
                    // one bit into the leaf-table index.
                    let mut idx = 0usize;
                    r.seek(internal_off);
                    for _ in 0..d {
                        let fr = r.read(self.w_f) as usize;
                        let tr = r.read(self.w_t) as usize;
                        let (f, enc, max_tr, thr_off) = self.feat_table[fr];
                        let tr = tr.min(max_tr);
                        let next = r.bit_pos();
                        r.seek(thr_off + tr * enc.width_bits() as usize);
                        let thr = read_threshold(&mut r, enc);
                        idx = 2 * idx + usize::from(!(x[f] <= thr));
                        r.seek(next);
                    }
                    idx
                } else {
                    let n_internal = (1usize << d) - 1;
                    let mut i = 0usize;
                    while i < n_internal {
                        r.seek(internal_off + i * node_w);
                        let fr = r.read(self.w_f) as usize;
                        let tr = r.read(self.w_t) as usize;
                        let (f, enc, max_tr, thr_off) = self.feat_table[fr];
                        let tr = tr.min(max_tr);
                        r.seek(thr_off + tr * enc.width_bits() as usize);
                        let thr = read_threshold(&mut r, enc);
                        i = if x[f] <= thr { 2 * i + 1 } else { 2 * i + 2 };
                    }
                    i - n_internal
                };
                r.seek(leaf_off + leaf_slot * self.w_l as usize);
                let lref = r.read(self.w_l) as usize;
                r.seek(self.p.leaf_off + lref * 32);
                out[k] += r.read_f32() as f64;
            }
        }
        out
    }

    /// Class prediction (binary/multiclass).
    pub fn predict_class(&self, x: &[f32]) -> usize {
        self.p.objective.predict_class(&self.predict_raw(x))
    }

    /// Regression prediction.
    pub fn predict_value(&self, x: &[f32]) -> f64 {
        self.predict_raw(x)[0]
    }

    /// Count the bit-level operations of one prediction (for the MCU
    /// cycle model): returns `(nodes_visited, bits_read)`.
    pub fn trace_row(&self, x: &[f32]) -> (usize, usize) {
        let mut nodes = 0usize;
        let mut bits = 0usize;
        let mut r = BitReader::new(&self.bytes);
        for k in 0..self.p.n_outputs {
            for t in 0..self.p.n_rounds {
                let (d, internal_off, leaf_off, obl) =
                    self.tree_offsets[k * self.p.n_rounds + t];
                let leaf_slot = if obl {
                    let mut idx = 0usize;
                    r.seek(internal_off);
                    for _ in 0..d {
                        let fr = r.read(self.w_f) as usize;
                        let tr = r.read(self.w_t) as usize;
                        let (f, enc, count) = self.p.map[fr];
                        let next = r.bit_pos();
                        let thr = self.threshold(fr, tr.min(count - 1));
                        nodes += 1;
                        bits += (self.w_f + self.w_t + enc.width_bits()) as usize;
                        idx = 2 * idx + usize::from(!(x[f] <= thr));
                        r.seek(next);
                    }
                    idx
                } else {
                    let n_internal = (1usize << d) - 1;
                    let mut i = 0usize;
                    while i < n_internal {
                        r.seek(internal_off + i * (self.w_f + self.w_t) as usize);
                        let fr = r.read(self.w_f) as usize;
                        let tr = r.read(self.w_t) as usize;
                        let (f, enc, count) = self.p.map[fr];
                        let thr = self.threshold(fr, tr.min(count - 1));
                        nodes += 1;
                        bits += (self.w_f + self.w_t + enc.width_bits()) as usize;
                        i = if x[f] <= thr { 2 * i + 1 } else { 2 * i + 2 };
                    }
                    i - n_internal
                };
                r.seek(leaf_off + leaf_slot * self.w_l as usize);
                let _ = r.read(self.w_l);
                bits += self.w_l as usize + 32;
                nodes += 1;
            }
        }
        (nodes, bits)
    }
}

#[cfg(test)]
#[cfg(not(miri))] // trains models / generates datasets - too slow under the Miri interpreter
mod tests {
    use super::*;
    use crate::data::synth::PaperDataset;
    use crate::data::train_test_split;
    use crate::gbdt::{self, GbdtParams};

    fn trained(ds: PaperDataset, rounds: usize, depth: usize) -> (GbdtModel, crate::data::Dataset) {
        let data = ds.generate(11);
        let n = data.n_rows().min(1500);
        let data = data.select(&(0..n).collect::<Vec<_>>());
        let (train_set, test_set) = train_test_split(&data, 0.2, 1);
        let model = gbdt::booster::train(&train_set, GbdtParams::paper(rounds, depth));
        (model, test_set)
    }

    #[test]
    fn roundtrip_predictions_exact_without_f16() {
        let (model, test) = trained(PaperDataset::BreastCancer, 12, 3);
        let finfo = FeatureInfo::from_dataset(&test);
        let opts = EncodeOptions { allow_f16: false, ..Default::default() };
        let bytes = encode(&model, &finfo, &opts).unwrap();
        let decoded = decode(&bytes);
        for i in 0..test.n_rows() {
            let x = test.row(i);
            let a = model.predict_raw(&x);
            let b = decoded.predict_raw(&x);
            for (p, q) in a.iter().zip(&b) {
                assert!((p - q).abs() < 1e-5, "row {i}: {p} vs {q}");
            }
        }
    }

    #[test]
    fn size_model_matches_encoded_length() {
        for (ds, rounds, depth) in [
            (PaperDataset::BreastCancer, 8, 2),
            (PaperDataset::Mushroom, 6, 3),
            (PaperDataset::Kin8nm, 10, 2),
        ] {
            let (model, test) = trained(ds, rounds, depth);
            let finfo = FeatureInfo::from_dataset(&test);
            for opts in [
                EncodeOptions { allow_f16: false, ..Default::default() },
                EncodeOptions { allow_f16: true, ..Default::default() },
            ] {
                let bytes = encode(&model, &finfo, &opts).unwrap();
                let bd = size_breakdown(&model, &finfo, &opts);
                assert_eq!(bd.total_bytes(), bytes.len(), "{:?}", ds);
            }
        }
    }

    #[test]
    fn packed_model_matches_decoded() {
        let (model, test) = trained(PaperDataset::Mushroom, 10, 3);
        let finfo = FeatureInfo::from_dataset(&test);
        let bytes = encode(&model, &finfo, &EncodeOptions::default()).unwrap();
        let decoded = decode(&bytes);
        let packed = PackedModel::from_bytes(bytes);
        for i in (0..test.n_rows()).step_by(7) {
            let x = test.row(i);
            let a = decoded.predict_raw(&x);
            let b = packed.predict_raw(&x);
            for (p, q) in a.iter().zip(&b) {
                assert!((p - q).abs() < 1e-6, "row {i}: {p} vs {q}");
            }
        }
    }

    #[test]
    fn integer_features_get_narrow_thresholds() {
        // kr-vs-kp is all-boolean: every threshold must be 1-bit.
        let (model, test) = trained(PaperDataset::KrVsKp, 8, 2);
        let finfo = FeatureInfo::from_dataset(&test);
        let bytes = encode(&model, &finfo, &EncodeOptions::default()).unwrap();
        let decoded = decode(&bytes);
        // Accuracy preserved through 1-bit thresholds.
        let a = model.score(&test);
        let b = decoded.score(&test);
        assert!((a - b).abs() < 1e-9, "accuracy changed: {a} vs {b}");
        // And the thresholds section must be tiny: <= |F_U| * maxT bits.
        let bd = size_breakdown(&model, &finfo, &EncodeOptions::default());
        let stats = crate::toad::ReuseStats::from_model(&model);
        assert!(
            bd.thresholds_bits <= stats.n_thresholds,
            "boolean thresholds must be 1 bit each: {} > {}",
            bd.thresholds_bits,
            stats.n_thresholds,
        );
    }

    #[test]
    fn f16_thresholds_keep_score() {
        let (model, test) = trained(PaperDataset::CaliforniaHousing, 16, 3);
        let finfo = FeatureInfo::from_dataset(&test);
        let no_f16 = EncodeOptions { allow_f16: false, ..Default::default() };
        let with_f16 = EncodeOptions { allow_f16: true, ..Default::default() };
        let exact = decode(&encode(&model, &finfo, &no_f16).unwrap());
        let lossy = decode(&encode(&model, &finfo, &with_f16).unwrap());
        let a = exact.score(&test);
        let b = lossy.score(&test);
        assert!((a - b).abs() < 0.02, "f16 thresholds moved R² too much: {a} vs {b}");
    }

    #[test]
    fn multiclass_roundtrip() {
        let (model, test) = trained(PaperDataset::WineQuality, 6, 2);
        let finfo = FeatureInfo::from_dataset(&test);
        let opts = EncodeOptions { allow_f16: false, ..Default::default() };
        let bytes = encode(&model, &finfo, &opts).unwrap();
        let decoded = decode(&bytes);
        assert_eq!(decoded.n_outputs(), 7);
        for i in (0..test.n_rows()).step_by(11) {
            let x = test.row(i);
            assert_eq!(model.predict_class(&x), decoded.predict_class(&x));
        }
    }

    #[test]
    fn bare_leaf_ensemble_roundtrip() {
        let data = PaperDataset::Kin8nm.generate(3).select(&(0..200).collect::<Vec<_>>());
        let model = gbdt::booster::train(&data, GbdtParams::paper(3, 0));
        let finfo = FeatureInfo::from_dataset(&data);
        let bytes = encode(&model, &finfo, &EncodeOptions::default()).unwrap();
        let decoded = decode(&bytes);
        let x = data.row(0);
        assert!((model.predict_value(&x) - decoded.predict_value(&x)).abs() < 1e-6);
        // No features used: layout is header + leaves + tiny trees.
        let bd = size_breakdown(&model, &finfo, &EncodeOptions::default());
        assert_eq!(bd.map_bits, 0);
        assert_eq!(bd.thresholds_bits, 0);
    }

    #[test]
    fn validate_accepts_every_encoded_model() {
        for (ds, rounds, depth) in [
            (PaperDataset::BreastCancer, 8, 2),
            (PaperDataset::WineQuality, 4, 2),
            (PaperDataset::Kin8nm, 6, 3),
        ] {
            let (model, test) = trained(ds, rounds, depth);
            let finfo = FeatureInfo::from_dataset(&test);
            let bytes = encode(&model, &finfo, &EncodeOptions::default()).unwrap();
            let bits = validate_blob(&bytes).unwrap_or_else(|e| panic!("{:?}: {e}", ds));
            assert!(bits <= bytes.len() * 8);
            assert!(bits + 8 > bytes.len() * 8, "no trailing garbage allowed");
            try_decode(&bytes).unwrap();
        }
    }

    #[test]
    fn validate_rejects_garbage_and_truncation() {
        // Random bytes: overwhelmingly rejected (never panics).
        let mut rng = crate::prng::Pcg64::new(0xBAD);
        for _ in 0..200 {
            let n = 1 + rng.gen_range(64);
            let bytes: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
            let _ = validate_blob(&bytes); // must not panic
        }
        // Truncating a valid blob must be caught.
        let (model, test) = trained(PaperDataset::BreastCancer, 8, 2);
        let finfo = FeatureInfo::from_dataset(&test);
        let bytes = encode(&model, &finfo, &EncodeOptions::default()).unwrap();
        for cut in [1usize, bytes.len() / 4, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                validate_blob(&bytes[..cut]).is_err(),
                "truncation at {cut}/{} must fail",
                bytes.len()
            );
        }
        assert!(try_decode(&bytes[..bytes.len() / 2]).is_err());
    }

    #[test]
    fn leaf_sharing_reduces_global_values() {
        let (model, test) = trained(PaperDataset::CaliforniaHousing, 24, 3);
        let finfo = FeatureInfo::from_dataset(&test);
        let full = EncodeOptions::default();
        let shared = EncodeOptions { leaf_mantissa_bits: Some(8), ..Default::default() };
        let bd_full = size_breakdown(&model, &finfo, &full);
        let bd_shared = size_breakdown(&model, &finfo, &shared);
        assert!(
            bd_shared.leaf_values_bits < bd_full.leaf_values_bits,
            "mantissa truncation must merge leaf values: {} vs {}",
            bd_shared.leaf_values_bits,
            bd_full.leaf_values_bits
        );
        // Quality barely moves.
        let a = decode(&encode(&model, &finfo, &full).unwrap()).score(&test);
        let b = decode(&encode(&model, &finfo, &shared).unwrap()).score(&test);
        assert!((a - b).abs() < 0.02, "leaf sharing moved R² too far: {a} vs {b}");
        // Size model still exact under the option.
        let bytes = encode(&model, &finfo, &shared).unwrap();
        assert_eq!(bd_shared.total_bytes(), bytes.len());
    }

    #[test]
    fn leaf_sharing_zero_bits_collapses_to_exponent_grid() {
        let (model, test) = trained(PaperDataset::BreastCancer, 16, 2);
        let finfo = FeatureInfo::from_dataset(&test);
        let extreme = EncodeOptions { leaf_mantissa_bits: Some(0), ..Default::default() };
        let bytes = encode(&model, &finfo, &extreme).unwrap();
        let decoded = decode(&bytes);
        // Still a functioning (if coarse) model.
        let s = decoded.score(&test);
        assert!(s > 0.7, "0-mantissa leaves should still classify: {s}");
    }

    /// A left-leaning chain of `depth` internal nodes (depth = chain
    /// length), with distinct thresholds on feature 0.
    fn chain_tree(depth: usize) -> Tree {
        let mut nodes = Vec::new();
        for d in 0..depth {
            let idx = nodes.len();
            nodes.push(Node::Internal {
                feature: 0,
                bin: d as u16,
                threshold: d as f32 + 0.5,
                left: idx + 2,
                right: idx + 1,
            });
            nodes.push(Node::Leaf { value: d as f64 });
        }
        nodes.push(Node::Leaf { value: -1.0 });
        Tree { nodes }
    }

    fn wrap(trees: Vec<Vec<Tree>>, n_features: usize) -> GbdtModel {
        let n_outputs = trees.len();
        GbdtModel {
            objective: if n_outputs == 1 {
                Objective::L2
            } else {
                Objective::Softmax { n_classes: n_outputs }
            },
            base_scores: vec![0.0; n_outputs],
            trees,
            n_features,
            name: "width-test".into(),
        }
    }

    /// A complete depth-`depth` level-uniform (oblivious) tree: level
    /// `ℓ` splits on feature `ℓ % 2` at threshold `ℓ + 0.5`, and the
    /// 2^depth leaves hold their own slot index as the value.
    fn oblivious_tree(depth: usize) -> Tree {
        fn build(lvl: usize, depth: usize, leaf_base: f64, nodes: &mut Vec<Node>) -> usize {
            let idx = nodes.len();
            if lvl == depth {
                nodes.push(Node::Leaf { value: leaf_base });
                return idx;
            }
            nodes.push(Node::Leaf { value: 0.0 }); // placeholder
            let stride = (1usize << (depth - lvl - 1)) as f64;
            let left = build(lvl + 1, depth, leaf_base, nodes);
            let right = build(lvl + 1, depth, leaf_base + stride, nodes);
            nodes[idx] = Node::Internal {
                feature: lvl % 2,
                bin: lvl as u16,
                threshold: lvl as f32 + 0.5,
                left,
                right,
            };
            idx
        }
        let mut nodes = Vec::new();
        build(0, depth, 0.0, &mut nodes);
        Tree { nodes }
    }

    #[test]
    fn oblivious_trees_roundtrip_through_all_decoders() {
        let model = wrap(vec![vec![oblivious_tree(1), oblivious_tree(2), oblivious_tree(3)]], 2);
        let finfo = [FeatureInfo::generic_float(), FeatureInfo::generic_float()];
        let opts = EncodeOptions { allow_f16: false, ..Default::default() };
        let bytes = encode(&model, &finfo, &opts).unwrap();

        let bd = size_breakdown(&model, &finfo, &opts);
        assert_eq!(bd.total_bytes(), bytes.len(), "size model must stay exact");
        let bits = validate_blob(&bytes).unwrap();
        assert!(bits + 8 > bytes.len() * 8, "no trailing garbage allowed");

        let decoded = try_decode(&bytes).unwrap();
        let packed = PackedModel::from_bytes(bytes);
        assert_eq!(packed.n_oblivious_trees(), 3);
        let probe = [-1.0f32, 0.7, 1.5, 2.6, f32::NAN];
        for &a in &probe {
            for &b in &probe {
                let x = [a, b];
                let want = model.predict_raw(&x);
                let dec = decoded.predict_raw(&x);
                let pck = packed.predict_raw(&x);
                assert_eq!(want, dec, "decode mismatch at {x:?}");
                assert_eq!(want, pck, "packed mismatch at {x:?}");
            }
        }
    }

    #[test]
    fn oblivious_body_is_smaller_than_general() {
        // The same depth-3 shape with one slot perturbed loses level
        // uniformity and must fall back to the 2^d − 1 general body.
        let obl = oblivious_tree(3);
        let mut perturbed = oblivious_tree(3);
        for n in perturbed.nodes.iter_mut() {
            if let Node::Internal { feature, bin, threshold, .. } = n {
                if *bin == 2 {
                    *feature = 0;
                    *bin = 4;
                    *threshold = 4.5;
                    break;
                }
            }
        }
        assert!(perturbed.oblivious_levels().is_none());
        let finfo = [FeatureInfo::generic_float(), FeatureInfo::generic_float()];
        let opts = EncodeOptions { allow_f16: false, ..Default::default() };
        let m_obl = wrap(vec![vec![obl]], 2);
        let m_gen = wrap(vec![vec![perturbed]], 2);
        let bd_obl = size_breakdown(&m_obl, &finfo, &opts);
        let bd_gen = size_breakdown(&m_gen, &finfo, &opts);
        assert!(
            bd_obl.trees_bits < bd_gen.trees_bits,
            "oblivious body must be smaller: {} vs {}",
            bd_obl.trees_bits,
            bd_gen.trees_bits
        );
        // Both stay byte-exact against the real encoding.
        for (m, bd) in [(&m_obl, bd_obl), (&m_gen, bd_gen)] {
            let bytes = encode(m, &finfo, &opts).unwrap();
            assert_eq!(bd.total_bytes(), bytes.len());
        }
    }

    #[test]
    fn mixed_ensemble_roundtrips_and_reports_per_tree_bits() {
        // Oblivious + general + bare-leaf trees in one blob.
        let model =
            wrap(vec![vec![oblivious_tree(2), chain_tree(3), Tree::leaf(0.25)]], 2);
        let finfo = [FeatureInfo::generic_float(), FeatureInfo::generic_float()];
        let opts = EncodeOptions { allow_f16: false, ..Default::default() };
        let bytes = encode(&model, &finfo, &opts).unwrap();
        let bd = size_breakdown(&model, &finfo, &opts);
        assert_eq!(bd.total_bytes(), bytes.len());
        validate_blob(&bytes).unwrap();

        let decoded = try_decode(&bytes).unwrap();
        let packed = PackedModel::from_bytes(bytes);
        assert_eq!(packed.n_oblivious_trees(), 1);
        // Measured per-tree bits must sum to the size model's component.
        let measured: usize = (0..packed.n_trees()).map(|i| packed.tree_bits(i)).sum();
        assert_eq!(measured, bd.trees_bits);
        let probe = [-1.0f32, 0.7, 1.5, 2.6, 3.5];
        for &a in &probe {
            for &b in &probe {
                let x = [a, b];
                assert_eq!(model.predict_raw(&x), decoded.predict_raw(&x));
                assert_eq!(model.predict_raw(&x), packed.predict_raw(&x));
            }
        }
        // trace_row on the oblivious tree counts d levels + 1 leaf.
        let (nodes, bits) = packed.trace_row(&[0.0, 0.0]);
        assert!(nodes >= 3 && bits > 0);
    }

    #[test]
    fn too_deep_model_errors_instead_of_truncating() {
        // W_DEPTH = 4 stores depths 0..=15. A depth-16 tree used to
        // pack `16 & 0xF == 0` — a silently corrupt blob. It must now
        // be a hard error, in debug and release alike.
        let finfo = [FeatureInfo::generic_float()];
        let ok = wrap(vec![vec![chain_tree(15)]], 1);
        let bad = wrap(vec![vec![chain_tree(16)]], 1);
        let opts = EncodeOptions { allow_f16: false, ..Default::default() };
        encode(&ok, &finfo, &opts).expect("depth 15 is the last encodable depth");
        let err = encode(&bad, &finfo, &opts).unwrap_err().to_string();
        assert!(err.contains("depth"), "error must name the offending field: {err}");
        assert!(err.contains("16"), "error must include the offending value: {err}");
    }

    #[test]
    fn too_many_outputs_error_instead_of_truncating() {
        // W_OUTPUTS = 8: 256 output streams cannot be encoded.
        let streams: Vec<Vec<Tree>> = (0..256).map(|k| vec![Tree::leaf(k as f64)]).collect();
        let model = wrap(streams, 1);
        let err = encode(&model, &[FeatureInfo::generic_float()], &EncodeOptions::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("n_outputs"), "error must name the field: {err}");
    }

    #[test]
    fn trace_row_counts_nodes() {
        let (model, test) = trained(PaperDataset::BreastCancer, 4, 2);
        let finfo = FeatureInfo::from_dataset(&test);
        let bytes = encode(&model, &finfo, &EncodeOptions::default()).unwrap();
        let packed = PackedModel::from_bytes(bytes);
        let (nodes, bits) = packed.trace_row(&test.row(0));
        // 4 trees × (≤2 internal + 1 leaf) visits.
        assert!(nodes >= 4 && nodes <= 12, "nodes={nodes}");
        assert!(bits > 0);
    }
}
