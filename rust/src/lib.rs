//! # Trees on a Diet (ToaD)
//!
//! A reproduction of *"Boosted Trees on a Diet: Compact Models for
//! Resource-Constrained Devices"* (Herrmann et al., 2025) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! The crate contains:
//!
//! * a from-scratch histogram-based GBDT trainer ([`gbdt`]) equivalent in
//!   objective and growth strategy to LightGBM (the paper's substrate),
//! * the ToaD training extension ([`toad`]): feature/threshold *reuse
//!   penalties* folded into the split gain, and memory-budget-bounded
//!   training (`toad_forestsize`),
//! * the ToaD bit-wise memory layout ([`layout`]): pointer-less
//!   complete-tree arrays referencing global threshold/leaf tables,
//! * native inference engines ([`inference`]): the flattened SoA batch
//!   engine (`FlatModel`, branchless complete-tree descent + blocked
//!   `predict_batch`), its quantized-threshold sibling
//!   (`QuantizedFlatModel`, u16 threshold ranks over pre-binned rows
//!   with multi-row interleaved descent, plus a zero-gather columnar
//!   batch path over the shared `data::BinMatrix` bin arena) and a
//!   direct bit-packed interpreter (what an MCU would execute),
//! * explicit SIMD kernels with runtime CPU dispatch ([`simd`]):
//!   AVX2/SSE2 lane kernels (scalar fallback elsewhere) behind both
//!   hot paths — the quantized descent and histogram accumulation —
//!   selected once per process and bit-identical across tiers,
//! * every baseline the paper evaluates ([`baselines`]): CEGB, CCP,
//!   random forests, and Guo et al. ordering-based ensemble pruning,
//! * an XLA/PJRT runtime ([`runtime`], behind the `xla` cargo feature)
//!   that loads AOT-compiled JAX/Pallas artifacts (`artifacts/*.hlo.txt`)
//!   for batched serving,
//! * an IoT fleet coordinator ([`coordinator`]): simulated
//!   memory-constrained devices, a deployment planner, a versioned model
//!   registry with atomic hot-swap, and a concurrent serving front door
//!   (`&self` submit, bounded-queue batching with backpressure,
//!   per-version latency metrics),
//! * a microcontroller cycle-cost model ([`mcu`]) reproducing the paper's
//!   Table 2 latency comparison, and
//! * the experiment sweep harness ([`sweep`]) regenerating every figure
//!   and table of the paper's evaluation.
//!
//! See `DESIGN.md` for the system inventory and per-experiment index, and
//! `EXPERIMENTS.md` for measured results. See the README's "Correctness
//! tooling" section for the loom/Miri/sanitizer/fuzz verification layer
//! and the unsafe-hygiene policy this crate enforces.

// Unsafe-hygiene gate: the only module allowed to contain `unsafe` is
// `simd` (vendor intrinsics and the width-punning kernels behind the
// runtime tier dispatch) — see the allow on its declaration below.
// Everything else is safe Rust by construction, and CI's clippy job
// additionally requires a `// SAFETY:` contract on every unsafe block
// (`-D clippy::undocumented_unsafe_blocks`).
#![deny(unsafe_code)]

pub mod baselines;
pub mod bitio;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod export;
pub mod gbdt;
pub mod inference;
pub mod layout;
pub mod mcu;
pub mod metrics;
pub mod prng;
pub mod runtime;
// The single crate-wide exemption from `#![deny(unsafe_code)]`: all
// intrinsics and raw-pointer kernels live here, behind tier checks.
#[allow(unsafe_code)]
pub mod simd;
pub mod sweep;
pub mod sync;
pub mod testutil;
pub mod toad;
