//! `toad` — train, size, and serve compact boosted tree ensembles.
//!
//! ```text
//! toad datasets                                    # Table 1
//! toad train   --dataset breastcancer --rounds 32 --depth 2 \
//!              [--iota 2] [--xi 1] [--forestsize 1024] [--oblivious] \
//!              [--workers K] [--out-of-core [--row-block N]] \
//!              [--out model.toad]
//! toad train   --libsvm data.svm [--task regression|binary|multiclass:K] \
//!              --rounds 32 --depth 2     # sparse CSR pipeline end to end
//! toad size    --model model.toad                  # layout breakdown
//! toad predict --model model.toad --dataset breastcancer [--n 10]
//! toad bench-inference --dataset covtype_binary    # packed vs decoded
//! ```

use toad::cli::{dataset_by_name, Args};
use toad::data::{train_test_split, train_test_split_sparse, Task};
use toad::gbdt::GbdtParams;
use toad::layout::{self, toad_format::size_breakdown, EncodeOptions, FeatureInfo, PackedModel};
use toad::sweep::table;
use toad::toad::{train_toad, train_toad_with_budget, ToadParams};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match args.command.as_str() {
        "datasets" => cmd_datasets(),
        "train" => cmd_train(&args),
        "size" => cmd_size(&args),
        "predict" => cmd_predict(&args),
        "sweep" => cmd_sweep(&args),
        "export-c" => cmd_export_c(&args),
        "" | "help" | "--help" => {
            print!("{}", HELP);
            0
        }
        other => {
            eprintln!("unknown command `{other}`\n{HELP}");
            2
        }
    };
    std::process::exit(code);
}

const HELP: &str = "\
toad — Trees on a Diet (paper reproduction)

commands:
  datasets               print the Table 1 dataset inventory
  train                  train a ToaD model (see flags in main.rs docs);
                         --oblivious grows CatBoost-style level-shared trees;
                         --workers K row-shards histogram builds over K threads;
                         --out-of-core streams bins through an on-disk arena
                         (--row-block N rows per block, default 65536);
                         --libsvm F trains on a sparse libsvm/svmlight file
                         (--task regression|binary|multiclass:K, default
                         regression) through the nnz-scaled CSR pipeline
  size                   print the layout size breakdown of a .toad blob
  predict                run a saved model over a synthetic dataset
  sweep                  run a penalty sweep: --dataset D [--kind feature|threshold]
                         [--rounds N] [--depth D] (Figure 6-style table);
                         --libsvm F [--libsvm-test F2] sweeps a sparse
                         dataset through the CSR trainer + sparse scorer
  export-c               generate a self-contained C99 file from a blob:
                         --model model.toad --out model.c [--outputs N --features D]
  help                   this text
";

fn cmd_datasets() -> i32 {
    use toad::data::synth::PaperDataset;
    let rows: Vec<Vec<String>> = PaperDataset::TABLE1
        .iter()
        .map(|ds| {
            vec![
                ds.name().to_string(),
                format!("{}", ds.paper_rows()),
                format!("{}", ds.gen_rows()),
                format!("{}", ds.n_features()),
                format!("{:?}", ds.task()),
            ]
        })
        .collect();
    print!(
        "{}",
        table::render(&["dataset", "paper_rows", "gen_rows", "features", "task"], &rows)
    );
    0
}

/// `--task regression|binary|multiclass:K` (default regression) — the
/// label convention a libsvm file should be read under.
fn parse_task(args: &Args) -> Result<Task, String> {
    let spec = args.get_or("task", "regression");
    match spec.as_str() {
        "regression" => Ok(Task::Regression),
        "binary" => Ok(Task::Binary),
        other => match other.strip_prefix("multiclass:") {
            Some(kstr) => {
                let k: usize = kstr
                    .parse()
                    .map_err(|_| format!("--task: invalid class count `{kstr}`"))?;
                if k < 2 {
                    return Err("--task multiclass:K needs K >= 2".into());
                }
                Ok(Task::Multiclass(k))
            }
            None => Err(format!("--task must be regression|binary|multiclass:K, got `{other}`")),
        },
    }
}

/// `train --libsvm <path>`: train on a sparse libsvm/svmlight file
/// through the CSR pipeline — sparse binning, the nnz-scaled histogram
/// kernel, and sparse columnar scoring; no dense float matrix is ever
/// materialized.
fn cmd_train_libsvm(args: &Args, path: &str) -> i32 {
    let run = || -> Result<i32, String> {
        let rounds = args.get_usize("rounds", 32)?;
        let depth = args.get_usize("depth", 2)?;
        let seed = args.get_usize("seed", 1)? as u64;
        let task = parse_task(args)?;
        let data = toad::data::csv::read_libsvm(std::path::Path::new(path), path, task)
            .map_err(|e| e.to_string())?;
        let (train_set, test_set) = train_test_split_sparse(&data, 0.2, seed);
        let mut gbdt = GbdtParams::paper(rounds, depth);
        if args.get_bool("oblivious") {
            gbdt.growth = toad::gbdt::GrowthMode::Oblivious;
        }
        gbdt.row_workers = args.get_usize("workers", 0)?;
        let model = toad::gbdt::train_sparse(&train_set, gbdt);
        let score = model.quantize().score_sparse(&test_set);
        println!(
            "{path}: rows={} features={} density={:.4} score={score:.4} trees={}",
            data.n_rows(),
            data.n_features(),
            data.x.density(),
            model.n_trees(),
        );
        Ok(0)
    };
    run().unwrap_or_else(|e| {
        eprintln!("error: {e}");
        2
    })
}

fn cmd_train(args: &Args) -> i32 {
    if let Some(path) = args.get("libsvm") {
        return cmd_train_libsvm(args, path);
    }
    let name = args.get_or("dataset", "breastcancer");
    let Some(ds) = dataset_by_name(&name) else {
        eprintln!("unknown dataset `{name}`");
        return 2;
    };
    let run = || -> Result<i32, String> {
        let rounds = args.get_usize("rounds", 32)?;
        let depth = args.get_usize("depth", 2)?;
        let iota = args.get_f64("iota", 0.0)?;
        let xi = args.get_f64("xi", 0.0)?;
        let seed = args.get_usize("seed", 1)? as u64;
        let data = ds.generate(seed);
        let (train_set, test_set) = train_test_split(&data, 0.2, seed);
        let mut gbdt = GbdtParams::paper(rounds, depth);
        if args.get_bool("oblivious") {
            gbdt.growth = toad::gbdt::GrowthMode::Oblivious;
        }
        gbdt.row_workers = args.get_usize("workers", 0)?;
        if args.get_bool("out-of-core") {
            // Plain GBDT trained from an on-disk arena streamed in row
            // blocks — the penalty/budget machinery stays in-RAM only.
            let block = args.get_usize("row-block", 65_536)?;
            if block == 0 {
                return Err("--row-block must be positive".into());
            }
            let arena = std::env::temp_dir().join(format!("toad-arena-{}.bin", std::process::id()));
            let n = train_set.n_rows();
            let (binner, chunked) = toad::data::binning::Binner::fit_transform_to_disk(
                &arena,
                n,
                train_set.n_features(),
                gbdt.max_bins,
                block,
                |range| {
                    train_set
                        .features
                        .iter()
                        .map(|col| col[range.clone()].to_vec())
                        .collect::<Vec<Vec<f32>>>()
                },
            )
            .map_err(|e| e.to_string())?;
            let model = toad::gbdt::booster::train_chunked(
                binner,
                chunked,
                train_set.targets.clone(),
                train_set.labels.clone(),
                train_set.task,
                &train_set.name,
                gbdt,
            );
            let _ = std::fs::remove_file(&arena);
            let score = model.score(&test_set);
            println!(
                "{name} (out-of-core, block={block}, workers={}): score={score:.4} trees={}",
                gbdt.row_workers,
                model.n_trees(),
            );
            return Ok(0);
        }
        let mut params = ToadParams::new(gbdt, iota, xi);
        let model = if let Some(fs) = args.get("forestsize") {
            params.forestsize_bytes =
                Some(fs.parse().map_err(|_| "--forestsize: invalid".to_string())?);
            train_toad_with_budget(&train_set, &params)
        } else {
            train_toad(&train_set, &params)
        };
        let score = model.model.score(&test_set);
        println!(
            "{}: score={score:.4} size={} trees={} |F_U|={} thresholds={} ReF={:.2}",
            name,
            table::human_bytes(model.size_bytes()),
            model.model.n_trees(),
            model.stats.n_features_used,
            model.stats.n_thresholds,
            model.reuse_factor(),
        );
        if args.get_bool("oblivious") {
            let packed = PackedModel::from_bytes(model.blob.clone());
            println!(
                "oblivious trees: {}/{} (level-shared splits, 2^d leaf tables)",
                packed.n_oblivious_trees(),
                packed.n_trees(),
            );
        }
        if let Some(out) = args.get("out") {
            std::fs::write(out, &model.blob).map_err(|e| e.to_string())?;
            println!("wrote {out} ({} bytes)", model.blob.len());
        }
        Ok(0)
    };
    run().unwrap_or_else(|e| {
        eprintln!("error: {e}");
        2
    })
}

fn cmd_size(args: &Args) -> i32 {
    let Some(path) = args.get("model") else {
        eprintln!("--model required");
        return 2;
    };
    let Ok(blob) = std::fs::read(path) else {
        eprintln!("cannot read {path}");
        return 2;
    };
    let model = layout::decode(&blob);
    // Re-derive a breakdown from the decoded model (generic float info).
    let finfo = vec![FeatureInfo::generic_float(); model.n_features];
    let bd = size_breakdown(&model, &finfo, &EncodeOptions::default());
    println!("blob:        {} bytes", blob.len());
    println!("header:      {} bits", bd.header_bits);
    println!("map:         {} bits", bd.map_bits);
    println!("thresholds:  {} bits", bd.thresholds_bits);
    println!("leaf values: {} bits", bd.leaf_values_bits);
    println!("trees:       {} bits", bd.trees_bits);
    println!(
        "pointer layout would be: {} bytes ({}x)",
        layout::baseline::pointer_f32_bytes(&model),
        layout::baseline::pointer_f32_bytes(&model) / blob.len().max(1)
    );
    0
}

/// The `sweep --libsvm` rows: load (and, with `--libsvm-test`, align)
/// sparse train/test sets, then run the univariate grid through the
/// CSR trainer and sparse columnar scorer.
fn sweep_rows_libsvm(
    args: &Args,
    path: &str,
    kind: toad::sweep::figures::PenaltyKind,
    values: &[f64],
    rounds: usize,
    depth: usize,
) -> Result<Vec<toad::sweep::figures::UniRow>, String> {
    use toad::sweep::figures::univariate_rows_sparse;
    let task = parse_task(args)?;
    let seed = args.get_usize("seed", 1)? as u64;
    let train =
        toad::data::csv::read_libsvm(std::path::Path::new(path), path, task).map_err(|e| e.to_string())?;
    if let Some(tpath) = args.get("libsvm-test") {
        let mut train = train;
        let mut test = toad::data::csv::read_libsvm(std::path::Path::new(tpath), tpath, task)
            .map_err(|e| e.to_string())?;
        // The two files may mention different max feature indices;
        // widen both to the common feature space before training.
        let nf = train.n_features().max(test.n_features());
        train.pad_features(nf)?;
        test.pad_features(nf)?;
        Ok(univariate_rows_sparse(&train, &test, kind, values, rounds, depth))
    } else {
        let (tr, te) = train_test_split_sparse(&train, 0.2, seed);
        Ok(univariate_rows_sparse(&tr, &te, kind, values, rounds, depth))
    }
}

fn cmd_sweep(args: &Args) -> i32 {
    use toad::sweep::figures::{univariate_rows, PenaltyKind};
    let kind = match args.get_or("kind", "threshold").as_str() {
        "feature" => PenaltyKind::Feature,
        "threshold" => PenaltyKind::Threshold,
        other => {
            eprintln!("--kind must be feature|threshold, got `{other}`");
            return 2;
        }
    };
    let rounds = args.get_usize("rounds", 64).unwrap_or(64);
    let depth = args.get_usize("depth", 2).unwrap_or(2);
    let values: Vec<f64> = (-4..=15).step_by(2).map(|e| 2f64.powi(e)).collect();
    let rows = if let Some(path) = args.get("libsvm") {
        match sweep_rows_libsvm(args, path, kind, &values, rounds, depth) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("error: {e}");
                return 2;
            }
        }
    } else {
        let name = args.get_or("dataset", "breastcancer");
        let Some(ds) = dataset_by_name(&name) else {
            eprintln!("unknown dataset `{name}`");
            return 2;
        };
        univariate_rows(ds, 1, kind, &values, rounds, depth, 4000)
    };
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("{}", r.penalty),
                format!("{:.4}", r.score),
                format!("{}", r.n_features),
                format!("{}", r.n_global_values),
                format!("{:.2}", r.reuse_factor),
            ]
        })
        .collect();
    print!(
        "{}",
        table::render(&["penalty", "score", "features", "global_values", "ReF"], &table)
    );
    0
}

fn cmd_export_c(args: &Args) -> i32 {
    let Some(path) = args.get("model") else {
        eprintln!("--model required");
        return 2;
    };
    let out_path = args.get_or("out", "model.c");
    let Ok(blob) = std::fs::read(path) else {
        eprintln!("cannot read {path}");
        return 2;
    };
    // Outputs/features can be read off the decoded model when omitted.
    let decoded = match toad::layout::toad_format::try_decode(&blob) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("invalid blob: {e}");
            return 2;
        }
    };
    let n_outputs = args.get_usize("outputs", decoded.n_outputs()).unwrap_or(1);
    let n_features = args.get_usize("features", decoded.n_features).unwrap_or(1);
    match toad::export::export_c(&blob, n_outputs, n_features) {
        Ok(c) => {
            if std::fs::write(&out_path, &c).is_err() {
                eprintln!("cannot write {out_path}");
                return 2;
            }
            println!("wrote {out_path} ({} bytes of C, {} byte blob)", c.len(), blob.len());
            0
        }
        Err(e) => {
            eprintln!("export failed: {e}");
            2
        }
    }
}

fn cmd_predict(args: &Args) -> i32 {
    let Some(path) = args.get("model") else {
        eprintln!("--model required");
        return 2;
    };
    let name = args.get_or("dataset", "breastcancer");
    let Some(ds) = dataset_by_name(&name) else {
        eprintln!("unknown dataset `{name}`");
        return 2;
    };
    let n = args.get_usize("n", 5).unwrap_or(5);
    let Ok(blob) = std::fs::read(path) else {
        eprintln!("cannot read {path}");
        return 2;
    };
    let packed = PackedModel::from_bytes(blob);
    let data = ds.generate(1);
    for i in 0..n.min(data.n_rows()) {
        let x = data.row(i);
        let raw = packed.predict_raw(&x);
        println!("row {i}: raw={raw:?}");
    }
    0
}
