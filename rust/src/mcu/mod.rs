//! Microcontroller cost model — the Table 2 / Appendix E.1 substitute.
//!
//! The paper measures per-prediction latency of a ToaD prototype and a
//! LightGBM export on two physical boards (XIAO ESP32-S3, Arduino Nano
//! 33 BLE) and finds ToaD ~5–8× slower due to bit-extraction overhead.
//! No boards exist in this environment, so this module provides a
//! deterministic **cycle-cost model** of a Cortex-M-class core and
//! derives latencies from instruction-level accounting of the two
//! inference loops (DESIGN.md §5):
//!
//! * pointer layout: per node — two word loads (feature id, threshold),
//!   a float compare and a branch, plus the child-pointer load;
//! * ToaD layout: per node — bit-offset arithmetic, two cross-byte bit
//!   extractions (feature ref, threshold index), a Feature & Threshold
//!   Map lookup, the threshold's bit extraction and numeric conversion,
//!   then the same compare/branch.
//!
//! The constants below are calibrated to Cortex-M4-class timing (flash
//! wait states folded into load costs) and land in the paper's observed
//! slowdown band without being fit to its exact numbers.

use crate::layout::PackedModel;

/// A microcontroller profile.
#[derive(Clone, Copy, Debug)]
pub struct McuSpec {
    pub name: &'static str,
    pub clock_hz: f64,
    /// Cycles for a 32-bit word load from flash (incl. wait states).
    pub c_load: f64,
    /// Cycles for an ALU op (shift/mask/add).
    pub c_alu: f64,
    /// Cycles for a float compare on the FPU (or soft-float multiple).
    pub c_fcmp: f64,
    /// Cycles for a (possibly mispredicted) branch.
    pub c_branch: f64,
}

/// Seeed XIAO ESP32-S3 (LX7 @ 240 MHz, fast flash cache).
pub const ESP32_S3: McuSpec =
    McuSpec { name: "XIAO ESP32S3", clock_hz: 240e6, c_load: 3.0, c_alu: 1.0, c_fcmp: 1.0, c_branch: 3.0 };

/// Arduino Nano 33 BLE (nRF52840, Cortex-M4F @ 64 MHz).
pub const NANO_33_BLE: McuSpec =
    McuSpec { name: "Arduino Nano 33 BLE", clock_hz: 64e6, c_load: 2.0, c_alu: 1.0, c_fcmp: 1.0, c_branch: 2.0 };

/// Arduino Uno R4 Minima (RA4M1, Cortex-M4 @ 48 MHz) — the paper's
/// motivating 32 KB-RAM device.
pub const UNO_R4: McuSpec =
    McuSpec { name: "Arduino Uno R4", clock_hz: 48e6, c_load: 2.0, c_alu: 1.0, c_fcmp: 1.0, c_branch: 2.0 };

impl McuSpec {
    /// Cycles to extract a `width`-bit field at an arbitrary bit offset:
    /// offset arithmetic, up to ⌈width/8⌉+1 byte loads, shifts + masks.
    fn bit_extract_cycles(&self, width: f64) -> f64 {
        let byte_loads = (width / 8.0).ceil() + 1.0;
        3.0 * self.c_alu + byte_loads * self.c_load + 2.0 * self.c_alu
    }

    /// Cycles per *internal node* of the direct bit-packed interpreter.
    ///
    /// `w_f`, `w_t`, `w_thr` are the bit widths of the feature
    /// reference, threshold index, and threshold value.
    pub fn toad_node_cycles(&self, w_f: f64, w_t: f64, w_thr: f64) -> f64 {
        let offset_calc = 4.0 * self.c_alu; // node index -> bit offset
        let feat_ref = self.bit_extract_cycles(w_f);
        let thr_idx = self.bit_extract_cycles(w_t);
        let map_lookup = 2.0 * self.c_load + 2.0 * self.c_alu; // F&T map entry
        let thr_offset = 3.0 * self.c_alu; // per-feature base + idx*width
        let thr_extract = self.bit_extract_cycles(w_thr);
        let convert = 2.0 * self.c_alu; // int widen / f16 -> f32
        let cmp_branch = self.c_fcmp + self.c_branch + 2.0 * self.c_alu;
        offset_calc + feat_ref + thr_idx + map_lookup + thr_offset + thr_extract + convert
            + cmp_branch
    }

    /// Cycles per *level* of the oblivious lookup-descent interpreter.
    ///
    /// Oblivious trees store their d (feature-ref, threshold-ref)
    /// records sequentially, so the bit cursor just advances — no
    /// per-node offset recomputation. Each level extracts one pair,
    /// resolves the threshold through the F&T map, compares, and
    /// shifts the outcome bit into the leaf index; the compare feeds
    /// a shift/or instead of a data-dependent branch.
    pub fn oblivious_level_cycles(&self, w_f: f64, w_t: f64, w_thr: f64) -> f64 {
        let feat_ref = self.bit_extract_cycles(w_f);
        let thr_idx = self.bit_extract_cycles(w_t);
        let map_lookup = 2.0 * self.c_load + 2.0 * self.c_alu; // F&T map entry
        let thr_offset = 3.0 * self.c_alu; // per-feature base + idx*width
        let thr_extract = self.bit_extract_cycles(w_thr);
        let convert = 2.0 * self.c_alu; // int widen / f16 -> f32
        let cmp_shift = self.c_fcmp + 2.0 * self.c_alu; // idx = 2*idx + gt
        feat_ref + thr_idx + map_lookup + thr_offset + thr_extract + convert + cmp_shift
    }

    /// Cycles per internal node of a pointer/array float32 layout.
    pub fn pointer_node_cycles(&self) -> f64 {
        // load feature id, load threshold, load x[f], compare, branch,
        // child index arithmetic.
        3.0 * self.c_load + self.c_fcmp + self.c_branch + 2.0 * self.c_alu
    }

    /// Estimated seconds per prediction for a packed ToaD model,
    /// using the model's actual traversal trace on a probe row.
    pub fn toad_latency(&self, packed: &PackedModel, probe: &[f32]) -> f64 {
        let (nodes, bits) = packed.trace_row(probe);
        // Approximate per-node widths from the trace average.
        let avg_bits = bits as f64 / nodes.max(1) as f64;
        // Split the average: refs ~40%, threshold ~60% (see layout).
        let cycles = nodes as f64
            * self.toad_node_cycles(avg_bits * 0.2, avg_bits * 0.2, avg_bits * 0.6);
        cycles / self.clock_hz
    }

    /// Estimated seconds per prediction for a packed model whose trees
    /// use the oblivious sub-format (table-lookup descent).
    ///
    /// The trace counts one record per level; on top of the level
    /// cycles each tree pays one final 2^d leaf-table lookup (index
    /// scale plus a leaf-ref bit extraction).
    pub fn oblivious_latency(&self, packed: &PackedModel, probe: &[f32]) -> f64 {
        let (levels, bits) = packed.trace_row(probe);
        let avg_bits = bits as f64 / levels.max(1) as f64;
        let descent = levels as f64
            * self.oblivious_level_cycles(avg_bits * 0.2, avg_bits * 0.2, avg_bits * 0.6);
        let table_lookup = packed.n_trees() as f64
            * (2.0 * self.c_alu + self.bit_extract_cycles(avg_bits * 0.2));
        (descent + table_lookup) / self.clock_hz
    }

    /// Estimated seconds per prediction for the same tree structure in a
    /// pointer layout (`nodes_visited` from the packed trace).
    pub fn pointer_latency(&self, packed: &PackedModel, probe: &[f32]) -> f64 {
        let (nodes, _) = packed.trace_row(probe);
        nodes as f64 * self.pointer_node_cycles() / self.clock_hz
    }

    /// The ToaD/pointer slowdown factor for a model (paper: ~5–8×).
    pub fn slowdown(&self, packed: &PackedModel, probe: &[f32]) -> f64 {
        self.toad_latency(packed, probe) / self.pointer_latency(packed, probe)
    }
}

#[cfg(test)]
#[cfg(not(miri))] // trains models / generates datasets - too slow under the Miri interpreter
mod tests {
    use super::*;
    use crate::data::synth::PaperDataset;
    use crate::gbdt::{self, GbdtParams};
    use crate::layout::{encode, EncodeOptions, FeatureInfo};

    fn packed_model() -> (PackedModel, Vec<f32>) {
        let data =
            PaperDataset::CovertypeBinary.generate(51).select(&(0..3000).collect::<Vec<_>>());
        // Paper Table 2 config: four trees of depth four.
        let model = gbdt::booster::train(&data, GbdtParams::paper(4, 4));
        let finfo = FeatureInfo::from_dataset(&data);
        let blob = encode(&model, &finfo, &EncodeOptions::default()).unwrap();
        (PackedModel::from_bytes(blob), data.row(0))
    }

    #[test]
    fn slowdown_in_paper_band() {
        let (packed, probe) = packed_model();
        for spec in [ESP32_S3, NANO_33_BLE, UNO_R4] {
            let s = spec.slowdown(&packed, &probe);
            assert!(
                (3.0..=12.0).contains(&s),
                "{}: slowdown {s:.1} outside the plausible band",
                spec.name
            );
        }
    }

    #[test]
    fn absolute_latencies_are_sub_millisecond() {
        // Paper: 137 µs (ESP32) and 513 µs (Nano) per ToaD prediction.
        let (packed, probe) = packed_model();
        let esp = ESP32_S3.toad_latency(&packed, &probe);
        let nano = NANO_33_BLE.toad_latency(&packed, &probe);
        assert!(esp > 1e-6 && esp < 1e-3, "esp32 latency {esp}");
        assert!(nano > esp, "slower clock must be slower");
        assert!(nano < 2e-3, "nano latency {nano}");
    }

    #[test]
    fn faster_clock_is_faster() {
        let (packed, probe) = packed_model();
        assert!(ESP32_S3.toad_latency(&packed, &probe) < UNO_R4.toad_latency(&packed, &probe));
    }

    #[test]
    fn node_cycle_models_ordered() {
        for spec in [ESP32_S3, NANO_33_BLE] {
            assert!(
                spec.toad_node_cycles(4.0, 4.0, 16.0) > spec.pointer_node_cycles(),
                "bit extraction must cost more than word loads"
            );
            // Lookup descent drops the per-node offset arithmetic and
            // the data-dependent branch but keeps every bit extraction.
            let obl = spec.oblivious_level_cycles(4.0, 4.0, 16.0);
            assert!(obl < spec.toad_node_cycles(4.0, 4.0, 16.0), "{}: oblivious level must undercut the classic node", spec.name);
            assert!(obl > spec.pointer_node_cycles(), "{}: still dominated by bit extraction", spec.name);
        }
    }

    #[test]
    fn oblivious_latency_undercuts_classic_toad() {
        let data =
            PaperDataset::CovertypeBinary.generate(51).select(&(0..3000).collect::<Vec<_>>());
        let mut params = GbdtParams::paper(4, 4);
        params.growth = gbdt::GrowthMode::Oblivious;
        let model = gbdt::booster::train(&data, params);
        let finfo = FeatureInfo::from_dataset(&data);
        let blob = encode(&model, &finfo, &EncodeOptions::default()).unwrap();
        let packed = PackedModel::from_bytes(blob);
        assert!(packed.n_oblivious_trees() > 0, "oblivious growth must pack the sub-format");
        let probe = data.row(0);
        for spec in [ESP32_S3, NANO_33_BLE, UNO_R4] {
            let obl = spec.oblivious_latency(&packed, &probe);
            let toad = spec.toad_latency(&packed, &probe);
            assert!(obl > 0.0 && obl.is_finite(), "{}: latency {obl}", spec.name);
            assert!(obl < toad, "{}: lookup descent ({obl:.2e}s) must beat branchy descent ({toad:.2e}s)", spec.name);
        }
    }
}
