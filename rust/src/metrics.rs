//! Evaluation metrics used throughout the experiments.
//!
//! The paper reports **accuracy** for classification datasets and the
//! **R² score** for regression datasets (§4.1); log-loss and RMSE are
//! used internally for early stopping and debugging.

/// Classification accuracy from predicted labels.
pub fn accuracy(y_true: &[usize], y_pred: &[usize]) -> f64 {
    assert_eq!(y_true.len(), y_pred.len());
    if y_true.is_empty() {
        return 0.0;
    }
    let hits = y_true.iter().zip(y_pred).filter(|(a, b)| a == b).count();
    hits as f64 / y_true.len() as f64
}

/// Coefficient of determination R² = 1 − SS_res / SS_tot.
///
/// Returns 1.0 for a perfect fit; can be negative for models worse than
/// predicting the mean. If the targets are constant, returns 1.0 when the
/// predictions match exactly and 0.0 otherwise (scikit-learn convention).
pub fn r2_score(y_true: &[f64], y_pred: &[f64]) -> f64 {
    assert_eq!(y_true.len(), y_pred.len());
    assert!(!y_true.is_empty());
    let mean = y_true.iter().sum::<f64>() / y_true.len() as f64;
    let ss_tot: f64 = y_true.iter().map(|y| (y - mean) * (y - mean)).sum();
    let ss_res: f64 = y_true.iter().zip(y_pred).map(|(y, p)| (y - p) * (y - p)).sum();
    if ss_tot == 0.0 {
        return if ss_res == 0.0 { 1.0 } else { 0.0 };
    }
    1.0 - ss_res / ss_tot
}

/// Root mean squared error.
pub fn rmse(y_true: &[f64], y_pred: &[f64]) -> f64 {
    assert_eq!(y_true.len(), y_pred.len());
    assert!(!y_true.is_empty());
    let mse: f64 =
        y_true.iter().zip(y_pred).map(|(y, p)| (y - p) * (y - p)).sum::<f64>() / y_true.len() as f64;
    mse.sqrt()
}

/// Binary log-loss over probabilities of the positive class.
pub fn binary_logloss(y_true: &[usize], p_pos: &[f64]) -> f64 {
    assert_eq!(y_true.len(), p_pos.len());
    assert!(!y_true.is_empty());
    let eps = 1e-12;
    let s: f64 = y_true
        .iter()
        .zip(p_pos)
        .map(|(&y, &p)| {
            let p = p.clamp(eps, 1.0 - eps);
            if y == 1 {
                -p.ln()
            } else {
                -(1.0 - p).ln()
            }
        })
        .sum();
    s / y_true.len() as f64
}

/// Multiclass log-loss over per-class probability rows.
pub fn multiclass_logloss(y_true: &[usize], probs: &[Vec<f64>]) -> f64 {
    assert_eq!(y_true.len(), probs.len());
    assert!(!y_true.is_empty());
    let eps = 1e-12;
    let s: f64 = y_true
        .iter()
        .zip(probs)
        .map(|(&y, row)| -(row[y].clamp(eps, 1.0)).ln())
        .sum();
    s / y_true.len() as f64
}

/// Mean and sample standard deviation of a series — used for the
/// error-bar aggregation across the paper's 12 train/test splits.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    if xs.len() == 1 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (xs.len() - 1) as f64;
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[0, 1, 1, 0], &[0, 1, 0, 0]), 0.75);
        assert_eq!(accuracy(&[1, 1], &[1, 1]), 1.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn r2_perfect_and_mean() {
        let y = [1.0, 2.0, 3.0, 4.0];
        assert!((r2_score(&y, &y) - 1.0).abs() < 1e-12);
        let mean_pred = [2.5; 4];
        assert!(r2_score(&y, &mean_pred).abs() < 1e-12);
    }

    #[test]
    fn r2_worse_than_mean_is_negative() {
        let y = [1.0, 2.0, 3.0];
        let bad = [10.0, -5.0, 7.0];
        assert!(r2_score(&y, &bad) < 0.0);
    }

    #[test]
    fn r2_constant_targets() {
        let y = [2.0, 2.0];
        assert_eq!(r2_score(&y, &[2.0, 2.0]), 1.0);
        assert_eq!(r2_score(&y, &[2.0, 3.0]), 0.0);
    }

    #[test]
    fn rmse_known() {
        assert!((rmse(&[0.0, 0.0], &[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn logloss_confident_correct_is_small() {
        let ll = binary_logloss(&[1, 0], &[0.99, 0.01]);
        assert!(ll < 0.02);
        let bad = binary_logloss(&[1, 0], &[0.01, 0.99]);
        assert!(bad > 4.0);
    }

    #[test]
    fn multiclass_logloss_uniform() {
        let probs = vec![vec![0.25; 4]; 3];
        let ll = multiclass_logloss(&[0, 1, 2], &probs);
        assert!((ll - (4.0f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - 1.0).abs() < 1e-12);
        let (m1, s1) = mean_std(&[5.0]);
        assert_eq!((m1, s1), (5.0, 0.0));
    }
}
