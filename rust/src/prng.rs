//! Deterministic pseudo-random number generation.
//!
//! The build environment is offline (no `rand` crate in the vendor set),
//! so the crate carries its own small, well-tested generator: PCG-XSH-RR
//! 64/32 (O'Neill 2014) seeded through SplitMix64. Every stochastic
//! component of the system (dataset synthesis, train/test splits, row and
//! feature subsampling, property tests, workload generators) draws from
//! this module so that all experiments are reproducible from a single
//! `u64` seed.

/// PCG-XSH-RR 64/32: 64-bit LCG state, 32-bit xorshift-rotate output.
///
/// Small (16 bytes), fast, and statistically solid for simulation
/// purposes. Not cryptographic.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

/// SplitMix64 step — used to expand a single seed into stream parameters
/// and to cheaply derive independent child seeds.
#[inline]
pub fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Pcg64 {
    /// Create a generator from a seed. Two different seeds give
    /// independent streams (distinct LCG increments).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let init_state = splitmix64(&mut sm);
        let init_inc = splitmix64(&mut sm) | 1; // must be odd
        let mut rng = Pcg64 { state: 0, inc: init_inc };
        rng.state = init_state.wrapping_add(init_inc);
        rng.next_u32();
        rng
    }

    /// Derive an independent child generator; `label` keeps derivations
    /// for different purposes from colliding.
    pub fn derive(&mut self, label: u64) -> Pcg64 {
        let s = (self.next_u64()).wrapping_add(label.wrapping_mul(0x9E3779B97F4A7C15));
        Pcg64::new(s)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift with rejection.
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        assert!(n > 0, "gen_range(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul128(x, n);
            if lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
            // reject and retry (rare)
            let _ = x;
        }
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn gen_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.gen_f64()
    }

    /// Standard normal via Box–Muller (polar-free variant, two uniforms).
    pub fn gen_normal(&mut self) -> f64 {
        // Avoid log(0).
        let u1 = (1.0 - self.gen_f64()).max(f64::MIN_POSITIVE);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli draw.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.gen_range(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[inline]
fn mul128(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "streams should be effectively independent");
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut rng = Pcg64::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.gen_range(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit in 1000 draws");
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut rng = Pcg64::new(9);
        for _ in 0..10_000 {
            let v = rng.gen_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(11);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::new(13);
        let s = rng.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
        assert!(d.iter().all(|&i| i < 50));
    }

    #[test]
    fn derive_gives_independent_streams() {
        let mut root = Pcg64::new(5);
        let mut c1 = root.derive(1);
        let mut c2 = root.derive(2);
        let same = (0..64).filter(|_| c1.next_u32() == c2.next_u32()).count();
        assert!(same < 4);
    }
}
