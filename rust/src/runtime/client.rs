//! PJRT client wrapper and artifact management.
//!
//! Artifacts are HLO **text** (not serialized protos — xla_extension
//! 0.5.1 rejects jax ≥ 0.5's 64-bit instruction ids; the text parser
//! reassigns them). `MANIFEST.txt`, written last by `aot.py`, lists one
//! artifact per line:
//!
//! ```text
//! predict predict_n256_t256_d4_f64_o1.hlo.txt n=256 t=256 d=4 f=64 o=1
//! histogram histogram_s4096_f64_b64.hlo.txt s=4096 f=64 b=64
//! ```

use crate::error::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Parsed manifest entry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactSpec {
    pub kind: String,
    pub file: String,
    /// Shape parameters, e.g. `n`, `t`, `d`, `f`, `o`.
    pub params: HashMap<String, usize>,
}

impl ArtifactSpec {
    pub fn param(&self, key: &str) -> Option<usize> {
        self.params.get(key).copied()
    }
}

/// Parse a MANIFEST.txt body.
pub fn parse_manifest(text: &str) -> Vec<ArtifactSpec> {
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .filter_map(|line| {
            let mut parts = line.split_whitespace();
            let kind = parts.next()?.to_string();
            let file = parts.next()?.to_string();
            let params = parts
                .filter_map(|kv| {
                    let (k, v) = kv.split_once('=')?;
                    Some((k.to_string(), v.parse().ok()?))
                })
                .collect();
            Some(ArtifactSpec { kind, file, params })
        })
        .collect()
}

/// A PJRT CPU client together with the artifact directory.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    specs: Vec<ArtifactSpec>,
}

impl XlaRuntime {
    /// Open the artifact directory (reads MANIFEST.txt) and create the
    /// PJRT CPU client.
    pub fn open(artifacts_dir: impl AsRef<Path>) -> Result<XlaRuntime> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = std::fs::read_to_string(dir.join("MANIFEST.txt"))
            .with_context(|| format!("no MANIFEST.txt in {dir:?}; run `make artifacts`"))?;
        let specs = parse_manifest(&manifest);
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(XlaRuntime { client, dir, specs })
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    pub fn specs(&self) -> &[ArtifactSpec] {
        &self.specs
    }

    /// Find an artifact by kind and exact parameter constraints.
    pub fn find(&self, kind: &str, constraints: &[(&str, usize)]) -> Option<&ArtifactSpec> {
        self.specs.iter().find(|s| {
            s.kind == kind && constraints.iter().all(|&(k, v)| s.param(k) == Some(v))
        })
    }

    /// Load + compile an artifact.
    pub fn compile(&self, spec: &ArtifactSpec) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).with_context(|| format!("compiling {}", spec.file))
    }

    /// Upload a literal to the device (device 0).
    pub fn to_device(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        let device = self
            .client
            .devices()
            .into_iter()
            .next()
            .ok_or_else(|| crate::anyhow!("no PJRT devices"))?;
        self.client
            .buffer_from_host_literal(Some(&device), lit)
            .context("buffer_from_host_literal")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parsing() {
        let text = "predict predict_n256_t256_d4_f64_o1.hlo.txt n=256 t=256 d=4 f=64 o=1\n\
                    histogram histogram_s4096_f64_b64.hlo.txt s=4096 f=64 b=64\n";
        let specs = parse_manifest(text);
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].kind, "predict");
        assert_eq!(specs[0].param("n"), Some(256));
        assert_eq!(specs[0].param("o"), Some(1));
        assert_eq!(specs[1].param("b"), Some(64));
        assert_eq!(specs[1].param("zz"), None);
    }

    #[test]
    fn manifest_skips_blank_lines() {
        let specs = parse_manifest("\n\npredict a.hlo.txt n=1\n\n");
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].file, "a.hlo.txt");
    }
}
