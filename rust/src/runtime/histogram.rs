//! The gradient-histogram executable: offload the GBDT training
//! hot-spot to the AOT-compiled Pallas kernel.
//!
//! The artifact computes, for a fixed `(S, F, B)` shape, the per
//! (feature, bin) gradient/hessian sums via the one-hot-matmul kernel
//! (see `python/compile/kernels/histogram.py`). Rows are padded with
//! `bin = 0, grad = hess = 0` (no-ops by construction); features are
//! padded with constant bin 0; extra bins simply stay empty.
//!
//! The native `HistogramSet` remains the trainer's default (at 16 k-row
//! leaves the native scatter outperforms a CPU-interpreted XLA matmul);
//! this engine exists to (a) prove the L1→L3 path end to end and (b)
//! serve as the drop-in once a real TPU PJRT plugin is available.

use super::client::XlaRuntime;
use crate::error::{Context, Result};

/// A compiled histogram executable.
pub struct HistogramEngine {
    exe: xla::PjRtLoadedExecutable,
    s: usize,
    f: usize,
    b: usize,
}

impl HistogramEngine {
    /// Compile the histogram artifact with shape `(s, f, b)`.
    pub fn new(rt: &XlaRuntime, s: usize, f: usize, b: usize) -> Result<HistogramEngine> {
        let spec = rt
            .find("histogram", &[("s", s), ("f", f), ("b", b)])
            .with_context(|| format!("no histogram artifact for s={s} f={f} b={b}"))?
            .clone();
        Ok(HistogramEngine { exe: rt.compile(&spec)?, s, f, b })
    }

    pub fn shape(&self) -> (usize, usize, usize) {
        (self.s, self.f, self.b)
    }

    /// Compute `(F, B, 2)` histograms for up to `s` rows.
    ///
    /// `bins[f][i]` is the bin of row `i` on feature `f` (column-major,
    /// like [`crate::data::BinMatrix`] — use `BinMatrix::to_u16_columns`
    /// to stage a matrix for this tensor interface); bins must be
    /// `< b`, rows beyond `grad.len()` are padding.
    pub fn run(
        &self,
        bins: &[Vec<u16>],
        grad: &[f64],
        hess: &[f64],
    ) -> Result<Vec<[f64; 2]>> {
        let n = grad.len();
        crate::ensure!(n <= self.s, "rows {n} exceed artifact size {}", self.s);
        crate::ensure!(bins.len() <= self.f, "features {} exceed {}", bins.len(), self.f);
        crate::ensure!(hess.len() == n);

        // Pack row-major padded int32 bins + f32 stats.
        let mut bins_i32 = vec![0i32; self.s * self.f];
        for (f, col) in bins.iter().enumerate() {
            crate::ensure!(col.len() == n, "ragged bins");
            for (i, &v) in col.iter().enumerate() {
                crate::ensure!((v as usize) < self.b, "bin {v} out of range {}", self.b);
                bins_i32[i * self.f + f] = v as i32;
            }
        }
        let grad_f32: Vec<f32> = grad.iter().map(|&g| g as f32).chain(
            std::iter::repeat(0.0).take(self.s - n),
        ).collect();
        let hess_f32: Vec<f32> = hess.iter().map(|&h| h as f32).chain(
            std::iter::repeat(0.0).take(self.s - n),
        ).collect();

        let bins_lit =
            xla::Literal::vec1(&bins_i32).reshape(&[self.s as i64, self.f as i64])?;
        let grad_lit = xla::Literal::vec1(&grad_f32);
        let hess_lit = xla::Literal::vec1(&hess_f32);
        let out = self.exe.execute::<xla::Literal>(&[bins_lit, grad_lit, hess_lit])?;
        let lit = out[0][0].to_literal_sync()?.to_tuple1()?;
        let vals: Vec<f32> = lit.to_vec()?;
        crate::ensure!(vals.len() == self.f * self.b * 2);
        Ok(vals
            .chunks_exact(2)
            .map(|c| [c[0] as f64, c[1] as f64])
            .collect())
    }

    /// Flat `(feature, bin)` index into [`HistogramEngine::run`] output.
    pub fn index(&self, feature: usize, bin: usize) -> usize {
        feature * self.b + bin
    }
}
