//! XLA/PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Python runs only at build time (`make artifacts`); at run time this
//! module compiles the HLO **text** artifacts once per process with the
//! PJRT CPU client and serves batched predictions from device-resident
//! model tensors.
//!
//! * [`tensorize`] — [`crate::gbdt::GbdtModel`] → fixed-shape complete
//!   tree tensors (padding trees to the artifact depth/count). Pure
//!   Rust, always available; also the oracle for parity tests.
//! * `client` — artifact discovery (MANIFEST.txt), HLO loading,
//!   compilation (**`xla` feature only**).
//! * `histogram` / `predict` — the XLA histogram and batched predict
//!   engines (**`xla` feature only**).
//!
//! The default build has no external dependencies; everything that
//! needs the PJRT bindings is gated behind the `xla` cargo feature (see
//! `Cargo.toml` for how to supply the bindings crate). Batched native
//! serving without artifacts is covered by
//! [`crate::inference::FlatModel`].

#[cfg(feature = "xla")]
pub mod client;
#[cfg(feature = "xla")]
pub mod histogram;
#[cfg(feature = "xla")]
pub mod predict;
pub mod tensorize;

#[cfg(feature = "xla")]
pub use client::{ArtifactSpec, XlaRuntime};
#[cfg(feature = "xla")]
pub use histogram::HistogramEngine;
#[cfg(feature = "xla")]
pub use predict::PredictEngine;
pub use tensorize::{tensorize, TensorModel};
