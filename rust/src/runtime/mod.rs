//! XLA/PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Python runs only at build time (`make artifacts`); at run time this
//! module compiles the HLO **text** artifacts once per process with the
//! PJRT CPU client and serves batched predictions from device-resident
//! model tensors.
//!
//! * [`client`] — artifact discovery (MANIFEST.txt), HLO loading,
//!   compilation.
//! * [`tensorize`] — [`crate::gbdt::GbdtModel`] → fixed-shape complete
//!   tree tensors (padding trees to the artifact depth/count).
//! * [`predict`] — the batched predict engine used by the coordinator.

pub mod client;
pub mod histogram;
pub mod predict;
pub mod tensorize;

pub use client::{ArtifactSpec, XlaRuntime};
pub use histogram::HistogramEngine;
pub use predict::PredictEngine;
pub use tensorize::{tensorize, TensorModel};
