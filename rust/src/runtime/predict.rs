//! The batched predict engine: one compiled artifact + device-resident
//! model tensors, serving raw scores for row batches.

use super::client::XlaRuntime;
use super::tensorize::TensorModel;
use crate::error::{Context, Result};

/// A compiled predict executable bound to one model's tensors.
///
/// The model tensors (`feat`, `thr`, `leaves`, `base`) are uploaded to
/// the device once at construction; each call uploads only the batch.
pub struct PredictEngine {
    exe: xla::PjRtLoadedExecutable,
    feat_buf: xla::PjRtBuffer,
    thr_buf: xla::PjRtBuffer,
    leaves_buf: xla::PjRtBuffer,
    base_buf: xla::PjRtBuffer,
    /// The host literals backing the device buffers.
    ///
    /// PJRT's `BufferFromHostLiteral` copies *asynchronously*: the
    /// literal must outlive the copy, or the deferred transfer reads
    /// freed memory (observed as a `literal.size_bytes() == b->size()`
    /// check-failure inside TFRT). Holding them here pins the memory
    /// for the engine's lifetime.
    _model_literals: Vec<xla::Literal>,
    /// Reused input literal: building a fresh `(batch, features)`
    /// literal per call dominated small-batch latency (§Perf
    /// iteration 5); `copy_raw_from` updates it in place.
    x_lit: xla::Literal,
    x_host: Vec<f32>,
    runtime_batch: usize,
    n_features: usize,
    n_outputs: usize,
    /// Native copy for fallback / verification.
    tensors: TensorModel,
}

impl PredictEngine {
    /// Compile the predict artifact matching `(batch, trees, depth,
    /// features, outputs)` and bind `tensors` to it.
    pub fn new(
        rt: &XlaRuntime,
        tensors: TensorModel,
        batch: usize,
        features: usize,
    ) -> Result<PredictEngine> {
        let spec = rt
            .find(
                "predict",
                &[
                    ("n", batch),
                    ("t", tensors.n_trees),
                    ("d", tensors.depth),
                    ("f", features),
                    ("o", tensors.n_outputs),
                ],
            )
            .with_context(|| {
                format!(
                    "no predict artifact for n={batch} t={} d={} f={features} o={}",
                    tensors.n_trees, tensors.depth, tensors.n_outputs
                )
            })?
            .clone();
        let exe = rt.compile(&spec)?;

        let i = tensors.n_internal_slots as i64;
        let l = tensors.n_leaf_slots as i64;
        let t = tensors.n_trees as i64;
        let feat_lit = xla::Literal::vec1(&tensors.feat).reshape(&[t, i])?;
        let thr_lit = xla::Literal::vec1(&tensors.thr).reshape(&[t, i])?;
        let leaves_lit = xla::Literal::vec1(&tensors.leaves).reshape(&[t, l])?;
        let base_lit = xla::Literal::vec1(&tensors.base);
        let feat_buf = rt.to_device(&feat_lit)?;
        let thr_buf = rt.to_device(&thr_lit)?;
        let leaves_buf = rt.to_device(&leaves_lit)?;
        let base_buf = rt.to_device(&base_lit)?;
        // Force the async host→device copies to complete while the
        // literals are certainly alive (cheap: done once per engine).
        for buf in [&feat_buf, &thr_buf, &leaves_buf, &base_buf] {
            let _ = buf.to_literal_sync()?;
        }
        let x_host = vec![0f32; batch * features];
        let x_lit =
            xla::Literal::vec1(&x_host).reshape(&[batch as i64, features as i64])?;
        Ok(PredictEngine {
            feat_buf,
            thr_buf,
            leaves_buf,
            base_buf,
            _model_literals: vec![feat_lit, thr_lit, leaves_lit, base_lit],
            x_lit,
            x_host,
            exe,
            runtime_batch: batch,
            n_features: features,
            n_outputs: tensors.n_outputs,
            tensors,
        })
    }

    /// The fixed batch size the artifact was compiled for.
    pub fn batch_size(&self) -> usize {
        self.runtime_batch
    }

    pub fn n_outputs(&self) -> usize {
        self.n_outputs
    }

    pub fn tensors(&self) -> &TensorModel {
        &self.tensors
    }

    /// Predict raw scores for up to `batch_size` rows (each row may have
    /// fewer than `n_features` features; zero-padded). Returns one
    /// `Vec<f64>` of length `n_outputs` per input row.
    pub fn predict(&mut self, rows: &[Vec<f32>]) -> Result<Vec<Vec<f64>>> {
        crate::ensure!(
            rows.len() <= self.runtime_batch,
            "batch {} exceeds compiled size {}",
            rows.len(),
            self.runtime_batch
        );
        // Pack + pad the batch into the reused host staging buffer and
        // refresh the input literal in place.
        self.x_host.iter_mut().for_each(|v| *v = 0.0);
        for (r, row) in rows.iter().enumerate() {
            crate::ensure!(row.len() <= self.n_features, "row has too many features");
            self.x_host[r * self.n_features..r * self.n_features + row.len()]
                .copy_from_slice(row);
        }
        self.x_lit.copy_raw_from(&self.x_host)?;
        let x_buf = self.exe.client().buffer_from_host_literal(
            Some(&self.exe.client().devices().into_iter().next().unwrap()),
            &self.x_lit,
        )?;

        let out = self
            .exe
            .execute_b(&[&x_buf, &self.feat_buf, &self.thr_buf, &self.leaves_buf, &self.base_buf])?;
        let lit = out[0][0].to_literal_sync()?;
        let result = lit.to_tuple1()?;
        let vals: Vec<f32> = result.to_vec()?;
        crate::ensure!(vals.len() == self.runtime_batch * self.n_outputs);
        Ok(rows
            .iter()
            .enumerate()
            .map(|(r, _)| {
                (0..self.n_outputs)
                    .map(|k| vals[r * self.n_outputs + k] as f64)
                    .collect()
            })
            .collect())
    }
}
