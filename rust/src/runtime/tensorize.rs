//! Convert a trained [`GbdtModel`] into the fixed-shape complete-tree
//! tensors the AOT predict artifacts expect.
//!
//! The artifact is compiled for `(T, depth, F, O)`; the model is padded:
//!
//! * every tree is completed at the artifact depth (early leaves are
//!   replicated; pass-through slots route left via a `+∞` threshold),
//! * the tree count is padded per output stream with zero-leaf trees,
//! * the feature dimension only requires `model.n_features ≤ F`
//!   (inputs are zero-padded by the predict engine).

use crate::bail;
use crate::error::Result;
use crate::gbdt::GbdtModel;

/// Row-major tensors mirroring the artifact's parameter order.
#[derive(Clone, Debug)]
pub struct TensorModel {
    /// `(T, I)` split features (as i32), row-major.
    pub feat: Vec<i32>,
    /// `(T, I)` split thresholds.
    pub thr: Vec<f32>,
    /// `(T, L)` leaf values.
    pub leaves: Vec<f32>,
    /// `(O,)` base scores.
    pub base: Vec<f32>,
    pub n_trees: usize,
    pub n_internal_slots: usize,
    pub n_leaf_slots: usize,
    pub n_outputs: usize,
    pub depth: usize,
}

/// Tensorize `model` for an artifact with `t_total` trees at `depth`,
/// `f` input features and `o` output streams.
pub fn tensorize(model: &GbdtModel, t_total: usize, depth: usize, f: usize, o: usize) -> Result<TensorModel> {
    if model.n_outputs() != o {
        bail!("model has {} outputs, artifact expects {o}", model.n_outputs());
    }
    if model.n_features > f {
        bail!("model has {} features, artifact supports {f}", model.n_features);
    }
    if model.max_depth() > depth {
        bail!("model depth {} exceeds artifact depth {depth}", model.max_depth());
    }
    let per_output = t_total / o;
    if t_total % o != 0 {
        bail!("tree budget {t_total} not divisible by outputs {o}");
    }
    if model.n_rounds() > per_output {
        bail!("model has {} rounds, artifact fits {per_output} per output", model.n_rounds());
    }

    let i_slots = (1usize << depth) - 1;
    let l_slots = 1usize << depth;
    let mut feat = vec![0i32; t_total * i_slots];
    let mut thr = vec![0f32; t_total * i_slots];
    let mut leaves = vec![0f32; t_total * l_slots];

    for (k, trees) in model.trees.iter().enumerate() {
        for (r, tree) in trees.iter().enumerate() {
            let ti = k * per_output + r;
            let (internal, leaf_vals) = tree.to_complete_at(depth);
            for (s, slot) in internal.iter().enumerate() {
                match slot {
                    Some((fi, _, t)) => {
                        feat[ti * i_slots + s] = *fi as i32;
                        thr[ti * i_slots + s] = *t;
                    }
                    None => {
                        // Pass-through: always route left.
                        feat[ti * i_slots + s] = 0;
                        thr[ti * i_slots + s] = f32::INFINITY;
                    }
                }
            }
            for (s, v) in leaf_vals.iter().enumerate() {
                leaves[ti * l_slots + s] = *v as f32;
            }
        }
        // Remaining tree slots of this output stay zero-leaf (no-ops);
        // their thresholds stay 0 which routes deterministically.
    }

    Ok(TensorModel {
        feat,
        thr,
        leaves,
        base: model.base_scores.iter().map(|&b| b as f32).collect(),
        n_trees: t_total,
        n_internal_slots: i_slots,
        n_leaf_slots: l_slots,
        n_outputs: o,
        depth,
    })
}

/// Pure-Rust evaluation of a [`TensorModel`] — the oracle the XLA parity
/// tests compare against, and a fallback predictor when no artifacts
/// are built.
pub fn eval_tensor_model(tm: &TensorModel, x: &[f32]) -> Vec<f64> {
    let per_output = tm.n_trees / tm.n_outputs;
    (0..tm.n_outputs)
        .map(|k| {
            let mut acc = tm.base[k] as f64;
            for r in 0..per_output {
                let ti = k * per_output + r;
                let mut i = 0usize;
                while i < tm.n_internal_slots {
                    let f = tm.feat[ti * tm.n_internal_slots + i] as usize;
                    let t = tm.thr[ti * tm.n_internal_slots + i];
                    i = if x[f] <= t { 2 * i + 1 } else { 2 * i + 2 };
                }
                acc += tm.leaves[ti * tm.n_leaf_slots + (i - tm.n_internal_slots)] as f64;
            }
            acc
        })
        .collect()
}

#[cfg(test)]
#[cfg(not(miri))] // trains models / generates datasets - too slow under the Miri interpreter
mod tests {
    use super::*;
    use crate::data::synth::PaperDataset;
    use crate::gbdt::{self, GbdtParams};

    fn model(rounds: usize, depth: usize) -> (GbdtModel, crate::data::Dataset) {
        let data = PaperDataset::BreastCancer.generate(21);
        let data = data.select(&(0..400).collect::<Vec<_>>());
        (gbdt::booster::train(&data, GbdtParams::paper(rounds, depth)), data)
    }

    #[test]
    fn tensorized_matches_native_predictions() {
        let (m, data) = model(12, 3);
        let tm = tensorize(&m, 256, 4, 64, 1).unwrap();
        for i in (0..data.n_rows()).step_by(17) {
            let mut x = data.row(i);
            x.resize(64, 0.0); // feature padding
            let a = m.predict_raw(&data.row(i))[0];
            let b = eval_tensor_model(&tm, &x)[0];
            assert!((a - b).abs() < 1e-4, "row {i}: native {a} vs tensor {b}");
        }
    }

    #[test]
    fn rejects_oversized_models() {
        let (m, _) = model(4, 3);
        assert!(tensorize(&m, 256, 2, 64, 1).is_err(), "depth overflow");
        assert!(tensorize(&m, 2, 4, 64, 1).is_err(), "tree overflow");
        assert!(tensorize(&m, 256, 4, 8, 1).is_err(), "feature overflow");
        assert!(tensorize(&m, 256, 4, 64, 3).is_err(), "output mismatch");
    }

    #[test]
    fn padding_trees_are_neutral() {
        let (m, data) = model(3, 2);
        let small = tensorize(&m, 4, 4, 64, 1).unwrap();
        let big = tensorize(&m, 64, 4, 64, 1).unwrap();
        let mut x = data.row(0);
        x.resize(64, 0.0);
        let a = eval_tensor_model(&small, &x)[0];
        let b = eval_tensor_model(&big, &x)[0];
        assert!((a - b).abs() < 1e-9);
    }

    #[test]
    fn multiclass_grouping() {
        let data = PaperDataset::WineQuality.generate(22).select(&(0..600).collect::<Vec<_>>());
        let m = gbdt::booster::train(&data, GbdtParams::paper(4, 2));
        let tm = tensorize(&m, 7 * 8, 4, 64, 7).unwrap();
        for i in (0..data.n_rows()).step_by(41) {
            let mut x = data.row(i);
            x.resize(64, 0.0);
            let a = m.predict_raw(&data.row(i));
            let b = eval_tensor_model(&tm, &x);
            for (p, q) in a.iter().zip(&b) {
                assert!((p - q).abs() < 1e-4);
            }
        }
    }
}
