//! Vectorized binning: count of table thresholds strictly below a
//! probe value.
//!
//! The quantized engine bins an input by its rank in the per-feature
//! ascending table of distinct thresholds: `bin(v) = #{b : b < v}`.
//! On a sorted table the elements below `v` form a prefix, so that
//! count *is* `table.partition_point(|&b| b < v)` — which means the
//! branchy binary search can be replaced by a branch-free vector count
//! for the short tables trained models produce: one lane-wide `b < v`
//! compare plus a movemask popcount per group of 8 (AVX) or 4 (SSE2)
//! floats. Above [`LINEAR_MAX`] entries the `O(log n)` search wins and
//! every tier falls back to it; the two paths agree exactly by the
//! prefix identity, so the cutoff never affects outputs.
//!
//! A NaN probe returns 0 on every path (`b < NaN` is false in both the
//! scalar predicate and the ordered vector compares). The engine maps
//! NaN inputs to its dedicated NaN bin before binning, so this is a
//! parity property, not a hot case.

use super::Tier;

/// Table length above which every tier uses the binary search: the
/// vector count is `O(n)`, and per-feature tables of trained compact
/// models are usually far shorter than this.
pub const LINEAR_MAX: usize = 128;

/// `#{b ∈ table : b < v}` for an ascending `table` (sorted by
/// `f32::total_cmp`, NaN-free). Bit-identical across tiers — the count
/// equals `partition_point(|&b| b < v)` on any sorted table, so
/// forcing [`Tier::Scalar`] yields the engine's historical
/// binary-search twin exactly (property-tested below). Unsupported
/// forced tiers clamp to the detected one.
pub fn count_lt(tier: Tier, table: &[f32], v: f32) -> usize {
    #[cfg(target_arch = "x86_64")]
    if table.len() <= LINEAR_MAX {
        match tier.clamp_detected() {
            // SAFETY: `clamp_detected` returned `Avx2`, so the running
            // CPU detected AVX2, which implies the AVX feature this fn
            // requires. `table` is a valid slice; the kernel reads only
            // within its bounds.
            Tier::Avx2 => return unsafe { x86::count_lt_avx(table, v) },
            // SAFETY: SSE2 is architecturally guaranteed on x86-64
            // (this arm is compiled only for that target). `table` is a
            // valid slice; the kernel reads only within its bounds.
            Tier::Sse2 => return unsafe { x86::count_lt_sse2(table, v) },
            Tier::Scalar => {}
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = tier;
    table.partition_point(|&b| b < v)
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use core::arch::x86_64::*;

    /// Four `f32` lanes per compare; scalar tail under one group.
    ///
    /// # Safety
    /// The caller must ensure the CPU supports SSE2 — architecturally
    /// guaranteed on x86-64, the only target this module compiles for.
    /// No other precondition: `table` may be any length (including 0);
    /// every `_mm_loadu_ps(table.as_ptr().add(i))` is guarded by
    /// `i + 4 <= table.len()`, so all unaligned loads stay in bounds.
    #[inline]
    pub unsafe fn count_lt_sse2(table: &[f32], v: f32) -> usize {
        let probe = _mm_set1_ps(v);
        let mut count = 0usize;
        let mut i = 0usize;
        while i + 4 <= table.len() {
            let t = _mm_loadu_ps(table.as_ptr().add(i));
            count += _mm_movemask_ps(_mm_cmplt_ps(t, probe)).count_ones() as usize;
            i += 4;
        }
        count + table[i..].iter().filter(|&&b| b < v).count()
    }

    /// Eight `f32` lanes per compare; scalar tail under one group.
    ///
    /// # Safety
    /// The caller must verify the CPU supports AVX before calling (the
    /// detected AVX2 tier implies it — route through
    /// `Tier::clamp_detected`); calling without it is immediate UB
    /// (`#[target_feature]`). No other precondition: `table` may be
    /// any length (including 0); every
    /// `_mm256_loadu_ps(table.as_ptr().add(i))` is guarded by
    /// `i + 8 <= table.len()`, so all unaligned loads stay in bounds.
    #[target_feature(enable = "avx")]
    pub unsafe fn count_lt_avx(table: &[f32], v: f32) -> usize {
        let probe = _mm256_set1_ps(v);
        let mut count = 0usize;
        let mut i = 0usize;
        while i + 8 <= table.len() {
            let t = _mm256_loadu_ps(table.as_ptr().add(i));
            let lt = _mm256_cmp_ps::<_CMP_LT_OQ>(t, probe);
            count += _mm256_movemask_ps(lt).count_ones() as usize;
            i += 8;
        }
        count + table[i..].iter().filter(|&&b| b < v).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg64;
    use crate::testutil::prop::run_prop;

    #[test]
    #[cfg_attr(miri, ignore)] // 120-case property sweep — slow under Miri;
                              // `tests/miri_surface.rs` covers the scalar path.
    fn prop_every_tier_matches_partition_point() {
        run_prop("simd count_lt == partition_point", 120, |g| {
            let n = g.usize_in(0, LINEAR_MAX + 40);
            let mut rng = Pcg64::new(g.case_seed ^ 0xB1);
            let mut table: Vec<f32> = (0..n).map(|_| rng.gen_uniform(-50.0, 50.0) as f32).collect();
            // Duplicates are legal in a sorted table (pre-dedup).
            if n > 4 && rng.gen_bool(0.4) {
                let i = 1 + rng.gen_range(n - 1);
                table[i] = table[i - 1];
            }
            table.sort_by(f32::total_cmp);
            let mut probes: Vec<f32> =
                (0..8).map(|_| rng.gen_uniform(-60.0, 60.0) as f32).collect();
            probes.extend([f32::INFINITY, f32::NEG_INFINITY, f32::NAN, 0.0, -0.0]);
            if n > 0 {
                // The exact boundary cases: a table element and its
                // adjacent representable floats.
                let b = table[rng.gen_range(n)];
                probes.push(b);
                probes.push(f32::from_bits(b.to_bits().wrapping_add(1)));
                probes.push(f32::from_bits(b.to_bits().wrapping_sub(1)));
            }
            for v in probes {
                let want = table.partition_point(|&b| b < v);
                for tier in crate::simd::available_tiers() {
                    let got = count_lt(tier, &table, v);
                    assert_eq!(got, want, "tier {} n {n} v {v}", tier.name());
                }
                // An unsupported forced tier must clamp, not crash.
                assert_eq!(count_lt(Tier::Avx2, &table, v), want);
            }
        });
    }

    #[test]
    fn long_tables_fall_back_to_search_on_every_tier() {
        let table: Vec<f32> = (0..(LINEAR_MAX as i32) * 2).map(|i| i as f32 * 0.5).collect();
        for tier in crate::simd::available_tiers() {
            assert_eq!(
                count_lt(tier, &table, 10.25),
                table.partition_point(|&b| b < 10.25),
                "tier {}",
                tier.name()
            );
            assert_eq!(count_lt(tier, &table, -1.0), 0);
            assert_eq!(count_lt(tier, &table, 1e9), table.len());
        }
    }

    #[test]
    fn empty_table_bins_everything_to_zero() {
        for tier in crate::simd::available_tiers() {
            assert_eq!(count_lt(tier, &[], 3.0), 0, "tier {}", tier.name());
        }
    }
}
