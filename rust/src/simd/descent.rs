//! Vectorized complete-tree descent over quantized (`u16` rank) codes.
//!
//! A complete tree of depth `d` descends in exactly `d` steps of
//! `i ← 2i + 2 − (xb[feat[i]] ≤ thr[i])`, so a whole lane group of rows
//! advances one level per iteration with no per-lane branching. The
//! SIMD kernels keep the lane indices in `u16` vector lanes (complete
//! trees cap at `MAX_COMPLETE_DEPTH = 10`, so the final index
//! `≤ 2^{d+1} − 2 = 2046` has headroom through depth 15) and run the
//! compare + index update as vector ops:
//!
//! * the unsigned compare `xb ≤ t` is the signed `cmpgt` of
//!   bias-flipped operands (`x ^ 0x8000`), since SSE2/AVX2 have no
//!   unsigned `u16` compare — `gt` lanes come back as `0xFFFF` (−1),
//!   so `i ← 2i + 1 − gt` lands on `2i + 1` (left) or `2i + 2` (right)
//!   exactly like the scalar expression;
//! * the per-lane fetches of `feat[i]`, `thr[i]` and the row code stay
//!   scalar through a lane-index spill: a hardware gather loads 32-bit
//!   elements and would over-read past the end of the `u16` arrays.
//!
//! This works on the sentinel values by construction: the NaN bin
//! `0xFFFF` exceeds every stored rank (routes right) and the
//! pass-through rank `0xFFFF` satisfies `xb ≤ t` for every bin (routes
//! left), both of which are plain unsigned comparisons — no special
//! cases in any tier.
//!
//! [`descend_row`] is the one scalar per-row routine: it serves the
//! single-row engine path, the scalar tier, and the sub-lane-group
//! tails of both vector tiers, so the tail and lane kernels cannot
//! drift apart.
//!
//! **Oblivious descent** ([`descend_oblivious`]) is the fully-vector
//! special case: an oblivious tree shares one `(feature, threshold)`
//! pair per level, so the per-lane node fetches disappear — each level
//! broadcasts the single threshold into every lane, fetches all lane
//! codes from the *same* column offset, and shifts the compare bit into
//! a per-lane leaf-table index `idx ← 2·idx + (code > µ)`. The kernel
//! returns raw leaf-table indices (`0 .. 2^d`); the caller does the one
//! leaf lookup per lane at the end. This erases the one scalar hole the
//! general kernels have (per-lane `feat[i]`/`thr[i]` fetches), which is
//! exactly why the mode exists.

use super::Tier;

/// Rows interleaved per iteration by the scalar tier (and the historic
/// `LANES` of `inference::quantized`): eight independent lane chains
/// keep the load→compare→index dependency chains of eight descents in
/// flight even without explicit vectors.
pub const SCALAR_LANES: usize = 8;

/// Descend one row through a complete tree and return the **leaf
/// index** (`0 .. 2^depth`). `feat`/`thr` are the tree's internal-slot
/// arrays (`2^depth − 1` entries); `row` is the full row of bin codes
/// (`row[feat[i]]` must be in range for every slot).
///
/// This is the shared per-row routine behind the quantized engine's
/// single-row path and every block tail — one definition, no drift.
#[inline]
pub fn descend_row(feat: &[u16], thr: &[u16], row: &[u16]) -> usize {
    let n_internal = feat.len();
    let mut i = 0usize;
    while i < n_internal {
        i = 2 * i + 2 - (row[feat[i] as usize] <= thr[i]) as usize;
    }
    i - n_internal
}

/// Descend every row of a row-major code block through one complete
/// tree, writing per-row **leaf indices** into `out`.
///
/// * `feat`/`thr`: the tree's `2^depth − 1` internal slots.
/// * `xb`: `out.len() × nf` row-major bin codes (`xb[r * nf + f]`).
/// * `tier`: requested dispatch tier; clamped to what the CPU supports
///   ([`Tier::clamp_detected`]), so forcing a wider tier on older
///   hardware degrades safely.
///
/// Every tier returns bit-identical indices (pure integer arithmetic,
/// property-tested in `tests/engine_parity.rs`); the caller adds the
/// leaf values in row order, so summation order is tier-independent.
pub fn descend_complete(
    tier: Tier,
    feat: &[u16],
    thr: &[u16],
    depth: usize,
    xb: &[u16],
    nf: usize,
    out: &mut [u32],
) {
    debug_assert!(depth <= 15, "lane indices must fit u16 (depth {depth})");
    debug_assert_eq!(feat.len(), (1usize << depth) - 1);
    debug_assert_eq!(thr.len(), (1usize << depth) - 1);
    debug_assert_eq!(xb.len(), out.len() * nf);
    let n_rows = out.len();
    // Lane-group body, dispatched per tier; returns the tail start.
    let r = {
        #[cfg(target_arch = "x86_64")]
        {
            descend_groups_x86(tier, feat, thr, depth, xb, nf, out)
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            let _ = tier;
            descend_scalar_groups(feat, thr, depth, xb, nf, out)
        }
    };
    // Shared scalar tail (fewer rows than one lane group).
    for t in r..n_rows {
        out[t] = descend_row(feat, thr, &xb[t * nf..(t + 1) * nf]) as u32;
    }
}

/// Descend a *subset* of a row-major code block through one complete
/// tree: lane `l` walks row `rows[l]` of `xb`, writing its **leaf
/// index** into `out[l]`. This is the gather twin of
/// [`descend_complete`] behind the adaptive early-exit kernel
/// (`inference::quantized`): as rows retire early, the caller
/// swap-compacts survivors to the front of `rows`, so live rows keep
/// filling whole 16/8-wide hardware lane groups instead of idling as
/// masked lanes. The per-lane code fetch was already a scalar load
/// through a lane-index spill in the direct kernels, so indirecting it
/// through `rows` adds one index load per lane per level.
///
/// Requires `out.len() == rows.len()` and
/// `(rows[l] as usize + 1) * nf ≤ xb.len()` for every lane. Every tier
/// returns bit-identical indices (property-tested below); row order
/// within `rows` does not affect any lane's result.
#[allow(clippy::too_many_arguments)]
pub fn descend_complete_gather(
    tier: Tier,
    feat: &[u16],
    thr: &[u16],
    depth: usize,
    xb: &[u16],
    nf: usize,
    rows: &[u32],
    out: &mut [u32],
) {
    debug_assert!(depth <= 15, "lane indices must fit u16 (depth {depth})");
    debug_assert_eq!(feat.len(), (1usize << depth) - 1);
    debug_assert_eq!(thr.len(), (1usize << depth) - 1);
    debug_assert_eq!(rows.len(), out.len());
    let n_rows = out.len();
    let r = {
        #[cfg(target_arch = "x86_64")]
        {
            gather_groups_x86(tier, feat, thr, depth, xb, nf, rows, out)
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            let _ = tier;
            gather_scalar_groups(feat, thr, depth, xb, nf, rows, out)
        }
    };
    // Shared scalar tail (fewer lanes than one lane group).
    for t in r..n_rows {
        let row = rows[t] as usize;
        out[t] = descend_row(feat, thr, &xb[row * nf..(row + 1) * nf]) as u32;
    }
}

/// Descend one row through an *oblivious* tree and return the
/// **leaf-table index** (`0 .. 2^d`). `feat`/`thr` hold one shared
/// `(feature, threshold rank)` pair per level, root level first
/// (`d = feat.len()`); bit `ℓ` of the index (MSB first) is
/// `row[feat[ℓ]] > thr[ℓ]`.
///
/// Sentinel behavior matches the general kernels: the NaN bin `0xFFFF`
/// exceeds every real stored rank, so NaN rows take the `1` bit (route
/// right) at every level — the same unsigned compare, no special case.
#[inline]
pub fn descend_oblivious_row(feat: &[u16], thr: &[u16], row: &[u16]) -> usize {
    let mut idx = 0usize;
    for (&f, &t) in feat.iter().zip(thr) {
        idx = 2 * idx + (row[f as usize] > t) as usize;
    }
    idx
}

/// Descend every row of a row-major code block through one *oblivious*
/// tree, writing per-row **leaf-table indices** into `out`.
///
/// * `feat`/`thr`: one shared `(feature, threshold rank)` pair per
///   level, root level first (`d = feat.len()`, at most 15 so indices
///   fit `u16` lanes).
/// * `xb`: `out.len() × nf` row-major bin codes (`xb[r * nf + f]`).
/// * `tier`: requested dispatch tier, clamped by
///   [`Tier::clamp_detected`].
///
/// Unlike [`descend_complete`] there are no per-lane node fetches: each
/// level is one broadcast threshold + one vector compare + one shift,
/// so the whole level step vectorizes. Every tier returns bit-identical
/// indices; the caller resolves `out[r]` against the tree's `2^d` leaf
/// table.
pub fn descend_oblivious(
    tier: Tier,
    feat: &[u16],
    thr: &[u16],
    xb: &[u16],
    nf: usize,
    out: &mut [u32],
) {
    let depth = feat.len();
    debug_assert!(depth <= 15, "leaf-table indices must fit u16 (depth {depth})");
    debug_assert_eq!(thr.len(), depth);
    debug_assert_eq!(xb.len(), out.len() * nf);
    let n_rows = out.len();
    let r = {
        #[cfg(target_arch = "x86_64")]
        {
            oblivious_groups_x86(tier, feat, thr, xb, nf, out)
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            let _ = tier;
            oblivious_scalar_groups(feat, thr, xb, nf, out)
        }
    };
    // Shared scalar tail (fewer rows than one lane group).
    for t in r..n_rows {
        out[t] = descend_oblivious_row(feat, thr, &xb[t * nf..(t + 1) * nf]) as u32;
    }
}

/// Gather twin of [`descend_oblivious`]: lane `l` walks row `rows[l]`
/// of `xb`, writing its **leaf-table index** into `out[l]` — the
/// adaptive early-exit caller swap-compacts surviving rows to the front
/// of `rows` exactly as with [`descend_complete_gather`].
///
/// Requires `out.len() == rows.len()` and
/// `(rows[l] as usize + 1) * nf ≤ xb.len()` for every lane.
pub fn descend_oblivious_gather(
    tier: Tier,
    feat: &[u16],
    thr: &[u16],
    xb: &[u16],
    nf: usize,
    rows: &[u32],
    out: &mut [u32],
) {
    let depth = feat.len();
    debug_assert!(depth <= 15, "leaf-table indices must fit u16 (depth {depth})");
    debug_assert_eq!(thr.len(), depth);
    debug_assert_eq!(rows.len(), out.len());
    let n_rows = out.len();
    let r = {
        #[cfg(target_arch = "x86_64")]
        {
            oblivious_gather_groups_x86(tier, feat, thr, xb, nf, rows, out)
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            let _ = tier;
            oblivious_gather_scalar_groups(feat, thr, xb, nf, rows, out)
        }
    };
    // Shared scalar tail (fewer lanes than one lane group).
    for t in r..n_rows {
        let row = rows[t] as usize;
        out[t] = descend_oblivious_row(feat, thr, &xb[row * nf..(row + 1) * nf]) as u32;
    }
}

/// x86-64 lane-group dispatch; returns the first row not processed.
#[cfg(target_arch = "x86_64")]
fn descend_groups_x86(
    tier: Tier,
    feat: &[u16],
    thr: &[u16],
    depth: usize,
    xb: &[u16],
    nf: usize,
    out: &mut [u32],
) -> usize {
    let n_rows = out.len();
    let mut r = 0usize;
    match tier.clamp_detected() {
        Tier::Avx2 => {
            while r + 16 <= n_rows {
                let lanes = &mut out[r..r + 16];
                // SAFETY: AVX2 verified by clamp_detected above — the
                // kernel's only soundness precondition (all its slice
                // accesses are bounds-checked).
                unsafe { x86::descend16_avx2(feat, thr, depth, xb, nf, r, lanes) };
                r += 16;
            }
            while r + 8 <= n_rows {
                // SAFETY: SSE2 is baseline on x86-64 — the kernel's
                // only soundness precondition.
                unsafe { x86::descend8_sse2(feat, thr, depth, xb, nf, r, &mut out[r..r + 8]) };
                r += 8;
            }
            r
        }
        Tier::Sse2 => {
            while r + 8 <= n_rows {
                // SAFETY: SSE2 is baseline on x86-64 — the kernel's
                // only soundness precondition.
                unsafe { x86::descend8_sse2(feat, thr, depth, xb, nf, r, &mut out[r..r + 8]) };
                r += 8;
            }
            r
        }
        Tier::Scalar => descend_scalar_groups(feat, thr, depth, xb, nf, out),
    }
}

/// Scalar tier: [`SCALAR_LANES`] interleaved lane chains per iteration
/// (independent, so the compiler can keep all eight descents in flight
/// and autovectorize the compare + index arithmetic). Returns the
/// first row not processed (the tail start).
fn descend_scalar_groups(
    feat: &[u16],
    thr: &[u16],
    depth: usize,
    xb: &[u16],
    nf: usize,
    out: &mut [u32],
) -> usize {
    let n_rows = out.len();
    let n_internal = (1usize << depth) - 1;
    let mut r = 0usize;
    while r + SCALAR_LANES <= n_rows {
        let mut idx = [0usize; SCALAR_LANES];
        for _ in 0..depth {
            for (l, i) in idx.iter_mut().enumerate() {
                let code = xb[(r + l) * nf + feat[*i] as usize];
                *i = 2 * *i + 2 - (code <= thr[*i]) as usize;
            }
        }
        for (l, &i) in idx.iter().enumerate() {
            out[r + l] = (i - n_internal) as u32;
        }
        r += SCALAR_LANES;
    }
    r
}

/// x86-64 lane-group dispatch of the gather variant; returns the first
/// lane not processed.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
fn gather_groups_x86(
    tier: Tier,
    feat: &[u16],
    thr: &[u16],
    depth: usize,
    xb: &[u16],
    nf: usize,
    rows: &[u32],
    out: &mut [u32],
) -> usize {
    let n_rows = out.len();
    let mut r = 0usize;
    match tier.clamp_detected() {
        Tier::Avx2 => {
            while r + 16 <= n_rows {
                // SAFETY: AVX2 verified by clamp_detected above — the
                // kernel's only soundness precondition (all its slice
                // accesses, including the `rows` indirection, are
                // bounds-checked).
                unsafe {
                    x86::descend16_avx2_gather(
                        feat,
                        thr,
                        depth,
                        xb,
                        nf,
                        &rows[r..r + 16],
                        &mut out[r..r + 16],
                    )
                };
                r += 16;
            }
            while r + 8 <= n_rows {
                // SAFETY: SSE2 is baseline on x86-64 — the kernel's
                // only soundness precondition.
                unsafe {
                    x86::descend8_sse2_gather(
                        feat,
                        thr,
                        depth,
                        xb,
                        nf,
                        &rows[r..r + 8],
                        &mut out[r..r + 8],
                    )
                };
                r += 8;
            }
            r
        }
        Tier::Sse2 => {
            while r + 8 <= n_rows {
                // SAFETY: SSE2 is baseline on x86-64 — the kernel's
                // only soundness precondition.
                unsafe {
                    x86::descend8_sse2_gather(
                        feat,
                        thr,
                        depth,
                        xb,
                        nf,
                        &rows[r..r + 8],
                        &mut out[r..r + 8],
                    )
                };
                r += 8;
            }
            r
        }
        Tier::Scalar => gather_scalar_groups(feat, thr, depth, xb, nf, rows, out),
    }
}

/// Scalar tier of the gather variant: [`SCALAR_LANES`] interleaved
/// lane chains, each following its own `rows[r + l]` row. Returns the
/// first lane not processed (the tail start).
fn gather_scalar_groups(
    feat: &[u16],
    thr: &[u16],
    depth: usize,
    xb: &[u16],
    nf: usize,
    rows: &[u32],
    out: &mut [u32],
) -> usize {
    let n_rows = out.len();
    let n_internal = (1usize << depth) - 1;
    let mut r = 0usize;
    while r + SCALAR_LANES <= n_rows {
        let mut idx = [0usize; SCALAR_LANES];
        for _ in 0..depth {
            for (l, i) in idx.iter_mut().enumerate() {
                let code = xb[rows[r + l] as usize * nf + feat[*i] as usize];
                *i = 2 * *i + 2 - (code <= thr[*i]) as usize;
            }
        }
        for (l, &i) in idx.iter().enumerate() {
            out[r + l] = (i - n_internal) as u32;
        }
        r += SCALAR_LANES;
    }
    r
}

/// x86-64 lane-group dispatch of the oblivious kernel; returns the
/// first row not processed.
#[cfg(target_arch = "x86_64")]
fn oblivious_groups_x86(
    tier: Tier,
    feat: &[u16],
    thr: &[u16],
    xb: &[u16],
    nf: usize,
    out: &mut [u32],
) -> usize {
    let n_rows = out.len();
    let mut r = 0usize;
    match tier.clamp_detected() {
        Tier::Avx2 => {
            while r + 16 <= n_rows {
                // SAFETY: AVX2 verified by clamp_detected above — the
                // kernel's only soundness precondition (all its slice
                // accesses are bounds-checked).
                unsafe { x86::oblivious16_avx2(feat, thr, xb, nf, r, &mut out[r..r + 16]) };
                r += 16;
            }
            while r + 8 <= n_rows {
                // SAFETY: SSE2 is baseline on x86-64 — the kernel's
                // only soundness precondition.
                unsafe { x86::oblivious8_sse2(feat, thr, xb, nf, r, &mut out[r..r + 8]) };
                r += 8;
            }
            r
        }
        Tier::Sse2 => {
            while r + 8 <= n_rows {
                // SAFETY: SSE2 is baseline on x86-64 — the kernel's
                // only soundness precondition.
                unsafe { x86::oblivious8_sse2(feat, thr, xb, nf, r, &mut out[r..r + 8]) };
                r += 8;
            }
            r
        }
        Tier::Scalar => oblivious_scalar_groups(feat, thr, xb, nf, out),
    }
}

/// Scalar tier of the oblivious kernel: [`SCALAR_LANES`] interleaved
/// lane chains. The level loop is outermost, so the shared
/// feature/threshold loads hoist out of the lane loop — the same shape
/// the vector tiers express with a broadcast. Returns the first row not
/// processed (the tail start).
fn oblivious_scalar_groups(
    feat: &[u16],
    thr: &[u16],
    xb: &[u16],
    nf: usize,
    out: &mut [u32],
) -> usize {
    let n_rows = out.len();
    let mut r = 0usize;
    while r + SCALAR_LANES <= n_rows {
        let mut idx = [0usize; SCALAR_LANES];
        for (&f, &t) in feat.iter().zip(thr) {
            let f = f as usize;
            for (l, i) in idx.iter_mut().enumerate() {
                let code = xb[(r + l) * nf + f];
                *i = 2 * *i + (code > t) as usize;
            }
        }
        for (l, &i) in idx.iter().enumerate() {
            out[r + l] = i as u32;
        }
        r += SCALAR_LANES;
    }
    r
}

/// x86-64 lane-group dispatch of the oblivious gather variant; returns
/// the first lane not processed.
#[cfg(target_arch = "x86_64")]
fn oblivious_gather_groups_x86(
    tier: Tier,
    feat: &[u16],
    thr: &[u16],
    xb: &[u16],
    nf: usize,
    rows: &[u32],
    out: &mut [u32],
) -> usize {
    let n_rows = out.len();
    let mut r = 0usize;
    match tier.clamp_detected() {
        Tier::Avx2 => {
            while r + 16 <= n_rows {
                // SAFETY: AVX2 verified by clamp_detected above — the
                // kernel's only soundness precondition (all its slice
                // accesses, including the `rows` indirection, are
                // bounds-checked).
                unsafe {
                    x86::oblivious16_avx2_gather(
                        feat,
                        thr,
                        xb,
                        nf,
                        &rows[r..r + 16],
                        &mut out[r..r + 16],
                    )
                };
                r += 16;
            }
            while r + 8 <= n_rows {
                // SAFETY: SSE2 is baseline on x86-64 — the kernel's
                // only soundness precondition.
                unsafe {
                    x86::oblivious8_sse2_gather(
                        feat,
                        thr,
                        xb,
                        nf,
                        &rows[r..r + 8],
                        &mut out[r..r + 8],
                    )
                };
                r += 8;
            }
            r
        }
        Tier::Sse2 => {
            while r + 8 <= n_rows {
                // SAFETY: SSE2 is baseline on x86-64 — the kernel's
                // only soundness precondition.
                unsafe {
                    x86::oblivious8_sse2_gather(
                        feat,
                        thr,
                        xb,
                        nf,
                        &rows[r..r + 8],
                        &mut out[r..r + 8],
                    )
                };
                r += 8;
            }
            r
        }
        Tier::Scalar => oblivious_gather_scalar_groups(feat, thr, xb, nf, rows, out),
    }
}

/// Scalar tier of the oblivious gather variant: [`SCALAR_LANES`]
/// interleaved lane chains, each following its own `rows[r + l]` row.
/// Returns the first lane not processed (the tail start).
fn oblivious_gather_scalar_groups(
    feat: &[u16],
    thr: &[u16],
    xb: &[u16],
    nf: usize,
    rows: &[u32],
    out: &mut [u32],
) -> usize {
    let n_rows = out.len();
    let mut r = 0usize;
    while r + SCALAR_LANES <= n_rows {
        let mut idx = [0usize; SCALAR_LANES];
        for (&f, &t) in feat.iter().zip(thr) {
            let f = f as usize;
            for (l, i) in idx.iter_mut().enumerate() {
                let code = xb[rows[r + l] as usize * nf + f];
                *i = 2 * *i + (code > t) as usize;
            }
        }
        for (l, &i) in idx.iter().enumerate() {
            out[r + l] = i as u32;
        }
        r += SCALAR_LANES;
    }
    r
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use core::arch::x86_64::*;

    /// Eight rows (`r .. r + 8`) in lockstep on 128-bit vectors;
    /// writes leaf indices into `out[0..8]`.
    ///
    /// # Safety
    /// The **only** soundness precondition is the CPU feature: SSE2,
    /// architecturally guaranteed on x86-64 (the only target this
    /// module compiles for). There is no memory precondition — every
    /// slice access (`xb[(r + l) * nf + feat[i]]`, `thr[i]`) is
    /// bounds-checked indexing that panics on out-of-range inputs
    /// rather than reading out of bounds, and the vector loads/stores
    /// touch only the local fixed-size lane arrays
    /// (`lanes`/`codes`/`thrs`, 8 × u16 each). Correctness (not
    /// safety) additionally wants `out.len() >= 8`: fewer lanes are
    /// silently left unwritten by the `zip`.
    #[inline]
    pub unsafe fn descend8_sse2(
        feat: &[u16],
        thr: &[u16],
        depth: usize,
        xb: &[u16],
        nf: usize,
        r: usize,
        out: &mut [u32],
    ) {
        let bias = _mm_set1_epi16(i16::MIN);
        let one = _mm_set1_epi16(1);
        let mut idx = _mm_setzero_si128();
        let mut lanes = [0u16; 8];
        let mut codes = [0u16; 8];
        let mut thrs = [0u16; 8];
        for _ in 0..depth {
            _mm_storeu_si128(lanes.as_mut_ptr().cast(), idx);
            for l in 0..8 {
                let i = lanes[l] as usize;
                codes[l] = xb[(r + l) * nf + feat[i] as usize];
                thrs[l] = thr[i];
            }
            let c = _mm_loadu_si128(codes.as_ptr().cast());
            let t = _mm_loadu_si128(thrs.as_ptr().cast());
            // Unsigned `c > t` as signed compare of bias-flipped lanes.
            let gt = _mm_cmpgt_epi16(_mm_xor_si128(c, bias), _mm_xor_si128(t, bias));
            // i ← 2i + 1 − gt   (gt lanes are 0 or −1)
            idx = _mm_sub_epi16(_mm_add_epi16(_mm_add_epi16(idx, idx), one), gt);
        }
        _mm_storeu_si128(lanes.as_mut_ptr().cast(), idx);
        let n_internal = (1u32 << depth) - 1;
        for (o, &lane) in out.iter_mut().zip(&lanes) {
            *o = lane as u32 - n_internal;
        }
    }

    /// Sixteen rows (`r .. r + 16`) in lockstep on 256-bit vectors;
    /// writes leaf indices into `out[0..16]`.
    ///
    /// # Safety
    /// The **only** soundness precondition is the CPU feature: the
    /// caller must verify AVX2 support before calling (route through
    /// `Tier::clamp_detected`); calling without it is immediate UB
    /// (`#[target_feature]`). There is no memory precondition — every
    /// slice access is bounds-checked indexing that panics rather than
    /// reading out of bounds, and the vector loads/stores touch only
    /// the local fixed-size lane arrays (`lanes`/`codes`/`thrs`,
    /// 16 × u16 each). Correctness (not safety) additionally wants
    /// `out.len() >= 16`: fewer lanes are silently left unwritten.
    #[target_feature(enable = "avx2")]
    pub unsafe fn descend16_avx2(
        feat: &[u16],
        thr: &[u16],
        depth: usize,
        xb: &[u16],
        nf: usize,
        r: usize,
        out: &mut [u32],
    ) {
        let bias = _mm256_set1_epi16(i16::MIN);
        let one = _mm256_set1_epi16(1);
        let mut idx = _mm256_setzero_si256();
        let mut lanes = [0u16; 16];
        let mut codes = [0u16; 16];
        let mut thrs = [0u16; 16];
        for _ in 0..depth {
            _mm256_storeu_si256(lanes.as_mut_ptr().cast(), idx);
            for l in 0..16 {
                let i = lanes[l] as usize;
                codes[l] = xb[(r + l) * nf + feat[i] as usize];
                thrs[l] = thr[i];
            }
            let c = _mm256_loadu_si256(codes.as_ptr().cast());
            let t = _mm256_loadu_si256(thrs.as_ptr().cast());
            let gt = _mm256_cmpgt_epi16(_mm256_xor_si256(c, bias), _mm256_xor_si256(t, bias));
            idx = _mm256_sub_epi16(_mm256_add_epi16(_mm256_add_epi16(idx, idx), one), gt);
        }
        _mm256_storeu_si256(lanes.as_mut_ptr().cast(), idx);
        let n_internal = (1u32 << depth) - 1;
        for (o, &lane) in out.iter_mut().zip(&lanes) {
            *o = lane as u32 - n_internal;
        }
    }

    /// Gather twin of [`descend8_sse2`]: lane `l` walks row `rows[l]`.
    ///
    /// # Safety
    /// The **only** soundness precondition is the CPU feature: SSE2,
    /// architecturally guaranteed on x86-64. No memory precondition —
    /// the row indirection `xb[rows[l] as usize * nf + feat[i]]` and
    /// `thr[i]` are bounds-checked indexing (an out-of-range `rows[l]`
    /// panics, never reads out of bounds), and vector loads/stores
    /// touch only the local fixed-size lane arrays. Correctness (not
    /// safety) wants `rows.len() >= 8` and `out.len() >= 8`.
    #[inline]
    pub unsafe fn descend8_sse2_gather(
        feat: &[u16],
        thr: &[u16],
        depth: usize,
        xb: &[u16],
        nf: usize,
        rows: &[u32],
        out: &mut [u32],
    ) {
        let bias = _mm_set1_epi16(i16::MIN);
        let one = _mm_set1_epi16(1);
        let mut idx = _mm_setzero_si128();
        let mut lanes = [0u16; 8];
        let mut codes = [0u16; 8];
        let mut thrs = [0u16; 8];
        for _ in 0..depth {
            _mm_storeu_si128(lanes.as_mut_ptr().cast(), idx);
            for l in 0..8 {
                let i = lanes[l] as usize;
                codes[l] = xb[rows[l] as usize * nf + feat[i] as usize];
                thrs[l] = thr[i];
            }
            let c = _mm_loadu_si128(codes.as_ptr().cast());
            let t = _mm_loadu_si128(thrs.as_ptr().cast());
            let gt = _mm_cmpgt_epi16(_mm_xor_si128(c, bias), _mm_xor_si128(t, bias));
            idx = _mm_sub_epi16(_mm_add_epi16(_mm_add_epi16(idx, idx), one), gt);
        }
        _mm_storeu_si128(lanes.as_mut_ptr().cast(), idx);
        let n_internal = (1u32 << depth) - 1;
        for (o, &lane) in out.iter_mut().zip(&lanes) {
            *o = lane as u32 - n_internal;
        }
    }

    /// Gather twin of [`descend16_avx2`]: lane `l` walks row `rows[l]`.
    ///
    /// # Safety
    /// The **only** soundness precondition is the CPU feature: the
    /// caller must verify AVX2 support before calling (route through
    /// `Tier::clamp_detected`); calling without it is immediate UB
    /// (`#[target_feature]`). No memory precondition — the row
    /// indirection and slot lookups are bounds-checked indexing (an
    /// out-of-range `rows[l]` panics, never reads out of bounds), and
    /// vector loads/stores touch only the local fixed-size lane
    /// arrays. Correctness (not safety) wants `rows.len() >= 16` and
    /// `out.len() >= 16`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn descend16_avx2_gather(
        feat: &[u16],
        thr: &[u16],
        depth: usize,
        xb: &[u16],
        nf: usize,
        rows: &[u32],
        out: &mut [u32],
    ) {
        let bias = _mm256_set1_epi16(i16::MIN);
        let one = _mm256_set1_epi16(1);
        let mut idx = _mm256_setzero_si256();
        let mut lanes = [0u16; 16];
        let mut codes = [0u16; 16];
        let mut thrs = [0u16; 16];
        for _ in 0..depth {
            _mm256_storeu_si256(lanes.as_mut_ptr().cast(), idx);
            for l in 0..16 {
                let i = lanes[l] as usize;
                codes[l] = xb[rows[l] as usize * nf + feat[i] as usize];
                thrs[l] = thr[i];
            }
            let c = _mm256_loadu_si256(codes.as_ptr().cast());
            let t = _mm256_loadu_si256(thrs.as_ptr().cast());
            let gt = _mm256_cmpgt_epi16(_mm256_xor_si256(c, bias), _mm256_xor_si256(t, bias));
            idx = _mm256_sub_epi16(_mm256_add_epi16(_mm256_add_epi16(idx, idx), one), gt);
        }
        _mm256_storeu_si256(lanes.as_mut_ptr().cast(), idx);
        let n_internal = (1u32 << depth) - 1;
        for (o, &lane) in out.iter_mut().zip(&lanes) {
            *o = lane as u32 - n_internal;
        }
    }

    /// Eight rows (`r .. r + 8`) through an oblivious tree: per level,
    /// one broadcast threshold, one shared-column code load, one vector
    /// compare, one shift — no per-lane node fetches. Writes
    /// leaf-*table* indices (`0 .. 2^d`) into `out[0..8]`.
    ///
    /// # Safety
    /// The **only** soundness precondition is the CPU feature: SSE2,
    /// architecturally guaranteed on x86-64 (the only target this
    /// module compiles for). There is no memory precondition — every
    /// slice access (`xb[(r + l) * nf + f]`) is bounds-checked indexing
    /// that panics on out-of-range inputs rather than reading out of
    /// bounds, and the vector loads/stores touch only the local
    /// fixed-size lane arrays (`codes`/`lanes`, 8 × u16 each).
    /// Correctness (not safety) additionally wants `out.len() >= 8`:
    /// fewer lanes are silently left unwritten by the `zip`.
    #[inline]
    pub unsafe fn oblivious8_sse2(
        feat: &[u16],
        thr: &[u16],
        xb: &[u16],
        nf: usize,
        r: usize,
        out: &mut [u32],
    ) {
        let bias = _mm_set1_epi16(i16::MIN);
        let mut idx = _mm_setzero_si128();
        let mut codes = [0u16; 8];
        for (&f, &t) in feat.iter().zip(thr) {
            let f = f as usize;
            for (l, c) in codes.iter_mut().enumerate() {
                *c = xb[(r + l) * nf + f];
            }
            let c = _mm_loadu_si128(codes.as_ptr().cast());
            let tv = _mm_xor_si128(_mm_set1_epi16(t as i16), bias);
            // Unsigned `c > t` as signed compare of bias-flipped lanes;
            // gt lanes are −1, so the subtract shifts the bit in:
            // idx ← 2·idx + (c > t).
            let gt = _mm_cmpgt_epi16(_mm_xor_si128(c, bias), tv);
            idx = _mm_sub_epi16(_mm_add_epi16(idx, idx), gt);
        }
        let mut lanes = [0u16; 8];
        _mm_storeu_si128(lanes.as_mut_ptr().cast(), idx);
        for (o, &lane) in out.iter_mut().zip(&lanes) {
            *o = lane as u32;
        }
    }

    /// Sixteen rows (`r .. r + 16`) through an oblivious tree on
    /// 256-bit vectors; writes leaf-*table* indices into `out[0..16]`.
    ///
    /// # Safety
    /// The **only** soundness precondition is the CPU feature: the
    /// caller must verify AVX2 support before calling (route through
    /// `Tier::clamp_detected`); calling without it is immediate UB
    /// (`#[target_feature]`). There is no memory precondition — every
    /// slice access is bounds-checked indexing that panics rather than
    /// reading out of bounds, and the vector loads/stores touch only
    /// the local fixed-size lane arrays (`codes`/`lanes`, 16 × u16
    /// each). Correctness (not safety) additionally wants
    /// `out.len() >= 16`: fewer lanes are silently left unwritten.
    #[target_feature(enable = "avx2")]
    pub unsafe fn oblivious16_avx2(
        feat: &[u16],
        thr: &[u16],
        xb: &[u16],
        nf: usize,
        r: usize,
        out: &mut [u32],
    ) {
        let bias = _mm256_set1_epi16(i16::MIN);
        let mut idx = _mm256_setzero_si256();
        let mut codes = [0u16; 16];
        for (&f, &t) in feat.iter().zip(thr) {
            let f = f as usize;
            for (l, c) in codes.iter_mut().enumerate() {
                *c = xb[(r + l) * nf + f];
            }
            let c = _mm256_loadu_si256(codes.as_ptr().cast());
            let tv = _mm256_xor_si256(_mm256_set1_epi16(t as i16), bias);
            let gt = _mm256_cmpgt_epi16(_mm256_xor_si256(c, bias), tv);
            idx = _mm256_sub_epi16(_mm256_add_epi16(idx, idx), gt);
        }
        let mut lanes = [0u16; 16];
        _mm256_storeu_si256(lanes.as_mut_ptr().cast(), idx);
        for (o, &lane) in out.iter_mut().zip(&lanes) {
            *o = lane as u32;
        }
    }

    /// Gather twin of [`oblivious8_sse2`]: lane `l` walks row `rows[l]`.
    ///
    /// # Safety
    /// The **only** soundness precondition is the CPU feature: SSE2,
    /// architecturally guaranteed on x86-64. No memory precondition —
    /// the row indirection `xb[rows[l] as usize * nf + f]` is
    /// bounds-checked indexing (an out-of-range `rows[l]` panics, never
    /// reads out of bounds), and vector loads/stores touch only the
    /// local fixed-size lane arrays. Correctness (not safety) wants
    /// `rows.len() >= 8` and `out.len() >= 8`.
    #[inline]
    pub unsafe fn oblivious8_sse2_gather(
        feat: &[u16],
        thr: &[u16],
        xb: &[u16],
        nf: usize,
        rows: &[u32],
        out: &mut [u32],
    ) {
        let bias = _mm_set1_epi16(i16::MIN);
        let mut idx = _mm_setzero_si128();
        let mut codes = [0u16; 8];
        for (&f, &t) in feat.iter().zip(thr) {
            let f = f as usize;
            for (l, c) in codes.iter_mut().enumerate() {
                *c = xb[rows[l] as usize * nf + f];
            }
            let c = _mm_loadu_si128(codes.as_ptr().cast());
            let tv = _mm_xor_si128(_mm_set1_epi16(t as i16), bias);
            let gt = _mm_cmpgt_epi16(_mm_xor_si128(c, bias), tv);
            idx = _mm_sub_epi16(_mm_add_epi16(idx, idx), gt);
        }
        let mut lanes = [0u16; 8];
        _mm_storeu_si128(lanes.as_mut_ptr().cast(), idx);
        for (o, &lane) in out.iter_mut().zip(&lanes) {
            *o = lane as u32;
        }
    }

    /// Gather twin of [`oblivious16_avx2`]: lane `l` walks row
    /// `rows[l]`.
    ///
    /// # Safety
    /// The **only** soundness precondition is the CPU feature: the
    /// caller must verify AVX2 support before calling (route through
    /// `Tier::clamp_detected`); calling without it is immediate UB
    /// (`#[target_feature]`). No memory precondition — the row
    /// indirection is bounds-checked indexing (an out-of-range
    /// `rows[l]` panics, never reads out of bounds), and vector
    /// loads/stores touch only the local fixed-size lane arrays.
    /// Correctness (not safety) wants `rows.len() >= 16` and
    /// `out.len() >= 16`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn oblivious16_avx2_gather(
        feat: &[u16],
        thr: &[u16],
        xb: &[u16],
        nf: usize,
        rows: &[u32],
        out: &mut [u32],
    ) {
        let bias = _mm256_set1_epi16(i16::MIN);
        let mut idx = _mm256_setzero_si256();
        let mut codes = [0u16; 16];
        for (&f, &t) in feat.iter().zip(thr) {
            let f = f as usize;
            for (l, c) in codes.iter_mut().enumerate() {
                *c = xb[rows[l] as usize * nf + f];
            }
            let c = _mm256_loadu_si256(codes.as_ptr().cast());
            let tv = _mm256_xor_si256(_mm256_set1_epi16(t as i16), bias);
            let gt = _mm256_cmpgt_epi16(_mm256_xor_si256(c, bias), tv);
            idx = _mm256_sub_epi16(_mm256_add_epi16(idx, idx), gt);
        }
        let mut lanes = [0u16; 16];
        _mm256_storeu_si256(lanes.as_mut_ptr().cast(), idx);
        for (o, &lane) in out.iter_mut().zip(&lanes) {
            *o = lane as u32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg64;
    use crate::testutil::prop::run_prop;

    /// Reference: per-row scalar routine over the whole block.
    fn oracle(feat: &[u16], thr: &[u16], xb: &[u16], nf: usize, out: &mut [u32]) {
        for (t, o) in out.iter_mut().enumerate() {
            *o = descend_row(feat, thr, &xb[t * nf..(t + 1) * nf]) as u32;
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 80-case property sweep — slow under Miri;
                              // the fixed-input tests below cover the scalar path.
    fn prop_every_tier_matches_the_per_row_oracle() {
        run_prop("simd descent == per-row oracle", 80, |g| {
            let depth = g.usize_in(0, 10);
            let n_internal = (1usize << depth) - 1;
            let nf = g.usize_in(1, 9);
            let mut rng = Pcg64::new(g.case_seed ^ 0xD15);
            // Thresholds mix real ranks with the 0xFFFF pass-through
            // sentinel; codes mix ranks with the 0xFFFF NaN sentinel.
            let feat: Vec<u16> = (0..n_internal).map(|_| rng.gen_range(nf) as u16).collect();
            let thr: Vec<u16> = (0..n_internal)
                .map(|_| {
                    if rng.gen_bool(0.15) {
                        u16::MAX
                    } else {
                        rng.gen_range(300) as u16
                    }
                })
                .collect();
            // Row counts sweep tails of both lane widths (1..=17) and
            // full blocks.
            let n_rows = if g.bool(0.5) { g.usize_in(1, 17) } else { g.usize_in(18, 70) };
            let xb: Vec<u16> = (0..n_rows * nf)
                .map(|_| {
                    if rng.gen_bool(0.1) {
                        u16::MAX
                    } else {
                        rng.gen_range(300) as u16
                    }
                })
                .collect();
            let mut want = vec![0u32; n_rows];
            oracle(&feat, &thr, &xb, nf, &mut want);
            for tier in crate::simd::available_tiers() {
                let mut got = vec![0u32; n_rows];
                descend_complete(tier, &feat, &thr, depth, &xb, nf, &mut got);
                assert_eq!(got, want, "tier {} depth {depth} rows {n_rows}", tier.name());
            }
            // An unsupported forced tier must clamp, not crash.
            let mut clamped = vec![0u32; n_rows];
            descend_complete(Tier::Avx2, &feat, &thr, depth, &xb, nf, &mut clamped);
            assert_eq!(clamped, want);
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 80-case property sweep — slow under Miri;
                              // `gather_with_identity_rows_equals_direct_descent` runs.
    fn prop_gather_variant_matches_oracle_on_arbitrary_row_subsets() {
        run_prop("simd gather descent == per-row oracle", 80, |g| {
            let depth = g.usize_in(0, 10);
            let n_internal = (1usize << depth) - 1;
            let nf = g.usize_in(1, 9);
            let mut rng = Pcg64::new(g.case_seed ^ 0x6A7);
            let feat: Vec<u16> = (0..n_internal).map(|_| rng.gen_range(nf) as u16).collect();
            let thr: Vec<u16> = (0..n_internal)
                .map(|_| {
                    if rng.gen_bool(0.15) {
                        u16::MAX
                    } else {
                        rng.gen_range(300) as u16
                    }
                })
                .collect();
            let n_block = g.usize_in(1, 70);
            let xb: Vec<u16> = (0..n_block * nf)
                .map(|_| {
                    if rng.gen_bool(0.1) {
                        u16::MAX
                    } else {
                        rng.gen_range(300) as u16
                    }
                })
                .collect();
            // An arbitrary subset in arbitrary order, with repeats —
            // exactly what the compacting early-exit caller produces.
            let n_lanes = g.usize_in(0, 70);
            let rows: Vec<u32> =
                (0..n_lanes).map(|_| rng.gen_range(n_block) as u32).collect();
            let want: Vec<u32> = rows
                .iter()
                .map(|&row| {
                    let row = row as usize;
                    descend_row(&feat, &thr, &xb[row * nf..(row + 1) * nf]) as u32
                })
                .collect();
            for tier in crate::simd::available_tiers() {
                let mut got = vec![0u32; n_lanes];
                descend_complete_gather(tier, &feat, &thr, depth, &xb, nf, &rows, &mut got);
                assert_eq!(got, want, "tier {} depth {depth} lanes {n_lanes}", tier.name());
            }
            // An unsupported forced tier must clamp, not crash.
            let mut clamped = vec![0u32; n_lanes];
            descend_complete_gather(Tier::Avx2, &feat, &thr, depth, &xb, nf, &rows, &mut clamped);
            assert_eq!(clamped, want);
        });
    }

    #[test]
    fn gather_with_identity_rows_equals_direct_descent() {
        let depth = 3usize;
        let n_internal = (1usize << depth) - 1;
        let mut rng = Pcg64::new(0xFEED);
        let nf = 4usize;
        let feat: Vec<u16> = (0..n_internal).map(|_| rng.gen_range(nf) as u16).collect();
        let thr: Vec<u16> = (0..n_internal).map(|_| rng.gen_range(40) as u16).collect();
        let n_rows = 37usize;
        let xb: Vec<u16> = (0..n_rows * nf).map(|_| rng.gen_range(40) as u16).collect();
        let rows: Vec<u32> = (0..n_rows as u32).collect();
        for tier in crate::simd::available_tiers() {
            let mut direct = vec![0u32; n_rows];
            descend_complete(tier, &feat, &thr, depth, &xb, nf, &mut direct);
            let mut gathered = vec![0u32; n_rows];
            descend_complete_gather(tier, &feat, &thr, depth, &xb, nf, &rows, &mut gathered);
            assert_eq!(gathered, direct, "tier {}", tier.name());
        }
    }

    #[test]
    fn depth_zero_tree_sends_every_row_to_leaf_zero() {
        let xb = vec![7u16; 24 * 3];
        for tier in crate::simd::available_tiers() {
            let mut out = vec![9u32; 24];
            descend_complete(tier, &[], &[], 0, &xb, 3, &mut out);
            assert!(out.iter().all(|&i| i == 0), "tier {}", tier.name());
        }
    }

    #[test]
    fn empty_block_is_a_no_op() {
        for tier in crate::simd::available_tiers() {
            let mut out: Vec<u32> = Vec::new();
            descend_complete(tier, &[0], &[5], 1, &[], 1, &mut out);
            assert!(out.is_empty());
        }
    }

    #[test]
    fn sentinel_routing_matches_scalar_semantics() {
        // Depth-1 tree on feature 0: rank threshold 5.
        let feat = [0u16];
        let nf = 1usize;
        // code ≤ 5 → left leaf 0; code > 5 (incl. the NaN bin) → leaf 1.
        let thr_real = [5u16];
        // Pass-through slot: every code (incl. NaN bin) routes left.
        let thr_pass = [u16::MAX];
        let xb = [0u16, 5, 6, u16::MAX, 3, 7, u16::MAX, 5, 1];
        for tier in crate::simd::available_tiers() {
            let mut out = vec![0u32; xb.len()];
            descend_complete(tier, &feat, &thr_real, 1, &xb, nf, &mut out);
            assert_eq!(out, [0, 0, 1, 1, 0, 1, 1, 0, 0], "tier {}", tier.name());
            descend_complete(tier, &feat, &thr_pass, 1, &xb, nf, &mut out);
            assert!(out.iter().all(|&i| i == 0), "pass-through must route left");
        }
    }

    /// Replicate per-level splits into the dense complete-tree arrays:
    /// slot `s` takes the split of its level `⌊log₂(s+1)⌋`.
    fn replicate(lfeat: &[u16], lthr: &[u16]) -> (Vec<u16>, Vec<u16>) {
        let d = lfeat.len();
        let n_internal = (1usize << d) - 1;
        let mut feat = Vec::with_capacity(n_internal);
        let mut thr = Vec::with_capacity(n_internal);
        for s in 0..n_internal {
            let lvl = (s + 1).ilog2() as usize;
            feat.push(lfeat[lvl]);
            thr.push(lthr[lvl]);
        }
        (feat, thr)
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 80-case property sweep — slow under Miri;
                              // the fixed-input oblivious tests below run.
    fn prop_oblivious_matches_replicated_complete_descent_on_every_tier() {
        run_prop("oblivious == replicated complete descent", 80, |g| {
            let depth = g.usize_in(1, 10);
            let nf = g.usize_in(1, 9);
            let mut rng = Pcg64::new(g.case_seed ^ 0x0B1);
            let lfeat: Vec<u16> = (0..depth).map(|_| rng.gen_range(nf) as u16).collect();
            let lthr: Vec<u16> = (0..depth).map(|_| rng.gen_range(300) as u16).collect();
            let (rfeat, rthr) = replicate(&lfeat, &lthr);
            // Row counts sweep the ragged tails of both lane widths
            // (1..=17) and full blocks; codes include the NaN bin.
            let n_rows = if g.bool(0.5) { g.usize_in(1, 17) } else { g.usize_in(18, 70) };
            let xb: Vec<u16> = (0..n_rows * nf)
                .map(|_| {
                    if rng.gen_bool(0.1) {
                        u16::MAX
                    } else {
                        rng.gen_range(300) as u16
                    }
                })
                .collect();
            // Oracle: the general kernel on the replicated dense tree.
            // Its leaf index equals the oblivious d-bit table index
            // (both are Σ bitℓ · 2^(d−1−ℓ)).
            let mut want = vec![0u32; n_rows];
            descend_complete(Tier::Scalar, &rfeat, &rthr, depth, &xb, nf, &mut want);
            for tier in crate::simd::available_tiers() {
                let mut got = vec![0u32; n_rows];
                descend_oblivious(tier, &lfeat, &lthr, &xb, nf, &mut got);
                assert_eq!(got, want, "tier {} depth {depth} rows {n_rows}", tier.name());
            }
            // Gather twin over an arbitrary row subset with repeats.
            let n_lanes = g.usize_in(0, 40);
            let rows: Vec<u32> =
                (0..n_lanes).map(|_| rng.gen_range(n_rows) as u32).collect();
            let want_g: Vec<u32> = rows
                .iter()
                .map(|&row| {
                    let row = row as usize;
                    descend_oblivious_row(&lfeat, &lthr, &xb[row * nf..(row + 1) * nf]) as u32
                })
                .collect();
            for tier in crate::simd::available_tiers() {
                let mut got = vec![0u32; n_lanes];
                descend_oblivious_gather(tier, &lfeat, &lthr, &xb, nf, &rows, &mut got);
                assert_eq!(got, want_g, "gather tier {} depth {depth}", tier.name());
            }
            // An unsupported forced tier must clamp, not crash.
            let mut clamped = vec![0u32; n_rows];
            descend_oblivious(Tier::Avx2, &lfeat, &lthr, &xb, nf, &mut clamped);
            assert_eq!(clamped, want);
        });
    }

    #[test]
    fn oblivious_bit_order_is_msb_first_root_level() {
        // Levels: (f0 > 5), (f1 > 10). Root level is the high bit.
        let lfeat = [0u16, 1];
        let lthr = [5u16, 10];
        let nf = 2usize;
        // Rows chosen to hit all four cells; NaN bin takes the 1 bit.
        let xb = [
            0u16, 0, // 00 → 0
            0, 11, // 01 → 1
            6, 0, // 10 → 2
            6, 11, // 11 → 3
            u16::MAX,
            u16::MAX, // NaN row → 3
        ];
        for tier in crate::simd::available_tiers() {
            let mut out = vec![0u32; 5];
            descend_oblivious(tier, &lfeat, &lthr, &xb, nf, &mut out);
            assert_eq!(out, [0, 1, 2, 3, 3], "tier {}", tier.name());
        }
    }

    #[test]
    fn oblivious_depth_zero_is_leaf_zero() {
        let xb = vec![7u16; 24 * 3];
        for tier in crate::simd::available_tiers() {
            let mut out = vec![9u32; 24];
            descend_oblivious(tier, &[], &[], &xb, 3, &mut out);
            assert!(out.iter().all(|&i| i == 0), "tier {}", tier.name());
        }
    }
}
