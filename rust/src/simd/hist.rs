//! Vectorized histogram accumulation over `u8`/`u16` bin-code columns.
//!
//! One histogram update is `triples[3·(off + code)] += (g, h, 1)` —
//! a scattered read-modify-write that cannot be vectorized naively,
//! because two rows of the same leaf can land in the same bin (a
//! vector scatter would need conflict detection, and reordering the
//! adds would break the bit-parity contract with the scalar oracle).
//! What *does* vectorize profitably:
//!
//! * **code streaming** — the dense path loads a full lane group of
//!   contiguous codes per iteration (16 `u8`s in one 128-bit load);
//!   the gathered path fills the same lane buffer with a software
//!   gather (`col[rows[j]]`; a hardware gather reads 32-bit elements
//!   and would over-read past the end of sub-32-bit arrays);
//! * **offset arithmetic** — widening `u8`/`u16` codes to `u32` and
//!   computing `3·code` happens entirely in vector registers, so the
//!   scalar scatter loop receives ready-made triple offsets and is
//!   pure read-modify-write;
//! * the scatter itself applies the `(g, h, 1)` bumps **in row
//!   order**, which is what keeps every tier bit-identical to
//!   [`HistogramSet::build_scalar`](crate::gbdt::histogram::HistogramSet::build_scalar)
//!   (property-tested in `tests/histogram_parity.rs`).
//!
//! The kernels are monomorphized per code width via the sealed
//! [`Code`] trait (`u8` for the common `max_bins ≤ 256` arena, `u16`
//! for wide features), mirroring `BinMatrix::columns` dispatch. The
//! scalar tier runs the 4-way unrolled twins — the exact loops the
//! histogram build shipped before this module existed.

use super::Tier;

mod sealed {
    pub trait Sealed {}
    impl Sealed for u8 {}
    impl Sealed for u16 {}
}

/// Bin-code element width of the `BinMatrix` arena: `u8` or `u16`
/// (sealed — the SIMD kernels pun slices through raw pointers based on
/// [`Code::IS_U8`], which is only sound for exactly these two types).
pub trait Code: sealed::Sealed + Copy + 'static {
    /// Whether this is the `u8` arena (`false` ⇒ `u16`).
    const IS_U8: bool;
    /// Lane-buffer initializer for the software gather.
    const ZERO: Self;
    /// The code as a bin index.
    fn idx(self) -> usize;
}

impl Code for u8 {
    const IS_U8: bool = true;
    const ZERO: u8 = 0;
    #[inline(always)]
    fn idx(self) -> usize {
        self as usize
    }
}

impl Code for u16 {
    const IS_U8: bool = false;
    const ZERO: u16 = 0;
    #[inline(always)]
    fn idx(self) -> usize {
        self as usize
    }
}

/// Add one `(grad, hess, count)` update at triple-offset `b`.
///
/// The single slice reborrow keeps this to one bounds check per update;
/// the caller guarantees `b` is a multiple of 3 derived from an
/// in-range bin (the `BinMatrix` invariant: `bin(f, i) < n_bins(f)`).
#[inline(always)]
fn bump(data: &mut [f64], b: usize, g: f64, h: f64) {
    let t = &mut data[b..b + 3];
    t[0] += g;
    t[1] += h;
    t[2] += 1.0;
}

/// Apply one lane group of bumps in row order. `off3[j]` is `3·code`
/// of the group's `j`-th row; `base3` is the feature's triple base.
#[inline(always)]
fn scatter(data: &mut [f64], base3: usize, off3: &[u32], g: &[f64], h: &[f64]) {
    for ((&o, &gj), &hj) in off3.iter().zip(g).zip(h) {
        bump(data, base3 + o as usize, gj, hj);
    }
}

/// Dense accumulation: every row of `col` contributes; `grad`/`hess`
/// are read sequentially. Tier-dispatched; all tiers bit-identical.
pub fn accumulate_dense<T: Code>(
    tier: Tier,
    data: &mut [f64],
    off: usize,
    col: &[T],
    grad: &[f64],
    hess: &[f64],
) {
    debug_assert_eq!(col.len(), grad.len());
    debug_assert_eq!(col.len(), hess.len());
    let n = col.len();
    let base3 = 3 * off;
    // Lane-group body, dispatched per tier; returns the tail start.
    let mut i = {
        #[cfg(target_arch = "x86_64")]
        {
            dense_groups_x86(tier, data, base3, col, grad, hess)
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            let _ = tier;
            dense_scalar_unrolled(data, base3, col, grad, hess)
        }
    };
    while i < n {
        bump(data, base3 + 3 * col[i].idx(), grad[i], hess[i]);
        i += 1;
    }
}

/// x86-64 lane-group dispatch of the dense path; returns the first row
/// not processed.
#[cfg(target_arch = "x86_64")]
fn dense_groups_x86<T: Code>(
    tier: Tier,
    data: &mut [f64],
    base3: usize,
    col: &[T],
    grad: &[f64],
    hess: &[f64],
) -> usize {
    let n = col.len();
    let mut i = 0usize;
    match tier.clamp_detected() {
        Tier::Avx2 => {
            let mut off3 = [0u32; 16];
            while i + 16 <= n {
                // SAFETY: AVX2 verified by clamp_detected; the loop
                // guard `i + 16 <= n` makes `col[i..]` ≥ 16 codes long.
                unsafe { x86::offsets16_avx2::<T>(&col[i..], &mut off3) };
                scatter(data, base3, &off3, &grad[i..i + 16], &hess[i..i + 16]);
                i += 16;
            }
            if i + 8 <= n {
                let mut off8 = [0u32; 8];
                // SAFETY: SSE2 is baseline on x86-64; the branch guard
                // `i + 8 <= n` makes `col[i..]` ≥ 8 codes long.
                unsafe { x86::offsets8_sse2::<T>(&col[i..], &mut off8) };
                scatter(data, base3, &off8, &grad[i..i + 8], &hess[i..i + 8]);
                i += 8;
            }
            i
        }
        Tier::Sse2 => {
            let mut off3 = [0u32; 8];
            while i + 8 <= n {
                // SAFETY: SSE2 is baseline on x86-64; the loop guard
                // `i + 8 <= n` makes `col[i..]` ≥ 8 codes long.
                unsafe { x86::offsets8_sse2::<T>(&col[i..], &mut off3) };
                scatter(data, base3, &off3, &grad[i..i + 8], &hess[i..i + 8]);
                i += 8;
            }
            i
        }
        Tier::Scalar => dense_scalar_unrolled(data, base3, col, grad, hess),
    }
}

/// Subset accumulation over gathered statistics: `og[j]`/`oh[j]` are
/// the grad/hess of row `rows[j]`, read sequentially; the bin lookup
/// `col[rows[j]]` is a software gather into the lane buffer.
/// Tier-dispatched; all tiers bit-identical.
pub fn accumulate_gathered<T: Code>(
    tier: Tier,
    data: &mut [f64],
    off: usize,
    col: &[T],
    rows: &[u32],
    og: &[f64],
    oh: &[f64],
) {
    debug_assert_eq!(rows.len(), og.len());
    debug_assert_eq!(rows.len(), oh.len());
    let n = rows.len();
    let base3 = 3 * off;
    // Lane-group body, dispatched per tier; returns the tail start.
    let mut j = {
        #[cfg(target_arch = "x86_64")]
        {
            gathered_groups_x86(tier, data, base3, col, rows, og, oh)
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            let _ = tier;
            gathered_scalar_unrolled(data, base3, col, rows, og, oh)
        }
    };
    while j < n {
        bump(data, base3 + 3 * col[rows[j] as usize].idx(), og[j], oh[j]);
        j += 1;
    }
}

/// x86-64 lane-group dispatch of the gathered path; returns the first
/// row not processed.
#[cfg(target_arch = "x86_64")]
fn gathered_groups_x86<T: Code>(
    tier: Tier,
    data: &mut [f64],
    base3: usize,
    col: &[T],
    rows: &[u32],
    og: &[f64],
    oh: &[f64],
) -> usize {
    let n = rows.len();
    let mut j = 0usize;
    match tier.clamp_detected() {
        Tier::Avx2 => {
            let mut codes = [T::ZERO; 16];
            let mut off3 = [0u32; 16];
            while j + 16 <= n {
                for (c, &r) in codes.iter_mut().zip(&rows[j..j + 16]) {
                    *c = col[r as usize];
                }
                // SAFETY: AVX2 verified by clamp_detected; the lane
                // buffer is a `[T; 16]`, exactly the 16 codes required.
                unsafe { x86::offsets16_avx2::<T>(&codes, &mut off3) };
                scatter(data, base3, &off3, &og[j..j + 16], &oh[j..j + 16]);
                j += 16;
            }
            if j + 8 <= n {
                let mut off8 = [0u32; 8];
                for (c, &r) in codes.iter_mut().take(8).zip(&rows[j..j + 8]) {
                    *c = col[r as usize];
                }
                // SAFETY: SSE2 is baseline on x86-64; the lane buffer
                // is a `[T; 16]`, more than the 8 codes required.
                unsafe { x86::offsets8_sse2::<T>(&codes, &mut off8) };
                scatter(data, base3, &off8, &og[j..j + 8], &oh[j..j + 8]);
                j += 8;
            }
            j
        }
        Tier::Sse2 => {
            let mut codes = [T::ZERO; 8];
            let mut off3 = [0u32; 8];
            while j + 8 <= n {
                for (c, &r) in codes.iter_mut().zip(&rows[j..j + 8]) {
                    *c = col[r as usize];
                }
                // SAFETY: SSE2 is baseline on x86-64; the lane buffer
                // is a `[T; 8]`, exactly the 8 codes required.
                unsafe { x86::offsets8_sse2::<T>(&codes, &mut off3) };
                scatter(data, base3, &off3, &og[j..j + 8], &oh[j..j + 8]);
                j += 8;
            }
            j
        }
        Tier::Scalar => gathered_scalar_unrolled(data, base3, col, rows, og, oh),
    }
}

/// Scalar tier of the dense path: the 4-way unrolled loop the build
/// shipped with before the SIMD layer (four independent bin updates in
/// flight). Returns the first row not processed.
fn dense_scalar_unrolled<T: Code>(
    data: &mut [f64],
    base3: usize,
    col: &[T],
    grad: &[f64],
    hess: &[f64],
) -> usize {
    let n = col.len();
    let mut i = 0usize;
    while i + 4 <= n {
        let b0 = base3 + 3 * col[i].idx();
        let b1 = base3 + 3 * col[i + 1].idx();
        let b2 = base3 + 3 * col[i + 2].idx();
        let b3 = base3 + 3 * col[i + 3].idx();
        bump(data, b0, grad[i], hess[i]);
        bump(data, b1, grad[i + 1], hess[i + 1]);
        bump(data, b2, grad[i + 2], hess[i + 2]);
        bump(data, b3, grad[i + 3], hess[i + 3]);
        i += 4;
    }
    i
}

/// Scalar tier of the gathered path: 4-way unrolled like
/// [`dense_scalar_unrolled`]. Returns the first row not processed.
fn gathered_scalar_unrolled<T: Code>(
    data: &mut [f64],
    base3: usize,
    col: &[T],
    rows: &[u32],
    og: &[f64],
    oh: &[f64],
) -> usize {
    let n = rows.len();
    let mut j = 0usize;
    while j + 4 <= n {
        let b0 = base3 + 3 * col[rows[j] as usize].idx();
        let b1 = base3 + 3 * col[rows[j + 1] as usize].idx();
        let b2 = base3 + 3 * col[rows[j + 2] as usize].idx();
        let b3 = base3 + 3 * col[rows[j + 3] as usize].idx();
        bump(data, b0, og[j], oh[j]);
        bump(data, b1, og[j + 1], oh[j + 1]);
        bump(data, b2, og[j + 2], oh[j + 2]);
        bump(data, b3, og[j + 3], oh[j + 3]);
        j += 4;
    }
    j
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::Code;
    use core::arch::x86_64::*;

    /// Widen the first 8 codes of `codes` to `u32` and store `3·code`
    /// into `out`.
    ///
    /// # Safety
    /// The caller must ensure the CPU supports SSE2 — architecturally
    /// guaranteed on x86-64, the only target this module compiles for —
    /// and that `codes.len() >= 8`. The kernel performs exactly one
    /// unaligned vector load of the first 8 elements (8 bytes for
    /// `u8`, 16 bytes for `u16` — `T` is sealed to those two widths)
    /// and never reads past them.
    #[inline]
    pub unsafe fn offsets8_sse2<T: Code>(codes: &[T], out: &mut [u32; 8]) {
        debug_assert!(codes.len() >= 8, "offsets8_sse2 needs 8 codes, got {}", codes.len());
        let codes = codes.as_ptr();
        let z = _mm_setzero_si128();
        // u16x8 lane group, whichever the source width.
        let w = if T::IS_U8 {
            let v = _mm_loadl_epi64(codes.cast()); // 8 bytes
            _mm_unpacklo_epi8(v, z)
        } else {
            _mm_loadu_si128(codes.cast()) // 8 u16s
        };
        let lo = _mm_unpacklo_epi16(w, z); // u32x4
        let hi = _mm_unpackhi_epi16(w, z); // u32x4
        // 3x = x + (x + x): no multiply unit needed on SSE2.
        let lo3 = _mm_add_epi32(lo, _mm_add_epi32(lo, lo));
        let hi3 = _mm_add_epi32(hi, _mm_add_epi32(hi, hi));
        _mm_storeu_si128(out.as_mut_ptr().cast(), lo3);
        _mm_storeu_si128(out.as_mut_ptr().add(4).cast(), hi3);
    }

    /// Widen the first 16 codes of `codes` to `u32` and store `3·code`
    /// into `out`.
    ///
    /// # Safety
    /// The caller must verify the CPU supports AVX2 before calling
    /// (route through `Tier::clamp_detected`); calling without it is
    /// immediate UB (`#[target_feature]`). `codes.len() >= 16`: the
    /// kernel reads exactly the first 16 elements (16 bytes for `u8`
    /// in one load, 32 bytes for `u16` in two — `T` is sealed to those
    /// two widths) and never past them.
    #[target_feature(enable = "avx2")]
    pub unsafe fn offsets16_avx2<T: Code>(codes: &[T], out: &mut [u32; 16]) {
        debug_assert!(codes.len() >= 16, "offsets16_avx2 needs 16 codes, got {}", codes.len());
        let codes = codes.as_ptr();
        let (lo, hi) = if T::IS_U8 {
            let v = _mm_loadu_si128(codes.cast()); // 16 bytes
            let w = _mm256_cvtepu8_epi16(v); // u16x16
            (
                _mm256_cvtepu16_epi32(_mm256_castsi256_si128(w)),
                _mm256_cvtepu16_epi32(_mm256_extracti128_si256::<1>(w)),
            )
        } else {
            (
                _mm256_cvtepu16_epi32(_mm_loadu_si128(codes.cast())),
                _mm256_cvtepu16_epi32(_mm_loadu_si128(codes.cast::<__m128i>().add(1))),
            )
        };
        let lo3 = _mm256_add_epi32(lo, _mm256_add_epi32(lo, lo));
        let hi3 = _mm256_add_epi32(hi, _mm256_add_epi32(hi, hi));
        _mm256_storeu_si256(out.as_mut_ptr().cast(), lo3);
        _mm256_storeu_si256(out.as_mut_ptr().cast::<__m256i>().add(1), hi3);
    }
}

/// Portable raw-pointer twin of the vector kernels' memory access:
/// one unaligned-capable read per lane starting at the slice head,
/// `3·code` widened to `u32`. Exists so Miri can check the pointer
/// discipline the `x86` kernels rely on (same provenance, same
/// bounds) without executing vendor intrinsics; the non-Miri test
/// additionally pins it bit-equal to the real kernels.
#[cfg(test)]
fn offsets_ptr_model<T: Code>(codes: &[T], out: &mut [u32]) {
    assert!(codes.len() >= out.len(), "lane group larger than the code slice");
    let p = codes.as_ptr();
    for (j, o) in out.iter_mut().enumerate() {
        // SAFETY: `j < out.len() <= codes.len()`, so `p.add(j)` stays
        // inside the slice's allocation and points at an initialized
        // `T`; `read` is an unaligned-safe copy of a `Copy` type here
        // because `T` (u8/u16) always meets its own alignment inside
        // a slice.
        *o = 3 * unsafe { p.add(j).read() }.idx() as u32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg64;
    use crate::testutil::prop::run_prop;

    /// Reference: the one-update-per-row scalar loop.
    fn oracle<T: Code>(
        data: &mut [f64],
        off: usize,
        col: &[T],
        rows: &[u32],
        g: &[f64],
        h: &[f64],
    ) {
        for &r in rows {
            let r = r as usize;
            let b = 3 * (off + col[r].idx());
            data[b] += g[r];
            data[b + 1] += h[r];
            data[b + 2] += 1.0;
        }
    }

    fn check_width<T: Code + From<u8>>(g: &mut crate::testutil::prop::Gen, n_bins: usize) {
        let n = g.usize_in(1, 120);
        let mut rng = Pcg64::new(g.case_seed ^ 0xA1);
        let col: Vec<T> =
            (0..n).map(|_| T::from(rng.gen_range(n_bins.min(256)) as u8)).collect();
        let grad: Vec<f64> = (0..n).map(|_| rng.gen_normal()).collect();
        let hess: Vec<f64> = (0..n).map(|_| rng.gen_uniform(0.01, 2.0)).collect();
        let all: Vec<u32> = (0..n as u32).collect();
        let subset: Vec<u32> = all.iter().copied().filter(|_| rng.gen_bool(0.5)).collect();

        let mut want = vec![0.0f64; 3 * (n_bins + 4)];
        oracle(&mut want, 1, &col, &all, &grad, &hess);
        for tier in crate::simd::available_tiers() {
            let mut got = vec![0.0f64; 3 * (n_bins + 4)];
            accumulate_dense(tier, &mut got, 1, &col, &grad, &hess);
            assert_bits(&want, &got, tier);
        }

        let og: Vec<f64> = subset.iter().map(|&r| grad[r as usize]).collect();
        let oh: Vec<f64> = subset.iter().map(|&r| hess[r as usize]).collect();
        let mut want = vec![0.0f64; 3 * (n_bins + 4)];
        oracle(&mut want, 1, &col, &subset, &grad, &hess);
        for tier in crate::simd::available_tiers() {
            let mut got = vec![0.0f64; 3 * (n_bins + 4)];
            accumulate_gathered(tier, &mut got, 1, &col, &subset, &og, &oh);
            assert_bits(&want, &got, tier);
        }
    }

    fn assert_bits(want: &[f64], got: &[f64], tier: Tier) {
        for (i, (w, g)) in want.iter().zip(got).enumerate() {
            assert_eq!(w.to_bits(), g.to_bits(), "tier {} slot {i}: {w} vs {g}", tier.name());
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 60-case property sweep — slow under Miri;
                              // the pointer-model test below is the Miri twin.
    fn prop_every_tier_matches_the_scalar_oracle() {
        run_prop("simd histogram == scalar oracle", 60, |g| {
            check_width::<u8>(g, 37);
            check_width::<u16>(g, 37);
        });
    }

    /// Miri-runnable: the raw-pointer lane walk agrees with safe
    /// indexing at every offset and both code widths, and (off Miri,
    /// on x86-64) bit-matches the vector kernels it models.
    #[test]
    fn pointer_model_matches_safe_indexing_and_kernels() {
        let codes8: Vec<u8> = (0..40u16).map(|i| (i * 7 % 251) as u8).collect();
        let codes16: Vec<u16> = (0..40u16).map(|i| i * 331 % 1021).collect();

        fn check<T: Code>(codes: &[T]) {
            for lanes in [8usize, 16] {
                for start in 0..=(codes.len() - lanes) {
                    let mut got = vec![0u32; lanes];
                    offsets_ptr_model(&codes[start..], &mut got);
                    let want: Vec<u32> =
                        codes[start..start + lanes].iter().map(|c| 3 * c.idx() as u32).collect();
                    assert_eq!(got, want, "lanes {lanes} start {start}");

                    #[cfg(all(target_arch = "x86_64", not(miri)))]
                    {
                        let tier = crate::simd::tier();
                        if lanes == 8 && tier >= Tier::Sse2 {
                            let mut out = [0u32; 8];
                            // SAFETY: SSE2 is baseline on x86-64 and the
                            // slice `codes[start..]` is ≥ 8 codes long
                            // by the loop bound.
                            unsafe { x86::offsets8_sse2::<T>(&codes[start..], &mut out) };
                            assert_eq!(&out[..], &want[..], "sse2 start {start}");
                        }
                        if lanes == 16 && tier >= Tier::Avx2 {
                            let mut out = [0u32; 16];
                            // SAFETY: AVX2 detected (tier check above);
                            // the slice `codes[start..]` is ≥ 16 codes
                            // long by the loop bound.
                            unsafe { x86::offsets16_avx2::<T>(&codes[start..], &mut out) };
                            assert_eq!(&out[..], &want[..], "avx2 start {start}");
                        }
                    }
                }
            }
        }
        check(&codes8);
        check(&codes16);
    }

    #[test]
    fn empty_and_single_row_inputs() {
        for tier in crate::simd::available_tiers() {
            let mut data = vec![0.0f64; 9];
            accumulate_dense::<u8>(tier, &mut data, 0, &[], &[], &[]);
            assert!(data.iter().all(|&v| v == 0.0));
            accumulate_dense::<u8>(tier, &mut data, 0, &[2], &[1.5], &[0.5]);
            assert_eq!(&data[6..9], &[1.5, 0.5, 1.0]);
            let mut data = vec![0.0f64; 9];
            accumulate_gathered::<u16>(tier, &mut data, 0, &[9, 1, 9], &[1], &[2.0], &[3.0]);
            assert_eq!(&data[3..6], &[2.0, 3.0, 1.0]);
        }
    }
}
