//! Explicit SIMD kernels with runtime CPU dispatch.
//!
//! The two hottest loops in the system — the quantized engine's
//! complete-tree descent (`inference::quantized`) and histogram
//! accumulation (`gbdt::histogram`) — previously relied on the
//! autovectorizer: the descent interleaved 8 independent scalar lane
//! chains and the accumulators were 4-way unrolled. This module makes
//! the vector shape explicit:
//!
//! * **Runtime dispatch, selected once.** [`tier`] probes the CPU a
//!   single time (cached in a `OnceLock`) and returns the best
//!   [`Tier`]: AVX2 (16 `u16` lanes) when detected, SSE2 (8 lanes) as
//!   the x86-64 baseline, and a portable scalar fallback everywhere
//!   else. Every kernel also accepts an explicit tier so tests and
//!   benches can force the scalar twin and assert bit-parity.
//! * **Descent** ([`descend_complete`]): one tree level advances a
//!   whole lane group with a vector unsigned-`u16` compare (signed
//!   `cmpgt` over bias-flipped operands — SSE2 has no unsigned compare)
//!   and vector index arithmetic `i ← 2i + 2 − (xb ≤ t)`. Node/code
//!   fetches per lane stay scalar (a hardware gather of `u16` elements
//!   would over-read past slice ends), which is exactly the memory-ILP
//!   shape PACSET identifies; the compare + index chain is where the
//!   vector units help. Complete trees cap at depth
//!   `MAX_COMPLETE_DEPTH = 10`, so lane indices (`≤ 2^{d+1} − 2`) fit
//!   `u16` lanes with headroom through depth 15.
//! * **Gather descent** ([`descend_complete_gather`]): the same level
//!   step over an explicit lane→row index map, so the adaptive
//!   early-exit kernel can swap-compact finished rows out of the lane
//!   groups and keep survivors densely packed; since the per-lane code
//!   fetch is scalar anyway, the indirection adds one index load per
//!   lane per level.
//! * **Oblivious descent** ([`descend_oblivious`], plus its gather
//!   twin): the CatBoost-style special case where one
//!   `(feature, threshold)` pair is shared by a whole level, so the
//!   per-lane node fetches of the general kernels disappear entirely —
//!   each level is a broadcast threshold, a shared-column code load, a
//!   vector compare, and a shift into a per-lane `2^d` leaf-table
//!   index. The one fully-vector descent in the system.
//! * **Binning** ([`count_lt`]): the per-row bin of the quantized
//!   engine is `#{b : b < v}` over a short sorted threshold table,
//!   which equals `partition_point` exactly — computed branch-free as
//!   vector compares + movemask popcounts for tables up to
//!   [`bin::LINEAR_MAX`] entries, binary search beyond.
//! * **Histogram accumulation** ([`hist`]): bin codes stream in as
//!   full vectors (dense path) or a software gather (leaf subsets),
//!   and the triple-offset arithmetic `3·code` is widened and computed
//!   in vector registers; the read-modify-write scatter into the
//!   `[g, h, c]` triples stays scalar **in row order** — two rows of a
//!   leaf can land in the same bin, so a vector scatter would need
//!   conflict detection, and preserving row order is what keeps every
//!   tier bit-identical to the scalar oracle.
//!
//! **Safety boundary:** all `unsafe` (the `core::arch` intrinsics and
//! the width-punning code-pointer casts) lives inside this module,
//! behind tier checks that clamp a requested tier to what the CPU
//! actually supports ([`Tier::clamp_detected`]). Everything exported is
//! a safe function; the rest of the crate contains no `unsafe` at all.
//!
//! **Bit-parity contract:** for identical inputs, every tier of every
//! kernel produces bit-identical outputs — descent is pure integer
//! arithmetic, and histogram accumulation performs the same `f64`
//! additions in the same row order per feature. Property-tested in
//! `tests/engine_parity.rs` and `tests/histogram_parity.rs` across all
//! tiers the running CPU supports.

pub mod bin;
pub mod descent;
pub mod hist;

pub use bin::count_lt;
pub use descent::{
    descend_complete, descend_complete_gather, descend_oblivious, descend_oblivious_gather,
    descend_oblivious_row, descend_row, SCALAR_LANES,
};
pub use hist::{accumulate_dense, accumulate_gathered, Code};

use std::sync::OnceLock;

/// A dispatch tier, ordered from portable to widest. `Ord` follows
/// capability: `Scalar < Sse2 < Avx2`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Tier {
    /// Portable fallback: the 8-row interleaved scalar descent and the
    /// 4-way unrolled accumulators (the autovectorizable twins every
    /// SIMD path is tested against). The only tier on non-x86-64.
    Scalar,
    /// x86-64 baseline: 128-bit vectors, 8 `u16` lanes.
    Sse2,
    /// 256-bit vectors, 16 `u16` lanes (runtime-detected).
    Avx2,
}

impl Tier {
    /// Human-readable name (bench output, CI logs, JSON).
    pub fn name(self) -> &'static str {
        match self {
            Tier::Scalar => "scalar",
            Tier::Sse2 => "sse2",
            Tier::Avx2 => "avx2",
        }
    }

    /// Probe the CPU (uncached — use [`tier`] on hot paths).
    ///
    /// Under Miri this always reports [`Tier::Scalar`]: the
    /// interpreter cannot execute vendor intrinsics, and the scalar
    /// twins are bit-identical by the parity contract anyway, so every
    /// Miri run exercises the portable kernels end to end.
    pub fn detect() -> Tier {
        #[cfg(miri)]
        {
            Tier::Scalar
        }
        #[cfg(all(target_arch = "x86_64", not(miri)))]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                Tier::Avx2
            } else {
                // SSE2 is architecturally guaranteed on x86-64.
                Tier::Sse2
            }
        }
        #[cfg(all(not(target_arch = "x86_64"), not(miri)))]
        {
            Tier::Scalar
        }
    }

    /// Clamp a *requested* tier to what this CPU supports, so forcing
    /// e.g. `Tier::Avx2` on an SSE2-only machine degrades safely (and
    /// bit-identically) instead of executing unsupported instructions.
    /// Every kernel entry point routes through this.
    pub fn clamp_detected(self) -> Tier {
        self.min(tier())
    }
}

/// The cached dispatch tier of this machine: detected once on first
/// use, then a single atomic load. This is what the production entry
/// points (`QuantizedFlatModel::predict_batch`, `HistogramPool::build`,
/// …) run with; the `*_with_tier` twins exist for parity tests and the
/// before/after bench pairs.
pub fn tier() -> Tier {
    static TIER: OnceLock<Tier> = OnceLock::new();
    *TIER.get_or_init(Tier::detect)
}

/// Every tier the running CPU can actually execute, widest last —
/// what the cross-tier parity property tests iterate.
pub fn available_tiers() -> Vec<Tier> {
    [Tier::Scalar, Tier::Sse2, Tier::Avx2]
        .into_iter()
        .filter(|t| *t <= tier())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detection_is_stable_and_ordered() {
        let t = tier();
        assert_eq!(t, tier(), "cached tier must be stable");
        assert_eq!(t, Tier::detect(), "cache must hold the detected tier");
        assert!(Tier::Scalar < Tier::Sse2 && Tier::Sse2 < Tier::Avx2);
        #[cfg(all(target_arch = "x86_64", not(miri)))]
        assert!(t >= Tier::Sse2, "SSE2 is the x86-64 baseline");
        #[cfg(any(not(target_arch = "x86_64"), miri))]
        assert_eq!(t, Tier::Scalar);
    }

    #[test]
    fn clamp_degrades_unsupported_tiers() {
        for requested in [Tier::Scalar, Tier::Sse2, Tier::Avx2] {
            let eff = requested.clamp_detected();
            assert!(eff <= tier());
            assert!(eff <= requested);
        }
        assert_eq!(Tier::Scalar.clamp_detected(), Tier::Scalar);
    }

    #[test]
    fn available_tiers_is_prefix_ending_at_detected() {
        let avail = available_tiers();
        assert_eq!(avail.first(), Some(&Tier::Scalar));
        assert_eq!(avail.last(), Some(&tier()));
        for w in avail.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn names_are_distinct() {
        assert_eq!(Tier::Scalar.name(), "scalar");
        assert_eq!(Tier::Sse2.name(), "sse2");
        assert_eq!(Tier::Avx2.name(), "avx2");
    }
}
